//! Graceful-shutdown flag: `install_sigint` returns a shared
//! `AtomicBool` that flips on the first SIGINT. The training loop and
//! the serve scheduler poll it at step boundaries, write a final
//! checkpoint (which carries the accountant's inputs — step count,
//! rate, sigma — so no privacy spend is lost), and exit cleanly. A
//! second SIGINT force-exits: an operator mashing Ctrl-C mid-
//! checkpoint still gets their terminal back.
//!
//! Zero-dependency: the handler is registered through libc's
//! `signal(2)`, already linked by std. Everything the handler touches
//! is a static atomic — async-signal-safe by construction.

use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, OnceLock};

static FLAG: OnceLock<Arc<AtomicBool>> = OnceLock::new();
static HITS: AtomicU32 = AtomicU32::new(0);

extern "C" fn on_sigint(_signum: i32) {
    let hits = HITS.fetch_add(1, Ordering::SeqCst);
    if hits == 0 {
        if let Some(flag) = FLAG.get() {
            flag.store(true, Ordering::SeqCst);
        }
    } else {
        extern "C" {
            fn _exit(code: i32) -> !;
        }
        // SAFETY: _exit is async-signal-safe (POSIX) and terminates
        // the process without running any user code — exactly the
        // force-exit semantics the second Ctrl-C asks for. 130 =
        // 128 + SIGINT, the conventional interrupted-exit status.
        unsafe { _exit(130) }
    }
}

/// Install the SIGINT handler (idempotent) and return the stop flag.
/// On non-unix targets the handler is not installed; the flag is
/// still returned so callers need no cfg of their own.
pub fn install_sigint() -> Arc<AtomicBool> {
    let flag = FLAG.get_or_init(|| Arc::new(AtomicBool::new(false)));
    #[cfg(unix)]
    {
        const SIGINT: i32 = 2;
        extern "C" {
            fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
        }
        // SAFETY: registering an async-signal-safe handler (it only
        // touches static atomics and _exit) for SIGINT; signal(2) is
        // the portable-enough registration path on the unix targets
        // we build for, and re-registering the same handler is a
        // no-op, so repeated calls are fine.
        unsafe {
            let _ = signal(SIGINT, on_sigint);
        }
    }
    Arc::clone(flag)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_hit_sets_flag_second_would_force_exit() {
        // drive the handler directly (raising a real SIGINT would kill
        // the whole test harness); HITS is process-global, so this
        // test owns both transitions in one body
        let flag = install_sigint();
        assert!(!flag.load(Ordering::SeqCst));
        on_sigint(2);
        assert!(flag.load(Ordering::SeqCst));
        assert_eq!(HITS.load(Ordering::SeqCst), 1);
        // the second hit calls _exit — assert only the counter's
        // state machine is armed, don't pull the trigger
        let same = install_sigint();
        assert!(Arc::ptr_eq(&flag, &same));
    }
}
