//! General-purpose substrates: JSON, logging, statistics, and the
//! counting global allocator behind the zero-allocation step checks.

pub mod alloc;
pub mod json;
pub mod logging;
pub mod signal;
pub mod stats;

use anyhow::{Context, Result};
use std::path::Path;

/// Read a whole file into a string with a path-carrying error.
pub fn read_file(path: &Path) -> Result<String> {
    std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))
}

/// Write a string to a file, creating parent dirs.
pub fn write_file(path: &Path, contents: &str) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)
            .with_context(|| format!("mkdir -p {}", parent.display()))?;
    }
    std::fs::write(path, contents)
        .with_context(|| format!("writing {}", path.display()))
}

/// Peak resident set size of this process in bytes (VmHWM from
/// /proc/self/status) — used by the memory experiment (§6.7).
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches(" kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Human-readable byte count.
pub fn fmt_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", n, UNITS[0])
    } else {
        format!("{:.1} {}", v, UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.0 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.0 MiB");
    }

    #[test]
    fn peak_rss_available_on_linux() {
        let rss = peak_rss_bytes();
        assert!(rss.is_some());
        assert!(rss.unwrap() > 1024 * 1024); // a process uses >1MiB
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("fastclip_util_test");
        let path = dir.join("sub/file.txt");
        write_file(&path, "hello").unwrap();
        assert_eq!(read_file(&path).unwrap(), "hello");
        std::fs::remove_dir_all(&dir).ok();
    }
}
