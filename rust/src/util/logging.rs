//! Leveled stderr logger (no `log`/`env_logger` facade needed for a
//! single binary; level comes from `FASTCLIP_LOG` or the CLI).

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level_from_env() {
    if let Ok(v) = std::env::var("FASTCLIP_LOG") {
        let lvl = match v.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            _ => Level::Info,
        };
        set_level(lvl);
    }
}

pub fn enabled(level: Level) -> bool {
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
    };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{:9.3}s {}] {}", t, tag, args);
}

#[macro_export]
macro_rules! log_error { ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_warn { ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_info { ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_debug { ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($a)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
