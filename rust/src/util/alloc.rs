//! Heap-allocation accounting: a counting wrapper around the system
//! allocator, installed process-wide as the crate's global allocator.
//!
//! Two consumers rely on the counter:
//!   - `tests/no_alloc.rs` asserts the warm `StepFn::run_into` path
//!     performs **zero** heap allocations (the point of the caller-
//!     owned `StepOut` arena);
//!   - the bench matrix probes the same property at bench time and
//!     records it as `steps_alloc_free` in the `BENCH_history.jsonl`
//!     trajectory, so CI notices if an allocation sneaks back into
//!     the hot loop.
//!
//! Cost: one relaxed atomic increment per allocation — unmeasurable
//! next to the allocation itself, so the counter stays on in release
//! builds (the bench probe needs it there). Installation is gated on
//! the default-on `alloc-count` cargo feature: a downstream consumer
//! of the library that wants its own `#[global_allocator]` builds
//! with `default-features = false`, and `counting_enabled()` lets the
//! probe report "not measured" instead of a vacuous zero delta.
//! (A plain `#[cfg(test)]` gate would not work: integration tests
//! link the library compiled *without* `cfg(test)`.)

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

/// System allocator wrapper that counts every allocation event
/// (`alloc`, `alloc_zeroed`, and growth via `realloc`). Frees are not
/// counted — the probe looks for allocation pressure, not leaks.
pub struct CountingAllocator;

// SAFETY: delegates every operation verbatim to `System`; the only
// addition is a relaxed counter increment, which cannot affect
// allocator correctness.
unsafe impl GlobalAlloc for CountingAllocator {
    // SAFETY: forwards `layout` unchanged to `System::alloc`, which
    // upholds the GlobalAlloc contract for any layout the caller was
    // required to make valid.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    // SAFETY: forwards `layout` unchanged to `System::alloc_zeroed`;
    // no bytes are touched here, so the zeroing guarantee is System's.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    // SAFETY: `ptr`/`layout`/`new_size` pass through untouched; the
    // caller's obligations (ptr from this allocator, layout matches,
    // new_size nonzero) are exactly System's preconditions.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    // SAFETY: pure pass-through; the caller guarantees `ptr` came from
    // this allocator with `layout`, which is System's precondition.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[cfg(feature = "alloc-count")]
#[global_allocator]
static GLOBAL: CountingAllocator = CountingAllocator;

/// Whether the counting allocator is actually installed. When the
/// `alloc-count` feature is off, `allocation_count` never moves — a
/// delta of zero would then be vacuous, so probes must check this
/// first.
pub fn counting_enabled() -> bool {
    cfg!(feature = "alloc-count")
}

/// Total allocation events since process start (process-wide — callers
/// measuring a delta must not race other allocating threads).
pub fn allocation_count() -> u64 {
    ALLOCATIONS.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drives every `CountingAllocator` method directly through raw
    /// `Layout` calls — independent of the `#[global_allocator]`
    /// installation, so the crate's only native `unsafe` is reachable
    /// under `cargo miri test --no-default-features` (the sanitizer
    /// lane runs with `alloc-count` off).
    #[test]
    fn counting_allocator_roundtrip_raw() {
        let a = CountingAllocator;
        let layout = Layout::from_size_align(64, 8).expect("valid layout");
        let before = allocation_count();
        // SAFETY: `layout` has nonzero size; every pointer below is
        // used only while live, written within its allocated size, and
        // freed exactly once with the layout it was (re)allocated as.
        unsafe {
            let p = a.alloc(layout);
            assert!(!p.is_null());
            std::ptr::write_bytes(p, 0xAB, 64);
            assert_eq!(*p, 0xAB);

            let grown = a.realloc(p, layout, 128);
            assert!(!grown.is_null());
            // the prefix survives realloc
            assert_eq!(*grown, 0xAB);
            let grown_layout = Layout::from_size_align(128, 8).expect("valid layout");
            a.dealloc(grown, grown_layout);

            let z = a.alloc_zeroed(layout);
            assert!(!z.is_null());
            assert_eq!(*z, 0);
            assert_eq!(*z.add(63), 0);
            a.dealloc(z, layout);
        }
        assert!(
            allocation_count() - before >= 3,
            "alloc + realloc + alloc_zeroed must each bump the counter"
        );
    }

    #[cfg(feature = "alloc-count")]
    #[test]
    fn counter_observes_allocations() {
        let before = allocation_count();
        let v: Vec<u64> = Vec::with_capacity(64);
        std::hint::black_box(&v);
        assert!(
            allocation_count() > before,
            "a fresh Vec allocation must bump the counter"
        );
    }
}
