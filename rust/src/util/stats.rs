//! Small statistics helpers shared by the bench harness and metrics.

/// Summary statistics over a sample of measurements.
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: s[0],
            p50: percentile(&s, 0.50),
            p95: percentile(&s, 0.95),
            max: s[n - 1],
        }
    }
}

/// Linear-interpolated percentile of an already-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Exponential moving average (loss smoothing in train logs).
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Ema {
        assert!((0.0..=1.0).contains(&alpha));
        Ema { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let s = [10.0, 20.0, 30.0, 40.0];
        assert!((percentile(&s, 0.0) - 10.0).abs() < 1e-12);
        assert!((percentile(&s, 1.0) - 40.0).abs() < 1e-12);
        assert!((percentile(&s, 0.5) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        assert_eq!(e.update(10.0), 10.0);
        let v = e.update(0.0);
        assert!((v - 5.0).abs() < 1e-12);
        for _ in 0..50 {
            e.update(1.0);
        }
        assert!((e.get().unwrap() - 1.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic]
    fn summary_rejects_empty() {
        Summary::of(&[]);
    }
}
