//! Minimal JSON parser + writer.
//!
//! The offline crate universe has no `serde`/`serde_json`, so the
//! manifest, run configs, checkpoints, and bench reports go through
//! this hand-rolled implementation. It supports the full JSON grammar
//! (RFC 8259) minus some exotic corner cases we have no use for
//! (surrogate-pair escapes are accepted and decoded; numbers are f64).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors ------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // ---- accessors ----------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["a"]["b"]`-style access; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn set(&mut self, key: &str, val: Json) {
        if let Json::Obj(o) = self {
            o.insert(key.to_string(), val);
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ---- parsing -------------------------------------------------------
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let bytes = src.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != bytes.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    // ---- writing ---------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() && n == n.trunc() && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else if n.is_finite() {
        out.push_str(&format!("{}", n));
    } else {
        // JSON has no Inf/NaN; emit null like most serializers
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{}`", word)))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    0x10000
                                        + ((hi - 0xD800) << 10)
                                        + (lo - 0xDC00)
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                hi
                            };
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            continue; // hex4 advanced i already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy one UTF-8 char
                    let start = self.i;
                    let rest = &self.b[start..];
                    let len = utf8_len(rest[0]);
                    if rest.len() < len {
                        return Err(self.err("truncated utf-8"));
                    }
                    s.push_str(
                        std::str::from_utf8(&rest[..len])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                    self.i += len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.b.len() < self.i + 4 {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b0: u8) -> usize {
    if b0 < 0x80 {
        1
    } else if b0 < 0xE0 {
        2
    } else if b0 < 0xF0 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(
            Json::parse("\"hi\\nthere\"").unwrap(),
            Json::Str("hi\nthere".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("c"));
        assert!(v.get("d").is_null());
        assert!(v.get("missing").is_null());
    }

    #[test]
    fn parses_unicode_escapes() {
        assert_eq!(
            Json::parse(r#""é😀""#).unwrap(),
            Json::Str("é😀".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"k\" 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"b":true,"nested":{"k":[{"deep":null}]},"s":"line\nbreak \"quoted\""}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
        // pretty output parses to the same value too
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::obj());
        assert_eq!(Json::Arr(vec![]).to_string(), "[]");
        assert_eq!(Json::obj().to_string(), "{}");
    }

    #[test]
    fn builder_api() {
        let mut o = Json::obj();
        o.set("n", 3usize.into());
        o.set("s", "str".into());
        o.set("v", vec![1.0f64, 2.0].into());
        let txt = o.to_string();
        let back = Json::parse(&txt).unwrap();
        assert_eq!(back.get("n").as_usize(), Some(3));
        assert_eq!(back.get("v").as_arr().unwrap().len(), 2);
    }

    #[test]
    fn big_ints_survive() {
        // manifest sizes etc. stay exact below 2^53
        let v = Json::parse("9007199254740992").unwrap();
        assert_eq!(v.to_string(), "9007199254740992");
    }
}
