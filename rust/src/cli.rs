//! Declarative-ish CLI flag parsing (no `clap` offline): subcommand +
//! `--key value` / `--flag` arguments with typed accessors.

use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;

/// Parse a batch sweep flag: `"16..512"` doubles from lo while it
/// stays <= hi (16,32,...,512); `"16,32,48"` is an explicit list;
/// `"128"` a single batch. The doubling form is how the paper's
/// speedup-vs-batch curves are sampled.
pub fn parse_batches(s: &str) -> Result<Vec<usize>> {
    let s = s.trim();
    if let Some((lo, hi)) = s.split_once("..") {
        let lo: usize = lo.trim().parse().with_context(|| {
            format!("batch sweep {s:?}: expected `LO..HI` with integers")
        })?;
        let hi: usize = hi.trim().parse().with_context(|| {
            format!("batch sweep {s:?}: expected `LO..HI` with integers")
        })?;
        ensure!(lo >= 1 && hi >= lo, "batch sweep {s:?}: need 1 <= LO <= HI");
        let mut out = Vec::new();
        let mut b = lo;
        while b <= hi {
            out.push(b);
            // checked: an unchecked `b *= 2` would wrap to 0 in release
            // builds near usize::MAX and loop forever
            match b.checked_mul(2) {
                Some(next) => b = next,
                None => break,
            }
        }
        return Ok(out);
    }
    let out: Vec<usize> = s
        .split(',')
        .map(|p| {
            p.trim().parse::<usize>().with_context(|| {
                format!("batch list {s:?}: expected comma-separated integers")
            })
        })
        .collect::<Result<_>>()?;
    ensure!(
        !out.is_empty() && out.iter().all(|&b| b >= 1),
        "batch list {s:?} must be non-empty, every batch >= 1"
    );
    Ok(out)
}

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`: first non-flag token is the subcommand;
    /// `--key value` pairs and bare `--switch`es follow.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                let key = key.to_string();
                // value if next token exists and is not a flag
                let is_switch = match it.peek() {
                    None => true,
                    Some(next) => next.starts_with("--"),
                };
                if is_switch {
                    out.flags.insert(key, "true".to_string());
                } else {
                    out.flags.insert(key, it.next().unwrap());
                }
            } else if out.subcommand.is_empty() {
                out.subcommand = tok;
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        Ok(self.u64_or(key, default as u64)? as usize)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.str_opt(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn require(&self, key: &str) -> Result<&str> {
        match self.str_opt(key) {
            Some(v) => Ok(v),
            None => bail!("missing required flag --{key}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("train --config mlp2_mnist_b32 --steps 100 --poisson --lr 0.001");
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.str_opt("config"), Some("mlp2_mnist_b32"));
        assert_eq!(a.u64_or("steps", 0).unwrap(), 100);
        assert!(a.bool("poisson"));
        assert!(!a.bool("missing"));
        assert!((a.f64_or("lr", 0.0).unwrap() - 0.001).abs() < 1e-12);
    }

    #[test]
    fn switch_at_end_and_before_flag() {
        let a = parse("bench --fast --config x");
        assert!(a.bool("fast"));
        assert_eq!(a.str_opt("config"), Some("x"));
        let b = parse("bench --config x --fast");
        assert!(b.bool("fast"));
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("train");
        assert_eq!(a.f64_or("clip", 1.0).unwrap(), 1.0);
        assert!(a.require("config").is_err());
        let bad = parse("train --steps abc");
        assert!(bad.u64_or("steps", 1).is_err());
    }

    #[test]
    fn positionals() {
        let a = parse("inspect cfg1 cfg2");
        assert_eq!(a.positional, vec!["cfg1", "cfg2"]);
    }

    #[test]
    fn batch_sweeps() {
        assert_eq!(
            parse_batches("16..512").unwrap(),
            vec![16, 32, 64, 128, 256, 512]
        );
        // hi off the doubling chain truncates below it
        assert_eq!(parse_batches("16..100").unwrap(), vec![16, 32, 64]);
        assert_eq!(parse_batches("16,32,48").unwrap(), vec![16, 32, 48]);
        assert_eq!(parse_batches(" 128 ").unwrap(), vec![128]);
        assert_eq!(parse_batches("1..1").unwrap(), vec![1]);
        for bad in ["", "0..8", "8..4", "a..b", "16,,32", "16,0"] {
            assert!(parse_batches(bad).is_err(), "{bad:?} parsed");
        }
    }
}
