//! Differential privacy substrate: RDP accounting (the paper's Moment
//! Accountant, Sec 2.2) and noise calibration.
//!
//! The coordinator's DP methods (reweight / multiloss / nxbp) all add
//! noise `N(0, (sigma * c / tau)^2)` to the *averaged* clipped gradient
//! — equivalent to `N(0, (sigma * c)^2)` on the clipped sum whose L2
//! sensitivity is c (Definition 4) — and charge the accountant one
//! subsampled-Gaussian step per iteration.

pub mod calibrate;
pub mod rdp;

pub use calibrate::{calibrate_sigma, epsilon_for, max_steps};
pub use rdp::{sgm_rdp_step, RdpAccountant};

/// Noise standard deviation to add to the gradient *average* for one
/// step: the clipped-sum query has sensitivity `clip`, the mechanism
/// adds sigma*clip noise to the sum, and dividing by tau scales it.
pub fn noise_stddev_for_mean(sigma: f64, clip: f64, tau: usize) -> f64 {
    sigma * clip / tau as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noise_scale_matches_mechanism() {
        // sigma=1.1, c=1.0, tau=32: noise on the mean is sigma*c/32
        let s = noise_stddev_for_mean(1.1, 1.0, 32);
        assert!((s - 1.1 / 32.0).abs() < 1e-12);
    }
}
