//! Rényi differential privacy accountant for the subsampled Gaussian
//! mechanism — the paper's privacy machinery (Sec 2.2; the "Moment
//! Accountant" of Abadi et al. [2], in its RDP formulation, Mironov).
//!
//! One DP-SGD step = Poisson-subsample the dataset with rate q, clip
//! per-example gradients to L2 norm c, sum, add N(0, (sigma*c)^2 I).
//! For integer orders alpha >= 2 the per-step RDP cost is
//!
//!   eps(alpha) = 1/(alpha-1) * log( sum_{k=0}^{alpha} C(alpha,k)
//!                  (1-q)^(alpha-k) q^k exp(k(k-1)/(2 sigma^2)) )
//!
//! computed in log-space. T steps compose additively per order
//! (Lemma 3); the final (eps, delta) is the minimum over orders of the
//! Lemma 1 conversion  eps' = eps_rdp(alpha) + log(1/delta)/(alpha-1).

/// Default integer RDP orders tracked by the accountant.
pub fn default_orders() -> Vec<u32> {
    let mut orders: Vec<u32> = (2..=64).collect();
    orders.extend([80, 96, 128, 160, 192, 256]);
    orders
}

/// log(exp(a) + exp(b)) without overflow.
fn log_add(a: f64, b: f64) -> f64 {
    if a == f64::NEG_INFINITY {
        return b;
    }
    if b == f64::NEG_INFINITY {
        return a;
    }
    let (hi, lo) = if a > b { (a, b) } else { (b, a) };
    hi + (lo - hi).exp().ln_1p()
}

/// log of the binomial coefficient C(n, k) via ln-gamma.
fn log_binom(n: u32, k: u32) -> f64 {
    ln_gamma(n as f64 + 1.0) - ln_gamma(k as f64 + 1.0)
        - ln_gamma((n - k) as f64 + 1.0)
}

/// Lanczos approximation of ln Gamma(x) for x > 0 (|err| < 1e-10 over
/// the range used here).
pub fn ln_gamma(x: f64) -> f64 {
    // g = 7, n = 9 Lanczos coefficients
    const COEF: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // reflection formula
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Per-step RDP cost of the subsampled Gaussian mechanism at integer
/// order `alpha`, sampling rate `q`, noise multiplier `sigma`
/// (noise stddev = sigma * sensitivity).
pub fn sgm_rdp_step(q: f64, sigma: f64, alpha: u32) -> f64 {
    assert!(alpha >= 2, "RDP orders start at 2");
    assert!((0.0..=1.0).contains(&q), "sampling rate in [0,1]");
    assert!(sigma > 0.0, "sigma must be positive");
    if q == 0.0 {
        return 0.0;
    }
    if q == 1.0 {
        // plain Gaussian mechanism: eps(alpha) = alpha / (2 sigma^2)
        return alpha as f64 / (2.0 * sigma * sigma);
    }
    let log_q = q.ln();
    let log_1mq = (-q).ln_1p(); // log(1-q)
    let mut log_a = f64::NEG_INFINITY;
    for k in 0..=alpha {
        let term = log_binom(alpha, k)
            + (alpha - k) as f64 * log_1mq
            + k as f64 * log_q
            + (k as f64 * (k as f64 - 1.0)) / (2.0 * sigma * sigma);
        log_a = log_add(log_a, term);
    }
    log_a / (alpha as f64 - 1.0)
}

/// Accumulated RDP over all tracked orders + conversion to (eps, delta).
#[derive(Debug, Clone)]
pub struct RdpAccountant {
    orders: Vec<u32>,
    /// total eps per order (composition is additive, Lemma 3)
    totals: Vec<f64>,
    pub steps: u64,
}

impl RdpAccountant {
    pub fn new() -> Self {
        Self::with_orders(default_orders())
    }

    pub fn with_orders(orders: Vec<u32>) -> Self {
        assert!(!orders.is_empty());
        let n = orders.len();
        RdpAccountant { orders, totals: vec![0.0; n], steps: 0 }
    }

    /// Account one subsampled-Gaussian step.
    pub fn step(&mut self, q: f64, sigma: f64) {
        for (i, &alpha) in self.orders.iter().enumerate() {
            self.totals[i] += sgm_rdp_step(q, sigma, alpha);
        }
        self.steps += 1;
    }

    /// Account `t` identical steps at once.
    pub fn steps(&mut self, q: f64, sigma: f64, t: u64) {
        if t == 0 {
            return;
        }
        for (i, &alpha) in self.orders.iter().enumerate() {
            self.totals[i] += t as f64 * sgm_rdp_step(q, sigma, alpha);
        }
        self.steps += t;
    }

    /// Best (eps, order) for a target delta via Lemma 1:
    /// eps' = eps_rdp(alpha) + log(1/delta) / (alpha - 1).
    pub fn epsilon(&self, delta: f64) -> (f64, u32) {
        assert!(delta > 0.0 && delta < 1.0);
        let log_inv_delta = (1.0 / delta).ln();
        let mut best = (f64::INFINITY, self.orders[0]);
        for (i, &alpha) in self.orders.iter().enumerate() {
            let eps = self.totals[i] + log_inv_delta / (alpha as f64 - 1.0);
            if eps < best.0 {
                best = (eps, alpha);
            }
        }
        best
    }

    /// RDP epsilon at a specific order (for reporting).
    pub fn rdp_at(&self, alpha: u32) -> Option<f64> {
        self.orders
            .iter()
            .position(|&a| a == alpha)
            .map(|i| self.totals[i])
    }

    pub fn orders(&self) -> &[u32] {
        &self.orders
    }
}

impl Default for RdpAccountant {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_known_values() {
        // Gamma(n) = (n-1)!
        assert!((ln_gamma(1.0) - 0.0).abs() < 1e-9);
        assert!((ln_gamma(2.0) - 0.0).abs() < 1e-9);
        assert!((ln_gamma(5.0) - (24.0f64).ln()).abs() < 1e-9);
        assert!((ln_gamma(11.0) - (3628800.0f64).ln()).abs() < 1e-8);
        // Gamma(1/2) = sqrt(pi)
        assert!((ln_gamma(0.5) - 0.5 * std::f64::consts::PI.ln()).abs() < 1e-9);
    }

    #[test]
    fn log_binom_matches_pascal() {
        for n in 2..20u32 {
            let mut row = vec![1u64];
            for _ in 0..n {
                let mut next = vec![1u64];
                for w in row.windows(2) {
                    next.push(w[0] + w[1]);
                }
                next.push(1);
                row = next;
            }
            for (k, &v) in row.iter().enumerate() {
                let lb = log_binom(n, k as u32);
                assert!(
                    (lb - (v as f64).ln()).abs() < 1e-8,
                    "C({},{})",
                    n,
                    k
                );
            }
        }
    }

    #[test]
    fn q1_reduces_to_gaussian() {
        for &sigma in &[0.8, 1.0, 2.0] {
            for &alpha in &[2u32, 8, 32] {
                let got = sgm_rdp_step(1.0, sigma, alpha);
                let want = alpha as f64 / (2.0 * sigma * sigma);
                assert!((got - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn alpha2_closed_form() {
        // A(2) = 1 + q^2 (e^{1/sigma^2} - 1)  =>  eps(2) = ln A(2)
        for &(q, sigma) in &[(0.01, 1.0), (0.05, 1.5), (0.2, 0.9)] {
            let got = sgm_rdp_step(q, sigma, 2);
            let want = (1.0 + q * q * ((1.0 / (sigma * sigma)).exp() - 1.0)).ln();
            assert!(
                (got - want).abs() < 1e-10,
                "q={} sigma={}: {} vs {}",
                q,
                sigma,
                got,
                want
            );
        }
    }

    #[test]
    fn zero_sampling_is_free() {
        assert_eq!(sgm_rdp_step(0.0, 1.0, 8), 0.0);
    }

    #[test]
    fn monotone_in_q_sigma_alpha() {
        // more sampling, less noise, higher moments => more leakage
        let base = sgm_rdp_step(0.01, 1.0, 16);
        assert!(sgm_rdp_step(0.02, 1.0, 16) > base);
        assert!(sgm_rdp_step(0.01, 0.8, 16) > base);
        assert!(sgm_rdp_step(0.01, 1.0, 32) > base);
        assert!(sgm_rdp_step(0.005, 1.0, 16) < base);
        assert!(sgm_rdp_step(0.01, 2.0, 16) < base);
    }

    #[test]
    fn composition_is_additive() {
        let mut a = RdpAccountant::new();
        a.steps(0.01, 1.1, 100);
        let mut b = RdpAccountant::new();
        for _ in 0..100 {
            b.step(0.01, 1.1);
        }
        for &alpha in a.orders().iter() {
            let (x, y) = (a.rdp_at(alpha).unwrap(), b.rdp_at(alpha).unwrap());
            assert!((x - y).abs() < 1e-9 * x.max(1.0));
        }
        let (ea, _) = a.epsilon(1e-5);
        let (eb, _) = b.epsilon(1e-5);
        assert!((ea - eb).abs() < 1e-9);
    }

    #[test]
    fn epsilon_grows_with_steps_and_shrinks_with_delta() {
        let mut acc = RdpAccountant::new();
        acc.steps(0.01, 1.1, 100);
        let (e100, _) = acc.epsilon(1e-5);
        acc.steps(0.01, 1.1, 900);
        let (e1000, _) = acc.epsilon(1e-5);
        assert!(e1000 > e100);
        let (loose, _) = acc.epsilon(1e-3);
        let (tight, _) = acc.epsilon(1e-7);
        assert!(loose < tight);
    }

    #[test]
    fn typical_dpsgd_budget_is_single_digit() {
        // Abadi-style setting: n=60k, batch 600 => q=0.01, sigma=1.1,
        // one epoch = 100 steps; 10 epochs. eps should be small single
        // digits at delta=1e-5 — a sanity band, not an exact golden.
        let mut acc = RdpAccountant::new();
        acc.steps(0.01, 1.1, 1000);
        let (eps, order) = acc.epsilon(1e-5);
        assert!(eps > 0.5 && eps < 10.0, "eps={} (order {})", eps, order);
    }

    /// Golden per-step RDP values, cross-checked against an
    /// independent high-precision Python implementation of Mironov's
    /// integer-order formula — the same algorithm TF-Privacy's
    /// `compute_rdp` / Opacus's RDP accountant use for integer alpha.
    #[test]
    fn sgm_rdp_step_golden_values() {
        let cases: &[(f64, f64, u32, f64)] = &[
            (0.01, 1.1, 2, 1.285100816052e-4),
            (0.01, 1.1, 16, 1.699826727753),
            (0.01, 1.1, 64, 2.176801286629e1),
            (0.1, 1.0, 8, 1.378361411348),
            (0.02, 2.0, 32, 1.744070602385e-2),
        ];
        for &(q, sigma, alpha, want) in cases {
            let got = sgm_rdp_step(q, sigma, alpha);
            assert!(
                ((got - want) / want).abs() < 1e-6,
                "sgm_rdp_step({q}, {sigma}, {alpha}) = {got}, want {want}"
            );
        }
    }

    /// End-to-end accountant goldens at known (q, sigma, T, delta)
    /// points. The Abadi-style MNIST setting (n=60000, batch 256,
    /// sigma=1.1, 60 epochs, delta=1e-5) lands at eps ~= 3.0 — the
    /// value TF-Privacy's compute_dp_sgd_privacy reports for the same
    /// inputs over integer orders.
    #[test]
    fn accountant_golden_values() {
        let cases: &[(f64, f64, u64, f64, f64, u32)] = &[
            (0.01, 1.1, 1000, 1e-5, 2.0867961136, 10),
            (0.01, 1.1, 10000, 1e-5, 6.2798110296, 5),
            (256.0 / 60000.0, 1.1, 14040, 1e-5, 3.0066432859, 9),
            (0.04, 0.8, 500, 1e-5, 11.7492452808, 3),
            (0.001, 2.0, 5000, 1e-6, 0.2996716499, 54),
            (1.0, 5.0, 100, 1e-5, 11.7564627325, 3),
        ];
        for &(q, sigma, t, delta, want_eps, want_order) in cases {
            let mut acc = RdpAccountant::new();
            acc.steps(q, sigma, t);
            let (eps, order) = acc.epsilon(delta);
            assert!(
                ((eps - want_eps) / want_eps).abs() < 1e-6,
                "q={q} sigma={sigma} T={t}: eps {eps}, want {want_eps}"
            );
            assert_eq!(order, want_order, "q={q} sigma={sigma} T={t}");
        }
    }

    #[test]
    fn log_add_edge_cases() {
        // identity element: -inf
        assert_eq!(log_add(f64::NEG_INFINITY, 3.5), 3.5);
        assert_eq!(log_add(3.5, f64::NEG_INFINITY), 3.5);
        assert_eq!(
            log_add(f64::NEG_INFINITY, f64::NEG_INFINITY),
            f64::NEG_INFINITY
        );
        // symmetric and exact on equal args: log(2e^x) = x + ln 2
        let x = -700.0; // would underflow without log-space
        assert!((log_add(x, x) - (x + 2f64.ln())).abs() < 1e-12);
        assert!((log_add(1.0, 2.0) - log_add(2.0, 1.0)).abs() < 1e-15);
        // against direct computation in a safe range
        let want = (1.0f64.exp() + 2.5f64.exp()).ln();
        assert!((log_add(1.0, 2.5) - want).abs() < 1e-12);
    }

    #[test]
    fn log_binom_edge_cases() {
        // C(n, 0) = C(n, n) = 1
        for n in [2u32, 7, 64, 256] {
            assert!(log_binom(n, 0).abs() < 1e-9, "C({n},0)");
            assert!(log_binom(n, n).abs() < 1e-9, "C({n},{n})");
        }
        // C(5, 2) = 10
        assert!((log_binom(5, 2) - 10f64.ln()).abs() < 1e-9);
        // large-n values stay finite and monotone to the middle
        assert!(log_binom(256, 128) > log_binom(256, 1));
        assert!(log_binom(256, 128).is_finite());
    }

    #[test]
    fn ln_gamma_reflection_and_small_args() {
        // reflection branch (x < 0.5): Gamma(1/4)Gamma(3/4) = pi*sqrt(2)
        let want = (std::f64::consts::PI * 2f64.sqrt()).ln();
        assert!((ln_gamma(0.25) + ln_gamma(0.75) - want).abs() < 1e-9);
        // Gamma(1.5) = sqrt(pi)/2
        let want = (std::f64::consts::PI.sqrt() / 2.0).ln();
        assert!((ln_gamma(1.5) - want).abs() < 1e-9);
    }

    #[test]
    fn pure_gaussian_conversion_beats_naive() {
        // For a single Gaussian step the minimum over orders must be
        // no worse than the alpha=2 conversion.
        let mut acc = RdpAccountant::new();
        acc.step(1.0, 1.0);
        let (eps, _) = acc.epsilon(1e-5);
        let naive = 2.0 / 2.0 + (1e5f64).ln() / 1.0;
        assert!(eps <= naive + 1e-12);
    }
}
