//! Noise calibration: find the smallest noise multiplier sigma that
//! keeps T steps of DP-SGD within a target (eps, delta) — Alg 1 line 1
//! ("Use Moment Accountant to determine noise variance").

use super::rdp::RdpAccountant;

/// Epsilon spent by T subsampled-Gaussian steps at (q, sigma).
pub fn epsilon_for(q: f64, sigma: f64, steps: u64, delta: f64) -> f64 {
    let mut acc = RdpAccountant::new();
    acc.steps(q, sigma, steps);
    acc.epsilon(delta).0
}

/// Smallest sigma (within `tol`) such that T steps cost at most
/// `target_eps` at `delta`. Returns None if even sigma = `hi` is not
/// enough (caller should reduce steps or q).
pub fn calibrate_sigma(
    q: f64,
    steps: u64,
    target_eps: f64,
    delta: f64,
) -> Option<f64> {
    calibrate_sigma_in(q, steps, target_eps, delta, 0.3, 200.0, 1e-4)
}

pub fn calibrate_sigma_in(
    q: f64,
    steps: u64,
    target_eps: f64,
    delta: f64,
    lo: f64,
    hi: f64,
    tol: f64,
) -> Option<f64> {
    assert!(lo > 0.0 && hi > lo);
    if epsilon_for(q, hi, steps, delta) > target_eps {
        return None; // infeasible even at max noise
    }
    let (mut lo, mut hi) = (lo, hi);
    if epsilon_for(q, lo, steps, delta) <= target_eps {
        return Some(lo); // already feasible at min noise
    }
    // eps is monotone decreasing in sigma => bisect
    while hi - lo > tol {
        let mid = 0.5 * (lo + hi);
        if epsilon_for(q, mid, steps, delta) <= target_eps {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    Some(hi)
}

/// How many steps fit within (target_eps, delta) at fixed (q, sigma)?
/// (Useful for "train until the budget is spent" schedules.)
pub fn max_steps(q: f64, sigma: f64, target_eps: f64, delta: f64) -> u64 {
    // exponential probe then bisect; eps is monotone in steps
    if epsilon_for(q, sigma, 1, delta) > target_eps {
        return 0;
    }
    let mut hi = 1u64;
    while epsilon_for(q, sigma, hi, delta) <= target_eps {
        hi = hi.saturating_mul(2);
        if hi > 1 << 32 {
            return hi; // effectively unbounded for our runs
        }
    }
    let mut lo = hi / 2;
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        if epsilon_for(q, sigma, mid, delta) <= target_eps {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibrated_sigma_hits_target() {
        let (q, steps, eps, delta) = (0.01, 1000, 2.0, 1e-5);
        let sigma = calibrate_sigma(q, steps, eps, delta).unwrap();
        let got = epsilon_for(q, sigma, steps, delta);
        assert!(got <= eps + 1e-6, "eps {} > target {}", got, eps);
        // and it is tight: slightly less noise would blow the budget
        let spent = epsilon_for(q, sigma - 5e-3, steps, delta);
        assert!(spent > eps, "calibration not tight: {} <= {}", spent, eps);
    }

    #[test]
    fn more_budget_needs_less_noise() {
        let s1 = calibrate_sigma(0.01, 1000, 1.0, 1e-5).unwrap();
        let s4 = calibrate_sigma(0.01, 1000, 4.0, 1e-5).unwrap();
        assert!(s4 < s1, "sigma({})={} vs sigma({})={}", 4.0, s4, 1.0, s1);
    }

    #[test]
    fn more_steps_need_more_noise() {
        let s100 = calibrate_sigma(0.01, 100, 2.0, 1e-5).unwrap();
        let s10k = calibrate_sigma(0.01, 10_000, 2.0, 1e-5).unwrap();
        assert!(s10k > s100);
    }

    #[test]
    fn max_steps_inverse_of_epsilon() {
        let (q, sigma, eps, delta) = (0.01, 1.5, 2.0, 1e-5);
        let t = max_steps(q, sigma, eps, delta);
        assert!(t > 0);
        assert!(epsilon_for(q, sigma, t, delta) <= eps);
        assert!(epsilon_for(q, sigma, t + 1, delta) > eps);
    }

    #[test]
    fn infeasible_returns_none() {
        // eps=0.0001 with q=0.5 and 1e6 steps cannot be met by sigma<=200
        assert!(calibrate_sigma(0.5, 1_000_000, 1e-4, 1e-5).is_none());
    }
}
