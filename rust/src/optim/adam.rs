//! Adam (Kingma & Ba) with the paper's experimental defaults
//! (Sec 6.1): lr 0.001, beta1 0.9, beta2 0.999. The "DP" in DP-Adam
//! lives upstream: the gradient fed here already carries the clipped
//! average plus Gaussian noise.

use super::Optimizer;
use crate::runtime::GradVec;

pub struct Adam {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    t: u64,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl Adam {
    pub fn new(lr: f64) -> Adam {
        Adam::with_betas(lr, 0.9, 0.999, 1e-8)
    }

    pub fn with_betas(lr: f64, beta1: f64, beta2: f64, eps: f64) -> Adam {
        assert!(lr > 0.0 && (0.0..1.0).contains(&beta1) && (0.0..1.0).contains(&beta2));
        Adam { lr, beta1, beta2, eps, t: 0, m: Vec::new(), v: Vec::new() }
    }

    fn ensure_state(&mut self, params: &[Vec<f32>]) {
        if self.m.len() != params.len() {
            self.m = params.iter().map(|p| vec![0.0; p.len()]).collect();
            self.v = params.iter().map(|p| vec![0.0; p.len()]).collect();
        }
    }

    pub fn step_count(&self) -> u64 {
        self.t
    }
}

impl Optimizer for Adam {
    fn step(&mut self, params: &mut [Vec<f32>], grads: &GradVec) {
        assert_eq!(params.len(), grads.n_params());
        self.ensure_state(params);
        self.t += 1;
        let (b1, b2) = (self.beta1 as f32, self.beta2 as f32);
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        // fold bias correction into the step size
        let alpha = (self.lr * bc2.sqrt() / bc1) as f32;
        let eps = self.eps as f32;
        for k in 0..params.len() {
            let (p, g) = (&mut params[k], grads.param(k));
            let (m, v) = (&mut self.m[k], &mut self.v[k]);
            assert_eq!(p.len(), g.len());
            for i in 0..p.len() {
                m[i] = b1 * m[i] + (1.0 - b1) * g[i];
                v[i] = b2 * v[i] + (1.0 - b2) * g[i] * g[i];
                p[i] -= alpha * m[i] / (v[i].sqrt() + eps);
            }
        }
    }

    fn name(&self) -> &'static str {
        "adam"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_matches_paper_formula() {
        // With m=v=0: m1 = (1-b1) g, v1 = (1-b2) g^2;
        // mhat = g, vhat = g^2; update = lr * g / (|g| + eps) ~ lr*sign(g)
        let mut p = vec![vec![1.0f32]];
        let g = GradVec::from_vecs(&[vec![0.5f32]]);
        let mut opt = Adam::new(0.001);
        opt.step(&mut p, &g);
        assert!((p[0][0] - (1.0 - 0.001)).abs() < 1e-5, "{}", p[0][0]);
    }

    #[test]
    fn converges_on_quadratic() {
        let mut p = vec![vec![-4.0f32]];
        let mut opt = Adam::new(0.05);
        for _ in 0..2000 {
            let g = GradVec::from_vecs(&[vec![2.0 * (p[0][0] - 3.0)]]);
            opt.step(&mut p, &g);
        }
        assert!((p[0][0] - 3.0).abs() < 1e-2, "{}", p[0][0]);
    }

    #[test]
    fn state_tracks_multiple_tensors() {
        let mut p = vec![vec![0.0f32; 3], vec![0.0f32; 2]];
        let g = GradVec::from_vecs(&[vec![1.0f32; 3], vec![-1.0f32; 2]]);
        let mut opt = Adam::new(0.1);
        for _ in 0..10 {
            opt.step(&mut p, &g);
        }
        assert!(p[0].iter().all(|&x| x < 0.0));
        assert!(p[1].iter().all(|&x| x > 0.0));
        assert_eq!(opt.step_count(), 10);
    }

    #[test]
    fn finite_under_noisy_gradients() {
        // DP setting: heavy noise must not produce NaN/Inf
        use crate::rng::Gaussian;
        let mut gauss = Gaussian::seeded(1, 0);
        let mut p = vec![vec![0.0f32; 16]];
        let mut opt = Adam::new(0.001);
        for _ in 0..500 {
            let mut g = GradVec::with_layout(&[16]);
            gauss.add_noise_f32(g.param_mut(0), 10.0);
            opt.step(&mut p, &g);
        }
        assert!(p[0].iter().all(|x| x.is_finite()));
    }
}
