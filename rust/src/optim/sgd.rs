//! Vanilla SGD: theta <- theta - eta * g  (paper Sec 3.1 update rule).

use super::Optimizer;
use crate::runtime::GradVec;

pub struct Sgd {
    pub lr: f64,
}

impl Sgd {
    pub fn new(lr: f64) -> Sgd {
        assert!(lr > 0.0);
        Sgd { lr }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut [Vec<f32>], grads: &GradVec) {
        assert_eq!(params.len(), grads.n_params());
        let lr = self.lr as f32;
        for (p, g) in params.iter_mut().zip(grads.params()) {
            assert_eq!(p.len(), g.len());
            for (pi, gi) in p.iter_mut().zip(g) {
                *pi -= lr * gi;
            }
        }
    }

    fn name(&self) -> &'static str {
        "sgd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_step() {
        let mut p = vec![vec![1.0f32, 2.0], vec![3.0]];
        let g = GradVec::from_vecs(&[vec![0.5f32, -1.0], vec![2.0]]);
        Sgd::new(0.1).step(&mut p, &g);
        assert_eq!(p[0], vec![0.95, 2.1]);
        assert!((p[1][0] - 2.8).abs() < 1e-6);
    }

    #[test]
    fn converges_on_quadratic() {
        // minimize f(x) = (x-3)^2, grad = 2(x-3)
        let mut p = vec![vec![0.0f32]];
        let mut opt = Sgd::new(0.1);
        for _ in 0..200 {
            let g = GradVec::from_vecs(&[vec![2.0 * (p[0][0] - 3.0)]]);
            opt.step(&mut p, &g);
        }
        assert!((p[0][0] - 3.0).abs() < 1e-4);
    }
}
