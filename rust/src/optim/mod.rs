//! Optimizers over the coordinator's host parameter representation
//! (one f32 vector per parameter tensor).
//!
//! The DP pipeline is: method produces the clipped averaged gradient
//! -> coordinator adds Gaussian noise (rng::Gaussian) -> optimizer
//! consumes the noisy gradient. Noise is *not* the optimizer's job
//! (postprocessing immunity, paper Sec 2.2, means anything after the
//! noisy gradient is privacy-free).

pub mod adam;
pub mod sgd;

pub use adam::Adam;
pub use sgd::Sgd;

use crate::runtime::GradVec;

/// A first-order optimizer over per-tensor parameter vectors.
pub trait Optimizer {
    /// In-place update with the (possibly noisy) gradient arena —
    /// per-parameter views in the same order/lengths as `params`.
    fn step(&mut self, params: &mut [Vec<f32>], grads: &GradVec);

    fn name(&self) -> &'static str;
}

/// Construct by name (CLI / config files).
pub fn by_name(name: &str, lr: f64) -> anyhow::Result<Box<dyn Optimizer>> {
    match name {
        "sgd" => Ok(Box::new(Sgd::new(lr))),
        "adam" => Ok(Box::new(Adam::new(lr))),
        other => anyhow::bail!("unknown optimizer {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory() {
        assert_eq!(by_name("sgd", 0.1).unwrap().name(), "sgd");
        assert_eq!(by_name("adam", 0.1).unwrap().name(), "adam");
        assert!(by_name("adamw", 0.1).is_err());
    }
}
