//! testkit::prop — a tiny property-testing harness (no `proptest` in
//! the offline crate set): seeded random case generation + invariant
//! checks with counterexample reporting.
//!
//! ```ignore
//! prop::check(100, |g| {
//!     let n = g.usize_in(1..500);
//!     let tau = g.usize_in(1..=n);
//!     ... assert invariant, or return Err(msg) ...
//! });
//! ```

pub mod prop {
    use crate::rng::ChaCha20;

    /// Per-case generator handle.
    pub struct Gen {
        rng: ChaCha20,
        pub case: usize,
    }

    impl Gen {
        pub fn usize_in(&mut self, range: std::ops::Range<usize>) -> usize {
            assert!(range.end > range.start);
            range.start
                + self.rng.next_bounded((range.end - range.start) as u64) as usize
        }

        pub fn usize_incl(&mut self, range: std::ops::RangeInclusive<usize>) -> usize {
            let (lo, hi) = (*range.start(), *range.end());
            lo + self.rng.next_bounded((hi - lo + 1) as u64) as usize
        }

        pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
            lo + self.rng.next_f64() * (hi - lo)
        }

        pub fn f32_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
            (0..n)
                .map(|_| lo + self.rng.next_f32() * (hi - lo))
                .collect()
        }

        pub fn bool(&mut self) -> bool {
            self.rng.next_u32() & 1 == 1
        }

        pub fn u64(&mut self) -> u64 {
            self.rng.next_u64()
        }

        pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
            &xs[self.usize_in(0..xs.len())]
        }
    }

    /// Run `cases` random cases of `f`; panics with the failing case
    /// index + seed on the first counterexample so it can be replayed.
    pub fn check<F>(cases: usize, mut f: F)
    where
        F: FnMut(&mut Gen) -> Result<(), String>,
    {
        let seed = std::env::var("FASTCLIP_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xF457C11Fu64);
        for case in 0..cases {
            let mut g = Gen {
                rng: ChaCha20::seeded(seed, case as u64),
                case,
            };
            if let Err(msg) = f(&mut g) {
                panic!(
                    "property failed at case {case} (seed {seed}, replay with FASTCLIP_PROP_SEED={seed}): {msg}"
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::prop;

    #[test]
    fn generators_respect_ranges() {
        prop::check(200, |g| {
            let a = g.usize_in(3..10);
            if !(3..10).contains(&a) {
                return Err(format!("usize_in out of range: {a}"));
            }
            let b = g.usize_incl(5..=5);
            if b != 5 {
                return Err(format!("usize_incl degenerate: {b}"));
            }
            let x = g.f64_in(-1.0, 1.0);
            if !(-1.0..1.0).contains(&x) {
                return Err(format!("f64_in out of range: {x}"));
            }
            let v = g.f32_vec(4, 0.0, 2.0);
            if v.len() != 4 || v.iter().any(|&y| !(0.0..2.0).contains(&y)) {
                return Err(format!("f32_vec bad: {v:?}"));
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failures_are_reported() {
        prop::check(10, |g| {
            if g.case == 7 {
                Err("intentional".into())
            } else {
                Ok(())
            }
        });
    }
}
