//! IDX file format (the MNIST distribution format): reader + writer.
//!
//! If real MNIST/FMNIST `.idx` files are present (FASTCLIP_DATA_DIR),
//! the coordinator trains on them instead of the synthetic stand-ins;
//! the writer exists so the round-trip is testable hermetically.
//!
//! Format: big-endian magic [0, 0, dtype, ndims], then ndims u32 dims,
//! then row-major payload. dtype 0x08 = u8 (the only one MNIST uses).

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

#[derive(Debug, Clone, PartialEq)]
pub struct IdxArray {
    pub dims: Vec<usize>,
    pub data: Vec<u8>,
}

impl IdxArray {
    pub fn len(&self) -> usize {
        self.dims.first().copied().unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn example_len(&self) -> usize {
        self.dims.iter().skip(1).product()
    }
}

pub fn read_idx(path: &Path) -> Result<IdxArray> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)?;
    parse_idx(&buf).with_context(|| format!("parsing {}", path.display()))
}

pub fn parse_idx(buf: &[u8]) -> Result<IdxArray> {
    if buf.len() < 4 {
        bail!("truncated idx header");
    }
    if buf[0] != 0 || buf[1] != 0 {
        bail!("bad idx magic prefix {:02x}{:02x}", buf[0], buf[1]);
    }
    let dtype = buf[2];
    if dtype != 0x08 {
        bail!("unsupported idx dtype 0x{dtype:02x} (only u8 supported)");
    }
    let ndims = buf[3] as usize;
    if ndims == 0 || ndims > 4 {
        bail!("unreasonable idx ndims {ndims}");
    }
    let header = 4 + 4 * ndims;
    if buf.len() < header {
        bail!("truncated idx dims");
    }
    let mut dims = Vec::with_capacity(ndims);
    for i in 0..ndims {
        let off = 4 + 4 * i;
        let d = u32::from_be_bytes(buf[off..off + 4].try_into().unwrap());
        dims.push(d as usize);
    }
    let total: usize = dims.iter().product();
    if buf.len() != header + total {
        bail!(
            "idx payload size mismatch: have {}, expect {}",
            buf.len() - header,
            total
        );
    }
    Ok(IdxArray { dims, data: buf[header..].to_vec() })
}

pub fn write_idx(path: &Path, arr: &IdxArray) -> Result<()> {
    let total: usize = arr.dims.iter().product();
    if total != arr.data.len() {
        bail!("dims/data mismatch");
    }
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(&[0, 0, 0x08, arr.dims.len() as u8])?;
    for &d in &arr.dims {
        f.write_all(&(d as u32).to_be_bytes())?;
    }
    f.write_all(&arr.data)?;
    Ok(())
}

/// Load an images+labels IDX pair into a Dataset (pixels scaled to
/// [0,1], channel dim inserted).
pub fn load_idx_dataset(
    name: &str,
    images: &Path,
    labels: &Path,
    n_classes: usize,
) -> Result<super::synth::Dataset> {
    let imgs = read_idx(images)?;
    let lbls = read_idx(labels)?;
    if imgs.dims.len() != 3 {
        bail!("expected [n, h, w] images, got {:?}", imgs.dims);
    }
    if lbls.dims.len() != 1 || lbls.len() != imgs.len() {
        bail!("label count {} != image count {}", lbls.len(), imgs.len());
    }
    let (h, w) = (imgs.dims[1], imgs.dims[2]);
    let features: Vec<f32> = imgs.data.iter().map(|&b| b as f32 / 255.0).collect();
    let labels: Vec<i32> = lbls.data.iter().map(|&b| b as i32).collect();
    if let Some(&bad) = labels.iter().find(|&&l| l as usize >= n_classes) {
        bail!("label {bad} out of range (n_classes={n_classes})");
    }
    Ok(super::synth::Dataset {
        name: name.to_string(),
        n: imgs.len(),
        shape: vec![1, h, w],
        n_classes,
        features: super::synth::Features::F32(features),
        labels,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("fastclip_idx_{}", name))
    }

    #[test]
    fn roundtrip() {
        let arr = IdxArray {
            dims: vec![3, 4, 5],
            data: (0..60).collect(),
        };
        let p = tmp("rt.idx");
        write_idx(&p, &arr).unwrap();
        let back = read_idx(&p).unwrap();
        assert_eq!(back, arr);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_corrupt() {
        assert!(parse_idx(&[]).is_err());
        assert!(parse_idx(&[1, 0, 8, 1, 0, 0, 0, 0]).is_err()); // bad magic
        assert!(parse_idx(&[0, 0, 0x0D, 1, 0, 0, 0, 0]).is_err()); // f32 dtype
        // payload shorter than dims claim
        let mut buf = vec![0, 0, 8, 1, 0, 0, 0, 10];
        buf.extend([0u8; 5]);
        assert!(parse_idx(&buf).is_err());
    }

    #[test]
    fn dataset_from_idx_pair() {
        let imgs = IdxArray {
            dims: vec![6, 4, 4],
            data: (0..96).map(|i| (i * 2) as u8).collect(),
        };
        let lbls = IdxArray { dims: vec![6], data: vec![0, 1, 2, 0, 1, 2] };
        let pi = tmp("imgs.idx");
        let pl = tmp("lbls.idx");
        write_idx(&pi, &imgs).unwrap();
        write_idx(&pl, &lbls).unwrap();
        let ds = load_idx_dataset("mini", &pi, &pl, 3).unwrap();
        assert_eq!(ds.n, 6);
        assert_eq!(ds.shape, vec![1, 4, 4]);
        match &ds.features {
            super::super::synth::Features::F32(v) => {
                assert!(v.iter().all(|&x| (0.0..=1.0).contains(&x)));
                assert!((v[1] - 2.0 / 255.0).abs() < 1e-6);
            }
            _ => panic!(),
        }
        // label out of range is rejected
        assert!(load_idx_dataset("mini", &pi, &pl, 2).is_err());
        std::fs::remove_file(&pi).ok();
        std::fs::remove_file(&pl).ok();
    }
}
