//! Synthetic dataset generators — stand-ins for MNIST / FMNIST /
//! CIFAR10 / LSUN / IMDB (DESIGN.md §5 substitutions).
//!
//! Images: each class has a fixed random template; a sample is
//! `0.8*template + 0.45*noise`, clamped to [0,1] — the same shape,
//! range, and class structure as the real datasets, and linearly
//! separable enough that training loss visibly decreases (which is all
//! the paper's timing/e2e experiments need from the data).
//!
//! Text: each sentiment class has a set of indicative tokens; a
//! sequence mixes class tokens with common filler. Labels are the
//! majority class.

use crate::rng::{streams, ChaCha20, Gaussian};

/// Feature storage — f32 images or i32 token ids.
#[derive(Debug, Clone)]
pub enum Features {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Features {
    pub fn len(&self) -> usize {
        match self {
            Features::F32(v) => v.len(),
            Features::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// An in-memory dataset of `n` examples with per-example feature shape
/// `shape` (no batch dim) and integer labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub n: usize,
    pub shape: Vec<usize>,
    pub n_classes: usize,
    pub features: Features,
    pub labels: Vec<i32>,
}

impl Dataset {
    pub fn example_len(&self) -> usize {
        self.shape.iter().product()
    }

    /// Copy example `i`'s features into `dst` (f32 datasets).
    pub fn copy_f32(&self, i: usize, dst: &mut [f32]) {
        let d = self.example_len();
        match &self.features {
            Features::F32(v) => dst.copy_from_slice(&v[i * d..(i + 1) * d]),
            Features::I32(_) => panic!("i32 dataset accessed as f32"),
        }
    }

    pub fn copy_i32(&self, i: usize, dst: &mut [i32]) {
        let d = self.example_len();
        match &self.features {
            Features::I32(v) => dst.copy_from_slice(&v[i * d..(i + 1) * d]),
            Features::F32(_) => panic!("f32 dataset accessed as i32"),
        }
    }
}

/// Template-plus-noise image dataset.
pub fn synth_images(
    name: &str,
    n: usize,
    shape: &[usize],
    n_classes: usize,
    seed: u64,
) -> Dataset {
    let d: usize = shape.iter().product();
    let mut gauss = Gaussian::seeded(seed, streams::DATA);
    let mut rng = ChaCha20::seeded(seed ^ 0xDA7A, streams::DATA);

    // Class templates depend on the dataset *name* only — never the
    // sample seed — so train and eval splits (different seeds) share
    // the same class structure and generalization is measurable.
    let mut tpl_rng = ChaCha20::seeded(name_hash(name), streams::DATA);
    let mut templates = vec![0f32; n_classes * d];
    for t in templates.iter_mut() {
        *t = tpl_rng.next_f32();
    }

    let mut features = vec![0f32; n * d];
    let mut labels = vec![0i32; n];
    for i in 0..n {
        let class = (i % n_classes) as i32;
        labels[i] = class;
        let tpl = &templates[class as usize * d..(class as usize + 1) * d];
        let dst = &mut features[i * d..(i + 1) * d];
        for (o, &t) in dst.iter_mut().zip(tpl) {
            let noisy = 0.8 * t + 0.45 * gauss.sample() as f32;
            *o = noisy.clamp(0.0, 1.0);
        }
    }
    // deterministic interleave so labels are not ordered by class
    let mut order: Vec<usize> = (0..n).collect();
    crate::rng::shuffle(&mut rng, &mut order);
    let mut f2 = vec![0f32; n * d];
    let mut l2 = vec![0i32; n];
    for (dst, &src) in order.iter().enumerate() {
        f2[dst * d..(dst + 1) * d].copy_from_slice(&features[src * d..(src + 1) * d]);
        l2[dst] = labels[src];
    }

    Dataset {
        name: name.to_string(),
        n,
        shape: shape.to_vec(),
        n_classes,
        features: Features::F32(f2),
        labels: l2,
    }
}

/// Stable 64-bit FNV-1a hash of the dataset name (template identity).
fn name_hash(name: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Token-majority sentiment dataset (IMDB substitute).
///
/// Vocabulary layout: [0, filler) common tokens; then `class_tokens`
/// indicative tokens per class.
pub fn synth_tokens(
    name: &str,
    n: usize,
    seq: usize,
    vocab: usize,
    n_classes: usize,
    seed: u64,
) -> Dataset {
    assert!(vocab > 64 + n_classes * 32);
    let filler = vocab - n_classes * 32;
    let mut rng = ChaCha20::seeded(seed, streams::DATA);
    let mut features = vec![0i32; n * seq];
    let mut labels = vec![0i32; n];
    for i in 0..n {
        let class = rng.next_bounded(n_classes as u64) as usize;
        labels[i] = class as i32;
        let class_base = filler + class * 32;
        for t in 0..seq {
            let indicative = rng.next_f64() < 0.35;
            features[i * seq + t] = if indicative {
                (class_base as u64 + rng.next_bounded(32)) as i32
            } else {
                rng.next_bounded(filler as u64) as i32
            };
        }
    }
    Dataset {
        name: name.to_string(),
        n,
        shape: vec![seq],
        n_classes,
        features: Features::I32(features),
        labels,
    }
}

/// Build the synthetic stand-in for a named dataset at a given size.
/// Shapes must match the manifest's `DATASETS` table (configs.py).
pub fn by_name(name: &str, n: usize, seed: u64) -> anyhow::Result<Dataset> {
    let ds = match name {
        "mnist" => synth_images("mnist", n, &[1, 28, 28], 10, seed ^ 0x01),
        "fmnist" => synth_images("fmnist", n, &[1, 28, 28], 10, seed ^ 0x02),
        "cifar10" => synth_images("cifar10", n, &[3, 32, 32], 10, seed ^ 0x03),
        "imdb" => synth_tokens("imdb", n, 64, 5000, 2, seed ^ 0x04),
        "lsun16" => synth_images("lsun16", n, &[3, 16, 16], 10, seed ^ 0x05),
        "lsun32" => synth_images("lsun32", n, &[3, 32, 32], 10, seed ^ 0x06),
        "lsun48" => synth_images("lsun48", n, &[3, 48, 48], 10, seed ^ 0x07),
        "lsun64" => synth_images("lsun64", n, &[3, 64, 64], 10, seed ^ 0x08),
        other => anyhow::bail!("unknown dataset {other:?}"),
    };
    Ok(ds)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn image_dataset_shape_and_range() {
        let ds = synth_images("t", 64, &[1, 8, 8], 10, 1);
        assert_eq!(ds.n, 64);
        assert_eq!(ds.example_len(), 64);
        match &ds.features {
            Features::F32(v) => {
                assert_eq!(v.len(), 64 * 64);
                assert!(v.iter().all(|&x| (0.0..=1.0).contains(&x)));
            }
            _ => panic!(),
        }
        assert!(ds.labels.iter().all(|&l| (0..10).contains(&l)));
        // every class present
        for c in 0..10 {
            assert!(ds.labels.contains(&c));
        }
    }

    #[test]
    fn images_deterministic_and_seed_sensitive() {
        let a = synth_images("t", 16, &[1, 4, 4], 4, 7);
        let b = synth_images("t", 16, &[1, 4, 4], 4, 7);
        let c = synth_images("t", 16, &[1, 4, 4], 4, 8);
        match (&a.features, &b.features, &c.features) {
            (Features::F32(x), Features::F32(y), Features::F32(z)) => {
                assert_eq!(x, y);
                assert_ne!(x, z);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn same_class_examples_correlate() {
        // template structure: two same-class examples are closer than
        // two different-class examples, on average
        let ds = synth_images("t", 200, &[1, 6, 6], 4, 3);
        let d = ds.example_len();
        let feat = match &ds.features {
            Features::F32(v) => v,
            _ => panic!(),
        };
        let dist = |i: usize, j: usize| -> f32 {
            (0..d)
                .map(|k| (feat[i * d + k] - feat[j * d + k]).powi(2))
                .sum()
        };
        let (mut same, mut diff, mut ns, mut nd) = (0f32, 0f32, 0, 0);
        for i in 0..50 {
            for j in (i + 1)..50 {
                if ds.labels[i] == ds.labels[j] {
                    same += dist(i, j);
                    ns += 1;
                } else {
                    diff += dist(i, j);
                    nd += 1;
                }
            }
        }
        assert!(same / (ns as f32) < diff / (nd as f32));
    }

    #[test]
    fn token_dataset_valid_ids() {
        let ds = synth_tokens("imdb", 100, 64, 5000, 2, 9);
        match &ds.features {
            Features::I32(v) => {
                assert_eq!(v.len(), 100 * 64);
                assert!(v.iter().all(|&t| (0..5000).contains(&t)));
            }
            _ => panic!(),
        }
        assert!(ds.labels.contains(&0) && ds.labels.contains(&1));
    }

    #[test]
    fn by_name_covers_manifest_datasets() {
        for name in [
            "mnist", "fmnist", "cifar10", "imdb", "lsun16", "lsun32",
            "lsun48", "lsun64",
        ] {
            let ds = by_name(name, 8, 0).unwrap();
            assert_eq!(ds.n, 8);
        }
        assert!(by_name("nope", 8, 0).is_err());
    }
}
