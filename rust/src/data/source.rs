//! The `DataSource` seam: samplers decide *which* rows make a batch
//! (`batcher.rs` draws indices), sources decide *how* those rows are
//! materialized into the staging buffers. The in-memory [`Dataset`] is
//! the default impl; [`stream::StreamingIdxSource`](super::stream)
//! materializes rows from an IDX file through a bounded chunk cache,
//! so Poisson sampling works over datasets that do not fit in memory.
//!
//! `fill_batch` takes `&mut self` deliberately: a streaming source
//! mutates its chunk cache while an in-memory one does not, and the
//! trait must cover both. It is a warm-loop call — implementations
//! must not allocate once warm.

use super::synth::{Dataset, Features};
use crate::runtime::BatchStage;
use anyhow::Result;

/// A dataset the training loop can draw batches from by row index.
pub trait DataSource: Send {
    /// Number of examples addressable by `fill_batch`.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Flat element count of one example (product of the example
    /// shape) — what one staged row occupies in the feature buffer.
    fn example_len(&self) -> usize;

    /// Whether examples stage into `feat_f32` (vs `feat_i32`).
    fn is_f32(&self) -> bool;

    /// Dataset name, for error messages and logs.
    fn name(&self) -> &str;

    /// Materialize `indices[slot]` into row `slot` of the stage's
    /// feature/label buffers. The stage must already be sized for
    /// exactly `indices.len()` examples of `example_len()` elements.
    fn fill_batch(
        &mut self,
        indices: &[usize],
        stage: &mut BatchStage,
    ) -> Result<()>;
}

impl DataSource for Dataset {
    fn len(&self) -> usize {
        self.n
    }

    fn example_len(&self) -> usize {
        Dataset::example_len(self)
    }

    fn is_f32(&self) -> bool {
        matches!(self.features, Features::F32(_))
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn fill_batch(
        &mut self,
        indices: &[usize],
        stage: &mut BatchStage,
    ) -> Result<()> {
        match self.features {
            Features::F32(_) => super::gather_batch_f32(
                self,
                indices,
                &mut stage.feat_f32,
                &mut stage.labels,
            ),
            // i32 token ids feeding an f32-staged config (the native
            // transformer family): widen in place — exact for any
            // vocab-sized id, and still allocation-free
            Features::I32(_) if stage.is_f32 => super::gather_batch_i32_as_f32(
                self,
                indices,
                &mut stage.feat_f32,
                &mut stage.labels,
            ),
            Features::I32(_) => super::gather_batch_i32(
                self,
                indices,
                &mut stage.feat_i32,
                &mut stage.labels,
            ),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn dataset_fill_batch_matches_gather() {
        let mut ds = synth::synth_images("t", 10, &[1, 2, 2], 2, 1);
        let batch = vec![3usize, 7, 1];
        let mut stage = BatchStage {
            feat_f32: vec![0.0; 3 * 4],
            feat_i32: Vec::new(),
            labels: vec![0; 3],
            input_dims: vec![3, 1, 2, 2],
            is_f32: true,
        };
        ds.fill_batch(&batch, &mut stage).unwrap();
        let mut row = vec![0f32; 4];
        ds.copy_f32(7, &mut row);
        assert_eq!(&stage.feat_f32[4..8], &row[..]);
        assert_eq!(stage.labels[1], ds.labels[7]);
        assert_eq!(DataSource::len(&ds), 10);
        assert_eq!(DataSource::example_len(&ds), 4);
        assert!(ds.is_f32());
    }
}
