//! Minibatch construction.
//!
//! Two sampling regimes:
//!  * `ShuffleBatcher` — the paper's Sec 6.1 procedure: shuffle each
//!    epoch, partition into non-overlapping chunks of size tau.
//!  * `PoissonSampler` — per-record inclusion with probability q, the
//!    regime the RDP subsampled-Gaussian analysis assumes. AOT
//!    artifacts have a fixed batch dimension, so Poisson draws are
//!    resized to tau (pad by resampling / truncate uniformly) — the
//!    standard fixed-batch compromise, documented in DESIGN.md.

use crate::rng::{shuffle, streams, ChaCha20};

/// A batch: indices into the dataset.
pub type Batch = Vec<usize>;

/// Epoch-shuffling sequential batcher (paper Sec 6.1).
pub struct ShuffleBatcher {
    n: usize,
    tau: usize,
    rng: ChaCha20,
    order: Vec<usize>,
    cursor: usize,
    pub epoch: u64,
}

impl ShuffleBatcher {
    pub fn new(n: usize, tau: usize, seed: u64) -> Self {
        assert!(tau > 0 && tau <= n, "batch {tau} vs dataset {n}");
        let mut b = ShuffleBatcher {
            n,
            tau,
            rng: ChaCha20::seeded(seed, streams::SHUFFLE),
            order: (0..n).collect(),
            cursor: 0,
            epoch: 0,
        };
        b.reshuffle();
        b
    }

    fn reshuffle(&mut self) {
        shuffle(&mut self.rng, &mut self.order);
        self.cursor = 0;
    }

    /// Number of full batches per epoch (trailing partial chunk is
    /// dropped — fixed AOT batch shape).
    pub fn batches_per_epoch(&self) -> usize {
        self.n / self.tau
    }

    /// Next batch of exactly tau indices; reshuffles between epochs.
    pub fn next_batch(&mut self) -> Batch {
        if self.cursor + self.tau > self.n {
            self.epoch += 1;
            self.reshuffle();
        }
        let b = self.order[self.cursor..self.cursor + self.tau].to_vec();
        self.cursor += self.tau;
        b
    }
}

/// Poisson subsampler: include each record independently w.p. q, then
/// resize to exactly `tau` for the fixed-shape executable.
pub struct PoissonSampler {
    n: usize,
    q: f64,
    tau: usize,
    rng: ChaCha20,
}

impl PoissonSampler {
    pub fn new(n: usize, tau: usize, seed: u64) -> Self {
        assert!(tau > 0 && tau <= n);
        PoissonSampler {
            n,
            q: tau as f64 / n as f64,
            tau,
            rng: ChaCha20::seeded(seed, streams::SAMPLER),
        }
    }

    pub fn sampling_rate(&self) -> f64 {
        self.q
    }

    /// One Poisson draw, resized to tau.
    pub fn next_batch(&mut self) -> Batch {
        let mut picked: Vec<usize> =
            (0..self.n).filter(|_| self.rng.next_f64() < self.q).collect();
        // resize to the fixed executable batch size
        while picked.len() < self.tau {
            picked.push(self.rng.next_bounded(self.n as u64) as usize);
        }
        if picked.len() > self.tau {
            shuffle(&mut self.rng, &mut picked);
            picked.truncate(self.tau);
        }
        picked
    }

    /// Raw Poisson draw (variable size) — used by tests to check the
    /// inclusion probability.
    pub fn raw_draw(&mut self) -> Batch {
        (0..self.n).filter(|_| self.rng.next_f64() < self.q).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn epoch_covers_every_index_once() {
        let mut b = ShuffleBatcher::new(100, 10, 1);
        let mut seen = Vec::new();
        for _ in 0..b.batches_per_epoch() {
            seen.extend(b.next_batch());
        }
        let set: HashSet<_> = seen.iter().copied().collect();
        assert_eq!(seen.len(), 100);
        assert_eq!(set.len(), 100);
    }

    #[test]
    fn epochs_reshuffle() {
        let mut b = ShuffleBatcher::new(64, 8, 2);
        let e1: Vec<Batch> = (0..8).map(|_| b.next_batch()).collect();
        let e2: Vec<Batch> = (0..8).map(|_| b.next_batch()).collect();
        assert_eq!(b.epoch, 1);
        assert_ne!(e1, e2);
    }

    #[test]
    fn partial_tail_dropped() {
        let mut b = ShuffleBatcher::new(25, 10, 3);
        assert_eq!(b.batches_per_epoch(), 2);
        b.next_batch();
        b.next_batch();
        assert_eq!(b.epoch, 0);
        b.next_batch(); // rolls into epoch 1
        assert_eq!(b.epoch, 1);
    }

    #[test]
    fn batch_always_tau_and_in_range() {
        let mut b = ShuffleBatcher::new(50, 7, 4);
        let mut p = PoissonSampler::new(50, 7, 4);
        for _ in 0..30 {
            for batch in [b.next_batch(), p.next_batch()] {
                assert_eq!(batch.len(), 7);
                assert!(batch.iter().all(|&i| i < 50));
            }
        }
    }

    #[test]
    fn poisson_inclusion_probability() {
        let mut p = PoissonSampler::new(1000, 100, 5); // q = 0.1
        let mut counts = vec![0usize; 1000];
        let draws = 400;
        for _ in 0..draws {
            for i in p.raw_draw() {
                counts[i] += 1;
            }
        }
        let mean = counts.iter().sum::<usize>() as f64 / 1000.0 / draws as f64;
        assert!((mean - 0.1).abs() < 0.01, "inclusion rate {}", mean);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = ShuffleBatcher::new(30, 5, 9);
        let mut b = ShuffleBatcher::new(30, 5, 9);
        for _ in 0..10 {
            assert_eq!(a.next_batch(), b.next_batch());
        }
    }
}
