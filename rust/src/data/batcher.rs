//! Minibatch construction.
//!
//! Two sampling regimes:
//!  * `ShuffleBatcher` — the paper's Sec 6.1 procedure: shuffle each
//!    epoch, partition into non-overlapping chunks of size tau.
//!  * `PoissonSampler` — per-record inclusion with probability q, the
//!    regime the RDP subsampled-Gaussian analysis assumes. AOT
//!    artifacts have a fixed batch dimension, so Poisson draws are
//!    resized to tau (pad from the complement / truncate uniformly) —
//!    the standard fixed-batch compromise, documented in DESIGN.md
//!    §"Poisson sampling vs the fixed batch ABI".

use crate::rng::{shuffle, streams, ChaCha20};

/// A batch: indices into the dataset.
pub type Batch = Vec<usize>;

/// Epoch-shuffling sequential batcher (paper Sec 6.1).
pub struct ShuffleBatcher {
    n: usize,
    tau: usize,
    rng: ChaCha20,
    order: Vec<usize>,
    cursor: usize,
    pub epoch: u64,
}

impl ShuffleBatcher {
    pub fn new(n: usize, tau: usize, seed: u64) -> Self {
        assert!(tau > 0 && tau <= n, "batch {tau} vs dataset {n}");
        let mut b = ShuffleBatcher {
            n,
            tau,
            rng: ChaCha20::seeded(seed, streams::SHUFFLE),
            order: (0..n).collect(),
            cursor: 0,
            epoch: 0,
        };
        b.reshuffle();
        b
    }

    fn reshuffle(&mut self) {
        shuffle(&mut self.rng, &mut self.order);
        self.cursor = 0;
    }

    /// Number of full batches per epoch (trailing partial chunk is
    /// dropped — fixed AOT batch shape).
    pub fn batches_per_epoch(&self) -> usize {
        self.n / self.tau
    }

    /// Next batch of exactly tau indices; reshuffles between epochs.
    pub fn next_batch(&mut self) -> Batch {
        let mut b = Vec::with_capacity(self.tau);
        self.next_batch_into(&mut b);
        b
    }

    /// `next_batch` into a caller-owned buffer — the warm-loop form:
    /// with `out` at capacity >= tau this performs zero heap
    /// allocation. Draw-order-identical to `next_batch`.
    pub fn next_batch_into(&mut self, out: &mut Vec<usize>) {
        if self.cursor + self.tau > self.n {
            self.epoch += 1;
            self.reshuffle();
        }
        out.clear();
        out.extend_from_slice(&self.order[self.cursor..self.cursor + self.tau]);
        self.cursor += self.tau;
    }
}

/// Poisson subsampler: include each record independently w.p. q, then
/// resize to exactly `tau` for the fixed-shape executable.
pub struct PoissonSampler {
    n: usize,
    q: f64,
    tau: usize,
    rng: ChaCha20,
    /// scratch for the short-draw padding path (`next_batch_into`):
    /// membership mask + complement buffer, allocated once so the warm
    /// sampling loop is heap-allocation-free
    in_draw: Vec<bool>,
    rest: Vec<usize>,
}

impl PoissonSampler {
    pub fn new(n: usize, tau: usize, seed: u64) -> Self {
        assert!(tau > 0 && tau <= n);
        PoissonSampler {
            n,
            q: tau as f64 / n as f64,
            tau,
            rng: ChaCha20::seeded(seed, streams::SAMPLER),
            in_draw: vec![false; n],
            rest: Vec::with_capacity(n),
        }
    }

    pub fn sampling_rate(&self) -> f64 {
        self.q
    }

    /// One Poisson draw, resized to tau.
    ///
    /// Short draws are padded **only from the complement** of the draw
    /// (a partial Fisher–Yates over the not-yet-picked indices).
    /// Padding by uniform resampling over all of `0..n` — the obvious
    /// fix-up — can re-pick a record already in the draw; a duplicated
    /// record contributes up to 2·clip to the step's gradient sum while
    /// the Gaussian noise is calibrated for sensitivity clip, silently
    /// voiding the DP guarantee (DESIGN.md §"Poisson sampling vs the
    /// fixed batch ABI"). Oversized draws are truncated uniformly,
    /// which cannot introduce duplicates.
    pub fn next_batch(&mut self) -> Batch {
        let mut picked = Vec::new();
        self.next_batch_into(&mut picked);
        picked
    }

    /// `next_batch` into a caller-owned buffer — the warm-loop form.
    /// Raw draw sizes vary binomially, so a zero-allocation caller
    /// reserves `out` to capacity `n` (the maximum possible draw), not
    /// tau. Draws exactly the same RNG sequence as the padding and
    /// truncation paths always have, so the batch stream is unchanged.
    pub fn next_batch_into(&mut self, out: &mut Vec<usize>) {
        out.clear();
        for i in 0..self.n {
            if self.rng.next_f64() < self.q {
                out.push(i);
            }
        }
        if out.len() < self.tau {
            for b in self.in_draw.iter_mut() {
                *b = false;
            }
            for &i in out.iter() {
                self.in_draw[i] = true;
            }
            self.rest.clear();
            for i in 0..self.n {
                if !self.in_draw[i] {
                    self.rest.push(i);
                }
            }
            // tau <= n, so the complement always has enough indices
            let need = self.tau - out.len();
            for j in 0..need {
                let k = j + self.rng.next_bounded((self.rest.len() - j) as u64) as usize;
                self.rest.swap(j, k);
                out.push(self.rest[j]);
            }
        } else if out.len() > self.tau {
            shuffle(&mut self.rng, out);
            out.truncate(self.tau);
        }
    }

    /// Raw Poisson draw (variable size) — used by tests to check the
    /// inclusion probability.
    pub fn raw_draw(&mut self) -> Batch {
        (0..self.n).filter(|_| self.rng.next_f64() < self.q).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn epoch_covers_every_index_once() {
        let mut b = ShuffleBatcher::new(100, 10, 1);
        let mut seen = Vec::new();
        for _ in 0..b.batches_per_epoch() {
            seen.extend(b.next_batch());
        }
        let set: HashSet<_> = seen.iter().copied().collect();
        assert_eq!(seen.len(), 100);
        assert_eq!(set.len(), 100);
    }

    #[test]
    fn epochs_reshuffle() {
        let mut b = ShuffleBatcher::new(64, 8, 2);
        let e1: Vec<Batch> = (0..8).map(|_| b.next_batch()).collect();
        let e2: Vec<Batch> = (0..8).map(|_| b.next_batch()).collect();
        assert_eq!(b.epoch, 1);
        assert_ne!(e1, e2);
    }

    #[test]
    fn partial_tail_dropped() {
        let mut b = ShuffleBatcher::new(25, 10, 3);
        assert_eq!(b.batches_per_epoch(), 2);
        b.next_batch();
        b.next_batch();
        assert_eq!(b.epoch, 0);
        b.next_batch(); // rolls into epoch 1
        assert_eq!(b.epoch, 1);
    }

    #[test]
    fn batch_always_tau_and_in_range() {
        let mut b = ShuffleBatcher::new(50, 7, 4);
        let mut p = PoissonSampler::new(50, 7, 4);
        for _ in 0..30 {
            for batch in [b.next_batch(), p.next_batch()] {
                assert_eq!(batch.len(), 7);
                assert!(batch.iter().all(|&i| i < 50));
            }
        }
    }

    /// Property: a Poisson draw never contains a duplicate index — a
    /// duplicated record would contribute up to 2·clip per step,
    /// past the sensitivity bound the noise is calibrated for. Random
    /// (n, tau, seed) combos, biased toward high q so the padding path
    /// is exercised constantly.
    #[test]
    fn prop_poisson_draw_never_duplicates() {
        use crate::testkit::prop;
        prop::check(40, |g| {
            let n = g.usize_in(2..200);
            // high tau/n => raw draws straddle tau, exercising both the
            // pad and the truncate path
            let tau = g.usize_incl(n.saturating_sub(n / 4).max(1)..=n);
            let mut p = PoissonSampler::new(n, tau, g.u64());
            for _ in 0..25 {
                let b = p.next_batch();
                if b.len() != tau {
                    return Err(format!("draw len {} != tau {tau}", b.len()));
                }
                let mut seen = vec![false; n];
                for &i in &b {
                    if i >= n {
                        return Err(format!("index {i} outside 0..{n}"));
                    }
                    if seen[i] {
                        return Err(format!(
                            "duplicate index {i} in Poisson draw (n={n}, tau={tau})"
                        ));
                    }
                    seen[i] = true;
                }
            }
            Ok(())
        });
    }

    /// Regression companion for the padding bug: replay the *old*
    /// padding strategy (uniform resampling over all of 0..n) on a
    /// scenario where short draws are common, and show it (a) pads and
    /// (b) duplicates an in-draw record. This pins down that
    /// `prop_poisson_draw_never_duplicates` is not vacuous — the same
    /// scenario run through the pre-fix sampler fails it.
    #[test]
    fn old_uniform_padding_duplicated_in_draw_records() {
        let (n, tau) = (20usize, 18usize);
        let q = tau as f64 / n as f64;
        let mut rng = ChaCha20::seeded(7, streams::SAMPLER);
        let (mut padded_draws, mut duplicated_draws) = (0usize, 0usize);
        for _ in 0..300 {
            let mut picked: Vec<usize> =
                (0..n).filter(|_| rng.next_f64() < q).collect();
            if picked.len() < tau {
                padded_draws += 1;
            }
            while picked.len() < tau {
                picked.push(rng.next_bounded(n as u64) as usize); // the bug
            }
            if picked.len() > tau {
                shuffle(&mut rng, &mut picked);
                picked.truncate(tau);
            }
            let distinct: HashSet<_> = picked.iter().copied().collect();
            if distinct.len() < picked.len() {
                duplicated_draws += 1;
            }
        }
        assert!(padded_draws > 0, "scenario never exercised padding");
        assert!(
            duplicated_draws > 0,
            "old uniform padding never duplicated — the regression \
             scenario lost its teeth"
        );
        // and the fixed sampler on the very same scenario never does
        let mut p = PoissonSampler::new(n, tau, 7);
        for _ in 0..300 {
            let b = p.next_batch();
            let distinct: HashSet<_> = b.iter().copied().collect();
            assert_eq!(distinct.len(), tau, "fixed sampler duplicated: {b:?}");
        }
    }

    #[test]
    fn poisson_inclusion_probability() {
        let mut p = PoissonSampler::new(1000, 100, 5); // q = 0.1
        let mut counts = vec![0usize; 1000];
        let draws = 400;
        for _ in 0..draws {
            for i in p.raw_draw() {
                counts[i] += 1;
            }
        }
        let mean = counts.iter().sum::<usize>() as f64 / 1000.0 / draws as f64;
        assert!((mean - 0.1).abs() < 0.01, "inclusion rate {}", mean);
    }

    /// The buffer-reuse API must replay the exact draw stream of the
    /// allocating API — the whole bitwise-resume story rides on the
    /// batch sequence being a pure function of (seed, call count).
    #[test]
    fn next_batch_into_matches_next_batch_stream() {
        let mut a = ShuffleBatcher::new(30, 5, 9);
        let mut b = ShuffleBatcher::new(30, 5, 9);
        let mut pa = PoissonSampler::new(20, 18, 7);
        let mut pb = PoissonSampler::new(20, 18, 7);
        let mut buf = Vec::new();
        for _ in 0..40 {
            a.next_batch_into(&mut buf);
            assert_eq!(buf, b.next_batch());
            pa.next_batch_into(&mut buf);
            assert_eq!(buf, pb.next_batch());
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = ShuffleBatcher::new(30, 5, 9);
        let mut b = ShuffleBatcher::new(30, 5, 9);
        for _ in 0..10 {
            assert_eq!(a.next_batch(), b.next_batch());
        }
    }
}
