//! Data pipeline substrate: synthetic dataset generators, the IDX
//! (MNIST) file format, shuffling batchers, and Poisson subsampling.

pub mod batcher;
pub mod idx;
pub mod source;
pub mod stream;
pub mod synth;

pub use batcher::{Batch, PoissonSampler, ShuffleBatcher};
pub use source::DataSource;
pub use stream::StreamingIdxSource;
pub use synth::{by_name, Dataset, Features};

use anyhow::Result;
use std::path::PathBuf;

/// Resolve a dataset: real IDX files if FASTCLIP_DATA_DIR has them,
/// synthetic otherwise.
pub fn load_dataset(name: &str, n: usize, seed: u64) -> Result<Dataset> {
    if let Ok(dir) = std::env::var("FASTCLIP_DATA_DIR") {
        let dir = PathBuf::from(dir);
        let (imgs, lbls) = match name {
            "mnist" => ("train-images-idx3-ubyte", "train-labels-idx1-ubyte"),
            "fmnist" => (
                "fmnist-train-images-idx3-ubyte",
                "fmnist-train-labels-idx1-ubyte",
            ),
            _ => ("", ""),
        };
        if !imgs.is_empty() {
            let pi = dir.join(imgs);
            let pl = dir.join(lbls);
            if pi.exists() && pl.exists() {
                crate::log_info!("loading real {name} from {}", dir.display());
                let mut ds = idx::load_idx_dataset(name, &pi, &pl, 10)?;
                if ds.n > n {
                    truncate(&mut ds, n);
                }
                return Ok(ds);
            }
        }
    }
    synth::by_name(name, n, seed)
}

fn truncate(ds: &mut Dataset, n: usize) {
    let d = ds.example_len();
    match &mut ds.features {
        Features::F32(v) => v.truncate(n * d),
        Features::I32(v) => v.truncate(n * d),
    }
    ds.labels.truncate(n);
    ds.n = n;
}

/// Gather a batch of examples into flat feature/label buffers
/// (the staging step before upload to the PJRT device).
pub fn gather_batch_f32(
    ds: &Dataset,
    batch: &[usize],
    feat_out: &mut [f32],
    label_out: &mut [i32],
) {
    let d = ds.example_len();
    assert_eq!(feat_out.len(), batch.len() * d);
    assert_eq!(label_out.len(), batch.len());
    for (row, &i) in batch.iter().enumerate() {
        ds.copy_f32(i, &mut feat_out[row * d..(row + 1) * d]);
        label_out[row] = ds.labels[i];
    }
}

pub fn gather_batch_i32(
    ds: &Dataset,
    batch: &[usize],
    feat_out: &mut [i32],
    label_out: &mut [i32],
) {
    let d = ds.example_len();
    assert_eq!(feat_out.len(), batch.len() * d);
    assert_eq!(label_out.len(), batch.len());
    for (row, &i) in batch.iter().enumerate() {
        ds.copy_i32(i, &mut feat_out[row * d..(row + 1) * d]);
        label_out[row] = ds.labels[i];
    }
}

/// Gather i32 token-id examples widened to f32 — the staging seam the
/// native transformer family consumes (token ids are exactly
/// representable in f32 up to 2^24, far above any vocab here).
/// Allocation-free: writes straight into the caller's stage buffers.
pub fn gather_batch_i32_as_f32(
    ds: &Dataset,
    batch: &[usize],
    feat_out: &mut [f32],
    label_out: &mut [i32],
) {
    let d = ds.example_len();
    assert_eq!(feat_out.len(), batch.len() * d);
    assert_eq!(label_out.len(), batch.len());
    let toks = match &ds.features {
        Features::I32(v) => v,
        Features::F32(_) => panic!("f32 dataset staged through the i32 seam"),
    };
    for (row, &i) in batch.iter().enumerate() {
        for (o, &t) in feat_out[row * d..(row + 1) * d]
            .iter_mut()
            .zip(&toks[i * d..(i + 1) * d])
        {
            *o = t as f32;
        }
        label_out[row] = ds.labels[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_places_rows() {
        let ds = synth::synth_images("t", 10, &[1, 2, 2], 2, 1);
        let batch = vec![3, 7, 1];
        let mut feats = vec![0f32; 3 * 4];
        let mut labels = vec![0i32; 3];
        gather_batch_f32(&ds, &batch, &mut feats, &mut labels);
        let mut row = vec![0f32; 4];
        ds.copy_f32(7, &mut row);
        assert_eq!(&feats[4..8], &row[..]);
        assert_eq!(labels[1], ds.labels[7]);
    }

    #[test]
    fn widening_gather_matches_token_ids() {
        let ds = synth::synth_tokens("imdb", 10, 8, 200, 2, 3);
        let batch = vec![4, 0, 9];
        let mut feats = vec![0f32; 3 * 8];
        let mut labels = vec![0i32; 3];
        gather_batch_i32_as_f32(&ds, &batch, &mut feats, &mut labels);
        let mut row = vec![0i32; 8];
        ds.copy_i32(0, &mut row);
        for (f, &t) in feats[8..16].iter().zip(&row) {
            assert_eq!(*f, t as f32);
            assert_eq!(*f as i32, t); // exactly representable
        }
        assert_eq!(labels[1], ds.labels[0]);
    }

    #[test]
    fn load_dataset_synth_fallback() {
        std::env::remove_var("FASTCLIP_DATA_DIR");
        let ds = load_dataset("mnist", 32, 0).unwrap();
        assert_eq!(ds.n, 32);
        assert_eq!(ds.shape, vec![1, 28, 28]);
    }
}
