//! Chunked IDX-backed [`DataSource`]: train from a dataset that does
//! not fit in memory. Only the labels (1 byte/example on disk, i32 in
//! memory) and one aligned chunk of `chunk_rows` images are resident
//! at a time — peak feature residency is bounded by the chunk size,
//! not the dataset size.
//!
//! Batch indices arrive in sampler order (Poisson draws are ascending,
//! shuffle draws are not); `fill_batch` sorts a persistent
//! `(row, slot)` scratch so each aligned chunk is read from disk at
//! most once per batch, then scatters rows to their original slots —
//! the staged batch is byte-identical to the in-memory gather.

use super::source::DataSource;
use crate::runtime::BatchStage;
use anyhow::{bail, Context, Result};
use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::path::Path;

pub struct StreamingIdxSource {
    name: String,
    file: File,
    /// byte offset of row 0 in the image file (magic + dims)
    header_bytes: u64,
    n: usize,
    shape: Vec<usize>,
    /// bytes (= u8 elements) of one image row on disk
    example_bytes: usize,
    /// labels stay fully resident: 4 bytes/example vs
    /// `example_bytes` (~784 for MNIST) per image row
    labels: Vec<i32>,
    chunk_rows: usize,
    cache_start: usize,
    /// rows currently valid in `cache`; 0 = nothing cached yet
    cache_len: usize,
    cache: Vec<u8>,
    /// per-batch (row, slot) scratch, sorted by row so each chunk
    /// loads at most once per batch
    order: Vec<(usize, usize)>,
}

impl StreamingIdxSource {
    /// Open an images/labels IDX pair. Validates the same invariants
    /// as `idx::load_idx_dataset` (3-dim u8 images, matching label
    /// count, labels < `n_classes`) without materializing the images.
    pub fn open(
        name: &str,
        images: &Path,
        labels: &Path,
        n_classes: usize,
        chunk_rows: usize,
    ) -> Result<StreamingIdxSource> {
        let lab = super::idx::read_idx(labels)
            .with_context(|| format!("reading labels {}", labels.display()))?;
        if lab.dims.len() != 1 {
            bail!("labels must be 1-dimensional, got {:?}", lab.dims);
        }
        for (i, &b) in lab.data.iter().enumerate() {
            if b as usize >= n_classes {
                bail!("label {} at index {} out of range 0..{}", b, i, n_classes);
            }
        }

        let mut file = File::open(images)
            .with_context(|| format!("opening images {}", images.display()))?;
        let mut head = [0u8; 4];
        file.read_exact(&mut head)
            .with_context(|| format!("reading IDX magic of {}", images.display()))?;
        if head[0] != 0 || head[1] != 0 {
            bail!("{}: bad IDX magic {:?}", images.display(), head);
        }
        if head[2] != 0x08 {
            bail!("{}: only u8 IDX supported, dtype 0x{:02x}", images.display(), head[2]);
        }
        if head[3] != 3 {
            bail!(
                "{}: streaming images must be 3-dimensional (n, h, w), got {} dims",
                images.display(),
                head[3]
            );
        }
        let mut dims = [0usize; 3];
        for d in dims.iter_mut() {
            let mut b = [0u8; 4];
            file.read_exact(&mut b)
                .with_context(|| format!("reading IDX dims of {}", images.display()))?;
            *d = u32::from_be_bytes(b) as usize;
        }
        let (n, h, w) = (dims[0], dims[1], dims[2]);
        let header_bytes = 4 + 4 * 3u64;
        let example_bytes = h * w;
        if n != lab.data.len() {
            bail!("{} images vs {} labels", n, lab.data.len());
        }
        let file_len = file
            .metadata()
            .with_context(|| format!("stat {}", images.display()))?
            .len();
        let expect = header_bytes + (n * example_bytes) as u64;
        if file_len != expect {
            bail!(
                "{}: file is {} bytes, dims {:?} need {}",
                images.display(),
                file_len,
                dims,
                expect
            );
        }
        if n == 0 || example_bytes == 0 {
            bail!("{}: empty image dims {:?}", images.display(), dims);
        }

        let chunk_rows = chunk_rows.clamp(1, n);
        Ok(StreamingIdxSource {
            name: name.to_string(),
            file,
            header_bytes,
            n,
            shape: vec![1, h, w],
            example_bytes,
            labels: lab.data.iter().map(|&b| b as i32).collect(),
            chunk_rows,
            cache_start: 0,
            cache_len: 0,
            cache: Vec::with_capacity(chunk_rows * example_bytes),
            order: Vec::new(),
        })
    }

    /// Resolve the IDX pair for a config's dataset name under
    /// `FASTCLIP_DATA_DIR` (same mapping as `data::load_dataset`).
    pub fn open_for_dataset(name: &str, chunk_rows: usize) -> Result<StreamingIdxSource> {
        // lint: allow(no-wallclock-entropy) -- startup path resolution only; batch
        // content and order depend on (path, seed, epoch), not on when this runs
        let dir = std::env::var("FASTCLIP_DATA_DIR").map(std::path::PathBuf::from).map_err(|_| {
            anyhow::anyhow!(
                "--stream-chunk needs FASTCLIP_DATA_DIR pointing at the IDX \
                 files for dataset {name:?} (streaming reads from disk; \
                 synthetic datasets are already in memory)"
            )
        })?;
        let (imgs, lbls) = match name {
            "mnist" => ("train-images-idx3-ubyte", "train-labels-idx1-ubyte"),
            "fmnist" => (
                "fmnist-train-images-idx3-ubyte",
                "fmnist-train-labels-idx1-ubyte",
            ),
            other => bail!(
                "no IDX file mapping for dataset {other:?} — streaming \
                 supports mnist and fmnist"
            ),
        };
        crate::log_info!("streaming {name} from {} (chunk {chunk_rows} rows)", dir.display());
        Self::open(name, &dir.join(imgs), &dir.join(lbls), 10, chunk_rows)
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Bytes this source keeps resident: the chunk cache, the label
    /// table, and the per-batch scratch. The residency test bounds
    /// this by chunk size, not dataset size.
    pub fn resident_bytes(&self) -> usize {
        self.cache.capacity()
            + self.labels.capacity() * std::mem::size_of::<i32>()
            + self.order.capacity() * std::mem::size_of::<(usize, usize)>()
    }

    /// Ensure `row` is inside the cache, loading its aligned chunk if
    /// not. Aligned chunks (not sliding windows) make the set of disk
    /// reads a pure function of the batch's row set.
    fn ensure_row(&mut self, row: usize) -> Result<()> {
        if self.cache_len > 0
            && row >= self.cache_start
            && row < self.cache_start + self.cache_len
        {
            return Ok(());
        }
        let start = (row / self.chunk_rows) * self.chunk_rows;
        let rows = self.chunk_rows.min(self.n - start);
        let bytes = rows * self.example_bytes;
        // capacity was reserved for a full chunk at open: resize never
        // reallocates, so the warm fill path stays allocation-free
        self.cache.resize(bytes, 0);
        self.file.seek(SeekFrom::Start(
            self.header_bytes + (start * self.example_bytes) as u64,
        ))?;
        self.file
            .read_exact(&mut self.cache[..bytes])
            .with_context(|| {
                format!("reading rows {}..{} of {}", start, start + rows, self.name)
            })?;
        self.cache_start = start;
        self.cache_len = rows;
        Ok(())
    }
}

impl DataSource for StreamingIdxSource {
    fn len(&self) -> usize {
        self.n
    }

    fn example_len(&self) -> usize {
        self.example_bytes
    }

    fn is_f32(&self) -> bool {
        true
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn fill_batch(
        &mut self,
        indices: &[usize],
        stage: &mut BatchStage,
    ) -> Result<()> {
        let d = self.example_bytes;
        anyhow::ensure!(stage.is_f32, "streaming IDX source stages f32 images");
        anyhow::ensure!(
            stage.feat_f32.len() == indices.len() * d
                && stage.labels.len() == indices.len(),
            "stage sized for {} examples of {}, got batch of {}",
            stage.labels.len(),
            stage.feat_f32.len() / d.max(1),
            indices.len()
        );
        self.order.clear();
        for (slot, &row) in indices.iter().enumerate() {
            anyhow::ensure!(row < self.n, "row {} out of range 0..{}", row, self.n);
            self.order.push((row, slot));
        }
        // in-place sort: ascending rows visit each aligned chunk once
        self.order.sort_unstable();
        for k in 0..self.order.len() {
            let (row, slot) = self.order[k];
            self.ensure_row(row)?;
            let off = (row - self.cache_start) * d;
            let src = &self.cache[off..off + d];
            let dst = &mut stage.feat_f32[slot * d..(slot + 1) * d];
            // same u8 -> f32 map as idx::load_idx_dataset, so staged
            // rows are bitwise equal to the in-memory gather
            for (o, &b) in dst.iter_mut().zip(src) {
                *o = b as f32 / 255.0;
            }
            stage.labels[slot] = self.labels[row];
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::idx::{write_idx, IdxArray};
    use std::path::PathBuf;

    fn write_pair(dir: &Path, n: usize) -> (PathBuf, PathBuf) {
        std::fs::create_dir_all(dir).unwrap();
        let imgs = IdxArray {
            dims: vec![n, 4, 3],
            data: (0..n * 12).map(|i| (i * 31 % 251) as u8).collect(),
        };
        let lbls = IdxArray {
            dims: vec![n],
            data: (0..n).map(|i| (i % 10) as u8).collect(),
        };
        let pi = dir.join("imgs.idx");
        let pl = dir.join("lbls.idx");
        write_idx(&pi, &imgs).unwrap();
        write_idx(&pl, &lbls).unwrap();
        (pi, pl)
    }

    fn stage_for(n: usize, d: usize) -> BatchStage {
        BatchStage {
            feat_f32: vec![0.0; n * d],
            feat_i32: Vec::new(),
            labels: vec![0; n],
            input_dims: vec![n as i64, 1, 4, 3],
            is_f32: true,
        }
    }

    #[test]
    fn streams_rows_identical_to_in_memory_load() {
        let dir = std::env::temp_dir().join("fastclip_stream_unit");
        let (pi, pl) = write_pair(&dir, 50);
        let mut mem = crate::data::idx::load_idx_dataset("t", &pi, &pl, 10).unwrap();
        let mut st = StreamingIdxSource::open("t", &pi, &pl, 10, 7).unwrap();
        assert_eq!(DataSource::len(&st), 50);
        assert_eq!(st.shape(), &[1, 4, 3]);
        // scattered, unsorted, chunk-straddling batch
        let batch = vec![49usize, 0, 13, 7, 48, 6];
        let mut sa = stage_for(6, 12);
        let mut sb = stage_for(6, 12);
        DataSource::fill_batch(&mut mem, &batch, &mut sa).unwrap();
        st.fill_batch(&batch, &mut sb).unwrap();
        assert_eq!(sa.feat_f32, sb.feat_f32);
        assert_eq!(sa.labels, sb.labels);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn residency_bounded_by_chunk_not_dataset() {
        let dir = std::env::temp_dir().join("fastclip_stream_resident");
        let n = 400;
        let (pi, pl) = write_pair(&dir, n);
        let mut st = StreamingIdxSource::open("t", &pi, &pl, 10, 16).unwrap();
        let mut stage = stage_for(8, 12);
        for s in 0..30 {
            let batch: Vec<usize> = (0..8).map(|i| (s * 53 + i * 41) % n).collect();
            st.fill_batch(&batch, &mut stage).unwrap();
        }
        let full_f32 = n * 12 * 4; // what the in-memory Dataset holds
        assert!(
            st.resident_bytes() < full_f32 / 4,
            "resident {} vs in-memory {}",
            st.resident_bytes(),
            full_f32
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncated_image_file_refused() {
        let dir = std::env::temp_dir().join("fastclip_stream_trunc");
        let (pi, pl) = write_pair(&dir, 20);
        let full = std::fs::read(&pi).unwrap();
        std::fs::write(&pi, &full[..full.len() / 2]).unwrap();
        let err = StreamingIdxSource::open("t", &pi, &pl, 10, 8).unwrap_err();
        assert!(err.to_string().contains("bytes"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
