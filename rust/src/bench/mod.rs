//! benchkit — the measurement harness behind every `cargo bench`
//! target (the offline crate set has no criterion; this is the
//! replacement, tuned for whole-step measurements rather than
//! nanosecond microbenches).
//!
//! Usage pattern in a bench target:
//!
//! ```ignore
//! let mut suite = Suite::new("fig5_architectures");
//! suite.bench("mlp2/reweight", opts, || { ... one step ... });
//! suite.finish(); // prints the table + writes bench_out/<name>.json
//! ```

pub mod driver;

use crate::util::json::Json;
use crate::util::stats::Summary;
use std::time::Instant;

#[derive(Debug, Clone, Copy)]
pub struct BenchOpts {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// stop once this much measurement time has accumulated
    pub target_seconds: f64,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 200,
            target_seconds: 2.0,
        }
    }
}

impl BenchOpts {
    /// Scale for expensive cases (nxBP on big models): fewer, longer
    /// iterations.
    pub fn heavy() -> BenchOpts {
        BenchOpts {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 30,
            target_seconds: 3.0,
        }
    }

    /// Honour FASTCLIP_BENCH_FAST=1 (CI smoke mode).
    pub fn from_env(self) -> BenchOpts {
        if std::env::var("FASTCLIP_BENCH_FAST").as_deref() == Ok("1") {
            BenchOpts {
                warmup_iters: 1,
                min_iters: 2,
                max_iters: 3,
                target_seconds: 0.2,
            }
        } else {
            self
        }
    }
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub summary: Summary,
    /// free-form key=value annotations carried into the report
    pub notes: Vec<(String, String)>,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.summary.mean * 1e3
    }
}

/// A named collection of benchmark measurements producing one table.
pub struct Suite {
    pub name: String,
    pub results: Vec<BenchResult>,
    started: Instant,
}

impl Suite {
    pub fn new(name: &str) -> Suite {
        eprintln!("## bench suite: {name}");
        Suite { name: name.to_string(), results: Vec::new(), started: Instant::now() }
    }

    /// Measure `f` (one invocation = one iteration).
    pub fn bench<F: FnMut()>(&mut self, name: &str, opts: BenchOpts, f: F) -> &BenchResult {
        let times = measure(opts, f);
        let summary = Summary::of(&times);
        eprintln!(
            "  {:<44} {:>10.3} ms/iter  (p50 {:.3}, p95 {:.3}, n={})",
            name,
            summary.mean * 1e3,
            summary.p50 * 1e3,
            summary.p95 * 1e3,
            times.len()
        );
        self.results.push(BenchResult {
            name: name.to_string(),
            iters: times.len(),
            summary,
            notes: Vec::new(),
        });
        self.results.last().unwrap()
    }

    /// Record a precomputed measurement (e.g. derived quantities such
    /// as per-epoch extrapolations or memory-model outputs).
    pub fn record(&mut self, name: &str, value_ms: f64, notes: Vec<(String, String)>) {
        eprintln!("  {:<44} {:>10.3} ms (derived)", name, value_ms);
        self.results.push(BenchResult {
            name: name.to_string(),
            iters: 0,
            summary: Summary::of(&[value_ms / 1e3]),
            notes,
        });
    }

    pub fn get(&self, name: &str) -> Option<&BenchResult> {
        self.results.iter().find(|r| r.name == name)
    }

    /// Print a markdown table and persist JSON under bench_out/.
    pub fn finish(self) -> anyhow::Result<()> {
        println!("\n### {} ({:.1}s total)\n", self.name, self.started.elapsed().as_secs_f64());
        println!("| case | mean ms | p50 ms | p95 ms | iters |");
        println!("|---|---:|---:|---:|---:|");
        for r in &self.results {
            println!(
                "| {} | {:.3} | {:.3} | {:.3} | {} |",
                r.name,
                r.summary.mean * 1e3,
                r.summary.p50 * 1e3,
                r.summary.p95 * 1e3,
                r.iters
            );
        }
        let mut arr = Vec::new();
        for r in &self.results {
            let mut o = Json::obj();
            o.set("name", r.name.as_str().into());
            o.set("mean_ms", (r.summary.mean * 1e3).into());
            o.set("p50_ms", (r.summary.p50 * 1e3).into());
            o.set("p95_ms", (r.summary.p95 * 1e3).into());
            o.set("iters", r.iters.into());
            for (k, v) in &r.notes {
                o.set(k, v.as_str().into());
            }
            arr.push(o);
        }
        let mut root = Json::obj();
        root.set("suite", self.name.as_str().into());
        root.set("results", Json::Arr(arr));
        let path = std::path::PathBuf::from("bench_out")
            .join(format!("{}.json", self.name));
        crate::util::write_file(&path, &root.to_string_pretty())?;
        eprintln!("(json: {})", path.display());
        Ok(())
    }
}

/// The one timing policy every harness entry point shares (Suite
/// benches and the bench-matrix runner): honour FASTCLIP_BENCH_FAST,
/// warm up, then iterate under the min/max/target-seconds bounds.
/// Returns the per-iteration times in seconds.
pub fn measure<F: FnMut()>(opts: BenchOpts, mut f: F) -> Vec<f64> {
    let opts = opts.from_env();
    for _ in 0..opts.warmup_iters {
        f();
    }
    let mut times = Vec::new();
    let t0 = Instant::now();
    while times.len() < opts.max_iters
        && (times.len() < opts.min_iters
            || t0.elapsed().as_secs_f64() < opts.target_seconds)
    {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    times
}

/// Speedup helper: a / b with guard.
pub fn speedup(slow_ms: f64, fast_ms: f64) -> f64 {
    if fast_ms <= 0.0 {
        f64::NAN
    } else {
        slow_ms / fast_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_respects_iteration_bounds() {
        let mut s = Suite::new("test_suite");
        let mut count = 0usize;
        let opts = BenchOpts {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 5,
            target_seconds: 0.0,
        };
        let r = s.bench("noop", opts, || {
            count += 1;
        });
        assert!(r.iters >= 3 && r.iters <= 5);
        assert_eq!(count, r.iters + 1); // + warmup
    }

    #[test]
    fn speedup_math() {
        assert!((speedup(100.0, 10.0) - 10.0).abs() < 1e-12);
        assert!(speedup(1.0, 0.0).is_nan());
    }

    #[test]
    fn suite_lookup() {
        let mut s = Suite::new("lookup");
        s.record("a", 5.0, vec![]);
        assert!(s.get("a").is_some());
        assert!(s.get("b").is_none());
        assert!((s.get("a").unwrap().mean_ms() - 5.0).abs() < 1e-9);
    }
}
