//! Shared driver for the per-figure bench targets: wraps one
//! (config, method) pair into a reusable "time one training step"
//! closure with staged data and warm executables.

use crate::coordinator::{stage_batch, ClipMethod, GradComputer};
use crate::data;
use crate::runtime::{
    artifacts_dir, init_params_glorot, BatchStage, Engine, ParamStore,
};
use anyhow::Result;

/// Everything needed to repeatedly execute one step of one method.
pub struct StepRunner {
    computer: GradComputer,
    params: ParamStore,
    stage: BatchStage,
    clip: f32,
    pub batch: usize,
}

impl StepRunner {
    pub fn new(engine: &Engine, config: &str, method: ClipMethod) -> Result<StepRunner> {
        StepRunner::with_dataset(engine, config, method, None)
    }

    /// `dataset_override` runs the same artifact on a different (shape-
    /// compatible) dataset — e.g. the MNIST-shaped MLP on FMNIST data
    /// for Fig 7 (timing is shape-determined; data comes along for
    /// honesty).
    pub fn with_dataset(
        engine: &Engine,
        config: &str,
        method: ClipMethod,
        dataset_override: Option<&str>,
    ) -> Result<StepRunner> {
        let cfg = engine.manifest.config(config)?.clone();
        let dataset = dataset_override.unwrap_or(&cfg.dataset);
        let ds = data::load_dataset(dataset, cfg.batch.max(256), 3)?;
        anyhow::ensure!(
            ds.example_len() * cfg.batch == cfg.input_elems(),
            "dataset {dataset} shape does not match config {config}"
        );
        let mut stage = BatchStage::for_config(&cfg);
        let batch: Vec<usize> = (0..cfg.batch).collect();
        stage_batch(&ds, &batch, &mut stage);
        let params =
            ParamStore::new(&cfg, Some(&init_params_glorot(&cfg, 5)))?;
        let computer = GradComputer::new(engine, config, method)?;
        Ok(StepRunner {
            computer,
            params,
            stage,
            clip: 1.0,
            batch: cfg.batch,
        })
    }

    /// One full gradient computation (what the figures time).
    pub fn step(&mut self) {
        let out = self
            .computer
            .compute(&mut self.params, &self.stage, self.clip)
            .expect("bench step failed");
        std::hint::black_box(out.loss);
    }
}

/// Shared engine for bench targets.
pub fn bench_engine() -> Engine {
    Engine::from_dir(&artifacts_dir()).expect(
        "artifacts not found — run `make artifacts` before `cargo bench`",
    )
}

/// Extrapolate a per-step time to the paper's per-epoch metric.
pub fn per_epoch_seconds(step_mean_s: f64, dataset_n: usize, tau: usize) -> f64 {
    step_mean_s * (dataset_n as f64 / tau as f64)
}

/// The four strategies every figure compares.
pub fn figure_methods() -> [ClipMethod; 4] {
    [
        ClipMethod::NonPrivate,
        ClipMethod::Reweight,
        ClipMethod::MultiLoss,
        ClipMethod::NxBp,
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoch_extrapolation() {
        // 10ms steps, 60000 examples, batch 32 => 1875 steps => 18.75 s
        let s = per_epoch_seconds(0.010, 60_000, 32);
        assert!((s - 18.75).abs() < 1e-9);
    }
}
