//! Shared driver for the per-figure bench targets: wraps one
//! (config, method) pair into a reusable "time one training step"
//! closure with staged data, a persistent `StepOut` arena, and warm
//! steps, over whatever `Backend` is available (PJRT artifacts when
//! present, native otherwise).
//!
//! Also home of the method-matrix runner behind `fastclip
//! bench-matrix`, which produces the `BENCH_<backend>.json` trajectory
//! artifact (per-method step times), the reweight-vs-nxbp speed check
//! CI gates on, and the `BENCH_history.jsonl` trajectory: one compact
//! record per run, appended via `append_history`, gated so a
//! reweight@b128 **p50** step-time regression beyond
//! `HISTORY_MAX_RATIO` versus the recent-history median fails the run
//! loudly (the entry is still recorded, so the trajectory tracks
//! reality and an outlier baseline self-heals). p50 rather than mean:
//! CI smoke runs take a handful of iterations on shared VMs, and one
//! descheduled iteration should not trip — or mask — a gate. Each
//! record also carries `steps_alloc_free`: whether the warm reweight
//! step path performed zero heap allocations (the `StepOut` arena
//! contract), probed at bench time via the counting allocator.

use crate::bench::BenchOpts;
use crate::coordinator::{stage_batch, ClipMethod, GradComputer};
use crate::data;
use crate::runtime::{
    default_backend, init_params_glorot, Backend, BatchStage, ClipPolicy,
    ParamStore, StepOut,
};
use crate::util::json::Json;
use crate::util::stats::Summary;
use anyhow::Result;

/// Everything needed to repeatedly execute one step of one method.
pub struct StepRunner {
    computer: GradComputer,
    params: ParamStore,
    stage: BatchStage,
    /// persistent output arena — reused every step, so the timed path
    /// matches the trainer's (allocation-free on native)
    out: StepOut,
    /// clip policy every timed step clips under (default: the paper's
    /// global hard clip at 1.0)
    policy: ClipPolicy,
    pub batch: usize,
}

impl StepRunner {
    pub fn new(
        backend: &dyn Backend,
        config: &str,
        method: ClipMethod,
    ) -> Result<StepRunner> {
        StepRunner::with_dataset(backend, config, method, None)
    }

    /// `dataset_override` runs the same step on a different (shape-
    /// compatible) dataset — e.g. the MNIST-shaped MLP on FMNIST data
    /// for Fig 7 (timing is shape-determined; data comes along for
    /// honesty).
    pub fn with_dataset(
        backend: &dyn Backend,
        config: &str,
        method: ClipMethod,
        dataset_override: Option<&str>,
    ) -> Result<StepRunner> {
        let cfg = backend.resolve(config)?;
        let dataset = dataset_override.unwrap_or(&cfg.dataset);
        let ds = data::load_dataset(dataset, cfg.batch.max(256), 3)?;
        anyhow::ensure!(
            ds.example_len() * cfg.batch == cfg.input_elems(),
            "dataset {dataset} shape does not match config {config}"
        );
        let mut stage = BatchStage::for_config(&cfg);
        let batch: Vec<usize> = (0..cfg.batch).collect();
        stage_batch(&ds, &batch, &mut stage);
        let params =
            ParamStore::new(&cfg, Some(&init_params_glorot(&cfg, 5)))?;
        let computer = GradComputer::new(backend, config, method)?;
        let out = computer.new_out();
        Ok(StepRunner {
            computer,
            params,
            stage,
            out,
            policy: ClipPolicy::hard_global(1.0),
            batch: cfg.batch,
        })
    }

    /// Swap the clip policy the timed steps run under (e.g. to compare
    /// group-wise against whole-model clipping on the same config).
    pub fn set_policy(&mut self, policy: ClipPolicy) {
        self.policy = policy;
    }

    /// One full gradient computation (what the figures time).
    pub fn step(&mut self) {
        self.computer
            .compute(&mut self.params, &self.stage, &self.policy, &mut self.out)
            .expect("bench step failed");
        std::hint::black_box(self.out.loss);
    }

    /// Probe the arena contract: warm the step, then count heap
    /// allocations across `iters` further steps. Zero means the whole
    /// gradient path (step + coordinator) ran out of reused buffers.
    /// Process-global counter — call from a single-threaded phase (the
    /// step's own rayon workers are part of the measurement, which is
    /// the point). The probe body runs inside one rayon scope so the
    /// pool's external-injection plumbing (which may allocate queue
    /// blocks) stays outside the measured window.
    pub fn probe_alloc_free(&mut self, iters: usize) -> bool {
        let mut clean = false;
        rayon::scope(|_| {
            self.step(); // warm: scratch, lazy buffers, arena
            let before = crate::util::alloc::allocation_count();
            for _ in 0..iters {
                self.step();
            }
            clean = crate::util::alloc::allocation_count() == before;
        });
        clean
    }
}

/// Shared backend for bench targets: PJRT over $FASTCLIP_ARTIFACTS when
/// compiled in and present, the native backend otherwise. Figures that
/// reference CNN/RNN/transformer configs need the artifacts; the MLP
/// figures run on either.
pub fn bench_backend() -> Box<dyn Backend> {
    default_backend().expect("no usable backend for benches")
}

/// Extrapolate a per-step time to the paper's per-epoch metric.
pub fn per_epoch_seconds(step_mean_s: f64, dataset_n: usize, tau: usize) -> f64 {
    step_mean_s * (dataset_n as f64 / tau as f64)
}

/// One timed (config, method) cell of the bench matrix.
#[derive(Debug, Clone)]
pub struct MatrixEntry {
    pub config: String,
    pub batch: usize,
    pub method: ClipMethod,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub iters: usize,
}

/// Per-method step times over a set of configs — the bench
/// trajectory's data point for one backend.
#[derive(Debug, Clone)]
pub struct MatrixReport {
    pub backend: String,
    pub smoke: bool,
    pub entries: Vec<MatrixEntry>,
    /// Whether every probed warm reweight step ran without a single
    /// heap allocation. `None` when no probe ran (non-native backend,
    /// or a method set without reweight).
    pub steps_alloc_free: Option<bool>,
}

impl MatrixReport {
    /// Mean step time of one (config, method) cell, if present.
    pub fn mean_ms(&self, config: &str, method: ClipMethod) -> Option<f64> {
        self.entries
            .iter()
            .find(|e| e.config == config && e.method == method)
            .map(|e| e.mean_ms)
    }

    /// p50 step time of one (config, method) cell, if present.
    pub fn p50_ms(&self, config: &str, method: ClipMethod) -> Option<f64> {
        self.entries
            .iter()
            .find(|e| e.config == config && e.method == method)
            .map(|e| e.p50_ms)
    }

    /// The paper's headline ratio: how many times faster `reweight`'s
    /// step is than the naive `nxbp` loop on `config`.
    pub fn reweight_speedup(&self, config: &str) -> Option<f64> {
        let rw = self.mean_ms(config, ClipMethod::Reweight)?;
        let nx = self.mean_ms(config, ClipMethod::NxBp)?;
        if rw <= 0.0 {
            return None;
        }
        Some(nx / rw)
    }

    /// The CI gate: on every batch-128 config that timed both methods,
    /// reweight must beat nxbp. Errors if no such config was measured
    /// (an empty check must not pass green).
    pub fn check_reweight_beats_nxbp(&self) -> Result<()> {
        let mut checked = 0usize;
        for e in &self.entries {
            if e.batch != 128 || e.method != ClipMethod::Reweight {
                continue;
            }
            let Some(nx) = self.mean_ms(&e.config, ClipMethod::NxBp) else {
                continue;
            };
            anyhow::ensure!(
                e.mean_ms < nx,
                "{}: reweight ({:.3} ms) is not faster than nxbp ({:.3} ms) \
                 at batch 128 — the batched clipping path has lost its \
                 structural advantage",
                e.config,
                e.mean_ms,
                nx
            );
            checked += 1;
        }
        anyhow::ensure!(
            checked > 0,
            "no batch-128 config with both reweight and nxbp timings in the \
             matrix — the check would be vacuous"
        );
        Ok(())
    }

    /// The arena gate: the alloc-free probe must have run and found
    /// the warm reweight step path allocation-free.
    pub fn check_steps_alloc_free(&self) -> Result<()> {
        match self.steps_alloc_free {
            Some(true) => Ok(()),
            Some(false) => anyhow::bail!(
                "warm reweight steps performed heap allocations — the \
                 StepOut arena contract regressed (see tests/no_alloc.rs \
                 for the per-method breakdown)"
            ),
            None => anyhow::bail!(
                "no alloc-free probe ran (non-native backend or no reweight \
                 entries) — the check would be vacuous"
            ),
        }
    }

    /// Compact record for the `BENCH_history.jsonl` trajectory: the
    /// reweight step p50s (and, for provenance/back-compat, means) on
    /// every batch-128 config in this run — the paper's headline
    /// operating point — plus the alloc-free probe result.
    pub fn history_entry(&self) -> Json {
        let mut p50s = Json::obj();
        let mut means = Json::obj();
        for e in &self.entries {
            if e.batch == 128 && e.method == ClipMethod::Reweight {
                p50s.set(&e.config, e.p50_ms.into());
                means.set(&e.config, e.mean_ms.into());
            }
        }
        let mut o = Json::obj();
        o.set("suite", "bench_matrix".into());
        o.set("backend", self.backend.as_str().into());
        o.set("smoke", self.smoke.into());
        if let Ok(sha) = std::env::var("GITHUB_SHA") {
            o.set("commit", sha.into());
        }
        o.set("reweight_b128_p50_ms", p50s);
        o.set("reweight_b128_ms", means);
        if let Some(af) = self.steps_alloc_free {
            o.set("steps_alloc_free", af.into());
        }
        o
    }

    /// The trajectory gate: no batch-128 config's reweight **p50**
    /// step time may be more than `max_ratio`x its **median** over the
    /// recent history entries in `prevs`. p50 (not mean) on both sides
    /// cuts smoke-run noise: one descheduled iteration in a 5-iter CI
    /// run inflates the mean by its full cost but leaves the median
    /// untouched. The median baseline makes the gate robust in both
    /// directions: one anomalously fast run cannot become a baseline
    /// that fails every later run, and one recorded regression cannot
    /// be laundered into the baseline by simply re-running the failed
    /// job. History entries from before the p50 migration contribute
    /// their recorded mean (`reweight_b128_ms`) instead of being
    /// skipped. Configs absent from the history are skipped — the
    /// matrix can grow — and malformed records contribute nothing
    /// rather than blocking every future run.
    pub fn check_history_regression(
        &self,
        prevs: &[Json],
        max_ratio: f64,
    ) -> Result<()> {
        for e in &self.entries {
            if e.batch != 128 || e.method != ClipMethod::Reweight {
                continue;
            }
            let mut samples: Vec<f64> = prevs
                .iter()
                .filter_map(|p| history_value(p, &e.config))
                .collect();
            if samples.is_empty() {
                continue;
            }
            samples.sort_by(|a, b| a.total_cmp(b));
            let baseline = samples[samples.len() / 2];
            anyhow::ensure!(
                e.p50_ms <= baseline * max_ratio,
                "{}: reweight@b128 p50 step time {:.3} ms is more than \
                 {:.0}% over the recent BENCH_history median {:.3} ms \
                 ({} samples)",
                e.config,
                e.p50_ms,
                (max_ratio - 1.0) * 100.0,
                baseline,
                samples.len()
            );
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut entries = Vec::with_capacity(self.entries.len());
        for e in &self.entries {
            let mut o = Json::obj();
            o.set("config", e.config.as_str().into());
            o.set("batch", e.batch.into());
            o.set("method", e.method.name().into());
            o.set("mean_ms", e.mean_ms.into());
            o.set("p50_ms", e.p50_ms.into());
            o.set("p95_ms", e.p95_ms.into());
            o.set("iters", e.iters.into());
            entries.push(o);
        }
        let mut speedups = Json::obj();
        let mut seen: Vec<&str> = Vec::new();
        for e in &self.entries {
            if seen.contains(&e.config.as_str()) {
                continue;
            }
            seen.push(&e.config);
            if let Some(s) = self.reweight_speedup(&e.config) {
                speedups.set(&e.config, s.into());
            }
        }
        let mut root = Json::obj();
        root.set("suite", "bench_matrix".into());
        root.set("backend", self.backend.as_str().into());
        root.set("smoke", self.smoke.into());
        if let Some(af) = self.steps_alloc_free {
            root.set("steps_alloc_free", af.into());
        }
        root.set("entries", Json::Arr(entries));
        root.set("reweight_speedup_vs_nxbp", speedups);
        root
    }
}

/// Step-time regression budget for the history gate: fail when a
/// reweight@b128 p50 step exceeds 1.25x the recent-history median
/// (>25%).
pub const HISTORY_MAX_RATIO: f64 = 1.25;

/// How many trailing history entries feed the gate's median baseline.
pub const HISTORY_WINDOW: usize = 5;

/// Append `report`'s compact record to the `BENCH_history.jsonl`
/// trajectory at `path`, gating against the median of the trailing
/// `HISTORY_WINDOW` entries via `check_history_regression`. The new
/// entry is appended **even when the gate trips** — the history
/// records reality; robustness against outlier baselines and
/// laundered regressions comes from the median, not from editing the
/// record. Unparsable lines (e.g. a half-written record from a killed
/// job) are skipped instead of bricking the gate.
pub fn append_history(
    report: &MatrixReport,
    path: &std::path::Path,
    max_ratio: f64,
) -> Result<()> {
    let mut text = if path.exists() {
        crate::util::read_file(path)?
    } else {
        String::new()
    };
    let prevs: Vec<Json> = text
        .lines()
        .filter(|l| !l.trim().is_empty())
        .rev()
        .take(HISTORY_WINDOW)
        .filter_map(|l| Json::parse(l).ok())
        .collect();
    let check = report.check_history_regression(&prevs, max_ratio);
    if !text.is_empty() && !text.ends_with('\n') {
        text.push('\n');
    }
    text.push_str(&report.history_entry().to_string());
    text.push('\n');
    crate::util::write_file(path, &text)?;
    check
}

/// One history entry's reweight@b128 step time for `config`: the p50
/// field wins, legacy mean-only records contribute their recorded
/// mean (`reweight_b128_ms`), malformed or non-positive values yield
/// `None`. The single extraction rule shared by the regression gate
/// (`check_history_regression`) and the renderer (`render_history`),
/// so the two can never disagree about the same jsonl line.
fn history_value(entry: &Json, config: &str) -> Option<f64> {
    entry
        .get("reweight_b128_p50_ms")
        .get(config)
        .as_f64()
        .or_else(|| entry.get("reweight_b128_ms").get(config).as_f64())
        .filter(|&v| v > 0.0)
}

/// Render the `BENCH_history.jsonl` trajectory as a markdown report:
/// one row per config key with run count, best/median/latest
/// reweight@b128 p50 and an ASCII sparkline of the whole series — the
/// "graph the jsonl across PRs" artifact CI uploads next to the raw
/// history (`fastclip bench-history`). Per entry, the p50 field wins;
/// legacy mean-only records contribute their recorded mean. Malformed
/// or non-positive values contribute nothing.
pub fn render_history(entries: &[Json]) -> String {
    use std::collections::BTreeMap;
    let mut series: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    for e in entries {
        let p50s = e.get("reweight_b128_p50_ms");
        let means = e.get("reweight_b128_ms");
        let mut keys: Vec<String> = Vec::new();
        if let Some(o) = p50s.as_obj() {
            keys.extend(o.keys().cloned());
        }
        if let Some(o) = means.as_obj() {
            keys.extend(o.keys().cloned());
        }
        keys.sort();
        keys.dedup();
        for k in keys {
            if let Some(v) = history_value(e, &k) {
                series.entry(k).or_default().push(v);
            }
        }
    }
    let mut out = String::new();
    out.push_str("# Bench history — reweight@b128 p50 step time (ms)\n\n");
    if series.is_empty() {
        out.push_str("_no parseable history entries_\n");
        return out;
    }
    out.push_str("| config | runs | best | median | latest | trend |\n");
    out.push_str("|---|---:|---:|---:|---:|---|\n");
    for (config, vals) in &series {
        let mut sorted = vals.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let best = sorted[0];
        let median = sorted[sorted.len() / 2];
        let latest = *vals.last().unwrap();
        out.push_str(&format!(
            "| {config} | {} | {best:.3} | {median:.3} | {latest:.3} | `{}` |\n",
            vals.len(),
            sparkline(vals)
        ));
    }
    out.push_str(
        "\nLower is faster. The sparkline spans the full series in file \
         order (oldest → newest), scaled per config.\n",
    );
    out
}

/// Map a series onto the eight unicode block heights, scaled to the
/// series' own min..max; a constant series renders mid-height.
pub fn sparkline(vals: &[f64]) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &v in vals {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    vals.iter()
        .map(|&v| {
            let t = if hi > lo { (v - lo) / (hi - lo) } else { 0.5 };
            BARS[((t * 7.0).round() as usize).min(7)]
        })
        .collect()
}

/// Time every (config, method) cell: warmup, then iterate under
/// `opts`'s iteration/time bounds. Methods a config cannot run
/// (e.g. a backend without the artifact) fail hard — the matrix is
/// the support claim, so a hole is an error, not a skip. On the
/// native backend, every reweight cell is additionally probed for the
/// zero-allocation warm path (`steps_alloc_free`). Every cell clips
/// under `policy` (pass `ClipPolicy::hard_global(1.0)` for the
/// classical matrix the trajectory artifacts track).
pub fn run_matrix(
    backend: &dyn Backend,
    configs: &[String],
    methods: &[ClipMethod],
    opts: BenchOpts,
    smoke: bool,
    policy: &ClipPolicy,
) -> Result<MatrixReport> {
    let mut entries = Vec::with_capacity(configs.len() * methods.len());
    // the probe only holds on native — PJRT marshalling allocates —
    // and only measures anything when the counting allocator is
    // installed (`alloc-count` feature, on by default)
    let probe =
        backend.name() == "native" && crate::util::alloc::counting_enabled();
    let mut alloc_free: Option<bool> = None;
    for config in configs {
        for &method in methods {
            let mut runner = StepRunner::new(backend, config, method)?;
            runner.set_policy(policy.clone());
            let times = crate::bench::measure(opts, || runner.step());
            let s = Summary::of(&times);
            crate::log_info!(
                "bench {config}/{}: {:.3} ms/step over {} iters",
                method.name(),
                s.mean * 1e3,
                times.len()
            );
            if probe && method == ClipMethod::Reweight {
                let clean = runner.probe_alloc_free(3);
                if !clean {
                    crate::log_info!(
                        "bench {config}/reweight: warm step path ALLOCATED"
                    );
                }
                alloc_free = Some(alloc_free.unwrap_or(true) && clean);
            }
            entries.push(MatrixEntry {
                config: config.clone(),
                batch: runner.batch,
                method,
                mean_ms: s.mean * 1e3,
                p50_ms: s.p50 * 1e3,
                p95_ms: s.p95 * 1e3,
                iters: times.len(),
            });
        }
    }
    Ok(MatrixReport {
        backend: backend.name().to_string(),
        smoke,
        entries,
        steps_alloc_free: alloc_free,
    })
}

/// The four strategies every figure compares.
pub fn figure_methods() -> [ClipMethod; 4] {
    [
        ClipMethod::NonPrivate,
        ClipMethod::Reweight,
        ClipMethod::MultiLoss,
        ClipMethod::NxBp,
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoch_extrapolation() {
        // 10ms steps, 60000 examples, batch 32 => 1875 steps => 18.75 s
        let s = per_epoch_seconds(0.010, 60_000, 32);
        assert!((s - 18.75).abs() < 1e-9);
    }

    #[test]
    fn matrix_check_logic() {
        let mk = |method: ClipMethod, mean_ms: f64| MatrixEntry {
            config: "mlp4_mnist_b128".into(),
            batch: 128,
            method,
            mean_ms,
            p50_ms: mean_ms,
            p95_ms: mean_ms,
            iters: 3,
        };
        let mut r = MatrixReport {
            backend: "native".into(),
            smoke: true,
            entries: vec![
                mk(ClipMethod::Reweight, 1.0),
                mk(ClipMethod::NxBp, 5.0),
            ],
            steps_alloc_free: Some(true),
        };
        assert!(r.check_reweight_beats_nxbp().is_ok());
        assert!(r.check_steps_alloc_free().is_ok());
        assert!(
            (r.reweight_speedup("mlp4_mnist_b128").unwrap() - 5.0).abs()
                < 1e-9
        );
        let j = r.to_json().to_string();
        assert!(j.contains("reweight") && j.contains("mlp4_mnist_b128"));
        assert!(j.contains("steps_alloc_free"));
        // reweight slower than nxbp => the gate trips
        r.entries[0].mean_ms = 10.0;
        assert!(r.check_reweight_beats_nxbp().is_err());
        // an allocating warm path trips the arena gate; an unprobed
        // run must not pass vacuously
        r.steps_alloc_free = Some(false);
        assert!(r.check_steps_alloc_free().is_err());
        r.steps_alloc_free = None;
        assert!(r.check_steps_alloc_free().is_err());
        // an empty matrix must not pass vacuously
        let empty = MatrixReport {
            backend: "native".into(),
            smoke: true,
            entries: Vec::new(),
            steps_alloc_free: None,
        };
        assert!(empty.check_reweight_beats_nxbp().is_err());
    }

    fn entry_with(config: &str, mean_ms: f64, p50_ms: f64) -> MatrixReport {
        MatrixReport {
            backend: "native".into(),
            smoke: true,
            entries: vec![MatrixEntry {
                config: config.into(),
                batch: 128,
                method: ClipMethod::Reweight,
                mean_ms,
                p50_ms,
                p95_ms: mean_ms,
                iters: 3,
            }],
            steps_alloc_free: Some(true),
        }
    }

    fn report_with(config: &str, reweight_ms: f64) -> MatrixReport {
        entry_with(config, reweight_ms, reweight_ms)
    }

    #[test]
    fn history_gate_trips_only_past_the_budget() {
        let prevs = vec![report_with("cnn2_mnist_b128", 10.0).history_entry()];
        // +20% passes, +30% fails
        assert!(report_with("cnn2_mnist_b128", 12.0)
            .check_history_regression(&prevs, HISTORY_MAX_RATIO)
            .is_ok());
        let err = report_with("cnn2_mnist_b128", 13.0)
            .check_history_regression(&prevs, HISTORY_MAX_RATIO)
            .unwrap_err();
        assert!(format!("{err:#}").contains("median"), "{err:#}");
        // a config the history never measured is not gated
        assert!(report_with("mlp4_mnist_b128", 999.0)
            .check_history_regression(&prevs, HISTORY_MAX_RATIO)
            .is_ok());
        // malformed history entries contribute nothing (never block)
        assert!(report_with("cnn2_mnist_b128", 999.0)
            .check_history_regression(
                &[Json::parse("{}").unwrap()],
                HISTORY_MAX_RATIO
            )
            .is_ok());
        // the median absorbs a single outlier: one anomalously fast
        // entry among normal ones does not trip the gate...
        let window: Vec<Json> = [10.0, 9.8, 4.0, 10.2, 9.9]
            .iter()
            .map(|&ms| report_with("cnn2_mnist_b128", ms).history_entry())
            .collect();
        assert!(report_with("cnn2_mnist_b128", 11.0)
            .check_history_regression(&window, HISTORY_MAX_RATIO)
            .is_ok());
        // ...and one recorded regression cannot launder itself into
        // the baseline: re-checking against a window that contains it
        // still fails
        let window: Vec<Json> = [20.0, 10.0, 9.8, 10.2, 9.9]
            .iter()
            .map(|&ms| report_with("cnn2_mnist_b128", ms).history_entry())
            .collect();
        assert!(report_with("cnn2_mnist_b128", 20.0)
            .check_history_regression(&window, HISTORY_MAX_RATIO)
            .is_err());
    }

    /// The gate compares p50s, not means: an entry whose mean is blown
    /// up by one slow iteration passes as long as its p50 holds, and a
    /// p50 regression trips even under an innocent-looking mean.
    #[test]
    fn history_gate_is_p50_based() {
        let prevs = vec![report_with("cnn2_mnist_b128", 10.0).history_entry()];
        // mean 3x the baseline, p50 fine => passes
        assert!(entry_with("cnn2_mnist_b128", 30.0, 10.0)
            .check_history_regression(&prevs, HISTORY_MAX_RATIO)
            .is_ok());
        // mean fine, p50 regressed => trips
        assert!(entry_with("cnn2_mnist_b128", 10.0, 30.0)
            .check_history_regression(&prevs, HISTORY_MAX_RATIO)
            .is_err());
        // legacy history entries (mean-only records) still gate: strip
        // the p50 field to simulate a pre-migration line
        let legacy = Json::parse(
            r#"{"reweight_b128_ms": {"cnn2_mnist_b128": 10.0}}"#,
        )
        .unwrap();
        assert!(entry_with("cnn2_mnist_b128", 10.0, 30.0)
            .check_history_regression(&[legacy], HISTORY_MAX_RATIO)
            .is_err());
    }

    #[test]
    fn history_file_appends_and_flags_regressions() {
        let path = std::env::temp_dir().join("fastclip_bench_history_test.jsonl");
        std::fs::remove_file(&path).ok();
        append_history(&report_with("cnn2_mnist_b128", 10.0), &path, 1.25)
            .unwrap();
        append_history(&report_with("cnn2_mnist_b128", 11.0), &path, 1.25)
            .unwrap();
        // regression vs the window median (20.0 > 11.0 * 1.25): the
        // gate errors, but the entry is still recorded so the
        // trajectory reflects reality
        assert!(append_history(&report_with("cnn2_mnist_b128", 20.0), &path, 1.25)
            .is_err());
        let after = std::fs::read_to_string(&path).unwrap();
        assert_eq!(after.lines().count(), 3);
        let last = Json::parse(after.lines().last().unwrap()).unwrap();
        assert_eq!(
            last.get("reweight_b128_p50_ms").get("cnn2_mnist_b128").as_f64(),
            Some(20.0)
        );
        assert_eq!(last.get("steps_alloc_free").as_bool(), Some(true));
        // a re-run at the regressed speed still fails: the median of
        // {10, 11, 20} is 11, so the recorded regression has not
        // become its own baseline
        assert!(append_history(&report_with("cnn2_mnist_b128", 19.0), &path, 1.25)
            .is_err());
        // a recovered run passes (upper median of {10,11,19,20} is
        // 19, and 12 <= 19 * 1.25)
        append_history(&report_with("cnn2_mnist_b128", 12.0), &path, 1.25)
            .unwrap();
        // a corrupt trailing line (half-written record) is skipped by
        // the parser instead of permanently failing the gate
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("{\"reweight_b128_p50_ms\": {\"cnn2_mni");
        std::fs::write(&path, &text).unwrap();
        // median of the parseable window {11,20,19,12} is 19;
        // 13 <= 19*1.25 passes — the corrupt line cost nothing
        append_history(&report_with("cnn2_mnist_b128", 13.0), &path, 1.25)
            .unwrap();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn history_renders_tables_and_sparklines() {
        // three modern entries + one legacy mean-only + one malformed
        let mut entries: Vec<Json> = [10.0, 12.0, 8.0]
            .iter()
            .map(|&ms| report_with("cnn2_mnist_b128", ms).history_entry())
            .collect();
        entries.push(
            Json::parse(r#"{"reweight_b128_ms": {"cnn2_mnist_b128": 14.0}}"#)
                .unwrap(),
        );
        entries.push(Json::parse("{}").unwrap());
        let md = render_history(&entries);
        assert!(md.contains("| cnn2_mnist_b128 | 4 |"), "{md}");
        // best 8, median of {8,10,12,14} (upper) 12, latest 14
        assert!(md.contains("| 8.000 | 12.000 | 14.000 |"), "{md}");
        // the sparkline covers all four runs and spans the full range
        assert!(md.contains('▁') && md.contains('█'), "{md}");
        // spec-key config names survive as table keys
        let spec_entries = vec![report_with(
            "mlp(depth=4,width=512)@cifar10:b128",
            5.0,
        )
        .history_entry()];
        let md = render_history(&spec_entries);
        assert!(md.contains("mlp(depth=4,width=512)@cifar10:b128"), "{md}");
        // an empty/garbage history renders a note, not a panic
        assert!(render_history(&[]).contains("no parseable"));
    }

    #[test]
    fn sparkline_scales_and_handles_constants() {
        let s = sparkline(&[1.0, 8.0]);
        assert_eq!(s.chars().count(), 2);
        assert_eq!(s.chars().next(), Some('▁'));
        assert_eq!(s.chars().last(), Some('█'));
        // constant series: mid-height, no division by zero
        let c = sparkline(&[3.0, 3.0, 3.0]);
        assert_eq!(c.chars().count(), 3);
        assert!(c.chars().all(|ch| ch == c.chars().next().unwrap()));
    }

    #[test]
    fn run_matrix_times_native_methods_and_probes_alloc() {
        let backend = crate::runtime::NativeBackend::new();
        let opts = BenchOpts {
            warmup_iters: 1,
            min_iters: 1,
            max_iters: 2,
            target_seconds: 0.0,
        };
        let report = run_matrix(
            &backend,
            &["mlp2_mnist_b16".to_string()],
            &[ClipMethod::Reweight, ClipMethod::ReweightDirect],
            opts,
            true,
            &ClipPolicy::hard_global(1.0),
        )
        .unwrap();
        assert_eq!(report.entries.len(), 2);
        assert!(report.entries.iter().all(|e| e.mean_ms > 0.0));
        assert_eq!(report.backend, "native");
        // the reweight cell was probed; whether it is clean is pinned
        // (strictly) by tests/no_alloc.rs — here we only require the
        // probe to have run on a native matrix containing reweight
        assert!(report.steps_alloc_free.is_some());
    }

    #[test]
    fn step_runner_on_native_backend() {
        // hermetic: construct the native backend explicitly rather
        // than going through the env-dependent auto selection
        let backend = crate::runtime::NativeBackend::new();
        let mut runner =
            StepRunner::new(&backend, "mlp2_mnist_b16", ClipMethod::Reweight)
                .unwrap();
        runner.step(); // must not panic
        assert_eq!(runner.batch, 16);
        // grouped and automatic policies run through the same timed path
        runner.set_policy(ClipPolicy::parse("per_layer:0.5").unwrap());
        runner.step();
        runner.set_policy(ClipPolicy::parse("auto:1,g=0.01").unwrap());
        runner.step();
    }
}
