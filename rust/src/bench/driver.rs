//! Shared driver for the per-figure bench targets: wraps one
//! (config, method) pair into a reusable "time one training step"
//! closure with staged data and warm steps, over whatever `Backend`
//! is available (PJRT artifacts when present, native otherwise).

use crate::coordinator::{stage_batch, ClipMethod, GradComputer};
use crate::data;
use crate::runtime::{
    default_backend, init_params_glorot, Backend, BatchStage, ParamStore,
};
use anyhow::Result;

/// Everything needed to repeatedly execute one step of one method.
pub struct StepRunner {
    computer: GradComputer,
    params: ParamStore,
    stage: BatchStage,
    clip: f32,
    pub batch: usize,
}

impl StepRunner {
    pub fn new(
        backend: &dyn Backend,
        config: &str,
        method: ClipMethod,
    ) -> Result<StepRunner> {
        StepRunner::with_dataset(backend, config, method, None)
    }

    /// `dataset_override` runs the same step on a different (shape-
    /// compatible) dataset — e.g. the MNIST-shaped MLP on FMNIST data
    /// for Fig 7 (timing is shape-determined; data comes along for
    /// honesty).
    pub fn with_dataset(
        backend: &dyn Backend,
        config: &str,
        method: ClipMethod,
        dataset_override: Option<&str>,
    ) -> Result<StepRunner> {
        let cfg = backend.manifest().config(config)?.clone();
        let dataset = dataset_override.unwrap_or(&cfg.dataset);
        let ds = data::load_dataset(dataset, cfg.batch.max(256), 3)?;
        anyhow::ensure!(
            ds.example_len() * cfg.batch == cfg.input_elems(),
            "dataset {dataset} shape does not match config {config}"
        );
        let mut stage = BatchStage::for_config(&cfg);
        let batch: Vec<usize> = (0..cfg.batch).collect();
        stage_batch(&ds, &batch, &mut stage);
        let params =
            ParamStore::new(&cfg, Some(&init_params_glorot(&cfg, 5)))?;
        let computer = GradComputer::new(backend, config, method)?;
        Ok(StepRunner {
            computer,
            params,
            stage,
            clip: 1.0,
            batch: cfg.batch,
        })
    }

    /// One full gradient computation (what the figures time).
    pub fn step(&mut self) {
        let out = self
            .computer
            .compute(&mut self.params, &self.stage, self.clip)
            .expect("bench step failed");
        std::hint::black_box(out.loss);
    }
}

/// Shared backend for bench targets: PJRT over $FASTCLIP_ARTIFACTS when
/// compiled in and present, the native backend otherwise. Figures that
/// reference CNN/RNN/transformer configs need the artifacts; the MLP
/// figures run on either.
pub fn bench_backend() -> Box<dyn Backend> {
    default_backend().expect("no usable backend for benches")
}

/// Extrapolate a per-step time to the paper's per-epoch metric.
pub fn per_epoch_seconds(step_mean_s: f64, dataset_n: usize, tau: usize) -> f64 {
    step_mean_s * (dataset_n as f64 / tau as f64)
}

/// The four strategies every figure compares.
pub fn figure_methods() -> [ClipMethod; 4] {
    [
        ClipMethod::NonPrivate,
        ClipMethod::Reweight,
        ClipMethod::MultiLoss,
        ClipMethod::NxBp,
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoch_extrapolation() {
        // 10ms steps, 60000 examples, batch 32 => 1875 steps => 18.75 s
        let s = per_epoch_seconds(0.010, 60_000, 32);
        assert!((s - 18.75).abs() < 1e-9);
    }

    #[test]
    fn step_runner_on_native_backend() {
        // hermetic: construct the native backend explicitly rather
        // than going through the env-dependent auto selection
        let backend = crate::runtime::NativeBackend::new();
        let mut runner =
            StepRunner::new(&backend, "mlp2_mnist_b16", ClipMethod::Reweight)
                .unwrap();
        runner.step(); // must not panic
        assert_eq!(runner.batch, 16);
    }
}
