//! Checkpointing: parameters as raw little-endian f32 (`.bin`) plus a
//! JSON sidecar with run metadata (step, accountant state inputs,
//! optimizer name). Resumable and Python-free.
//!
//! Writes are atomic per file: content goes to a `.tmp` sibling,
//! fsyncs, then renames over the final name (and the directory is
//! fsynced so the rename itself is durable). A crash mid-write leaves
//! either the previous checkpoint or the new one — never a truncated
//! file that `load` would deserialize as garbage. `params.bin` renames
//! before `meta.json`: the sidecar is the commit record, so a crash
//! between the two renames leaves the old metadata (resume re-runs a
//! suffix) rather than metadata describing parameters that were never
//! written.
//!
//! [`CheckpointWriter`] moves saves off the serve scheduler's hot
//! path: a background thread drains a queue of (dir, meta, params)
//! jobs through the same atomic `save_flat`, so the continuity
//! guarantees are identical to an inline save.

use crate::runtime::{ConfigSpec, ParamStore};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

#[derive(Debug, Clone)]
pub struct CheckpointMeta {
    pub config: String,
    pub method: String,
    /// optimizer name ("adam"/"sgd"); empty in pre-PR5 checkpoints.
    /// Optimizer *state* (Adam moments) is not checkpointed — resume
    /// validates the name and warns that stateful optimizers restart
    /// their moments (see `trainer::train`).
    pub optimizer: String,
    pub step: u64,
    pub sampling_rate: f64,
    pub sigma: f64,
    pub clip: f64,
    /// learning rate of the recorded steps; 0.0 in pre-PR5 checkpoints
    /// (resume skips the continuity check then)
    pub lr: f64,
    pub seed: u64,
    /// Poisson subsampling vs shuffle-partition — the sampling regime
    /// the recorded steps ran under (and the one the RDP re-charge
    /// assumes). `None` for pre-PR5 checkpoints that did not record
    /// it: resume must *skip* the mode check then, not treat the
    /// absence as a definitive shuffle-partition.
    pub poisson: Option<bool>,
    /// Canonical clip-policy name (`ClipPolicy` Display form, e.g.
    /// `per_layer:0.5` or `auto:1,g=0.01`) the recorded steps clipped
    /// under. `None` for pre-policy checkpoints, which recorded only
    /// the bare `clip` — resume treats that as the classical global
    /// hard policy rather than skipping the check.
    pub clip_policy: Option<String>,
}

pub fn save(
    dir: &Path,
    meta: &CheckpointMeta,
    params: &ParamStore,
) -> Result<()> {
    save_flat(dir, meta, &params.host)
}

/// Write `path` atomically: `.tmp` sibling, fsync, rename, directory
/// fsync. The data fsync precedes the rename — rename-before-data
/// could expose a durable name pointing at un-flushed content.
fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let mut tmp_name = path.as_os_str().to_owned();
    tmp_name.push(".tmp");
    let tmp = PathBuf::from(tmp_name);
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(bytes)?;
        f.sync_all()
            .with_context(|| format!("fsync {}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path).with_context(|| {
        format!("renaming {} into place", path.display())
    })?;
    if let Some(parent) = path.parent() {
        // directory fsync makes the rename durable; opening a dir
        // read-only works on the unix targets we build for, and a
        // failure here (exotic fs) only weakens durability, never
        // correctness — so it is advisory
        if let Ok(d) = std::fs::File::open(parent) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

/// `save` for a bare host parameter list — what the serve scheduler's
/// writer thread snapshots (it cannot hold the session's `ParamStore`
/// across the queue).
pub fn save_flat(
    dir: &Path,
    meta: &CheckpointMeta,
    host: &[Vec<f32>],
) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let total: usize = host.iter().map(|v| v.len()).sum();
    let mut bin = Vec::with_capacity(total * 4);
    for v in host {
        for f in v {
            bin.extend_from_slice(&f.to_le_bytes());
        }
    }
    // params first, meta second: meta.json is the commit record
    write_atomic(&dir.join("params.bin"), &bin)?;
    let mut j = Json::obj();
    j.set("config", meta.config.as_str().into());
    j.set("method", meta.method.as_str().into());
    j.set("optimizer", meta.optimizer.as_str().into());
    j.set("step", (meta.step as usize).into());
    j.set("sampling_rate", meta.sampling_rate.into());
    j.set("sigma", meta.sigma.into());
    j.set("clip", meta.clip.into());
    j.set("lr", meta.lr.into());
    j.set("seed", (meta.seed as usize).into());
    if let Some(p) = meta.poisson {
        j.set("poisson", p.into());
    }
    if let Some(cp) = &meta.clip_policy {
        j.set("clip_policy", cp.as_str().into());
    }
    j.set("param_elems", total.into());
    write_atomic(&dir.join("meta.json"), j.to_string_pretty().as_bytes())?;
    Ok(())
}

/// A background checkpoint writer: `enqueue` hands off a (dir, meta,
/// params-snapshot) job and returns immediately; the writer thread
/// runs the same atomic [`save_flat`], so every checkpoint it lands
/// upholds the resume continuity guards. `finish` drains the queue,
/// joins the thread, and surfaces the first write error.
pub struct CheckpointWriter {
    tx: Option<std::sync::mpsc::Sender<WriteJob>>,
    handle: Option<std::thread::JoinHandle<Result<()>>>,
}

struct WriteJob {
    dir: PathBuf,
    meta: CheckpointMeta,
    host: Vec<Vec<f32>>,
}

impl CheckpointWriter {
    pub fn spawn() -> CheckpointWriter {
        let (tx, rx) = std::sync::mpsc::channel::<WriteJob>();
        let handle = std::thread::spawn(move || -> Result<()> {
            // stop at the first failure: a later job's checkpoint must
            // not paper over an earlier job's missing one
            for job in rx {
                save_flat(&job.dir, &job.meta, &job.host).with_context(|| {
                    format!("checkpoint writer: {}", job.dir.display())
                })?;
            }
            Ok(())
        });
        CheckpointWriter { tx: Some(tx), handle: Some(handle) }
    }

    pub fn enqueue(
        &self,
        dir: &Path,
        meta: CheckpointMeta,
        host: Vec<Vec<f32>>,
    ) -> Result<()> {
        self.tx
            .as_ref()
            .expect("checkpoint writer already finished")
            .send(WriteJob { dir: dir.to_path_buf(), meta, host })
            .map_err(|_| {
                anyhow::anyhow!(
                    "checkpoint writer thread exited early — a previous \
                     save failed; its error surfaces from finish()"
                )
            })
    }

    /// Close the queue, wait for pending saves, propagate the first
    /// write error.
    pub fn finish(mut self) -> Result<()> {
        drop(self.tx.take());
        let handle = self.handle.take().expect("checkpoint writer handle");
        match handle.join() {
            Ok(r) => r,
            Err(_) => bail!("checkpoint writer thread panicked"),
        }
    }
}

pub fn load(dir: &Path, cfg: &ConfigSpec) -> Result<(CheckpointMeta, Vec<f32>)> {
    let meta_text = crate::util::read_file(&dir.join("meta.json"))?;
    let j = Json::parse(&meta_text).context("parsing checkpoint meta")?;
    let meta = CheckpointMeta {
        config: j.get("config").as_str().unwrap_or("").to_string(),
        method: j.get("method").as_str().unwrap_or("").to_string(),
        optimizer: j.get("optimizer").as_str().unwrap_or("").to_string(),
        step: j.get("step").as_usize().unwrap_or(0) as u64,
        sampling_rate: j.get("sampling_rate").as_f64().unwrap_or(0.0),
        sigma: j.get("sigma").as_f64().unwrap_or(0.0),
        clip: j.get("clip").as_f64().unwrap_or(1.0),
        lr: j.get("lr").as_f64().unwrap_or(0.0),
        seed: j.get("seed").as_usize().unwrap_or(0) as u64,
        poisson: j.get("poisson").as_bool(),
        clip_policy: j.get("clip_policy").as_str().map(String::from),
    };
    if meta.config != cfg.name {
        bail!(
            "checkpoint is for config {:?}, expected {:?}",
            meta.config,
            cfg.name
        );
    }
    let mut f = std::fs::File::open(dir.join("params.bin"))?;
    let mut bytes = Vec::new();
    f.read_to_end(&mut bytes)?;
    if bytes.len() != cfg.param_elems() * 4 {
        bail!(
            "params.bin has {} bytes, expected {}",
            bytes.len(),
            cfg.param_elems() * 4
        );
    }
    let flat: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok((meta, flat))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ParamSpec;

    fn cfg() -> ConfigSpec {
        ConfigSpec {
            name: "ckpt_test".into(),
            model: "mlp".into(),
            dataset: "mnist".into(),
            batch: 2,
            n_classes: 10,
            tags: vec![],
            input_shape: vec![2, 4],
            input_dtype: "f32".into(),
            act_elems_per_example: 0,
            conv: None,
            spec: None,
            params: vec![
                ParamSpec { name: "w".into(), shape: vec![4, 3] },
                ParamSpec { name: "b".into(), shape: vec![3] },
            ],
            artifacts: Default::default(),
        }
    }

    #[test]
    fn roundtrip() {
        let c = cfg();
        let init: Vec<f32> = (0..15).map(|i| i as f32 * 0.5).collect();
        let ps = ParamStore::new(&c, Some(&init)).unwrap();
        let meta = CheckpointMeta {
            config: "ckpt_test".into(),
            method: "reweight".into(),
            optimizer: "adam".into(),
            step: 42,
            sampling_rate: 0.01,
            sigma: 1.1,
            clip: 1.0,
            lr: 1e-3,
            seed: 7,
            poisson: Some(true),
            clip_policy: Some("per_layer:0.5".into()),
        };
        let dir = std::env::temp_dir().join("fastclip_ckpt_test");
        save(&dir, &meta, &ps).unwrap();
        let (m2, flat) = load(&dir, &c).unwrap();
        assert_eq!(m2.step, 42);
        assert_eq!(m2.method, "reweight");
        assert_eq!(m2.optimizer, "adam");
        assert_eq!(m2.poisson, Some(true));
        assert_eq!(m2.clip_policy.as_deref(), Some("per_layer:0.5"));
        assert!((m2.sigma - 1.1).abs() < 1e-12);
        assert_eq!(flat, init);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncated_checkpoint_files_are_refused() {
        let c = cfg();
        let init: Vec<f32> = (0..15).map(|i| i as f32).collect();
        let ps = ParamStore::new(&c, Some(&init)).unwrap();
        let meta = CheckpointMeta {
            config: "ckpt_test".into(),
            method: "reweight".into(),
            optimizer: "sgd".into(),
            step: 9,
            sampling_rate: 0.1,
            sigma: 1.0,
            clip: 1.0,
            lr: 1e-3,
            seed: 1,
            poisson: Some(false),
            clip_policy: Some("global:1".into()),
        };
        let dir = std::env::temp_dir().join("fastclip_ckpt_truncated");
        std::fs::remove_dir_all(&dir).ok();

        // a crash mid-params leaves a short params.bin: refused with
        // the byte counts, not deserialized short
        save(&dir, &meta, &ps).unwrap();
        let full = std::fs::read(dir.join("params.bin")).unwrap();
        std::fs::write(dir.join("params.bin"), &full[..full.len() / 2]).unwrap();
        let err = load(&dir, &c).unwrap_err();
        assert!(err.to_string().contains("expected"), "{err}");

        // a crash mid-meta leaves invalid JSON: refused as a parse
        // error, not defaulted field-by-field into a wrong resume
        save(&dir, &meta, &ps).unwrap();
        let full = std::fs::read_to_string(dir.join("meta.json")).unwrap();
        std::fs::write(dir.join("meta.json"), &full[..full.len() / 2]).unwrap();
        let err = load(&dir, &c).unwrap_err();
        assert!(format!("{err:#}").contains("parsing checkpoint meta"), "{err:#}");

        // and the atomic path leaves no .tmp siblings behind
        save(&dir, &meta, &ps).unwrap();
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn writer_thread_saves_identically_to_inline_save() {
        let c = cfg();
        let init: Vec<f32> = (0..15).map(|i| 1.5 - i as f32).collect();
        let ps = ParamStore::new(&c, Some(&init)).unwrap();
        let meta = CheckpointMeta {
            config: "ckpt_test".into(),
            method: "naive".into(),
            optimizer: "sgd".into(),
            step: 3,
            sampling_rate: 0.25,
            sigma: 1.2,
            clip: 0.5,
            lr: 0.01,
            seed: 4,
            poisson: Some(true),
            clip_policy: Some("per_layer:0.5".into()),
        };
        let inline_dir = std::env::temp_dir().join("fastclip_ckpt_wr_inline");
        let queued_dir = std::env::temp_dir().join("fastclip_ckpt_wr_queued");
        for d in [&inline_dir, &queued_dir] {
            std::fs::remove_dir_all(d).ok();
        }
        save(&inline_dir, &meta, &ps).unwrap();
        let w = CheckpointWriter::spawn();
        w.enqueue(&queued_dir, meta.clone(), ps.host.clone()).unwrap();
        w.finish().unwrap();
        assert_eq!(
            std::fs::read(inline_dir.join("params.bin")).unwrap(),
            std::fs::read(queued_dir.join("params.bin")).unwrap()
        );
        assert_eq!(
            std::fs::read_to_string(inline_dir.join("meta.json")).unwrap(),
            std::fs::read_to_string(queued_dir.join("meta.json")).unwrap()
        );
        for d in [&inline_dir, &queued_dir] {
            std::fs::remove_dir_all(d).ok();
        }
    }

    #[test]
    fn wrong_config_rejected() {
        let c = cfg();
        let ps = ParamStore::new(&c, None).unwrap();
        let meta = CheckpointMeta {
            config: "ckpt_test".into(),
            method: "reweight".into(),
            optimizer: "sgd".into(),
            step: 1,
            sampling_rate: 0.0,
            sigma: 0.0,
            clip: 1.0,
            lr: 1e-3,
            seed: 0,
            poisson: None,
            clip_policy: None,
        };
        let dir = std::env::temp_dir().join("fastclip_ckpt_test2");
        save(&dir, &meta, &ps).unwrap();
        let mut other = cfg();
        other.name = "different".into();
        assert!(load(&dir, &other).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
