//! Checkpointing: parameters as raw little-endian f32 (`.bin`) plus a
//! JSON sidecar with run metadata (step, accountant state inputs,
//! optimizer name). Resumable and Python-free.

use crate::runtime::{ConfigSpec, ParamStore};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

#[derive(Debug, Clone)]
pub struct CheckpointMeta {
    pub config: String,
    pub method: String,
    /// optimizer name ("adam"/"sgd"); empty in pre-PR5 checkpoints.
    /// Optimizer *state* (Adam moments) is not checkpointed — resume
    /// validates the name and warns that stateful optimizers restart
    /// their moments (see `trainer::train`).
    pub optimizer: String,
    pub step: u64,
    pub sampling_rate: f64,
    pub sigma: f64,
    pub clip: f64,
    /// learning rate of the recorded steps; 0.0 in pre-PR5 checkpoints
    /// (resume skips the continuity check then)
    pub lr: f64,
    pub seed: u64,
    /// Poisson subsampling vs shuffle-partition — the sampling regime
    /// the recorded steps ran under (and the one the RDP re-charge
    /// assumes). `None` for pre-PR5 checkpoints that did not record
    /// it: resume must *skip* the mode check then, not treat the
    /// absence as a definitive shuffle-partition.
    pub poisson: Option<bool>,
    /// Canonical clip-policy name (`ClipPolicy` Display form, e.g.
    /// `per_layer:0.5` or `auto:1,g=0.01`) the recorded steps clipped
    /// under. `None` for pre-policy checkpoints, which recorded only
    /// the bare `clip` — resume treats that as the classical global
    /// hard policy rather than skipping the check.
    pub clip_policy: Option<String>,
}

pub fn save(
    dir: &Path,
    meta: &CheckpointMeta,
    params: &ParamStore,
) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let mut bin = std::fs::File::create(dir.join("params.bin"))?;
    let mut total = 0usize;
    for v in &params.host {
        // safe: f32 slices serialize as raw LE bytes on all our targets
        let bytes: Vec<u8> = v.iter().flat_map(|f| f.to_le_bytes()).collect();
        bin.write_all(&bytes)?;
        total += v.len();
    }
    let mut j = Json::obj();
    j.set("config", meta.config.as_str().into());
    j.set("method", meta.method.as_str().into());
    j.set("optimizer", meta.optimizer.as_str().into());
    j.set("step", (meta.step as usize).into());
    j.set("sampling_rate", meta.sampling_rate.into());
    j.set("sigma", meta.sigma.into());
    j.set("clip", meta.clip.into());
    j.set("lr", meta.lr.into());
    j.set("seed", (meta.seed as usize).into());
    if let Some(p) = meta.poisson {
        j.set("poisson", p.into());
    }
    if let Some(cp) = &meta.clip_policy {
        j.set("clip_policy", cp.as_str().into());
    }
    j.set("param_elems", total.into());
    crate::util::write_file(&dir.join("meta.json"), &j.to_string_pretty())?;
    Ok(())
}

pub fn load(dir: &Path, cfg: &ConfigSpec) -> Result<(CheckpointMeta, Vec<f32>)> {
    let meta_text = crate::util::read_file(&dir.join("meta.json"))?;
    let j = Json::parse(&meta_text).context("parsing checkpoint meta")?;
    let meta = CheckpointMeta {
        config: j.get("config").as_str().unwrap_or("").to_string(),
        method: j.get("method").as_str().unwrap_or("").to_string(),
        optimizer: j.get("optimizer").as_str().unwrap_or("").to_string(),
        step: j.get("step").as_usize().unwrap_or(0) as u64,
        sampling_rate: j.get("sampling_rate").as_f64().unwrap_or(0.0),
        sigma: j.get("sigma").as_f64().unwrap_or(0.0),
        clip: j.get("clip").as_f64().unwrap_or(1.0),
        lr: j.get("lr").as_f64().unwrap_or(0.0),
        seed: j.get("seed").as_usize().unwrap_or(0) as u64,
        poisson: j.get("poisson").as_bool(),
        clip_policy: j.get("clip_policy").as_str().map(String::from),
    };
    if meta.config != cfg.name {
        bail!(
            "checkpoint is for config {:?}, expected {:?}",
            meta.config,
            cfg.name
        );
    }
    let mut f = std::fs::File::open(dir.join("params.bin"))?;
    let mut bytes = Vec::new();
    f.read_to_end(&mut bytes)?;
    if bytes.len() != cfg.param_elems() * 4 {
        bail!(
            "params.bin has {} bytes, expected {}",
            bytes.len(),
            cfg.param_elems() * 4
        );
    }
    let flat: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok((meta, flat))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ParamSpec;

    fn cfg() -> ConfigSpec {
        ConfigSpec {
            name: "ckpt_test".into(),
            model: "mlp".into(),
            dataset: "mnist".into(),
            batch: 2,
            n_classes: 10,
            tags: vec![],
            input_shape: vec![2, 4],
            input_dtype: "f32".into(),
            act_elems_per_example: 0,
            conv: None,
            spec: None,
            params: vec![
                ParamSpec { name: "w".into(), shape: vec![4, 3] },
                ParamSpec { name: "b".into(), shape: vec![3] },
            ],
            artifacts: Default::default(),
        }
    }

    #[test]
    fn roundtrip() {
        let c = cfg();
        let init: Vec<f32> = (0..15).map(|i| i as f32 * 0.5).collect();
        let ps = ParamStore::new(&c, Some(&init)).unwrap();
        let meta = CheckpointMeta {
            config: "ckpt_test".into(),
            method: "reweight".into(),
            optimizer: "adam".into(),
            step: 42,
            sampling_rate: 0.01,
            sigma: 1.1,
            clip: 1.0,
            lr: 1e-3,
            seed: 7,
            poisson: Some(true),
            clip_policy: Some("per_layer:0.5".into()),
        };
        let dir = std::env::temp_dir().join("fastclip_ckpt_test");
        save(&dir, &meta, &ps).unwrap();
        let (m2, flat) = load(&dir, &c).unwrap();
        assert_eq!(m2.step, 42);
        assert_eq!(m2.method, "reweight");
        assert_eq!(m2.optimizer, "adam");
        assert_eq!(m2.poisson, Some(true));
        assert_eq!(m2.clip_policy.as_deref(), Some("per_layer:0.5"));
        assert!((m2.sigma - 1.1).abs() < 1e-12);
        assert_eq!(flat, init);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wrong_config_rejected() {
        let c = cfg();
        let ps = ParamStore::new(&c, None).unwrap();
        let meta = CheckpointMeta {
            config: "ckpt_test".into(),
            method: "reweight".into(),
            optimizer: "sgd".into(),
            step: 1,
            sampling_rate: 0.0,
            sigma: 0.0,
            clip: 1.0,
            lr: 1e-3,
            seed: 0,
            poisson: None,
            clip_policy: None,
        };
        let dir = std::env::temp_dir().join("fastclip_ckpt_test2");
        save(&dir, &meta, &ps).unwrap();
        let mut other = cfg();
        other.name = "different".into();
        assert!(load(&dir, &other).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
