//! Training metrics: per-step wall-time breakdown and run-level
//! aggregates, exportable as JSON.

use crate::util::json::Json;
use crate::util::stats::{Ema, Summary};
use std::time::Instant;

/// Phases of one training step (the --profile breakdown).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Gather,
    Execute,
    Noise,
    Update,
}

const PHASES: [Phase; 4] = [Phase::Gather, Phase::Execute, Phase::Noise, Phase::Update];

impl Phase {
    fn idx(self) -> usize {
        match self {
            Phase::Gather => 0,
            Phase::Execute => 1,
            Phase::Noise => 2,
            Phase::Update => 3,
        }
    }

    fn name(self) -> &'static str {
        match self {
            Phase::Gather => "gather",
            Phase::Execute => "execute",
            Phase::Noise => "noise",
            Phase::Update => "update",
        }
    }
}

/// Collects per-step timings and loss.
pub struct Metrics {
    pub step_times: Vec<f64>,
    phase_totals: [f64; 4],
    pub loss_ema: Ema,
    pub losses: Vec<f32>,
    pub eval_points: Vec<(u64, f32, f32)>, // (step, eval loss, accuracy)
    /// running sum of per-group mean unclipped norms (grouped clip
    /// policies only; `StepOut::group_norms`), one slot per group
    group_norm_sums: Vec<f64>,
    /// steps that contributed to `group_norm_sums`
    group_norm_steps: u64,
    run_start: Instant,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            step_times: Vec::new(),
            phase_totals: [0.0; 4],
            loss_ema: Ema::new(0.05),
            losses: Vec::new(),
            eval_points: Vec::new(),
            group_norm_sums: Vec::new(),
            group_norm_steps: 0,
            run_start: Instant::now(),
        }
    }

    /// Preallocate the per-step records for `n` further steps so the
    /// warm loop's `record_step` pushes never grow the vectors — part
    /// of the `TrainSession::step` zero-allocation contract.
    pub fn reserve_steps(&mut self, n: usize) {
        self.step_times.reserve(n);
        self.losses.reserve(n);
    }

    pub fn record_step(&mut self, total_s: f64, loss: f32) {
        self.step_times.push(total_s);
        self.losses.push(loss);
        self.loss_ema.update(loss as f64);
    }

    pub fn record_phase(&mut self, phase: Phase, secs: f64) {
        self.phase_totals[phase.idx()] += secs;
    }

    pub fn record_eval(&mut self, step: u64, loss: f32, acc: f32) {
        self.eval_points.push((step, loss, acc));
    }

    /// Record one step's per-group per-example norms (group-major,
    /// `norms.len() == n_groups * batch`): the batch mean of each
    /// group's unclipped norm accumulates into a per-group running
    /// sum, exported as `group_norm_mean` — how far each layer group
    /// sits from its clip threshold over the run.
    pub fn record_group_norms(&mut self, norms: &[f32], n_groups: usize) {
        debug_assert!(n_groups > 0 && norms.len() % n_groups == 0);
        if self.group_norm_sums.len() != n_groups {
            self.group_norm_sums.clear();
            self.group_norm_sums.resize(n_groups, 0.0);
            self.group_norm_steps = 0;
        }
        let b = norms.len() / n_groups;
        for g in 0..n_groups {
            let sum: f64 =
                norms[g * b..(g + 1) * b].iter().map(|&v| v as f64).sum();
            self.group_norm_sums[g] += sum / b as f64;
        }
        self.group_norm_steps += 1;
    }

    /// Mean unclipped norm per group over the recorded steps, if any
    /// grouped-policy steps were recorded.
    pub fn group_norm_means(&self) -> Option<Vec<f64>> {
        if self.group_norm_steps == 0 {
            return None;
        }
        let n = self.group_norm_steps as f64;
        Some(self.group_norm_sums.iter().map(|&s| s / n).collect())
    }

    pub fn steps(&self) -> usize {
        self.step_times.len()
    }

    pub fn wall_seconds(&self) -> f64 {
        self.run_start.elapsed().as_secs_f64()
    }

    pub fn step_summary(&self) -> Option<Summary> {
        if self.step_times.is_empty() {
            None
        } else {
            Some(Summary::of(&self.step_times))
        }
    }

    /// Phase breakdown as (name, total seconds, share).
    pub fn phase_breakdown(&self) -> Vec<(&'static str, f64, f64)> {
        let total: f64 = self.phase_totals.iter().sum();
        PHASES
            .iter()
            .map(|&p| {
                let t = self.phase_totals[p.idx()];
                (p.name(), t, if total > 0.0 { t / total } else { 0.0 })
            })
            .collect()
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("steps", self.steps().into());
        o.set("wall_seconds", self.wall_seconds().into());
        if let Some(s) = self.step_summary() {
            let mut t = Json::obj();
            t.set("mean_ms", (s.mean * 1e3).into());
            t.set("p50_ms", (s.p50 * 1e3).into());
            t.set("p95_ms", (s.p95 * 1e3).into());
            o.set("step_time", t);
        }
        let mut phases = Json::obj();
        for (name, total, share) in self.phase_breakdown() {
            let mut p = Json::obj();
            p.set("seconds", total.into());
            p.set("share", share.into());
            phases.set(name, p);
        }
        o.set("phases", phases);
        if let Some(l) = self.loss_ema.get() {
            o.set("loss_ema", l.into());
        }
        if let Some(means) = self.group_norm_means() {
            o.set(
                "group_norm_mean",
                Json::Arr(means.into_iter().map(Json::from).collect()),
            );
        }
        o.set(
            "eval",
            Json::Arr(
                self.eval_points
                    .iter()
                    .map(|&(s, l, a)| {
                        Json::from_pairs(vec![
                            ("step", (s as usize).into()),
                            ("loss", (l as f64).into()),
                            ("acc", (a as f64).into()),
                        ])
                    })
                    .collect(),
            ),
        );
        o
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

/// RAII-ish phase timer.
pub struct PhaseTimer {
    start: Instant,
}

impl PhaseTimer {
    pub fn start() -> PhaseTimer {
        PhaseTimer { start: Instant::now() }
    }

    pub fn stop(self, metrics: &mut Metrics, phase: Phase) -> f64 {
        let s = self.start.elapsed().as_secs_f64();
        metrics.record_phase(phase, s);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_shares_sum_to_one() {
        let mut m = Metrics::new();
        m.record_phase(Phase::Gather, 1.0);
        m.record_phase(Phase::Execute, 3.0);
        let shares: f64 = m.phase_breakdown().iter().map(|(_, _, s)| s).sum();
        assert!((shares - 1.0).abs() < 1e-12);
        assert!((m.phase_breakdown()[1].2 - 0.75).abs() < 1e-12);
    }

    #[test]
    fn group_norm_means_average_over_steps() {
        let mut m = Metrics::new();
        assert!(m.group_norm_means().is_none());
        // 2 groups, batch 2: per-step group means (2, 6) then (4, 8)
        m.record_group_norms(&[1.0, 3.0, 5.0, 7.0], 2);
        m.record_group_norms(&[3.0, 5.0, 7.0, 9.0], 2);
        let means = m.group_norm_means().unwrap();
        assert!((means[0] - 3.0).abs() < 1e-12);
        assert!((means[1] - 7.0).abs() < 1e-12);
        let j = m.to_json();
        assert_eq!(j.get("group_norm_mean").as_arr().unwrap().len(), 2);
    }

    #[test]
    fn json_export_contains_fields() {
        let mut m = Metrics::new();
        m.record_step(0.010, 2.3);
        m.record_step(0.012, 2.1);
        m.record_eval(1, 2.0, 0.5);
        let j = m.to_json();
        assert_eq!(j.get("steps").as_usize(), Some(2));
        assert!(j.get("step_time").get("mean_ms").as_f64().unwrap() > 0.0);
        assert_eq!(j.get("eval").as_arr().unwrap().len(), 1);
    }
}
