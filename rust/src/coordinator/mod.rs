//! L3 coordinator: the DP-SGD training orchestrator around the AOT
//! compute artifacts — method dispatch (the four clipping strategies),
//! the session state machine + thin training loop (paper Alg 1), the
//! multi-job serve scheduler, metrics, checkpoints, and the memory
//! model for the Sec 6.7 experiment.

pub mod checkpoint;
pub mod memory;
pub mod methods;
pub mod metrics;
pub mod serve;
pub mod session;
pub mod trainer;

pub use methods::{ClipMethod, GradComputer};
pub use metrics::{Metrics, Phase, PhaseTimer};
pub use serve::{parse_jobs, serve, JobSpec, ServeOptions, ServeReport};
pub use session::TrainSession;
pub use trainer::{evaluate, stage_batch, train, TrainOptions, TrainReport};
