//! Gradient-clipping strategies (paper Sec 6.1 + the §Perf
//! extensions): the seven ways to compute
//! `1/tau sum_i clip_c(grad l_i)`, dispatched by the trainer and
//! bench harness.
//!
//! All private methods return identical gradients (tested in
//! rust/tests/integration.rs); only the computational structure —
//! and therefore the wall clock — differs:
//!
//!   NonPrivate     — one batched backward, no clipping (lower bound).
//!   Reweight       — the paper: norms from taps, reweighted second
//!                    backward, all inside one step executable.
//!   ReweightGram   — norms via the Gram-matrix route (Sec 5.2),
//!                    reweighted second backward.
//!   ReweightDirect — one backward: the weighted gradient is
//!                    assembled directly from the tapped deltas.
//!   ReweightPallas — one backward, nu fused into the gradient GEMM.
//!   MultiLoss      — materialized per-example gradients (vmap of
//!                    grad).
//!   NxBp           — TF-Privacy-style loop: one backward per example
//!                    on a batch-1 step; Rust clips and accumulates.
//!
//! Everything here goes through the `Backend`/`StepFn` traits and the
//! caller-owned `StepOut` arena (`compute` writes into the arena the
//! caller reuses across steps), so the same dispatch drives the
//! native and PJRT implementations with no per-step allocation on the
//! coordinator side. The nxBP loop keeps its own persistent arena for
//! the per-example naive1 outputs.

use crate::runtime::{
    Backend, BatchStage, ClipPolicy, ConfigSpec, ParamStore, StepFn, StepOut,
};
use anyhow::{Context, Result};
use std::sync::Arc;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClipMethod {
    NonPrivate,
    Reweight,
    ReweightPallas,
    ReweightGram,
    /// one-backward extension (§Perf): weighted grads assembled from
    /// the same tapped intermediates as the norms
    ReweightDirect,
    MultiLoss,
    NxBp,
}

impl ClipMethod {
    pub fn parse(s: &str) -> Result<ClipMethod> {
        Ok(match s {
            "nonprivate" => ClipMethod::NonPrivate,
            "reweight" => ClipMethod::Reweight,
            "reweight_pallas" => ClipMethod::ReweightPallas,
            "reweight_gram" => ClipMethod::ReweightGram,
            "reweight_direct" => ClipMethod::ReweightDirect,
            "multiloss" => ClipMethod::MultiLoss,
            "nxbp" => ClipMethod::NxBp,
            // the list in the error is generated, not hand-written, so
            // it can never drift from the actual method set
            other => anyhow::bail!(
                "unknown method {other:?} ({})",
                ClipMethod::names().join("|")
            ),
        })
    }

    /// Every method's CLI name, in `all()` order — the single source
    /// the help text and parse errors render from.
    pub fn names() -> Vec<&'static str> {
        ClipMethod::all().iter().map(|m| m.name()).collect()
    }

    pub fn name(&self) -> &'static str {
        match self {
            ClipMethod::NonPrivate => "nonprivate",
            ClipMethod::Reweight => "reweight",
            ClipMethod::ReweightPallas => "reweight_pallas",
            ClipMethod::ReweightGram => "reweight_gram",
            ClipMethod::ReweightDirect => "reweight_direct",
            ClipMethod::MultiLoss => "multiloss",
            ClipMethod::NxBp => "nxbp",
        }
    }

    /// Artifact method name backing this strategy (NxBp uses the
    /// batch-1 naive1 artifact of the sibling config).
    pub fn artifact(&self) -> &'static str {
        match self {
            ClipMethod::NonPrivate => "nonprivate",
            ClipMethod::Reweight => "reweight",
            ClipMethod::ReweightPallas => "reweight_pallas",
            ClipMethod::ReweightGram => "reweight_gram",
            ClipMethod::ReweightDirect => "reweight_direct",
            ClipMethod::MultiLoss => "multiloss",
            ClipMethod::NxBp => "naive1",
        }
    }

    pub fn is_private(&self) -> bool {
        !matches!(self, ClipMethod::NonPrivate)
    }

    pub fn all() -> [ClipMethod; 7] {
        [
            ClipMethod::NonPrivate,
            ClipMethod::Reweight,
            ClipMethod::ReweightPallas,
            ClipMethod::ReweightGram,
            ClipMethod::ReweightDirect,
            ClipMethod::MultiLoss,
            ClipMethod::NxBp,
        ]
    }
}

/// A ready-to-run gradient computer for one (config, method) pair.
pub struct GradComputer {
    pub method: ClipMethod,
    pub cfg: ConfigSpec,
    exe: Arc<dyn StepFn>,
    /// gradient arena layout of the config's parameter tensors
    param_lens: Vec<usize>,
    /// parametric-layer count (every layer is one (W, b) pair in
    /// manifest order) — what clip-policy group boundaries index
    n_param_layers: usize,
    /// NxBp only: the batch-1 config + persistent staging/output state
    naive: Option<NaiveLoop>,
}

/// Persistent nxBP loop state: the batch-1 staging buffers, the arena
/// the per-example naive1 steps write into, and the norm/group
/// collection buffers — all reused across steps so the loop allocates
/// nothing warm.
struct NaiveLoop {
    cfg: ConfigSpec,
    stage: BatchStage,
    out: StepOut,
    norms: Vec<f32>,
    /// group index of each parametric layer under the current policy
    groups: Vec<usize>,
    /// group boundaries in parametric-layer index space (ng+1 entries)
    gb: Vec<usize>,
    /// per-group per-example norms, group-major (`g*tau + i`)
    gnorms: Vec<f32>,
}

impl GradComputer {
    /// `config` is a config *reference* — a manifest/preset name or,
    /// on backends that synthesize (native), a `model@dataset:bN` spec
    /// key — resolved through `Backend::resolve`.
    pub fn new(
        backend: &dyn Backend,
        config: &str,
        method: ClipMethod,
    ) -> Result<GradComputer> {
        let cfg = backend.resolve(config)?;
        let param_lens: Vec<usize> =
            cfg.params.iter().map(|p| p.elems()).collect();
        // every parametric layer contributes exactly (W, b) in
        // manifest order — the layout grouped policies slice on
        let n_param_layers = cfg.params.len() / 2;
        let (exe, naive) = if method == ClipMethod::NxBp {
            let ncfg = backend
                .naive_sibling(&cfg)
                .context("nxbp needs the batch-1 naive1 sibling config")?;
            let exe = backend.load(&ncfg, "naive1")?;
            let stage = BatchStage::for_config(&ncfg);
            let out = StepOut::for_config(&ncfg);
            let norms = Vec::with_capacity(cfg.batch);
            (
                exe,
                Some(NaiveLoop {
                    cfg: ncfg,
                    stage,
                    out,
                    norms,
                    groups: vec![0; n_param_layers],
                    gb: Vec::new(),
                    gnorms: Vec::new(),
                }),
            )
        } else {
            (backend.load(&cfg, method.artifact())?, None)
        };
        Ok(GradComputer { method, cfg, exe, param_lens, n_param_layers, naive })
    }

    /// A fresh output arena sized for this computer's config — the
    /// caller holds it and passes it to every `compute`.
    pub fn new_out(&self) -> StepOut {
        StepOut::for_config(&self.cfg)
    }

    /// Parametric-layer count of this computer's config — the index
    /// space clip-policy group boundaries live in (and the argument
    /// the trainer passes to `ClipPolicy::sensitivity`).
    pub fn n_param_layers(&self) -> usize {
        self.n_param_layers
    }

    /// Compute the (clipped, averaged) gradient for the staged batch
    /// into the caller-owned arena. The policy decides both the
    /// clipping granularity and the nu formula; `NonPrivate` ignores
    /// it.
    ///
    /// For NxBp, `stage` holds the full batch; the loop re-stages one
    /// example at a time into the batch-1 buffers and applies the
    /// policy to the *materialized* per-example gradient — the oracle
    /// every batched method is tested against, for every policy.
    pub fn compute(
        &mut self,
        params: &mut ParamStore,
        stage: &BatchStage,
        policy: &ClipPolicy,
        out: &mut StepOut,
    ) -> Result<()> {
        match self.method {
            ClipMethod::NonPrivate => self.exe.run_into(params, stage, None, out),
            ClipMethod::Reweight
            | ClipMethod::ReweightPallas
            | ClipMethod::ReweightGram
            | ClipMethod::ReweightDirect
            | ClipMethod::MultiLoss => {
                self.exe.run_into(params, stage, Some(policy), out)
            }
            ClipMethod::NxBp => self.nxbp_loop(params, stage, policy, out),
        }
    }

    /// The naive strategy (paper Sec 3.3): per-example backward, clip
    /// in Rust, accumulate, average. This deliberately preserves the
    /// inefficiency being benchmarked — one executable launch per
    /// example — while still being a *correct* DP gradient.
    ///
    /// Because the per-example gradient is fully materialized here,
    /// grouped policies are implemented by the definition itself: each
    /// group's parameter window gets its own norm
    /// (`GradVec::sq_norm_params`) and its own nu-scaled accumulation
    /// (`add_scaled_params`). This is the reference the batched
    /// kernels' slab reductions are checked against.
    fn nxbp_loop(
        &mut self,
        params: &mut ParamStore,
        stage: &BatchStage,
        policy: &ClipPolicy,
        out: &mut StepOut,
    ) -> Result<()> {
        let naive = self.naive.as_mut().expect("nxbp state");
        let tau = self.cfg.batch;
        let nl = self.n_param_layers;
        policy.check(nl)?;
        let ng = policy.n_groups(nl);
        // layer -> group map and the group boundaries in parametric-
        // layer index space (group g spans layers gb[g]..gb[g+1], i.e.
        // params 2*gb[g]..2*gb[g+1]); rebuilt into grow-only buffers
        policy.fill_layer_groups(&mut naive.groups);
        naive.gb.clear();
        naive.gb.push(0);
        for l in 1..nl {
            if naive.groups[l] != naive.groups[l - 1] {
                naive.gb.push(l);
            }
        }
        naive.gb.push(nl);
        debug_assert_eq!(naive.gb.len(), ng + 1);
        naive.gnorms.clear();
        if ng > 1 {
            naive.gnorms.resize(ng * tau, 0.0);
        }
        let d = naive.cfg.input_elems(); // per-example elems (batch 1)
        // The loop below slices example i out of the staged buffers; a
        // partially staged batch would silently replay stale tail rows
        // (or panic), so validate the full batch is really there.
        let staged = if naive.stage.is_f32 {
            stage.feat_f32.len()
        } else {
            stage.feat_i32.len()
        };
        anyhow::ensure!(
            staged == tau * d && stage.labels.len() == tau,
            "nxbp: staged batch holds {staged} feature elems / {} labels, \
             but config {} needs {} / {tau} — stage the full batch before \
             calling compute",
            stage.labels.len(),
            self.cfg.name,
            tau * d
        );
        // the caller's arena accumulates Σ_i nu_i·g_i directly
        out.reset(&self.param_lens);
        naive.norms.clear();
        // f64: the batched paths accumulate loss in f64, and the
        // nxbp-vs-reweight loss equivalence must hold at large tau
        let mut loss_sum = 0.0f64;
        for i in 0..tau {
            if naive.stage.is_f32 {
                naive.stage.feat_f32
                    .copy_from_slice(&stage.feat_f32[i * d..(i + 1) * d]);
            } else {
                naive.stage.feat_i32
                    .copy_from_slice(&stage.feat_i32[i * d..(i + 1) * d]);
            }
            naive.stage.labels[0] = stage.labels[i];
            self.exe.run_into(params, &naive.stage, None, &mut naive.out)?;
            // A missing norm MUST be a hard error: defaulting it to 0
            // would make nu = 1 and silently add an *unclipped*
            // gradient — the noise calibrated for sensitivity `clip`
            // would no longer cover it, voiding the DP guarantee.
            let norm = match naive.out.norms().and_then(|n| n.first()) {
                Some(&n) => n,
                None => anyhow::bail!(
                    "nxbp: the naive1 step for config {} returned no \
                     per-example norm for example {i}; refusing to treat \
                     it as 0 (nu would be 1 and the update would go in \
                     unclipped, breaking the sensitivity bound)",
                    naive.cfg.name
                ),
            };
            if ng == 1 {
                // global granularity: nu from the step-reported norm
                // (for the hard formula this is bitwise the pre-policy
                // clip_factor path)
                let nu = policy.nu_for(norm);
                out.grads.add_scaled(&naive.out.grads, nu);
            } else {
                for g in 0..ng {
                    let (lo, hi) = (2 * naive.gb[g], 2 * naive.gb[g + 1]);
                    let gnorm =
                        naive.out.grads.sq_norm_params(lo, hi).sqrt() as f32;
                    let nu = policy.nu_for(gnorm);
                    out.grads.add_scaled_params(&naive.out.grads, lo, hi, nu);
                    naive.gnorms[g * tau + i] = gnorm;
                }
            }
            naive.norms.push(norm);
            loss_sum += naive.out.loss as f64;
        }
        out.grads.scale(1.0 / tau as f32);
        out.set_norms(&naive.norms);
        if ng > 1 {
            out.set_group_norms(&naive.gnorms, ng);
        }
        out.loss = (loss_sum / tau as f64) as f32;
        Ok(())
    }

    pub fn compile_ms(&self) -> f64 {
        self.exe.compile_ms()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for m in ClipMethod::all() {
            assert_eq!(ClipMethod::parse(m.name()).unwrap(), m);
        }
        assert!(ClipMethod::parse("bogus").is_err());
        // the generated name list covers every method (this is what
        // the help text and parse errors render from — the old
        // hand-written list silently omitted reweight_direct)
        assert_eq!(ClipMethod::names().len(), ClipMethod::all().len());
        assert!(ClipMethod::names().contains(&"reweight_direct"));
        // ...and the parse error actually lists it
        let err = ClipMethod::parse("bogus").unwrap_err();
        assert!(format!("{err:#}").contains("reweight_direct"));
    }

    #[test]
    fn privacy_flags() {
        assert!(!ClipMethod::NonPrivate.is_private());
        assert!(ClipMethod::Reweight.is_private());
        assert!(ClipMethod::NxBp.is_private());
        assert_eq!(ClipMethod::NxBp.artifact(), "naive1");
    }

    /// The partial-batch hazard: a stage holding fewer examples than
    /// the config batch must be a clear error, not stale-data reuse.
    #[test]
    fn nxbp_rejects_partial_batch() {
        use crate::runtime::NativeBackend;
        let backend = NativeBackend::new();
        let cfg = backend
            .manifest()
            .config("mlp2_mnist_b32")
            .unwrap()
            .clone();
        let mut computer =
            GradComputer::new(&backend, "mlp2_mnist_b32", ClipMethod::NxBp)
                .unwrap();
        let mut params = ParamStore::new(&cfg, None).unwrap();
        let mut stage = BatchStage::for_config(&cfg);
        stage.feat_f32.truncate(784 * 30); // 30 of 32 examples staged
        let mut out = computer.new_out();
        let pol = ClipPolicy::hard_global(1.0);
        let err = computer
            .compute(&mut params, &stage, &pol, &mut out)
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("nxbp") && msg.contains("stage"), "{msg}");
    }
}
