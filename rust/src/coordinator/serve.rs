//! `fastclip serve`: a cooperative scheduler that interleaves
//! [`TrainSession::step()`] calls from many concurrent training jobs.
//!
//! Jobs come from a JSON jobs file ([`parse_jobs`]); up to
//! `max_concurrent` sessions are live at once, stepped round-robin in
//! declaration order over the shared rayon pool. Because every
//! session's batch and noise streams are keyed by its own seed (and
//! the noise stream is schedule-independent), each job's trajectory is
//! **bitwise-identical to a solo `train()` run** — interleaving
//! changes wall-clock sharing, never results. `tests/serve.rs` pins
//! this.
//!
//! Per-job `StepOut` arenas come from a reusable [`ArenaPool`]: when a
//! job retires, its arena is recycled into the next admitted session
//! (the first compute re-layouts it for the new config).
//!
//! Privacy governance: a [`BudgetLedger`] holds one lookahead probe
//! accountant per job (cloned from the session, so resume re-charges
//! are included). Before each step the probe charges that step and the
//! scheduler *refuses* the step if the job's epsilon would exceed its
//! `target_eps` budget — the job retires with a final checkpoint at
//! its last admitted step, spend strictly within budget.
//!
//! Checkpoints are written on a background [`CheckpointWriter`] thread
//! (atomic tmp+fsync+rename writes), so a retiring job never stalls
//! the jobs still stepping. A graceful-stop flag retires every live
//! session with a final checkpoint and skips un-started jobs.

use super::checkpoint::CheckpointWriter;
use super::session::TrainSession;
use super::trainer::{TrainOptions, TrainReport};
use crate::privacy::RdpAccountant;
use crate::runtime::{Backend, ClipPolicy, StepOut};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// One entry of the jobs file: a named training job plus an optional
/// privacy budget the serve ledger enforces.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub name: String,
    pub opts: TrainOptions,
    /// Hard epsilon ceiling (at the job's delta). Unlike
    /// `TrainOptions::target_eps` — which calibrates sigma up-front —
    /// this is *enforcement*: the scheduler refuses any step whose
    /// spend would exceed it. `None` = unbounded (run to `steps`).
    pub eps_budget: Option<f64>,
}

/// Scheduler options.
#[derive(Debug, Clone, Default)]
pub struct ServeOptions {
    /// Maximum live sessions; `0` = all jobs at once.
    pub max_concurrent: usize,
    /// Graceful-stop flag (see `util::signal::install_sigint`): when it
    /// flips, every live session retires with a final checkpoint and
    /// pending jobs are skipped.
    pub stop: Option<Arc<AtomicBool>>,
}

/// How one job ended.
#[derive(Debug)]
pub struct JobOutcome {
    pub name: String,
    /// The privacy ledger refused the next step (epsilon budget
    /// exhausted) — the report's step count is where it stopped.
    pub budget_stopped: bool,
    pub report: TrainReport,
}

#[derive(Debug)]
pub struct ServeReport {
    /// One outcome per *started* job, in jobs-file order.
    pub outcomes: Vec<JobOutcome>,
    /// The stop flag ended the run before all jobs completed.
    pub stopped_early: bool,
}

const JOB_KEYS: &[&str] = &[
    "name",
    "config",
    "method",
    "steps",
    "n",
    "lr",
    "clip",
    "clip_policy",
    "sigma",
    "delta",
    "optimizer",
    "seed",
    "eval_every",
    "eval_n",
    "log_every",
    "poisson",
    "checkpoint",
    "target_eps",
    "stream_chunk",
];

fn want_str(v: &Json, idx: usize, key: &str) -> Result<String> {
    v.as_str()
        .map(str::to_string)
        .with_context(|| format!("jobs[{idx}]: {key:?} must be a string"))
}

fn want_f64(v: &Json, idx: usize, key: &str) -> Result<f64> {
    v.as_f64()
        .with_context(|| format!("jobs[{idx}]: {key:?} must be a number"))
}

fn want_usize(v: &Json, idx: usize, key: &str) -> Result<usize> {
    let n = want_f64(v, idx, key)?;
    anyhow::ensure!(
        n >= 0.0 && n.fract() == 0.0,
        "jobs[{idx}]: {key:?} must be a non-negative integer, got {n}"
    );
    Ok(n as usize)
}

/// Parse a jobs file: `{"max_concurrent": N, "jobs": [{...}, ...]}`.
/// Returns the job list and the file's `max_concurrent` (0 = all at
/// once). Unknown keys — top-level or per-job — are hard errors: a
/// typo'd `"sigm"` silently training at the default noise multiplier
/// is exactly the failure mode a DP tool cannot afford.
pub fn parse_jobs(text: &str) -> Result<(Vec<JobSpec>, usize)> {
    let root = Json::parse(text).map_err(|e| anyhow::anyhow!("jobs file: {e}"))?;
    let top = root
        .as_obj()
        .context("jobs file: top level must be an object")?;
    for k in top.keys() {
        anyhow::ensure!(
            k == "jobs" || k == "max_concurrent",
            "jobs file: unknown top-level key {k:?} (expected \"jobs\" and \
             optionally \"max_concurrent\")"
        );
    }
    let max_concurrent = match root.get("max_concurrent") {
        Json::Null => 0,
        v => v
            .as_f64()
            .filter(|n| *n >= 0.0 && n.fract() == 0.0)
            .context("jobs file: \"max_concurrent\" must be a non-negative integer")?
            as usize,
    };
    let arr = root
        .get("jobs")
        .as_arr()
        .context("jobs file: missing \"jobs\" array")?;
    anyhow::ensure!(!arr.is_empty(), "jobs file: \"jobs\" is empty");

    let mut jobs: Vec<JobSpec> = Vec::with_capacity(arr.len());
    for (idx, item) in arr.iter().enumerate() {
        let obj = item
            .as_obj()
            .with_context(|| format!("jobs[{idx}]: each job must be an object"))?;
        for k in obj.keys() {
            anyhow::ensure!(
                JOB_KEYS.contains(&k.as_str()),
                "jobs[{idx}]: unknown key {k:?} (known keys: {})",
                JOB_KEYS.join(", ")
            );
        }
        let name = want_str(item.get("name"), idx, "name")
            .with_context(|| format!("jobs[{idx}]: every job needs a \"name\""))?;
        anyhow::ensure!(!name.is_empty(), "jobs[{idx}]: \"name\" is empty");
        anyhow::ensure!(
            jobs.iter().all(|p| p.name != name),
            "jobs[{idx}]: duplicate job name {name:?}"
        );

        // serve jobs default to silent per-step logging — the scheduler
        // emits per-job lifecycle lines instead
        let mut opts = TrainOptions {
            log_every: 0,
            ..TrainOptions::default()
        };
        let mut eps_budget = None;
        let mut saw_clip = false;
        for (k, v) in obj {
            match k.as_str() {
                "name" => {}
                "config" => opts.config = want_str(v, idx, k)?,
                "method" => {
                    opts.method = super::ClipMethod::parse(&want_str(v, idx, k)?)
                        .with_context(|| format!("jobs[{idx}] ({name})"))?
                }
                "steps" => opts.steps = want_usize(v, idx, k)? as u64,
                "n" => opts.dataset_n = want_usize(v, idx, k)?,
                "lr" => opts.lr = want_f64(v, idx, k)?,
                "clip" => {
                    opts.clip = want_f64(v, idx, k)?;
                    saw_clip = true;
                }
                "clip_policy" => {
                    opts.policy = Some(
                        ClipPolicy::parse(&want_str(v, idx, k)?)
                            .with_context(|| format!("jobs[{idx}] ({name})"))?,
                    )
                }
                "sigma" => opts.sigma = want_f64(v, idx, k)?,
                "delta" => opts.delta = want_f64(v, idx, k)?,
                "optimizer" => opts.optimizer = want_str(v, idx, k)?,
                "seed" => opts.seed = want_usize(v, idx, k)? as u64,
                "eval_every" => opts.eval_every = want_usize(v, idx, k)? as u64,
                "eval_n" => opts.eval_n = Some(want_usize(v, idx, k)?),
                "log_every" => opts.log_every = want_usize(v, idx, k)? as u64,
                "poisson" => {
                    opts.poisson = v
                        .as_bool()
                        .with_context(|| format!("jobs[{idx}]: \"poisson\" must be a bool"))?
                }
                "checkpoint" => {
                    opts.checkpoint_dir = Some(PathBuf::from(want_str(v, idx, k)?))
                }
                "target_eps" => eps_budget = Some(want_f64(v, idx, k)?),
                "stream_chunk" => opts.stream_chunk = Some(want_usize(v, idx, k)?),
                _ => unreachable!("unknown keys rejected above"),
            }
        }
        anyhow::ensure!(
            !(saw_clip && opts.policy.is_some()),
            "jobs[{idx}] ({name}): pass either \"clip\" or \"clip_policy\", \
             not both — the policy carries its own threshold"
        );
        if let Some(b) = eps_budget {
            anyhow::ensure!(
                b > 0.0,
                "jobs[{idx}] ({name}): \"target_eps\" must be positive"
            );
            anyhow::ensure!(
                opts.method.is_private(),
                "jobs[{idx}] ({name}): \"target_eps\" set but method {} adds \
                 no noise — there is no privacy spend to budget",
                opts.method.name()
            );
        }
        jobs.push(JobSpec {
            name,
            opts,
            eps_budget,
        });
    }
    Ok((jobs, max_concurrent))
}

/// Recycled `StepOut` arenas: a retiring job's arena becomes the next
/// admitted session's, so K concurrent slots allocate K arenas total
/// no matter how many jobs pass through them.
struct ArenaPool {
    free: Vec<StepOut>,
}

impl ArenaPool {
    fn new() -> ArenaPool {
        ArenaPool { free: Vec::new() }
    }

    fn acquire(&mut self) -> Option<StepOut> {
        self.free.pop()
    }

    fn release(&mut self, arena: StepOut) {
        self.free.push(arena);
    }
}

/// One job's budget-enforcement state: a probe accountant that stays
/// exactly one admitted step ahead of the session's real accountant.
struct LedgerSlot {
    probe: RdpAccountant,
    q: f64,
    sigma: f64,
    delta: f64,
    budget: Option<f64>,
    private: bool,
}

/// The global privacy-budget ledger. `admit` charges the *next* step
/// into the job's probe and answers whether its epsilon stays within
/// budget — so a refused job has spent strictly less than its budget
/// (the probe overshoots by the one refused step; the session's real
/// accountant never charges it).
struct BudgetLedger {
    slots: Vec<Option<LedgerSlot>>,
}

impl BudgetLedger {
    fn new() -> BudgetLedger {
        BudgetLedger { slots: Vec::new() }
    }

    fn register(&mut self, job: usize, session: &TrainSession, budget: Option<f64>) {
        if self.slots.len() <= job {
            self.slots.resize_with(job + 1, || None);
        }
        self.slots[job] = Some(LedgerSlot {
            // clone, not fresh: a resumed session has already re-charged
            // its checkpointed steps, and the probe must count them
            probe: session.accountant_clone(),
            q: session.sampling_rate(),
            sigma: session.sigma(),
            delta: session.delta(),
            budget,
            private: session.is_private(),
        });
    }

    /// May `job` run one more step? Invariant: each `true` answer is
    /// followed by exactly one `session.step()`, keeping the probe one
    /// step ahead.
    fn admit(&mut self, job: usize) -> bool {
        let slot = self.slots[job].as_mut().expect("job registered");
        if !slot.private {
            return true;
        }
        let Some(budget) = slot.budget else {
            return true;
        };
        slot.probe.step(slot.q, slot.sigma);
        slot.probe.epsilon(slot.delta).0 <= budget
    }
}

/// Run `jobs` to completion (or budget refusal, or stop flag),
/// stepping live sessions round-robin in declaration order. Per-job
/// results are bitwise-identical to solo `train()` runs with the same
/// options.
pub fn serve(
    backend: &dyn Backend,
    jobs: &[JobSpec],
    sopts: &ServeOptions,
) -> Result<ServeReport> {
    anyhow::ensure!(!jobs.is_empty(), "serve: no jobs");
    for (i, a) in jobs.iter().enumerate() {
        anyhow::ensure!(
            jobs[..i].iter().all(|b| b.name != a.name),
            "serve: duplicate job name {:?}",
            a.name
        );
    }
    let cap = if sopts.max_concurrent == 0 {
        jobs.len()
    } else {
        sopts.max_concurrent.min(jobs.len())
    };
    crate::log_info!("serve: {} job(s), {} concurrent slot(s)", jobs.len(), cap);

    let writer = CheckpointWriter::spawn();
    let mut pool = ArenaPool::new();
    let mut ledger = BudgetLedger::new();
    let mut outcomes: Vec<Option<JobOutcome>> = Vec::new();
    outcomes.resize_with(jobs.len(), || None);
    // (job index, session), in admission order
    let mut active: Vec<(usize, TrainSession)> = Vec::new();
    let mut next_pending = 0usize;
    let mut stopped_early = false;

    loop {
        // admit before reading the stop flag: a flag already set when a
        // job would start still admits it, so every admitted job gets a
        // (possibly step-0) checkpoint — deterministic, testable
        // semantics for "interrupt during startup"
        while !stopped_early && active.len() < cap && next_pending < jobs.len() {
            let spec = &jobs[next_pending];
            let session =
                TrainSession::with_parts(backend, &spec.opts, None, pool.acquire())
                    .with_context(|| format!("serve: starting job {:?}", spec.name))?;
            ledger.register(next_pending, &session, spec.eps_budget);
            crate::log_info!(
                "serve: job {:?} started ({} of {} steps done, config {})",
                spec.name,
                session.step_index(),
                session.total_steps(),
                session.config_name()
            );
            active.push((next_pending, session));
            next_pending += 1;
        }
        if !stopped_early
            && sopts.stop.as_ref().is_some_and(|s| s.load(Ordering::SeqCst))
        {
            stopped_early = true;
            crate::log_info!(
                "serve: stop requested — checkpointing {} live job(s)",
                active.len()
            );
        }
        if active.is_empty() {
            break;
        }

        // one round-robin pass; retiring in-place keeps declaration
        // order for the jobs that remain
        let mut i = 0;
        while i < active.len() {
            let (job, session) = &mut active[i];
            let job = *job;
            let finished = session.finished();
            let refused = !finished && !stopped_early && !ledger.admit(job);
            if finished || refused || stopped_early {
                let (_, session) = active.remove(i);
                if refused {
                    let spent = session
                        .epsilon()
                        .map(|(e, _)| e)
                        .unwrap_or(f64::NAN);
                    crate::log_info!(
                        "serve: ledger refused job {:?} at step {} — the next \
                         step would exceed eps budget {} (spent {:.4})",
                        jobs[job].name,
                        session.step_index(),
                        jobs[job].eps_budget.unwrap_or(f64::NAN),
                        spent
                    );
                } else if !finished {
                    crate::log_info!(
                        "serve: job {:?} interrupted at step {} of {}",
                        jobs[job].name,
                        session.step_index(),
                        session.total_steps()
                    );
                } else {
                    crate::log_info!(
                        "serve: job {:?} finished ({} steps)",
                        jobs[job].name,
                        session.step_index()
                    );
                }
                if let Some(dir) = session.checkpoint_dir() {
                    writer.enqueue(
                        dir,
                        session.checkpoint_meta(),
                        session.params_snapshot(),
                    )?;
                    crate::log_info!(
                        "serve: job {:?} checkpoint queued for {}",
                        jobs[job].name,
                        dir.display()
                    );
                }
                let (report, arena) = session.finish();
                pool.release(arena);
                outcomes[job] = Some(JobOutcome {
                    name: jobs[job].name.clone(),
                    budget_stopped: refused,
                    report,
                });
                continue;
            }
            session
                .step()
                .with_context(|| {
                    format!(
                        "serve: job {:?} failed at step {}",
                        jobs[job].name,
                        session.step_index()
                    )
                })?;
            i += 1;
        }
    }

    if stopped_early && next_pending < jobs.len() {
        crate::log_info!(
            "serve: {} pending job(s) never started",
            jobs.len() - next_pending
        );
    }
    // surface any background write failure before reporting success
    writer.finish()?;
    Ok(ServeReport {
        outcomes: outcomes.into_iter().flatten().collect(),
        stopped_early,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_jobs_reads_fields_and_defaults() {
        let (jobs, maxc) = parse_jobs(
            r#"{"max_concurrent": 2, "jobs": [
                {"name": "a", "config": "mlp2_mnist_b32", "method": "reweight",
                 "steps": 7, "n": 128, "lr": 0.05, "sigma": 1.25, "seed": 9,
                 "optimizer": "sgd", "target_eps": 3.5, "poisson": true,
                 "checkpoint": "ckpt/a", "stream_chunk": 64},
                {"name": "b", "method": "nonprivate"}
            ]}"#,
        )
        .unwrap();
        assert_eq!(maxc, 2);
        assert_eq!(jobs.len(), 2);
        let a = &jobs[0];
        assert_eq!(a.name, "a");
        assert_eq!(a.opts.steps, 7);
        assert_eq!(a.opts.dataset_n, 128);
        assert_eq!(a.opts.seed, 9);
        assert_eq!(a.opts.optimizer, "sgd");
        assert!(a.opts.poisson);
        assert_eq!(a.opts.stream_chunk, Some(64));
        assert_eq!(a.eps_budget, Some(3.5));
        // budget is ledger enforcement, NOT sigma calibration
        assert!(a.opts.target_eps.is_none());
        assert_eq!(
            a.opts.checkpoint_dir.as_deref(),
            Some(std::path::Path::new("ckpt/a"))
        );
        // defaults: silent per-step logging, non-private job b has no budget
        assert_eq!(a.opts.log_every, 0);
        assert!(jobs[1].eps_budget.is_none());
    }

    #[test]
    fn parse_jobs_rejects_bad_files() {
        let dup = parse_jobs(
            r#"{"jobs": [{"name": "x"}, {"name": "x"}]}"#,
        );
        assert!(dup.unwrap_err().to_string().contains("duplicate"));

        let unknown = parse_jobs(r#"{"jobs": [{"name": "x", "sigm": 1.0}]}"#);
        assert!(unknown.unwrap_err().to_string().contains("unknown key"));

        let top = parse_jobs(r#"{"jobs": [{"name": "x"}], "maxconc": 1}"#);
        assert!(top.unwrap_err().to_string().contains("top-level"));

        let both = parse_jobs(
            r#"{"jobs": [{"name": "x", "clip": 1.0, "clip_policy": "global:0.5"}]}"#,
        );
        assert!(both.unwrap_err().to_string().contains("not both"));

        let budget_nonpriv = parse_jobs(
            r#"{"jobs": [{"name": "x", "method": "nonprivate", "target_eps": 2.0}]}"#,
        );
        assert!(budget_nonpriv
            .unwrap_err()
            .to_string()
            .contains("no noise"));

        let empty = parse_jobs(r#"{"jobs": []}"#);
        assert!(empty.unwrap_err().to_string().contains("empty"));
    }
}
