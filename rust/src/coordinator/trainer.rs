//! The DP training loop — paper Algorithm 1 end to end.
//!
//! Per step: sample a minibatch (shuffle-partition or Poisson), stage
//! it, run the selected gradient-clipping method's executable(s), add
//! calibrated Gaussian noise (the mechanism of Lemma 2), update with
//! DP-Adam/SGD, and charge the RDP accountant. Python never runs here.

use super::methods::{ClipMethod, GradComputer};
use super::metrics::{Metrics, Phase, PhaseTimer};
use crate::data::{self, Dataset, Features, PoissonSampler, ShuffleBatcher};
use crate::optim;
use crate::privacy::{calibrate_sigma, noise_stddev_for_mean, RdpAccountant};
use crate::runtime::{
    init_params_glorot, Backend, BatchStage, ClipPolicy, ParamStore, StepFn,
};
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::time::Instant;

#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub config: String,
    pub method: ClipMethod,
    pub steps: u64,
    /// synthetic dataset size (sampling rate q = batch / n)
    pub dataset_n: usize,
    pub lr: f64,
    pub clip: f64,
    /// Clipping policy (granularity × nu formula). `None` means the
    /// classical policy the paper uses — global granularity, hard clip
    /// at `clip` — reproducing the pre-policy trainer bitwise
    /// (including the noise stream, which then calibrates to the f64
    /// `clip` exactly). When set, `clip` is ignored: the policy
    /// carries its own threshold, and the noise is calibrated to the
    /// policy's true L2 sensitivity (C·sqrt(G) for grouped
    /// granularities).
    pub policy: Option<ClipPolicy>,
    /// noise multiplier; ignored when target_eps is set (calibrated)
    pub sigma: f64,
    pub target_eps: Option<f64>,
    pub delta: f64,
    pub optimizer: String,
    pub seed: u64,
    /// 0 = no eval
    pub eval_every: u64,
    /// eval set size; None = 4 batches (the old hardcoded default).
    /// Must be a positive multiple of the config batch — evaluation
    /// runs in full batches, and a remainder would be silently dropped
    pub eval_n: Option<usize>,
    pub log_every: u64,
    pub checkpoint_dir: Option<PathBuf>,
    /// Resume from a checkpoint directory (`checkpoint::load`):
    /// restores the parameters, the step counter, and the RDP
    /// accountant state (the checkpointed steps are re-charged at
    /// their recorded sampling rate and sigma). `steps` stays a
    /// *total*: resuming a 5-step checkpoint with `steps: 8` runs 3
    /// more steps. The resumed run must continue the *same* process:
    /// seed, sampling mode, method, optimizer, lr, and sampling rate
    /// must match, and (for private methods) clip policy / sigma must
    /// match the recorded values and `target_eps` is rejected — the
    /// checkpoint can record only one value of each for its whole
    /// history, so a heterogeneous chain would corrupt the accounting
    /// of a later resume. Optimizer *state* is not checkpointed: sgd
    /// resumes bitwise-exactly, adam restarts its moments (warned
    /// loudly).
    pub resume: Option<PathBuf>,
    /// Poisson subsampling (the regime the RDP analysis assumes)
    /// instead of shuffle-partition
    pub poisson: bool,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            config: "mlp2_mnist_b32".into(),
            method: ClipMethod::Reweight,
            steps: 100,
            dataset_n: 2048,
            lr: 1e-3,
            clip: 1.0,
            policy: None,
            sigma: 1.1,
            target_eps: None,
            delta: 1e-5,
            optimizer: "adam".into(),
            seed: 0,
            eval_every: 0,
            eval_n: None,
            log_every: 20,
            checkpoint_dir: None,
            resume: None,
            poisson: false,
        }
    }
}

#[derive(Debug)]
pub struct TrainReport {
    pub config: String,
    pub method: ClipMethod,
    pub steps: u64,
    pub final_loss_ema: f64,
    pub losses: Vec<f32>,
    pub eval_points: Vec<(u64, f32, f32)>,
    pub epsilon: Option<(f64, u32)>,
    pub sigma: f64,
    /// canonical clip-policy name the run clipped under
    pub policy: String,
    /// the L2 sensitivity the noise was calibrated to (C for global
    /// policies, C·sqrt(G) for grouped ones)
    pub sensitivity: f64,
    pub sampling_rate: f64,
    pub wall_seconds: f64,
    pub mean_step_ms: f64,
    pub metrics_json: crate::util::json::Json,
    pub peak_rss_bytes: Option<u64>,
}

enum Sampler {
    Shuffle(ShuffleBatcher),
    Poisson(PoissonSampler),
}

impl Sampler {
    fn next_batch(&mut self) -> Vec<usize> {
        match self {
            Sampler::Shuffle(b) => b.next_batch(),
            Sampler::Poisson(p) => p.next_batch(),
        }
    }
}

pub fn train(backend: &dyn Backend, opts: &TrainOptions) -> Result<TrainReport> {
    let cfg = backend.resolve(&opts.config)?;
    let tau = cfg.batch;
    anyhow::ensure!(
        opts.dataset_n >= tau,
        "dataset_n {} < batch {}",
        opts.dataset_n,
        tau
    );
    let q = tau as f64 / opts.dataset_n as f64;

    // --- effective clip policy ---------------------------------------
    // Every parametric layer is one (W, b) pair in manifest order, so
    // policy group boundaries index cfg.params in steps of two.
    let n_param_layers = cfg.params.len() / 2;
    let policy = opts
        .policy
        .clone()
        .unwrap_or_else(|| ClipPolicy::hard_global(opts.clip as f32));
    if opts.method.is_private() {
        policy.check(n_param_layers).with_context(|| {
            format!("--clip-policy {policy} on config {}", cfg.name)
        })?;
    }
    // The mechanism's L2 sensitivity — what the Gaussian noise must be
    // calibrated to. The pre-policy flag path keeps the exact f64 clip
    // (bitwise noise-stream continuity); an explicit policy computes
    // C·sqrt(G) (= C for global granularities).
    let sensitivity = match &opts.policy {
        None => opts.clip,
        Some(p) => p.sensitivity(n_param_layers),
    };

    // --- resume: restore params / step counter / accountant inputs ---
    let mut start_step = 0u64;
    let mut resume_init: Option<Vec<f32>> = None;
    // (sampling rate, sigma) the checkpointed steps were run at — what
    // the accountant must re-charge, regardless of the current flags
    let mut resume_charge: Option<(f64, f64)> = None;
    if let Some(dir) = &opts.resume {
        let (meta, flat) = super::checkpoint::load(dir, &cfg)
            .with_context(|| format!("resuming from {}", dir.display()))?;
        anyhow::ensure!(
            meta.step < opts.steps,
            "checkpoint at {} already covers {} steps and --steps {} is a \
             total, not an increment — raise --steps to continue training",
            dir.display(),
            meta.step,
            opts.steps
        );
        // Continuity: the replayed sampler and the step-keyed noise
        // stream both derive from the seed, so a silently different
        // seed would diverge from the run being continued.
        anyhow::ensure!(
            opts.seed == meta.seed,
            "resume: checkpoint at {} was trained with --seed {} but this \
             run uses --seed {} — the replayed batch and noise streams \
             would diverge from the run being continued",
            dir.display(),
            meta.seed,
            opts.seed
        );
        // Sampling-mode continuity: the replayed sampler AND the
        // RDP re-charge both assume the recorded regime — resuming a
        // Poisson run with shuffle-partition (or vice versa) would
        // silently change both the batch stream and the subsampling
        // assumption the accountant's rate q rests on. A pre-PR5
        // checkpoint recorded no mode (None): skip the check rather
        // than misread the absence as shuffle-partition.
        if let Some(was_poisson) = meta.poisson {
            anyhow::ensure!(
                opts.poisson == was_poisson,
                "resume: checkpoint was trained with {} sampling but this \
                 run uses {} — the replayed batch stream and the \
                 accountant's subsampling assumption would both change \
                 mid-run; {}",
                if was_poisson { "--poisson" } else { "shuffle-partition" },
                if opts.poisson { "--poisson" } else { "shuffle-partition" },
                if was_poisson { "pass --poisson" } else { "drop --poisson" }
            );
        }
        // Method continuity: all private methods agree to ~1e-5 but
        // not bitwise, so switching mid-run is not a continuation of
        // the same trajectory (and private/non-private switches would
        // corrupt the epsilon report outright).
        anyhow::ensure!(
            meta.method == opts.method.name(),
            "resume: checkpoint was trained with --method {} but this run \
             uses --method {} — switch methods only in a fresh run; pass \
             --method {}",
            meta.method,
            opts.method.name(),
            meta.method
        );
        // Optimizer continuity: the name is validated (a pre-PR5
        // checkpoint records none — skip); optimizer *state* is not
        // checkpointed, so a stateful optimizer restarts its moments —
        // warn loudly rather than silently diverging. With sgd
        // (stateless) a resumed run is bitwise the continuous run.
        if !meta.optimizer.is_empty() {
            anyhow::ensure!(
                opts.optimizer == meta.optimizer,
                "resume: checkpoint was trained with --optimizer {} but \
                 this run uses --optimizer {} — switching optimizers \
                 mid-run is not a continuation; pass --optimizer {}",
                meta.optimizer,
                opts.optimizer,
                meta.optimizer
            );
        }
        // Learning-rate continuity (every method): the tail would
        // silently train at a different rate than the recorded steps.
        // A pre-PR5 checkpoint records no lr (0.0): skip.
        if meta.lr > 0.0 {
            anyhow::ensure!(
                (opts.lr - meta.lr).abs() < 1e-12,
                "resume: checkpoint records lr={} but this run passes \
                 lr={} — the continuation would train at a different \
                 rate; pass --lr {}",
                meta.lr,
                opts.lr,
                meta.lr
            );
        }
        if opts.optimizer != "sgd" {
            crate::log_info!(
                "resume: WARNING — optimizer state is not checkpointed; \
                 {} restarts its moment estimates from zero at step {}, \
                 so the continuation is not bitwise identical to an \
                 uninterrupted run (use --optimizer sgd for exact \
                 continuation)",
                opts.optimizer,
                meta.step
            );
        }
        if opts.method.is_private() {
            // The checkpoint records ONE (sampling_rate, sigma, clip)
            // for its whole history, so the accountant cannot represent
            // a heterogeneous chain: a later resume of the checkpoint
            // this run writes would re-charge every step at whatever
            // values are current here. Refuse the combinations that
            // would corrupt (or double-count) the recorded privacy
            // spend — or, for clip, silently break the continuation
            // (noise_std and the clipping threshold both derive from
            // it).
            match &meta.clip_policy {
                // policy-recording checkpoint: the canonical name is
                // the policy's stable identity — compare it wholesale
                Some(rec) => {
                    anyhow::ensure!(
                        *rec == policy.to_string(),
                        "resume: checkpoint records clip policy {} but \
                         this run clips under {} — the threshold \
                         structure and the noise scale would change \
                         mid-run; pass --clip-policy {}",
                        rec,
                        policy,
                        rec
                    );
                }
                // pre-policy checkpoint + pre-policy flags: the
                // recorded bare clip IS the classical global hard
                // policy — the original continuity check, verbatim
                None if opts.policy.is_none() => {
                    anyhow::ensure!(
                        (opts.clip - meta.clip).abs() < 1e-12,
                        "resume: checkpoint records clip={} but this run \
                         passes clip={} — the clipping threshold and the \
                         noise scale would both change mid-run; pass \
                         --clip {}",
                        meta.clip,
                        opts.clip,
                        meta.clip
                    );
                }
                // pre-policy checkpoint + explicit --clip-policy: only
                // the classical policy at the recorded threshold
                // continues the same process (1e-6: the policy
                // threshold is f32)
                None => {
                    anyhow::ensure!(
                        policy.is_global_hard()
                            && (policy.clip() as f64 - meta.clip).abs()
                                < 1e-6,
                        "resume: checkpoint predates clip policies — its \
                         steps ran the classical global hard clip at {} — \
                         but this run passes --clip-policy {}; pass \
                         --clip-policy global:{} (or drop the flag and \
                         pass --clip {})",
                        meta.clip,
                        policy,
                        meta.clip,
                        meta.clip
                    );
                }
            }
            anyhow::ensure!(
                opts.target_eps.is_none(),
                "resume: --target-eps would re-calibrate sigma as if all \
                 {} steps were fresh budget, double-counting the {} \
                 checkpointed steps' spend; pass --sigma explicitly (the \
                 checkpoint records sigma={})",
                opts.steps,
                meta.step,
                meta.sigma
            );
            anyhow::ensure!(
                (opts.sigma - meta.sigma).abs() < 1e-12,
                "resume: checkpoint records sigma={} but this run passes \
                 sigma={} — the checkpoint written at the end could only \
                 record one value for the whole history, mis-charging a \
                 later resume; pass --sigma {}",
                meta.sigma,
                opts.sigma,
                meta.sigma
            );
        }
        // The sampling rate fixes both the replayed batch stream (the
        // samplers are seeded over dataset_n) and, for private
        // methods, the accountant's subsampling rate — so it must
        // match for *every* method, not only private ones. Guard on a
        // recorded rate > 0 (a damaged/ancient meta contributes
        // nothing rather than a division by zero in the hint).
        if meta.sampling_rate > 0.0 {
            anyhow::ensure!(
                (q - meta.sampling_rate).abs() < 1e-12,
                "resume: checkpoint records sampling rate q={} but --n {} \
                 gives q={} — the replayed batch stream (and any privacy \
                 accounting) must cover the whole history at one rate; \
                 pass --n {}",
                meta.sampling_rate,
                opts.dataset_n,
                q,
                (tau as f64 / meta.sampling_rate).round()
            );
        }
        crate::log_info!(
            "resume: {} at step {} (q={:.4}, sigma={:.3})",
            dir.display(),
            meta.step,
            meta.sampling_rate,
            meta.sigma
        );
        start_step = meta.step;
        resume_charge = Some((meta.sampling_rate, meta.sigma));
        resume_init = Some(flat);
    }

    // --- eval set size (was: a silent hardcoded `tau * 4`) ----------
    let eval_n = match opts.eval_n {
        Some(n) => {
            anyhow::ensure!(
                opts.eval_every > 0,
                "--eval-n has no effect without --eval-every; set an \
                 evaluation interval or drop --eval-n"
            );
            anyhow::ensure!(
                n >= tau && n % tau == 0,
                "--eval-n {n} must be a positive multiple of config {}'s \
                 batch {tau} — evaluation runs in full batches and would \
                 silently drop the remainder examples",
                cfg.name
            );
            n
        }
        None => tau * 4,
    };

    // --- noise calibration (Alg 1, line 1) --------------------------
    let sigma = match opts.target_eps {
        Some(eps) if opts.method.is_private() => {
            let s = calibrate_sigma(q, opts.steps, eps, opts.delta)
                .context("target epsilon infeasible at sigma<=200")?;
            crate::log_info!(
                "calibrated sigma={:.3} for eps<={} delta={} over {} steps (q={:.4})",
                s, eps, opts.delta, opts.steps, q
            );
            s
        }
        _ => opts.sigma,
    };

    // --- data --------------------------------------------------------
    let ds = data::load_dataset(&cfg.dataset, opts.dataset_n, opts.seed)?;
    let eval_ds = if opts.eval_every > 0 {
        Some(data::load_dataset(&cfg.dataset, eval_n, opts.seed + 1)?)
    } else {
        None
    };

    // --- executables / params / optimizer ----------------------------
    let mut computer = GradComputer::new(backend, &opts.config, opts.method)?;
    let fwd_exe = if opts.eval_every > 0 {
        Some(backend.load(&cfg, "fwd")?)
    } else {
        None
    };
    let init = match resume_init {
        Some(flat) => flat,
        None => init_params_glorot(&cfg, opts.seed),
    };
    let mut params = ParamStore::new(&cfg, Some(&init))?;
    let mut opt = optim::by_name(&opts.optimizer, opts.lr)?;
    let mut accountant = RdpAccountant::new();
    if opts.method.is_private() && start_step > 0 {
        // re-charge the checkpointed steps at their *recorded* rate and
        // sigma: budget already spent cannot change just because the
        // resumed run passes different flags
        let (q0, s0) = resume_charge.expect("resume meta");
        accountant.steps(q0, s0, start_step);
    }
    let mut sampler = if opts.poisson {
        Sampler::Poisson(PoissonSampler::new(opts.dataset_n, tau, opts.seed))
    } else {
        Sampler::Shuffle(ShuffleBatcher::new(opts.dataset_n, tau, opts.seed))
    };
    // replay the sampler to the resume point, so a resumed run draws
    // the same batch sequence the continuous run would have drawn
    for _ in 0..start_step {
        sampler.next_batch();
    }

    let mut stage = BatchStage::for_config(&cfg);
    // one output arena for the whole run: the step resets it each
    // call, so the warm loop performs zero per-step heap allocation
    let mut out = computer.new_out();
    let mut metrics = Metrics::new();
    let noise_std = noise_stddev_for_mean(sigma, sensitivity, tau);

    crate::log_info!(
        "train {} method={} steps={} tau={} q={:.4} sigma={:.3} policy={} sens={} opt={}",
        cfg.name, opts.method.name(), opts.steps, tau, q, sigma, policy, sensitivity, opts.optimizer
    );

    // --- the loop (Alg 1, lines 2-16) --------------------------------
    for step in start_step..opts.steps {
        let t_step = Instant::now();

        let t = PhaseTimer::start();
        let batch = sampler.next_batch();
        stage_batch(&ds, &batch, &mut stage);
        t.stop(&mut metrics, Phase::Gather);

        let t = PhaseTimer::start();
        computer.compute(&mut params, &stage, &policy, &mut out)?;
        t.stop(&mut metrics, Phase::Execute);
        if let Some((gn, ng)) = out.group_norms() {
            metrics.record_group_norms(gn, ng);
        }

        if opts.method.is_private() {
            let t = PhaseTimer::start();
            // §Perf L3 iteration 3: parallel chunked polar-method noise
            // (was: sequential Box-Muller at 68% of step time) — one
            // flat pass over the arena's gradient buffer.
            crate::rng::add_noise_parallel(
                out.grads.flat_mut(),
                noise_std,
                opts.seed,
                step,
            );
            // poisoning guard (debug/test profile only): the noised
            // gradient is the last value before the optimizer — a
            // NaN/Inf here must fail at the source, not as a drifted
            // loss many steps later
            crate::runtime::store::debug_assert_finite(
                out.grads.flat(),
                "trainer noise path (post add_noise_parallel)",
            );
            accountant.step(q, sigma);
            t.stop(&mut metrics, Phase::Noise);
        }

        let t = PhaseTimer::start();
        opt.step(&mut params.host, &out.grads);
        params.mark_dirty();
        t.stop(&mut metrics, Phase::Update);

        metrics.record_step(t_step.elapsed().as_secs_f64(), out.loss);

        if opts.log_every > 0 && (step + 1) % opts.log_every == 0 {
            let eps_str = if opts.method.is_private() {
                let (e, a) = accountant.epsilon(opts.delta);
                format!(" eps={:.3}(a={})", e, a)
            } else {
                String::new()
            };
            crate::log_info!(
                "step {:>5} loss={:.4} ema={:.4}{}",
                step + 1,
                out.loss,
                metrics.loss_ema.get().unwrap_or(0.0),
                eps_str
            );
        }

        if let (Some(fwd), Some(eds)) = (&fwd_exe, &eval_ds) {
            if (step + 1) % opts.eval_every == 0 {
                let (l, a) = evaluate(fwd.as_ref(), &mut params, eds, &cfg)?;
                metrics.record_eval(step + 1, l, a);
                crate::log_info!(
                    "eval  step {:>5} loss={:.4} acc={:.3}",
                    step + 1,
                    l,
                    a
                );
            }
        }
    }

    // --- checkpoint ----------------------------------------------------
    if let Some(dir) = &opts.checkpoint_dir {
        super::checkpoint::save(
            dir,
            &super::checkpoint::CheckpointMeta {
                config: cfg.name.clone(),
                method: opts.method.name().into(),
                optimizer: opts.optimizer.clone(),
                step: opts.steps,
                sampling_rate: q,
                sigma,
                clip: match &opts.policy {
                    Some(p) => p.clip() as f64,
                    None => opts.clip,
                },
                lr: opts.lr,
                seed: opts.seed,
                poisson: Some(opts.poisson),
                clip_policy: Some(policy.to_string()),
            },
            &params,
        )?;
        crate::log_info!("checkpoint written to {}", dir.display());
    }

    let epsilon = if opts.method.is_private() {
        Some(accountant.epsilon(opts.delta))
    } else {
        None
    };
    let mean_step_ms = metrics
        .step_summary()
        .map(|s| s.mean * 1e3)
        .unwrap_or(0.0);
    Ok(TrainReport {
        config: cfg.name,
        method: opts.method,
        steps: opts.steps,
        final_loss_ema: metrics.loss_ema.get().unwrap_or(f64::NAN),
        losses: metrics.losses.clone(),
        eval_points: metrics.eval_points.clone(),
        epsilon,
        sigma,
        policy: policy.to_string(),
        sensitivity,
        sampling_rate: q,
        wall_seconds: metrics.wall_seconds(),
        mean_step_ms,
        metrics_json: metrics.to_json(),
        peak_rss_bytes: crate::util::peak_rss_bytes(),
    })
}

/// Stage a batch of examples into the upload buffers.
pub fn stage_batch(ds: &Dataset, batch: &[usize], stage: &mut BatchStage) {
    match ds.features {
        Features::F32(_) => {
            data::gather_batch_f32(ds, batch, &mut stage.feat_f32, &mut stage.labels)
        }
        Features::I32(_) => {
            data::gather_batch_i32(ds, batch, &mut stage.feat_i32, &mut stage.labels)
        }
    }
}

/// Run the fwd step over the eval set; returns (mean loss, accuracy).
///
/// The staging buffers come from `BatchStage::for_config` — the same
/// constructor every other execution path uses — rather than a
/// hand-built duplicate that could drift from the config's shapes. An
/// eval set smaller than one batch is a hard error: it would yield
/// zero batches and a silent NaN loss/accuracy.
///
/// Accuracy is integer-exact: the fwd step reports the
/// correct-prediction *count* (`u32`), summed here in `u64` and
/// divided once by the number of evaluated examples — no float
/// accumulation of counts.
pub fn evaluate(
    fwd: &dyn StepFn,
    params: &mut ParamStore,
    eval_ds: &Dataset,
    cfg: &crate::runtime::ConfigSpec,
) -> Result<(f32, f32)> {
    let tau = cfg.batch;
    anyhow::ensure!(
        eval_ds.n >= tau,
        "eval set holds {} examples but config {} evaluates in full \
         batches of {tau}; supply at least one batch",
        eval_ds.n,
        cfg.name
    );
    anyhow::ensure!(
        eval_ds.example_len() * cfg.batch == cfg.input_elems(),
        "eval dataset example shape {:?} does not match config {}",
        eval_ds.shape,
        cfg.name
    );
    let n_batches = eval_ds.n / tau;
    let mut stage = BatchStage::for_config(cfg);
    let mut out = crate::runtime::StepOut::for_config(cfg);
    let mut loss_sum = 0.0f32;
    let mut correct_sum = 0u64;
    for b in 0..n_batches {
        let batch: Vec<usize> = (b * tau..(b + 1) * tau).collect();
        stage_batch(eval_ds, &batch, &mut stage);
        fwd.run_into(params, &stage, None, &mut out)?;
        loss_sum += out.loss;
        correct_sum += u64::from(out.correct.unwrap_or(0));
    }
    Ok((
        loss_sum / n_batches as f32,
        correct_sum as f32 / (n_batches * tau) as f32,
    ))
}
