//! The DP training loop — paper Algorithm 1 end to end.
//!
//! Since the session-core refactor, all per-step mechanics live in
//! [`TrainSession`](super::session::TrainSession): `train()` is a thin
//! driver — construct a session, `step()` it to completion (honoring a
//! graceful-stop flag), log/evaluate at the configured cadence, write
//! the final checkpoint, return the report. A single run is
//! bitwise-identical to the pre-refactor monolith; the equivalence
//! suite in `tests/session.rs` pins that.

use super::methods::ClipMethod;
use super::session::TrainSession;
use crate::data::{self, Dataset, Features};
use crate::runtime::{Backend, BatchStage, ClipPolicy, ParamStore, StepFn};
use anyhow::Result;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub config: String,
    pub method: ClipMethod,
    pub steps: u64,
    /// synthetic dataset size (sampling rate q = batch / n)
    pub dataset_n: usize,
    pub lr: f64,
    pub clip: f64,
    /// Clipping policy (granularity × nu formula). `None` means the
    /// classical policy the paper uses — global granularity, hard clip
    /// at `clip` — reproducing the pre-policy trainer bitwise
    /// (including the noise stream, which then calibrates to the f64
    /// `clip` exactly). When set, `clip` is ignored: the policy
    /// carries its own threshold, and the noise is calibrated to the
    /// policy's true L2 sensitivity (C·sqrt(G) for grouped
    /// granularities).
    pub policy: Option<ClipPolicy>,
    /// noise multiplier; ignored when target_eps is set (calibrated)
    pub sigma: f64,
    pub target_eps: Option<f64>,
    pub delta: f64,
    pub optimizer: String,
    pub seed: u64,
    /// 0 = no eval
    pub eval_every: u64,
    /// eval set size; None = 4 batches (the old hardcoded default).
    /// Must be a positive multiple of the config batch — evaluation
    /// runs in full batches, and a remainder would be silently dropped
    pub eval_n: Option<usize>,
    pub log_every: u64,
    pub checkpoint_dir: Option<PathBuf>,
    /// Resume from a checkpoint directory (`checkpoint::load`):
    /// restores the parameters, the step counter, and the RDP
    /// accountant state (the checkpointed steps are re-charged at
    /// their recorded sampling rate and sigma). `steps` stays a
    /// *total*: resuming a 5-step checkpoint with `steps: 8` runs 3
    /// more steps. The resumed run must continue the *same* process:
    /// seed, sampling mode, method, optimizer, lr, and sampling rate
    /// must match, and (for private methods) clip policy / sigma must
    /// match the recorded values and `target_eps` is rejected — the
    /// checkpoint can record only one value of each for its whole
    /// history, so a heterogeneous chain would corrupt the accounting
    /// of a later resume. Optimizer *state* is not checkpointed: sgd
    /// resumes bitwise-exactly, adam restarts its moments (warned
    /// loudly).
    pub resume: Option<PathBuf>,
    /// Poisson subsampling (the regime the RDP analysis assumes)
    /// instead of shuffle-partition
    pub poisson: bool,
    /// Graceful-stop flag (see `util::signal::install_sigint`), polled
    /// at step boundaries: when it flips, the loop breaks, writes the
    /// final checkpoint (a valid `--resume` point — the accountant's
    /// inputs travel with it), and returns a truthful report. `None`
    /// never stops early.
    pub stop: Option<Arc<AtomicBool>>,
    /// Stream the dataset from its IDX files in chunks of this many
    /// rows (`data::StreamingIdxSource`) instead of loading it fully
    /// into memory. Batches are bitwise-identical to the in-memory
    /// path; only residency changes. `None` = in-memory.
    pub stream_chunk: Option<usize>,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            config: "mlp2_mnist_b32".into(),
            method: ClipMethod::Reweight,
            steps: 100,
            dataset_n: 2048,
            lr: 1e-3,
            clip: 1.0,
            policy: None,
            sigma: 1.1,
            target_eps: None,
            delta: 1e-5,
            optimizer: "adam".into(),
            seed: 0,
            eval_every: 0,
            eval_n: None,
            log_every: 20,
            checkpoint_dir: None,
            resume: None,
            poisson: false,
            stop: None,
            stream_chunk: None,
        }
    }
}

#[derive(Debug)]
pub struct TrainReport {
    pub config: String,
    pub method: ClipMethod,
    pub steps: u64,
    pub final_loss_ema: f64,
    pub losses: Vec<f32>,
    pub eval_points: Vec<(u64, f32, f32)>,
    pub epsilon: Option<(f64, u32)>,
    pub sigma: f64,
    /// canonical clip-policy name the run clipped under
    pub policy: String,
    /// the L2 sensitivity the noise was calibrated to (C for global
    /// policies, C·sqrt(G) for grouped ones)
    pub sensitivity: f64,
    pub sampling_rate: f64,
    pub wall_seconds: f64,
    pub mean_step_ms: f64,
    pub metrics_json: crate::util::json::Json,
    pub peak_rss_bytes: Option<u64>,
}

pub fn train(backend: &dyn Backend, opts: &TrainOptions) -> Result<TrainReport> {
    let mut session = TrainSession::new(backend, opts)?;

    // --- the loop (Alg 1, lines 2-16) --------------------------------
    while !session.finished() {
        // stop-flag check FIRST: a flag raised mid-step takes effect at
        // the next boundary, and a flag preset before the run performs
        // zero steps (checkpoint at the current — possibly resumed —
        // step index).
        if opts.stop.as_ref().is_some_and(|s| s.load(Ordering::SeqCst)) {
            crate::log_info!(
                "train: stop requested — writing final checkpoint at step {}",
                session.step_index()
            );
            break;
        }

        let loss = session.step()?;
        let done = session.step_index();

        if opts.log_every > 0 && done % opts.log_every == 0 {
            let eps_str = match session.epsilon() {
                Some((e, a)) => format!(" eps={:.3}(a={})", e, a),
                None => String::new(),
            };
            crate::log_info!(
                "step {:>5} loss={:.4} ema={:.4}{}",
                done,
                loss,
                session.loss_ema(),
                eps_str
            );
        }

        if session.eval_due() {
            let (l, a) = session.run_eval()?;
            crate::log_info!("eval  step {:>5} loss={:.4} acc={:.3}", done, l, a);
        }
    }

    // --- checkpoint ----------------------------------------------------
    if session.maybe_checkpoint()? {
        if let Some(dir) = &opts.checkpoint_dir {
            crate::log_info!("checkpoint written to {}", dir.display());
        }
    }

    Ok(session.finish().0)
}

/// Stage a batch of examples into the upload buffers. The stage's
/// dtype (from the config) decides the destination: an i32 token
/// dataset feeding an f32-staged config (the native transformer
/// family) widens token ids to f32 in place — ids are exactly
/// representable, and the gather is allocation-free either way.
pub fn stage_batch(ds: &Dataset, batch: &[usize], stage: &mut BatchStage) {
    match ds.features {
        Features::F32(_) => {
            data::gather_batch_f32(ds, batch, &mut stage.feat_f32, &mut stage.labels)
        }
        Features::I32(_) if stage.is_f32 => data::gather_batch_i32_as_f32(
            ds,
            batch,
            &mut stage.feat_f32,
            &mut stage.labels,
        ),
        Features::I32(_) => {
            data::gather_batch_i32(ds, batch, &mut stage.feat_i32, &mut stage.labels)
        }
    }
}

/// Run the fwd step over the eval set; returns (mean loss, accuracy).
///
/// The staging buffers come from `BatchStage::for_config` — the same
/// constructor every other execution path uses — rather than a
/// hand-built duplicate that could drift from the config's shapes. An
/// eval set smaller than one batch is a hard error: it would yield
/// zero batches and a silent NaN loss/accuracy.
///
/// Accuracy is integer-exact: the fwd step reports the
/// correct-prediction *count* (`u32`), summed here in `u64` and
/// divided once by the number of evaluated examples — no float
/// accumulation of counts.
pub fn evaluate(
    fwd: &dyn StepFn,
    params: &mut ParamStore,
    eval_ds: &Dataset,
    cfg: &crate::runtime::ConfigSpec,
) -> Result<(f32, f32)> {
    let tau = cfg.batch;
    anyhow::ensure!(
        eval_ds.n >= tau,
        "eval set holds {} examples but config {} evaluates in full \
         batches of {tau}; supply at least one batch",
        eval_ds.n,
        cfg.name
    );
    anyhow::ensure!(
        eval_ds.example_len() * cfg.batch == cfg.input_elems(),
        "eval dataset example shape {:?} does not match config {}",
        eval_ds.shape,
        cfg.name
    );
    let n_batches = eval_ds.n / tau;
    let mut stage = BatchStage::for_config(cfg);
    let mut out = crate::runtime::StepOut::for_config(cfg);
    let mut loss_sum = 0.0f32;
    let mut correct_sum = 0u64;
    for b in 0..n_batches {
        let batch: Vec<usize> = (b * tau..(b + 1) * tau).collect();
        stage_batch(eval_ds, &batch, &mut stage);
        fwd.run_into(params, &stage, None, &mut out)?;
        loss_sum += out.loss;
        correct_sum += u64::from(out.correct.unwrap_or(0));
    }
    Ok((
        loss_sum / n_batches as f32,
        correct_sum as f32 / (n_batches * tau) as f32,
    ))
}
