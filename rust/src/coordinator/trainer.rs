//! The DP training loop — paper Algorithm 1 end to end.
//!
//! Per step: sample a minibatch (shuffle-partition or Poisson), stage
//! it, run the selected gradient-clipping method's executable(s), add
//! calibrated Gaussian noise (the mechanism of Lemma 2), update with
//! DP-Adam/SGD, and charge the RDP accountant. Python never runs here.

use super::methods::{ClipMethod, GradComputer};
use super::metrics::{Metrics, Phase, PhaseTimer};
use crate::data::{self, Dataset, Features, PoissonSampler, ShuffleBatcher};
use crate::optim;
use crate::privacy::{calibrate_sigma, noise_stddev_for_mean, RdpAccountant};
use crate::runtime::{
    init_params_glorot, Backend, BatchStage, ParamStore, StepFn,
};
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::time::Instant;

#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub config: String,
    pub method: ClipMethod,
    pub steps: u64,
    /// synthetic dataset size (sampling rate q = batch / n)
    pub dataset_n: usize,
    pub lr: f64,
    pub clip: f64,
    /// noise multiplier; ignored when target_eps is set (calibrated)
    pub sigma: f64,
    pub target_eps: Option<f64>,
    pub delta: f64,
    pub optimizer: String,
    pub seed: u64,
    /// 0 = no eval
    pub eval_every: u64,
    pub log_every: u64,
    pub checkpoint_dir: Option<PathBuf>,
    /// Poisson subsampling (the regime the RDP analysis assumes)
    /// instead of shuffle-partition
    pub poisson: bool,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            config: "mlp2_mnist_b32".into(),
            method: ClipMethod::Reweight,
            steps: 100,
            dataset_n: 2048,
            lr: 1e-3,
            clip: 1.0,
            sigma: 1.1,
            target_eps: None,
            delta: 1e-5,
            optimizer: "adam".into(),
            seed: 0,
            eval_every: 0,
            log_every: 20,
            checkpoint_dir: None,
            poisson: false,
        }
    }
}

#[derive(Debug)]
pub struct TrainReport {
    pub config: String,
    pub method: ClipMethod,
    pub steps: u64,
    pub final_loss_ema: f64,
    pub losses: Vec<f32>,
    pub eval_points: Vec<(u64, f32, f32)>,
    pub epsilon: Option<(f64, u32)>,
    pub sigma: f64,
    pub sampling_rate: f64,
    pub wall_seconds: f64,
    pub mean_step_ms: f64,
    pub metrics_json: crate::util::json::Json,
    pub peak_rss_bytes: Option<u64>,
}

enum Sampler {
    Shuffle(ShuffleBatcher),
    Poisson(PoissonSampler),
}

impl Sampler {
    fn next_batch(&mut self) -> Vec<usize> {
        match self {
            Sampler::Shuffle(b) => b.next_batch(),
            Sampler::Poisson(p) => p.next_batch(),
        }
    }
}

pub fn train(backend: &dyn Backend, opts: &TrainOptions) -> Result<TrainReport> {
    let cfg = backend.manifest().config(&opts.config)?.clone();
    let tau = cfg.batch;
    anyhow::ensure!(
        opts.dataset_n >= tau,
        "dataset_n {} < batch {}",
        opts.dataset_n,
        tau
    );
    let q = tau as f64 / opts.dataset_n as f64;

    // --- noise calibration (Alg 1, line 1) --------------------------
    let sigma = match opts.target_eps {
        Some(eps) if opts.method.is_private() => {
            let s = calibrate_sigma(q, opts.steps, eps, opts.delta)
                .context("target epsilon infeasible at sigma<=200")?;
            crate::log_info!(
                "calibrated sigma={:.3} for eps<={} delta={} over {} steps (q={:.4})",
                s, eps, opts.delta, opts.steps, q
            );
            s
        }
        _ => opts.sigma,
    };

    // --- data --------------------------------------------------------
    let ds = data::load_dataset(&cfg.dataset, opts.dataset_n, opts.seed)?;
    let eval_ds = if opts.eval_every > 0 {
        Some(data::load_dataset(&cfg.dataset, tau * 4, opts.seed + 1)?)
    } else {
        None
    };

    // --- executables / params / optimizer ----------------------------
    let mut computer = GradComputer::new(backend, &opts.config, opts.method)?;
    let fwd_exe = if opts.eval_every > 0 {
        Some(backend.load(&cfg, "fwd")?)
    } else {
        None
    };
    let mut params = ParamStore::new(&cfg, Some(&init_params_glorot(&cfg, opts.seed)))?;
    let mut opt = optim::by_name(&opts.optimizer, opts.lr)?;
    let mut accountant = RdpAccountant::new();
    let mut sampler = if opts.poisson {
        Sampler::Poisson(PoissonSampler::new(opts.dataset_n, tau, opts.seed))
    } else {
        Sampler::Shuffle(ShuffleBatcher::new(opts.dataset_n, tau, opts.seed))
    };

    let mut stage = BatchStage::for_config(&cfg);
    // one output arena for the whole run: the step resets it each
    // call, so the warm loop performs zero per-step heap allocation
    let mut out = computer.new_out();
    let mut metrics = Metrics::new();
    let noise_std = noise_stddev_for_mean(sigma, opts.clip, tau);

    crate::log_info!(
        "train {} method={} steps={} tau={} q={:.4} sigma={:.3} clip={} opt={}",
        cfg.name, opts.method.name(), opts.steps, tau, q, sigma, opts.clip, opts.optimizer
    );

    // --- the loop (Alg 1, lines 2-16) --------------------------------
    for step in 0..opts.steps {
        let t_step = Instant::now();

        let t = PhaseTimer::start();
        let batch = sampler.next_batch();
        stage_batch(&ds, &batch, &mut stage);
        t.stop(&mut metrics, Phase::Gather);

        let t = PhaseTimer::start();
        computer.compute(&mut params, &stage, opts.clip as f32, &mut out)?;
        t.stop(&mut metrics, Phase::Execute);

        if opts.method.is_private() {
            let t = PhaseTimer::start();
            // §Perf L3 iteration 3: parallel chunked polar-method noise
            // (was: sequential Box-Muller at 68% of step time) — one
            // flat pass over the arena's gradient buffer.
            crate::rng::add_noise_parallel(
                out.grads.flat_mut(),
                noise_std,
                opts.seed,
                step,
            );
            accountant.step(q, sigma);
            t.stop(&mut metrics, Phase::Noise);
        }

        let t = PhaseTimer::start();
        opt.step(&mut params.host, &out.grads);
        params.mark_dirty();
        t.stop(&mut metrics, Phase::Update);

        metrics.record_step(t_step.elapsed().as_secs_f64(), out.loss);

        if opts.log_every > 0 && (step + 1) % opts.log_every == 0 {
            let eps_str = if opts.method.is_private() {
                let (e, a) = accountant.epsilon(opts.delta);
                format!(" eps={:.3}(a={})", e, a)
            } else {
                String::new()
            };
            crate::log_info!(
                "step {:>5} loss={:.4} ema={:.4}{}",
                step + 1,
                out.loss,
                metrics.loss_ema.get().unwrap_or(0.0),
                eps_str
            );
        }

        if let (Some(fwd), Some(eds)) = (&fwd_exe, &eval_ds) {
            if (step + 1) % opts.eval_every == 0 {
                let (l, a) = evaluate(fwd.as_ref(), &mut params, eds, &cfg)?;
                metrics.record_eval(step + 1, l, a);
                crate::log_info!(
                    "eval  step {:>5} loss={:.4} acc={:.3}",
                    step + 1,
                    l,
                    a
                );
            }
        }
    }

    // --- checkpoint ----------------------------------------------------
    if let Some(dir) = &opts.checkpoint_dir {
        super::checkpoint::save(
            dir,
            &super::checkpoint::CheckpointMeta {
                config: cfg.name.clone(),
                method: opts.method.name().into(),
                step: opts.steps,
                sampling_rate: q,
                sigma,
                clip: opts.clip,
                seed: opts.seed,
            },
            &params,
        )?;
        crate::log_info!("checkpoint written to {}", dir.display());
    }

    let epsilon = if opts.method.is_private() {
        Some(accountant.epsilon(opts.delta))
    } else {
        None
    };
    let mean_step_ms = metrics
        .step_summary()
        .map(|s| s.mean * 1e3)
        .unwrap_or(0.0);
    Ok(TrainReport {
        config: cfg.name,
        method: opts.method,
        steps: opts.steps,
        final_loss_ema: metrics.loss_ema.get().unwrap_or(f64::NAN),
        losses: metrics.losses.clone(),
        eval_points: metrics.eval_points.clone(),
        epsilon,
        sigma,
        sampling_rate: q,
        wall_seconds: metrics.wall_seconds(),
        mean_step_ms,
        metrics_json: metrics.to_json(),
        peak_rss_bytes: crate::util::peak_rss_bytes(),
    })
}

/// Stage a batch of examples into the upload buffers.
pub fn stage_batch(ds: &Dataset, batch: &[usize], stage: &mut BatchStage) {
    match ds.features {
        Features::F32(_) => {
            data::gather_batch_f32(ds, batch, &mut stage.feat_f32, &mut stage.labels)
        }
        Features::I32(_) => {
            data::gather_batch_i32(ds, batch, &mut stage.feat_i32, &mut stage.labels)
        }
    }
}

/// Run the fwd step over the eval set; returns (mean loss, accuracy).
///
/// The staging buffers come from `BatchStage::for_config` — the same
/// constructor every other execution path uses — rather than a
/// hand-built duplicate that could drift from the config's shapes. An
/// eval set smaller than one batch is a hard error: it would yield
/// zero batches and a silent NaN loss/accuracy.
///
/// Accuracy is integer-exact: the fwd step reports the
/// correct-prediction *count* (`u32`), summed here in `u64` and
/// divided once by the number of evaluated examples — no float
/// accumulation of counts.
pub fn evaluate(
    fwd: &dyn StepFn,
    params: &mut ParamStore,
    eval_ds: &Dataset,
    cfg: &crate::runtime::ConfigSpec,
) -> Result<(f32, f32)> {
    let tau = cfg.batch;
    anyhow::ensure!(
        eval_ds.n >= tau,
        "eval set holds {} examples but config {} evaluates in full \
         batches of {tau}; supply at least one batch",
        eval_ds.n,
        cfg.name
    );
    anyhow::ensure!(
        eval_ds.example_len() * cfg.batch == cfg.input_elems(),
        "eval dataset example shape {:?} does not match config {}",
        eval_ds.shape,
        cfg.name
    );
    let n_batches = eval_ds.n / tau;
    let mut stage = BatchStage::for_config(cfg);
    let mut out = crate::runtime::StepOut::for_config(cfg);
    let mut loss_sum = 0.0f32;
    let mut correct_sum = 0u64;
    for b in 0..n_batches {
        let batch: Vec<usize> = (b * tau..(b + 1) * tau).collect();
        stage_batch(eval_ds, &batch, &mut stage);
        fwd.run_into(params, &stage, None, &mut out)?;
        loss_sum += out.loss;
        correct_sum += u64::from(out.correct.unwrap_or(0));
    }
    Ok((
        loss_sum / n_batches as f32,
        correct_sum as f32 / (n_batches * tau) as f32,
    ))
}
