//! Memory model for the paper's Sec 6.7 experiment ("largest batch
//! size before running out of memory").
//!
//! On the CPU backend nothing OOMs at these scales, so the experiment
//! is reproduced two ways (DESIGN.md §5):
//!   1. an *analytic* per-method byte model driven by the manifest's
//!      parameter and activation footprints, and
//!   2. the real peak RSS (VmHWM) measured around actual runs.
//!
//! Model (f32 = 4 bytes; P = param elems, A = activation elems per
//! example, I = input elems per example, tau = batch):
//!
//!   nonprivate:  8P + 4*tau*(A + I)            params+grads, one fwd/bwd
//!   reweight:    8P + 4*tau*(1.35*A + I) + 8*tau
//!                 (taps + recorded inputs retained for the norm pass;
//!                  1.35 calibrated to the paper's ~25-33% overhead)
//!   multiloss:   8P + 4*tau*(A + I) + 4*tau*P  per-example grads live!
//!   nxbp:        8P + 4*(A + tau*I)            one example in flight
//!
//! The model reproduces the paper's qualitative result: max batch
//! ordering nonprivate > reweight >> multiloss, nxbp ~ flat.

use crate::runtime::ConfigSpec;

pub const BYTES_F32: u64 = 4;

/// Footprints of one model family, read from the manifest.
#[derive(Debug, Clone, Copy)]
pub struct Footprint {
    /// total parameter elements
    pub p: u64,
    /// activation (pre-activation tap) elements per example
    pub a: u64,
    /// input elements per example
    pub i: u64,
}

impl Footprint {
    pub fn of(cfg: &ConfigSpec, act_elems_per_example: u64) -> Footprint {
        Footprint {
            p: cfg.param_elems() as u64,
            a: act_elems_per_example,
            i: (cfg.input_elems() / cfg.batch) as u64,
        }
    }
}

/// Reweight's activation multiplier (taps + recorded layer inputs).
pub const REWEIGHT_ACT_FACTOR: f64 = 1.35;

/// Estimated bytes for one training step of each method.
pub fn step_bytes(method: &str, fp: Footprint, tau: u64) -> u64 {
    let base = 2 * fp.p * BYTES_F32; // params + gradient
    match method {
        "nonprivate" => base + BYTES_F32 * tau * (fp.a + fp.i),
        "reweight" | "reweight_pallas" | "reweight_gram" => {
            base + BYTES_F32 * tau * ((REWEIGHT_ACT_FACTOR * fp.a as f64) as u64 + fp.i)
                + 2 * BYTES_F32 * tau
        }
        "multiloss" => {
            base + BYTES_F32 * tau * (fp.a + fp.i) + BYTES_F32 * tau * fp.p
        }
        "nxbp" => base + BYTES_F32 * (fp.a + tau * fp.i),
        other => panic!("unknown method {other}"),
    }
}

/// Largest batch that fits in `budget` bytes (0 if even tau=1 does
/// not fit). nxbp grows only by the staged input, so it supports far
/// larger batches — matching the paper's observation.
pub fn max_batch(method: &str, fp: Footprint, budget: u64) -> u64 {
    // step_bytes is monotone in tau: exponential probe + bisect
    if step_bytes(method, fp, 1) > budget {
        return 0;
    }
    let mut hi = 1u64;
    while step_bytes(method, fp, hi) <= budget {
        hi *= 2;
        if hi > 1 << 40 {
            return hi;
        }
    }
    let mut lo = hi / 2;
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        if step_bytes(method, fp, mid) <= budget {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;

    /// ResNet101-flavoured footprint: 44M params, big activations.
    fn resnet101ish() -> Footprint {
        Footprint { p: 44_000_000, a: 60_000_000, i: 3 * 256 * 256 }
    }

    #[test]
    fn ordering_matches_paper() {
        // paper Sec 6.7: nonprivate failed first at 48, reweight at 36,
        // multiloss at 18; nxbp basically unaffected.
        let fp = resnet101ish();
        let budget = 11 * 1024 * 1024 * 1024; // 1080 Ti: 11 GiB
        let non = max_batch("nonprivate", fp, budget);
        let rw = max_batch("reweight", fp, budget);
        let ml = max_batch("multiloss", fp, budget);
        let nx = max_batch("nxbp", fp, budget);
        assert!(non > rw, "nonprivate {non} vs reweight {rw}");
        assert!(rw > ml, "reweight {rw} vs multiloss {ml}");
        assert!(nx > non, "nxbp {nx} should dwarf nonprivate {non}");
        // reweight overhead vs nonprivate is ~25-35%, not 2x
        let overhead = (non as f64 - rw as f64) / non as f64;
        assert!(
            (0.15..=0.45).contains(&overhead),
            "overhead {overhead} (non={non}, rw={rw})"
        );
    }

    #[test]
    fn multiloss_collapses_with_many_params() {
        // per-example gradient materialization: tau * P dominates
        let fp = Footprint { p: 100_000_000, a: 1_000_000, i: 1000 };
        let budget = 16 * 1024 * 1024 * 1024;
        assert!(max_batch("multiloss", fp, budget) < 45);
        assert!(max_batch("reweight", fp, budget) > 1000);
    }

    #[test]
    fn monotone_in_budget() {
        let fp = resnet101ish();
        let b1 = max_batch("reweight", fp, 8 << 30);
        let b2 = max_batch("reweight", fp, 16 << 30);
        assert!(b2 >= b1);
    }

    #[test]
    fn zero_when_params_alone_blow_budget() {
        let fp = Footprint { p: 1 << 30, a: 1, i: 1 };
        assert_eq!(max_batch("nonprivate", fp, 1 << 20), 0);
    }

    #[test]
    fn max_batch_is_exact_boundary() {
        let fp = Footprint { p: 1000, a: 5000, i: 784 };
        let budget = 10_000_000;
        let b = max_batch("multiloss", fp, budget);
        assert!(step_bytes("multiloss", fp, b) <= budget);
        assert!(step_bytes("multiloss", fp, b + 1) > budget);
    }
}
