//! `TrainSession`: one training job as an explicit state machine —
//! `new` (init) → `step()` until `finished()` → `maybe_checkpoint` →
//! `finish()`. It owns everything one job needs — params, the
//! `StepOut` arena, optimizer, `ClipPolicy`, RDP accountant, sampler,
//! data source, metrics — so a driver is a thin loop: `train()` runs
//! one session to completion (bitwise-identical to the pre-session
//! monolith), the serve scheduler interleaves `step()` calls from many
//! sessions over the shared rayon pool.
//!
//! The PR-5 resume continuity guards (seed / sampling mode / method /
//! optimizer / lr / clip policy / sigma / rate) live in `new` — they
//! are session invariants: no session can exist whose step stream
//! would diverge from the run it claims to continue.
//!
//! `step()` is the warm path and performs **zero heap allocation**
//! (enforced by `tests/no_alloc.rs`): the batch buffer, the Poisson
//! scratch, the staging buffers, the arena, and the metrics vectors
//! are all pre-sized in `new`. Logging and evaluation — which format
//! and allocate — stay in the drivers.

use super::checkpoint::{self, CheckpointMeta};
use super::methods::GradComputer;
use super::metrics::{Metrics, Phase, PhaseTimer};
use super::trainer::{evaluate, TrainOptions, TrainReport};
use crate::data::{self, DataSource, Dataset, PoissonSampler, ShuffleBatcher, StreamingIdxSource};
use crate::optim::{self, Optimizer};
use crate::privacy::{calibrate_sigma, noise_stddev_for_mean, RdpAccountant};
use crate::runtime::{
    init_params_glorot, Backend, BatchStage, ClipPolicy, ConfigSpec, ParamStore,
    StepFn, StepOut,
};
use anyhow::{Context, Result};
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

/// Batch-index sampler — which rows form the next step's minibatch.
pub(crate) enum Sampler {
    Shuffle(ShuffleBatcher),
    Poisson(PoissonSampler),
}

impl Sampler {
    fn next_batch_into(&mut self, out: &mut Vec<usize>) {
        match self {
            Sampler::Shuffle(b) => b.next_batch_into(out),
            Sampler::Poisson(p) => p.next_batch_into(out),
        }
    }
}

/// One training job's complete state. See the module docs for the
/// lifecycle; construction performs every validation the old
/// monolithic `train()` did, in the same order.
pub struct TrainSession {
    opts: TrainOptions,
    cfg: ConfigSpec,
    policy: ClipPolicy,
    sensitivity: f64,
    q: f64,
    sigma: f64,
    noise_std: f64,
    computer: GradComputer,
    fwd_exe: Option<Arc<dyn StepFn>>,
    eval_ds: Option<Dataset>,
    params: ParamStore,
    opt: Box<dyn Optimizer>,
    accountant: RdpAccountant,
    sampler: Sampler,
    source: Box<dyn DataSource>,
    stage: BatchStage,
    out: StepOut,
    metrics: Metrics,
    /// persistent batch buffer: capacity covers the worst-case draw
    /// (dataset_n for Poisson, tau for shuffle), so `step()` never
    /// reallocates it
    batch: Vec<usize>,
    /// next step index to run; starts at the resume point
    step: u64,
}

impl TrainSession {
    pub fn new(backend: &dyn Backend, opts: &TrainOptions) -> Result<TrainSession> {
        Self::with_parts(backend, opts, None, None)
    }

    /// `new` with injectable parts: an explicit data source (tests,
    /// streaming-vs-memory equivalence) and/or a recycled `StepOut`
    /// arena (the serve scheduler's arena pool — the first compute
    /// re-layouts it, so a pooled arena behaves like a fresh one).
    pub fn with_parts(
        backend: &dyn Backend,
        opts: &TrainOptions,
        source: Option<Box<dyn DataSource>>,
        arena: Option<StepOut>,
    ) -> Result<TrainSession> {
        let cfg = backend.resolve(&opts.config)?;
        let tau = cfg.batch;
        anyhow::ensure!(
            opts.dataset_n >= tau,
            "dataset_n {} < batch {}",
            opts.dataset_n,
            tau
        );
        let q = tau as f64 / opts.dataset_n as f64;

        // --- effective clip policy -----------------------------------
        // Every parametric layer is one (W, b) pair in manifest order,
        // so policy group boundaries index cfg.params in steps of two.
        let n_param_layers = cfg.params.len() / 2;
        let policy = opts
            .policy
            .clone()
            .unwrap_or_else(|| ClipPolicy::hard_global(opts.clip as f32));
        if opts.method.is_private() {
            policy.check(n_param_layers).with_context(|| {
                format!("--clip-policy {policy} on config {}", cfg.name)
            })?;
        }
        // The mechanism's L2 sensitivity — what the Gaussian noise must
        // be calibrated to. The pre-policy flag path keeps the exact
        // f64 clip (bitwise noise-stream continuity); an explicit
        // policy computes C·sqrt(G) (= C for global granularities).
        let sensitivity = match &opts.policy {
            None => opts.clip,
            Some(p) => p.sensitivity(n_param_layers),
        };

        // --- resume: restore params / step counter / accountant ------
        let mut start_step = 0u64;
        let mut resume_init: Option<Vec<f32>> = None;
        // (sampling rate, sigma) the checkpointed steps were run at —
        // what the accountant must re-charge, regardless of the
        // current flags
        let mut resume_charge: Option<(f64, f64)> = None;
        if let Some(dir) = &opts.resume {
            let (meta, flat) = checkpoint::load(dir, &cfg)
                .with_context(|| format!("resuming from {}", dir.display()))?;
            anyhow::ensure!(
                meta.step < opts.steps,
                "checkpoint at {} already covers {} steps and --steps {} is a \
                 total, not an increment — raise --steps to continue training",
                dir.display(),
                meta.step,
                opts.steps
            );
            // Continuity: the replayed sampler and the step-keyed noise
            // stream both derive from the seed, so a silently different
            // seed would diverge from the run being continued.
            anyhow::ensure!(
                opts.seed == meta.seed,
                "resume: checkpoint at {} was trained with --seed {} but this \
                 run uses --seed {} — the replayed batch and noise streams \
                 would diverge from the run being continued",
                dir.display(),
                meta.seed,
                opts.seed
            );
            // Sampling-mode continuity: the replayed sampler AND the
            // RDP re-charge both assume the recorded regime — resuming
            // a Poisson run with shuffle-partition (or vice versa)
            // would silently change both the batch stream and the
            // subsampling assumption the accountant's rate q rests on.
            // A pre-PR5 checkpoint recorded no mode (None): skip the
            // check rather than misread the absence as
            // shuffle-partition.
            if let Some(was_poisson) = meta.poisson {
                anyhow::ensure!(
                    opts.poisson == was_poisson,
                    "resume: checkpoint was trained with {} sampling but this \
                     run uses {} — the replayed batch stream and the \
                     accountant's subsampling assumption would both change \
                     mid-run; {}",
                    if was_poisson { "--poisson" } else { "shuffle-partition" },
                    if opts.poisson { "--poisson" } else { "shuffle-partition" },
                    if was_poisson { "pass --poisson" } else { "drop --poisson" }
                );
            }
            // Method continuity: all private methods agree to ~1e-5
            // but not bitwise, so switching mid-run is not a
            // continuation of the same trajectory (and private/
            // non-private switches would corrupt the epsilon report
            // outright).
            anyhow::ensure!(
                meta.method == opts.method.name(),
                "resume: checkpoint was trained with --method {} but this run \
                 uses --method {} — switch methods only in a fresh run; pass \
                 --method {}",
                meta.method,
                opts.method.name(),
                meta.method
            );
            // Optimizer continuity: the name is validated (a pre-PR5
            // checkpoint records none — skip); optimizer *state* is
            // not checkpointed, so a stateful optimizer restarts its
            // moments — warn loudly rather than silently diverging.
            // With sgd (stateless) a resumed run is bitwise the
            // continuous run.
            if !meta.optimizer.is_empty() {
                anyhow::ensure!(
                    opts.optimizer == meta.optimizer,
                    "resume: checkpoint was trained with --optimizer {} but \
                     this run uses --optimizer {} — switching optimizers \
                     mid-run is not a continuation; pass --optimizer {}",
                    meta.optimizer,
                    opts.optimizer,
                    meta.optimizer
                );
            }
            // Learning-rate continuity (every method): the tail would
            // silently train at a different rate than the recorded
            // steps. A pre-PR5 checkpoint records no lr (0.0): skip.
            if meta.lr > 0.0 {
                anyhow::ensure!(
                    (opts.lr - meta.lr).abs() < 1e-12,
                    "resume: checkpoint records lr={} but this run passes \
                     lr={} — the continuation would train at a different \
                     rate; pass --lr {}",
                    meta.lr,
                    opts.lr,
                    meta.lr
                );
            }
            if opts.optimizer != "sgd" {
                crate::log_info!(
                    "resume: WARNING — optimizer state is not checkpointed; \
                     {} restarts its moment estimates from zero at step {}, \
                     so the continuation is not bitwise identical to an \
                     uninterrupted run (use --optimizer sgd for exact \
                     continuation)",
                    opts.optimizer,
                    meta.step
                );
            }
            if opts.method.is_private() {
                // The checkpoint records ONE (sampling_rate, sigma,
                // clip) for its whole history, so the accountant
                // cannot represent a heterogeneous chain: a later
                // resume of the checkpoint this run writes would
                // re-charge every step at whatever values are current
                // here. Refuse the combinations that would corrupt (or
                // double-count) the recorded privacy spend — or, for
                // clip, silently break the continuation (noise_std and
                // the clipping threshold both derive from it).
                match &meta.clip_policy {
                    // policy-recording checkpoint: the canonical name
                    // is the policy's stable identity — compare it
                    // wholesale
                    Some(rec) => {
                        anyhow::ensure!(
                            *rec == policy.to_string(),
                            "resume: checkpoint records clip policy {} but \
                             this run clips under {} — the threshold \
                             structure and the noise scale would change \
                             mid-run; pass --clip-policy {}",
                            rec,
                            policy,
                            rec
                        );
                    }
                    // pre-policy checkpoint + pre-policy flags: the
                    // recorded bare clip IS the classical global hard
                    // policy — the original continuity check, verbatim
                    None if opts.policy.is_none() => {
                        anyhow::ensure!(
                            (opts.clip - meta.clip).abs() < 1e-12,
                            "resume: checkpoint records clip={} but this run \
                             passes clip={} — the clipping threshold and the \
                             noise scale would both change mid-run; pass \
                             --clip {}",
                            meta.clip,
                            opts.clip,
                            meta.clip
                        );
                    }
                    // pre-policy checkpoint + explicit --clip-policy:
                    // only the classical policy at the recorded
                    // threshold continues the same process (1e-6: the
                    // policy threshold is f32)
                    None => {
                        anyhow::ensure!(
                            policy.is_global_hard()
                                && (policy.clip() as f64 - meta.clip).abs()
                                    < 1e-6,
                            "resume: checkpoint predates clip policies — its \
                             steps ran the classical global hard clip at {} — \
                             but this run passes --clip-policy {}; pass \
                             --clip-policy global:{} (or drop the flag and \
                             pass --clip {})",
                            meta.clip,
                            policy,
                            meta.clip,
                            meta.clip
                        );
                    }
                }
                anyhow::ensure!(
                    opts.target_eps.is_none(),
                    "resume: --target-eps would re-calibrate sigma as if all \
                     {} steps were fresh budget, double-counting the {} \
                     checkpointed steps' spend; pass --sigma explicitly (the \
                     checkpoint records sigma={})",
                    opts.steps,
                    meta.step,
                    meta.sigma
                );
                anyhow::ensure!(
                    (opts.sigma - meta.sigma).abs() < 1e-12,
                    "resume: checkpoint records sigma={} but this run passes \
                     sigma={} — the checkpoint written at the end could only \
                     record one value for the whole history, mis-charging a \
                     later resume; pass --sigma {}",
                    meta.sigma,
                    opts.sigma,
                    meta.sigma
                );
            }
            // The sampling rate fixes both the replayed batch stream
            // (the samplers are seeded over dataset_n) and, for
            // private methods, the accountant's subsampling rate — so
            // it must match for *every* method, not only private ones.
            // Guard on a recorded rate > 0 (a damaged/ancient meta
            // contributes nothing rather than a division by zero in
            // the hint).
            if meta.sampling_rate > 0.0 {
                anyhow::ensure!(
                    (q - meta.sampling_rate).abs() < 1e-12,
                    "resume: checkpoint records sampling rate q={} but --n {} \
                     gives q={} — the replayed batch stream (and any privacy \
                     accounting) must cover the whole history at one rate; \
                     pass --n {}",
                    meta.sampling_rate,
                    opts.dataset_n,
                    q,
                    (tau as f64 / meta.sampling_rate).round()
                );
            }
            crate::log_info!(
                "resume: {} at step {} (q={:.4}, sigma={:.3})",
                dir.display(),
                meta.step,
                meta.sampling_rate,
                meta.sigma
            );
            start_step = meta.step;
            resume_charge = Some((meta.sampling_rate, meta.sigma));
            resume_init = Some(flat);
        }

        // --- eval set size -------------------------------------------
        let eval_n = match opts.eval_n {
            Some(n) => {
                anyhow::ensure!(
                    opts.eval_every > 0,
                    "--eval-n has no effect without --eval-every; set an \
                     evaluation interval or drop --eval-n"
                );
                anyhow::ensure!(
                    n >= tau && n % tau == 0,
                    "--eval-n {n} must be a positive multiple of config {}'s \
                     batch {tau} — evaluation runs in full batches and would \
                     silently drop the remainder examples",
                    cfg.name
                );
                n
            }
            None => tau * 4,
        };

        // --- noise calibration (Alg 1, line 1) -----------------------
        let sigma = match opts.target_eps {
            Some(eps) if opts.method.is_private() => {
                let s = calibrate_sigma(q, opts.steps, eps, opts.delta)
                    .context("target epsilon infeasible at sigma<=200")?;
                crate::log_info!(
                    "calibrated sigma={:.3} for eps<={} delta={} over {} steps (q={:.4})",
                    s, eps, opts.delta, opts.steps, q
                );
                s
            }
            _ => opts.sigma,
        };

        // --- data ----------------------------------------------------
        let source: Box<dyn DataSource> = match source {
            Some(s) => s,
            None => match opts.stream_chunk {
                Some(chunk) => {
                    Box::new(StreamingIdxSource::open_for_dataset(&cfg.dataset, chunk)?)
                }
                None => {
                    Box::new(data::load_dataset(&cfg.dataset, opts.dataset_n, opts.seed)?)
                }
            },
        };
        anyhow::ensure!(
            source.len() >= opts.dataset_n,
            "data source {:?} holds {} examples but the run samples over \
             n={} — the sampler would draw rows past the end",
            source.name(),
            source.len(),
            opts.dataset_n
        );
        // dtype compatibility: an f32 stage accepts f32 sources
        // directly and i32 token sources through the widening gather
        // (`fill_batch` stages ids as f32 — the transformer path); an
        // i32 stage accepts only i32 sources.
        anyhow::ensure!(
            source.example_len() * tau == cfg.input_elems()
                && (cfg.input_dtype == "f32" || !source.is_f32()),
            "data source {:?} example shape ({} {} elements) does not match \
             config {}",
            source.name(),
            source.example_len(),
            if source.is_f32() { "f32" } else { "i32" },
            cfg.name
        );
        let eval_ds = if opts.eval_every > 0 {
            Some(data::load_dataset(&cfg.dataset, eval_n, opts.seed + 1)?)
        } else {
            None
        };

        // --- executables / params / optimizer ------------------------
        let computer = GradComputer::new(backend, &opts.config, opts.method)?;
        let fwd_exe = if opts.eval_every > 0 {
            Some(backend.load(&cfg, "fwd")?)
        } else {
            None
        };
        let init = match resume_init {
            Some(flat) => flat,
            None => init_params_glorot(&cfg, opts.seed),
        };
        let params = ParamStore::new(&cfg, Some(&init))?;
        let opt = optim::by_name(&opts.optimizer, opts.lr)?;
        let mut accountant = RdpAccountant::new();
        if opts.method.is_private() && start_step > 0 {
            // re-charge the checkpointed steps at their *recorded* rate
            // and sigma: budget already spent cannot change just
            // because the resumed run passes different flags
            let (q0, s0) = resume_charge.expect("resume meta");
            accountant.steps(q0, s0, start_step);
        }
        let mut sampler = if opts.poisson {
            Sampler::Poisson(PoissonSampler::new(opts.dataset_n, tau, opts.seed))
        } else {
            Sampler::Shuffle(ShuffleBatcher::new(opts.dataset_n, tau, opts.seed))
        };
        // the batch buffer is reused every step; a Poisson raw draw
        // can reach dataset_n rows, so reserve for the worst case —
        // a later large draw must not reallocate mid-run
        let mut batch =
            Vec::with_capacity(if opts.poisson { opts.dataset_n } else { tau });
        // replay the sampler to the resume point, so a resumed run
        // draws the same batch sequence the continuous run would have
        for _ in 0..start_step {
            sampler.next_batch_into(&mut batch);
        }

        let stage = BatchStage::for_config(&cfg);
        // one output arena for the whole run: the step resets it each
        // call, so the warm loop performs zero per-step heap allocation
        let out = match arena {
            Some(a) => a,
            None => computer.new_out(),
        };
        let mut metrics = Metrics::new();
        metrics.reserve_steps((opts.steps - start_step) as usize);
        let noise_std = noise_stddev_for_mean(sigma, sensitivity, tau);

        crate::log_info!(
            "train {} method={} steps={} tau={} q={:.4} sigma={:.3} policy={} sens={} opt={}",
            cfg.name, opts.method.name(), opts.steps, tau, q, sigma, policy, sensitivity, opts.optimizer
        );

        Ok(TrainSession {
            opts: opts.clone(),
            cfg,
            policy,
            sensitivity,
            q,
            sigma,
            noise_std,
            computer,
            fwd_exe,
            eval_ds,
            params,
            opt,
            accountant,
            sampler,
            source,
            stage,
            out,
            metrics,
            batch,
            step: start_step,
        })
    }

    /// Run one training step (Alg 1 lines 2-16 for one iteration):
    /// sample → gather → compute clipped gradients → noise + account →
    /// optimizer update. Returns the step's loss. Allocation-free once
    /// warm; panics in debug builds if called after `finished()`.
    pub fn step(&mut self) -> Result<f32> {
        debug_assert!(!self.finished(), "step() on a finished session");
        let t_step = Instant::now();

        let t = PhaseTimer::start();
        self.sampler.next_batch_into(&mut self.batch);
        self.source.fill_batch(&self.batch, &mut self.stage)?;
        t.stop(&mut self.metrics, Phase::Gather);

        let t = PhaseTimer::start();
        self.computer
            .compute(&mut self.params, &self.stage, &self.policy, &mut self.out)?;
        t.stop(&mut self.metrics, Phase::Execute);
        if let Some((gn, ng)) = self.out.group_norms() {
            self.metrics.record_group_norms(gn, ng);
        }

        if self.opts.method.is_private() {
            let t = PhaseTimer::start();
            // §Perf L3 iteration 3: parallel chunked polar-method noise
            // — one flat pass over the arena's gradient buffer, keyed
            // by (seed, step) so the stream is schedule-independent
            crate::rng::add_noise_parallel(
                self.out.grads.flat_mut(),
                self.noise_std,
                self.opts.seed,
                self.step,
            );
            // poisoning guard (debug/test profile only): the noised
            // gradient is the last value before the optimizer — a
            // NaN/Inf here must fail at the source, not as a drifted
            // loss many steps later
            crate::runtime::store::debug_assert_finite(
                self.out.grads.flat(),
                "session noise path (post add_noise_parallel)",
            );
            self.accountant.step(self.q, self.sigma);
            t.stop(&mut self.metrics, Phase::Noise);
        }

        let t = PhaseTimer::start();
        self.opt.step(&mut self.params.host, &self.out.grads);
        self.params.mark_dirty();
        t.stop(&mut self.metrics, Phase::Update);

        self.metrics
            .record_step(t_step.elapsed().as_secs_f64(), self.out.loss);
        self.step += 1;
        Ok(self.out.loss)
    }

    /// Steps completed so far (== the next step's index).
    pub fn step_index(&self) -> u64 {
        self.step
    }

    pub fn total_steps(&self) -> u64 {
        self.opts.steps
    }

    pub fn finished(&self) -> bool {
        self.step >= self.opts.steps
    }

    pub fn is_private(&self) -> bool {
        self.opts.method.is_private()
    }

    pub fn sampling_rate(&self) -> f64 {
        self.q
    }

    pub fn sigma(&self) -> f64 {
        self.sigma
    }

    pub fn delta(&self) -> f64 {
        self.opts.delta
    }

    pub fn config_name(&self) -> &str {
        &self.cfg.name
    }

    pub fn loss_ema(&self) -> f64 {
        self.metrics.loss_ema.get().unwrap_or(0.0)
    }

    /// Current privacy spend `(epsilon, best RDP order)`; `None` for
    /// non-private methods.
    pub fn epsilon(&self) -> Option<(f64, u32)> {
        if self.opts.method.is_private() {
            Some(self.accountant.epsilon(self.opts.delta))
        } else {
            None
        }
    }

    /// Clone of the accountant — the serve ledger's lookahead probe
    /// starts from exactly the session's charged state (including any
    /// resume re-charge).
    pub fn accountant_clone(&self) -> RdpAccountant {
        self.accountant.clone()
    }

    /// Whether the driver should evaluate now: only true immediately
    /// after a step that lands on the eval interval.
    pub fn eval_due(&self) -> bool {
        self.opts.eval_every > 0
            && self.fwd_exe.is_some()
            && self.eval_ds.is_some()
            && self.step > 0
            && self.step % self.opts.eval_every == 0
    }

    /// Run evaluation over the held-out set; records the point in the
    /// session metrics and returns `(mean loss, accuracy)`. Allocates
    /// (fresh staging buffers) — drivers call it off the hot path.
    pub fn run_eval(&mut self) -> Result<(f32, f32)> {
        let fwd = self.fwd_exe.as_ref().expect("eval executable");
        let eds = self.eval_ds.as_ref().expect("eval dataset");
        let (l, a) = evaluate(fwd.as_ref(), &mut self.params, eds, &self.cfg)?;
        self.metrics.record_eval(self.step, l, a);
        Ok((l, a))
    }

    pub fn checkpoint_dir(&self) -> Option<&Path> {
        self.opts.checkpoint_dir.as_deref()
    }

    /// The checkpoint metadata for the session's *current* state —
    /// `step` is the true completed count, so a mid-run (graceful-
    /// stop) checkpoint is a valid resume point under the same guards.
    pub fn checkpoint_meta(&self) -> CheckpointMeta {
        CheckpointMeta {
            config: self.cfg.name.clone(),
            method: self.opts.method.name().into(),
            optimizer: self.opts.optimizer.clone(),
            step: self.step,
            sampling_rate: self.q,
            sigma: self.sigma,
            clip: match &self.opts.policy {
                Some(p) => p.clip() as f64,
                None => self.opts.clip,
            },
            lr: self.opts.lr,
            seed: self.opts.seed,
            poisson: Some(self.opts.poisson),
            clip_policy: Some(self.policy.to_string()),
        }
    }

    /// Snapshot of the host parameters — what the background
    /// checkpoint writer ships across its queue.
    pub fn params_snapshot(&self) -> Vec<Vec<f32>> {
        self.params.host.clone()
    }

    /// Synchronously checkpoint to `opts.checkpoint_dir`, if set.
    /// Returns whether a checkpoint was written.
    pub fn maybe_checkpoint(&self) -> Result<bool> {
        let Some(dir) = &self.opts.checkpoint_dir else {
            return Ok(false);
        };
        checkpoint::save(dir, &self.checkpoint_meta(), &self.params)?;
        Ok(true)
    }

    /// Consume the session into its report, releasing the arena for
    /// reuse (the serve scheduler returns it to the pool).
    pub fn finish(self) -> (TrainReport, StepOut) {
        let epsilon = if self.opts.method.is_private() {
            Some(self.accountant.epsilon(self.opts.delta))
        } else {
            None
        };
        let mean_step_ms = self
            .metrics
            .step_summary()
            .map(|s| s.mean * 1e3)
            .unwrap_or(0.0);
        let report = TrainReport {
            config: self.cfg.name,
            method: self.opts.method,
            steps: self.step,
            final_loss_ema: self.metrics.loss_ema.get().unwrap_or(f64::NAN),
            losses: self.metrics.losses.clone(),
            eval_points: self.metrics.eval_points.clone(),
            epsilon,
            sigma: self.sigma,
            policy: self.policy.to_string(),
            sensitivity: self.sensitivity,
            sampling_rate: self.q,
            wall_seconds: self.metrics.wall_seconds(),
            mean_step_ms,
            metrics_json: self.metrics.to_json(),
            peak_rss_bytes: crate::util::peak_rss_bytes(),
        };
        (report, self.out)
    }
}
