//! fastclip CLI — the leader entrypoint.
//!
//! Subcommands:
//!   train         run DP training on one config (paper Alg 1)
//!   serve         interleave many training jobs from a jobs file
//!   bench-step    time one (config, method) step
//!   bench-matrix  time a config x method matrix, write BENCH_<backend>.json
//!   accountant    RDP accounting / sigma calibration queries
//!   memory        Sec 6.7 memory model table for a config
//!   inspect       list manifest configs and artifacts
//!
//! Every compute subcommand takes `--backend native|pjrt|auto`
//! (default auto: PJRT when compiled in and artifacts exist, native
//! otherwise).

use anyhow::{Context, Result};
use fastclip::cli::Args;
use fastclip::coordinator::{memory, train, ClipMethod, GradComputer, TrainOptions};
use fastclip::privacy;
use fastclip::runtime::{
    backend_by_name, Backend, BatchStage, ClipPolicy, ModelSpec, ParamStore,
    SpecKey,
};
use fastclip::util::json::Json;
use fastclip::{log_info, util};

fn main() {
    fastclip::util::logging::level_from_env();
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.subcommand.as_str() {
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "bench-step" => cmd_bench_step(&args),
        "bench-matrix" => cmd_bench_matrix(&args),
        "bench-history" => cmd_bench_history(&args),
        "accountant" => cmd_accountant(&args),
        "memory" => cmd_memory(&args),
        "inspect" => cmd_inspect(&args),
        "" | "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            anyhow::bail!("unknown subcommand {other:?}")
        }
    }
}

fn print_help() {
    // generated from ClipMethod::all() and ClipPolicy::kinds(), so
    // neither list can drift from what the binary actually accepts
    let methods = ClipMethod::names().join("|");
    let policies = ClipPolicy::help_grammar();
    println!(
        r#"fastclip — DP deep learning with fast per-example gradient clipping

USAGE: fastclip <subcommand> [flags]

Configs are referenced either by name (--config) — a builtin preset
like mlp2_mnist_b32, a full spec key, or an artifacts-manifest entry —
or composed from parts (--model + --dataset + --batch):

  --config "mlp(depth=4,width=512)@cifar10:b256"
  --model "cnn(depth=2,k=3,s=1,pad=1,ch=8-16)" --dataset mnist --batch 48

Spec keys synthesize on demand (native backend; any depth/width/
kernel/stride/batch); the pjrt backend is manifest-bound.

  train       --config NAME | --model SPEC [--dataset D] [--batch N]
              [--method {methods}]
              [--steps N] [--n DATASET_SIZE]
              [--lr F] [--clip F | --clip-policy P]
              [--sigma F | --target-eps F] [--delta F]
              [--optimizer adam|sgd] [--seed N] [--eval-every N]
              [--eval-n N] [--poisson] [--checkpoint DIR] [--resume DIR]
              [--json]
              --clip-policy P selects clipping granularity x nu
              formula: {policies}.
              Noise is calibrated to the policy's true L2 sensitivity
              (C*sqrt(G) for grouped granularities). Grouped/automatic
              policies need --backend native. --clip F is shorthand
              for global:F (the paper's classical hard clip).
              --resume restores params/step/accountant state from a
              checkpoint dir; --steps stays the *total* step count,
              and the run must continue the same process (seed,
              sampling mode, method, optimizer, lr, sampling rate —
              and, for private methods, clip policy and sigma — must
              match the checkpoint; --target-eps is rejected).
              --eval-n sizes the eval set (default 4 batches; must be
              a multiple of the config batch — eval runs full batches)
              --stream-chunk N streams the dataset from its IDX files
              in N-row chunks instead of loading it fully into memory
              (bitwise-identical batches; bounded residency)
              Ctrl-C checkpoints at the next step boundary and exits
              cleanly; a second Ctrl-C force-exits
  serve       --jobs FILE [--max-concurrent N] [--json]
              interleaves TrainSession steps from many concurrent jobs
              (round-robin; each job bitwise-identical to a solo run).
              FILE is {{"max_concurrent": N, "jobs": [{{...}}, ...]}} —
              per-job keys mirror the train flags (config, method,
              steps, n, lr, clip|clip_policy, sigma, delta, optimizer,
              seed, eval_every, eval_n, log_every, poisson, checkpoint,
              stream_chunk) plus "target_eps": a hard epsilon budget —
              the scheduler refuses any step that would exceed it and
              retires the job with a final checkpoint. See
              examples/serve_jobs.json
  bench-step  (--config NAME | --model SPEC [--dataset D] [--batch N])
              --method M [--iters N] [--clip-policy P]
  bench-matrix [--configs NAME,NAME,...] [--methods M,M,...] [--smoke]
              [--model SPEC [--dataset D] [--batches 16..512]]
              [--out FILE] [--check] [--history FILE]
              times every (config, method) step and writes the
              BENCH_<backend>.json trajectory artifact; --model with
              --batches sweeps one spec across batch sizes (doubling
              LO..HI or a comma list) and prints the speedup-vs-batch
              curve; --check fails unless reweight beats nxbp on every
              batch-128 config and (on the native backend) the warm
              reweight step path ran with zero heap allocations;
              --history appends a compact record (p50s +
              steps_alloc_free) to a jsonl trajectory and fails on a
              >25% reweight@b128 p50 step-time regression versus the
              median of that file's recent entries
  bench-history [--file BENCH_history.jsonl] [--out FILE.md]
              renders the jsonl trajectory as a markdown table with an
              ASCII sparkline per config (stdout without --out)
  accountant  --q F --sigma F --steps N [--delta F]
              | --calibrate --q F --steps N --eps F [--delta F]
  memory      (--config NAME | --model SPEC ...) [--budget-gib F]
  inspect     [--config NAME | --model SPEC ...] [--tag TAG]

All compute subcommands accept --backend native|pjrt|auto (default
auto). The native backend runs the builtin presets and any spec key in
pure Rust — no Python, no artifacts. The pjrt backend (requires
building with --features pjrt) executes AOT HLO artifacts from
$FASTCLIP_ARTIFACTS (default ./artifacts; build with `make artifacts`)."#
    );
}

/// The config reference from the flags: `--config NAME` (preset,
/// manifest entry, or full `model@dataset:bN` spec key), or the
/// composed form `--model SPEC [--dataset D] [--batch N]`. The
/// composed form is canonicalized through `SpecKey`, so checkpoints
/// and bench records key on one stable spelling.
fn config_ref(args: &Args) -> Result<String> {
    if let Some(model) = args.str_opt("model") {
        anyhow::ensure!(
            args.str_opt("config").is_none(),
            "--model and --config are mutually exclusive; --model composes \
             a spec key from --dataset/--batch, --config names one directly"
        );
        let spec = ModelSpec::parse(model)?;
        let dataset = args.str_or("dataset", "mnist");
        let batch = args.usize_or("batch", 32)?;
        Ok(SpecKey::new(spec, &dataset, batch).to_string())
    } else {
        // --dataset/--batch only compose with --model; silently
        // ignoring them here would run a different batch (and a
        // different RDP sampling ratio) than the user asked for
        for flag in ["dataset", "batch"] {
            anyhow::ensure!(
                args.str_opt(flag).is_none(),
                "--{flag} has no effect with --config (the config names its \
                 dataset and batch); use --model to compose a spec, or put \
                 it in the spec key (model@dataset:bN)"
            );
        }
        Ok(args.require("config")?.to_string())
    }
}

fn backend(args: &Args) -> Result<Box<dyn Backend>> {
    let b = backend_by_name(args.str_opt("backend")).with_context(|| {
        format!(
            "selecting backend {:?}",
            args.str_or("backend", "auto")
        )
    })?;
    log_info!("backend: {}", b.name());
    Ok(b)
}

/// Parse `--clip-policy`, if present. `None` keeps the classical
/// global hard clip at `--clip` (and, in the trainer, the exact
/// pre-policy noise stream).
fn clip_policy_opt(args: &Args) -> Result<Option<ClipPolicy>> {
    args.str_opt("clip-policy")
        .map(|v| {
            ClipPolicy::parse(v)
                .with_context(|| format!("parsing --clip-policy {v:?}"))
        })
        .transpose()
}

fn cmd_train(args: &Args) -> Result<()> {
    let policy = clip_policy_opt(args)?;
    anyhow::ensure!(
        policy.is_none() || args.str_opt("clip").is_none(),
        "--clip and --clip-policy are mutually exclusive; --clip F is \
         shorthand for --clip-policy global:F (the policy carries its \
         own clip threshold)"
    );
    let opts = TrainOptions {
        config: config_ref(args)?,
        method: ClipMethod::parse(&args.str_or("method", "reweight"))?,
        steps: args.u64_or("steps", 100)?,
        dataset_n: args.usize_or("n", 2048)?,
        lr: args.f64_or("lr", 1e-3)?,
        clip: args.f64_or("clip", 1.0)?,
        policy,
        sigma: args.f64_or("sigma", 1.1)?,
        target_eps: args.str_opt("target-eps").map(|v| v.parse()).transpose()?,
        delta: args.f64_or("delta", 1e-5)?,
        optimizer: args.str_or("optimizer", "adam"),
        seed: args.u64_or("seed", 0)?,
        eval_every: args.u64_or("eval-every", 0)?,
        eval_n: match args.str_opt("eval-n") {
            Some(v) => Some(v.parse().with_context(|| {
                format!("--eval-n expects an integer, got {v:?}")
            })?),
            None => None,
        },
        log_every: args.u64_or("log-every", 20)?,
        checkpoint_dir: args.str_opt("checkpoint").map(Into::into),
        resume: args.str_opt("resume").map(Into::into),
        poisson: args.bool("poisson"),
        // Ctrl-C breaks the loop at the next step boundary and writes
        // the final checkpoint; a second Ctrl-C force-exits
        stop: Some(fastclip::util::signal::install_sigint()),
        stream_chunk: match args.str_opt("stream-chunk") {
            Some(v) => Some(v.parse().with_context(|| {
                format!("--stream-chunk expects an integer, got {v:?}")
            })?),
            None => None,
        },
    };
    let backend = backend(args)?;
    let report = train(backend.as_ref(), &opts)?;
    if args.bool("json") {
        let mut j = report.metrics_json.clone();
        j.set("config", report.config.as_str().into());
        j.set("method", report.method.name().into());
        if let Some((eps, order)) = report.epsilon {
            j.set("epsilon", eps.into());
            j.set("rdp_order", (order as usize).into());
        }
        println!("{}", j.to_string_pretty());
    } else {
        println!(
            "done: {} steps, loss(ema)={:.4}, mean step {:.2} ms, wall {:.1}s",
            report.steps, report.final_loss_ema, report.mean_step_ms, report.wall_seconds
        );
        if let Some((eps, order)) = report.epsilon {
            println!(
                "privacy: ({:.3}, {:.0e})-DP via RDP order {}",
                eps,
                opts_delta(args)?,
                order
            );
        }
        if let Some(rss) = report.peak_rss_bytes {
            println!("peak RSS: {}", util::fmt_bytes(rss));
        }
        if args.bool("profile") {
            println!("\nstep phase breakdown:");
            let phases = report.metrics_json.get("phases");
            for name in ["gather", "execute", "noise", "update"] {
                let p = phases.get(name);
                println!(
                    "  {:<8} {:>8.1} ms total  {:>5.1}%",
                    name,
                    p.get("seconds").as_f64().unwrap_or(0.0) * 1e3,
                    p.get("share").as_f64().unwrap_or(0.0) * 100.0
                );
            }
        }
    }
    Ok(())
}

fn opts_delta(args: &Args) -> Result<f64> {
    args.f64_or("delta", 1e-5)
}

fn cmd_serve(args: &Args) -> Result<()> {
    use fastclip::coordinator::{parse_jobs, serve, ServeOptions};
    let path = args.require("jobs")?;
    let text = util::read_file(std::path::Path::new(path))?;
    let (jobs, file_maxc) = parse_jobs(&text)
        .with_context(|| format!("parsing jobs file {path:?}"))?;
    let max_concurrent = match args.str_opt("max-concurrent") {
        Some(v) => v.parse().with_context(|| {
            format!("--max-concurrent expects an integer, got {v:?}")
        })?,
        None => file_maxc,
    };
    let backend = backend(args)?;
    let sopts = ServeOptions {
        max_concurrent,
        // first Ctrl-C checkpoints every live job and skips pending
        // ones; a second Ctrl-C force-exits
        stop: Some(fastclip::util::signal::install_sigint()),
    };
    let report = serve(backend.as_ref(), &jobs, &sopts)?;
    if args.bool("json") {
        let mut arr = Vec::new();
        for o in &report.outcomes {
            let mut j = Json::obj();
            j.set("name", o.name.as_str().into());
            j.set("steps", (o.report.steps as usize).into());
            j.set("budget_stopped", o.budget_stopped.into());
            j.set("loss_ema", o.report.final_loss_ema.into());
            if let Some((e, a)) = o.report.epsilon {
                j.set("epsilon", e.into());
                j.set("rdp_order", (a as usize).into());
            }
            arr.push(j);
        }
        let mut top = Json::obj();
        top.set("stopped_early", report.stopped_early.into());
        top.set("jobs", Json::Arr(arr));
        println!("{}", top.to_string_pretty());
    } else {
        println!("| job | steps | loss(ema) | epsilon | budget stop |");
        println!("|---|---:|---:|---:|---|");
        for o in &report.outcomes {
            let eps = o
                .report
                .epsilon
                .map(|(e, _)| format!("{e:.3}"))
                .unwrap_or_else(|| "-".into());
            println!(
                "| {} | {} | {:.4} | {} | {} |",
                o.name,
                o.report.steps,
                o.report.final_loss_ema,
                eps,
                if o.budget_stopped { "yes" } else { "no" }
            );
        }
        if report.stopped_early {
            println!("stopped early (interrupt): pending jobs were skipped");
        }
    }
    Ok(())
}

fn cmd_bench_step(args: &Args) -> Result<()> {
    let config = config_ref(args)?;
    let method = ClipMethod::parse(&args.str_or("method", "reweight"))?;
    let iters = args.usize_or("iters", 10)?;
    let policy = match clip_policy_opt(args)? {
        Some(p) => p,
        None => ClipPolicy::hard_global(args.f64_or("clip", 1.0)? as f32),
    };
    let backend = backend(args)?;
    let cfg = backend.resolve(&config)?;
    let mut computer = GradComputer::new(backend.as_ref(), &config, method)?;
    let ds = fastclip::data::load_dataset(&cfg.dataset, cfg.batch.max(256), 0)?;
    let mut stage = BatchStage::for_config(&cfg);
    let batch: Vec<usize> = (0..cfg.batch).collect();
    fastclip::coordinator::stage_batch(&ds, &batch, &mut stage);
    let mut params = ParamStore::new(
        &cfg,
        Some(&fastclip::runtime::init_params_glorot(&cfg, 0)),
    )?;
    // one arena for every timed step (the trainer's shape)
    let mut out = computer.new_out();
    // warmup (includes compile)
    computer.compute(&mut params, &stage, &policy, &mut out)?;
    log_info!("compile took {:.0} ms", computer.compile_ms());
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = std::time::Instant::now();
        computer.compute(&mut params, &stage, &policy, &mut out)?;
        times.push(t.elapsed().as_secs_f64());
    }
    let s = fastclip::util::stats::Summary::of(&times);
    println!(
        "{config} {} [{policy}]: mean {:.3} ms, p50 {:.3} ms, p95 {:.3} ms over {iters} iters",
        method.name(),
        s.mean * 1e3,
        s.p50 * 1e3,
        s.p95 * 1e3
    );
    Ok(())
}

fn cmd_bench_matrix(args: &Args) -> Result<()> {
    use fastclip::bench::driver::run_matrix;
    use fastclip::bench::BenchOpts;
    let backend = backend(args)?;
    let mut configs: Vec<String> = match args.str_opt("configs") {
        Some(csv) => csv
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect(),
        // sweep mode without --configs times only the sweep
        None if args.str_opt("model").is_some() => Vec::new(),
        None => ["mlp2_mnist_b128", "mlp4_mnist_b128", "cnn2_mnist_b128"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    };
    // --model SPEC [--dataset D] [--batches 16..512]: sweep one spec
    // across batch sizes — the paper's speedup-vs-batch curves, past
    // the old grid's ceiling of 128
    anyhow::ensure!(
        args.str_opt("batches").is_none() || args.str_opt("model").is_some(),
        "--batches sweeps a --model spec across batch sizes; without \
         --model it would be silently ignored (name full configs with \
         --configs instead)"
    );
    anyhow::ensure!(
        args.str_opt("batch").is_none(),
        "bench-matrix takes --batches (a sweep), not --batch; a single \
         batch is `--batches N`"
    );
    anyhow::ensure!(
        args.str_opt("dataset").is_none() || args.str_opt("model").is_some(),
        "--dataset only composes with --model; configs named via \
         --configs carry their own dataset"
    );
    let mut sweep: Vec<(usize, String)> = Vec::new();
    if let Some(model) = args.str_opt("model") {
        let spec = ModelSpec::parse(model)?;
        let dataset = args.str_or("dataset", "mnist");
        let batches =
            fastclip::cli::parse_batches(&args.str_or("batches", "16..128"))?;
        for b in batches {
            let name = SpecKey::new(spec.clone(), &dataset, b).to_string();
            sweep.push((b, name.clone()));
            configs.push(name);
        }
    }
    let methods: Vec<ClipMethod> = match args.str_opt("methods") {
        Some(csv) => csv
            .split(',')
            .map(|m| ClipMethod::parse(m.trim()))
            .collect::<Result<Vec<ClipMethod>>>()?,
        None => ClipMethod::all().to_vec(),
    };
    let smoke = args.bool("smoke");
    let opts = if smoke {
        // CI smoke: enough iterations to rank methods, not to publish
        BenchOpts {
            warmup_iters: 1,
            min_iters: 2,
            max_iters: 5,
            target_seconds: 0.3,
        }
    } else {
        BenchOpts::default()
    };
    let policy_arg = clip_policy_opt(args)?;
    let policy = policy_arg
        .clone()
        .unwrap_or_else(|| ClipPolicy::hard_global(1.0));
    if policy_arg.is_some() {
        println!("clip policy: {policy}");
    }
    let report =
        run_matrix(backend.as_ref(), &configs, &methods, opts, smoke, &policy)?;
    println!("| config | method | mean ms | p50 ms | p95 ms | iters |");
    println!("|---|---|---:|---:|---:|---:|");
    for e in &report.entries {
        println!(
            "| {} | {} | {:.3} | {:.3} | {:.3} | {} |",
            e.config,
            e.method.name(),
            e.mean_ms,
            e.p50_ms,
            e.p95_ms,
            e.iters
        );
    }
    for config in &configs {
        if let Some(s) = report.reweight_speedup(config) {
            println!("{config}: reweight is {s:.1}x faster than nxbp");
        }
    }
    if !sweep.is_empty() {
        let fmt = |v: Option<f64>| {
            v.map(|x| format!("{x:.3}")).unwrap_or_else(|| "-".into())
        };
        println!(
            "\nspeedup vs batch for {}:",
            args.str_or("model", "?")
        );
        println!("| batch | reweight p50 ms | nxbp p50 ms | speedup |");
        println!("|---:|---:|---:|---:|");
        for (b, name) in &sweep {
            let rw = report.p50_ms(name, ClipMethod::Reweight);
            let nx = report.p50_ms(name, ClipMethod::NxBp);
            let sp = match (rw, nx) {
                (Some(r), Some(n)) if r > 0.0 => format!("{:.1}x", n / r),
                _ => "-".into(),
            };
            println!("| {b} | {} | {} | {sp} |", fmt(rw), fmt(nx));
        }
    }
    // where does group-wise clipping pay? re-time reweight under the
    // classical whole-model hard clip at the same C and show the p50
    // overhead (or win) of the requested policy side by side
    if !policy.is_global_hard() && methods.contains(&ClipMethod::Reweight) {
        let base = ClipPolicy::hard_global(policy.clip());
        let base_report = run_matrix(
            backend.as_ref(),
            &configs,
            &[ClipMethod::Reweight],
            opts,
            smoke,
            &base,
        )?;
        let fmt = |v: Option<f64>| {
            v.map(|x| format!("{x:.3}")).unwrap_or_else(|| "-".into())
        };
        println!("\nreweight p50: {policy} vs whole-model {base}:");
        println!("| config | {policy} ms | {base} ms | ratio |");
        println!("|---|---:|---:|---:|");
        for config in &configs {
            let pol = report.p50_ms(config, ClipMethod::Reweight);
            let glb = base_report.p50_ms(config, ClipMethod::Reweight);
            let ratio = match (pol, glb) {
                (Some(p), Some(g)) if g > 0.0 => format!("{:.2}x", p / g),
                _ => "-".into(),
            };
            println!("| {config} | {} | {} | {ratio} |", fmt(pol), fmt(glb));
        }
    }
    let out = args.str_or("out", &format!("BENCH_{}.json", backend.name()));
    fastclip::util::write_file(
        std::path::Path::new(&out),
        &report.to_json().to_string_pretty(),
    )?;
    println!("wrote {out}");
    if args.bool("check") {
        report.check_reweight_beats_nxbp()?;
        println!("check passed: reweight beats nxbp at batch 128");
        // the zero-allocation arena contract only holds (and is only
        // probed) on the native backend — PJRT marshalling allocates —
        // and only when the counting allocator is installed: a
        // no-default-features build skips the gate instead of failing
        // on an unmeasurable probe
        if backend.name() == "native"
            && fastclip::util::alloc::counting_enabled()
        {
            report.check_steps_alloc_free()?;
            println!("check passed: warm reweight steps are allocation-free");
        }
    }
    if let Some(hist) = args.str_opt("history") {
        // history medians baseline the *default* policy; mixing in
        // entries timed under another policy would poison the
        // regression gate with incomparable step times
        anyhow::ensure!(
            policy_arg.is_none(),
            "--history tracks the default-policy trajectory; drop \
             --clip-policy (or --history) so the appended entry stays \
             comparable with the file's recent medians"
        );
        fastclip::bench::driver::append_history(
            &report,
            std::path::Path::new(hist),
            fastclip::bench::driver::HISTORY_MAX_RATIO,
        )?;
        println!("appended bench-history entry to {hist}");
    }
    Ok(())
}

fn cmd_bench_history(args: &Args) -> Result<()> {
    let file = args.str_or("file", "BENCH_history.jsonl");
    let text = util::read_file(std::path::Path::new(&file))
        .with_context(|| format!("reading bench history {file:?}"))?;
    let entries: Vec<Json> = text
        .lines()
        .filter(|l| !l.trim().is_empty())
        .filter_map(|l| Json::parse(l).ok())
        .collect();
    let md = fastclip::bench::driver::render_history(&entries);
    match args.str_opt("out") {
        Some(out) => {
            util::write_file(std::path::Path::new(out), &md)?;
            println!("wrote {out} ({} history entries)", entries.len());
        }
        None => print!("{md}"),
    }
    Ok(())
}

fn cmd_accountant(args: &Args) -> Result<()> {
    let q = args.f64_or("q", 0.01)?;
    let delta = args.f64_or("delta", 1e-5)?;
    let steps = args.u64_or("steps", 1000)?;
    if args.bool("calibrate") {
        let eps = args.f64_or("eps", 2.0)?;
        match privacy::calibrate_sigma(q, steps, eps, delta) {
            Some(sigma) => println!(
                "sigma = {:.4} achieves ({}, {:.0e})-DP over {} steps at q={}",
                sigma, eps, delta, steps, q
            ),
            None => println!("infeasible: even sigma=200 exceeds eps={eps}"),
        }
    } else {
        let sigma = args.f64_or("sigma", 1.1)?;
        let eps = privacy::epsilon_for(q, sigma, steps, delta);
        println!(
            "({:.4}, {:.0e})-DP after {} steps at q={}, sigma={}",
            eps, delta, steps, q, sigma
        );
    }
    Ok(())
}

fn cmd_memory(args: &Args) -> Result<()> {
    let config = config_ref(args)?;
    let budget_gib = args.f64_or("budget-gib", 11.0)?; // 1080 Ti
    let backend = backend(args)?;
    let cfg = backend.resolve(&config)?;
    let fp = memory::Footprint::of(&cfg, cfg.act_elems_per_example as u64);
    let budget = (budget_gib * (1u64 << 30) as f64) as u64;
    println!(
        "memory model for {config} (P={} params, A={} act/ex, budget {:.1} GiB):",
        fp.p, fp.a, budget_gib
    );
    println!("| method | bytes @tau={} | max batch |", cfg.batch);
    println!("|---|---:|---:|");
    for m in ["nonprivate", "reweight", "multiloss", "nxbp"] {
        println!(
            "| {} | {} | {} |",
            m,
            util::fmt_bytes(memory::step_bytes(m, fp, cfg.batch as u64)),
            memory::max_batch(m, fp, budget)
        );
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let backend = backend(args)?;
    if args.str_opt("config").is_some() || args.str_opt("model").is_some() {
        let name = config_ref(args)?;
        let cfg = backend.resolve(&name)?;
        let mut j = Json::obj();
        j.set("name", cfg.name.as_str().into());
        j.set("backend", backend.name().into());
        j.set("model", cfg.model.as_str().into());
        if let Some(spec) = &cfg.spec {
            j.set("spec", spec.to_string().into());
        }
        j.set("dataset", cfg.dataset.as_str().into());
        j.set("batch", cfg.batch.into());
        j.set("param_tensors", cfg.params.len().into());
        j.set("param_elems", cfg.param_elems().into());
        j.set("act_elems_per_example", cfg.act_elems_per_example.into());
        j.set(
            "artifacts",
            Json::Arr(
                cfg.artifacts.keys().map(|k| k.as_str().into()).collect(),
            ),
        );
        println!("{}", j.to_string_pretty());
    } else {
        let tag = args.str_opt("tag");
        println!("| config | model | dataset | batch | params | artifacts |");
        println!("|---|---|---|---:|---:|---|");
        for cfg in backend.manifest().configs.values() {
            if let Some(t) = tag {
                if !cfg.has_tag(t) {
                    continue;
                }
            }
            println!(
                "| {} | {} | {} | {} | {} | {} |",
                cfg.name,
                cfg.model,
                cfg.dataset,
                cfg.batch,
                cfg.param_elems(),
                cfg.artifacts.keys().cloned().collect::<Vec<_>>().join(",")
            );
        }
    }
    Ok(())
}
