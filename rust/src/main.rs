//! fastclip CLI — the leader entrypoint.
//!
//! Subcommands:
//!   train         run DP training on one config (paper Alg 1)
//!   bench-step    time one (config, method) step
//!   bench-matrix  time a config x method matrix, write BENCH_<backend>.json
//!   accountant    RDP accounting / sigma calibration queries
//!   memory        Sec 6.7 memory model table for a config
//!   inspect       list manifest configs and artifacts
//!
//! Every compute subcommand takes `--backend native|pjrt|auto`
//! (default auto: PJRT when compiled in and artifacts exist, native
//! otherwise).

use anyhow::{Context, Result};
use fastclip::cli::Args;
use fastclip::coordinator::{memory, train, ClipMethod, GradComputer, TrainOptions};
use fastclip::privacy;
use fastclip::runtime::{backend_by_name, Backend, BatchStage, ParamStore};
use fastclip::util::json::Json;
use fastclip::{log_info, util};

fn main() {
    fastclip::util::logging::level_from_env();
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.subcommand.as_str() {
        "train" => cmd_train(&args),
        "bench-step" => cmd_bench_step(&args),
        "bench-matrix" => cmd_bench_matrix(&args),
        "accountant" => cmd_accountant(&args),
        "memory" => cmd_memory(&args),
        "inspect" => cmd_inspect(&args),
        "" | "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            anyhow::bail!("unknown subcommand {other:?}")
        }
    }
}

fn print_help() {
    println!(
        r#"fastclip — DP deep learning with fast per-example gradient clipping

USAGE: fastclip <subcommand> [flags]

  train       --config NAME [--method reweight|nxbp|multiloss|nonprivate|
              reweight_pallas|reweight_gram] [--steps N] [--n DATASET_SIZE]
              [--lr F] [--clip F] [--sigma F | --target-eps F] [--delta F]
              [--optimizer adam|sgd] [--seed N] [--eval-every N]
              [--poisson] [--checkpoint DIR] [--json]
  bench-step  --config NAME --method M [--iters N]
  bench-matrix [--configs NAME,NAME,...] [--methods M,M,...] [--smoke]
              [--out FILE] [--check] [--history FILE]
              times every (config, method) step and writes the
              BENCH_<backend>.json trajectory artifact; --check fails
              unless reweight beats nxbp on every batch-128 config and
              (on the native backend) the warm reweight step path ran
              with zero heap allocations; --history appends a compact
              record (p50s + steps_alloc_free) to a jsonl trajectory
              and fails on a >25% reweight@b128 p50 step-time
              regression versus the median of that file's recent
              entries
  accountant  --q F --sigma F --steps N [--delta F]
              | --calibrate --q F --steps N --eps F [--delta F]
  memory      --config NAME [--budget-gib F]
  inspect     [--config NAME] [--tag TAG]

All compute subcommands accept --backend native|pjrt|auto (default
auto). The native backend runs the built-in MLP config family in pure
Rust — no Python, no artifacts. The pjrt backend (requires building
with --features pjrt) executes AOT HLO artifacts from
$FASTCLIP_ARTIFACTS (default ./artifacts; build with `make artifacts`)."#
    );
}

fn backend(args: &Args) -> Result<Box<dyn Backend>> {
    let b = backend_by_name(args.str_opt("backend")).with_context(|| {
        format!(
            "selecting backend {:?}",
            args.str_or("backend", "auto")
        )
    })?;
    log_info!("backend: {}", b.name());
    Ok(b)
}

fn cmd_train(args: &Args) -> Result<()> {
    let opts = TrainOptions {
        config: args.require("config")?.to_string(),
        method: ClipMethod::parse(&args.str_or("method", "reweight"))?,
        steps: args.u64_or("steps", 100)?,
        dataset_n: args.usize_or("n", 2048)?,
        lr: args.f64_or("lr", 1e-3)?,
        clip: args.f64_or("clip", 1.0)?,
        sigma: args.f64_or("sigma", 1.1)?,
        target_eps: args.str_opt("target-eps").map(|v| v.parse()).transpose()?,
        delta: args.f64_or("delta", 1e-5)?,
        optimizer: args.str_or("optimizer", "adam"),
        seed: args.u64_or("seed", 0)?,
        eval_every: args.u64_or("eval-every", 0)?,
        log_every: args.u64_or("log-every", 20)?,
        checkpoint_dir: args.str_opt("checkpoint").map(Into::into),
        poisson: args.bool("poisson"),
    };
    let backend = backend(args)?;
    let report = train(backend.as_ref(), &opts)?;
    if args.bool("json") {
        let mut j = report.metrics_json.clone();
        j.set("config", report.config.as_str().into());
        j.set("method", report.method.name().into());
        if let Some((eps, order)) = report.epsilon {
            j.set("epsilon", eps.into());
            j.set("rdp_order", (order as usize).into());
        }
        println!("{}", j.to_string_pretty());
    } else {
        println!(
            "done: {} steps, loss(ema)={:.4}, mean step {:.2} ms, wall {:.1}s",
            report.steps, report.final_loss_ema, report.mean_step_ms, report.wall_seconds
        );
        if let Some((eps, order)) = report.epsilon {
            println!(
                "privacy: ({:.3}, {:.0e})-DP via RDP order {}",
                eps,
                opts_delta(args)?,
                order
            );
        }
        if let Some(rss) = report.peak_rss_bytes {
            println!("peak RSS: {}", util::fmt_bytes(rss));
        }
        if args.bool("profile") {
            println!("\nstep phase breakdown:");
            let phases = report.metrics_json.get("phases");
            for name in ["gather", "execute", "noise", "update"] {
                let p = phases.get(name);
                println!(
                    "  {:<8} {:>8.1} ms total  {:>5.1}%",
                    name,
                    p.get("seconds").as_f64().unwrap_or(0.0) * 1e3,
                    p.get("share").as_f64().unwrap_or(0.0) * 100.0
                );
            }
        }
    }
    Ok(())
}

fn opts_delta(args: &Args) -> Result<f64> {
    args.f64_or("delta", 1e-5)
}

fn cmd_bench_step(args: &Args) -> Result<()> {
    let config = args.require("config")?.to_string();
    let method = ClipMethod::parse(&args.str_or("method", "reweight"))?;
    let iters = args.usize_or("iters", 10)?;
    let backend = backend(args)?;
    let cfg = backend.manifest().config(&config)?.clone();
    let mut computer = GradComputer::new(backend.as_ref(), &config, method)?;
    let ds = fastclip::data::load_dataset(&cfg.dataset, cfg.batch.max(256), 0)?;
    let mut stage = BatchStage::for_config(&cfg);
    let batch: Vec<usize> = (0..cfg.batch).collect();
    fastclip::coordinator::stage_batch(&ds, &batch, &mut stage);
    let mut params = ParamStore::new(
        &cfg,
        Some(&fastclip::runtime::init_params_glorot(&cfg, 0)),
    )?;
    // one arena for every timed step (the trainer's shape)
    let mut out = computer.new_out();
    // warmup (includes compile)
    computer.compute(&mut params, &stage, 1.0, &mut out)?;
    log_info!("compile took {:.0} ms", computer.compile_ms());
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = std::time::Instant::now();
        computer.compute(&mut params, &stage, 1.0, &mut out)?;
        times.push(t.elapsed().as_secs_f64());
    }
    let s = fastclip::util::stats::Summary::of(&times);
    println!(
        "{config} {}: mean {:.3} ms, p50 {:.3} ms, p95 {:.3} ms over {iters} iters",
        method.name(),
        s.mean * 1e3,
        s.p50 * 1e3,
        s.p95 * 1e3
    );
    Ok(())
}

fn cmd_bench_matrix(args: &Args) -> Result<()> {
    use fastclip::bench::driver::run_matrix;
    use fastclip::bench::BenchOpts;
    let backend = backend(args)?;
    let configs: Vec<String> = args
        .str_or("configs", "mlp2_mnist_b128,mlp4_mnist_b128,cnn2_mnist_b128")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let methods: Vec<ClipMethod> = match args.str_opt("methods") {
        Some(csv) => csv
            .split(',')
            .map(|m| ClipMethod::parse(m.trim()))
            .collect::<Result<Vec<ClipMethod>>>()?,
        None => ClipMethod::all().to_vec(),
    };
    let smoke = args.bool("smoke");
    let opts = if smoke {
        // CI smoke: enough iterations to rank methods, not to publish
        BenchOpts {
            warmup_iters: 1,
            min_iters: 2,
            max_iters: 5,
            target_seconds: 0.3,
        }
    } else {
        BenchOpts::default()
    };
    let report = run_matrix(backend.as_ref(), &configs, &methods, opts, smoke)?;
    println!("| config | method | mean ms | p50 ms | p95 ms | iters |");
    println!("|---|---|---:|---:|---:|---:|");
    for e in &report.entries {
        println!(
            "| {} | {} | {:.3} | {:.3} | {:.3} | {} |",
            e.config,
            e.method.name(),
            e.mean_ms,
            e.p50_ms,
            e.p95_ms,
            e.iters
        );
    }
    for config in &configs {
        if let Some(s) = report.reweight_speedup(config) {
            println!("{config}: reweight is {s:.1}x faster than nxbp");
        }
    }
    let out = args.str_or("out", &format!("BENCH_{}.json", backend.name()));
    fastclip::util::write_file(
        std::path::Path::new(&out),
        &report.to_json().to_string_pretty(),
    )?;
    println!("wrote {out}");
    if args.bool("check") {
        report.check_reweight_beats_nxbp()?;
        println!("check passed: reweight beats nxbp at batch 128");
        // the zero-allocation arena contract only holds (and is only
        // probed) on the native backend — PJRT marshalling allocates —
        // and only when the counting allocator is installed: a
        // no-default-features build skips the gate instead of failing
        // on an unmeasurable probe
        if backend.name() == "native"
            && fastclip::util::alloc::counting_enabled()
        {
            report.check_steps_alloc_free()?;
            println!("check passed: warm reweight steps are allocation-free");
        }
    }
    if let Some(hist) = args.str_opt("history") {
        fastclip::bench::driver::append_history(
            &report,
            std::path::Path::new(hist),
            fastclip::bench::driver::HISTORY_MAX_RATIO,
        )?;
        println!("appended bench-history entry to {hist}");
    }
    Ok(())
}

fn cmd_accountant(args: &Args) -> Result<()> {
    let q = args.f64_or("q", 0.01)?;
    let delta = args.f64_or("delta", 1e-5)?;
    let steps = args.u64_or("steps", 1000)?;
    if args.bool("calibrate") {
        let eps = args.f64_or("eps", 2.0)?;
        match privacy::calibrate_sigma(q, steps, eps, delta) {
            Some(sigma) => println!(
                "sigma = {:.4} achieves ({}, {:.0e})-DP over {} steps at q={}",
                sigma, eps, delta, steps, q
            ),
            None => println!("infeasible: even sigma=200 exceeds eps={eps}"),
        }
    } else {
        let sigma = args.f64_or("sigma", 1.1)?;
        let eps = privacy::epsilon_for(q, sigma, steps, delta);
        println!(
            "({:.4}, {:.0e})-DP after {} steps at q={}, sigma={}",
            eps, delta, steps, q, sigma
        );
    }
    Ok(())
}

fn cmd_memory(args: &Args) -> Result<()> {
    let config = args.require("config")?.to_string();
    let budget_gib = args.f64_or("budget-gib", 11.0)?; // 1080 Ti
    let backend = backend(args)?;
    let cfg = backend.manifest().config(&config)?;
    let fp = memory::Footprint::of(cfg, cfg.act_elems_per_example as u64);
    let budget = (budget_gib * (1u64 << 30) as f64) as u64;
    println!(
        "memory model for {config} (P={} params, A={} act/ex, budget {:.1} GiB):",
        fp.p, fp.a, budget_gib
    );
    println!("| method | bytes @tau={} | max batch |", cfg.batch);
    println!("|---|---:|---:|");
    for m in ["nonprivate", "reweight", "multiloss", "nxbp"] {
        println!(
            "| {} | {} | {} |",
            m,
            util::fmt_bytes(memory::step_bytes(m, fp, cfg.batch as u64)),
            memory::max_batch(m, fp, budget)
        );
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let backend = backend(args)?;
    if let Some(name) = args.str_opt("config") {
        let cfg = backend.manifest().config(name)?;
        let mut j = Json::obj();
        j.set("name", cfg.name.as_str().into());
        j.set("backend", backend.name().into());
        j.set("model", cfg.model.as_str().into());
        j.set("dataset", cfg.dataset.as_str().into());
        j.set("batch", cfg.batch.into());
        j.set("param_tensors", cfg.params.len().into());
        j.set("param_elems", cfg.param_elems().into());
        j.set("act_elems_per_example", cfg.act_elems_per_example.into());
        j.set(
            "artifacts",
            Json::Arr(
                cfg.artifacts.keys().map(|k| k.as_str().into()).collect(),
            ),
        );
        println!("{}", j.to_string_pretty());
    } else {
        let tag = args.str_opt("tag");
        println!("| config | model | dataset | batch | params | artifacts |");
        println!("|---|---|---|---:|---:|---|");
        for cfg in backend.manifest().configs.values() {
            if let Some(t) = tag {
                if !cfg.has_tag(t) {
                    continue;
                }
            }
            println!(
                "| {} | {} | {} | {} | {} | {} |",
                cfg.name,
                cfg.model,
                cfg.dataset,
                cfg.batch,
                cfg.param_elems(),
                cfg.artifacts.keys().cloned().collect::<Vec<_>>().join(",")
            );
        }
    }
    Ok(())
}
