//! Clipping policies: *what* gets a per-example norm and *how* that
//! norm becomes a scale factor nu (DESIGN.md §"Clipping policies").
//!
//! The paper's fast-clipping machinery computes one whole-model
//! per-example norm and one scalar nu per example. Two follow-up lines
//! generalize exactly those two axes, and `ClipPolicy` is their
//! product:
//!
//!   - **granularity** (He et al. 2022, group-wise / per-layer
//!     clipping): instead of one norm over the whole parameter vector,
//!     the parametric layers are partitioned into G groups and each
//!     group is clipped against the threshold independently. The
//!     mechanism's L2 sensitivity becomes sqrt(Σ_g C_g²) = C·sqrt(G).
//!   - **nu formula** (Bu et al. 2022, automatic clipping): the hard
//!     factor min(1, C/norm) is replaced by C/(norm+gamma), which is
//!     strictly inside the C-ball for every norm and removes the
//!     clip-threshold tuning sensitivity.
//!
//! A policy is written `<granularity>:<clip>[,g=<gamma>]` — e.g.
//! `global:1.0`, `per_layer:0.5`, `auto:1.0,g=0.01`,
//! `groups(2,4):1.0`. `auto` is shorthand for the global granularity
//! with the automatic formula; appending `,g=<gamma>` to any
//! granularity selects the automatic formula there too. The canonical
//! `Display` form round-trips through `parse` and is the policy's
//! stable name (checkpoint meta, bench labels).
//!
//! The granularity grammar is driven by `ClipPolicy::kinds()` — the
//! same registry renders the `--clip-policy` help text and the parse
//! errors, so the documented list can never drift from the parser
//! (the `ClipMethod::all()` pattern).

use crate::runtime::store::clip_factor;
use anyhow::{bail, ensure, Context, Result};
use std::fmt;

/// Which slices of the parameter vector get their own per-example
/// norm (and their own nu). Group boundaries are *parametric-layer*
/// indices (a layer = one (W, b) pair; parameterless layers such as
/// avg-pool are not counted).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Granularity {
    /// One norm over the whole parameter vector — the paper's setting.
    Global,
    /// Every parametric layer is its own group.
    PerLayer,
    /// Explicit group boundaries: strictly increasing layer indices;
    /// boundary `b` starts a new group at layer `b`. `Groups(vec![2,4])`
    /// on a 6-layer model yields groups {0,1}, {2,3}, {4,5}.
    Groups(Vec<usize>),
}

/// How a per-example (per-group) norm becomes the scale factor nu.
#[derive(Debug, Clone, PartialEq)]
pub enum NuFormula {
    /// nu = min(1, clip/norm) — the classical Abadi et al. clip.
    Hard { clip: f32 },
    /// nu = clip/(norm + gamma) — automatic clipping (Bu et al. 2022):
    /// nu·norm < clip for every norm ≥ 0, no hard threshold.
    Automatic { clip: f32, gamma: f32 },
}

/// Default gamma for the automatic formula when `,g=` is omitted
/// (the stability constant of Bu et al. 2022).
pub const DEFAULT_GAMMA: f32 = 0.01;

/// A complete clipping policy: granularity × nu formula. Replaces the
/// bare `clip: f32` everywhere a step or trainer clips.
#[derive(Debug, Clone, PartialEq)]
pub struct ClipPolicy {
    pub granularity: Granularity,
    pub nu: NuFormula,
}

/// One entry of the policy-kind registry: (syntax, description).
/// Drives `--clip-policy` help text and parse errors.
pub struct PolicyKind {
    pub syntax: &'static str,
    pub describes: &'static str,
}

impl ClipPolicy {
    /// The granularity registry — every syntax `parse` accepts, with
    /// the one-line description the CLI help renders. Parse errors
    /// list exactly these, so the documented grammar cannot drift.
    pub fn kinds() -> &'static [PolicyKind] {
        &[
            PolicyKind {
                syntax: "global:<clip>",
                describes: "one whole-model norm per example (the paper)",
            },
            PolicyKind {
                syntax: "per_layer:<clip>",
                describes: "every parametric layer clipped independently",
            },
            PolicyKind {
                syntax: "groups(<b1>,<b2>,...):<clip>",
                describes: "custom layer groups split at the given boundaries",
            },
            PolicyKind {
                syntax: "auto:<clip>[,g=<gamma>]",
                describes: "automatic clipping, nu = clip/(norm+gamma)",
            },
        ]
    }

    /// One-line grammar summary for help text: every registered
    /// syntax, `|`-joined, plus the gamma suffix rule.
    pub fn help_grammar() -> String {
        let kinds: Vec<&str> = Self::kinds().iter().map(|k| k.syntax).collect();
        format!(
            "{} (append ,g=<gamma> to any form for the automatic formula)",
            kinds.join(" | ")
        )
    }

    /// The classical policy: global granularity, hard clip at `clip`.
    /// Exactly what the pre-policy code meant by a bare clip value.
    pub fn hard_global(clip: f32) -> ClipPolicy {
        ClipPolicy {
            granularity: Granularity::Global,
            nu: NuFormula::Hard { clip },
        }
    }

    /// Parse `<granularity>:<clip>[,g=<gamma>]`. Errors list the
    /// registered kinds.
    pub fn parse(s: &str) -> Result<ClipPolicy> {
        let grammar = || {
            let kinds: Vec<&str> =
                Self::kinds().iter().map(|k| k.syntax).collect();
            format!("expected one of: {}", kinds.join(", "))
        };
        let (gran_s, rest) = s.split_once(':').with_context(|| {
            format!("clip policy {s:?} has no `:<clip>` part — {}", grammar())
        })?;
        // rest = <clip>[,g=<gamma>]
        let (clip_s, gamma_s) = match rest.split_once(',') {
            Some((c, tail)) => {
                let g = tail.strip_prefix("g=").with_context(|| {
                    format!(
                        "clip policy {s:?}: expected `,g=<gamma>` after the \
                         clip value, got `,{tail}`"
                    )
                })?;
                (c, Some(g))
            }
            None => (rest, None),
        };
        let clip: f32 = clip_s
            .parse()
            .with_context(|| format!("clip policy {s:?}: bad clip value {clip_s:?}"))?;
        ensure!(
            clip.is_finite() && clip > 0.0,
            "clip policy {s:?}: clip must be finite and > 0, got {clip}"
        );
        let gamma: Option<f32> = match gamma_s {
            Some(gs) => {
                let g: f32 = gs.parse().with_context(|| {
                    format!("clip policy {s:?}: bad gamma value {gs:?}")
                })?;
                ensure!(
                    g.is_finite() && g > 0.0,
                    "clip policy {s:?}: gamma must be finite and > 0, got {g}"
                );
                Some(g)
            }
            None => None,
        };
        // `auto` forces the automatic formula; everywhere else the
        // formula is selected by the presence of `,g=`.
        let (granularity, auto) = if gran_s == "global" {
            (Granularity::Global, false)
        } else if gran_s == "per_layer" {
            (Granularity::PerLayer, false)
        } else if gran_s == "auto" {
            (Granularity::Global, true)
        } else if let Some(inner) =
            gran_s.strip_prefix("groups(").and_then(|t| t.strip_suffix(')'))
        {
            let mut bounds = Vec::new();
            for tok in inner.split(',') {
                let v: usize = tok.trim().parse().with_context(|| {
                    format!(
                        "clip policy {s:?}: bad group boundary {tok:?} \
                         (want layer indices, e.g. groups(2,4))"
                    )
                })?;
                bounds.push(v);
            }
            ensure!(
                !bounds.is_empty(),
                "clip policy {s:?}: groups(...) needs at least one boundary"
            );
            ensure!(
                bounds.windows(2).all(|w| w[0] < w[1]) && bounds[0] > 0,
                "clip policy {s:?}: group boundaries must be strictly \
                 increasing layer indices starting above 0, got {bounds:?}"
            );
            (Granularity::Groups(bounds), false)
        } else {
            bail!(
                "unknown clip-policy granularity {gran_s:?} in {s:?} — {}",
                grammar()
            );
        };
        let nu = if auto || gamma.is_some() {
            NuFormula::Automatic { clip, gamma: gamma.unwrap_or(DEFAULT_GAMMA) }
        } else {
            NuFormula::Hard { clip }
        };
        Ok(ClipPolicy { granularity, nu })
    }

    /// The clip threshold C (per group for grouped granularities).
    pub fn clip(&self) -> f32 {
        match self.nu {
            NuFormula::Hard { clip } => clip,
            NuFormula::Automatic { clip, .. } => clip,
        }
    }

    /// nu for one (per-example, per-group) norm.
    #[inline]
    pub fn nu_for(&self, norm: f32) -> f32 {
        let nu = match self.nu {
            NuFormula::Hard { clip } => clip_factor(norm, clip),
            NuFormula::Automatic { clip, gamma } => clip / (norm + gamma),
        };
        // poisoning guard: a NaN norm (or gamma=0 with norm=0) would
        // otherwise propagate a non-finite nu into every element of
        // this example's clipped gradient
        debug_assert!(
            nu.is_finite() && nu > 0.0,
            "ClipPolicy::nu_for: non-finite or non-positive nu {nu} (norm {norm}, {self})"
        );
        nu
    }

    pub fn is_global(&self) -> bool {
        self.granularity == Granularity::Global
    }

    /// The exact policy the pre-policy scalar-clip code implemented —
    /// the only one the PJRT artifacts understand.
    pub fn is_global_hard(&self) -> bool {
        self.is_global() && matches!(self.nu, NuFormula::Hard { .. })
    }

    /// Validate against a model with `n_layers` parametric layers.
    pub fn check(&self, n_layers: usize) -> Result<()> {
        ensure!(n_layers > 0, "clip policy on a model with no parameters");
        if let Granularity::Groups(bounds) = &self.granularity {
            for &b in bounds {
                ensure!(
                    b < n_layers,
                    "clip policy {self}: group boundary {b} out of range — \
                     the model has {n_layers} parametric layers \
                     (boundaries must be in 1..{n_layers})"
                );
            }
        }
        Ok(())
    }

    /// Number of groups on a model with `n_layers` parametric layers.
    pub fn n_groups(&self, n_layers: usize) -> usize {
        match &self.granularity {
            Granularity::Global => 1,
            Granularity::PerLayer => n_layers,
            Granularity::Groups(bounds) => bounds.len() + 1,
        }
    }

    /// Fill `out[l]` with the group index of parametric layer `l`
    /// (`out.len() == n_layers`; no allocation — the warm-path
    /// contract).
    pub fn fill_layer_groups(&self, out: &mut [usize]) {
        match &self.granularity {
            Granularity::Global => out.iter_mut().for_each(|g| *g = 0),
            Granularity::PerLayer => {
                out.iter_mut().enumerate().for_each(|(l, g)| *g = l)
            }
            Granularity::Groups(bounds) => {
                for (l, g) in out.iter_mut().enumerate() {
                    *g = bounds.iter().filter(|&&b| b <= l).count();
                }
            }
        }
    }

    /// The mechanism's true L2 sensitivity on a model with `n_layers`
    /// parametric layers: every group contributes a gradient of norm
    /// at most C (hard: min(1,C/n)·n ≤ C; automatic: C·n/(n+γ) < C),
    /// and the groups are orthogonal slices of the parameter vector,
    /// so the whole clipped gradient has norm ≤ sqrt(Σ_g C²) =
    /// C·sqrt(G). Global policies keep the paper's sensitivity C.
    pub fn sensitivity(&self, n_layers: usize) -> f64 {
        self.clip() as f64 * (self.n_groups(n_layers) as f64).sqrt()
    }
}

impl fmt::Display for ClipPolicy {
    /// Canonical form — round-trips through `parse` and is the
    /// policy's stable name. `auto` is preferred over `global:…,g=…`
    /// for the global-automatic combination.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let auto_global = self.is_global()
            && matches!(self.nu, NuFormula::Automatic { .. });
        match &self.granularity {
            Granularity::Global if auto_global => write!(f, "auto")?,
            Granularity::Global => write!(f, "global")?,
            Granularity::PerLayer => write!(f, "per_layer")?,
            Granularity::Groups(bounds) => {
                write!(f, "groups(")?;
                for (i, b) in bounds.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{b}")?;
                }
                write!(f, ")")?;
            }
        }
        match self.nu {
            NuFormula::Hard { clip } => write!(f, ":{clip}"),
            NuFormula::Automatic { clip, gamma } => {
                write!(f, ":{clip},g={gamma}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_every_registered_kind() {
        let p = ClipPolicy::parse("global:1.0").unwrap();
        assert!(p.is_global_hard());
        assert_eq!(p.clip(), 1.0);

        let p = ClipPolicy::parse("per_layer:0.5").unwrap();
        assert_eq!(p.granularity, Granularity::PerLayer);
        assert_eq!(p.nu, NuFormula::Hard { clip: 0.5 });

        let p = ClipPolicy::parse("auto:1.0").unwrap();
        assert!(p.is_global() && !p.is_global_hard());
        assert_eq!(
            p.nu,
            NuFormula::Automatic { clip: 1.0, gamma: DEFAULT_GAMMA }
        );

        let p = ClipPolicy::parse("auto:1.0,g=0.25").unwrap();
        assert_eq!(p.nu, NuFormula::Automatic { clip: 1.0, gamma: 0.25 });

        let p = ClipPolicy::parse("groups(2,4):0.8").unwrap();
        assert_eq!(p.granularity, Granularity::Groups(vec![2, 4]));

        // gamma suffix switches any granularity to the automatic formula
        let p = ClipPolicy::parse("per_layer:0.5,g=0.1").unwrap();
        assert_eq!(p.granularity, Granularity::PerLayer);
        assert_eq!(p.nu, NuFormula::Automatic { clip: 0.5, gamma: 0.1 });
    }

    /// parse ↔ print round-trip on the canonical forms (the satellite
    /// contract: the printed form is the stable name).
    #[test]
    fn canonical_display_round_trips() {
        for s in [
            "global:1",
            "global:0.5",
            "per_layer:0.25",
            "per_layer:0.5,g=0.1",
            "auto:1,g=0.01",
            "auto:2.5,g=0.001",
            "groups(1):1",
            "groups(2,4):0.75",
            "groups(1,2,3):0.5,g=0.02",
        ] {
            let p = ClipPolicy::parse(s).unwrap();
            assert_eq!(p.to_string(), s, "not canonical: {s}");
            let p2 = ClipPolicy::parse(&p.to_string()).unwrap();
            assert_eq!(p, p2, "round trip changed {s}");
        }
        // non-canonical spellings normalize to the canonical name
        let p = ClipPolicy::parse("auto:1.0").unwrap();
        assert_eq!(p.to_string(), "auto:1,g=0.01");
        let p = ClipPolicy::parse("global:1.0,g=0.01").unwrap();
        assert_eq!(p.to_string(), "auto:1,g=0.01");
    }

    /// Parse errors are generated from the registry — every registered
    /// syntax appears in the unknown-granularity message.
    #[test]
    fn parse_errors_list_registered_kinds() {
        let err = ClipPolicy::parse("bogus:1.0").unwrap_err();
        let msg = format!("{err:#}");
        for k in ClipPolicy::kinds() {
            let head = k.syntax.split(':').next().unwrap();
            assert!(msg.contains(head), "missing {head} in: {msg}");
        }
        assert!(ClipPolicy::parse("global").is_err()); // no clip
        assert!(ClipPolicy::parse("global:0").is_err()); // clip <= 0
        assert!(ClipPolicy::parse("global:nan").is_err());
        assert!(ClipPolicy::parse("auto:1.0,g=0").is_err()); // gamma <= 0
        assert!(ClipPolicy::parse("auto:1.0,x=2").is_err()); // not g=
        assert!(ClipPolicy::parse("groups():1.0").is_err());
        assert!(ClipPolicy::parse("groups(0):1.0").is_err()); // must be > 0
        assert!(ClipPolicy::parse("groups(3,2):1.0").is_err()); // not increasing
        assert!(ClipPolicy::parse("groups(2,2):1.0").is_err());
        // help grammar mentions every kind
        let help = ClipPolicy::help_grammar();
        for k in ClipPolicy::kinds() {
            let head = k.syntax.split(':').next().unwrap();
            assert!(help.contains(head), "help missing {head}");
        }
    }

    #[test]
    fn groups_and_sensitivity() {
        let n = 6usize;
        let mut g = vec![0usize; n];

        let p = ClipPolicy::parse("global:1.0").unwrap();
        p.fill_layer_groups(&mut g);
        assert_eq!(g, vec![0; 6]);
        assert_eq!(p.n_groups(n), 1);
        assert_eq!(p.sensitivity(n), 1.0);

        let p = ClipPolicy::parse("per_layer:2.0").unwrap();
        p.fill_layer_groups(&mut g);
        assert_eq!(g, vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(p.n_groups(n), 6);
        assert!((p.sensitivity(n) - 2.0 * 6f64.sqrt()).abs() < 1e-12);

        let p = ClipPolicy::parse("groups(2,4):1.5").unwrap();
        p.fill_layer_groups(&mut g);
        assert_eq!(g, vec![0, 0, 1, 1, 2, 2]);
        assert_eq!(p.n_groups(n), 3);
        assert!((p.sensitivity(n) - 1.5 * 3f64.sqrt()).abs() < 1e-12);
        assert!(p.check(6).is_ok());
        assert!(p.check(4).is_err()); // boundary 4 out of range
        assert!(p.check(5).is_ok());
    }

    #[test]
    fn nu_formulas() {
        let hard = ClipPolicy::parse("global:1.0").unwrap();
        assert_eq!(hard.nu_for(0.5), 1.0); // under the threshold
        assert_eq!(hard.nu_for(2.0), 0.5); // clipped to C/norm
        let auto = ClipPolicy::parse("auto:1.0,g=0.01").unwrap();
        for norm in [0.0f32, 0.1, 1.0, 10.0, 1e6] {
            let nu = auto.nu_for(norm);
            assert!(nu * norm < 1.0, "auto nu·norm = {} >= C", nu * norm);
        }
        // norm = 0 stays finite (the gamma regularizer)
        assert!(auto.nu_for(0.0).is_finite());
    }
}
