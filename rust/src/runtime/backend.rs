//! The execution-backend seam: "compile a (config, method) step and
//! execute it", abstracted over *how* the math runs.
//!
//! Two implementations ship today:
//!   - `runtime::native::NativeBackend` — pure-Rust batched execution,
//!     always available, hermetic (the default; what tier-1 CI
//!     exercises). Model families are pluggable: the backend resolves
//!     a config's `model` string through its `ModelFamily` registry
//!     (`runtime::native::taps`), so new families (attention, RNN)
//!     register themselves without touching this seam.
//!   - `runtime::engine::Engine` (feature `pjrt`) — compiles AOT HLO
//!     artifacts produced by the Python build path and executes them
//!     via the PJRT C API.
//!
//! The coordinator (`GradComputer`, the trainer, the bench driver, the
//! CLI) is written against these traits only, so adding a backend —
//! GPU PJRT, a sharded multi-host runner, a fused-kernel path — never
//! touches the training loop again.
//!
//! # Step execution contract (arena form)
//!
//! `run_into` is the primitive: the **caller owns the `StepOut`
//! arena** and reuses it across steps, so the warm execution path
//! performs zero heap allocation (DESIGN.md §"Step execution
//! contract", pinned by `tests/no_alloc.rs`). The step — not the
//! caller — resets the arena at entry (`StepOut::reset`): gradients
//! are zeroed, norms/scalars cleared, and the gradient layout adopted
//! from the step's config, so a cold (empty) arena and a warm (dirty)
//! arena produce bitwise-identical results. `run` is a thin
//! convenience wrapper for one-shot callers that allocates a fresh
//! arena per call.

use super::manifest::{ConfigSpec, Manifest};
use super::policy::ClipPolicy;
use super::spec::SpecKey;
use super::store::{BatchStage, ParamStore, StepOut};
use anyhow::Result;
use std::sync::Arc;

/// A compiled/ready step for one (config, method) pair.
///
/// Semantics by method (the artifact contract, DESIGN.md §7):
///   - `nonprivate`: grads = batch-mean gradient, loss = mean loss.
///   - `reweight` / `reweight_gram` / `reweight_direct` /
///     `reweight_pallas` / `multiloss`: grads = 1/tau * sum_i nu_i *
///     g_i with nu_i determined by the clip *policy* (hard global:
///     nu_i = min(1, clip/||g_i||), the paper's setting; grouped
///     granularities clip each layer group's slice independently;
///     the automatic formula uses clip/(norm+gamma)); norms = the
///     unclipped whole-model per-example norms, and grouped policies
///     additionally publish per-group norms (`StepOut::group_norms`).
///     Requires a policy. The variants differ only in how norms are
///     computed and where nu is applied — never in the result.
///   - `naive1` (batch-1): grads = the single example's unclipped
///     gradient; norms = [||g_0||]. The nxBP loop clips/averages in
///     the coordinator.
///   - `fwd`: loss = mean loss, correct = correct-prediction count,
///     no grads (the arena's gradient buffer collapses to the empty
///     layout — zero parameters — on every backend).
pub trait StepFn: Send + Sync {
    /// Artifact method name this step implements (e.g. "reweight").
    fn method(&self) -> &str;

    /// Compile/lowering time, if any (0.0 for interpreted backends).
    fn compile_ms(&self) -> f64 {
        0.0
    }

    /// Execute one step into the caller-owned arena: params + staged
    /// batch (+ the clip policy for the private batched methods).
    /// Steps never mutate the store; backends that cache device
    /// uploads key on `ParamStore::{id, version}`. The step resets
    /// `out` first — callers only ever *read* it afterwards.
    fn run_into(
        &self,
        params: &ParamStore,
        stage: &BatchStage,
        policy: Option<&ClipPolicy>,
        out: &mut StepOut,
    ) -> Result<()>;

    /// One-shot convenience: allocate a fresh arena, `run_into` it,
    /// return it. Hot loops should hold an arena and call `run_into`.
    fn run(
        &self,
        params: &ParamStore,
        stage: &BatchStage,
        policy: Option<&ClipPolicy>,
    ) -> Result<StepOut> {
        let mut out = StepOut::new();
        self.run_into(params, stage, policy, &mut out)?;
        Ok(out)
    }
}

/// An execution backend: a manifest of runnable configs plus the
/// ability to produce a `StepFn` for any (config, method) the manifest
/// declares.
pub trait Backend: Send + Sync {
    /// Short identifier for logs/reports ("native", "pjrt").
    fn name(&self) -> &'static str;

    /// The configs this backend can run.
    fn manifest(&self) -> &Manifest;

    /// Compile (or fetch from cache) the step for a config's method.
    /// `method` is the artifact method name (see `ClipMethod::artifact`).
    fn load(&self, cfg: &ConfigSpec, method: &str) -> Result<Arc<dyn StepFn>>;

    /// Resolve a config *reference*: an exact manifest name, or — on
    /// backends that can synthesize configs — a `model@dataset:bN`
    /// spec key (see `runtime::spec`). This is the `ConfigSource` seam
    /// above `Manifest`: the coordinator (trainer, `GradComputer`,
    /// bench driver, CLI) resolves every reference through it, so a
    /// backend decides for itself whether the config space is a closed
    /// manifest or an open spec grammar.
    ///
    /// The default implementation is **manifest-bound** (the PJRT
    /// engine executes ahead-of-time compiled artifacts, so it cannot
    /// synthesize steps for arbitrary shapes): it accepts exactly the
    /// manifest's names, and when the reference *parses* as a spec key
    /// it explains that this backend cannot synthesize configs instead
    /// of pretending the name is merely unknown. The native backend
    /// overrides this with spec synthesis.
    fn resolve(&self, name: &str) -> Result<ConfigSpec> {
        match self.manifest().config(name) {
            Ok(cfg) => Ok(cfg.clone()),
            Err(e) => {
                if SpecKey::parse(name).is_ok() {
                    anyhow::bail!(
                        "config {name:?} is a synthesizable model spec, but \
                         the `{}` backend is manifest-bound (it executes \
                         ahead-of-time compiled artifacts); run it with \
                         `--backend native`, or AOT-compile the config into \
                         the artifacts manifest",
                        self.name()
                    );
                }
                // spec-shaped but malformed (no manifest name contains
                // `@`): the grammar error is the useful diagnostic
                if name.contains('@') {
                    return Err(SpecKey::parse(name).unwrap_err().context(
                        format!(
                            "config reference {name:?} looks like a spec \
                             key but does not parse"
                        ),
                    ));
                }
                Err(e)
            }
        }
    }

    /// The batch-1 sibling config the nxBP loop's naive1 body runs on.
    /// Spec-derived configs (provenance present) rebuild structurally
    /// via `ConfigSpec::with_batch(1)`; manifest-loaded configs fall
    /// back to the manifest's `_b1` naming convention.
    fn naive_sibling(&self, cfg: &ConfigSpec) -> Result<ConfigSpec> {
        if cfg.spec.is_some() {
            return cfg.with_batch(1);
        }
        Ok(self.manifest().naive_config(&cfg.name)?.clone())
    }
}
