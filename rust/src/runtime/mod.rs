//! Runtime layer: the `Backend` abstraction over step execution, the
//! pure-Rust `NativeBackend` (always available, hermetic), and — behind
//! the `pjrt` feature — the PJRT engine that loads AOT artifacts (HLO
//! text) and executes them on the PJRT CPU client (DESIGN.md §7).

pub mod backend;
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod manifest;
pub mod native;
pub mod policy;
pub mod spec;
pub mod store;

pub use backend::{Backend, StepFn};
#[cfg(feature = "pjrt")]
pub use engine::{Engine, StepExe};
pub use manifest::{ArtifactSpec, ConfigSpec, ConvMeta, Manifest, ParamSpec};
pub use native::taps::{FamilyBuilder, FamilyRegistry, ModelFamily, NuBlock};
pub use native::NativeBackend;
pub use policy::{ClipPolicy, Granularity, NuFormula};
pub use spec::{ConfigBuilder, ModelSpec, SpecKey};
pub use store::{
    clip_factor, init_params_glorot, BatchStage, GradVec, ParamStore, StepOut,
};

use anyhow::Result;
use std::path::PathBuf;

/// Default artifacts directory: $FASTCLIP_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> PathBuf {
    // lint: allow(no-wallclock-entropy) -- startup config resolution
    // (where to find artifacts), not a hot-path value; resolved once
    // before any step runs.
    std::env::var("FASTCLIP_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Whether an artifacts manifest is present on disk (needed by the
/// PJRT backend; the native backend never touches the filesystem).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").is_file()
}

/// Whether the PJRT engine was compiled into this binary.
pub fn pjrt_enabled() -> bool {
    cfg!(feature = "pjrt")
}

/// Pick the best available backend: PJRT when it is compiled in *and*
/// artifacts are present, the hermetic native backend otherwise.
pub fn default_backend() -> Result<Box<dyn Backend>> {
    #[cfg(feature = "pjrt")]
    {
        if artifacts_available() {
            return Ok(Box::new(Engine::from_dir(&artifacts_dir())?));
        }
        crate::log_info!(
            "no artifacts at {} — falling back to the native backend",
            artifacts_dir().display()
        );
    }
    Ok(Box::new(NativeBackend::new()))
}

/// Backend by CLI name: "native", "pjrt", or "auto"/None for
/// `default_backend`.
pub fn backend_by_name(name: Option<&str>) -> Result<Box<dyn Backend>> {
    match name {
        None | Some("auto") => default_backend(),
        Some("native") => Ok(Box::new(NativeBackend::new())),
        Some("pjrt") => {
            #[cfg(feature = "pjrt")]
            {
                Ok(Box::new(Engine::from_dir(&artifacts_dir())?))
            }
            #[cfg(not(feature = "pjrt"))]
            {
                anyhow::bail!(
                    "this binary was built without the `pjrt` feature; \
                     rebuild with `cargo build --features pjrt` (requires the \
                     vendored xla crate) or use --backend native"
                )
            }
        }
        Some(other) => {
            anyhow::bail!("unknown backend {other:?} (native|pjrt|auto)")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_backend_always_resolves() {
        // hermetic guarantee: with no artifacts and default features,
        // something runnable comes back
        let b = default_backend().unwrap();
        assert!(!b.manifest().configs.is_empty());
    }

    #[test]
    fn backend_by_name_native_and_errors() {
        assert_eq!(backend_by_name(Some("native")).unwrap().name(), "native");
        assert!(backend_by_name(Some("bogus")).is_err());
        if !pjrt_enabled() {
            let err = backend_by_name(Some("pjrt")).unwrap_err();
            assert!(format!("{err:#}").contains("pjrt"));
        }
    }
}
