//! Runtime layer: loads AOT artifacts (HLO text) and executes them on
//! the PJRT CPU client. See DESIGN.md §7 for the ABI.

pub mod engine;
pub mod manifest;

pub use engine::{
    init_params_glorot, run_step, BatchStage, Engine, ParamStore, StepExe,
    StepOut,
};
pub use manifest::{ArtifactSpec, ConfigSpec, Manifest, ParamSpec};

use std::path::PathBuf;

/// Default artifacts directory: $FASTCLIP_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("FASTCLIP_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}
