//! Conv tap producer for the native backend: conv layers lowered to
//! im2col patch matrices over the `gemm` kernels, so the whole
//! batched clip-method matrix (`NativeStep` via `taps::TapModel`)
//! runs on CNNs with no new per-method code.
//!
//! Layout. The network input arrives CHW per example (the manifest's
//! `[b, c, h, w]` input shape) and is rearranged once per step to HWC
//! (position-major, channel-minor), because that is the layout every
//! conv GEMM naturally produces: layer l's pre-activation is a
//! (B·P_l) x cout_l matrix whose row (i, p) is output position p of
//! example i — flat, that *is* the HWC activation map of example i.
//! Flattening into the fc head is therefore free (the fc input is the
//! same buffer read as B x (P·c) rows), and the fc head reuses the
//! MLP GEMM orientations unchanged.
//!
//! Per layer l (conv): patches_l = im2col(act_{l-1}) of shape
//! (B·P) x K with K = cin·kh·kw and patch columns in (c, ky, kx)
//! order — element-for-element the layout of one out-channel slice of
//! the `[cout, cin, kh, kw]` weight tensor. Then:
//!
//!   forward:   Z = patches · Wᵀ + bias rows      (`sgemm_nt`)
//!   backward:  dPatches = Δ · W                  (`sgemm`), then
//!              col2im scatters dPatches onto act_{l-1} (overlapping
//!              receptive fields sum — the weight sharing)
//!   grads:     gW = Δᵀ · patches per example     (`sgemm_tn_f64acc`),
//!              gb = column sums of Δ
//!
//! # Per-example norms under weight sharing
//!
//! The MLP tap trick ||g_i||² = Σ_l (||a_{l-1,i}||²+1)·||δ_{l,i}||²
//! is exact only because each example owns a *single* tap row per
//! layer. A conv layer's per-example weight gradient is a sum of P
//! overlapping rank-1 contributions, g_i = A_iᵀ·Δ_i (A_i, Δ_i the
//! example's P-row patch/delta blocks), so the row-norm product is
//! only the Cauchy–Schwarz **upper bound**
//!
//!   ||A_iᵀ·Δ_i||²_F ≤ ||A_i||²_F · ||Δ_i||²_F .
//!
//! Clipping with an overestimated norm would still be DP-safe (nu
//! only shrinks) but would *not* match the materialized-gradient
//! methods, so every clip method here uses the exact norm. Two exact
//! routes are provided, mirroring the paper's Sec 5.2 trade-off:
//!
//!   - `sq_norms` materializes the small K x cout product A_iᵀ·Δ_i
//!     per example (cheap when K·cout is small — the direct route);
//!   - `gram_sq_norms` forms the P x P position Grams A_i·A_iᵀ and
//!     Δ_i·Δ_iᵀ and sums their Hadamard product (cheap when P² is
//!     small; this is where the Gram structure's off-diagonal terms —
//!     degenerate on MLPs — become load-bearing).
//!
//! `tap_bound_sq_norms` keeps the row-norm-product bound for
//! diagnostics; the ordering tap ≥ gram (equality on MLPs) is pinned
//! by tests here and in the integration suite. See DESIGN.md
//! §"Per-example norms under weight sharing".

use super::gemm;
use super::taps::{
    downcast_scratch, downcast_scratch_ref, ModelFamily, NuBlock, ScratchAny,
};
use crate::runtime::manifest::{ConfigSpec, ConvMeta};
use crate::runtime::store::GradVec;
use anyhow::{bail, ensure, Result};
use rayon::prelude::*;

/// One layer of a cnn config: conv layers first (each optionally
/// followed by an average-pool stage), then the flatten boundary, then
/// fc layers (the last fc maps to the classes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Layer {
    Conv {
        cin: usize,
        cout: usize,
        k: usize,
        stride: usize,
        pad: usize,
        h_in: usize,
        w_in: usize,
        h_out: usize,
        w_out: usize,
    },
    /// Parameterless k x k average pool with window == stride
    /// (disjoint windows, a rim narrower than `k` is dropped — the
    /// floor(h/k) convention). Mean-pooled post-ReLU maps stay ≥ 0, so
    /// the uniform ReLU applied between layers is the identity here.
    Pool {
        c: usize,
        h_in: usize,
        w_in: usize,
        h_out: usize,
        w_out: usize,
        k: usize,
    },
    Fc {
        din: usize,
        dout: usize,
    },
}

impl Layer {
    /// Rows of this layer's activation/delta matrix at batch `b`.
    fn rows(&self, b: usize) -> usize {
        match *self {
            Layer::Conv { h_out, w_out, .. }
            | Layer::Pool { h_out, w_out, .. } => b * h_out * w_out,
            Layer::Fc { .. } => b,
        }
    }

    /// Feature columns per row (out-channels / fc out-dim).
    fn cols(&self) -> usize {
        match *self {
            Layer::Conv { cout, .. } => cout,
            Layer::Pool { c, .. } => c,
            Layer::Fc { dout, .. } => dout,
        }
    }

    /// Reduction dim of the layer GEMMs (patch K / fc in-dim; pool
    /// runs no GEMM).
    fn k_dim(&self) -> usize {
        match *self {
            Layer::Conv { cin, k, .. } => cin * k * k,
            Layer::Pool { .. } => 0,
            Layer::Fc { din, .. } => din,
        }
    }

    /// Activation/delta elements of one example in this layer.
    fn elems_per_example(&self) -> usize {
        match *self {
            Layer::Conv { cout, h_out, w_out, .. } => h_out * w_out * cout,
            Layer::Pool { c, h_out, w_out, .. } => h_out * w_out * c,
            Layer::Fc { dout, .. } => dout,
        }
    }
}

/// Conv-family dimensions parsed and validated from a manifest config.
#[derive(Debug, Clone)]
pub struct ConvSpec {
    /// flat input elements per example (cin·h·w)
    pub d_in: usize,
    pub in_c: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub layers: Vec<Layer>,
    /// chain layer → parametric layer index (None for pool stages):
    /// the chain is longer than the param list once pools are in, so
    /// every `params[2*p]` access routes through this map
    pub park: Vec<Option<usize>>,
    /// chain layer → first norm-slab slot of that layer (pools own no
    /// slots; their entry points at the next layer's base)
    slot_base: Vec<usize>,
    /// norm-slab slot → parametric layer (the `norm_slots` contract)
    slots: Vec<usize>,
    pub n_classes: usize,
    pub batch: usize,
}

impl ConvSpec {
    pub fn from_config(cfg: &ConfigSpec) -> Result<ConvSpec> {
        ensure!(
            cfg.model == "cnn",
            "conv tap producer expects the `cnn` config family; config {} \
             has model {:?}",
            cfg.name,
            cfg.model
        );
        ensure!(
            cfg.input_dtype == "f32",
            "native cnn expects f32 input, config {} has {:?}",
            cfg.name,
            cfg.input_dtype
        );
        ensure!(
            cfg.input_shape.len() == 4 && cfg.input_shape[0] == cfg.batch,
            "config {}: cnn input shape {:?} must be [batch, c, h, w] \
             leading with batch {}",
            cfg.name,
            cfg.input_shape,
            cfg.batch
        );
        let (in_c, in_h, in_w) =
            (cfg.input_shape[1], cfg.input_shape[2], cfg.input_shape[3]);
        ensure!(
            !cfg.params.is_empty() && cfg.params.len() % 2 == 0,
            "config {}: cnn params must be (weight, bias) pairs, got {} tensors",
            cfg.name,
            cfg.params.len()
        );
        let meta: ConvMeta = cfg.conv.unwrap_or_default();
        ensure!(
            meta.kernel > 0 && meta.stride > 0,
            "config {}: conv meta {:?} has a zero kernel or stride",
            cfg.name,
            meta
        );
        let mut layers = Vec::with_capacity(cfg.params.len() / 2);
        let (mut cur_c, mut cur_h, mut cur_w) = (in_c, in_h, in_w);
        // Some(dout) once an fc layer has flattened the map
        let mut flat: Option<usize> = None;
        for (l, pair) in cfg.params.chunks(2).enumerate() {
            let (w, b) = (&pair[0], &pair[1]);
            ensure!(
                b.shape.len() == 1,
                "config {}: layer {l} expects a 1-d bias, got {:?}",
                cfg.name,
                b.shape
            );
            match w.shape.len() {
                4 => {
                    ensure!(
                        flat.is_none(),
                        "config {}: conv layer {l} after the flatten boundary",
                        cfg.name
                    );
                    let (cout, cin, kh, kw) =
                        (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
                    ensure!(
                        cin == cur_c,
                        "config {}: conv layer {l} in-channels {cin} != \
                         current channels {cur_c}",
                        cfg.name
                    );
                    ensure!(
                        kh == meta.kernel && kw == meta.kernel,
                        "config {}: conv layer {l} kernel {kh}x{kw} != conv \
                         meta kernel {}",
                        cfg.name,
                        meta.kernel
                    );
                    ensure!(
                        b.shape[0] == cout,
                        "config {}: conv layer {l} bias dim {} != out-channels \
                         {cout}",
                        cfg.name,
                        b.shape[0]
                    );
                    ensure!(
                        cur_h + 2 * meta.pad >= kh && cur_w + 2 * meta.pad >= kw,
                        "config {}: conv layer {l} kernel {kh}x{kw} larger than \
                         the padded {cur_h}x{cur_w} map",
                        cfg.name
                    );
                    let h_out = gemm::conv_out(cur_h, kh, meta.stride, meta.pad);
                    let w_out = gemm::conv_out(cur_w, kw, meta.stride, meta.pad);
                    layers.push(Layer::Conv {
                        cin,
                        cout,
                        k: meta.kernel,
                        stride: meta.stride,
                        pad: meta.pad,
                        h_in: cur_h,
                        w_in: cur_w,
                        h_out,
                        w_out,
                    });
                    cur_c = cout;
                    cur_h = h_out;
                    cur_w = w_out;
                    // pool >= 2 inserts an average-pool stage after
                    // every conv layer (pool 0/1 means none)
                    if meta.pool >= 2 {
                        ensure!(
                            cur_h >= meta.pool && cur_w >= meta.pool,
                            "config {}: pool {} larger than the {cur_h}x{cur_w} \
                             map after conv layer {l}",
                            cfg.name,
                            meta.pool
                        );
                        let (ph, pw) =
                            (cur_h / meta.pool, cur_w / meta.pool);
                        layers.push(Layer::Pool {
                            c: cur_c,
                            h_in: cur_h,
                            w_in: cur_w,
                            h_out: ph,
                            w_out: pw,
                            k: meta.pool,
                        });
                        cur_h = ph;
                        cur_w = pw;
                    }
                }
                2 => {
                    let (din, dout) = (w.shape[0], w.shape[1]);
                    let expect = flat.unwrap_or(cur_c * cur_h * cur_w);
                    ensure!(
                        din == expect,
                        "config {}: fc layer {l} in-dim {din} != flattened \
                         feature dim {expect}",
                        cfg.name
                    );
                    ensure!(
                        b.shape[0] == dout,
                        "config {}: fc layer {l} bias dim {} != out-dim {dout}",
                        cfg.name,
                        b.shape[0]
                    );
                    layers.push(Layer::Fc { din, dout });
                    flat = Some(dout);
                }
                other => bail!(
                    "config {}: layer {l} weight has {other} dims; cnn layers \
                     are 4-d conv or 2-d fc",
                    cfg.name
                ),
            }
        }
        ensure!(
            layers.iter().any(|l| matches!(l, Layer::Conv { .. })),
            "config {}: cnn family needs at least one conv layer",
            cfg.name
        );
        match layers.last() {
            Some(Layer::Fc { dout, .. }) if *dout == cfg.n_classes => {}
            other => bail!(
                "config {}: the final layer must be an fc head onto \
                 n_classes {} (got {other:?})",
                cfg.name,
                cfg.n_classes
            ),
        }
        // parametric-index and norm-slab maps over the final chain:
        // conv layers own two slab slots (weight term, bias term), fc
        // layers one, pool stages none
        let mut park = Vec::with_capacity(layers.len());
        let mut slot_base = Vec::with_capacity(layers.len());
        let mut slots = Vec::new();
        let mut p = 0usize;
        for l in &layers {
            park.push(match l {
                Layer::Pool { .. } => None,
                _ => Some(p),
            });
            slot_base.push(slots.len());
            match l {
                Layer::Conv { .. } => {
                    slots.push(p);
                    slots.push(p);
                    p += 1;
                }
                Layer::Fc { .. } => {
                    slots.push(p);
                    p += 1;
                }
                Layer::Pool { .. } => {}
            }
        }
        Ok(ConvSpec {
            d_in: in_c * in_h * in_w,
            in_c,
            in_h,
            in_w,
            layers,
            park,
            slot_base,
            slots,
            n_classes: cfg.n_classes,
            batch: cfg.batch,
        })
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Parametric (weight, bias) layer pairs — the chain minus pools.
    pub fn n_param_layers(&self) -> usize {
        self.park.iter().flatten().count()
    }

    /// Per-parameter element counts in manifest order
    /// [W0, b0, W1, b1, ...] — the gradient arena layout. Pool stages
    /// are parameterless and contribute nothing.
    pub fn grad_lens(&self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.layers.len() * 2);
        for l in &self.layers {
            if !matches!(l, Layer::Pool { .. }) {
                out.push(l.cols() * l.k_dim());
                out.push(l.cols());
            }
        }
        out
    }

    /// Per-example conv working-buffer extents over all conv layers:
    /// (max cout·K weight elements, max cout, max P²). Sizes the
    /// scratch's per-example partial/Gram buffers.
    fn conv_partial_dims(&self) -> (usize, usize, usize) {
        let (mut max_w, mut max_b, mut max_p2) = (1usize, 1usize, 1usize);
        for l in &self.layers {
            if let Layer::Conv { cin, cout, k, h_out, w_out, .. } = *l {
                let p = h_out * w_out;
                max_w = max_w.max(cout * cin * k * k);
                max_b = max_b.max(cout);
                max_p2 = max_p2.max(p * p);
            }
        }
        (max_w, max_b, max_p2)
    }

    /// Check a param store's tensor count and per-tensor lengths.
    pub fn check_params(&self, config: &str, host: &[Vec<f32>]) -> Result<()> {
        ensure!(
            host.len() == 2 * self.n_param_layers(),
            "{config}: param store has {} tensors, spec needs {}",
            host.len(),
            2 * self.n_param_layers()
        );
        for (l, layer) in self.layers.iter().enumerate() {
            let Some(p) = self.park[l] else { continue };
            ensure!(
                host[2 * p].len() == layer.cols() * layer.k_dim()
                    && host[2 * p + 1].len() == layer.cols(),
                "{config}: layer {l} param shapes do not match the config"
            );
        }
        Ok(())
    }
}

/// Whole-batch forward/backward scratch for the conv family. All
/// buffers are fully rewritten by every forward/backward, so one
/// scratch can be reused across steps.
pub struct ConvScratch {
    pub b: usize,
    /// network input rearranged CHW -> HWC, b x (h·w·cin)
    x_hwc: Vec<f32>,
    /// conv layers: the im2col patch matrix, rows x K (empty for fc)
    patches: Vec<Vec<f32>>,
    /// conv layers: dLoss/dPatches scratch, rows x K (empty for fc)
    dpatches: Vec<Vec<f32>>,
    /// pre-activations z_l, rows x cols
    zs: Vec<Vec<f32>>,
    /// post-activations relu(z_l); the last entry is unused
    acts: Vec<Vec<f32>>,
    /// dLoss/dz_l
    deltas: Vec<Vec<f32>>,
    /// softmax rows, b x n_classes
    probs: Vec<f32>,
    /// Per-example working buffers, grown lazily on first use and
    /// reused afterwards (the warm path allocates nothing). Example i
    /// owns the i-th fixed-stride chunk of each, so parallel
    /// per-example stages write disjoint slices:
    ///   - `ex_w` (b x max cout·K, f32): the K x cout per-example
    ///     product of the direct norm route / the per-example weight-
    ///     gradient partials of the parallel assembly;
    ///   - `ex_work` (b x max cout·K, f64): the f64 accumulation
    ///     workspace those reductions run in;
    ///   - `ex_b` (b x max cout, f32): per-example bias partials;
    ///   - `ex_ga`/`ex_gd` (b x max P², f32): the position-Gram
    ///     buffers of the Gram norm route.
    ex_w: Vec<f32>,
    ex_work: Vec<f64>,
    ex_b: Vec<f32>,
    ex_ga: Vec<f32>,
    ex_gd: Vec<f32>,
}

impl ConvScratch {
    pub fn for_spec(spec: &ConvSpec, b: usize) -> ConvScratch {
        let mut patches = Vec::with_capacity(spec.layers.len());
        let mut dpatches = Vec::with_capacity(spec.layers.len());
        let mut zs = Vec::with_capacity(spec.layers.len());
        let mut acts = Vec::with_capacity(spec.layers.len());
        let mut deltas = Vec::with_capacity(spec.layers.len());
        for (li, l) in spec.layers.iter().enumerate() {
            let rows = l.rows(b);
            let cols = l.cols();
            match l {
                Layer::Conv { .. } => {
                    patches.push(vec![0.0; rows * l.k_dim()]);
                    // layer 0 never receives a propagated delta
                    // (backward stops at l == 1), so its dPatches
                    // buffer would be dead weight
                    if li > 0 {
                        dpatches.push(vec![0.0; rows * l.k_dim()]);
                    } else {
                        dpatches.push(Vec::new());
                    }
                }
                Layer::Pool { .. } | Layer::Fc { .. } => {
                    patches.push(Vec::new());
                    dpatches.push(Vec::new());
                }
            }
            zs.push(vec![0.0; rows * cols]);
            acts.push(vec![0.0; rows * cols]);
            deltas.push(vec![0.0; rows * cols]);
        }
        ConvScratch {
            b,
            x_hwc: vec![0.0; b * spec.d_in],
            patches,
            dpatches,
            zs,
            acts,
            deltas,
            probs: vec![0.0; b * spec.n_classes],
            ex_w: Vec::new(),
            ex_work: Vec::new(),
            ex_b: Vec::new(),
            ex_ga: Vec::new(),
            ex_gd: Vec::new(),
        }
    }
}

/// Rearrange b CHW examples to HWC in `out` (same flat length).
fn chw_to_hwc(b: usize, c: usize, h: usize, w: usize, x: &[f32], out: &mut [f32]) {
    let d = c * h * w;
    debug_assert_eq!(x.len(), b * d);
    debug_assert_eq!(out.len(), b * d);
    for i in 0..b {
        let src = &x[i * d..(i + 1) * d];
        let dst = &mut out[i * d..(i + 1) * d];
        for ch in 0..c {
            let plane = &src[ch * h * w..(ch + 1) * h * w];
            for (pos, &v) in plane.iter().enumerate() {
                dst[pos * c + ch] = v;
            }
        }
    }
}

/// Batched forward: im2col + GEMM per conv layer, the MLP GEMM per fc
/// layer, row-wise softmax-CE at the head. Fills every scratch buffer;
/// returns (f64 loss sum, correct-prediction count).
pub fn forward_batch(
    spec: &ConvSpec,
    params: &[Vec<f32>],
    x: &[f32],
    labels: &[i32],
    s: &mut ConvScratch,
) -> (f64, usize) {
    let b = s.b;
    let n = spec.n_layers();
    chw_to_hwc(b, spec.in_c, spec.in_h, spec.in_w, x, &mut s.x_hwc);
    for l in 0..n {
        match spec.layers[l] {
            Layer::Conv {
                cin, cout, k, stride, pad, h_in, w_in, h_out, w_out,
            } => {
                let p = spec.park[l].unwrap();
                let w = &params[2 * p];
                let bias = &params[2 * p + 1];
                let rows = b * h_out * w_out;
                let kdim = cin * k * k;
                {
                    let input: &[f32] =
                        if l == 0 { &s.x_hwc } else { &s.acts[l - 1] };
                    gemm::im2col_hwc(
                        b, cin, h_in, w_in, k, k, stride, pad, input,
                        &mut s.patches[l],
                    );
                }
                let z = &mut s.zs[l];
                for r in 0..rows {
                    z[r * cout..(r + 1) * cout].copy_from_slice(bias);
                }
                gemm::sgemm_nt(rows, kdim, cout, &s.patches[l], w, z);
            }
            Layer::Pool { c, h_in, w_in, h_out, w_out, k } => {
                // mean over disjoint k x k windows of the HWC map; a
                // pool always follows a conv, so acts[l-1] exists
                let input = &s.acts[l - 1];
                let z = &mut s.zs[l];
                let inv = 1.0 / (k * k) as f32;
                let (d_in, d_out) = (h_in * w_in * c, h_out * w_out * c);
                for i in 0..b {
                    let src = &input[i * d_in..(i + 1) * d_in];
                    let dst = &mut z[i * d_out..(i + 1) * d_out];
                    for oy in 0..h_out {
                        for ox in 0..w_out {
                            for ch in 0..c {
                                // lint: allow(f32-accum) -- k*k pool
                                // window summed in fixed (ky, kx)
                                // ascending order; tiny (k<=3) and the
                                // same order on every path, so bitwise
                                // reproducible.
                                let mut sum = 0.0f32;
                                for ky in 0..k {
                                    for kx in 0..k {
                                        let idx = ((oy * k + ky) * w_in
                                            + ox * k
                                            + kx)
                                            * c
                                            + ch;
                                        sum += src[idx];
                                    }
                                }
                                dst[(oy * w_out + ox) * c + ch] = sum * inv;
                            }
                        }
                    }
                }
            }
            Layer::Fc { din, dout } => {
                let p = spec.park[l].unwrap();
                let w = &params[2 * p];
                let bias = &params[2 * p + 1];
                let z = &mut s.zs[l];
                for r in 0..b {
                    z[r * dout..(r + 1) * dout].copy_from_slice(bias);
                }
                let input: &[f32] =
                    if l == 0 { &s.x_hwc } else { &s.acts[l - 1] };
                gemm::sgemm(b, din, dout, input, w, z);
            }
        }
        if l < n - 1 {
            let a = &mut s.acts[l];
            for (av, &zv) in a.iter_mut().zip(s.zs[l].iter()) {
                *av = zv.max(0.0);
            }
        }
    }
    super::taps::softmax_xent_rows(
        b,
        spec.n_classes,
        &s.zs[n - 1],
        &mut s.probs,
        labels,
    )
}

/// Batched backward (after `forward_batch`): fills `deltas` for every
/// layer — fc layers via `sgemm_nt`, conv layers via dPatches =
/// Δ·W (`sgemm`) + col2im scatter — with the ReLU mask applied per
/// layer. `nu`, when given, scales example i's output delta by nu_i
/// (the reweighted second backward).
pub fn backward_batch(
    spec: &ConvSpec,
    params: &[Vec<f32>],
    labels: &[i32],
    nu: Option<&[f32]>,
    s: &mut ConvScratch,
) {
    let b = s.b;
    let n = spec.n_layers();
    let nc = spec.n_classes;
    {
        // dCE_i/dz = softmax(z_i) - onehot(y_i), optionally nu_i-scaled
        let d = &mut s.deltas[n - 1];
        d.copy_from_slice(&s.probs);
        for r in 0..b {
            d[r * nc + labels[r] as usize] -= 1.0;
        }
        if let Some(nu) = nu {
            for (r, &wv) in nu.iter().enumerate() {
                for v in d[r * nc..(r + 1) * nc].iter_mut() {
                    *v *= wv;
                }
            }
        }
    }
    for l in (1..n).rev() {
        let (head, tail) = s.deltas.split_at_mut(l);
        let d_here = &tail[0];
        let d_prev = &mut head[l - 1];
        match spec.layers[l] {
            Layer::Fc { din, dout } => {
                let w = &params[2 * spec.park[l].unwrap()];
                d_prev.iter_mut().for_each(|v| *v = 0.0);
                // Δ_{l-1,flat} = Δ_l · W_lᵀ
                gemm::sgemm_nt(b, dout, din, d_here, w, d_prev);
            }
            Layer::Conv {
                cin, cout, k, stride, pad, h_in, w_in, h_out, w_out,
            } => {
                let w = &params[2 * spec.park[l].unwrap()];
                let rows = b * h_out * w_out;
                let kdim = cin * k * k;
                let dp = &mut s.dpatches[l];
                dp.iter_mut().for_each(|v| *v = 0.0);
                // dPatches = Δ_l · W_l  (W stored cout x K)
                gemm::sgemm(rows, cout, kdim, d_here, w, dp);
                // scatter overlapping receptive fields back onto the
                // previous HWC map (col2im zeroes d_prev itself)
                gemm::col2im_hwc(
                    b, cin, h_in, w_in, k, k, stride, pad, dp, d_prev,
                );
            }
            Layer::Pool { c, h_in, w_in, h_out, w_out, k } => {
                // mean pool: each output delta spreads /k² onto its
                // disjoint window; positions in the dropped rim (and
                // anything stale) are zeroed first
                d_prev.iter_mut().for_each(|v| *v = 0.0);
                let inv = 1.0 / (k * k) as f32;
                let (d_in, d_out) = (h_in * w_in * c, h_out * w_out * c);
                for i in 0..b {
                    let src = &d_here[i * d_out..(i + 1) * d_out];
                    let dst = &mut d_prev[i * d_in..(i + 1) * d_in];
                    for oy in 0..h_out {
                        for ox in 0..w_out {
                            for ch in 0..c {
                                let g =
                                    src[(oy * w_out + ox) * c + ch] * inv;
                                for ky in 0..k {
                                    for kx in 0..k {
                                        let idx = ((oy * k + ky) * w_in
                                            + ox * k
                                            + kx)
                                            * c
                                            + ch;
                                        dst[idx] = g;
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        // every non-final layer is ReLU: mask by the stored z_{l-1}.
        // (When z_{l-1} is a pool output the map is ≥ 0, so the mask
        // only zeroes positions whose whole window was already dead —
        // a no-op on the propagated gradient.)
        for (dv, &zv) in d_prev.iter_mut().zip(s.zs[l - 1].iter()) {
            if zv <= 0.0 {
                *dv = 0.0;
            }
        }
    }
}

/// Per-example slice of layer l's delta/patch rows for example `i`.
fn example_rows(v: &[f32], i: usize, per_example: usize) -> &[f32] {
    &v[i * per_example..(i + 1) * per_example]
}

/// The fc-layer tap term (||a_i||² + 1)·||δ_i||², f64-accumulated —
/// exact for a dense layer, and the single definition all three norm
/// routes (`sq_norms`, `gram_sq_norms`, `tap_bound_sq_norms`) share
/// so they cannot silently desynchronize.
fn fc_tap_sq(input: &[f32], deltas: &[f32], i: usize, din: usize, dout: usize) -> f64 {
    let a = example_rows(input, i, din);
    let d = example_rows(deltas, i, dout);
    let a2: f64 = a.iter().map(|&v| (v as f64) * (v as f64)).sum();
    let d2: f64 = d.iter().map(|&v| (v as f64) * (v as f64)).sum();
    (a2 + 1.0) * d2
}

/// Exact per-example squared gradient norms — the direct route: per
/// conv layer, materialize the small K x cout product A_iᵀ·Δ_i per
/// example and take its Frobenius norm (plus the bias column-sum
/// term); per fc layer, the MLP tap trick. Terms land in the `out`
/// slab (len = batch × `norm_slots().len()`, example-major): a conv
/// layer's weight and bias terms fill its two slots, an fc layer its
/// one — summing a row in ascending slot order replays the legacy
/// whole-model f64 addition sequence exactly. Parallel over examples
/// writing disjoint scratch chunks (`ex_w`/`ex_work`/`ex_b`);
/// per-example work has a fixed order, so the result is bitwise
/// deterministic — and the warm path allocates nothing.
pub fn sq_norms(spec: &ConvSpec, s: &mut ConvScratch, out: &mut [f64]) {
    let b = s.b;
    let ns = spec.slots.len();
    debug_assert_eq!(out.len(), b * ns);
    let (max_w, max_b, _) = spec.conv_partial_dims();
    let ConvScratch {
        x_hwc, patches, acts, deltas, ex_w, ex_work, ex_b, ..
    } = s;
    if ex_w.len() < b * max_w {
        ex_w.resize(b * max_w, 0.0);
        ex_work.resize(b * max_w, 0.0);
    }
    if ex_b.len() < b * max_b {
        ex_b.resize(b * max_b, 0.0);
    }
    // downgrade the read-only fields to shared refs: the parallel
    // closure must be Sync, and a captured `&mut` is not
    let (x_hwc, patches, acts, deltas) =
        (&*x_hwc, &*patches, &*acts, &*deltas);
    out.par_chunks_mut(ns)
        .zip(ex_w.par_chunks_mut(max_w))
        .zip(ex_work.par_chunks_mut(max_w))
        .zip(ex_b.par_chunks_mut(max_b))
        .enumerate()
        .for_each(|(i, (((row, wbuf), workbuf), bbuf))| {
            for l in 0..spec.n_layers() {
                let base = spec.slot_base[l];
                match spec.layers[l] {
                    Layer::Conv { cin, cout, k, h_out, w_out, .. } => {
                        let p = h_out * w_out;
                        let kdim = cin * k * k;
                        let delta = example_rows(&deltas[l], i, p * cout);
                        let pat = example_rows(&patches[l], i, p * kdim);
                        let mbuf = &mut wbuf[..cout * kdim];
                        mbuf.iter_mut().for_each(|v| *v = 0.0);
                        // M = Δ_iᵀ · A_i, reduced over the P positions
                        // in f64 — the same kernel the gradient
                        // assembly and multiloss materialization use,
                        // so every method reports identical norms
                        gemm::sgemm_tn_f64acc(
                            cout,
                            p,
                            kdim,
                            delta,
                            None,
                            pat,
                            mbuf,
                            &mut workbuf[..cout * kdim],
                        );
                        row[base] = mbuf
                            .iter()
                            .map(|&v| (v as f64) * (v as f64))
                            .sum::<f64>();
                        let bias = &mut bbuf[..cout];
                        bias.iter_mut().for_each(|v| *v = 0.0);
                        gemm::col_sums(p, cout, delta, None, bias);
                        row[base + 1] = bias
                            .iter()
                            .map(|&v| (v as f64) * (v as f64))
                            .sum::<f64>();
                    }
                    Layer::Pool { .. } => {}
                    Layer::Fc { din, dout } => {
                        let input: &[f32] =
                            if l == 0 { x_hwc } else { &acts[l - 1] };
                        row[base] =
                            fc_tap_sq(input, &deltas[l], i, din, dout);
                    }
                }
            }
        });
}

/// Exact per-example squared gradient norms — the Gram route (paper
/// Sec 5.2): per conv layer, form the P x P position Grams A_i·A_iᵀ
/// and Δ_i·Δ_iᵀ and sum their Hadamard product; the all-ones bias
/// "tap" contributes Σ_pq (Δ_i·Δ_iᵀ)_pq. The off-diagonal terms are
/// exactly what weight sharing adds over the MLP diagonal. Parallel
/// over examples, Gram buffers in the scratch (`ex_ga`/`ex_gd`).
pub fn gram_sq_norms(spec: &ConvSpec, s: &mut ConvScratch, out: &mut [f64]) {
    let b = s.b;
    let ns = spec.slots.len();
    debug_assert_eq!(out.len(), b * ns);
    let (_, _, max_p2) = spec.conv_partial_dims();
    let ConvScratch { x_hwc, patches, acts, deltas, ex_ga, ex_gd, .. } = s;
    if ex_ga.len() < b * max_p2 {
        ex_ga.resize(b * max_p2, 0.0);
        ex_gd.resize(b * max_p2, 0.0);
    }
    // shared views for the Sync parallel closure (see sq_norms)
    let (x_hwc, patches, acts, deltas) =
        (&*x_hwc, &*patches, &*acts, &*deltas);
    out.par_chunks_mut(ns)
        .zip(ex_ga.par_chunks_mut(max_p2))
        .zip(ex_gd.par_chunks_mut(max_p2))
        .enumerate()
        .for_each(|(i, ((row, gabuf), gdbuf))| {
            for l in 0..spec.n_layers() {
                let base = spec.slot_base[l];
                match spec.layers[l] {
                    Layer::Conv { cin, cout, k, h_out, w_out, .. } => {
                        let p = h_out * w_out;
                        let kdim = cin * k * k;
                        let delta = example_rows(&deltas[l], i, p * cout);
                        let pat = example_rows(&patches[l], i, p * kdim);
                        let ga = &mut gabuf[..p * p];
                        ga.iter_mut().for_each(|v| *v = 0.0);
                        let gd = &mut gdbuf[..p * p];
                        gd.iter_mut().for_each(|v| *v = 0.0);
                        gemm::sgemm_nt(p, kdim, p, pat, pat, ga);
                        gemm::sgemm_nt(p, cout, p, delta, delta, gd);
                        let mut w_term = 0.0f64;
                        let mut b_term = 0.0f64;
                        for (&gav, &gdv) in ga.iter().zip(gd.iter()) {
                            w_term += (gav as f64) * (gdv as f64);
                            b_term += gdv as f64;
                        }
                        // this route computes the conv layer's terms
                        // jointly as one addend — it fills the first
                        // slot and pads the second with the +0.0
                        // identity (the slab contract)
                        row[base] = w_term + b_term;
                        row[base + 1] = 0.0;
                    }
                    Layer::Pool { .. } => {}
                    Layer::Fc { din, dout } => {
                        let input: &[f32] =
                            if l == 0 { x_hwc } else { &acts[l - 1] };
                        row[base] =
                            fc_tap_sq(input, &deltas[l], i, din, dout);
                    }
                }
            }
        });
}

/// The row-norm-product upper bound: Σ_l (||A_{l,i}||²_F + P_l) ·
/// ||Δ_{l,i}||²_F (the +P_l augments the bias's all-ones tap column).
/// Exact on fc layers, a strict overestimate wherever an example's
/// patches overlap — see the module docs. Never used to clip.
pub fn tap_bound_sq_norms(spec: &ConvSpec, s: &ConvScratch, out: &mut [f64]) {
    let b = s.b;
    let ns = spec.slots.len();
    debug_assert_eq!(out.len(), b * ns);
    for l in 0..spec.n_layers() {
        let base = spec.slot_base[l];
        match spec.layers[l] {
            Layer::Conv { cin, cout, k, h_out, w_out, .. } => {
                let p = h_out * w_out;
                let kdim = cin * k * k;
                for i in 0..b {
                    let patches = example_rows(&s.patches[l], i, p * kdim);
                    let delta = example_rows(&s.deltas[l], i, p * cout);
                    let a2: f64 = patches
                        .iter()
                        .map(|&v| (v as f64) * (v as f64))
                        .sum();
                    let d2: f64 = delta
                        .iter()
                        .map(|&v| (v as f64) * (v as f64))
                        .sum();
                    // one joint addend per conv layer: first slot
                    // carries it, the second takes the +0.0 pad
                    out[i * ns + base] = (a2 + p as f64) * d2;
                    out[i * ns + base + 1] = 0.0;
                }
            }
            Layer::Pool { .. } => {}
            Layer::Fc { din, dout } => {
                let input: &[f32] =
                    if l == 0 { &s.x_hwc } else { &s.acts[l - 1] };
                for i in 0..b {
                    out[i * ns + base] =
                        fc_tap_sq(input, &s.deltas[l], i, din, dout);
                }
            }
        }
    }
}

/// Scale every delta element of example i by its layer group's clip
/// factor in place (the `reweight_direct` assembly — conv examples own
/// P rows per layer). Pool deltas are intermediate-only (no params,
/// never read by the assembly) and are skipped.
pub fn scale_delta_rows(spec: &ConvSpec, nu: &NuBlock<'_>, s: &mut ConvScratch) {
    for l in 0..spec.n_layers() {
        let Some(p) = spec.park[l] else { continue };
        let per_example = spec.layers[l].elems_per_example();
        let d = &mut s.deltas[l];
        for (i, &wv) in nu.layer(p).iter().enumerate() {
            for v in d[i * per_example..(i + 1) * per_example].iter_mut() {
                *v *= wv;
            }
        }
    }
}

/// Accumulate the batch-summed gradients from the current deltas into
/// the arena: conv grads via Δᵀ·patches, fc grads as in the MLP.
/// With `scale` (per example, the `reweight_pallas` path) the clip
/// factor is fused into the reductions — conv layers apply it
/// uniformly over the P patch rows each example owns.
///
/// Conv layers keep the **per-example association**: example i's
/// contribution is the f64-reduced Δ_iᵀ·A_i (`sgemm_tn_f64acc`), so
/// the assembly matches the multiloss materialization and the nxBP
/// coordinator loop, and the cross-method float divergence stays
/// batch-sized instead of growing with B·P. A cout x K output fills
/// only one GEMM tile, so the reduction itself cannot parallelize —
/// instead the per-example partials are computed **on all cores**
/// (disjoint `ex_w`/`ex_b` chunks) and merged into the gradient in
/// ascending example order, which preserves both the determinism
/// contract and the example-order float association of the old serial
/// loop.
pub fn grads_from_deltas(
    spec: &ConvSpec,
    s: &mut ConvScratch,
    scale: Option<&NuBlock<'_>>,
    grads: &mut GradVec,
) {
    let b = s.b;
    let (max_w, max_b, _) = spec.conv_partial_dims();
    let ConvScratch {
        x_hwc, patches, acts, deltas, ex_w, ex_work, ex_b, ..
    } = s;
    if ex_w.len() < b * max_w {
        ex_w.resize(b * max_w, 0.0);
        ex_work.resize(b * max_w, 0.0);
    }
    if ex_b.len() < b * max_b {
        ex_b.resize(b * max_b, 0.0);
    }
    // shared views for the Sync parallel closure (see sq_norms)
    let (x_hwc, patches, acts, deltas) =
        (&*x_hwc, &*patches, &*acts, &*deltas);
    for l in 0..spec.n_layers() {
        let Some(pi) = spec.park[l] else { continue };
        let scale_l = scale.map(|nb| nb.layer(pi));
        match spec.layers[l] {
            Layer::Conv { cin, cout, k, h_out, w_out, .. } => {
                let p = h_out * w_out;
                let kdim = cin * k * k;
                let wlen = cout * kdim;
                // per-example f64 partials, all cores
                ex_w.par_chunks_mut(max_w)
                    .zip(ex_work.par_chunks_mut(max_w))
                    .zip(ex_b.par_chunks_mut(max_b))
                    .enumerate()
                    .for_each(|(i, ((wbuf, workbuf), bbuf))| {
                        let delta = example_rows(&deltas[l], i, p * cout);
                        let pat = example_rows(&patches[l], i, p * kdim);
                        let wpart = &mut wbuf[..wlen];
                        wpart.iter_mut().for_each(|v| *v = 0.0);
                        let bpart = &mut bbuf[..cout];
                        bpart.iter_mut().for_each(|v| *v = 0.0);
                        let work = &mut workbuf[..wlen];
                        match scale_l {
                            Some(nu) => {
                                gemm::sgemm_tn_f64acc_uniform(
                                    cout, p, kdim, delta, nu[i], pat, wpart,
                                    work,
                                );
                                gemm::col_sums_uniform(
                                    p, cout, delta, nu[i], bpart,
                                );
                            }
                            None => {
                                gemm::sgemm_tn_f64acc(
                                    cout, p, kdim, delta, None, pat, wpart,
                                    work,
                                );
                                gemm::col_sums(p, cout, delta, None, bpart);
                            }
                        }
                    });
                // ascending-example merge into the arena
                let gw = grads.param_mut(2 * pi);
                for i in 0..b {
                    let wpart = &ex_w[i * max_w..i * max_w + wlen];
                    for (g, &v) in gw.iter_mut().zip(wpart) {
                        *g += v;
                    }
                }
                let gb = grads.param_mut(2 * pi + 1);
                for i in 0..b {
                    let bpart = &ex_b[i * max_b..i * max_b + cout];
                    for (g, &v) in gb.iter_mut().zip(bpart) {
                        *g += v;
                    }
                }
            }
            Layer::Pool { .. } => unreachable!("pool layers carry no params"),
            Layer::Fc { din, dout } => {
                let input: &[f32] = if l == 0 { x_hwc } else { &acts[l - 1] };
                let delta = &deltas[l];
                match scale_l {
                    Some(nu) => gemm::sgemm_tn_scaled(
                        din,
                        b,
                        dout,
                        input,
                        nu,
                        delta,
                        grads.param_mut(2 * pi),
                    ),
                    None => gemm::sgemm_tn(
                        din,
                        b,
                        dout,
                        input,
                        delta,
                        grads.param_mut(2 * pi),
                    ),
                }
                gemm::col_sums(
                    b,
                    dout,
                    delta,
                    scale_l,
                    grads.param_mut(2 * pi + 1),
                );
            }
        }
    }
}

/// Materialize example i's full gradient into the arena (overwriting),
/// returning its squared norm from the materialized values — the
/// multiLoss structure. The conv weight blocks run the same
/// per-example Δᵀ·A reduction as `sq_norms`, so the reported norms
/// agree bitwise with the direct route. `work` is the caller's
/// grow-only f64 workspace (multiloss chunks own one each, so this is
/// safe to run concurrently over distinct examples).
pub fn materialize_grad_row(
    spec: &ConvSpec,
    s: &ConvScratch,
    i: usize,
    out: &mut GradVec,
    work: &mut Vec<f64>,
) -> f64 {
    let (max_w, _, _) = spec.conv_partial_dims();
    if work.len() < max_w {
        work.resize(max_w, 0.0);
    }
    let mut sq = 0.0f64;
    for l in 0..spec.n_layers() {
        let Some(pi) = spec.park[l] else { continue };
        match spec.layers[l] {
            Layer::Conv { cin, cout, k, h_out, w_out, .. } => {
                let p = h_out * w_out;
                let kdim = cin * k * k;
                let delta = example_rows(&s.deltas[l], i, p * cout);
                let patches = example_rows(&s.patches[l], i, p * kdim);
                let gw = out.param_mut(2 * pi);
                gw.iter_mut().for_each(|v| *v = 0.0);
                gemm::sgemm_tn_f64acc(
                    cout,
                    p,
                    kdim,
                    delta,
                    None,
                    patches,
                    gw,
                    &mut work[..cout * kdim],
                );
                sq += gw.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>();
                let gb = out.param_mut(2 * pi + 1);
                gb.iter_mut().for_each(|v| *v = 0.0);
                gemm::col_sums(p, cout, delta, None, gb);
                sq += gb.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>();
            }
            Layer::Pool { .. } => unreachable!("pool layers carry no params"),
            Layer::Fc { din, dout } => {
                let input: &[f32] =
                    if l == 0 { &s.x_hwc } else { &s.acts[l - 1] };
                let a = example_rows(input, i, din);
                let d = example_rows(&s.deltas[l], i, dout);
                let gw = out.param_mut(2 * pi);
                for (kk, &xk) in a.iter().enumerate() {
                    let row = &mut gw[kk * dout..(kk + 1) * dout];
                    for (g, &dv) in row.iter_mut().zip(d.iter()) {
                        *g = xk * dv;
                        sq += (*g as f64) * (*g as f64);
                    }
                }
                let gb = out.param_mut(2 * pi + 1);
                for (g, &dv) in gb.iter_mut().zip(d.iter()) {
                    *g = dv;
                    sq += (*g as f64) * (*g as f64);
                }
            }
        }
    }
    sq
}

// ---------------------------------------------------------------------
// ModelFamily registration (taps::FamilyRegistry "cnn")
// ---------------------------------------------------------------------

impl ModelFamily for ConvSpec {
    fn family(&self) -> &'static str {
        "cnn"
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn d_in(&self) -> usize {
        self.d_in
    }

    fn n_classes(&self) -> usize {
        self.n_classes
    }

    fn grad_layout(&self) -> Vec<usize> {
        self.grad_lens()
    }

    /// Two slots per conv layer (weight term, then bias term), one per
    /// fc layer, none for pool stages.
    fn norm_slots(&self) -> Vec<usize> {
        self.slots.clone()
    }

    fn validate_params(&self, config: &str, host: &[Vec<f32>]) -> Result<()> {
        self.check_params(config, host)
    }

    fn new_scratch(&self) -> Box<ScratchAny> {
        Box::new(ConvScratch::for_spec(self, self.batch))
    }

    fn forward_batch(
        &self,
        params: &[Vec<f32>],
        x: &[f32],
        labels: &[i32],
        s: &mut ScratchAny,
    ) -> (f64, usize) {
        let scr = downcast_scratch::<ConvScratch>(s, "cnn");
        forward_batch(self, params, x, labels, scr)
    }

    fn backward_batch(
        &self,
        params: &[Vec<f32>],
        labels: &[i32],
        nu: Option<&[f32]>,
        s: &mut ScratchAny,
    ) {
        let scr = downcast_scratch::<ConvScratch>(s, "cnn");
        backward_batch(self, params, labels, nu, scr)
    }

    /// The network input is not needed — the scratch holds the HWC
    /// rearrangement from the forward pass.
    fn sq_norms(&self, _x: &[f32], s: &mut ScratchAny, out: &mut [f64]) {
        let scr = downcast_scratch::<ConvScratch>(s, "cnn");
        sq_norms(self, scr, out)
    }

    fn gram_sq_norms(&self, _x: &[f32], s: &mut ScratchAny, out: &mut [f64]) {
        let scr = downcast_scratch::<ConvScratch>(s, "cnn");
        gram_sq_norms(self, scr, out)
    }

    fn tap_bound_sq_norms(&self, _x: &[f32], s: &mut ScratchAny, out: &mut [f64]) {
        let scr = downcast_scratch::<ConvScratch>(s, "cnn");
        tap_bound_sq_norms(self, scr, out)
    }

    fn scale_delta_rows(&self, nu: &NuBlock<'_>, s: &mut ScratchAny) {
        let scr = downcast_scratch::<ConvScratch>(s, "cnn");
        scale_delta_rows(self, nu, scr)
    }

    fn grads_from_deltas(
        &self,
        _x: &[f32],
        s: &mut ScratchAny,
        scale: Option<&NuBlock<'_>>,
        grads: &mut GradVec,
    ) {
        let scr = downcast_scratch::<ConvScratch>(s, "cnn");
        grads_from_deltas(self, scr, scale, grads)
    }

    fn materialize_grad_row(
        &self,
        _x: &[f32],
        s: &ScratchAny,
        i: usize,
        out: &mut GradVec,
        work: &mut Vec<f64>,
    ) -> f64 {
        let scr = downcast_scratch_ref::<ConvScratch>(s, "cnn");
        materialize_grad_row(self, scr, i, out, work)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::ChaCha20;
    use crate::runtime::manifest::ParamSpec;
    use std::collections::BTreeMap;

    /// conv(1->2, 3x3 s2 p1) on 1x6x6 -> 3x3x2, fc 18 -> 3.
    fn tiny_cnn_cfg() -> ConfigSpec {
        ConfigSpec {
            name: "tiny_cnn_b2".into(),
            model: "cnn".into(),
            dataset: "mnist".into(),
            batch: 2,
            n_classes: 3,
            tags: vec![],
            input_shape: vec![2, 1, 6, 6],
            input_dtype: "f32".into(),
            act_elems_per_example: 3 * 3 * 2 + 3,
            conv: Some(ConvMeta { kernel: 3, stride: 2, pad: 1, pool: 0 }),
            spec: None,
            params: vec![
                ParamSpec { name: "conv0.w".into(), shape: vec![2, 1, 3, 3] },
                ParamSpec { name: "conv0.b".into(), shape: vec![2] },
                ParamSpec { name: "fc.w".into(), shape: vec![18, 3] },
                ParamSpec { name: "fc.b".into(), shape: vec![3] },
            ],
            artifacts: BTreeMap::new(),
        }
    }

    /// Two stacked convs (exercises the col2im backprop boundary):
    /// conv(1->2) on 1x7x7 -> 4x4, conv(2->3) -> 2x2, fc 12 -> 3.
    fn deep_cnn_cfg() -> ConfigSpec {
        ConfigSpec {
            name: "deep_cnn_b3".into(),
            model: "cnn".into(),
            dataset: "mnist".into(),
            batch: 3,
            n_classes: 3,
            tags: vec![],
            input_shape: vec![3, 1, 7, 7],
            input_dtype: "f32".into(),
            act_elems_per_example: 4 * 4 * 2 + 2 * 2 * 3 + 3,
            conv: Some(ConvMeta { kernel: 3, stride: 2, pad: 1, pool: 0 }),
            spec: None,
            params: vec![
                ParamSpec { name: "conv0.w".into(), shape: vec![2, 1, 3, 3] },
                ParamSpec { name: "conv0.b".into(), shape: vec![2] },
                ParamSpec { name: "conv1.w".into(), shape: vec![3, 2, 3, 3] },
                ParamSpec { name: "conv1.b".into(), shape: vec![3] },
                ParamSpec { name: "fc.w".into(), shape: vec![12, 3] },
                ParamSpec { name: "fc.b".into(), shape: vec![3] },
            ],
            artifacts: BTreeMap::new(),
        }
    }

    /// conv(1->2, 3x3 s1 p1) on 1x6x6 -> 6x6x2, avg-pool 2 -> 3x3x2,
    /// fc 18 -> 3 — the stride-1+pool geometry the pool stage unlocks.
    fn pooled_cnn_cfg() -> ConfigSpec {
        ConfigSpec {
            name: "pooled_cnn_b2".into(),
            model: "cnn".into(),
            dataset: "mnist".into(),
            batch: 2,
            n_classes: 3,
            tags: vec![],
            input_shape: vec![2, 1, 6, 6],
            input_dtype: "f32".into(),
            act_elems_per_example: 6 * 6 * 2 + 3 * 3 * 2 + 3,
            conv: Some(ConvMeta { kernel: 3, stride: 1, pad: 1, pool: 2 }),
            spec: None,
            params: vec![
                ParamSpec { name: "conv0.w".into(), shape: vec![2, 1, 3, 3] },
                ParamSpec { name: "conv0.b".into(), shape: vec![2] },
                ParamSpec { name: "fc.w".into(), shape: vec![18, 3] },
                ParamSpec { name: "fc.b".into(), shape: vec![3] },
            ],
            artifacts: BTreeMap::new(),
        }
    }

    fn rand_params(spec: &ConvSpec, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = ChaCha20::seeded(seed, 42);
        spec.layers
            .iter()
            .filter(|l| !matches!(l, Layer::Pool { .. }))
            .flat_map(|l| {
                vec![
                    (0..l.cols() * l.k_dim())
                        .map(|_| rng.next_f32() - 0.5)
                        .collect::<Vec<f32>>(),
                    (0..l.cols()).map(|_| rng.next_f32() - 0.5).collect(),
                ]
            })
            .collect()
    }

    /// Whole-model squared norms from a slab: per-example ascending-
    /// slot row sums (what the global policy's reduce does).
    fn slab_row_sums(slab: &[f64], b: usize, ns: usize) -> Vec<f64> {
        (0..b).map(|i| slab[i * ns..(i + 1) * ns].iter().sum()).collect()
    }

    fn rand_input(spec: &ConvSpec, b: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let mut rng = ChaCha20::seeded(seed, 7);
        let x: Vec<f32> = (0..b * spec.d_in)
            .map(|_| rng.next_f32() * 2.0 - 1.0)
            .collect();
        let labels: Vec<i32> = (0..b)
            .map(|_| (rng.next_u32() % spec.n_classes as u32) as i32)
            .collect();
        (x, labels)
    }

    #[test]
    fn spec_parses_and_validates() {
        let cfg = tiny_cnn_cfg();
        let spec = ConvSpec::from_config(&cfg).unwrap();
        assert_eq!(spec.d_in, 36);
        assert_eq!(spec.layers.len(), 2);
        assert_eq!(
            spec.layers[0],
            Layer::Conv {
                cin: 1, cout: 2, k: 3, stride: 2, pad: 1,
                h_in: 6, w_in: 6, h_out: 3, w_out: 3,
            }
        );
        assert_eq!(spec.layers[1], Layer::Fc { din: 18, dout: 3 });

        // channel-chain mismatch rejected
        let mut bad = cfg.clone();
        bad.params[0].shape = vec![2, 4, 3, 3];
        assert!(ConvSpec::from_config(&bad).is_err());
        // fc in-dim mismatch rejected
        let mut bad = cfg.clone();
        bad.params[2].shape = vec![20, 3];
        assert!(ConvSpec::from_config(&bad).is_err());
        // wrong family rejected
        let mut bad = cfg.clone();
        bad.model = "mlp".into();
        assert!(ConvSpec::from_config(&bad).is_err());
        // all-fc (no conv layer) rejected
        let mut bad = cfg.clone();
        bad.params = vec![
            ParamSpec { name: "fc.w".into(), shape: vec![36, 3] },
            ParamSpec { name: "fc.b".into(), shape: vec![3] },
        ];
        assert!(ConvSpec::from_config(&bad).is_err());
    }

    #[test]
    fn pooled_spec_inserts_parameterless_pool_stages() {
        let cfg = pooled_cnn_cfg();
        let spec = ConvSpec::from_config(&cfg).unwrap();
        assert_eq!(spec.layers.len(), 3);
        assert_eq!(
            spec.layers[1],
            Layer::Pool { c: 2, h_in: 6, w_in: 6, h_out: 3, w_out: 3, k: 2 }
        );
        assert_eq!(spec.layers[2], Layer::Fc { din: 18, dout: 3 });
        // the chain is 3 long but only 2 layers are parametric
        assert_eq!(spec.park, vec![Some(0), None, Some(1)]);
        assert_eq!(spec.n_param_layers(), 2);
        assert_eq!(spec.grad_lens(), vec![2 * 9, 2, 18 * 3, 3]);
        // slab: conv owns two slots, pool none, fc one
        assert_eq!(spec.slots, vec![0, 0, 1]);

        // a pool wider than the conv output map is rejected
        let mut bad = cfg.clone();
        bad.conv = Some(ConvMeta { kernel: 3, stride: 2, pad: 0, pool: 4 });
        assert!(ConvSpec::from_config(&bad).is_err());
    }

    /// The ground-truth check the conv family rests on: batch-summed
    /// gradients from backward_batch + grads_from_deltas match central
    /// finite differences of the batch loss sum, through both the
    /// single-conv and the stacked-conv (col2im) nets.
    #[test]
    fn conv_gradients_match_finite_differences() {
        for cfg in [tiny_cnn_cfg(), deep_cnn_cfg(), pooled_cnn_cfg()] {
            let spec = ConvSpec::from_config(&cfg).unwrap();
            let b = spec.batch;
            let params = rand_params(&spec, 11);
            let (x, labels) = rand_input(&spec, b, 5);

            let mut s = ConvScratch::for_spec(&spec, b);
            forward_batch(&spec, &params, &x, &labels, &mut s);
            backward_batch(&spec, &params, &labels, None, &mut s);
            let mut grads = GradVec::with_layout(&spec.grad_lens());
            grads_from_deltas(&spec, &mut s, None, &mut grads);

            // eps: small enough that a pre-activation sitting near a
            // ReLU kink (a bias nudge shifts a whole channel) cannot
            // bend the central difference, large enough that the f32
            // forward's rounding stays far below the tolerance
            let eps = 1e-4f32;
            let mut scratch = ConvScratch::for_spec(&spec, b);
            for t in 0..params.len() {
                for idx in [0usize, params[t].len() / 2, params[t].len() - 1] {
                    let mut p_hi = params.clone();
                    p_hi[t][idx] += eps;
                    let (l_hi, _) =
                        forward_batch(&spec, &p_hi, &x, &labels, &mut scratch);
                    let mut p_lo = params.clone();
                    p_lo[t][idx] -= eps;
                    let (l_lo, _) =
                        forward_batch(&spec, &p_lo, &x, &labels, &mut scratch);
                    let fd = ((l_hi - l_lo) / (2.0 * eps as f64)) as f32;
                    let an = grads.param(t)[idx];
                    assert!(
                        (fd - an).abs() < 3e-3 * (1.0 + an.abs()),
                        "{}: param {t}[{idx}]: finite-diff {fd} vs analytic {an}",
                        cfg.name
                    );
                }
            }
        }
    }

    /// Norm routes: direct == gram == materialized (all exact), and
    /// the tap product bounds them from above — strictly, on conv
    /// layers with overlapping patches.
    #[test]
    fn norm_routes_agree_and_tap_bounds_them() {
        for cfg in [deep_cnn_cfg(), pooled_cnn_cfg()] {
            let spec = ConvSpec::from_config(&cfg).unwrap();
            let b = spec.batch;
            let ns = spec.slots.len();
            let params = rand_params(&spec, 23);
            let (x, labels) = rand_input(&spec, b, 9);
            let mut s = ConvScratch::for_spec(&spec, b);
            forward_batch(&spec, &params, &x, &labels, &mut s);
            backward_batch(&spec, &params, &labels, None, &mut s);

            let mut direct_slab = vec![0.0f64; b * ns];
            sq_norms(&spec, &mut s, &mut direct_slab);
            let mut gram_slab = vec![0.0f64; b * ns];
            gram_sq_norms(&spec, &mut s, &mut gram_slab);
            let mut tap_slab = vec![0.0f64; b * ns];
            tap_bound_sq_norms(&spec, &s, &mut tap_slab);
            let direct = slab_row_sums(&direct_slab, b, ns);
            let gram = slab_row_sums(&gram_slab, b, ns);
            let tap = slab_row_sums(&tap_slab, b, ns);
            // per-slot: gram folds each conv layer's two terms into
            // its first slot, so compare per parametric layer
            for i in 0..b {
                for (pl, dv) in (0..spec.n_param_layers()).map(|pl| {
                    let layer_sum = |slab: &[f64]| -> f64 {
                        spec.slots
                            .iter()
                            .enumerate()
                            .filter(|&(_, &sl)| sl == pl)
                            .map(|(slot, _)| slab[i * ns + slot])
                            .sum()
                    };
                    (pl, (layer_sum(&direct_slab), layer_sum(&gram_slab)))
                }) {
                    let (d, g) = dv;
                    assert!(
                        (d - g).abs() / d.max(1e-9) < 1e-5,
                        "{}: layer {pl} term: direct {d} vs gram {g}",
                        cfg.name
                    );
                }
            }
            let mut mat = GradVec::with_layout(&spec.grad_lens());
            let mut work: Vec<f64> = Vec::new();
            for i in 0..b {
                let sq_mat =
                    materialize_grad_row(&spec, &s, i, &mut mat, &mut work);
                assert!(
                    (direct[i] - sq_mat).abs() / sq_mat.max(1e-9) < 1e-6,
                    "{}: direct {} vs materialized {sq_mat} (example {i})",
                    cfg.name,
                    direct[i]
                );
                assert!(
                    (gram[i] - sq_mat).abs() / sq_mat.max(1e-9) < 1e-5,
                    "{}: gram {} vs materialized {sq_mat} (example {i})",
                    cfg.name,
                    gram[i]
                );
                // the bound is a true bound...
                assert!(
                    tap[i] >= gram[i] * (1.0 - 1e-9),
                    "{}: tap bound {} below exact {} (example {i})",
                    cfg.name,
                    tap[i],
                    gram[i]
                );
            }
            // ...and strictly loose on these nets (patches overlap)
            let slack: f64 =
                (0..b).map(|i| tap[i] / gram[i]).sum::<f64>() / b as f64;
            assert!(
                slack > 1.001,
                "{}: tap bound unexpectedly tight: mean ratio {slack}",
                cfg.name
            );
        }
    }

    /// The three weighted-assembly routes agree: a nu-weighted second
    /// backward, nu-scaling the tapped deltas in place, and fusing nu
    /// into the gradient GEMM — the conv-side guarantee behind
    /// reweight / reweight_direct / reweight_pallas.
    #[test]
    fn weighted_assembly_routes_agree() {
        for cfg in [deep_cnn_cfg(), pooled_cnn_cfg()] {
            let spec = ConvSpec::from_config(&cfg).unwrap();
            let b = spec.batch;
            let params = rand_params(&spec, 31);
            let (x, labels) = rand_input(&spec, b, 13);
            let nu: Vec<f32> = (0..b).map(|i| 0.2 + 0.3 * i as f32).collect();
            let groups = vec![0usize; spec.n_param_layers()];
            let block = NuBlock { nu: &nu, groups: &groups, b };

            // route 1: second backward of the nu-weighted loss
            let mut s1 = ConvScratch::for_spec(&spec, b);
            forward_batch(&spec, &params, &x, &labels, &mut s1);
            backward_batch(&spec, &params, &labels, Some(&nu), &mut s1);
            let mut g1 = GradVec::with_layout(&spec.grad_lens());
            grads_from_deltas(&spec, &mut s1, None, &mut g1);

            // route 2: one backward, deltas nu-scaled in place
            let mut s2 = ConvScratch::for_spec(&spec, b);
            forward_batch(&spec, &params, &x, &labels, &mut s2);
            backward_batch(&spec, &params, &labels, None, &mut s2);
            let mut g3 = GradVec::with_layout(&spec.grad_lens());
            // route 3 first (fused), from the unscaled deltas
            grads_from_deltas(&spec, &mut s2, Some(&block), &mut g3);
            scale_delta_rows(&spec, &block, &mut s2);
            let mut g2 = GradVec::with_layout(&spec.grad_lens());
            grads_from_deltas(&spec, &mut s2, None, &mut g2);

            for (&av, &bv) in g1.flat().iter().zip(g2.flat()) {
                assert!(
                    (av - bv).abs() < 1e-5,
                    "{}: backward-nu {av} vs scaled-deltas {bv}",
                    cfg.name
                );
            }
            for (&av, &cv) in g2.flat().iter().zip(g3.flat()) {
                assert!(
                    (av - cv).abs() < 1e-5,
                    "{}: scaled-deltas {av} vs fused {cv}",
                    cfg.name
                );
            }
        }
    }

    /// Group-wise scaling: a two-group NuBlock applied through the
    /// fused assembly equals scaling each materialized per-example
    /// gradient's param-group views independently — the runtime-side
    /// guarantee behind the per_layer/groups policies.
    #[test]
    fn group_blocks_match_per_group_materialized_scaling() {
        let cfg = deep_cnn_cfg();
        let spec = ConvSpec::from_config(&cfg).unwrap();
        let b = spec.batch;
        let np = spec.n_param_layers();
        let params = rand_params(&spec, 41);
        let (x, labels) = rand_input(&spec, b, 43);
        // conv layers in group 0, fc head in group 1
        let groups: Vec<usize> = spec
            .layers
            .iter()
            .filter(|l| !matches!(l, Layer::Pool { .. }))
            .map(|l| matches!(l, Layer::Fc { .. }) as usize)
            .collect();
        assert_eq!(groups.len(), np);
        let n_groups = 2usize;
        let nu: Vec<f32> =
            (0..n_groups * b).map(|i| 0.15 + 0.2 * i as f32).collect();
        let block = NuBlock { nu: &nu, groups: &groups, b };

        let mut s = ConvScratch::for_spec(&spec, b);
        forward_batch(&spec, &params, &x, &labels, &mut s);
        backward_batch(&spec, &params, &labels, None, &mut s);
        let mut fused = GradVec::with_layout(&spec.grad_lens());
        grads_from_deltas(&spec, &mut s, Some(&block), &mut fused);

        let mut mat = GradVec::with_layout(&spec.grad_lens());
        let mut want = GradVec::with_layout(&spec.grad_lens());
        let mut work: Vec<f64> = Vec::new();
        for i in 0..b {
            materialize_grad_row(&spec, &s, i, &mut mat, &mut work);
            for (pl, &g) in groups.iter().enumerate() {
                want.add_scaled_params(
                    &mat,
                    2 * pl,
                    2 * pl + 2,
                    nu[g * b + i],
                );
            }
        }
        for (&fv, &wv) in fused.flat().iter().zip(want.flat()) {
            assert!(
                (fv - wv).abs() < 1e-5,
                "fused group-scaled {fv} vs materialized {wv}"
            );
        }
    }

    /// multiLoss agreement at the conv level: clipped-and-summed
    /// materialized per-example gradients equal the reweighted batched
    /// assembly when nu comes from the same (exact) norms.
    #[test]
    fn materialized_clipped_sum_matches_reweighted_assembly() {
        let cfg = tiny_cnn_cfg();
        let spec = ConvSpec::from_config(&cfg).unwrap();
        let b = spec.batch;
        let params = rand_params(&spec, 3);
        let (x, labels) = rand_input(&spec, b, 17);
        let clip = 0.5f32;

        let mut s = ConvScratch::for_spec(&spec, b);
        forward_batch(&spec, &params, &x, &labels, &mut s);
        backward_batch(&spec, &params, &labels, None, &mut s);
        let ns = spec.slots.len();
        let mut slab = vec![0.0f64; b * ns];
        sq_norms(&spec, &mut s, &mut slab);
        let sq = slab_row_sums(&slab, b, ns);
        let nu: Vec<f32> = sq
            .iter()
            .map(|&v| crate::runtime::clip_factor(v.sqrt() as f32, clip))
            .collect();
        // clipping must actually bite for this to mean anything
        assert!(nu.iter().any(|&v| v < 1.0));
        let groups = vec![0usize; spec.n_param_layers()];
        let block = NuBlock { nu: &nu, groups: &groups, b };

        let mut batched = GradVec::with_layout(&spec.grad_lens());
        grads_from_deltas(&spec, &mut s, Some(&block), &mut batched);

        let mut mat = GradVec::with_layout(&spec.grad_lens());
        let mut summed = GradVec::with_layout(&spec.grad_lens());
        let mut work: Vec<f64> = Vec::new();
        for i in 0..b {
            materialize_grad_row(&spec, &s, i, &mut mat, &mut work);
            summed.add_scaled(&mat, nu[i]);
        }
        for (&av, &mv) in batched.flat().iter().zip(summed.flat()) {
            assert!(
                (av - mv).abs() < 1e-5,
                "batched {av} vs materialized-sum {mv}"
            );
        }
    }

    /// Scratch reuse is clean: running the same step on a dirty
    /// scratch reproduces the fresh-scratch results bitwise.
    #[test]
    fn scratch_reuse_is_bitwise_clean() {
        let cfg = deep_cnn_cfg();
        let spec = ConvSpec::from_config(&cfg).unwrap();
        let b = spec.batch;
        let params = rand_params(&spec, 19);
        let (x, labels) = rand_input(&spec, b, 29);
        let (x2, labels2) = rand_input(&spec, b, 30);

        let run = |s: &mut ConvScratch| {
            let (loss, _) = forward_batch(&spec, &params, &x, &labels, s);
            backward_batch(&spec, &params, &labels, None, s);
            let mut g = GradVec::with_layout(&spec.grad_lens());
            grads_from_deltas(&spec, s, None, &mut g);
            let mut sq = vec![0.0f64; s.b * spec.slots.len()];
            sq_norms(&spec, s, &mut sq);
            (loss, sq, g)
        };
        let mut fresh = ConvScratch::for_spec(&spec, b);
        let want = run(&mut fresh);
        let mut dirty = ConvScratch::for_spec(&spec, b);
        // soil every buffer with an unrelated batch first
        forward_batch(&spec, &params, &x2, &labels2, &mut dirty);
        backward_batch(&spec, &params, &labels2, None, &mut dirty);
        let got = run(&mut dirty);
        assert_eq!(want.0.to_bits(), got.0.to_bits(), "loss");
        assert_eq!(want.1, got.1, "norms");
        assert_eq!(want.2, got.2, "grads");
    }
}
