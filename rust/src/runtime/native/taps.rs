//! The model-family seam of the native backend: an **open registry**
//! of `ModelFamily` tap producers.
//!
//! `NativeStep` executes every clip method against the `ModelFamily`
//! trait, so a family only has to provide batched forward/backward
//! passes that expose per-layer activation ("tap") and delta matrices
//! plus per-layer gradient assembly — the seven clipping strategies,
//! the norm tricks, and the bench matrix then come for free. Families
//! are resolved by the config's `model` string through a name-keyed
//! `FamilyRegistry` on `NativeBackend`: adding a family (attention
//! per-head taps, RNN timestep taps) is one new file implementing the
//! trait plus one `register` call — zero dispatch edits anywhere.
//!
//! Three families register by default (`FamilyRegistry::builtin`):
//!   - `"mlp"` (`native/mlp.rs`, `MlpSpec`): dense layers; taps are
//!     the B x d layer inputs, one row per example.
//!   - `"cnn"` (`native/conv.rs`, `ConvSpec`): conv layers lowered to
//!     im2col patch matrices over the same `gemm` kernels; taps are
//!     (B·P) x K patch matrices, P rows per example.
//!   - `"transformer"` (`native/attention.rs`, `AttnSpec`): a
//!     single-block encoder; taps are (B·T) x d position matrices, T
//!     rows per example — the conv position-Gram structure with
//!     sequence positions in place of patches, plus a one-hot-tap
//!     embedding.
//!
//! # ModelFamily obligations
//!
//! Scratch: `new_scratch` returns the family's whole-batch buffer set,
//! type-erased (`Box<ScratchAny>`); every other method downcasts it
//! back (`downcast_scratch`). All scratch buffers must be **fully
//! rewritten or explicitly cleared** by the passes that use them —
//! `NativeStep` reuses one scratch across steps, and the warm-vs-cold
//! bitwise tests pin that reuse changes no bits. Buffers may grow
//! lazily, but never per-call: after the first (cold) execution the
//! warm path must not allocate (`tests/no_alloc.rs`).
//!
//! Outputs: norm methods write per-layer contributions into a caller
//! **slab** (`out: &mut [f64]`, len = batch × `norm_slots().len()`,
//! example-major) — the clipping *policy* performs the final reduction
//! over slots (`reduce_norm_slab`), which is what lets a group-wise
//! policy keep the per-layer structure the old per-example sum threw
//! away. `grads_from_deltas`/`materialize_grad_row` write into a
//! caller `GradVec` arena via its per-parameter views. Gradient
//! assembly *accumulates* (`+=`) into `grads_from_deltas`'s target —
//! the step zeroes the arena — while `materialize_grad_row`
//! *overwrites* its target completely.
//!
//! # Slab contract (bitwise-compatibility load-bearing)
//!
//! `norm_slots()` declares the slab layout: slot s belongs to
//! parametric layer `norm_slots()[s]`, slots ascend with the layer
//! order, and each slot holds exactly one f64 **addend** of the
//! legacy per-example norm sum (a route with fewer addends for a
//! layer pads its extra slots with +0.0). Reducing a slab row in
//! ascending slot order from +0.0 therefore replays the exact f64
//! addition sequence of the pre-slab routes — the `global` policy is
//! bitwise-identical to the pre-policy code by construction.
//!
//! The norm methods expose the paper's two routes plus the bound that
//! separates them:
//!   - `sq_norms` — the exact per-example squared gradient norms every
//!     clipping method uses (the tap trick on MLPs; the per-example
//!     position reduction on conv, where taps of one example overlap).
//!   - `gram_sq_norms` — the same exact quantity through the
//!     Gram-matrix structure of paper Sec 5.2 (A·Aᵀ ∘ Δ·Δᵀ); on MLPs
//!     this degenerates to the tap trick's diagonal, on conv the
//!     off-diagonal (cross-position) terms are load-bearing.
//!   - `tap_bound_sq_norms` — the plain row-norm product. Equal to
//!     `sq_norms` on MLPs; on conv it is only an *upper bound*
//!     (Cauchy–Schwarz over the overlapping patches), so it must never
//!     be used to clip alongside methods that use the exact norm. Kept
//!     for diagnostics and the tap-vs-gram ordering tests.
//!
//! Determinism: every method must be bitwise deterministic under the
//! gemm module's contract (parallel only over disjoint outputs, fixed
//! reduction orders) — `materialize_grad_row` in particular runs
//! concurrently over examples against a shared scratch.

use crate::runtime::manifest::ConfigSpec;
use crate::runtime::store::GradVec;
use anyhow::{bail, Result};
use std::any::Any;
use std::collections::BTreeMap;

/// A group-blocked nu matrix: per-group per-example clip factors plus
/// the layer → group map, group-major (`nu[g*b + i]` is example i's
/// factor in group g). `layer(l)` yields parametric layer l's len-b
/// factor slice — for a global policy every layer maps to group 0 and
/// the slice is the same one the pre-policy whole-batch code used, so
/// the degenerate case is bitwise-identical.
#[derive(Debug, Clone, Copy)]
pub struct NuBlock<'a> {
    /// group-major factors, len = n_groups · b
    pub nu: &'a [f32],
    /// group index of each parametric layer
    pub groups: &'a [usize],
    pub b: usize,
}

impl NuBlock<'_> {
    /// Parametric layer l's per-example factors (len = b).
    #[inline]
    pub fn layer(&self, l: usize) -> &[f32] {
        &self.nu[self.groups[l] * self.b..][..self.b]
    }
}

/// Reduce a norm slab (b rows × `slot_layers.len()` slots,
/// example-major) into group-major per-group squared norms
/// (`gsq[g*b + i]`). Slots are added in ascending order starting from
/// +0.0 per (group, example) accumulator — with one group this
/// replays the legacy whole-model sum bit-for-bit (see the module
/// docs' slab contract).
pub fn reduce_norm_slab(
    slab: &[f64],
    b: usize,
    slot_layers: &[usize],
    layer_groups: &[usize],
    n_groups: usize,
    gsq: &mut [f64],
) {
    let s = slot_layers.len();
    debug_assert_eq!(slab.len(), b * s);
    debug_assert!(gsq.len() >= n_groups * b);
    gsq[..n_groups * b].iter_mut().for_each(|v| *v = 0.0);
    for i in 0..b {
        let row = &slab[i * s..(i + 1) * s];
        for (slot, &v) in row.iter().enumerate() {
            let g = layer_groups[slot_layers[slot]];
            gsq[g * b + i] += v;
        }
    }
}

/// Type-erased whole-batch scratch for one `ModelFamily`. Concretely a
/// family-private struct (`BatchScratch`, `ConvScratch`, ...); only
/// the owning family looks inside.
pub type ScratchAny = dyn Any + Send + Sync;

/// Downcast a family's scratch back to its concrete type. Panics with
/// the family name on a mismatch — that is a plumbing bug (a scratch
/// can only come from the same family's `new_scratch`), never a user
/// error.
pub fn downcast_scratch<'a, T: 'static>(
    s: &'a mut ScratchAny,
    family: &str,
) -> &'a mut T {
    match s.downcast_mut::<T>() {
        Some(t) => t,
        None => panic!("scratch does not belong to the {family} family"),
    }
}

/// Shared-reference variant of `downcast_scratch` (for the methods
/// that read the scratch concurrently, e.g. `materialize_grad_row`).
pub fn downcast_scratch_ref<'a, T: 'static>(
    s: &'a ScratchAny,
    family: &str,
) -> &'a T {
    match s.downcast_ref::<T>() {
        Some(t) => t,
        None => panic!("scratch does not belong to the {family} family"),
    }
}

/// A model family's batched tap producer, parsed from a manifest
/// config. See the module docs for the full obligations.
pub trait ModelFamily: Send + Sync {
    /// Registry name of this family ("mlp", "cnn", ...).
    fn family(&self) -> &'static str;

    /// The config's batch size (the leading dimension of every pass).
    fn batch(&self) -> usize;

    /// Flat input elements per example.
    fn d_in(&self) -> usize;

    fn n_classes(&self) -> usize;

    /// Per-parameter element counts in manifest order — the gradient
    /// arena layout (`GradVec::ensure_layout`).
    fn grad_layout(&self) -> Vec<usize>;

    /// The norm-slab layout: slot s of a slab row holds one f64 addend
    /// of parametric layer `norm_slots()[s]`'s squared-norm
    /// contribution (see the module docs' slab contract). Layer
    /// indices are parametric (one per (W, b) pair — parameterless
    /// layers such as avg-pool do not appear) and must ascend.
    fn norm_slots(&self) -> Vec<usize>;

    /// Check the param store's tensor count and per-tensor lengths
    /// against the spec; `config` names the config in errors.
    fn validate_params(&self, config: &str, host: &[Vec<f32>]) -> Result<()>;

    /// Allocate this family's whole-batch forward/backward buffers.
    fn new_scratch(&self) -> Box<ScratchAny>;

    /// Batched forward over the staged batch; fills the scratch taps
    /// and returns (f64 loss sum, correct-prediction count).
    fn forward_batch(
        &self,
        params: &[Vec<f32>],
        x: &[f32],
        labels: &[i32],
        s: &mut ScratchAny,
    ) -> (f64, usize);

    /// Batched backward (after `forward_batch`); `nu` runs the
    /// reweighted pass (loss Σ_i nu_i·l_i).
    fn backward_batch(
        &self,
        params: &[Vec<f32>],
        labels: &[i32],
        nu: Option<&[f32]>,
        s: &mut ScratchAny,
    );

    /// Exact per-example squared gradient norms — what every clipping
    /// method uses. Writes per-layer contributions into the `out` slab
    /// (len = batch × `norm_slots().len()`, example-major; see the
    /// slab contract).
    fn sq_norms(&self, x: &[f32], s: &mut ScratchAny, out: &mut [f64]);

    /// Exact per-example squared norms through the Gram-matrix
    /// structure (paper Sec 5.2). Same slab output as `sq_norms`.
    fn gram_sq_norms(&self, x: &[f32], s: &mut ScratchAny, out: &mut [f64]);

    /// The row-norm-product bound: equal to `sq_norms` on MLPs, an
    /// upper bound (tap ≥ gram) under weight sharing. Same slab
    /// output. Diagnostics/tests only — never used to clip.
    fn tap_bound_sq_norms(&self, x: &[f32], s: &mut ScratchAny, out: &mut [f64]);

    /// Scale the delta rows by the policy's clip factors in place (the
    /// `reweight_direct` assembly): example i's rows of parametric
    /// layer l scale by `nu.layer(l)[i]`.
    fn scale_delta_rows(&self, nu: &NuBlock<'_>, s: &mut ScratchAny);

    /// Accumulate the batch-summed gradients from the current deltas
    /// into the arena; `scale` fuses the policy's clip factors into
    /// the reductions (the `reweight_pallas` path), layer l using
    /// `scale.layer(l)`.
    fn grads_from_deltas(
        &self,
        x: &[f32],
        s: &mut ScratchAny,
        scale: Option<&NuBlock<'_>>,
        grads: &mut GradVec,
    );

    /// Materialize example i's full gradient (the multiLoss structure)
    /// into `out`, overwriting it, and return its squared norm. `work`
    /// is a caller-owned grow-only f64 workspace for families whose
    /// per-example reduction needs one (conv); MLPs ignore it. Safe to
    /// call concurrently over distinct `i` against a shared scratch.
    fn materialize_grad_row(
        &self,
        x: &[f32],
        s: &ScratchAny,
        i: usize,
        out: &mut GradVec,
        work: &mut Vec<f64>,
    ) -> f64;
}

/// Builder: parse a manifest config into a family instance. Plain fn
/// pointer so registries stay `Clone` and registration stays a
/// one-liner.
pub type FamilyBuilder = fn(&ConfigSpec) -> Result<Box<dyn ModelFamily>>;

/// Name-keyed `ModelFamily` registry: `NativeBackend` resolves a
/// config's `model` string here, and **only** here — there is no
/// match-on-family-name anywhere outside registration, which is what
/// makes the family set open.
#[derive(Clone)]
pub struct FamilyRegistry {
    builders: BTreeMap<String, FamilyBuilder>,
}

impl FamilyRegistry {
    /// Registry with no families (tests, fully custom backends).
    pub fn empty() -> FamilyRegistry {
        FamilyRegistry { builders: BTreeMap::new() }
    }

    /// The built-in families: `mlp` (dense), `cnn` (im2col conv) and
    /// `transformer` (single-block attention encoder).
    pub fn builtin() -> FamilyRegistry {
        let mut r = FamilyRegistry::empty();
        r.register("mlp", |cfg| {
            Ok(Box::new(super::mlp::MlpSpec::from_config(cfg)?))
        });
        r.register("cnn", |cfg| {
            Ok(Box::new(super::conv::ConvSpec::from_config(cfg)?))
        });
        r.register("transformer", |cfg| {
            Ok(Box::new(super::attention::AttnSpec::from_config(cfg)?))
        });
        r
    }

    /// Register (or replace) the builder for family `name`.
    pub fn register(&mut self, name: &str, builder: FamilyBuilder) {
        self.builders.insert(name.to_string(), builder);
    }

    /// Registered family names, sorted.
    pub fn names(&self) -> Vec<&str> {
        self.builders.keys().map(|s| s.as_str()).collect()
    }

    /// Build the tap producer for `cfg.model`, or a clear error naming
    /// the unknown family and the registered ones.
    pub fn build(&self, cfg: &ConfigSpec) -> Result<Box<dyn ModelFamily>> {
        match self.builders.get(&cfg.model) {
            Some(b) => b(cfg),
            None => bail!(
                "native backend has no registered tap producer for model \
                 family {:?} (config {}); registered families: {:?}",
                cfg.model,
                cfg.name,
                self.names()
            ),
        }
    }
}

impl Default for FamilyRegistry {
    fn default() -> Self {
        FamilyRegistry::builtin()
    }
}

/// Row-wise numerically stable softmax + cross-entropy over b x nc
/// logits: fills `probs`, returns (f64 loss sum, correct-prediction
/// count). Shared by every tap producer; the op order matches the
/// scalar reference in `mlp.rs` exactly, so moving a family onto this
/// helper changes no bits.
pub fn softmax_xent_rows(
    b: usize,
    nc: usize,
    logits: &[f32],
    probs: &mut [f32],
    labels: &[i32],
) -> (f64, usize) {
    debug_assert_eq!(logits.len(), b * nc);
    debug_assert_eq!(probs.len(), b * nc);
    debug_assert_eq!(labels.len(), b);
    let mut loss_sum = 0.0f64;
    let mut correct = 0usize;
    for r in 0..b {
        let row = &logits[r * nc..(r + 1) * nc];
        let prow = &mut probs[r * nc..(r + 1) * nc];
        let mut m = f32::NEG_INFINITY;
        let mut argmax = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > m {
                m = v;
                argmax = j;
            }
        }
        let mut sum = 0.0f64;
        for (p, &z) in prow.iter_mut().zip(row.iter()) {
            let e = ((z - m) as f64).exp();
            *p = e as f32;
            sum += e;
        }
        let inv = (1.0 / sum) as f32;
        for p in prow.iter_mut() {
            *p *= inv;
        }
        let y = labels[r] as usize;
        let loss = sum.ln() as f32 - (row[y] - m);
        loss_sum += loss as f64;
        correct += usize::from(argmax == y);
    }
    (loss_sum, correct)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ParamSpec;
    use std::collections::BTreeMap;

    fn rnn_cfg() -> ConfigSpec {
        ConfigSpec {
            name: "rnn1_mnist_b4".into(),
            model: "rnn".into(),
            dataset: "mnist".into(),
            batch: 4,
            n_classes: 10,
            tags: vec![],
            input_shape: vec![4, 1, 28, 28],
            input_dtype: "f32".into(),
            act_elems_per_example: 0,
            conv: None,
            spec: None,
            params: vec![
                ParamSpec { name: "w".into(), shape: vec![784, 10] },
                ParamSpec { name: "b".into(), shape: vec![10] },
            ],
            artifacts: BTreeMap::new(),
        }
    }

    #[test]
    fn unknown_family_is_a_clear_error() {
        let err = FamilyRegistry::builtin().build(&rnn_cfg()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("rnn") && msg.contains("tap producer"),
            "{msg}"
        );
        // ...and the error lists what *is* registered
        assert!(msg.contains("mlp") && msg.contains("cnn"), "{msg}");
    }

    #[test]
    fn registry_is_open_registration_resolves() {
        // a custom builder registered under a new name resolves; the
        // builtin families stay untouched
        let mut r = FamilyRegistry::builtin();
        assert_eq!(r.names(), vec!["cnn", "mlp", "transformer"]);
        // route "rnn" to the mlp builder as a stand-in: registration
        // alone (no dispatch edits) makes the family resolvable
        fn rnn_as_mlp(
            cfg: &ConfigSpec,
        ) -> Result<Box<dyn ModelFamily>> {
            let mut mlp_cfg = cfg.clone();
            mlp_cfg.model = "mlp".into();
            mlp_cfg.input_shape = vec![cfg.batch, 784];
            Ok(Box::new(super::super::mlp::MlpSpec::from_config(&mlp_cfg)?))
        }
        r.register("rnn", rnn_as_mlp);
        let fam = r.build(&rnn_cfg()).unwrap();
        assert_eq!(fam.batch(), 4);
        assert_eq!(fam.d_in(), 784);
        assert_eq!(fam.grad_layout(), vec![784 * 10, 10]);
    }

    /// Regression for the no-hash-container rule's motivation: family
    /// resolution order must be a pure function of the registered name
    /// set — whatever order registration happened in.
    #[test]
    fn registry_iteration_order_is_stable() {
        fn stub(cfg: &ConfigSpec) -> Result<Box<dyn ModelFamily>> {
            let mut mlp_cfg = cfg.clone();
            mlp_cfg.model = "mlp".into();
            mlp_cfg.input_shape = vec![cfg.batch, 784];
            Ok(Box::new(super::super::mlp::MlpSpec::from_config(&mlp_cfg)?))
        }
        let names = ["zeta", "alpha", "mu", "beta"];
        let mut fwd = FamilyRegistry::empty();
        for n in names {
            fwd.register(n, stub);
        }
        let mut rev = FamilyRegistry::empty();
        for n in names.iter().rev() {
            rev.register(n, stub);
        }
        assert_eq!(fwd.names(), vec!["alpha", "beta", "mu", "zeta"]);
        assert_eq!(fwd.names(), rev.names(), "registration order must not leak");
        // builtin() is likewise sorted, not registration-ordered
        assert_eq!(
            FamilyRegistry::builtin().names(),
            vec!["cnn", "mlp", "transformer"]
        );
    }

    #[test]
    fn softmax_rows_match_uniform_at_zero_logits() {
        let b = 3;
        let nc = 4;
        let logits = vec![0.0f32; b * nc];
        let mut probs = vec![0.0f32; b * nc];
        let labels = vec![1i32, 0, 3];
        let (loss_sum, _) =
            softmax_xent_rows(b, nc, &logits, &mut probs, &labels);
        for &p in &probs {
            assert!((p - 0.25).abs() < 1e-6);
        }
        let want = (4.0f64).ln() * b as f64;
        assert!((loss_sum - want).abs() < 1e-5, "{loss_sum} vs {want}");
    }
}
