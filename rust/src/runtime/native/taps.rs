//! The tap-producer seam: `NativeStep` executes every clip method
//! against this interface, so a model family only has to provide
//! batched forward/backward passes that expose per-layer activation
//! ("tap") and delta matrices plus per-layer gradient assembly — the
//! seven clipping strategies, the norm tricks, and the bench matrix
//! then come for free.
//!
//! Two families ship today:
//!   - `Mlp` (`native/mlp.rs`): dense layers; taps are the B x d
//!     layer inputs, one row per example.
//!   - `Cnn` (`native/conv.rs`): conv layers lowered to im2col patch
//!     matrices over the same `gemm` kernels; taps are (B·P) x K
//!     patch matrices, P rows per example.
//!
//! The norm methods expose the paper's two routes plus the bound that
//! separates them:
//!   - `sq_norms` — the exact per-example squared gradient norms every
//!     clipping method uses (the tap trick on MLPs; the per-example
//!     position reduction on conv, where taps of one example overlap).
//!   - `gram_sq_norms` — the same exact quantity through the
//!     Gram-matrix structure of paper Sec 5.2 (A·Aᵀ ∘ Δ·Δᵀ); on MLPs
//!     this degenerates to the tap trick's diagonal, on conv the
//!     off-diagonal (cross-position) terms are load-bearing.
//!   - `tap_bound_sq_norms` — the plain row-norm product. Equal to
//!     `sq_norms` on MLPs; on conv it is only an *upper bound*
//!     (Cauchy–Schwarz over the overlapping patches), so it must never
//!     be used to clip alongside methods that use the exact norm. Kept
//!     for diagnostics and the tap-vs-gram ordering tests.
//!
//! An enum rather than a trait object: two families today, static
//! dispatch, and the scratch type stays concrete per family.

use super::conv::{self, ConvScratch, ConvSpec};
use super::mlp::{self, BatchScratch, MlpSpec};
use crate::runtime::manifest::ConfigSpec;
use anyhow::{bail, Result};

/// A model family's batched tap producer, parsed from a manifest
/// config.
pub enum TapModel {
    Mlp(MlpSpec),
    Cnn(ConvSpec),
}

/// Whole-batch forward/backward buffers for one `TapModel`.
pub enum TapScratch {
    Mlp(BatchScratch),
    Cnn(ConvScratch),
}

impl TapModel {
    /// Dispatch on the config's model family.
    pub fn from_config(cfg: &ConfigSpec) -> Result<TapModel> {
        match cfg.model.as_str() {
            "mlp" => Ok(TapModel::Mlp(MlpSpec::from_config(cfg)?)),
            "cnn" => Ok(TapModel::Cnn(ConvSpec::from_config(cfg)?)),
            other => bail!(
                "native backend has no tap producer for model family \
                 {other:?} (config {})",
                cfg.name
            ),
        }
    }

    pub fn family(&self) -> &'static str {
        match self {
            TapModel::Mlp(_) => "mlp",
            TapModel::Cnn(_) => "cnn",
        }
    }

    pub fn batch(&self) -> usize {
        match self {
            TapModel::Mlp(m) => m.batch,
            TapModel::Cnn(m) => m.batch,
        }
    }

    /// Flat input elements per example.
    pub fn d_in(&self) -> usize {
        match self {
            TapModel::Mlp(m) => m.d_in,
            TapModel::Cnn(m) => m.d_in,
        }
    }

    pub fn n_classes(&self) -> usize {
        match self {
            TapModel::Mlp(m) => m.n_classes,
            TapModel::Cnn(m) => m.n_classes,
        }
    }

    /// Check the param store's tensor count and per-tensor lengths
    /// against the spec; `config` names the config in errors.
    pub fn validate_params(&self, config: &str, host: &[Vec<f32>]) -> Result<()> {
        match self {
            TapModel::Mlp(m) => m.validate_params(config, host),
            TapModel::Cnn(m) => m.validate_params(config, host),
        }
    }

    /// Flat gradient buffers in manifest order.
    pub fn zero_grads(&self) -> Vec<Vec<f32>> {
        match self {
            TapModel::Mlp(m) => m.zero_grads(),
            TapModel::Cnn(m) => m.zero_grads(),
        }
    }

    pub fn new_scratch(&self, b: usize) -> TapScratch {
        match self {
            TapModel::Mlp(m) => TapScratch::Mlp(BatchScratch::for_spec(m, b)),
            TapModel::Cnn(m) => TapScratch::Cnn(ConvScratch::for_spec(m, b)),
        }
    }

    /// Batched forward over the staged batch; fills the scratch taps
    /// and returns (f64 loss sum, correct-prediction count).
    pub fn forward_batch(
        &self,
        params: &[Vec<f32>],
        x: &[f32],
        labels: &[i32],
        s: &mut TapScratch,
    ) -> (f64, usize) {
        match (self, s) {
            (TapModel::Mlp(m), TapScratch::Mlp(s)) => {
                mlp::forward_batch(m, params, x, labels, s)
            }
            (TapModel::Cnn(m), TapScratch::Cnn(s)) => {
                conv::forward_batch(m, params, x, labels, s)
            }
            _ => unreachable!("tap scratch does not match the model family"),
        }
    }

    /// Batched backward (after `forward_batch`); `nu` runs the
    /// reweighted pass (loss Σ_i nu_i·l_i).
    pub fn backward_batch(
        &self,
        params: &[Vec<f32>],
        labels: &[i32],
        nu: Option<&[f32]>,
        s: &mut TapScratch,
    ) {
        match (self, s) {
            (TapModel::Mlp(m), TapScratch::Mlp(s)) => {
                mlp::backward_batch(m, params, labels, nu, s)
            }
            (TapModel::Cnn(m), TapScratch::Cnn(s)) => {
                conv::backward_batch(m, params, labels, nu, s)
            }
            _ => unreachable!("tap scratch does not match the model family"),
        }
    }

    /// Exact per-example squared gradient norms — what every clipping
    /// method uses.
    pub fn sq_norms(&self, x: &[f32], s: &TapScratch) -> Vec<f64> {
        match (self, s) {
            (TapModel::Mlp(m), TapScratch::Mlp(s)) => mlp::tap_sq_norms(m, x, s),
            (TapModel::Cnn(m), TapScratch::Cnn(s)) => conv::sq_norms(m, s),
            _ => unreachable!("tap scratch does not match the model family"),
        }
    }

    /// Exact per-example squared norms through the Gram-matrix
    /// structure (paper Sec 5.2).
    pub fn gram_sq_norms(&self, x: &[f32], s: &TapScratch) -> Vec<f64> {
        match (self, s) {
            (TapModel::Mlp(m), TapScratch::Mlp(s)) => {
                mlp::gram_sq_norms(m, x, s)
            }
            (TapModel::Cnn(m), TapScratch::Cnn(s)) => conv::gram_sq_norms(m, s),
            _ => unreachable!("tap scratch does not match the model family"),
        }
    }

    /// The row-norm-product bound: equal to `sq_norms` on MLPs, an
    /// upper bound (tap ≥ gram) on conv. Diagnostics/tests only.
    pub fn tap_bound_sq_norms(&self, x: &[f32], s: &TapScratch) -> Vec<f64> {
        match (self, s) {
            (TapModel::Mlp(m), TapScratch::Mlp(s)) => mlp::tap_sq_norms(m, x, s),
            (TapModel::Cnn(m), TapScratch::Cnn(s)) => {
                conv::tap_bound_sq_norms(m, s)
            }
            _ => unreachable!("tap scratch does not match the model family"),
        }
    }

    /// Scale example i's delta rows by nu_i in place (the
    /// `reweight_direct` assembly).
    pub fn scale_delta_rows(&self, nu: &[f32], s: &mut TapScratch) {
        match (self, s) {
            (TapModel::Mlp(m), TapScratch::Mlp(s)) => {
                mlp::scale_delta_rows(m, nu, s)
            }
            (TapModel::Cnn(m), TapScratch::Cnn(s)) => {
                conv::scale_delta_rows(m, nu, s)
            }
            _ => unreachable!("tap scratch does not match the model family"),
        }
    }

    /// Accumulate the batch-summed gradients from the current deltas;
    /// `scale` fuses per-example clip factors into the reductions (the
    /// `reweight_pallas` path).
    pub fn grads_from_deltas(
        &self,
        x: &[f32],
        s: &TapScratch,
        scale: Option<&[f32]>,
        grads: &mut [Vec<f32>],
    ) {
        match (self, s) {
            (TapModel::Mlp(m), TapScratch::Mlp(s)) => {
                mlp::grads_from_deltas(m, x, s, scale, grads)
            }
            (TapModel::Cnn(m), TapScratch::Cnn(s)) => {
                conv::grads_from_deltas(m, s, scale, grads)
            }
            _ => unreachable!("tap scratch does not match the model family"),
        }
    }

    /// Materialize example i's full gradient (the multiLoss
    /// structure), returning its squared norm.
    pub fn materialize_grad_row(
        &self,
        x: &[f32],
        s: &TapScratch,
        i: usize,
        out: &mut [Vec<f32>],
    ) -> f64 {
        match (self, s) {
            (TapModel::Mlp(m), TapScratch::Mlp(s)) => {
                mlp::materialize_grad_row(m, x, s, i, out)
            }
            (TapModel::Cnn(m), TapScratch::Cnn(s)) => {
                conv::materialize_grad_row(m, s, i, out)
            }
            _ => unreachable!("tap scratch does not match the model family"),
        }
    }
}

/// Row-wise numerically stable softmax + cross-entropy over b x nc
/// logits: fills `probs`, returns (f64 loss sum, correct-prediction
/// count). Shared by every tap producer; the op order matches the
/// scalar reference in `mlp.rs` exactly, so moving a family onto this
/// helper changes no bits.
pub fn softmax_xent_rows(
    b: usize,
    nc: usize,
    logits: &[f32],
    probs: &mut [f32],
    labels: &[i32],
) -> (f64, usize) {
    debug_assert_eq!(logits.len(), b * nc);
    debug_assert_eq!(probs.len(), b * nc);
    debug_assert_eq!(labels.len(), b);
    let mut loss_sum = 0.0f64;
    let mut correct = 0usize;
    for r in 0..b {
        let row = &logits[r * nc..(r + 1) * nc];
        let prow = &mut probs[r * nc..(r + 1) * nc];
        let mut m = f32::NEG_INFINITY;
        let mut argmax = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > m {
                m = v;
                argmax = j;
            }
        }
        let mut sum = 0.0f64;
        for (p, &z) in prow.iter_mut().zip(row.iter()) {
            let e = ((z - m) as f64).exp();
            *p = e as f32;
            sum += e;
        }
        let inv = (1.0 / sum) as f32;
        for p in prow.iter_mut() {
            *p *= inv;
        }
        let y = labels[r] as usize;
        let loss = sum.ln() as f32 - (row[y] - m);
        loss_sum += loss as f64;
        correct += usize::from(argmax == y);
    }
    (loss_sum, correct)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ParamSpec;
    use std::collections::BTreeMap;

    #[test]
    fn unknown_family_is_a_clear_error() {
        let cfg = ConfigSpec {
            name: "rnn1_mnist_b4".into(),
            model: "rnn".into(),
            dataset: "mnist".into(),
            batch: 4,
            n_classes: 10,
            tags: vec![],
            input_shape: vec![4, 1, 28, 28],
            input_dtype: "f32".into(),
            act_elems_per_example: 0,
            conv: None,
            params: vec![
                ParamSpec { name: "w".into(), shape: vec![784, 10] },
                ParamSpec { name: "b".into(), shape: vec![10] },
            ],
            artifacts: BTreeMap::new(),
        };
        let err = TapModel::from_config(&cfg).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("rnn") && msg.contains("tap producer"), "{msg}");
    }

    #[test]
    fn softmax_rows_match_uniform_at_zero_logits() {
        let b = 3;
        let nc = 4;
        let logits = vec![0.0f32; b * nc];
        let mut probs = vec![0.0f32; b * nc];
        let labels = vec![1i32, 0, 3];
        let (loss_sum, _) =
            softmax_xent_rows(b, nc, &logits, &mut probs, &labels);
        for &p in &probs {
            assert!((p - 0.25).abs() < 1e-6);
        }
        let want = (4.0f64).ln() * b as f64;
        assert!((loss_sum - want).abs() < 1e-5, "{loss_sum} vs {want}");
    }
}
