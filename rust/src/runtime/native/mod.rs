//! NativeBackend — a pure-Rust execution backend for the manifest's
//! config families. Always available, no Python, no artifacts, no
//! xla: this is what makes tier-1 (`cargo build --release && cargo
//! test -q`) hermetic, and it is the reference implementation the
//! PJRT artifacts are checked against when both are present.
//!
//! Execution is *batched* (the point of the paper) and goes through
//! the `taps::ModelFamily` registry: each model family provides a tap
//! producer — batched forward/backward exposing per-layer activation
//! and delta matrices plus gradient assembly — and the clipping
//! strategies differ only in the extra work they do around one
//! batched forward/backward, which is exactly the structure the
//! paper's figures compare:
//!
//!   - `nonprivate`:      one batched backward, no clipping.
//!   - `reweight`:        exact per-example norms from the taps, then
//!                        a *second*, nu-reweighted backward pass (the
//!                        paper's main method).
//!   - `reweight_gram`:   norms via the A·Aᵀ ∘ Δ·Δᵀ Gram structure
//!                        (paper Sec 5.2 — the off-diagonal terms are
//!                        load-bearing under conv weight sharing),
//!                        then the reweighted backward.
//!   - `reweight_direct`: one backward only — the tapped deltas are
//!                        nu-scaled in place and the weighted gradient
//!                        is assembled directly.
//!   - `reweight_pallas`: one backward, and nu is fused *into* the
//!                        gradient GEMM (no weighted delta matrix is
//!                        ever materialized) — the fused-kernel
//!                        variant.
//!   - `multiloss`:       materialized per-example gradients, clipped
//!                        and summed (the vmap-of-grad structure).
//!   - `naive1`:          the batch-1 body of the nxBP loop.
//!
//! Model families resolve through a name-keyed `FamilyRegistry`
//! (`NativeBackend::register_family` to add one): `mlp` (dense),
//! `cnn` (convs lowered to im2col patch matrices, fc head), and
//! `transformer` (single-block attention encoder over token
//! sequences) register by default. The *config* space is open too:
//! `resolve` synthesizes any `model@dataset:bN` spec key through
//! `spec::ConfigBuilder` (e.g. `mlp(depth=4,width=512)@cifar10:b256`
//! or `transformer(heads=4,d_model=64)@imdb:b32`), while the builtin
//! grid — mlp{2,4,6,8} and cnn{2,4} over mnist/fmnist/cifar10, plus
//! `transformer_imdb`, at batch {1,16,32,64,128} — survives as a
//! preset naming layer over the same builder.
//!
//! Determinism: the GEMM/im2col kernels parallelize only over
//! disjoint output blocks with fixed reduction orders (see `gemm`),
//! and the remaining per-example stages (multiloss materialization,
//! per-example norm reductions, the conv per-example gradient
//! partials) run over disjoint per-example buffers merged in
//! ascending example order — results are bitwise reproducible
//! regardless of thread scheduling.
//!
//! Hot path: each `NativeStep` owns its whole execution state behind
//! a mutex (`StepFn::run_into` takes `&self`) — the family scratch,
//! the norm/clip-factor buffers, and the multiloss chunk arenas — and
//! writes results into the **caller-owned `StepOut` arena**. After
//! the first (cold) execution the warm step path performs zero heap
//! allocation (pinned by `tests/no_alloc.rs`); reuse is bitwise clean
//! (pinned by `cached_scratch_matches_fresh_step` and the
//! warm-vs-cold integration tests).

pub mod attention;
pub mod conv;
pub mod gemm;
pub mod mlp;
pub mod taps;

use self::taps::{
    reduce_norm_slab, FamilyRegistry, ModelFamily, NuBlock, ScratchAny,
};
use super::backend::{Backend, StepFn};
use super::manifest::{ConfigSpec, Manifest};
use super::policy::ClipPolicy;
use super::spec::{
    ConfigBuilder, ModelSpec, SpecKey, DEFAULT_CNN_CHANNELS, DEFAULT_MLP_WIDTH,
};
use super::store::{BatchStage, GradVec, ParamStore, StepOut};
use anyhow::{bail, ensure, Context, Result};
use rayon::prelude::*;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

/// Examples per parallel work unit in the multiloss materialization
/// stage. Fixed (not derived from the thread count) so the
/// floating-point merge order — and therefore every gradient bit — is
/// independent of the machine's parallelism.
const CHUNK_EXAMPLES: usize = 8;

pub struct NativeBackend {
    manifest: Manifest,
    families: FamilyRegistry,
}

impl NativeBackend {
    /// Backend over the built-in config families (mlp{2,4,6,8} and
    /// cnn{2,4} x {mnist,fmnist,cifar10} x batch {1,16,32,64,128})
    /// with the built-in family registry.
    pub fn new() -> NativeBackend {
        NativeBackend {
            manifest: builtin_manifest(),
            families: FamilyRegistry::builtin(),
        }
    }

    /// Backend over a caller-supplied manifest (tests, custom configs).
    pub fn with_manifest(manifest: Manifest) -> NativeBackend {
        NativeBackend { manifest, families: FamilyRegistry::builtin() }
    }

    /// Register (or replace) a model family: `name` is matched against
    /// `ConfigSpec::model`. This is the extension point for new
    /// families (attention, RNN) — no dispatch code changes anywhere.
    pub fn register_family(&mut self, name: &str, builder: taps::FamilyBuilder) {
        self.families.register(name, builder);
    }

    /// The family registry (read access — e.g. to build a tap producer
    /// directly in tests/diagnostics).
    pub fn families(&self) -> &FamilyRegistry {
        &self.families
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Native config resolution is *open*: a reference that parses as
    /// a `model@dataset:bN` spec key is synthesized on demand through
    /// `spec::ConfigBuilder` (any depth/width/kernel/stride/batch the
    /// family kernels can run); anything else must name a builtin
    /// preset or a caller-manifest entry. Spec grammar is checked
    /// first — it cannot collide with preset names (`mlp2_mnist_b32`
    /// has no `@`), a parseable-but-unbuildable spec errors with the
    /// builder's explanation, and a *malformed* spec-shaped reference
    /// (it contains `@`, which no manifest name does) surfaces the
    /// grammar error instead of a useless "unknown config".
    fn resolve(&self, name: &str) -> Result<ConfigSpec> {
        match SpecKey::parse(name) {
            Ok(key) => ConfigBuilder::from_key(key)
                .build()
                .with_context(|| format!("synthesizing config {name:?}")),
            Err(e) if name.contains('@') => Err(e.context(format!(
                "config reference {name:?} looks like a spec key but does \
                 not parse"
            ))),
            Err(_) => Ok(self.manifest.config(name)?.clone()),
        }
    }

    fn load(&self, cfg: &ConfigSpec, method: &str) -> Result<Arc<dyn StepFn>> {
        // route through the manifest so unsupported methods fail with
        // the same "config X has no `m` artifact" error as PJRT
        let art = cfg.artifact(method)?;
        let kind = Kind::parse(&art.method).with_context(|| {
            format!("native backend cannot execute artifact {}", art.file)
        })?;
        // the one and only family dispatch: the registry
        let model = self.families.build(cfg)?;
        let lens = model.grad_layout();
        let slot_layers = model.norm_slots();
        let n_param_layers =
            slot_layers.iter().copied().max().map_or(0, |m| m + 1);
        let state = Mutex::new(StepState::new(
            model.as_ref(),
            &lens,
            &slot_layers,
            n_param_layers,
            kind,
        ));
        Ok(Arc::new(NativeStep {
            model,
            kind,
            method: art.method.clone(),
            config: cfg.name.clone(),
            lens,
            slot_layers,
            n_param_layers,
            state,
        }))
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    NonPrivate,
    Reweight,
    ReweightGram,
    ReweightDirect,
    ReweightPallas,
    MultiLoss,
    Naive1,
    Fwd,
}

impl Kind {
    fn parse(method: &str) -> Result<Kind> {
        Ok(match method {
            "nonprivate" => Kind::NonPrivate,
            "reweight" => Kind::Reweight,
            "reweight_gram" => Kind::ReweightGram,
            "reweight_direct" => Kind::ReweightDirect,
            "reweight_pallas" => Kind::ReweightPallas,
            "multiloss" => Kind::MultiLoss,
            "naive1" => Kind::Naive1,
            "fwd" => Kind::Fwd,
            other => bail!("no native kernel for method {other:?}"),
        })
    }

    /// Does this kernel need the clip policy?
    fn needs_policy(&self) -> bool {
        matches!(
            self,
            Kind::Reweight
                | Kind::ReweightGram
                | Kind::ReweightDirect
                | Kind::ReweightPallas
                | Kind::MultiLoss
        )
    }
}

/// One fixed-size multiloss work unit: examples `lo..hi` materialize
/// into `mat`, accumulate nu-weighted into `acc`, norms collect into
/// `norms`. All buffers are owned by the chunk, so the parallel stage
/// allocates nothing and writes only disjoint memory.
struct MlChunk {
    lo: usize,
    hi: usize,
    acc: GradVec,
    mat: GradVec,
    /// f64 workspace for families whose per-example reduction needs
    /// one (conv); grows once, then reused
    work: Vec<f64>,
    norms: Vec<f32>,
    /// per-group norms under a grouped policy, example-major within
    /// the chunk (`gnorms[(i-lo)*n_groups + g]`); empty otherwise
    gnorms: Vec<f32>,
}

/// Everything a `NativeStep` mutates during execution, behind one
/// mutex: the family scratch plus the per-step working buffers that
/// used to be per-call allocations. Sized at `load`, reused forever.
struct StepState {
    taps: Box<ScratchAny>,
    /// per-layer squared-norm slab (batch × `norm_slots()`,
    /// example-major) the norm routes write into; the *policy* reduces
    /// it (`reduce_norm_slab`)
    slab: Vec<f64>,
    /// group-major per-group per-example squared norms (grow-only:
    /// sized for one group at load, regrown once if a grouped policy
    /// runs)
    gsq: Vec<f64>,
    /// group-major norms, then rescaled in place to clip factors nu
    /// (grow-only, like `gsq`)
    nu: Vec<f32>,
    /// group-major per-group norms published to the arena under a
    /// grouped policy (grow-only; empty under global)
    gnorms: Vec<f32>,
    /// whole-model per-example norms under a grouped policy (len = b)
    wnorms: Vec<f32>,
    /// group index of each parametric layer (len = n_param_layers),
    /// refilled from the policy every step
    groups: Vec<usize>,
    /// layer-index boundaries of the groups (`gb[g]..gb[g+1]`),
    /// rebuilt per step for the grouped multiloss path (grow-only)
    gb: Vec<usize>,
    /// multiloss chunk arenas (empty for every other kind)
    ml: Vec<MlChunk>,
}

impl StepState {
    fn new(
        model: &dyn ModelFamily,
        lens: &[usize],
        slot_layers: &[usize],
        n_param_layers: usize,
        kind: Kind,
    ) -> StepState {
        let b = model.batch();
        let ml = if kind == Kind::MultiLoss {
            let n_chunks =
                b / CHUNK_EXAMPLES + usize::from(b % CHUNK_EXAMPLES != 0);
            (0..n_chunks)
                .map(|ci| {
                    let lo = ci * CHUNK_EXAMPLES;
                    MlChunk {
                        lo,
                        hi: (lo + CHUNK_EXAMPLES).min(b),
                        acc: GradVec::with_layout(lens),
                        mat: GradVec::with_layout(lens),
                        work: Vec::new(),
                        norms: Vec::with_capacity(CHUNK_EXAMPLES),
                        gnorms: Vec::new(),
                    }
                })
                .collect()
        } else {
            Vec::new()
        };
        StepState {
            taps: model.new_scratch(),
            slab: vec![0.0; b * slot_layers.len()],
            gsq: vec![0.0; b],
            nu: vec![0.0; b],
            gnorms: Vec::new(),
            wnorms: vec![0.0; b],
            groups: vec![0; n_param_layers],
            gb: Vec::new(),
            ml,
        }
    }
}

struct NativeStep {
    model: Box<dyn ModelFamily>,
    kind: Kind,
    method: String,
    config: String,
    /// gradient arena layout (per-parameter element counts)
    lens: Vec<usize>,
    /// the family's norm-slab layout (`ModelFamily::norm_slots`)
    slot_layers: Vec<usize>,
    /// parametric layer count — the clip policy's granularity domain
    n_param_layers: usize,
    /// Cached execution state, reused across `run_into` calls
    /// (`StepFn::run_into` takes `&self`). Every buffer is fully
    /// rewritten (or explicitly cleared) each step, so reuse changes
    /// no bits — pinned by `cached_scratch_matches_fresh_step`.
    state: Mutex<StepState>,
}

impl StepFn for NativeStep {
    fn method(&self) -> &str {
        &self.method
    }

    fn run_into(
        &self,
        params: &ParamStore,
        stage: &BatchStage,
        policy: Option<&ClipPolicy>,
        out: &mut StepOut,
    ) -> Result<()> {
        let model = self.model.as_ref();
        ensure!(
            stage.is_f32,
            "{}: native {} expects f32 features",
            self.config,
            model.family()
        );
        // The batch comes from the *config*, never from the staged
        // buffers: a consistently truncated stage (features and labels
        // both short) must be a hard error, or training would silently
        // run at a smaller batch than the sampling ratio the RDP
        // accountant charges for.
        let b = model.batch();
        let d = model.d_in();
        ensure!(
            stage.labels.len() == b,
            "{}: staged batch holds {} labels but the config batch is {b} — \
             executing a smaller batch would change the sampling ratio the \
             RDP accountant assumes; stage the full batch",
            self.config,
            stage.labels.len()
        );
        ensure!(
            stage.feat_f32.len() == b * d,
            "{}: staged features hold {} elems, need {} ({} examples x {})",
            self.config,
            stage.feat_f32.len(),
            b * d,
            b,
            d
        );
        model.validate_params(&self.config, &params.host)?;
        for (i, &y) in stage.labels.iter().enumerate() {
            ensure!(
                y >= 0 && (y as usize) < model.n_classes(),
                "{}: label {y} at row {i} outside 0..{}",
                self.config,
                model.n_classes()
            );
        }
        let policy = if self.kind.needs_policy() {
            let p = policy.with_context(|| {
                format!("{}: {} requires a clip policy", self.config, self.method)
            })?;
            p.check(self.n_param_layers).with_context(|| {
                format!("{}: {}", self.config, self.method)
            })?;
            Some(p)
        } else {
            None
        };

        let host = &params.host;
        let x = &stage.feat_f32;
        let labels = &stage.labels;
        // a panicked step leaves only buffers that the next run fully
        // rewrites, so a poisoned lock is safe to recover
        let mut guard = self
            .state
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        let st = &mut *guard;

        // the step owns the arena reset: layout adopted, grads zeroed,
        // norms/scalars cleared — cold and warm arenas behave the same.
        // fwd produces no gradients, so its arena collapses to the
        // empty layout (matching the PJRT engine's fwd decode) instead
        // of memsetting a parameter-sized buffer once per eval batch.
        if self.kind == Kind::Fwd {
            out.reset(&[]);
        } else {
            out.reset(&self.lens);
        }

        let (loss_sum, correct) =
            model.forward_batch(host, x, labels, st.taps.as_mut());
        out.loss = (loss_sum / b as f64) as f32;

        match self.kind {
            Kind::Fwd => {
                out.correct = Some(correct as u32);
                return Ok(());
            }
            Kind::NonPrivate => {
                model.backward_batch(host, labels, None, st.taps.as_mut());
                model.grads_from_deltas(x, st.taps.as_mut(), None, &mut out.grads);
            }
            Kind::Naive1 => {
                // batch-1 nxBP body: unclipped gradient + its norm;
                // the coordinator clips and accumulates (grouped
                // policies re-derive per-group norms from the
                // materialized gradient there)
                model.backward_batch(host, labels, None, st.taps.as_mut());
                model.sq_norms(x, st.taps.as_mut(), &mut st.slab);
                st.groups.iter_mut().for_each(|g| *g = 0);
                reduce_norm_slab(
                    &st.slab,
                    b,
                    &self.slot_layers,
                    &st.groups,
                    1,
                    &mut st.gsq,
                );
                model.grads_from_deltas(x, st.taps.as_mut(), None, &mut out.grads);
                let norms = out.norms_fill(b);
                for (n, &s) in norms.iter_mut().zip(st.gsq.iter()) {
                    *n = s.sqrt() as f32;
                }
            }
            Kind::Reweight
            | Kind::ReweightGram
            | Kind::ReweightDirect
            | Kind::ReweightPallas => {
                // shared prefix of the reweight family: one backward
                // for the taps, exact per-example norms into the slab,
                // policy reduction, clip factors
                let p = policy.unwrap();
                let ng = p.n_groups(self.n_param_layers);
                model.backward_batch(host, labels, None, st.taps.as_mut());
                if self.kind == Kind::ReweightGram {
                    model.gram_sq_norms(x, st.taps.as_mut(), &mut st.slab);
                } else {
                    model.sq_norms(x, st.taps.as_mut(), &mut st.slab);
                }
                p.fill_layer_groups(&mut st.groups);
                if st.gsq.len() < ng * b {
                    st.gsq.resize(ng * b, 0.0);
                }
                if st.nu.len() < ng * b {
                    st.nu.resize(ng * b, 0.0);
                }
                reduce_norm_slab(
                    &st.slab,
                    b,
                    &self.slot_layers,
                    &st.groups,
                    ng,
                    &mut st.gsq,
                );
                if ng == 1 {
                    // one group: the ascending slab reduction replayed
                    // the legacy whole-model sum bit-for-bit; st.nu is
                    // first the norms (published), then the factors
                    for (nv, &s) in
                        st.nu[..b].iter_mut().zip(st.gsq[..b].iter())
                    {
                        *nv = s.sqrt() as f32;
                    }
                    out.set_norms(&st.nu[..b]);
                    for nv in st.nu[..b].iter_mut() {
                        *nv = p.nu_for(*nv);
                    }
                } else {
                    // grouped: per-group norms (published group-major)
                    // plus the whole-model norms for the norm report
                    if st.gnorms.len() < ng * b {
                        st.gnorms.resize(ng * b, 0.0);
                    }
                    for (gn, &s) in st.gnorms[..ng * b]
                        .iter_mut()
                        .zip(st.gsq[..ng * b].iter())
                    {
                        *gn = s.sqrt() as f32;
                    }
                    out.set_group_norms(&st.gnorms[..ng * b], ng);
                    for i in 0..b {
                        let mut s = 0.0f64;
                        for g in 0..ng {
                            s += st.gsq[g * b + i];
                        }
                        st.wnorms[i] = s.sqrt() as f32;
                    }
                    out.set_norms(&st.wnorms);
                    for (nv, &gn) in st.nu[..ng * b]
                        .iter_mut()
                        .zip(st.gnorms[..ng * b].iter())
                    {
                        *nv = p.nu_for(gn);
                    }
                }
                let block = NuBlock {
                    nu: &st.nu[..ng * b],
                    groups: &st.groups,
                    b,
                };
                match self.kind {
                    // the paper's reweight (and its gram-norm twin): a
                    // *second* backward pass of the nu-weighted loss
                    // Σ_i nu_i·l_i. The reweighted loss can only carry
                    // one scalar per example, so grouped policies
                    // scale the tapped deltas per layer instead —
                    // algebraically the same weighted gradient.
                    Kind::Reweight | Kind::ReweightGram => {
                        if ng == 1 {
                            model.backward_batch(
                                host,
                                labels,
                                Some(&st.nu[..b]),
                                st.taps.as_mut(),
                            );
                            model.grads_from_deltas(
                                x,
                                st.taps.as_mut(),
                                None,
                                &mut out.grads,
                            );
                        } else {
                            model.scale_delta_rows(&block, st.taps.as_mut());
                            model.grads_from_deltas(
                                x,
                                st.taps.as_mut(),
                                None,
                                &mut out.grads,
                            );
                        }
                    }
                    // one backward: reuse the tapped deltas, nu-scaled
                    Kind::ReweightDirect => {
                        model.scale_delta_rows(&block, st.taps.as_mut());
                        model.grads_from_deltas(
                            x,
                            st.taps.as_mut(),
                            None,
                            &mut out.grads,
                        );
                    }
                    // fused: nu enters the gradient GEMM directly
                    Kind::ReweightPallas => {
                        model.grads_from_deltas(
                            x,
                            st.taps.as_mut(),
                            Some(&block),
                            &mut out.grads,
                        );
                    }
                    _ => unreachable!("outer match covers the family"),
                }
            }
            Kind::MultiLoss => {
                let p = policy.unwrap();
                let ng = p.n_groups(self.n_param_layers);
                model.backward_batch(host, labels, None, st.taps.as_mut());
                // group g spans parametric layers gb[g]..gb[g+1], i.e.
                // params 2·gb[g]..2·gb[g+1] (one (W, b) pair per layer)
                p.fill_layer_groups(&mut st.groups);
                st.gb.clear();
                st.gb.push(0);
                for l in 1..self.n_param_layers {
                    if st.groups[l] != st.groups[l - 1] {
                        st.gb.push(l);
                    }
                }
                st.gb.push(self.n_param_layers);
                debug_assert_eq!(st.gb.len(), ng + 1);
                // materialize per-example gradients in fixed-size
                // chunks: parallel over the pre-allocated chunk
                // arenas, merged in order below
                let taps_ref: &ScratchAny = st.taps.as_ref();
                let model_ref = &self.model;
                let gb = &st.gb;
                st.ml.par_iter_mut().for_each(|chunk| {
                    chunk.norms.clear();
                    chunk.gnorms.clear();
                    chunk.acc.zero();
                    for i in chunk.lo..chunk.hi {
                        let sq = model_ref.materialize_grad_row(
                            x,
                            taps_ref,
                            i,
                            &mut chunk.mat,
                            &mut chunk.work,
                        );
                        if ng == 1 {
                            // whole-model squared norm straight from
                            // the materialization — the legacy path
                            let norm = sq.sqrt() as f32;
                            chunk.norms.push(norm);
                            let nu = p.nu_for(norm);
                            chunk.acc.add_scaled(&chunk.mat, nu);
                        } else {
                            // grouped: each group's slice of the
                            // materialized gradient is normed and
                            // scaled independently
                            let mut wsq = 0.0f64;
                            for g in 0..ng {
                                let (lo, hi) = (2 * gb[g], 2 * gb[g + 1]);
                                let gsq = chunk.mat.sq_norm_params(lo, hi);
                                wsq += gsq;
                                let gn = gsq.sqrt() as f32;
                                chunk.gnorms.push(gn);
                                let nu = p.nu_for(gn);
                                chunk
                                    .acc
                                    .add_scaled_params(&chunk.mat, lo, hi, nu);
                            }
                            chunk.norms.push(wsq.sqrt() as f32);
                        }
                    }
                });
                {
                    let norms = out.norms_fill(b);
                    let mut at = 0usize;
                    for chunk in &st.ml {
                        for &n in &chunk.norms {
                            norms[at] = n;
                            at += 1;
                        }
                    }
                }
                if ng > 1 {
                    // regroup the chunks' example-major group norms
                    // into the arena's group-major layout
                    if st.gnorms.len() < ng * b {
                        st.gnorms.resize(ng * b, 0.0);
                    }
                    for chunk in &st.ml {
                        for (k, i) in (chunk.lo..chunk.hi).enumerate() {
                            for g in 0..ng {
                                st.gnorms[g * b + i] =
                                    chunk.gnorms[k * ng + g];
                            }
                        }
                    }
                    out.set_group_norms(&st.gnorms[..ng * b], ng);
                }
                for chunk in &st.ml {
                    out.grads.add(&chunk.acc);
                }
            }
        }

        out.grads.scale(1.0 / b as f32);
        Ok(())
    }
}

/// One builtin *preset*: a spec-built config published under the
/// grid's stable short name (`mlp2_mnist_b32`-style), with the figure
/// tags the bench suite selects on. Structurally this is exactly
/// `ConfigBuilder` output — the grid is a thin naming/tagging layer
/// over the open spec space, not a separate construction path.
fn preset(model: ModelSpec, dataset: &str, batch: usize) -> ConfigSpec {
    let depth = model.depth();
    let family = model.family();
    let name = format!("{family}{depth}_{dataset}_b{batch}");
    let mut cfg = ConfigBuilder::new(model, dataset, batch)
        .named(&name)
        .build()
        .expect("builtin preset must synthesize");
    if depth == 2 && batch == 32 && (dataset == "mnist" || dataset == "fmnist") {
        cfg.tags.push("fig5".into());
    }
    if family == "mlp" && batch == 128 {
        cfg.tags.push("fig7".into());
    }
    cfg
}

/// The built-in preset grid the native backend always carries:
/// mlp{2,4,6,8} (width `DEFAULT_MLP_WIDTH`) and cnn{2,4} (stride-2 3x3, channels
/// from `DEFAULT_CNN_CHANNELS`) over mnist/fmnist/cifar10, plus the
/// transformer encoder (`transformer_imdb`, grid-default
/// heads=2/d_model=32/seq=64/ff=64) over the imdb token dataset, all
/// at batch {1,16,32,64,128}. Anything beyond the grid resolves
/// through the spec grammar (`NativeBackend::resolve`) instead of
/// being added here.
fn builtin_manifest() -> Manifest {
    let mut configs = BTreeMap::new();
    for batch in [1usize, 16, 32, 64, 128] {
        let cfg = ConfigBuilder::new(
            ModelSpec::Transformer { heads: 2, d_model: 32, seq: 64, ff: 64 },
            "imdb",
            batch,
        )
        .named(&format!("transformer_imdb_b{batch}"))
        .build()
        .expect("builtin preset must synthesize");
        configs.insert(cfg.name.clone(), cfg);
    }
    for dataset in ["mnist", "fmnist", "cifar10"] {
        for batch in [1usize, 16, 32, 64, 128] {
            for depth in [2usize, 4, 6, 8] {
                let cfg = preset(
                    ModelSpec::Mlp { depth, width: DEFAULT_MLP_WIDTH },
                    dataset,
                    batch,
                );
                configs.insert(cfg.name.clone(), cfg);
            }
            for depth in [2usize, 4] {
                let cfg = preset(
                    ModelSpec::Cnn {
                        k: 3,
                        s: 2,
                        pad: 1,
                        pool: 0,
                        ch: DEFAULT_CNN_CHANNELS[..depth].to_vec(),
                    },
                    dataset,
                    batch,
                );
                configs.insert(cfg.name.clone(), cfg);
            }
        }
    }
    Manifest { dir: PathBuf::from("builtin:native"), configs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ConvMeta;
    use crate::runtime::store::init_params_glorot;

    /// Stage the first `cfg.batch` examples of the config's own
    /// dataset: f32 image datasets gather directly, i32 token datasets
    /// widen through the trainer's staging seam
    /// (`gather_batch_i32_as_f32`).
    fn stage_first_batch(cfg: &ConfigSpec, n: usize, seed: u64) -> BatchStage {
        let ds = crate::data::load_dataset(&cfg.dataset, n, seed).unwrap();
        let mut stage = BatchStage::for_config(cfg);
        let batch: Vec<usize> = (0..cfg.batch).collect();
        match &ds.features {
            crate::data::Features::F32(_) => crate::data::gather_batch_f32(
                &ds,
                &batch,
                &mut stage.feat_f32,
                &mut stage.labels,
            ),
            crate::data::Features::I32(_) => {
                crate::data::gather_batch_i32_as_f32(
                    &ds,
                    &batch,
                    &mut stage.feat_f32,
                    &mut stage.labels,
                )
            }
        }
        stage
    }

    #[test]
    fn builtin_manifest_is_consistent() {
        let b = NativeBackend::new();
        let m = b.manifest();
        let cfg = m.config("mlp2_mnist_b32").unwrap();
        assert_eq!(cfg.batch, 32);
        assert_eq!(cfg.params[0].shape, vec![784, DEFAULT_MLP_WIDTH]);
        // the full batched method matrix is native, on all families
        for name in [
            "mlp2_mnist_b32",
            "cnn2_mnist_b32",
            "cnn4_cifar10_b64",
            "transformer_imdb_b32",
        ] {
            let cfg = m.config(name).unwrap();
            for method in [
                "nonprivate",
                "reweight",
                "reweight_gram",
                "reweight_direct",
                "reweight_pallas",
                "multiloss",
                "fwd",
            ] {
                assert!(cfg.artifacts.contains_key(method), "{name}/{method}");
            }
        }
        // every batched config has a naive1-capable b1 sibling
        for name in m.configs.keys().filter(|n| !n.ends_with("_b1")) {
            let n1 = m.naive_config(name).unwrap();
            assert!(n1.artifacts.contains_key("naive1"), "{name}");
        }
        // every config resolves through the family registry — no
        // family-name dispatch outside it
        for cfg in m.configs.values() {
            let model = b.families().build(cfg).unwrap();
            assert_eq!(model.family(), cfg.model);
            assert_eq!(model.batch(), cfg.batch);
            let lens = model.grad_layout();
            assert_eq!(lens.len(), cfg.params.len(), "{}", cfg.name);
            for (l, p) in lens.iter().zip(&cfg.params) {
                assert_eq!(*l, p.elems(), "{}.{}", cfg.name, p.name);
            }
        }
        // cnn spatial chain: mnist 28 -> 14 -> 7, fc 7*7*16 -> 10
        let cnn = m.config("cnn2_mnist_b32").unwrap();
        assert_eq!(cnn.params[0].shape, vec![8, 1, 3, 3]);
        assert_eq!(cnn.params[4].shape, vec![7 * 7 * 16, 10]);
        assert_eq!(
            cnn.conv,
            Some(ConvMeta { kernel: 3, stride: 2, pad: 1, pool: 0 })
        );
        let cnn4 = m.config("cnn4_cifar10_b16").unwrap();
        assert_eq!(cnn4.params[8].shape, vec![2 * 2 * 32, 10]);
        // transformer chain: embed 5000->32, q/k/v/o 32x32, ff 32<->64,
        // head 32->2, token input [batch, seq]
        let tf = m.config("transformer_imdb_b32").unwrap();
        assert_eq!(tf.batch, 32);
        assert_eq!(tf.input_shape, vec![32, 64]);
        assert_eq!(tf.params.len(), 16);
        assert_eq!(tf.params[0].shape, vec![5000, 32]);
        assert_eq!(tf.params[10].shape, vec![32, 64]);
        assert_eq!(tf.params[14].shape, vec![32, 2]);
        assert_eq!(tf.conv, None);
    }

    /// Every builtin preset carries spec provenance, and its batch-1
    /// sibling derived *structurally* (`with_batch(1)`) matches the
    /// manifest's `_b1` entry in everything but the name — so the
    /// preset layer and the builder can never drift apart.
    #[test]
    fn presets_carry_provenance_matching_their_b1_sibling() {
        let b = NativeBackend::new();
        for name in ["mlp4_cifar10_b64", "cnn2_mnist_b32", "transformer_imdb_b32"]
        {
            let cfg = b.manifest().config(name).unwrap();
            assert!(cfg.spec.is_some(), "{name} has no spec provenance");
            let structural = b.naive_sibling(cfg).unwrap();
            let by_name = b.manifest().naive_config(name).unwrap();
            assert_eq!(structural.batch, 1);
            assert_eq!(structural.params.len(), by_name.params.len(), "{name}");
            for (a, c) in structural.params.iter().zip(&by_name.params) {
                assert_eq!(a.shape, c.shape, "{name}.{}", a.name);
            }
            assert_eq!(
                structural.act_elems_per_example,
                by_name.act_elems_per_example,
                "{name}"
            );
            assert_eq!(structural.conv, by_name.conv, "{name}");
            assert!(structural.artifacts.contains_key("naive1"), "{name}");
        }
    }

    /// Native resolution order: spec keys synthesize (off the grid),
    /// preset names hit the manifest, and everything else errors with
    /// the manifest's unknown-config message.
    #[test]
    fn resolve_synthesizes_specs_and_keeps_preset_names() {
        let b = NativeBackend::new();
        // a config far outside the builtin grid synthesizes on demand
        let cfg = b.resolve("mlp(depth=4,width=512)@cifar10:b256").unwrap();
        assert_eq!(cfg.batch, 256);
        assert_eq!(cfg.params[0].shape, vec![3072, 512]);
        assert!(b.manifest().config(&cfg.name).is_err(), "not grid-backed");
        // ...and executes through the ordinary load path
        assert!(b.load(&cfg, "reweight").is_ok());
        // preset names resolve to the grid entry, bit-for-bit
        let grid = b.resolve("mlp2_mnist_b32").unwrap();
        assert_eq!(grid.name, "mlp2_mnist_b32");
        assert_eq!(grid.batch, 32);
        // a parseable-but-unbuildable spec reports the builder's error
        let err = b.resolve("mlp(depth=2,width=8)@nodataset:b4").unwrap_err();
        assert!(format!("{err:#}").contains("unknown dataset"));
        // a malformed spec-shaped reference (contains `@`) surfaces the
        // grammar error — not a useless "unknown config"
        let err =
            b.resolve("mlp(depth=4,widht=512)@cifar10:b256").unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("does not parse") && msg.contains("widht"),
            "{msg}"
        );
        // a plain unknown name reports the manifest's error
        let err = b.resolve("no_such_config").unwrap_err();
        assert!(format!("{err:#}").contains("no_such_config"));
    }

    #[test]
    fn unsupported_method_is_a_manifest_error() {
        let b = NativeBackend::new();
        let cfg = b.manifest().config("mlp2_mnist_b32").unwrap();
        // naive1 is only registered on the batch-1 siblings
        let err = b.load(cfg, "naive1").unwrap_err();
        assert!(format!("{err:#}").contains("naive1"));
    }

    #[test]
    fn fwd_counts_and_losses_are_sane() {
        let b = NativeBackend::new();
        for name in
            ["mlp2_mnist_b32", "cnn2_mnist_b32", "transformer_imdb_b32"]
        {
            let cfg = b.manifest().config(name).unwrap().clone();
            let step = b.load(&cfg, "fwd").unwrap();
            let params =
                ParamStore::new(&cfg, Some(&init_params_glorot(&cfg, 0)))
                    .unwrap();
            let stage = stage_first_batch(&cfg, 64, 0);
            let out = step.run(&params, &stage, None).unwrap();
            assert!(out.loss.is_finite() && out.loss > 0.0, "{name}");
            // the correct-prediction *count* is an integer in 0..=32
            let correct = out.correct.unwrap();
            assert!(correct <= 32, "{name}: {correct}");
            // fwd collapses the gradient arena to the empty layout —
            // the same observable state the PJRT engine's fwd decode
            // produces
            assert_eq!(out.grads.n_params(), 0, "{name}: fwd wrote gradients");
            assert_eq!(out.grads.total_elems(), 0, "{name}");
            assert!(out.norms().is_none(), "{name}");
        }
    }

    #[test]
    fn partial_batch_is_rejected() {
        let b = NativeBackend::new();
        let cfg = b.manifest().config("mlp2_mnist_b32").unwrap().clone();
        let step = b.load(&cfg, "nonprivate").unwrap();
        let params = ParamStore::new(&cfg, None).unwrap();
        let mut stage = BatchStage::for_config(&cfg);
        stage.feat_f32.truncate(784 * 31); // one example short
        let err = step.run(&params, &stage, None).unwrap_err();
        assert!(format!("{err:#}").contains("staged features"));
    }

    /// The batch-size-laundering hazard: a stage where features *and*
    /// labels are consistently short must still error — the batch is
    /// defined by the config (and the accountant's sampling ratio),
    /// not by whatever happens to be staged.
    #[test]
    fn consistently_truncated_stage_is_rejected() {
        let b = NativeBackend::new();
        let cfg = b.manifest().config("mlp2_mnist_b32").unwrap().clone();
        let step = b.load(&cfg, "nonprivate").unwrap();
        let params = ParamStore::new(&cfg, None).unwrap();
        let mut stage = BatchStage::for_config(&cfg);
        stage.feat_f32.truncate(784 * 16);
        stage.labels.truncate(16); // a consistent batch... of 16
        let err = step.run(&params, &stage, None).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("16 labels") && msg.contains("sampling ratio"),
            "{msg}"
        );
    }

    #[test]
    fn results_are_deterministic_across_runs() {
        let b = NativeBackend::new();
        for name in
            ["mlp2_mnist_b32", "cnn2_mnist_b32", "transformer_imdb_b32"]
        {
            let cfg = b.manifest().config(name).unwrap().clone();
            let stage = stage_first_batch(&cfg, 64, 3);
            let params =
                ParamStore::new(&cfg, Some(&init_params_glorot(&cfg, 1)))
                    .unwrap();
            // determinism holds for every policy shape, not just the
            // classical global-hard one
            for pol in ["global:0.7", "per_layer:0.7", "auto:0.7,g=0.01"] {
                let pol = ClipPolicy::parse(pol).unwrap();
                for method in [
                    "reweight",
                    "reweight_gram",
                    "reweight_direct",
                    "reweight_pallas",
                ] {
                    let step = b.load(&cfg, method).unwrap();
                    let a = step.run(&params, &stage, Some(&pol)).unwrap();
                    let a2 = step.run(&params, &stage, Some(&pol)).unwrap();
                    // bitwise: fixed tiles + ordered merge + clean
                    // scratch reuse
                    assert_eq!(a.grads, a2.grads, "{name}/{method}/{pol}");
                    assert_eq!(a.norms(), a2.norms(), "{name}/{method}/{pol}");
                    assert_eq!(
                        a.group_norms(),
                        a2.group_norms(),
                        "{name}/{method}/{pol}"
                    );
                }
            }
        }
    }

    /// The cached-state fast path changes no bits: a step object that
    /// has already run (warm, reused buffers) produces results
    /// identical to a freshly loaded step (cold buffers) — on both
    /// model families, for the methods that touch every scratch
    /// buffer. (The all-seven-methods warm-vs-cold arena test lives in
    /// tests/integration.rs.)
    #[test]
    fn cached_scratch_matches_fresh_step() {
        let b = NativeBackend::new();
        for name in
            ["mlp2_mnist_b16", "cnn2_mnist_b16", "transformer_imdb_b16"]
        {
            let cfg = b.manifest().config(name).unwrap().clone();
            let stage = stage_first_batch(&cfg, 64, 9);
            let params =
                ParamStore::new(&cfg, Some(&init_params_glorot(&cfg, 4)))
                    .unwrap();
            let pol = ClipPolicy::hard_global(0.6);
            for method in ["reweight", "multiloss", "nonprivate"] {
                let warm = b.load(&cfg, method).unwrap();
                // reuse one arena across the warm runs: dirty arena in,
                // same bits out
                let mut out = StepOut::for_config(&cfg);
                warm.run_into(&params, &stage, Some(&pol), &mut out).unwrap();
                let first = out.clone();
                warm.run_into(&params, &stage, Some(&pol), &mut out).unwrap();
                let fresh = b.load(&cfg, method).unwrap();
                let cold = fresh.run(&params, &stage, Some(&pol)).unwrap();
                assert_eq!(first.grads, out.grads, "{name}/{method}");
                assert_eq!(first.grads, cold.grads, "{name}/{method}");
                assert_eq!(first.norms(), cold.norms(), "{name}/{method}");
                assert_eq!(
                    first.loss.to_bits(),
                    cold.loss.to_bits(),
                    "{name}/{method}"
                );
            }
        }
    }

    /// All five batched private methods agree under *grouped* and
    /// *automatic* policies too: reweight, gram, direct, pallas and
    /// multiloss compute the same nu-weighted gradient whichever way
    /// nu is derived and applied — the cross-method equivalence that
    /// pins the global case extends to every policy shape. Grouped
    /// runs must also publish consistent per-group norms (whole-model
    /// norm² = Σ_g group-norm²).
    #[test]
    fn batched_methods_agree_under_grouped_and_auto_policies() {
        let b = NativeBackend::new();
        for name in
            ["mlp2_mnist_b16", "cnn2_mnist_b16", "transformer_imdb_b16"]
        {
            let cfg = b.manifest().config(name).unwrap().clone();
            let stage = stage_first_batch(&cfg, 64, 11);
            let params =
                ParamStore::new(&cfg, Some(&init_params_glorot(&cfg, 7)))
                    .unwrap();
            for pol_s in [
                "per_layer:0.5",
                "groups(1):0.5",
                "auto:0.5,g=0.01",
                "per_layer:0.5,g=0.01",
            ] {
                let pol = ClipPolicy::parse(pol_s).unwrap();
                let outs: Vec<StepOut> = [
                    "reweight",
                    "reweight_gram",
                    "reweight_direct",
                    "reweight_pallas",
                    "multiloss",
                ]
                .iter()
                .map(|m| {
                    b.load(&cfg, m)
                        .unwrap()
                        .run(&params, &stage, Some(&pol))
                        .unwrap()
                })
                .collect();
                let reference = &outs[0];
                for (k, o) in outs.iter().enumerate().skip(1) {
                    for (a, c) in
                        reference.grads.flat().iter().zip(o.grads.flat())
                    {
                        assert!(
                            (a - c).abs() <= 1e-5 * a.abs().max(1.0),
                            "{name}/{pol_s}/method{k}: {a} vs {c}"
                        );
                    }
                    let rn = reference.norms().unwrap();
                    let on = o.norms().unwrap();
                    for (a, c) in rn.iter().zip(on) {
                        assert!(
                            (a - c).abs() <= 1e-4 * a.max(1.0),
                            "{name}/{pol_s}/method{k} norms: {a} vs {c}"
                        );
                    }
                }
                // grouped policies publish group norms consistent with
                // the whole-model norm; global ones publish none
                let ng = pol.n_groups(cfg.params.len() / 2);
                if ng > 1 {
                    let (gn, got_ng) = reference.group_norms().unwrap();
                    assert_eq!(got_ng, ng, "{name}/{pol_s}");
                    let norms = reference.norms().unwrap();
                    for (i, &w) in norms.iter().enumerate() {
                        let sum: f32 = (0..ng)
                            .map(|g| gn[g * cfg.batch + i].powi(2))
                            .sum();
                        assert!(
                            (sum.sqrt() - w).abs() <= 1e-4 * w.max(1.0),
                            "{name}/{pol_s}: sqrt({sum}) vs {w}"
                        );
                    }
                } else {
                    assert!(
                        reference.group_norms().is_none(),
                        "{name}/{pol_s}"
                    );
                }
            }
        }
    }

    /// Every artifact the builtin manifest declares actually executes
    /// — on both model families, including the batch-1 naive1 bodies.
    #[test]
    fn all_declared_artifacts_execute() {
        let b = NativeBackend::new();
        for name in [
            "mlp2_mnist_b16",
            "mlp2_mnist_b1",
            "cnn2_mnist_b16",
            "cnn2_mnist_b1",
            "cnn4_cifar10_b16",
            "transformer_imdb_b16",
            "transformer_imdb_b1",
        ] {
            let cfg = b.manifest().config(name).unwrap().clone();
            let stage = stage_first_batch(&cfg, 64, 5);
            let params =
                ParamStore::new(&cfg, Some(&init_params_glorot(&cfg, 2)))
                    .unwrap();
            // one shared arena across every method of the config: the
            // reset contract isolates them
            let mut out = StepOut::for_config(&cfg);
            let pol = ClipPolicy::hard_global(1.0);
            for method in cfg.artifacts.keys() {
                let step = b.load(&cfg, method).unwrap();
                step.run_into(&params, &stage, Some(&pol), &mut out)
                    .unwrap_or_else(|e| panic!("{name}/{method}: {e:#}"));
                assert!(out.loss.is_finite(), "{name}/{method}");
            }
        }
    }
}
