//! NativeBackend — a pure-Rust execution backend for the manifest's
//! MLP and CNN config families. Always available, no Python, no
//! artifacts, no xla: this is what makes tier-1 (`cargo build
//! --release && cargo test -q`) hermetic, and it is the reference
//! implementation the PJRT artifacts are checked against when both
//! are present.
//!
//! Execution is *batched* (the point of the paper) and goes through
//! the `taps::TapModel` seam: each model family provides a tap
//! producer — batched forward/backward exposing per-layer activation
//! and delta matrices plus gradient assembly — and the clipping
//! strategies differ only in the extra work they do around one
//! batched forward/backward, which is exactly the structure the
//! paper's figures compare:
//!
//!   - `nonprivate`:      one batched backward, no clipping.
//!   - `reweight`:        exact per-example norms from the taps, then
//!                        a *second*, nu-reweighted backward pass (the
//!                        paper's main method).
//!   - `reweight_gram`:   norms via the A·Aᵀ ∘ Δ·Δᵀ Gram structure
//!                        (paper Sec 5.2 — the off-diagonal terms are
//!                        load-bearing under conv weight sharing),
//!                        then the reweighted backward.
//!   - `reweight_direct`: one backward only — the tapped deltas are
//!                        nu-scaled in place and the weighted gradient
//!                        is assembled directly.
//!   - `reweight_pallas`: one backward, and nu is fused *into* the
//!                        gradient GEMM (no weighted delta matrix is
//!                        ever materialized) — the fused-kernel
//!                        variant.
//!   - `multiloss`:       materialized per-example gradients, clipped
//!                        and summed (the vmap-of-grad structure).
//!   - `naive1`:          the batch-1 body of the nxBP loop.
//!
//! Model families: `mlp{2,4,6,8}` (dense) and `cnn{2,4}` (stride-2
//! 3x3 convs lowered to im2col patch matrices, fc head) over
//! mnist/fmnist/cifar10 at batch {1,16,32,64,128}.
//!
//! Determinism: the GEMM/im2col kernels parallelize only over
//! disjoint output blocks with fixed reduction orders (see `gemm`),
//! and the remaining per-example stages (multiloss materialization,
//! per-example norm reductions) run in fixed-size chunks merged in
//! order — results are bitwise reproducible regardless of thread
//! scheduling.
//!
//! Hot path: each `NativeStep` caches its batch scratch behind a
//! mutex (`StepFn::run` takes `&self`), so the several hundred KB of
//! forward/backward buffer alloc+zero that used to sit inside the
//! timed step is paid once at `load` time; the returned gradient
//! tensors are the one remaining per-step allocation (they are owned
//! by `StepOut`).

pub mod conv;
pub mod gemm;
pub mod mlp;
pub mod taps;

use self::taps::{TapModel, TapScratch};
use super::backend::{Backend, StepFn};
use super::manifest::{ArtifactSpec, ConfigSpec, ConvMeta, Manifest, ParamSpec};
use super::store::{BatchStage, ParamStore, StepOut};
use anyhow::{bail, ensure, Context, Result};
use rayon::prelude::*;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

/// Examples per parallel work unit in the multiloss materialization
/// stage. Fixed (not derived from the thread count) so the
/// floating-point merge order — and therefore every gradient bit — is
/// independent of the machine's parallelism.
const CHUNK_EXAMPLES: usize = 8;

/// Hidden width of the built-in MLP config family.
const HIDDEN: usize = 128;

/// Conv channel progression of the built-in CNN config family.
const CNN_CHANNELS: [usize; 4] = [8, 16, 32, 32];

pub struct NativeBackend {
    manifest: Manifest,
}

impl NativeBackend {
    /// Backend over the built-in config families (mlp{2,4,6,8} and
    /// cnn{2,4} x {mnist,fmnist,cifar10} x batch {1,16,32,64,128}).
    pub fn new() -> NativeBackend {
        NativeBackend { manifest: builtin_manifest() }
    }

    /// Backend over a caller-supplied manifest (tests, custom configs).
    pub fn with_manifest(manifest: Manifest) -> NativeBackend {
        NativeBackend { manifest }
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn load(&self, cfg: &ConfigSpec, method: &str) -> Result<Arc<dyn StepFn>> {
        // route through the manifest so unsupported methods fail with
        // the same "config X has no `m` artifact" error as PJRT
        let art = cfg.artifact(method)?;
        let kind = Kind::parse(&art.method).with_context(|| {
            format!("native backend cannot execute artifact {}", art.file)
        })?;
        let model = TapModel::from_config(cfg)?;
        let scratch = Mutex::new(model.new_scratch(cfg.batch));
        Ok(Arc::new(NativeStep {
            model,
            kind,
            method: art.method.clone(),
            config: cfg.name.clone(),
            scratch,
        }))
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    NonPrivate,
    Reweight,
    ReweightGram,
    ReweightDirect,
    ReweightPallas,
    MultiLoss,
    Naive1,
    Fwd,
}

impl Kind {
    fn parse(method: &str) -> Result<Kind> {
        Ok(match method {
            "nonprivate" => Kind::NonPrivate,
            "reweight" => Kind::Reweight,
            "reweight_gram" => Kind::ReweightGram,
            "reweight_direct" => Kind::ReweightDirect,
            "reweight_pallas" => Kind::ReweightPallas,
            "multiloss" => Kind::MultiLoss,
            "naive1" => Kind::Naive1,
            "fwd" => Kind::Fwd,
            other => bail!("no native kernel for method {other:?}"),
        })
    }

    /// Does this kernel need the clip threshold?
    fn needs_clip(&self) -> bool {
        matches!(
            self,
            Kind::Reweight
                | Kind::ReweightGram
                | Kind::ReweightDirect
                | Kind::ReweightPallas
                | Kind::MultiLoss
        )
    }
}

struct NativeStep {
    model: TapModel,
    kind: Kind,
    method: String,
    config: String,
    /// Cached batch scratch, reused across `run` calls (`StepFn::run`
    /// takes `&self`). Every buffer is fully rewritten each step, so
    /// reuse changes no bits — pinned by
    /// `cached_scratch_matches_fresh_step`. The returned gradient
    /// tensors are deliberately NOT cached: `StepOut` owns them, so a
    /// fresh `zero_grads` + in-place scale is one full memory pass
    /// cheaper than accumulate-into-cache + scale-into-a-new-copy.
    scratch: Mutex<TapScratch>,
}

/// nu_i = min(1, clip / ||g_i||) for every example, via the shared
/// `runtime::clip_factor` definition.
fn clip_factors(norms: &[f32], clip: f32) -> Vec<f32> {
    norms
        .iter()
        .map(|&n| crate::runtime::clip_factor(n, clip))
        .collect()
}

impl StepFn for NativeStep {
    fn method(&self) -> &str {
        &self.method
    }

    fn run(
        &self,
        params: &ParamStore,
        stage: &BatchStage,
        clip: Option<f32>,
    ) -> Result<StepOut> {
        let model = &self.model;
        ensure!(
            stage.is_f32,
            "{}: native {} expects f32 features",
            self.config,
            model.family()
        );
        // The batch comes from the *config*, never from the staged
        // buffers: a consistently truncated stage (features and labels
        // both short) must be a hard error, or training would silently
        // run at a smaller batch than the sampling ratio the RDP
        // accountant charges for.
        let b = model.batch();
        let d = model.d_in();
        ensure!(
            stage.labels.len() == b,
            "{}: staged batch holds {} labels but the config batch is {b} — \
             executing a smaller batch would change the sampling ratio the \
             RDP accountant assumes; stage the full batch",
            self.config,
            stage.labels.len()
        );
        ensure!(
            stage.feat_f32.len() == b * d,
            "{}: staged features hold {} elems, need {} ({} examples x {})",
            self.config,
            stage.feat_f32.len(),
            b * d,
            b,
            d
        );
        model.validate_params(&self.config, &params.host)?;
        for (i, &y) in stage.labels.iter().enumerate() {
            ensure!(
                y >= 0 && (y as usize) < model.n_classes(),
                "{}: label {y} at row {i} outside 0..{}",
                self.config,
                model.n_classes()
            );
        }
        let clip = if self.kind.needs_clip() {
            Some(clip.with_context(|| {
                format!("{}: {} requires a clip threshold", self.config, self.method)
            })?)
        } else {
            None
        };

        let host = &params.host;
        let x = &stage.feat_f32;
        let labels = &stage.labels;
        // a panicked step leaves only buffers that the next run fully
        // rewrites, so a poisoned lock is safe to recover
        let mut guard = self
            .scratch
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        let s = &mut *guard;
        let (loss_sum, correct) = model.forward_batch(host, x, labels, s);
        let loss = (loss_sum / b as f64) as f32;

        if self.kind == Kind::Fwd {
            return Ok(StepOut {
                grads: Vec::new(),
                loss,
                norms: None,
                correct: Some(correct as f32),
            });
        }

        let mut grads = model.zero_grads();
        let norms: Option<Vec<f32>> = match self.kind {
            Kind::Fwd => unreachable!("fwd returned above"),
            Kind::NonPrivate => {
                model.backward_batch(host, labels, None, s);
                model.grads_from_deltas(x, s, None, &mut grads);
                None
            }
            Kind::Naive1 => {
                // batch-1 nxBP body: unclipped gradient + its norm;
                // the coordinator clips and accumulates
                model.backward_batch(host, labels, None, s);
                let sq = model.sq_norms(x, s);
                model.grads_from_deltas(x, s, None, &mut grads);
                Some(sq.iter().map(|&v| v.sqrt() as f32).collect())
            }
            Kind::Reweight
            | Kind::ReweightGram
            | Kind::ReweightDirect
            | Kind::ReweightPallas => {
                // shared prefix of the reweight family: one backward
                // for the taps, exact per-example norms, clip factors
                model.backward_batch(host, labels, None, s);
                let sq = if self.kind == Kind::ReweightGram {
                    model.gram_sq_norms(x, s)
                } else {
                    model.sq_norms(x, s)
                };
                let norms: Vec<f32> =
                    sq.iter().map(|&v| v.sqrt() as f32).collect();
                let nu = clip_factors(&norms, clip.unwrap());
                match self.kind {
                    // the paper's reweight (and its gram-norm twin): a
                    // *second* backward pass of the nu-weighted loss
                    // Σ_i nu_i·l_i
                    Kind::Reweight | Kind::ReweightGram => {
                        model.backward_batch(host, labels, Some(&nu), s);
                        model.grads_from_deltas(x, s, None, &mut grads);
                    }
                    // one backward: reuse the tapped deltas, nu-scaled
                    Kind::ReweightDirect => {
                        model.scale_delta_rows(&nu, s);
                        model.grads_from_deltas(x, s, None, &mut grads);
                    }
                    // fused: nu enters the gradient GEMM directly
                    Kind::ReweightPallas => {
                        model.grads_from_deltas(x, s, Some(&nu), &mut grads);
                    }
                    _ => unreachable!("outer match covers the family"),
                }
                Some(norms)
            }
            Kind::MultiLoss => {
                let c = clip.unwrap();
                model.backward_batch(host, labels, None, s);
                // materialize per-example gradients in fixed-size
                // chunks (parallel, merged in order)
                let n_chunks =
                    b / CHUNK_EXAMPLES + usize::from(b % CHUNK_EXAMPLES != 0);
                let shared: &TapScratch = s;
                // (chunk's summed weighted grads, chunk's norms)
                let partials = (0..n_chunks)
                    .into_par_iter()
                    .map(|ci| {
                        let lo = ci * CHUNK_EXAMPLES;
                        let hi = (lo + CHUNK_EXAMPLES).min(b);
                        let mut acc = model.zero_grads();
                        let mut mat = model.zero_grads();
                        let mut norms = Vec::with_capacity(hi - lo);
                        for i in lo..hi {
                            let sq = model.materialize_grad_row(
                                x, shared, i, &mut mat,
                            );
                            let norm = sq.sqrt() as f32;
                            let nu = crate::runtime::clip_factor(norm, c);
                            for (a, g) in acc.iter_mut().zip(&mat) {
                                for (av, &gv) in a.iter_mut().zip(g) {
                                    *av += nu * gv;
                                }
                            }
                            norms.push(norm);
                        }
                        (acc, norms)
                    })
                    .collect::<Vec<_>>();
                let mut norms = Vec::with_capacity(b);
                for (acc, chunk_norms) in partials {
                    norms.extend(chunk_norms);
                    for (g, a) in grads.iter_mut().zip(&acc) {
                        for (gv, &av) in g.iter_mut().zip(a) {
                            *gv += av;
                        }
                    }
                }
                Some(norms)
            }
        };

        let inv_b = 1.0 / b as f32;
        for g in grads.iter_mut() {
            for v in g.iter_mut() {
                *v *= inv_b;
            }
        }
        Ok(StepOut { grads, loss, norms, correct: None })
    }
}

fn artifact(method: &str, config: &str) -> (String, ArtifactSpec) {
    let (extra, outputs): (&[&str], &[&str]) = match method {
        "nonprivate" => (&[], &["grads", "loss"]),
        "reweight" | "reweight_gram" | "reweight_direct" | "reweight_pallas"
        | "multiloss" => (&["clip"], &["grads", "loss", "norms"]),
        "naive1" => (&[], &["grads", "loss", "norm"]),
        "fwd" => (&[], &["loss", "correct"]),
        _ => (&[], &[]),
    };
    (
        method.to_string(),
        ArtifactSpec {
            method: method.to_string(),
            file: format!("native:{config}.{method}"),
            extra_args: extra.iter().map(|s| s.to_string()).collect(),
            outputs: outputs.iter().map(|s| s.to_string()).collect(),
        },
    )
}

/// The full batched method family every native config carries (plus
/// `naive1` on the batch-1 siblings).
fn insert_artifacts(name: &str, batch: usize, artifacts: &mut BTreeMap<String, ArtifactSpec>) {
    for m in [
        "nonprivate",
        "reweight",
        "reweight_gram",
        "reweight_direct",
        "reweight_pallas",
        "multiloss",
        "fwd",
    ] {
        let (k, v) = artifact(m, name);
        artifacts.insert(k, v);
    }
    if batch == 1 {
        let (k, v) = artifact("naive1", name);
        artifacts.insert(k, v);
    }
}

fn mlp_config(
    dataset: &str,
    img_shape: &[usize],
    n_classes: usize,
    depth: usize,
    batch: usize,
) -> ConfigSpec {
    let name = format!("mlp{depth}_{dataset}_b{batch}");
    let d_in: usize = img_shape.iter().product();
    let mut params = Vec::with_capacity(depth * 2);
    let mut prev = d_in;
    for l in 0..depth {
        let out = if l == depth - 1 { n_classes } else { HIDDEN };
        params.push(ParamSpec { name: format!("fc{l}.w"), shape: vec![prev, out] });
        params.push(ParamSpec { name: format!("fc{l}.b"), shape: vec![out] });
        prev = out;
    }
    let mut tags: Vec<String> = Vec::new();
    if batch == 1 {
        tags.push("naive".into());
    }
    if depth == 2 && batch == 32 && (dataset == "mnist" || dataset == "fmnist") {
        tags.push("fig5".into());
    }
    if batch == 128 {
        tags.push("fig7".into());
    }
    let mut artifacts = BTreeMap::new();
    insert_artifacts(&name, batch, &mut artifacts);
    let mut input_shape = vec![batch];
    input_shape.extend_from_slice(img_shape);
    ConfigSpec {
        name,
        model: "mlp".into(),
        dataset: dataset.into(),
        batch,
        n_classes,
        tags,
        input_shape,
        input_dtype: "f32".into(),
        act_elems_per_example: (depth - 1) * HIDDEN + n_classes,
        conv: None,
        params,
        artifacts,
    }
}

/// Built-in CNN config: `depth` stride-2 3x3 conv layers (channels
/// from `CNN_CHANNELS`) followed by one fc head onto the classes.
/// Spatial maps halve per conv (ceil), so mnist runs 28→14→7→4→2 and
/// cifar10 32→16→8→4→2.
fn cnn_config(
    dataset: &str,
    img_shape: &[usize],
    n_classes: usize,
    depth: usize,
    batch: usize,
) -> ConfigSpec {
    assert!((1..=CNN_CHANNELS.len()).contains(&depth));
    let name = format!("cnn{depth}_{dataset}_b{batch}");
    let meta = ConvMeta { kernel: 3, stride: 2, pad: 1 };
    let (mut cin, mut h, mut w) = (img_shape[0], img_shape[1], img_shape[2]);
    let mut params = Vec::with_capacity(depth * 2 + 2);
    let mut act_elems = 0usize;
    for l in 0..depth {
        let cout = CNN_CHANNELS[l];
        params.push(ParamSpec {
            name: format!("conv{l}.w"),
            shape: vec![cout, cin, meta.kernel, meta.kernel],
        });
        params.push(ParamSpec { name: format!("conv{l}.b"), shape: vec![cout] });
        h = gemm::conv_out(h, meta.kernel, meta.stride, meta.pad);
        w = gemm::conv_out(w, meta.kernel, meta.stride, meta.pad);
        act_elems += h * w * cout;
        cin = cout;
    }
    let flat = cin * h * w;
    params.push(ParamSpec { name: "fc.w".into(), shape: vec![flat, n_classes] });
    params.push(ParamSpec { name: "fc.b".into(), shape: vec![n_classes] });
    act_elems += n_classes;
    let mut tags: Vec<String> = Vec::new();
    if batch == 1 {
        tags.push("naive".into());
    }
    if depth == 2 && batch == 32 && (dataset == "mnist" || dataset == "fmnist") {
        tags.push("fig5".into());
    }
    let mut artifacts = BTreeMap::new();
    insert_artifacts(&name, batch, &mut artifacts);
    let mut input_shape = vec![batch];
    input_shape.extend_from_slice(img_shape);
    ConfigSpec {
        name,
        model: "cnn".into(),
        dataset: dataset.into(),
        batch,
        n_classes,
        tags,
        input_shape,
        input_dtype: "f32".into(),
        act_elems_per_example: act_elems,
        conv: Some(meta),
        params,
        artifacts,
    }
}

/// The built-in config families the native backend can always run.
fn builtin_manifest() -> Manifest {
    let mut configs = BTreeMap::new();
    let datasets: [(&str, &[usize], usize); 3] = [
        ("mnist", &[1, 28, 28], 10),
        ("fmnist", &[1, 28, 28], 10),
        ("cifar10", &[3, 32, 32], 10),
    ];
    for (dataset, shape, n_classes) in datasets {
        for batch in [1usize, 16, 32, 64, 128] {
            for depth in [2usize, 4, 6, 8] {
                let cfg = mlp_config(dataset, shape, n_classes, depth, batch);
                configs.insert(cfg.name.clone(), cfg);
            }
            for depth in [2usize, 4] {
                let cfg = cnn_config(dataset, shape, n_classes, depth, batch);
                configs.insert(cfg.name.clone(), cfg);
            }
        }
    }
    Manifest { dir: PathBuf::from("builtin:native"), configs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::store::init_params_glorot;

    #[test]
    fn builtin_manifest_is_consistent() {
        let b = NativeBackend::new();
        let m = b.manifest();
        let cfg = m.config("mlp2_mnist_b32").unwrap();
        assert_eq!(cfg.batch, 32);
        assert_eq!(cfg.params[0].shape, vec![784, HIDDEN]);
        // the full batched method matrix is native, on both families
        for name in ["mlp2_mnist_b32", "cnn2_mnist_b32", "cnn4_cifar10_b64"] {
            let cfg = m.config(name).unwrap();
            for method in [
                "nonprivate",
                "reweight",
                "reweight_gram",
                "reweight_direct",
                "reweight_pallas",
                "multiloss",
                "fwd",
            ] {
                assert!(cfg.artifacts.contains_key(method), "{name}/{method}");
            }
        }
        // every batched config has a naive1-capable b1 sibling
        for name in m.configs.keys().filter(|n| !n.ends_with("_b1")) {
            let n1 = m.naive_config(name).unwrap();
            assert!(n1.artifacts.contains_key("naive1"), "{name}");
        }
        // every config parses into its family's tap producer
        for cfg in m.configs.values() {
            let model = TapModel::from_config(cfg).unwrap();
            assert_eq!(model.family(), cfg.model);
            assert_eq!(model.batch(), cfg.batch);
        }
        // cnn spatial chain: mnist 28 -> 14 -> 7, fc 7*7*16 -> 10
        let cnn = m.config("cnn2_mnist_b32").unwrap();
        assert_eq!(cnn.params[0].shape, vec![8, 1, 3, 3]);
        assert_eq!(cnn.params[4].shape, vec![7 * 7 * 16, 10]);
        assert_eq!(cnn.conv, Some(ConvMeta { kernel: 3, stride: 2, pad: 1 }));
        let cnn4 = m.config("cnn4_cifar10_b16").unwrap();
        assert_eq!(cnn4.params[8].shape, vec![2 * 2 * 32, 10]);
    }

    #[test]
    fn unsupported_method_is_a_manifest_error() {
        let b = NativeBackend::new();
        let cfg = b.manifest().config("mlp2_mnist_b32").unwrap();
        // naive1 is only registered on the batch-1 siblings
        let err = b.load(cfg, "naive1").unwrap_err();
        assert!(format!("{err:#}").contains("naive1"));
    }

    #[test]
    fn fwd_counts_and_losses_are_sane() {
        let b = NativeBackend::new();
        for name in ["mlp2_mnist_b32", "cnn2_mnist_b32"] {
            let cfg = b.manifest().config(name).unwrap().clone();
            let step = b.load(&cfg, "fwd").unwrap();
            let mut params =
                ParamStore::new(&cfg, Some(&init_params_glorot(&cfg, 0)))
                    .unwrap();
            let ds = crate::data::load_dataset("mnist", 64, 0).unwrap();
            let mut stage = BatchStage::for_config(&cfg);
            let batch: Vec<usize> = (0..32).collect();
            crate::data::gather_batch_f32(
                &ds,
                &batch,
                &mut stage.feat_f32,
                &mut stage.labels,
            );
            let out = step.run(&mut params, &stage, None).unwrap();
            assert!(out.loss.is_finite() && out.loss > 0.0, "{name}");
            let correct = out.correct.unwrap();
            assert!((0.0..=32.0).contains(&correct), "{name}");
            assert!(out.grads.is_empty(), "{name}");
        }
    }

    #[test]
    fn partial_batch_is_rejected() {
        let b = NativeBackend::new();
        let cfg = b.manifest().config("mlp2_mnist_b32").unwrap().clone();
        let step = b.load(&cfg, "nonprivate").unwrap();
        let mut params = ParamStore::new(&cfg, None).unwrap();
        let mut stage = BatchStage::for_config(&cfg);
        stage.feat_f32.truncate(784 * 31); // one example short
        let err = step.run(&mut params, &stage, None).unwrap_err();
        assert!(format!("{err:#}").contains("staged features"));
    }

    /// The batch-size-laundering hazard: a stage where features *and*
    /// labels are consistently short must still error — the batch is
    /// defined by the config (and the accountant's sampling ratio),
    /// not by whatever happens to be staged.
    #[test]
    fn consistently_truncated_stage_is_rejected() {
        let b = NativeBackend::new();
        let cfg = b.manifest().config("mlp2_mnist_b32").unwrap().clone();
        let step = b.load(&cfg, "nonprivate").unwrap();
        let mut params = ParamStore::new(&cfg, None).unwrap();
        let mut stage = BatchStage::for_config(&cfg);
        stage.feat_f32.truncate(784 * 16);
        stage.labels.truncate(16); // a consistent batch... of 16
        let err = step.run(&mut params, &stage, None).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("16 labels") && msg.contains("sampling ratio"),
            "{msg}"
        );
    }

    #[test]
    fn results_are_deterministic_across_runs() {
        let b = NativeBackend::new();
        for name in ["mlp2_mnist_b32", "cnn2_mnist_b32"] {
            let cfg = b.manifest().config(name).unwrap().clone();
            let ds = crate::data::load_dataset("mnist", 64, 3).unwrap();
            let mut stage = BatchStage::for_config(&cfg);
            let batch: Vec<usize> = (0..32).collect();
            crate::data::gather_batch_f32(
                &ds,
                &batch,
                &mut stage.feat_f32,
                &mut stage.labels,
            );
            let mut params =
                ParamStore::new(&cfg, Some(&init_params_glorot(&cfg, 1)))
                    .unwrap();
            for method in
                ["reweight", "reweight_gram", "reweight_direct", "reweight_pallas"]
            {
                let step = b.load(&cfg, method).unwrap();
                let a = step.run(&mut params, &stage, Some(0.7)).unwrap();
                let a2 = step.run(&mut params, &stage, Some(0.7)).unwrap();
                // bitwise: fixed tiles + ordered merge + clean scratch
                // reuse
                assert_eq!(a.grads, a2.grads, "{name}/{method}");
                assert_eq!(a.norms, a2.norms, "{name}/{method}");
            }
        }
    }

    /// The cached-scratch fast path changes no bits: a step object
    /// that has already run (warm, reused buffers) produces results
    /// identical to a freshly loaded step (cold buffers) — on both
    /// model families, for the methods that touch every scratch
    /// buffer.
    #[test]
    fn cached_scratch_matches_fresh_step() {
        let b = NativeBackend::new();
        for name in ["mlp2_mnist_b16", "cnn2_mnist_b16"] {
            let cfg = b.manifest().config(name).unwrap().clone();
            let ds = crate::data::load_dataset("mnist", 64, 9).unwrap();
            let mut stage = BatchStage::for_config(&cfg);
            let batch: Vec<usize> = (0..cfg.batch).collect();
            crate::data::gather_batch_f32(
                &ds,
                &batch,
                &mut stage.feat_f32,
                &mut stage.labels,
            );
            let mut params =
                ParamStore::new(&cfg, Some(&init_params_glorot(&cfg, 4)))
                    .unwrap();
            for method in ["reweight", "multiloss", "nonprivate"] {
                let warm = b.load(&cfg, method).unwrap();
                let first = warm.run(&mut params, &stage, Some(0.6)).unwrap();
                let second = warm.run(&mut params, &stage, Some(0.6)).unwrap();
                let fresh = b.load(&cfg, method).unwrap();
                let cold = fresh.run(&mut params, &stage, Some(0.6)).unwrap();
                assert_eq!(first.grads, second.grads, "{name}/{method}");
                assert_eq!(first.grads, cold.grads, "{name}/{method}");
                assert_eq!(first.norms, cold.norms, "{name}/{method}");
                assert_eq!(
                    first.loss.to_bits(),
                    cold.loss.to_bits(),
                    "{name}/{method}"
                );
            }
        }
    }

    /// Every artifact the builtin manifest declares actually executes
    /// — on both model families, including the batch-1 naive1 bodies.
    #[test]
    fn all_declared_artifacts_execute() {
        let b = NativeBackend::new();
        for name in [
            "mlp2_mnist_b16",
            "mlp2_mnist_b1",
            "cnn2_mnist_b16",
            "cnn2_mnist_b1",
            "cnn4_cifar10_b16",
        ] {
            let cfg = b.manifest().config(name).unwrap().clone();
            let ds = crate::data::load_dataset(&cfg.dataset, 64, 5).unwrap();
            let mut stage = BatchStage::for_config(&cfg);
            let batch: Vec<usize> = (0..cfg.batch).collect();
            crate::data::gather_batch_f32(
                &ds,
                &batch,
                &mut stage.feat_f32,
                &mut stage.labels,
            );
            let mut params =
                ParamStore::new(&cfg, Some(&init_params_glorot(&cfg, 2)))
                    .unwrap();
            for method in cfg.artifacts.keys() {
                let step = b.load(&cfg, method).unwrap();
                let out = step
                    .run(&mut params, &stage, Some(1.0))
                    .unwrap_or_else(|e| panic!("{name}/{method}: {e:#}"));
                assert!(out.loss.is_finite(), "{name}/{method}");
            }
        }
    }
}
