//! NativeBackend — a pure-Rust execution backend for the manifest's
//! MLP config family (linear + bias + ReLU + softmax-CE). Always
//! available, no Python, no artifacts, no xla: this is what makes
//! tier-1 (`cargo build --release && cargo test -q`) hermetic, and it
//! is the reference implementation the PJRT artifacts are checked
//! against when both are present.
//!
//! Execution is *batched* (the point of the paper): activations and
//! deltas live as B x d matrices and every heavy op is a `gemm`
//! kernel, so the clipping strategies differ only in the extra work
//! they do around one batched forward/backward — which is exactly the
//! structure the paper's figures compare:
//!
//!   - `nonprivate`:      one batched backward, no clipping.
//!   - `reweight`:        per-example norms via the activation/delta
//!                        tap trick, then a *second*, nu-reweighted
//!                        backward pass (the paper's main method).
//!   - `reweight_gram`:   norms via the A·Aᵀ ∘ Δ·Δᵀ Gram diagonal
//!                        (paper Sec 5.2), then the reweighted
//!                        backward.
//!   - `reweight_direct`: one backward only — the tapped deltas are
//!                        nu-scaled in place and the weighted gradient
//!                        is assembled directly.
//!   - `reweight_pallas`: one backward, and nu is fused *into* the
//!                        gradient GEMM (no weighted delta matrix is
//!                        ever materialized) — the fused-kernel
//!                        variant.
//!   - `multiloss`:       materialized per-example gradients, clipped
//!                        and summed (the vmap-of-grad structure).
//!   - `naive1`:          the batch-1 body of the nxBP loop.
//!
//! Determinism: the GEMM kernels parallelize only over disjoint
//! output-row blocks with a fixed reduction order (see `gemm`), and
//! the one remaining per-example stage (multiloss materialization)
//! runs in fixed-size chunks merged in order — results are bitwise
//! reproducible regardless of thread scheduling.

pub mod gemm;
pub mod mlp;

use self::mlp::{BatchScratch, MlpSpec};
use super::backend::{Backend, StepFn};
use super::manifest::{ArtifactSpec, ConfigSpec, Manifest, ParamSpec};
use super::store::{BatchStage, ParamStore, StepOut};
use anyhow::{bail, ensure, Context, Result};
use rayon::prelude::*;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

/// Examples per parallel work unit in the multiloss materialization
/// stage. Fixed (not derived from the thread count) so the
/// floating-point merge order — and therefore every gradient bit — is
/// independent of the machine's parallelism.
const CHUNK_EXAMPLES: usize = 8;

/// Hidden width of the built-in MLP config family.
const HIDDEN: usize = 128;

pub struct NativeBackend {
    manifest: Manifest,
}

impl NativeBackend {
    /// Backend over the built-in MLP config family (mlp{2,4,6,8} x
    /// {mnist,fmnist,cifar10} x batch {1,16,32,64,128}).
    pub fn new() -> NativeBackend {
        NativeBackend { manifest: builtin_manifest() }
    }

    /// Backend over a caller-supplied manifest (tests, custom configs).
    pub fn with_manifest(manifest: Manifest) -> NativeBackend {
        NativeBackend { manifest }
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn load(&self, cfg: &ConfigSpec, method: &str) -> Result<Arc<dyn StepFn>> {
        // route through the manifest so unsupported methods fail with
        // the same "config X has no `m` artifact" error as PJRT
        let art = cfg.artifact(method)?;
        let kind = Kind::parse(&art.method).with_context(|| {
            format!("native backend cannot execute artifact {}", art.file)
        })?;
        let spec = MlpSpec::from_config(cfg)?;
        Ok(Arc::new(NativeStep {
            spec,
            kind,
            method: art.method.clone(),
            config: cfg.name.clone(),
        }))
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    NonPrivate,
    Reweight,
    ReweightGram,
    ReweightDirect,
    ReweightPallas,
    MultiLoss,
    Naive1,
    Fwd,
}

impl Kind {
    fn parse(method: &str) -> Result<Kind> {
        Ok(match method {
            "nonprivate" => Kind::NonPrivate,
            "reweight" => Kind::Reweight,
            "reweight_gram" => Kind::ReweightGram,
            "reweight_direct" => Kind::ReweightDirect,
            "reweight_pallas" => Kind::ReweightPallas,
            "multiloss" => Kind::MultiLoss,
            "naive1" => Kind::Naive1,
            "fwd" => Kind::Fwd,
            other => bail!("no native kernel for method {other:?}"),
        })
    }

    /// Does this kernel need the clip threshold?
    fn needs_clip(&self) -> bool {
        matches!(
            self,
            Kind::Reweight
                | Kind::ReweightGram
                | Kind::ReweightDirect
                | Kind::ReweightPallas
                | Kind::MultiLoss
        )
    }
}

struct NativeStep {
    spec: MlpSpec,
    kind: Kind,
    method: String,
    config: String,
}

/// nu_i = min(1, clip / ||g_i||) for every example, via the shared
/// `runtime::clip_factor` definition.
fn clip_factors(norms: &[f32], clip: f32) -> Vec<f32> {
    norms
        .iter()
        .map(|&n| crate::runtime::clip_factor(n, clip))
        .collect()
}

impl StepFn for NativeStep {
    fn method(&self) -> &str {
        &self.method
    }

    fn run(
        &self,
        params: &ParamStore,
        stage: &BatchStage,
        clip: Option<f32>,
    ) -> Result<StepOut> {
        let spec = &self.spec;
        ensure!(
            stage.is_f32,
            "{}: native mlp expects f32 features",
            self.config
        );
        // The batch comes from the *config*, never from the staged
        // buffers: a consistently truncated stage (features and labels
        // both short) must be a hard error, or training would silently
        // run at a smaller batch than the sampling ratio the RDP
        // accountant charges for.
        let b = spec.batch;
        let d = spec.d_in;
        ensure!(
            stage.labels.len() == b,
            "{}: staged batch holds {} labels but the config batch is {b} — \
             executing a smaller batch would change the sampling ratio the \
             RDP accountant assumes; stage the full batch",
            self.config,
            stage.labels.len()
        );
        ensure!(
            stage.feat_f32.len() == b * d,
            "{}: staged features hold {} elems, need {} ({} examples x {})",
            self.config,
            stage.feat_f32.len(),
            b * d,
            b,
            d
        );
        ensure!(
            params.host.len() == 2 * spec.n_layers(),
            "{}: param store has {} tensors, spec needs {}",
            self.config,
            params.host.len(),
            2 * spec.n_layers()
        );
        for (l, &(din, dout)) in spec.layers.iter().enumerate() {
            ensure!(
                params.host[2 * l].len() == din * dout
                    && params.host[2 * l + 1].len() == dout,
                "{}: layer {l} param shapes do not match the config",
                self.config
            );
        }
        for (i, &y) in stage.labels.iter().enumerate() {
            ensure!(
                y >= 0 && (y as usize) < spec.n_classes,
                "{}: label {y} at row {i} outside 0..{}",
                self.config,
                spec.n_classes
            );
        }
        let clip = if self.kind.needs_clip() {
            Some(clip.with_context(|| {
                format!("{}: {} requires a clip threshold", self.config, self.method)
            })?)
        } else {
            None
        };

        let host = &params.host;
        let x = &stage.feat_f32;
        let labels = &stage.labels;
        let mut s = BatchScratch::for_spec(spec, b);
        let (loss_sum, correct) = mlp::forward_batch(spec, host, x, labels, &mut s);
        let loss = (loss_sum / b as f64) as f32;

        if self.kind == Kind::Fwd {
            return Ok(StepOut {
                grads: Vec::new(),
                loss,
                norms: None,
                correct: Some(correct as f32),
            });
        }

        let mut grads = spec.zero_grads();
        let norms: Option<Vec<f32>> = match self.kind {
            Kind::Fwd => unreachable!("fwd returned above"),
            Kind::NonPrivate => {
                mlp::backward_batch(spec, host, labels, None, &mut s);
                mlp::grads_from_deltas(spec, x, &s, None, &mut grads);
                None
            }
            Kind::Naive1 => {
                // batch-1 nxBP body: unclipped gradient + its norm;
                // the coordinator clips and accumulates
                mlp::backward_batch(spec, host, labels, None, &mut s);
                let sq = mlp::tap_sq_norms(spec, x, &s);
                mlp::grads_from_deltas(spec, x, &s, None, &mut grads);
                Some(sq.iter().map(|&v| v.sqrt() as f32).collect())
            }
            Kind::Reweight
            | Kind::ReweightGram
            | Kind::ReweightDirect
            | Kind::ReweightPallas => {
                // shared prefix of the reweight family: one backward
                // for the taps, per-example norms, clip factors
                mlp::backward_batch(spec, host, labels, None, &mut s);
                let sq = if self.kind == Kind::ReweightGram {
                    mlp::gram_sq_norms(spec, x, &s)
                } else {
                    mlp::tap_sq_norms(spec, x, &s)
                };
                let norms: Vec<f32> =
                    sq.iter().map(|&v| v.sqrt() as f32).collect();
                let nu = clip_factors(&norms, clip.unwrap());
                match self.kind {
                    // the paper's reweight (and its gram-norm twin): a
                    // *second* backward pass of the nu-weighted loss
                    // Σ_i nu_i·l_i
                    Kind::Reweight | Kind::ReweightGram => {
                        mlp::backward_batch(spec, host, labels, Some(&nu), &mut s);
                        mlp::grads_from_deltas(spec, x, &s, None, &mut grads);
                    }
                    // one backward: reuse the tapped deltas, nu-scaled
                    Kind::ReweightDirect => {
                        mlp::scale_delta_rows(spec, &nu, &mut s);
                        mlp::grads_from_deltas(spec, x, &s, None, &mut grads);
                    }
                    // fused: nu enters the gradient GEMM directly
                    Kind::ReweightPallas => {
                        mlp::grads_from_deltas(spec, x, &s, Some(&nu), &mut grads);
                    }
                    _ => unreachable!("outer match covers the family"),
                }
                Some(norms)
            }
            Kind::MultiLoss => {
                let c = clip.unwrap();
                mlp::backward_batch(spec, host, labels, None, &mut s);
                // materialize per-example gradients in fixed-size
                // chunks (parallel, merged in order)
                let n_chunks =
                    b / CHUNK_EXAMPLES + usize::from(b % CHUNK_EXAMPLES != 0);
                let shared = &s;
                // (chunk's summed weighted grads, chunk's norms)
                let partials = (0..n_chunks)
                    .into_par_iter()
                    .map(|ci| {
                        let lo = ci * CHUNK_EXAMPLES;
                        let hi = (lo + CHUNK_EXAMPLES).min(b);
                        let mut acc = spec.zero_grads();
                        let mut mat = spec.zero_grads();
                        let mut norms = Vec::with_capacity(hi - lo);
                        for i in lo..hi {
                            let sq = mlp::materialize_grad_row(
                                spec, x, shared, i, &mut mat,
                            );
                            let norm = sq.sqrt() as f32;
                            let nu = crate::runtime::clip_factor(norm, c);
                            for (a, g) in acc.iter_mut().zip(&mat) {
                                for (av, &gv) in a.iter_mut().zip(g) {
                                    *av += nu * gv;
                                }
                            }
                            norms.push(norm);
                        }
                        (acc, norms)
                    })
                    .collect::<Vec<_>>();
                let mut norms = Vec::with_capacity(b);
                for (acc, chunk_norms) in partials {
                    norms.extend(chunk_norms);
                    for (g, a) in grads.iter_mut().zip(&acc) {
                        for (gv, &av) in g.iter_mut().zip(a) {
                            *gv += av;
                        }
                    }
                }
                Some(norms)
            }
        };

        let inv_b = 1.0 / b as f32;
        for g in grads.iter_mut() {
            for v in g.iter_mut() {
                *v *= inv_b;
            }
        }
        Ok(StepOut { grads, loss, norms, correct: None })
    }
}

fn artifact(method: &str, config: &str) -> (String, ArtifactSpec) {
    let (extra, outputs): (&[&str], &[&str]) = match method {
        "nonprivate" => (&[], &["grads", "loss"]),
        "reweight" | "reweight_gram" | "reweight_direct" | "reweight_pallas"
        | "multiloss" => (&["clip"], &["grads", "loss", "norms"]),
        "naive1" => (&[], &["grads", "loss", "norm"]),
        "fwd" => (&[], &["loss", "correct"]),
        _ => (&[], &[]),
    };
    (
        method.to_string(),
        ArtifactSpec {
            method: method.to_string(),
            file: format!("native:{config}.{method}"),
            extra_args: extra.iter().map(|s| s.to_string()).collect(),
            outputs: outputs.iter().map(|s| s.to_string()).collect(),
        },
    )
}

fn mlp_config(
    dataset: &str,
    img_shape: &[usize],
    n_classes: usize,
    depth: usize,
    batch: usize,
) -> ConfigSpec {
    let name = format!("mlp{depth}_{dataset}_b{batch}");
    let d_in: usize = img_shape.iter().product();
    let mut params = Vec::with_capacity(depth * 2);
    let mut prev = d_in;
    for l in 0..depth {
        let out = if l == depth - 1 { n_classes } else { HIDDEN };
        params.push(ParamSpec { name: format!("fc{l}.w"), shape: vec![prev, out] });
        params.push(ParamSpec { name: format!("fc{l}.b"), shape: vec![out] });
        prev = out;
    }
    let mut tags: Vec<String> = Vec::new();
    if batch == 1 {
        tags.push("naive".into());
    }
    if depth == 2 && batch == 32 && (dataset == "mnist" || dataset == "fmnist") {
        tags.push("fig5".into());
    }
    if batch == 128 {
        tags.push("fig7".into());
    }
    let mut artifacts = BTreeMap::new();
    for m in [
        "nonprivate",
        "reweight",
        "reweight_gram",
        "reweight_direct",
        "reweight_pallas",
        "multiloss",
        "fwd",
    ] {
        let (k, v) = artifact(m, &name);
        artifacts.insert(k, v);
    }
    if batch == 1 {
        let (k, v) = artifact("naive1", &name);
        artifacts.insert(k, v);
    }
    let mut input_shape = vec![batch];
    input_shape.extend_from_slice(img_shape);
    ConfigSpec {
        name,
        model: "mlp".into(),
        dataset: dataset.into(),
        batch,
        n_classes,
        tags,
        input_shape,
        input_dtype: "f32".into(),
        act_elems_per_example: (depth - 1) * HIDDEN + n_classes,
        params,
        artifacts,
    }
}

/// The built-in config family the native backend can always run.
fn builtin_manifest() -> Manifest {
    let mut configs = BTreeMap::new();
    let datasets: [(&str, &[usize], usize); 3] = [
        ("mnist", &[1, 28, 28], 10),
        ("fmnist", &[1, 28, 28], 10),
        ("cifar10", &[3, 32, 32], 10),
    ];
    for (dataset, shape, n_classes) in datasets {
        for depth in [2usize, 4, 6, 8] {
            for batch in [1usize, 16, 32, 64, 128] {
                let cfg = mlp_config(dataset, shape, n_classes, depth, batch);
                configs.insert(cfg.name.clone(), cfg);
            }
        }
    }
    Manifest { dir: PathBuf::from("builtin:native"), configs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::store::init_params_glorot;

    #[test]
    fn builtin_manifest_is_consistent() {
        let b = NativeBackend::new();
        let m = b.manifest();
        let cfg = m.config("mlp2_mnist_b32").unwrap();
        assert_eq!(cfg.batch, 32);
        assert_eq!(cfg.params[0].shape, vec![784, HIDDEN]);
        // the full batched method matrix is native now
        for method in [
            "nonprivate",
            "reweight",
            "reweight_gram",
            "reweight_direct",
            "reweight_pallas",
            "multiloss",
            "fwd",
        ] {
            assert!(cfg.artifacts.contains_key(method), "{method}");
        }
        // every batched config has a naive1-capable b1 sibling
        for name in m.configs.keys().filter(|n| !n.ends_with("_b1")) {
            let n1 = m.naive_config(name).unwrap();
            assert!(n1.artifacts.contains_key("naive1"), "{name}");
        }
        // every config parses into an MlpSpec
        for cfg in m.configs.values() {
            MlpSpec::from_config(cfg).unwrap();
        }
    }

    #[test]
    fn unsupported_method_is_a_manifest_error() {
        let b = NativeBackend::new();
        let cfg = b.manifest().config("mlp2_mnist_b32").unwrap();
        // naive1 is only registered on the batch-1 siblings
        let err = b.load(cfg, "naive1").unwrap_err();
        assert!(format!("{err:#}").contains("naive1"));
    }

    #[test]
    fn fwd_counts_and_losses_are_sane() {
        let b = NativeBackend::new();
        let cfg = b.manifest().config("mlp2_mnist_b32").unwrap().clone();
        let step = b.load(&cfg, "fwd").unwrap();
        let mut params =
            ParamStore::new(&cfg, Some(&init_params_glorot(&cfg, 0))).unwrap();
        let ds = crate::data::load_dataset("mnist", 64, 0).unwrap();
        let mut stage = BatchStage::for_config(&cfg);
        let batch: Vec<usize> = (0..32).collect();
        crate::data::gather_batch_f32(
            &ds,
            &batch,
            &mut stage.feat_f32,
            &mut stage.labels,
        );
        let out = step.run(&mut params, &stage, None).unwrap();
        assert!(out.loss.is_finite() && out.loss > 0.0);
        let correct = out.correct.unwrap();
        assert!((0.0..=32.0).contains(&correct));
        assert!(out.grads.is_empty());
    }

    #[test]
    fn partial_batch_is_rejected() {
        let b = NativeBackend::new();
        let cfg = b.manifest().config("mlp2_mnist_b32").unwrap().clone();
        let step = b.load(&cfg, "nonprivate").unwrap();
        let mut params = ParamStore::new(&cfg, None).unwrap();
        let mut stage = BatchStage::for_config(&cfg);
        stage.feat_f32.truncate(784 * 31); // one example short
        let err = step.run(&mut params, &stage, None).unwrap_err();
        assert!(format!("{err:#}").contains("staged features"));
    }

    /// The batch-size-laundering hazard: a stage where features *and*
    /// labels are consistently short must still error — the batch is
    /// defined by the config (and the accountant's sampling ratio),
    /// not by whatever happens to be staged.
    #[test]
    fn consistently_truncated_stage_is_rejected() {
        let b = NativeBackend::new();
        let cfg = b.manifest().config("mlp2_mnist_b32").unwrap().clone();
        let step = b.load(&cfg, "nonprivate").unwrap();
        let mut params = ParamStore::new(&cfg, None).unwrap();
        let mut stage = BatchStage::for_config(&cfg);
        stage.feat_f32.truncate(784 * 16);
        stage.labels.truncate(16); // a consistent batch... of 16
        let err = step.run(&mut params, &stage, None).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("16 labels") && msg.contains("sampling ratio"),
            "{msg}"
        );
    }

    #[test]
    fn results_are_deterministic_across_runs() {
        let b = NativeBackend::new();
        let cfg = b.manifest().config("mlp2_mnist_b32").unwrap().clone();
        let ds = crate::data::load_dataset("mnist", 64, 3).unwrap();
        let mut stage = BatchStage::for_config(&cfg);
        let batch: Vec<usize> = (0..32).collect();
        crate::data::gather_batch_f32(
            &ds,
            &batch,
            &mut stage.feat_f32,
            &mut stage.labels,
        );
        let mut params =
            ParamStore::new(&cfg, Some(&init_params_glorot(&cfg, 1))).unwrap();
        for method in
            ["reweight", "reweight_gram", "reweight_direct", "reweight_pallas"]
        {
            let step = b.load(&cfg, method).unwrap();
            let a = step.run(&mut params, &stage, Some(0.7)).unwrap();
            let a2 = step.run(&mut params, &stage, Some(0.7)).unwrap();
            // bitwise: fixed tiles + ordered merge
            assert_eq!(a.grads, a2.grads, "{method}");
            assert_eq!(a.norms, a2.norms, "{method}");
        }
    }

    /// Every artifact the builtin manifest declares actually executes.
    #[test]
    fn all_declared_artifacts_execute() {
        let b = NativeBackend::new();
        for name in ["mlp2_mnist_b16", "mlp2_mnist_b1"] {
            let cfg = b.manifest().config(name).unwrap().clone();
            let ds = crate::data::load_dataset("mnist", 64, 5).unwrap();
            let mut stage = BatchStage::for_config(&cfg);
            let batch: Vec<usize> = (0..cfg.batch).collect();
            crate::data::gather_batch_f32(
                &ds,
                &batch,
                &mut stage.feat_f32,
                &mut stage.labels,
            );
            let mut params =
                ParamStore::new(&cfg, Some(&init_params_glorot(&cfg, 2)))
                    .unwrap();
            for method in cfg.artifacts.keys() {
                let step = b.load(&cfg, method).unwrap();
                let out = step
                    .run(&mut params, &stage, Some(1.0))
                    .unwrap_or_else(|e| panic!("{name}/{method}: {e:#}"));
                assert!(out.loss.is_finite(), "{name}/{method}");
            }
        }
    }
}
