//! NativeBackend — a pure-Rust execution backend for the manifest's
//! MLP config family (linear + bias + ReLU + softmax-CE). Always
//! available, no Python, no artifacts, no xla: this is what makes
//! tier-1 (`cargo build --release && cargo test -q`) hermetic, and it
//! is the reference implementation the PJRT artifacts are checked
//! against when both are present.
//!
//! All four clip methods are implemented with the *structure* the
//! paper compares (Sec 6.1):
//!   - `nonprivate`: one batched backward, no clipping.
//!   - `reweight`:   per-example norms via the activation/delta tap
//!                   trick, then a nu-reweighted gradient assembly —
//!                   per-example gradients are never materialized.
//!   - `multiloss`:  materialized per-example gradients, clipped and
//!                   summed (the vmap-of-grad structure).
//!   - `naive1`:     the batch-1 body of the nxBP loop.
//!
//! Examples are processed in fixed-size chunks in parallel (rayon);
//! chunk boundaries and the merge order are deterministic, so results
//! are bitwise reproducible regardless of thread scheduling.

pub mod mlp;

use super::backend::{Backend, StepFn};
use super::manifest::{ArtifactSpec, ConfigSpec, Manifest, ParamSpec};
use super::store::{BatchStage, ParamStore, StepOut};
use anyhow::{bail, ensure, Context, Result};
use self::mlp::{MlpSpec, Scratch};
use rayon::prelude::*;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

/// Examples per parallel work unit. Fixed (not derived from the thread
/// count) so the floating-point merge order — and therefore every
/// gradient bit — is independent of the machine's parallelism.
const CHUNK_EXAMPLES: usize = 8;

/// Hidden width of the built-in MLP config family.
const HIDDEN: usize = 128;

pub struct NativeBackend {
    manifest: Manifest,
}

impl NativeBackend {
    /// Backend over the built-in MLP config family (mlp{2,4,6,8} x
    /// {mnist,fmnist,cifar10} x batch {1,16,32,64,128}).
    pub fn new() -> NativeBackend {
        NativeBackend { manifest: builtin_manifest() }
    }

    /// Backend over a caller-supplied manifest (tests, custom configs).
    pub fn with_manifest(manifest: Manifest) -> NativeBackend {
        NativeBackend { manifest }
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn load(&self, cfg: &ConfigSpec, method: &str) -> Result<Arc<dyn StepFn>> {
        // route through the manifest so unsupported methods fail with
        // the same "config X has no `m` artifact" error as PJRT
        let art = cfg.artifact(method)?;
        let kind = Kind::parse(&art.method).with_context(|| {
            format!("native backend cannot execute artifact {}", art.file)
        })?;
        let spec = MlpSpec::from_config(cfg)?;
        Ok(Arc::new(NativeStep {
            spec,
            kind,
            method: art.method.clone(),
            config: cfg.name.clone(),
        }))
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    NonPrivate,
    Reweight,
    MultiLoss,
    Naive1,
    Fwd,
}

impl Kind {
    fn parse(method: &str) -> Result<Kind> {
        Ok(match method {
            "nonprivate" => Kind::NonPrivate,
            "reweight" => Kind::Reweight,
            "multiloss" => Kind::MultiLoss,
            "naive1" => Kind::Naive1,
            "fwd" => Kind::Fwd,
            other => bail!("no native kernel for method {other:?}"),
        })
    }
}

struct NativeStep {
    spec: MlpSpec,
    kind: Kind,
    method: String,
    config: String,
}

/// Per-chunk partial results, merged sequentially in chunk order.
struct Partial {
    grads: Vec<Vec<f32>>,
    loss_sum: f64,
    norms: Vec<f32>,
    correct: usize,
}

impl StepFn for NativeStep {
    fn method(&self) -> &str {
        &self.method
    }

    fn run(
        &self,
        params: &ParamStore,
        stage: &BatchStage,
        clip: Option<f32>,
    ) -> Result<StepOut> {
        let spec = &self.spec;
        ensure!(
            stage.is_f32,
            "{}: native mlp expects f32 features",
            self.config
        );
        let b = stage.labels.len();
        let d = spec.d_in;
        ensure!(b > 0, "{}: empty staged batch", self.config);
        ensure!(
            stage.feat_f32.len() == b * d,
            "{}: staged features hold {} elems, need {} ({} examples x {})",
            self.config,
            stage.feat_f32.len(),
            b * d,
            b,
            d
        );
        ensure!(
            params.host.len() == 2 * spec.n_layers(),
            "{}: param store has {} tensors, spec needs {}",
            self.config,
            params.host.len(),
            2 * spec.n_layers()
        );
        for (l, &(din, dout)) in spec.layers.iter().enumerate() {
            ensure!(
                params.host[2 * l].len() == din * dout
                    && params.host[2 * l + 1].len() == dout,
                "{}: layer {l} param shapes do not match the config",
                self.config
            );
        }
        let clip = match self.kind {
            Kind::Reweight | Kind::MultiLoss => Some(
                clip.with_context(|| {
                    format!("{}: {} requires a clip threshold", self.config, self.method)
                })?,
            ),
            _ => None,
        };

        let host = &params.host;
        let feats = &stage.feat_f32;
        let labels = &stage.labels;
        let n_chunks = b / CHUNK_EXAMPLES + usize::from(b % CHUNK_EXAMPLES != 0);
        let kind = self.kind;
        let config = self.config.as_str();

        let partials: Vec<Partial> = (0..n_chunks)
            .into_par_iter()
            .map(|ci| -> Result<Partial> {
                let lo = ci * CHUNK_EXAMPLES;
                let hi = (lo + CHUNK_EXAMPLES).min(b);
                let mut scratch = Scratch::for_spec(spec);
                let mut p = Partial {
                    grads: if kind == Kind::Fwd {
                        Vec::new()
                    } else {
                        spec.zero_grads()
                    },
                    loss_sum: 0.0,
                    norms: Vec::with_capacity(hi - lo),
                    correct: 0,
                };
                // multiLoss materializes one example gradient at a time
                let mut mat = if kind == Kind::MultiLoss {
                    spec.zero_grads()
                } else {
                    Vec::new()
                };
                for i in lo..hi {
                    let x = &feats[i * d..(i + 1) * d];
                    let y = labels[i];
                    ensure!(
                        y >= 0 && (y as usize) < spec.n_classes,
                        "{config}: label {y} at row {i} outside 0..{}",
                        spec.n_classes
                    );
                    let (loss, hit) = mlp::forward(spec, host, x, y, &mut scratch);
                    p.loss_sum += loss as f64;
                    match kind {
                        Kind::Fwd => p.correct += usize::from(hit),
                        Kind::NonPrivate => {
                            mlp::backward(spec, host, x, y, &mut scratch);
                            mlp::accumulate_weighted(spec, x, &scratch, 1.0, &mut p.grads);
                        }
                        Kind::Reweight | Kind::Naive1 => {
                            let sq = mlp::backward(spec, host, x, y, &mut scratch);
                            let norm = sq.sqrt() as f32;
                            let nu = match clip {
                                Some(c) if norm > c => c / norm,
                                _ => 1.0,
                            };
                            mlp::accumulate_weighted(spec, x, &scratch, nu, &mut p.grads);
                            p.norms.push(norm);
                        }
                        Kind::MultiLoss => {
                            mlp::backward(spec, host, x, y, &mut scratch);
                            let sq = mlp::materialize_grad(spec, x, &scratch, &mut mat);
                            let norm = sq.sqrt() as f32;
                            let c = clip.unwrap();
                            let nu = if norm > c { c / norm } else { 1.0 };
                            for (acc, g) in p.grads.iter_mut().zip(&mat) {
                                for (a, &gv) in acc.iter_mut().zip(g) {
                                    *a += nu * gv;
                                }
                            }
                            p.norms.push(norm);
                        }
                    }
                }
                Ok(p)
            })
            .collect::<Result<Vec<Partial>>>()?;

        // deterministic sequential merge in chunk order
        let mut loss_sum = 0.0f64;
        let mut correct = 0usize;
        let mut norms: Vec<f32> = Vec::with_capacity(b);
        let mut grads = if kind == Kind::Fwd {
            Vec::new()
        } else {
            spec.zero_grads()
        };
        for p in partials {
            loss_sum += p.loss_sum;
            correct += p.correct;
            norms.extend(p.norms);
            for (acc, pg) in grads.iter_mut().zip(&p.grads) {
                for (a, &v) in acc.iter_mut().zip(pg) {
                    *a += v;
                }
            }
        }
        let inv = 1.0 / b as f32;
        for g in grads.iter_mut() {
            for v in g.iter_mut() {
                *v *= inv;
            }
        }
        Ok(StepOut {
            grads,
            loss: (loss_sum / b as f64) as f32,
            norms: match kind {
                Kind::Reweight | Kind::MultiLoss | Kind::Naive1 => Some(norms),
                _ => None,
            },
            correct: if kind == Kind::Fwd {
                Some(correct as f32)
            } else {
                None
            },
        })
    }
}

fn artifact(method: &str, config: &str) -> (String, ArtifactSpec) {
    let (extra, outputs): (&[&str], &[&str]) = match method {
        "nonprivate" => (&[], &["grads", "loss"]),
        "reweight" | "multiloss" => (&["clip"], &["grads", "loss", "norms"]),
        "naive1" => (&[], &["grads", "loss", "norm"]),
        "fwd" => (&[], &["loss", "correct"]),
        _ => (&[], &[]),
    };
    (
        method.to_string(),
        ArtifactSpec {
            method: method.to_string(),
            file: format!("native:{config}.{method}"),
            extra_args: extra.iter().map(|s| s.to_string()).collect(),
            outputs: outputs.iter().map(|s| s.to_string()).collect(),
        },
    )
}

fn mlp_config(
    dataset: &str,
    img_shape: &[usize],
    n_classes: usize,
    depth: usize,
    batch: usize,
) -> ConfigSpec {
    let name = format!("mlp{depth}_{dataset}_b{batch}");
    let d_in: usize = img_shape.iter().product();
    let mut params = Vec::with_capacity(depth * 2);
    let mut prev = d_in;
    for l in 0..depth {
        let out = if l == depth - 1 { n_classes } else { HIDDEN };
        params.push(ParamSpec { name: format!("fc{l}.w"), shape: vec![prev, out] });
        params.push(ParamSpec { name: format!("fc{l}.b"), shape: vec![out] });
        prev = out;
    }
    let mut tags: Vec<String> = Vec::new();
    if batch == 1 {
        tags.push("naive".into());
    }
    if depth == 2 && batch == 32 && (dataset == "mnist" || dataset == "fmnist") {
        tags.push("fig5".into());
    }
    if batch == 128 {
        tags.push("fig7".into());
    }
    let mut artifacts = BTreeMap::new();
    for m in ["nonprivate", "reweight", "multiloss", "fwd"] {
        let (k, v) = artifact(m, &name);
        artifacts.insert(k, v);
    }
    if batch == 1 {
        let (k, v) = artifact("naive1", &name);
        artifacts.insert(k, v);
    }
    let mut input_shape = vec![batch];
    input_shape.extend_from_slice(img_shape);
    ConfigSpec {
        name,
        model: "mlp".into(),
        dataset: dataset.into(),
        batch,
        n_classes,
        tags,
        input_shape,
        input_dtype: "f32".into(),
        act_elems_per_example: (depth - 1) * HIDDEN + n_classes,
        params,
        artifacts,
    }
}

/// The built-in config family the native backend can always run.
fn builtin_manifest() -> Manifest {
    let mut configs = BTreeMap::new();
    let datasets: [(&str, &[usize], usize); 3] = [
        ("mnist", &[1, 28, 28], 10),
        ("fmnist", &[1, 28, 28], 10),
        ("cifar10", &[3, 32, 32], 10),
    ];
    for (dataset, shape, n_classes) in datasets {
        for depth in [2usize, 4, 6, 8] {
            for batch in [1usize, 16, 32, 64, 128] {
                let cfg = mlp_config(dataset, shape, n_classes, depth, batch);
                configs.insert(cfg.name.clone(), cfg);
            }
        }
    }
    Manifest { dir: PathBuf::from("builtin:native"), configs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::store::init_params_glorot;

    #[test]
    fn builtin_manifest_is_consistent() {
        let b = NativeBackend::new();
        let m = b.manifest();
        let cfg = m.config("mlp2_mnist_b32").unwrap();
        assert_eq!(cfg.batch, 32);
        assert_eq!(cfg.params[0].shape, vec![784, HIDDEN]);
        assert!(cfg.artifacts.contains_key("reweight"));
        // every batched config has a naive1-capable b1 sibling
        for name in m.configs.keys().filter(|n| !n.ends_with("_b1")) {
            let n1 = m.naive_config(name).unwrap();
            assert!(n1.artifacts.contains_key("naive1"), "{name}");
        }
        // every config parses into an MlpSpec
        for cfg in m.configs.values() {
            MlpSpec::from_config(cfg).unwrap();
        }
    }

    #[test]
    fn unsupported_method_is_a_manifest_error() {
        let b = NativeBackend::new();
        let cfg = b.manifest().config("mlp2_mnist_b32").unwrap();
        let err = b.load(cfg, "reweight_pallas").unwrap_err();
        assert!(format!("{err:#}").contains("reweight_pallas"));
    }

    #[test]
    fn fwd_counts_and_losses_are_sane() {
        let b = NativeBackend::new();
        let cfg = b.manifest().config("mlp2_mnist_b32").unwrap().clone();
        let step = b.load(&cfg, "fwd").unwrap();
        let mut params =
            ParamStore::new(&cfg, Some(&init_params_glorot(&cfg, 0))).unwrap();
        let ds = crate::data::load_dataset("mnist", 64, 0).unwrap();
        let mut stage = BatchStage::for_config(&cfg);
        let batch: Vec<usize> = (0..32).collect();
        crate::data::gather_batch_f32(
            &ds,
            &batch,
            &mut stage.feat_f32,
            &mut stage.labels,
        );
        let out = step.run(&mut params, &stage, None).unwrap();
        assert!(out.loss.is_finite() && out.loss > 0.0);
        let correct = out.correct.unwrap();
        assert!((0.0..=32.0).contains(&correct));
        assert!(out.grads.is_empty());
    }

    #[test]
    fn partial_batch_is_rejected() {
        let b = NativeBackend::new();
        let cfg = b.manifest().config("mlp2_mnist_b32").unwrap().clone();
        let step = b.load(&cfg, "nonprivate").unwrap();
        let mut params = ParamStore::new(&cfg, None).unwrap();
        let mut stage = BatchStage::for_config(&cfg);
        stage.feat_f32.truncate(784 * 31); // one example short
        let err = step.run(&mut params, &stage, None).unwrap_err();
        assert!(format!("{err:#}").contains("staged features"));
    }

    #[test]
    fn results_are_deterministic_across_runs() {
        let b = NativeBackend::new();
        let cfg = b.manifest().config("mlp2_mnist_b32").unwrap().clone();
        let step = b.load(&cfg, "reweight").unwrap();
        let ds = crate::data::load_dataset("mnist", 64, 3).unwrap();
        let mut stage = BatchStage::for_config(&cfg);
        let batch: Vec<usize> = (0..32).collect();
        crate::data::gather_batch_f32(
            &ds,
            &batch,
            &mut stage.feat_f32,
            &mut stage.labels,
        );
        let mut params =
            ParamStore::new(&cfg, Some(&init_params_glorot(&cfg, 1))).unwrap();
        let a = step.run(&mut params, &stage, Some(0.7)).unwrap();
        let b2 = step.run(&mut params, &stage, Some(0.7)).unwrap();
        assert_eq!(a.grads, b2.grads); // bitwise: fixed chunking + ordered merge
        assert_eq!(a.norms, b2.norms);
    }
}
