//! Batched matrix kernels for the native backend: cache-blocked,
//! rayon-parallel f32 GEMMs in the three orientations the
//! forward/backward/gradient passes need, plus the fused
//! per-row-scaled variant behind `reweight_pallas`, the im2col /
//! col2im lowering pair that turns convolution into these same GEMMs,
//! and the column-sum reduction helpers behind the bias gradients.
//!
//! All matrices are dense row-major flat slices.
//!
//! # Determinism contract
//!
//! Every kernel is bitwise deterministic regardless of the rayon
//! thread count:
//!   - parallelism is only over disjoint row blocks of the *output* —
//!     no two tasks ever accumulate into the same element, so there is
//!     no reduction race to order;
//!   - within a task, every output element is accumulated over the
//!     reduction dimension in a single fixed ascending order. (`sgemm`
//!     additionally blocks that loop by `TILE_K` for cache reuse —
//!     blocks are visited in order, so the per-element floating-point
//!     sequence is still plain ascending; `sgemm_nt`/`sgemm_tn` walk
//!     the reduction unblocked.)
//! Tile sizes are fixed constants — never derived from the machine's
//! parallelism — so the same inputs produce the same bits on a laptop
//! and a 128-core server.

use rayon::prelude::*;

/// Output rows per parallel task. Fixed so task boundaries (and the
/// work split, though not the bits — see module docs) are
/// machine-independent.
pub const TILE_M: usize = 32;

/// Reduction-dimension block: one block of the B (or A) operand stays
/// hot in cache across the rows of a task.
pub const TILE_K: usize = 256;

/// C[m x n] += A[m x k] · B[k x n].
///
/// The inner loop is an axpy over a row of B, so it streams
/// contiguous memory and skips zero A entries (ReLU activations are
/// sparse — the skip changes no bits, only work).
pub fn sgemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * k, "sgemm: A must be {m}x{k}");
    assert_eq!(b.len(), k * n, "sgemm: B must be {k}x{n}");
    assert_eq!(c.len(), m * n, "sgemm: C must be {m}x{n}");
    c.par_chunks_mut(TILE_M * n).enumerate().for_each(|(blk, cblk)| {
        let row0 = blk * TILE_M;
        let rows = cblk.len() / n;
        let mut kb = 0;
        while kb < k {
            let kend = (kb + TILE_K).min(k);
            for r in 0..rows {
                let arow = &a[(row0 + r) * k..(row0 + r) * k + k];
                let crow = &mut cblk[r * n..(r + 1) * n];
                for kk in kb..kend {
                    let av = arow[kk];
                    if av != 0.0 {
                        let brow = &b[kk * n..(kk + 1) * n];
                        for (cv, &bv) in crow.iter_mut().zip(brow) {
                            *cv += av * bv;
                        }
                    }
                }
            }
            kb = kend;
        }
    });
}

/// C[m x n] += A[m x k] · B[n x k]ᵀ  (B stored row-major n x k).
///
/// C[i][j] = dot(A row i, B row j): both operands stream
/// contiguously, which is why the backward pass (Δ_{l+1} · Wᵀ) and the
/// Gram products (X · Xᵀ) use this orientation.
pub fn sgemm_nt(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * k, "sgemm_nt: A must be {m}x{k}");
    assert_eq!(b.len(), n * k, "sgemm_nt: B must be {n}x{k}");
    assert_eq!(c.len(), m * n, "sgemm_nt: C must be {m}x{n}");
    c.par_chunks_mut(TILE_M * n).enumerate().for_each(|(blk, cblk)| {
        let row0 = blk * TILE_M;
        let rows = cblk.len() / n;
        for r in 0..rows {
            let arow = &a[(row0 + r) * k..(row0 + r) * k + k];
            let crow = &mut cblk[r * n..(r + 1) * n];
            for (j, cv) in crow.iter_mut().enumerate() {
                let brow = &b[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for (&av, &bv) in arow.iter().zip(brow) {
                    acc += av * bv;
                }
                *cv += acc;
            }
        }
    });
}

/// C[m x n] += A[p x m]ᵀ · B[p x n]  (A stored row-major p x m).
///
/// C[i][j] = Σ_r A[r][i] · B[r][j]: the weight-gradient orientation
/// (taps ᵀ · deltas), reducing over the batch dimension p in ascending
/// row order.
pub fn sgemm_tn(m: usize, p: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    sgemm_tn_impl(m, p, n, a, None, b, c)
}

/// C[m x n] += Σ_r s[r] · A[r][i] · B[r][j] — `sgemm_tn` with a
/// per-reduction-row scale fused into the kernel. This is the
/// `reweight_pallas` trick: the clip factor nu_r multiplies each
/// example's rank-1 gradient contribution *inside* the GEMM, so the
/// nu-weighted delta matrix is never materialized.
pub fn sgemm_tn_scaled(
    m: usize,
    p: usize,
    n: usize,
    a: &[f32],
    scale: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    assert_eq!(scale.len(), p, "sgemm_tn_scaled: scale must have len {p}");
    sgemm_tn_impl(m, p, n, a, Some(scale), b, c)
}

fn sgemm_tn_impl(
    m: usize,
    p: usize,
    n: usize,
    a: &[f32],
    scale: Option<&[f32]>,
    b: &[f32],
    c: &mut [f32],
) {
    assert_eq!(a.len(), p * m, "sgemm_tn: A must be {p}x{m}");
    assert_eq!(b.len(), p * n, "sgemm_tn: B must be {p}x{n}");
    assert_eq!(c.len(), m * n, "sgemm_tn: C must be {m}x{n}");
    c.par_chunks_mut(TILE_M * n).enumerate().for_each(|(blk, cblk)| {
        let row0 = blk * TILE_M;
        let rows = cblk.len() / n;
        for r in 0..p {
            let arow = &a[r * m..(r + 1) * m];
            let brow = &b[r * n..(r + 1) * n];
            let s = match scale {
                Some(sc) => sc[r],
                None => 1.0,
            };
            for i in 0..rows {
                let av = s * arow[row0 + i];
                if av != 0.0 {
                    let crow = &mut cblk[i * n..(i + 1) * n];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += av * bv;
                    }
                }
            }
        }
    });
}

/// Per-reduction-row scaling mode of the f64-accumulating TN kernel.
#[derive(Clone, Copy)]
enum RowScale<'a> {
    One,
    /// per-row factors, len p
    Rows(&'a [f32]),
    /// one factor for every row (a conv example's nu expanded over its
    /// P patch rows, without materializing the expansion)
    Uniform(f32),
}

impl RowScale<'_> {
    #[inline]
    fn at(&self, r: usize) -> f32 {
        match *self {
            RowScale::One => 1.0,
            RowScale::Rows(sc) => sc[r],
            RowScale::Uniform(s) => s,
        }
    }
}

/// `sgemm_tn` with **f64 accumulation**: C[m x n] += A[p x m]ᵀ · B[p x n],
/// each output element reduced in f64 over the p rows (products of the
/// f32 operands, cast exactly) and rounded to f32 once on store. With
/// `scale`, row r's contribution is scaled by `scale[r]` — the
/// multiply happens in f32 (`s * a`), bitwise matching a caller that
/// pre-scales the A rows and passes `None`.
///
/// `work` is the caller-owned f64 accumulation workspace (>= m*n
/// elements): the kernel allocates nothing, which is what keeps the
/// warm step path allocation-free (the arena contract in backend.rs).
///
/// This is the conv family's per-example gradient/norm reduction: a
/// conv weight gradient sums P overlapping position contributions per
/// example, and carrying that reduction in f32 would make the
/// cross-method float divergence grow with P (the MLP family only
/// ever reduces over the batch). Same parallelism contract as the
/// other kernels: disjoint output-row blocks, ascending reduction.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_tn_f64acc(
    m: usize,
    p: usize,
    n: usize,
    a: &[f32],
    scale: Option<&[f32]>,
    b: &[f32],
    c: &mut [f32],
    work: &mut [f64],
) {
    let scale = match scale {
        Some(sc) => {
            assert_eq!(sc.len(), p, "sgemm_tn_f64acc: scale must have len {p}");
            RowScale::Rows(sc)
        }
        None => RowScale::One,
    };
    sgemm_tn_f64acc_impl(m, p, n, a, scale, b, c, work);
}

/// `sgemm_tn_f64acc` with one scale factor applied to every reduction
/// row — bitwise identical to passing `scale = Some(&[s; p])` without
/// materializing that vector.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_tn_f64acc_uniform(
    m: usize,
    p: usize,
    n: usize,
    a: &[f32],
    s: f32,
    b: &[f32],
    c: &mut [f32],
    work: &mut [f64],
) {
    sgemm_tn_f64acc_impl(m, p, n, a, RowScale::Uniform(s), b, c, work);
}

#[allow(clippy::too_many_arguments)]
fn sgemm_tn_f64acc_impl(
    m: usize,
    p: usize,
    n: usize,
    a: &[f32],
    scale: RowScale<'_>,
    b: &[f32],
    c: &mut [f32],
    work: &mut [f64],
) {
    assert_eq!(a.len(), p * m, "sgemm_tn_f64acc: A must be {p}x{m}");
    assert_eq!(b.len(), p * n, "sgemm_tn_f64acc: B must be {p}x{n}");
    assert_eq!(c.len(), m * n, "sgemm_tn_f64acc: C must be {m}x{n}");
    assert!(
        work.len() >= m * n,
        "sgemm_tn_f64acc: work must hold {} f64s, has {}",
        m * n,
        work.len()
    );
    // zip by identical chunk size so work chunk k covers the same
    // output offsets as c chunk k (zip stops at the shorter side)
    c.par_chunks_mut(TILE_M * n)
        .zip(work.par_chunks_mut(TILE_M * n))
        .enumerate()
        .for_each(|(blk, (cblk, wblk))| {
            let row0 = blk * TILE_M;
            let rows = cblk.len() / n;
            let acc = &mut wblk[..cblk.len()];
            acc.iter_mut().for_each(|v| *v = 0.0);
            for r in 0..p {
                let arow = &a[r * m..(r + 1) * m];
                let brow = &b[r * n..(r + 1) * n];
                let s = scale.at(r);
                for i in 0..rows {
                    let av = (s * arow[row0 + i]) as f64;
                    if av != 0.0 {
                        let accrow = &mut acc[i * n..(i + 1) * n];
                        for (cv, &bv) in accrow.iter_mut().zip(brow) {
                            *cv += av * bv as f64;
                        }
                    }
                }
            }
            for (cv, &av) in cblk.iter_mut().zip(acc.iter()) {
                *cv += av as f32;
            }
        });
}

/// Output spatial extent of a convolution dimension:
/// `(len + 2*pad - k) / stride + 1`.
pub fn conv_out(len: usize, k: usize, stride: usize, pad: usize) -> usize {
    debug_assert!(len + 2 * pad >= k && stride > 0);
    (len + 2 * pad - k) / stride + 1
}

/// im2col over an HWC activation map: gather every kh x kw receptive
/// field into one row of the patch matrix.
///
/// `input` is b x (h*w*cin) row-major with per-example layout HWC
/// (position-major, channel-minor — the layout the conv GEMMs
/// produce). `out` is (b * h_out * w_out) x (cin*kh*kw), example-major
/// (example i owns rows i*P..(i+1)*P), with **column order (c, ky,
/// kx)** so a patch row lines up element-for-element with one
/// out-channel slice of a `[cout, cin, kh, kw]` weight tensor.
///
/// Padded taps are written as explicit zeros (never skipped), so the
/// buffer can be reused across steps without a separate clear.
/// Parallel over examples — disjoint output slices, pure gather —
/// hence bitwise deterministic under the module's contract.
#[allow(clippy::too_many_arguments)]
pub fn im2col_hwc(
    b: usize,
    cin: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    input: &[f32],
    out: &mut [f32],
) {
    let h_out = conv_out(h, kh, stride, pad);
    let w_out = conv_out(w, kw, stride, pad);
    let p = h_out * w_out;
    let k = cin * kh * kw;
    assert_eq!(input.len(), b * h * w * cin, "im2col: input must be {b} x {h}x{w}x{cin}");
    assert_eq!(out.len(), b * p * k, "im2col: out must be {} x {k}", b * p);
    out.par_chunks_mut(p * k).enumerate().for_each(|(i, oblk)| {
        let iblk = &input[i * h * w * cin..(i + 1) * h * w * cin];
        for oy in 0..h_out {
            for ox in 0..w_out {
                let row = &mut oblk[(oy * w_out + ox) * k..(oy * w_out + ox + 1) * k];
                for c in 0..cin {
                    for ky in 0..kh {
                        let y = (oy * stride + ky) as isize - pad as isize;
                        let in_y = y >= 0 && (y as usize) < h;
                        for kx in 0..kw {
                            let x = (ox * stride + kx) as isize - pad as isize;
                            let col = c * kh * kw + ky * kw + kx;
                            row[col] = if in_y && x >= 0 && (x as usize) < w {
                                iblk[((y as usize) * w + x as usize) * cin + c]
                            } else {
                                0.0
                            };
                        }
                    }
                }
            }
        }
    });
}

/// Adjoint of `im2col_hwc`: scatter-accumulate patch-row gradients
/// back onto the HWC input map (overlapping receptive fields sum —
/// this is where conv weight sharing lives). Zeroes `out` first, so
/// the buffer is safe to reuse across steps. Parallel over examples
/// (disjoint output slices) with a fixed within-example scatter order
/// — bitwise deterministic.
#[allow(clippy::too_many_arguments)]
pub fn col2im_hwc(
    b: usize,
    cin: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    dpatches: &[f32],
    out: &mut [f32],
) {
    let h_out = conv_out(h, kh, stride, pad);
    let w_out = conv_out(w, kw, stride, pad);
    let p = h_out * w_out;
    let k = cin * kh * kw;
    assert_eq!(dpatches.len(), b * p * k, "col2im: dpatches must be {} x {k}", b * p);
    assert_eq!(out.len(), b * h * w * cin, "col2im: out must be {b} x {h}x{w}x{cin}");
    out.par_chunks_mut(h * w * cin).enumerate().for_each(|(i, oblk)| {
        oblk.iter_mut().for_each(|v| *v = 0.0);
        let pblk = &dpatches[i * p * k..(i + 1) * p * k];
        for oy in 0..h_out {
            for ox in 0..w_out {
                let row = &pblk[(oy * w_out + ox) * k..(oy * w_out + ox + 1) * k];
                for c in 0..cin {
                    for ky in 0..kh {
                        let y = (oy * stride + ky) as isize - pad as isize;
                        if y < 0 || y as usize >= h {
                            continue;
                        }
                        for kx in 0..kw {
                            let x = (ox * stride + kx) as isize - pad as isize;
                            if x < 0 || x as usize >= w {
                                continue;
                            }
                            oblk[((y as usize) * w + x as usize) * cin + c] +=
                                row[c * kh * kw + ky * kw + kx];
                        }
                    }
                }
            }
        }
    });
}

// (The old `row_sq_norms` helper was removed: the tap-trick row
// reduction now lives fused inside `mlp::tap_sq_norms`, writing into
// the caller's buffer so the warm norm path allocates nothing.)

/// `col_sums` with one scale factor for every row — bitwise identical
/// to passing `scale = Some(&[s; rows])` without materializing that
/// vector (a conv example's nu expanded over its P patch rows).
pub fn col_sums_uniform(rows: usize, cols: usize, b: &[f32], s: f32, out: &mut [f32]) {
    assert_eq!(b.len(), rows * cols, "col_sums: B must be {rows}x{cols}");
    assert_eq!(out.len(), cols, "col_sums: out must have len {cols}");
    for r in 0..rows {
        let brow = &b[r * cols..(r + 1) * cols];
        for (o, &bv) in out.iter_mut().zip(brow) {
            *o += s * bv;
        }
    }
}

/// out[j] += Σ_r s[r] · B[r][j] (s = 1 when `scale` is None) — the
/// bias-gradient reduction over the batch, in ascending row order.
pub fn col_sums(rows: usize, cols: usize, b: &[f32], scale: Option<&[f32]>, out: &mut [f32]) {
    assert_eq!(b.len(), rows * cols, "col_sums: B must be {rows}x{cols}");
    assert_eq!(out.len(), cols, "col_sums: out must have len {cols}");
    if let Some(sc) = scale {
        assert_eq!(sc.len(), rows, "col_sums: scale must have len {rows}");
    }
    for r in 0..rows {
        let brow = &b[r * cols..(r + 1) * cols];
        let s = match scale {
            Some(sc) => sc[r],
            None => 1.0,
        };
        for (o, &bv) in out.iter_mut().zip(brow) {
            *o += s * bv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::ChaCha20;

    fn rand_mat(rows: usize, cols: usize, seed: u64) -> Vec<f32> {
        let mut rng = ChaCha20::seeded(seed, 77);
        (0..rows * cols).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
    }

    /// f64 triple-loop reference for C += A·B.
    fn ref_nn(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f64> {
        let mut c = vec![0.0f64; m * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + kk] as f64 * b[kk * n + j] as f64;
                }
            }
        }
        c
    }

    fn assert_close(got: &[f32], want: &[f64]) {
        assert_eq!(got.len(), want.len());
        for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
            let denom = w.abs().max(1.0);
            assert!(
                ((g as f64) - w).abs() / denom < 1e-4,
                "elem {i}: {g} vs {w}"
            );
        }
    }

    #[test]
    fn nn_matches_reference_awkward_shapes() {
        // sizes straddling the tile boundaries: 1, < tile, > tile
        for (m, k, n) in [(1, 1, 1), (3, 5, 4), (33, 70, 17), (65, 300, 9)] {
            let a = rand_mat(m, k, 1);
            let b = rand_mat(k, n, 2);
            let mut c = vec![0.0f32; m * n];
            sgemm(m, k, n, &a, &b, &mut c);
            assert_close(&c, &ref_nn(m, k, n, &a, &b));
        }
    }

    #[test]
    fn nt_matches_nn_on_transposed_operand() {
        let (m, k, n) = (19, 37, 23);
        let a = rand_mat(m, k, 3);
        let b = rand_mat(k, n, 4); // k x n
        // bt: n x k row-major (the transpose of b)
        let mut bt = vec![0.0f32; n * k];
        for i in 0..k {
            for j in 0..n {
                bt[j * k + i] = b[i * n + j];
            }
        }
        let mut c = vec![0.0f32; m * n];
        sgemm_nt(m, k, n, &a, &bt, &mut c);
        assert_close(&c, &ref_nn(m, k, n, &a, &b));
    }

    #[test]
    fn tn_matches_nn_on_transposed_operand() {
        let (m, p, n) = (40, 13, 7);
        let at = rand_mat(p, m, 5); // p x m: the stored operand
        let b = rand_mat(p, n, 6);
        // a: m x p (the logical Aᵀ as a plain matrix)
        let mut a = vec![0.0f32; m * p];
        for r in 0..p {
            for i in 0..m {
                a[i * p + r] = at[r * m + i];
            }
        }
        let mut c = vec![0.0f32; m * n];
        sgemm_tn(m, p, n, &at, &b, &mut c);
        assert_close(&c, &ref_nn(m, p, n, &a, &b));
    }

    #[test]
    fn tn_scaled_matches_prescaled_rows() {
        let (m, p, n) = (11, 9, 6);
        let at = rand_mat(p, m, 7);
        let b = rand_mat(p, n, 8);
        let scale: Vec<f32> = (0..p).map(|r| 0.1 + r as f32 * 0.2).collect();
        // reference: scale the rows of `at` up front, then plain tn
        let scaled_at: Vec<f32> = at
            .iter()
            .enumerate()
            .map(|(idx, &v)| scale[idx / m] * v)
            .collect();
        let mut want = vec![0.0f32; m * n];
        sgemm_tn(m, p, n, &scaled_at, &b, &mut want);
        let mut got = vec![0.0f32; m * n];
        sgemm_tn_scaled(m, p, n, &at, &scale, &b, &mut got);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-5, "{g} vs {w}");
        }
    }

    #[test]
    fn gemm_accumulates_into_c() {
        let (m, k, n) = (2, 3, 2);
        let a = rand_mat(m, k, 9);
        let b = rand_mat(k, n, 10);
        let mut once = vec![0.0f32; m * n];
        sgemm(m, k, n, &a, &b, &mut once);
        let mut twice = vec![0.0f32; m * n];
        sgemm(m, k, n, &a, &b, &mut twice);
        sgemm(m, k, n, &a, &b, &mut twice);
        for (o, t) in once.iter().zip(&twice) {
            assert!((2.0 * o - t).abs() < 1e-5);
        }
    }

    #[test]
    fn kernels_are_bitwise_deterministic() {
        // big enough for several parallel tasks
        let (m, k, n) = (130, 500, 40);
        let a = rand_mat(m, k, 11);
        let b = rand_mat(k, n, 12);
        let run = |f: &dyn Fn(&mut [f32])| {
            let mut c = vec![0.0f32; m * n];
            f(&mut c);
            c
        };
        for _ in 0..3 {
            assert_eq!(
                run(&|c| sgemm(m, k, n, &a, &b, c)),
                run(&|c| sgemm(m, k, n, &a, &b, c))
            );
        }
        let bt = rand_mat(n, k, 13);
        assert_eq!(
            run(&|c| sgemm_nt(m, k, n, &a, &bt, c)),
            run(&|c| sgemm_nt(m, k, n, &a, &bt, c))
        );
        let at = rand_mat(k, m, 14);
        let bb = rand_mat(k, n, 15);
        assert_eq!(
            run(&|c| sgemm_tn(m, k, n, &at, &bb, c)),
            run(&|c| sgemm_tn(m, k, n, &at, &bb, c))
        );
    }

    #[test]
    fn tn_f64acc_matches_reference_and_scaled_rows() {
        let (m, p, n) = (7, 50, 9);
        let at = rand_mat(p, m, 16);
        let b = rand_mat(p, n, 17);
        let mut work = vec![0.0f64; m * n];
        // against the f64 triple-loop reference (via the transpose)
        let mut a = vec![0.0f32; m * p];
        for r in 0..p {
            for i in 0..m {
                a[i * p + r] = at[r * m + i];
            }
        }
        let mut c = vec![0.0f32; m * n];
        sgemm_tn_f64acc(m, p, n, &at, None, &b, &mut c, &mut work);
        assert_close(&c, &ref_nn(m, p, n, &a, &b));
        // fused scale is bitwise identical to pre-scaling the A rows
        let scale: Vec<f32> = (0..p).map(|r| 0.1 + r as f32 * 0.05).collect();
        let scaled_at: Vec<f32> = at
            .iter()
            .enumerate()
            .map(|(idx, &v)| scale[idx / m] * v)
            .collect();
        let mut want = vec![0.0f32; m * n];
        sgemm_tn_f64acc(m, p, n, &scaled_at, None, &b, &mut want, &mut work);
        let mut got = vec![0.0f32; m * n];
        sgemm_tn_f64acc(m, p, n, &at, Some(&scale), &b, &mut got, &mut work);
        assert_eq!(want, got);
        // the uniform variant is bitwise identical to a constant
        // per-row scale vector (a dirty, oversized workspace is fine —
        // the kernel zeroes what it uses)
        let flat: Vec<f32> = vec![0.37; p];
        let mut per_row = vec![0.0f32; m * n];
        sgemm_tn_f64acc(m, p, n, &at, Some(&flat), &b, &mut per_row, &mut work);
        let mut dirty_work = vec![f64::NAN; m * n + 13];
        let mut uniform = vec![0.0f32; m * n];
        sgemm_tn_f64acc_uniform(
            m, p, n, &at, 0.37, &b, &mut uniform, &mut dirty_work,
        );
        assert_eq!(per_row, uniform);
        // and it accumulates into C
        let mut twice = c.clone();
        sgemm_tn_f64acc(m, p, n, &at, None, &b, &mut twice, &mut work);
        for (t, &o) in twice.iter().zip(&c) {
            assert!((t - 2.0 * o).abs() < 1e-4);
        }
    }

    #[test]
    fn im2col_hand_checked_tiny() {
        // one example, one channel, 2x2 input, 3x3 kernel, stride 2,
        // pad 1 => exactly one 1x1 output position centered so the
        // patch window covers rows/cols -1..=1
        let input = vec![1.0f32, 2.0, 3.0, 4.0]; // HW (c=1)
        assert_eq!(conv_out(2, 3, 2, 1), 1);
        let mut out = vec![f32::NAN; 9];
        im2col_hwc(1, 1, 2, 2, 3, 3, 2, 1, &input, &mut out);
        // window rows: (-1: all pad) (0: pad,1,2) (1: pad,3,4)
        assert_eq!(out, vec![0.0, 0.0, 0.0, 0.0, 1.0, 2.0, 0.0, 3.0, 4.0]);
    }

    #[test]
    fn im2col_stride1_positions_and_channels() {
        // 2 channels, 3x3 input, 3x3 kernel, stride 1, pad 1 => 9
        // positions; the center position's patch is the whole map.
        let (h, w, cin) = (3usize, 3usize, 2usize);
        let input = rand_mat(1, h * w * cin, 21);
        let p = conv_out(h, 3, 1, 1) * conv_out(w, 3, 1, 1);
        assert_eq!(p, 9);
        let k = cin * 9;
        let mut out = vec![0.0f32; p * k];
        im2col_hwc(1, cin, h, w, 3, 3, 1, 1, &input, &mut out);
        // center position (oy=1, ox=1): tap (c, ky, kx) = input pixel
        // (y=ky, x=kx) of channel c
        let center = &out[4 * k..5 * k];
        for c in 0..cin {
            for ky in 0..3 {
                for kx in 0..3 {
                    assert_eq!(
                        center[c * 9 + ky * 3 + kx],
                        input[(ky * w + kx) * cin + c],
                        "c={c} ky={ky} kx={kx}"
                    );
                }
            }
        }
    }

    /// col2im is the exact adjoint of im2col:
    /// <im2col(x), y> == <x, col2im(y)> for random x, y — the identity
    /// the conv backward pass rests on.
    #[test]
    fn col2im_is_the_adjoint_of_im2col() {
        for (b, cin, h, w, k, stride, pad) in
            [(2usize, 3usize, 5usize, 4usize, 3usize, 2usize, 1usize),
             (1, 2, 6, 6, 3, 1, 1),
             (3, 1, 4, 4, 2, 2, 0)]
        {
            let p = conv_out(h, k, stride, pad) * conv_out(w, k, stride, pad);
            let kd = cin * k * k;
            let x = rand_mat(b, h * w * cin, 31);
            let y = rand_mat(b * p, kd, 32);
            let mut ax = vec![0.0f32; b * p * kd];
            im2col_hwc(b, cin, h, w, k, k, stride, pad, &x, &mut ax);
            let mut aty = vec![0.0f32; b * h * w * cin];
            col2im_hwc(b, cin, h, w, k, k, stride, pad, &y, &mut aty);
            let lhs: f64 = ax.iter().zip(&y).map(|(&a, &b)| a as f64 * b as f64).sum();
            let rhs: f64 = x.iter().zip(&aty).map(|(&a, &b)| a as f64 * b as f64).sum();
            assert!(
                (lhs - rhs).abs() / lhs.abs().max(1.0) < 1e-4,
                "adjoint identity broke: {lhs} vs {rhs} (b={b} cin={cin} h={h} w={w} k={k} s={stride} p={pad})"
            );
        }
    }

    #[test]
    fn im2col_col2im_deterministic_and_reusable() {
        let (b, cin, h, w) = (4usize, 2usize, 7usize, 7usize);
        let p = conv_out(h, 3, 2, 1) * conv_out(w, 3, 2, 1);
        let kd = cin * 9;
        let x = rand_mat(b, h * w * cin, 41);
        let dp = rand_mat(b * p, kd, 42);
        // dirty buffers must come out identical to clean ones: every
        // slot (including padding) is rewritten
        let mut clean = vec![0.0f32; b * p * kd];
        im2col_hwc(b, cin, h, w, 3, 3, 2, 1, &x, &mut clean);
        let mut dirty = vec![7.5f32; b * p * kd];
        im2col_hwc(b, cin, h, w, 3, 3, 2, 1, &x, &mut dirty);
        assert_eq!(clean, dirty);
        let mut c1 = vec![0.0f32; b * h * w * cin];
        col2im_hwc(b, cin, h, w, 3, 3, 2, 1, &dp, &mut c1);
        let mut c2 = vec![-3.25f32; b * h * w * cin];
        col2im_hwc(b, cin, h, w, 3, 3, 2, 1, &dp, &mut c2);
        assert_eq!(c1, c2);
    }

    #[test]
    fn col_sums_plain_scaled_and_uniform() {
        let a = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2 x 3
        let mut sums = vec![0.0f32; 3];
        col_sums(2, 3, &a, None, &mut sums);
        assert_eq!(sums, vec![5.0, 7.0, 9.0]);
        let mut wsums = vec![0.0f32; 3];
        col_sums(2, 3, &a, Some(&[2.0, 0.5]), &mut wsums);
        assert_eq!(wsums, vec![4.0, 6.5, 9.0]);
        // the uniform variant matches a constant scale vector bitwise
        let mut per_row = vec![0.0f32; 3];
        col_sums(2, 3, &a, Some(&[0.3, 0.3]), &mut per_row);
        let mut uniform = vec![0.0f32; 3];
        col_sums_uniform(2, 3, &a, 0.3, &mut uniform);
        assert_eq!(per_row, uniform);
    }
}
