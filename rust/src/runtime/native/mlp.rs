//! Dense MLP math for the native backend: per-example forward,
//! softmax-CE loss, backward deltas, the paper's tap-based squared-norm
//! trick, and (weighted or materialized) gradient assembly.
//!
//! Layer l (0-based, L layers total): z_l = a_{l-1} W_l + b_l with
//! a_{-1} = x, a_l = relu(z_l) for l < L-1, and softmax-CE on z_{L-1}.
//! W_l is row-major [in, out] — matching the manifest's `fc{l}.w`
//! shapes — so the forward inner loop streams contiguous rows.
//!
//! The reweight norm trick (paper Sec 5): the per-example gradient of a
//! linear layer is the rank-1 outer product a_{l-1,i} δ_{l,i}^T, so
//!   ||g_i||² = Σ_l ( ||a_{l-1,i}||²·||δ_{l,i}||² + ||δ_{l,i}||² )
//! needs only the forward taps and backward deltas — never the
//! per-example gradient tensors themselves.

use crate::runtime::manifest::ConfigSpec;
use anyhow::{ensure, Result};

/// Layer dimensions parsed and validated from a manifest config.
#[derive(Debug, Clone)]
pub struct MlpSpec {
    pub d_in: usize,
    /// (in, out) of each linear layer, in order
    pub layers: Vec<(usize, usize)>,
    pub n_classes: usize,
    pub batch: usize,
}

impl MlpSpec {
    pub fn from_config(cfg: &ConfigSpec) -> Result<MlpSpec> {
        ensure!(
            cfg.model == "mlp",
            "native backend supports the `mlp` config family; config {} has model {:?}",
            cfg.name,
            cfg.model
        );
        ensure!(
            cfg.input_dtype == "f32",
            "native mlp expects f32 input, config {} has {:?}",
            cfg.name,
            cfg.input_dtype
        );
        ensure!(
            !cfg.params.is_empty() && cfg.params.len() % 2 == 0,
            "config {}: mlp params must be (weight, bias) pairs, got {} tensors",
            cfg.name,
            cfg.params.len()
        );
        ensure!(
            cfg.input_shape.len() >= 2 && cfg.input_shape[0] == cfg.batch,
            "config {}: input shape {:?} does not lead with batch {}",
            cfg.name,
            cfg.input_shape,
            cfg.batch
        );
        let d_in: usize = cfg.input_shape[1..].iter().product();
        let mut layers = Vec::with_capacity(cfg.params.len() / 2);
        let mut prev = d_in;
        for (l, pair) in cfg.params.chunks(2).enumerate() {
            let (w, b) = (&pair[0], &pair[1]);
            ensure!(
                w.shape.len() == 2 && b.shape.len() == 1,
                "config {}: layer {l} expects 2-d weight + 1-d bias, got {:?} / {:?}",
                cfg.name,
                w.shape,
                b.shape
            );
            ensure!(
                w.shape[0] == prev,
                "config {}: layer {l} weight in-dim {} != previous out-dim {prev}",
                cfg.name,
                w.shape[0]
            );
            ensure!(
                b.shape[0] == w.shape[1],
                "config {}: layer {l} bias dim {} != weight out-dim {}",
                cfg.name,
                b.shape[0],
                w.shape[1]
            );
            layers.push((w.shape[0], w.shape[1]));
            prev = w.shape[1];
        }
        ensure!(
            prev == cfg.n_classes,
            "config {}: final layer out-dim {prev} != n_classes {}",
            cfg.name,
            cfg.n_classes
        );
        Ok(MlpSpec {
            d_in,
            layers,
            n_classes: cfg.n_classes,
            batch: cfg.batch,
        })
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Flat gradient buffers in manifest order [W0, b0, W1, b1, ...].
    pub fn zero_grads(&self) -> Vec<Vec<f32>> {
        let mut out = Vec::with_capacity(self.layers.len() * 2);
        for &(din, dout) in &self.layers {
            out.push(vec![0.0f32; din * dout]);
            out.push(vec![0.0f32; dout]);
        }
        out
    }
}

/// Per-example forward/backward scratch, reused across the examples of
/// one chunk to keep allocation off the hot path.
pub struct Scratch {
    /// pre-activations z_l
    zs: Vec<Vec<f32>>,
    /// post-activations a_l = relu(z_l); the last entry is unused
    acts: Vec<Vec<f32>>,
    /// dLoss/dz_l
    deltas: Vec<Vec<f32>>,
    probs: Vec<f32>,
}

impl Scratch {
    pub fn for_spec(spec: &MlpSpec) -> Scratch {
        let outs: Vec<usize> = spec.layers.iter().map(|&(_, o)| o).collect();
        Scratch {
            zs: outs.iter().map(|&o| vec![0.0; o]).collect(),
            acts: outs.iter().map(|&o| vec![0.0; o]).collect(),
            deltas: outs.iter().map(|&o| vec![0.0; o]).collect(),
            probs: vec![0.0; spec.n_classes],
        }
    }
}

/// Forward one example. Fills `zs`/`acts`/`probs`; returns
/// (cross-entropy loss, predicted-class == label).
pub fn forward(
    spec: &MlpSpec,
    params: &[Vec<f32>],
    x: &[f32],
    y: i32,
    s: &mut Scratch,
) -> (f32, bool) {
    let n = spec.n_layers();
    for l in 0..n {
        let (din, dout) = spec.layers[l];
        let w = &params[2 * l];
        let b = &params[2 * l + 1];
        let input: &[f32] = if l == 0 { x } else { &s.acts[l - 1] };
        let z = &mut s.zs[l];
        z.copy_from_slice(b);
        debug_assert_eq!(input.len(), din);
        for (k, &xk) in input.iter().enumerate() {
            if xk != 0.0 {
                let row = &w[k * dout..(k + 1) * dout];
                for (zj, &wj) in z.iter_mut().zip(row) {
                    *zj += xk * wj;
                }
            }
        }
        if l < n - 1 {
            for (a, &z) in s.acts[l].iter_mut().zip(s.zs[l].iter()) {
                *a = z.max(0.0);
            }
        }
    }
    // softmax-CE on the logits, numerically stable
    let logits = &s.zs[n - 1];
    let mut m = f32::NEG_INFINITY;
    let mut argmax = 0usize;
    for (j, &v) in logits.iter().enumerate() {
        if v > m {
            m = v;
            argmax = j;
        }
    }
    let mut sum = 0.0f64;
    for (p, &z) in s.probs.iter_mut().zip(logits.iter()) {
        let e = ((z - m) as f64).exp();
        *p = e as f32;
        sum += e;
    }
    let inv = (1.0 / sum) as f32;
    for p in s.probs.iter_mut() {
        *p *= inv;
    }
    let logsum = sum.ln() as f32;
    let loss = logsum - (logits[y as usize] - m);
    (loss, argmax == y as usize)
}

/// Backward one example (after `forward`): fills `deltas` and returns
/// the example's squared gradient norm via the tap trick, accumulated
/// in f64.
pub fn backward(
    spec: &MlpSpec,
    params: &[Vec<f32>],
    x: &[f32],
    y: i32,
    s: &mut Scratch,
) -> f64 {
    let n = spec.n_layers();
    // dCE/dz = softmax(z) - onehot(y), for the per-example loss
    {
        let d = &mut s.deltas[n - 1];
        d.copy_from_slice(&s.probs);
        d[y as usize] -= 1.0;
    }
    for l in (0..n - 1).rev() {
        let (_, dout_next) = spec.layers[l + 1];
        let w_next = &params[2 * (l + 1)];
        // split-borrow: delta_l from delta_{l+1}
        let (head, tail) = s.deltas.split_at_mut(l + 1);
        let d_next = &tail[0];
        let d_here = &mut head[l];
        for (k, dk) in d_here.iter_mut().enumerate() {
            if s.zs[l][k] > 0.0 {
                let row = &w_next[k * dout_next..(k + 1) * dout_next];
                let mut acc = 0.0f32;
                for (&wv, &dv) in row.iter().zip(d_next.iter()) {
                    acc += wv * dv;
                }
                *dk = acc;
            } else {
                *dk = 0.0;
            }
        }
    }
    // tap-based squared norm: sum_l (||a_{l-1}||^2 + 1) * ||delta_l||^2
    let mut sq = 0.0f64;
    for l in 0..n {
        let input: &[f32] = if l == 0 { x } else { &s.acts[l - 1] };
        let a2: f64 = input.iter().map(|&v| (v as f64) * (v as f64)).sum();
        let d2: f64 = s.deltas[l]
            .iter()
            .map(|&v| (v as f64) * (v as f64))
            .sum();
        sq += (a2 + 1.0) * d2;
    }
    sq
}

/// Accumulate `nu * g_i` into `acc` (layout [W0, b0, W1, b1, ...])
/// from the deltas/taps of the last `forward`+`backward`.
pub fn accumulate_weighted(
    spec: &MlpSpec,
    x: &[f32],
    s: &Scratch,
    nu: f32,
    acc: &mut [Vec<f32>],
) {
    let n = spec.n_layers();
    for l in 0..n {
        let (din, dout) = spec.layers[l];
        let input: &[f32] = if l == 0 { x } else { &s.acts[l - 1] };
        let delta = &s.deltas[l];
        let gw = &mut acc[2 * l];
        debug_assert_eq!(input.len(), din);
        for (k, &xk) in input.iter().enumerate() {
            let scaled = nu * xk;
            if scaled != 0.0 {
                let row = &mut gw[k * dout..(k + 1) * dout];
                for (g, &d) in row.iter_mut().zip(delta.iter()) {
                    *g += scaled * d;
                }
            }
        }
        let gb = &mut acc[2 * l + 1];
        for (g, &d) in gb.iter_mut().zip(delta.iter()) {
            *g += nu * d;
        }
    }
}

/// Materialize the example's full gradient into `out` (overwriting),
/// returning its squared norm computed from the materialized values —
/// the multiLoss structure, deliberately heavier than the tap trick.
pub fn materialize_grad(
    spec: &MlpSpec,
    x: &[f32],
    s: &Scratch,
    out: &mut [Vec<f32>],
) -> f64 {
    let n = spec.n_layers();
    let mut sq = 0.0f64;
    for l in 0..n {
        let (din, dout) = spec.layers[l];
        let input: &[f32] = if l == 0 { x } else { &s.acts[l - 1] };
        let delta = &s.deltas[l];
        let gw = &mut out[2 * l];
        debug_assert_eq!(input.len(), din);
        for (k, &xk) in input.iter().enumerate() {
            let row = &mut gw[k * dout..(k + 1) * dout];
            for (g, &d) in row.iter_mut().zip(delta.iter()) {
                *g = xk * d;
                sq += (*g as f64) * (*g as f64);
            }
        }
        let gb = &mut out[2 * l + 1];
        for (g, &d) in gb.iter_mut().zip(delta.iter()) {
            *g = d;
            sq += (*g as f64) * (*g as f64);
        }
    }
    sq
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ParamSpec;

    fn tiny_cfg() -> ConfigSpec {
        ConfigSpec {
            name: "tiny_b2".into(),
            model: "mlp".into(),
            dataset: "mnist".into(),
            batch: 2,
            n_classes: 3,
            tags: vec![],
            input_shape: vec![2, 4],
            input_dtype: "f32".into(),
            act_elems_per_example: 5,
            params: vec![
                ParamSpec { name: "fc0.w".into(), shape: vec![4, 5] },
                ParamSpec { name: "fc0.b".into(), shape: vec![5] },
                ParamSpec { name: "fc1.w".into(), shape: vec![5, 3] },
                ParamSpec { name: "fc1.b".into(), shape: vec![3] },
            ],
            artifacts: Default::default(),
        }
    }

    fn tiny_params(spec: &MlpSpec, seed: u64) -> Vec<Vec<f32>> {
        use crate::rng::ChaCha20;
        let mut rng = ChaCha20::seeded(seed, 42);
        spec.layers
            .iter()
            .flat_map(|&(i, o)| {
                vec![
                    (0..i * o)
                        .map(|_| rng.next_f32() - 0.5)
                        .collect::<Vec<f32>>(),
                    (0..o).map(|_| rng.next_f32() - 0.5).collect(),
                ]
            })
            .collect()
    }

    #[test]
    fn spec_parses_and_validates() {
        let cfg = tiny_cfg();
        let spec = MlpSpec::from_config(&cfg).unwrap();
        assert_eq!(spec.d_in, 4);
        assert_eq!(spec.layers, vec![(4, 5), (5, 3)]);
        assert_eq!(spec.n_classes, 3);

        let mut bad = cfg.clone();
        bad.params[2].shape = vec![6, 3]; // chain mismatch
        assert!(MlpSpec::from_config(&bad).is_err());
        let mut wrong_model = cfg.clone();
        wrong_model.model = "cnn".into();
        assert!(MlpSpec::from_config(&wrong_model).is_err());
    }

    #[test]
    fn softmax_ce_loss_matches_uniform_at_zero_logits() {
        let cfg = tiny_cfg();
        let spec = MlpSpec::from_config(&cfg).unwrap();
        let params = spec.zero_grads(); // all-zero weights: logits are zero
        let mut s = Scratch::for_spec(&spec);
        let (loss, _) = forward(&spec, &params, &[0.3, -0.1, 0.5, 0.9], 1, &mut s);
        assert!((loss - (3.0f32).ln()).abs() < 1e-6, "loss {loss}");
        for &p in &s.probs {
            assert!((p - 1.0 / 3.0).abs() < 1e-6);
        }
    }

    /// Backward gradients match central finite differences of the loss
    /// — the ground-truth check the whole native backend rests on.
    #[test]
    fn gradient_matches_finite_differences() {
        let cfg = tiny_cfg();
        let spec = MlpSpec::from_config(&cfg).unwrap();
        let params = tiny_params(&spec, 9);
        let x = [0.8f32, -0.4, 0.1, 1.2];
        let y = 2i32;

        let mut s = Scratch::for_spec(&spec);
        forward(&spec, &params, &x, y, &mut s);
        let sq = backward(&spec, &params, &x, y, &mut s);
        let mut grads = spec.zero_grads();
        let sq_mat = materialize_grad(&spec, &x, &s, &mut grads);
        assert!(
            (sq - sq_mat).abs() / sq_mat.max(1e-9) < 1e-5,
            "tap norm {sq} vs materialized {sq_mat}"
        );

        let eps = 1e-3f32;
        let mut scratch = Scratch::for_spec(&spec);
        for t in 0..params.len() {
            for idx in [0usize, params[t].len() / 2, params[t].len() - 1] {
                let mut p_hi = params.clone();
                p_hi[t][idx] += eps;
                let (l_hi, _) = forward(&spec, &p_hi, &x, y, &mut scratch);
                let mut p_lo = params.clone();
                p_lo[t][idx] -= eps;
                let (l_lo, _) = forward(&spec, &p_lo, &x, y, &mut scratch);
                let fd = (l_hi - l_lo) / (2.0 * eps);
                let an = grads[t][idx];
                assert!(
                    (fd - an).abs() < 2e-3 * (1.0 + an.abs()),
                    "param {t}[{idx}]: finite-diff {fd} vs analytic {an}"
                );
            }
        }
    }

    #[test]
    fn weighted_accumulate_scales_materialized_grad() {
        let cfg = tiny_cfg();
        let spec = MlpSpec::from_config(&cfg).unwrap();
        let params = tiny_params(&spec, 4);
        let x = [0.2f32, 0.7, -0.3, 0.5];
        let mut s = Scratch::for_spec(&spec);
        forward(&spec, &params, &x, 0, &mut s);
        backward(&spec, &params, &x, 0, &mut s);

        let mut mat = spec.zero_grads();
        materialize_grad(&spec, &x, &s, &mut mat);
        let mut acc = spec.zero_grads();
        accumulate_weighted(&spec, &x, &s, 0.25, &mut acc);
        for (a, m) in acc.iter().zip(&mat) {
            for (&av, &mv) in a.iter().zip(m) {
                assert!((av - 0.25 * mv).abs() < 1e-6);
            }
        }
    }
}
