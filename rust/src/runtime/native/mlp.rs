//! Dense MLP math for the native backend, in two tiers:
//!
//!   - the **batched execution core** (`BatchScratch`,
//!     `forward_batch`, `backward_batch`, `tap_sq_norms`,
//!     `gram_sq_norms`, `grads_from_deltas`, ...): activations and
//!     deltas held as B x d matrices, every heavy op a `gemm` kernel
//!     call. `NativeStep` executes it through the `taps::ModelFamily`
//!     trait (`MlpSpec` is the registry's `"mlp"` family, alongside
//!     the conv family) — it is where the paper's "clipping can stay
//!     batched" claim lives.
//!   - the **scalar reference** (`Scratch`, `forward`, `backward`,
//!     `accumulate_weighted`, `materialize_grad`): one example at a
//!     time, validated against central finite differences. The batched
//!     core is tested against it.
//!
//! Layer l (0-based, L layers total): z_l = a_{l-1} W_l + b_l with
//! a_{-1} = x, a_l = relu(z_l) for l < L-1, and softmax-CE on z_{L-1}.
//! W_l is row-major [in, out] — matching the manifest's `fc{l}.w`
//! shapes — so forward GEMMs stream contiguous rows.
//!
//! The reweight norm trick (paper Sec 5): the per-example gradient of a
//! linear layer is the rank-1 outer product a_{l-1,i} δ_{l,i}^T, so
//!   ||g_i||² = Σ_l ( ||a_{l-1,i}||²·||δ_{l,i}||² + ||δ_{l,i}||² )
//! needs only the forward taps and backward deltas — never the
//! per-example gradient tensors themselves.

use super::gemm;
use super::taps::{
    downcast_scratch, downcast_scratch_ref, ModelFamily, NuBlock, ScratchAny,
};
use crate::runtime::manifest::ConfigSpec;
use crate::runtime::store::GradVec;
use anyhow::{ensure, Result};

/// Layer dimensions parsed and validated from a manifest config.
#[derive(Debug, Clone)]
pub struct MlpSpec {
    pub d_in: usize,
    /// (in, out) of each linear layer, in order
    pub layers: Vec<(usize, usize)>,
    pub n_classes: usize,
    pub batch: usize,
}

impl MlpSpec {
    pub fn from_config(cfg: &ConfigSpec) -> Result<MlpSpec> {
        ensure!(
            cfg.model == "mlp",
            "native backend supports the `mlp` config family; config {} has model {:?}",
            cfg.name,
            cfg.model
        );
        ensure!(
            cfg.input_dtype == "f32",
            "native mlp expects f32 input, config {} has {:?}",
            cfg.name,
            cfg.input_dtype
        );
        ensure!(
            !cfg.params.is_empty() && cfg.params.len() % 2 == 0,
            "config {}: mlp params must be (weight, bias) pairs, got {} tensors",
            cfg.name,
            cfg.params.len()
        );
        ensure!(
            cfg.input_shape.len() >= 2 && cfg.input_shape[0] == cfg.batch,
            "config {}: input shape {:?} does not lead with batch {}",
            cfg.name,
            cfg.input_shape,
            cfg.batch
        );
        let d_in: usize = cfg.input_shape[1..].iter().product();
        let mut layers = Vec::with_capacity(cfg.params.len() / 2);
        let mut prev = d_in;
        for (l, pair) in cfg.params.chunks(2).enumerate() {
            let (w, b) = (&pair[0], &pair[1]);
            ensure!(
                w.shape.len() == 2 && b.shape.len() == 1,
                "config {}: layer {l} expects 2-d weight + 1-d bias, got {:?} / {:?}",
                cfg.name,
                w.shape,
                b.shape
            );
            ensure!(
                w.shape[0] == prev,
                "config {}: layer {l} weight in-dim {} != previous out-dim {prev}",
                cfg.name,
                w.shape[0]
            );
            ensure!(
                b.shape[0] == w.shape[1],
                "config {}: layer {l} bias dim {} != weight out-dim {}",
                cfg.name,
                b.shape[0],
                w.shape[1]
            );
            layers.push((w.shape[0], w.shape[1]));
            prev = w.shape[1];
        }
        ensure!(
            prev == cfg.n_classes,
            "config {}: final layer out-dim {prev} != n_classes {}",
            cfg.name,
            cfg.n_classes
        );
        Ok(MlpSpec {
            d_in,
            layers,
            n_classes: cfg.n_classes,
            batch: cfg.batch,
        })
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Per-parameter element counts in manifest order
    /// [W0, b0, W1, b1, ...] — the gradient arena layout.
    pub fn grad_lens(&self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.layers.len() * 2);
        for &(din, dout) in &self.layers {
            out.push(din * dout);
            out.push(dout);
        }
        out
    }

    /// Check a param store's tensor count and per-tensor lengths.
    pub fn check_params(&self, config: &str, host: &[Vec<f32>]) -> Result<()> {
        ensure!(
            host.len() == 2 * self.n_layers(),
            "{config}: param store has {} tensors, spec needs {}",
            host.len(),
            2 * self.n_layers()
        );
        for (l, &(din, dout)) in self.layers.iter().enumerate() {
            ensure!(
                host[2 * l].len() == din * dout && host[2 * l + 1].len() == dout,
                "{config}: layer {l} param shapes do not match the config"
            );
        }
        Ok(())
    }

    /// Flat gradient buffers in manifest order [W0, b0, W1, b1, ...].
    pub fn zero_grads(&self) -> Vec<Vec<f32>> {
        let mut out = Vec::with_capacity(self.layers.len() * 2);
        for &(din, dout) in &self.layers {
            out.push(vec![0.0f32; din * dout]);
            out.push(vec![0.0f32; dout]);
        }
        out
    }
}

/// Per-example forward/backward scratch, reused across the examples of
/// one chunk to keep allocation off the hot path.
pub struct Scratch {
    /// pre-activations z_l
    zs: Vec<Vec<f32>>,
    /// post-activations a_l = relu(z_l); the last entry is unused
    acts: Vec<Vec<f32>>,
    /// dLoss/dz_l
    deltas: Vec<Vec<f32>>,
    probs: Vec<f32>,
}

impl Scratch {
    pub fn for_spec(spec: &MlpSpec) -> Scratch {
        let outs: Vec<usize> = spec.layers.iter().map(|&(_, o)| o).collect();
        Scratch {
            zs: outs.iter().map(|&o| vec![0.0; o]).collect(),
            acts: outs.iter().map(|&o| vec![0.0; o]).collect(),
            deltas: outs.iter().map(|&o| vec![0.0; o]).collect(),
            probs: vec![0.0; spec.n_classes],
        }
    }
}

/// Forward one example. Fills `zs`/`acts`/`probs`; returns
/// (cross-entropy loss, predicted-class == label).
pub fn forward(
    spec: &MlpSpec,
    params: &[Vec<f32>],
    x: &[f32],
    y: i32,
    s: &mut Scratch,
) -> (f32, bool) {
    let n = spec.n_layers();
    for l in 0..n {
        let (din, dout) = spec.layers[l];
        let w = &params[2 * l];
        let b = &params[2 * l + 1];
        let input: &[f32] = if l == 0 { x } else { &s.acts[l - 1] };
        let z = &mut s.zs[l];
        z.copy_from_slice(b);
        debug_assert_eq!(input.len(), din);
        for (k, &xk) in input.iter().enumerate() {
            if xk != 0.0 {
                let row = &w[k * dout..(k + 1) * dout];
                for (zj, &wj) in z.iter_mut().zip(row) {
                    *zj += xk * wj;
                }
            }
        }
        if l < n - 1 {
            for (a, &z) in s.acts[l].iter_mut().zip(s.zs[l].iter()) {
                *a = z.max(0.0);
            }
        }
    }
    // softmax-CE on the logits, numerically stable
    let logits = &s.zs[n - 1];
    let mut m = f32::NEG_INFINITY;
    let mut argmax = 0usize;
    for (j, &v) in logits.iter().enumerate() {
        if v > m {
            m = v;
            argmax = j;
        }
    }
    let mut sum = 0.0f64;
    for (p, &z) in s.probs.iter_mut().zip(logits.iter()) {
        let e = ((z - m) as f64).exp();
        *p = e as f32;
        sum += e;
    }
    let inv = (1.0 / sum) as f32;
    for p in s.probs.iter_mut() {
        *p *= inv;
    }
    let logsum = sum.ln() as f32;
    let loss = logsum - (logits[y as usize] - m);
    (loss, argmax == y as usize)
}

/// Backward one example (after `forward`): fills `deltas` and returns
/// the example's squared gradient norm via the tap trick, accumulated
/// in f64.
pub fn backward(
    spec: &MlpSpec,
    params: &[Vec<f32>],
    x: &[f32],
    y: i32,
    s: &mut Scratch,
) -> f64 {
    let n = spec.n_layers();
    // dCE/dz = softmax(z) - onehot(y), for the per-example loss
    {
        let d = &mut s.deltas[n - 1];
        d.copy_from_slice(&s.probs);
        d[y as usize] -= 1.0;
    }
    for l in (0..n - 1).rev() {
        let (_, dout_next) = spec.layers[l + 1];
        let w_next = &params[2 * (l + 1)];
        // split-borrow: delta_l from delta_{l+1}
        let (head, tail) = s.deltas.split_at_mut(l + 1);
        let d_next = &tail[0];
        let d_here = &mut head[l];
        for (k, dk) in d_here.iter_mut().enumerate() {
            if s.zs[l][k] > 0.0 {
                let row = &w_next[k * dout_next..(k + 1) * dout_next];
                // lint: allow(f32-accum) -- single-row dot in fixed
                // ascending index order (the zip walks 0..dout_next),
                // identical order on every path, so it is bitwise
                // reproducible; dout_next is small (a layer width).
                let mut acc = 0.0f32;
                for (&wv, &dv) in row.iter().zip(d_next.iter()) {
                    acc += wv * dv;
                }
                *dk = acc;
            } else {
                *dk = 0.0;
            }
        }
    }
    // tap-based squared norm: sum_l (||a_{l-1}||^2 + 1) * ||delta_l||^2
    let mut sq = 0.0f64;
    for l in 0..n {
        let input: &[f32] = if l == 0 { x } else { &s.acts[l - 1] };
        let a2: f64 = input.iter().map(|&v| (v as f64) * (v as f64)).sum();
        let d2: f64 = s.deltas[l]
            .iter()
            .map(|&v| (v as f64) * (v as f64))
            .sum();
        sq += (a2 + 1.0) * d2;
    }
    sq
}

/// Accumulate `nu * g_i` into `acc` (layout [W0, b0, W1, b1, ...])
/// from the deltas/taps of the last `forward`+`backward`.
pub fn accumulate_weighted(
    spec: &MlpSpec,
    x: &[f32],
    s: &Scratch,
    nu: f32,
    acc: &mut [Vec<f32>],
) {
    let n = spec.n_layers();
    for l in 0..n {
        let (din, dout) = spec.layers[l];
        let input: &[f32] = if l == 0 { x } else { &s.acts[l - 1] };
        let delta = &s.deltas[l];
        let gw = &mut acc[2 * l];
        debug_assert_eq!(input.len(), din);
        for (k, &xk) in input.iter().enumerate() {
            let scaled = nu * xk;
            if scaled != 0.0 {
                let row = &mut gw[k * dout..(k + 1) * dout];
                for (g, &d) in row.iter_mut().zip(delta.iter()) {
                    *g += scaled * d;
                }
            }
        }
        let gb = &mut acc[2 * l + 1];
        for (g, &d) in gb.iter_mut().zip(delta.iter()) {
            *g += nu * d;
        }
    }
}

/// Materialize the example's full gradient into `out` (overwriting),
/// returning its squared norm computed from the materialized values —
/// the multiLoss structure, deliberately heavier than the tap trick.
pub fn materialize_grad(
    spec: &MlpSpec,
    x: &[f32],
    s: &Scratch,
    out: &mut [Vec<f32>],
) -> f64 {
    let n = spec.n_layers();
    let mut sq = 0.0f64;
    for l in 0..n {
        let (din, dout) = spec.layers[l];
        let input: &[f32] = if l == 0 { x } else { &s.acts[l - 1] };
        let delta = &s.deltas[l];
        let gw = &mut out[2 * l];
        debug_assert_eq!(input.len(), din);
        for (k, &xk) in input.iter().enumerate() {
            let row = &mut gw[k * dout..(k + 1) * dout];
            for (g, &d) in row.iter_mut().zip(delta.iter()) {
                *g = xk * d;
                sq += (*g as f64) * (*g as f64);
            }
        }
        let gb = &mut out[2 * l + 1];
        for (g, &d) in gb.iter_mut().zip(delta.iter()) {
            *g = d;
            sq += (*g as f64) * (*g as f64);
        }
    }
    sq
}

// ---------------------------------------------------------------------
// Batched execution core
// ---------------------------------------------------------------------

/// Whole-batch forward/backward scratch: per layer, B x d_out
/// matrices for pre-activations, post-activations and deltas, held
/// flat and row-major so every pass is a `gemm` call.
pub struct BatchScratch {
    pub b: usize,
    /// pre-activations z_l, each b x dout_l
    pub zs: Vec<Vec<f32>>,
    /// post-activations a_l = relu(z_l); the last entry is unused
    pub acts: Vec<Vec<f32>>,
    /// dLoss_i/dz_l as rows, each b x dout_l
    pub deltas: Vec<Vec<f32>>,
    /// softmax rows, b x n_classes
    pub probs: Vec<f32>,
    /// b x b activation/delta Gram buffers for `gram_sq_norms` —
    /// lazily grown on first use, then reused so the warm norm path
    /// allocates nothing
    gram_a: Vec<f32>,
    gram_d: Vec<f32>,
}

impl BatchScratch {
    pub fn for_spec(spec: &MlpSpec, b: usize) -> BatchScratch {
        let outs: Vec<usize> = spec.layers.iter().map(|&(_, o)| o).collect();
        BatchScratch {
            b,
            zs: outs.iter().map(|&o| vec![0.0; b * o]).collect(),
            acts: outs.iter().map(|&o| vec![0.0; b * o]).collect(),
            deltas: outs.iter().map(|&o| vec![0.0; b * o]).collect(),
            probs: vec![0.0; b * spec.n_classes],
            gram_a: Vec::new(),
            gram_d: Vec::new(),
        }
    }
}

/// Batched forward: X[b x d_in] through every layer (bias rows +
/// GEMM + ReLU), then row-wise stable softmax-CE. Fills
/// `zs`/`acts`/`probs`; returns (f64 loss sum over the batch,
/// correct-prediction count). Labels must be pre-validated.
pub fn forward_batch(
    spec: &MlpSpec,
    params: &[Vec<f32>],
    x: &[f32],
    labels: &[i32],
    s: &mut BatchScratch,
) -> (f64, usize) {
    let b = s.b;
    let n = spec.n_layers();
    for l in 0..n {
        let (din, dout) = spec.layers[l];
        let w = &params[2 * l];
        let bias = &params[2 * l + 1];
        let input: &[f32] = if l == 0 { x } else { &s.acts[l - 1] };
        let z = &mut s.zs[l];
        for r in 0..b {
            z[r * dout..(r + 1) * dout].copy_from_slice(bias);
        }
        gemm::sgemm(b, din, dout, input, w, z);
        if l < n - 1 {
            let a = &mut s.acts[l];
            for (av, &zv) in a.iter_mut().zip(s.zs[l].iter()) {
                *av = zv.max(0.0);
            }
        }
    }
    // row-wise numerically stable softmax-CE (f64 accumulation, same
    // op order as the scalar reference) — shared with the conv family
    super::taps::softmax_xent_rows(
        b,
        spec.n_classes,
        &s.zs[n - 1],
        &mut s.probs,
        labels,
    )
}

/// Batched backward (after `forward_batch`): fills `deltas` for every
/// layer via one `sgemm_nt` per layer plus the ReLU mask.
///
/// `nu`, when given, scales example i's output delta by nu_i — this is
/// the paper's *second*, reweighted backward pass (the loss becomes
/// Σ_i nu_i·l_i), used by `reweight`/`reweight_gram` after the norm
/// pass.
pub fn backward_batch(
    spec: &MlpSpec,
    params: &[Vec<f32>],
    labels: &[i32],
    nu: Option<&[f32]>,
    s: &mut BatchScratch,
) {
    let b = s.b;
    let n = spec.n_layers();
    let nc = spec.n_classes;
    {
        // dCE_i/dz = softmax(z_i) - onehot(y_i), optionally nu_i-scaled
        let d = &mut s.deltas[n - 1];
        d.copy_from_slice(&s.probs);
        for r in 0..b {
            d[r * nc + labels[r] as usize] -= 1.0;
        }
        if let Some(nu) = nu {
            for (r, &w) in nu.iter().enumerate() {
                for v in d[r * nc..(r + 1) * nc].iter_mut() {
                    *v *= w;
                }
            }
        }
    }
    for l in (0..n - 1).rev() {
        let (_, dout) = spec.layers[l];
        let (_, dout_next) = spec.layers[l + 1];
        let w_next = &params[2 * (l + 1)];
        let (head, tail) = s.deltas.split_at_mut(l + 1);
        let d_here = &mut head[l];
        let d_next = &tail[0];
        d_here.iter_mut().for_each(|v| *v = 0.0);
        // Δ_l = (Δ_{l+1} · W_{l+1}ᵀ) ∘ relu'(z_l)
        gemm::sgemm_nt(b, dout_next, dout, d_next, w_next, d_here);
        for (dv, &zv) in d_here.iter_mut().zip(s.zs[l].iter()) {
            if zv <= 0.0 {
                *dv = 0.0;
            }
        }
    }
}

/// Per-example, per-layer squared gradient norm contributions via the
/// tap trick (paper Sec 5): row norms of the taps and deltas only,
/// written into the `out` slab (len = batch × n_layers, example-major:
/// `out[i*L + l]` is layer l's term for example i; no allocation — the
/// arena contract). Summing a slab row recovers the whole-model norm.
pub fn tap_sq_norms(spec: &MlpSpec, x: &[f32], s: &BatchScratch, out: &mut [f64]) {
    let b = s.b;
    let n = spec.n_layers();
    debug_assert_eq!(out.len(), b * n);
    for l in 0..n {
        let (din, dout) = spec.layers[l];
        let input: &[f32] = if l == 0 { x } else { &s.acts[l - 1] };
        let delta = &s.deltas[l];
        for i in 0..b {
            let a2: f64 = input[i * din..(i + 1) * din]
                .iter()
                .map(|&v| (v as f64) * (v as f64))
                .sum();
            let d2: f64 = delta[i * dout..(i + 1) * dout]
                .iter()
                .map(|&v| (v as f64) * (v as f64))
                .sum();
            out[i * n + l] = (a2 + 1.0) * d2;
        }
    }
}

/// Per-example squared gradient norms via the Gram route (paper Sec
/// 5.2): per layer, form A·Aᵀ and Δ·Δᵀ with `sgemm_nt` and read the
/// diagonal of their Hadamard product. For an MLP (no weight sharing
/// across a sequence dimension) the diagonal degenerates to the tap
/// trick's per-row products — the point here is the *computational
/// structure*, which carries over unchanged to the conv/attention taps
/// where the off-diagonal (per-example, cross-position) terms are
/// genuinely needed.
pub fn gram_sq_norms(
    spec: &MlpSpec,
    x: &[f32],
    s: &mut BatchScratch,
    out: &mut [f64],
) {
    let b = s.b;
    let n = spec.n_layers();
    debug_assert_eq!(out.len(), b * n);
    let BatchScratch { acts, deltas, gram_a, gram_d, .. } = s;
    // grow-only: first use allocates, every later step reuses
    if gram_a.len() < b * b {
        gram_a.resize(b * b, 0.0);
        gram_d.resize(b * b, 0.0);
    }
    for l in 0..n {
        let (din, dout) = spec.layers[l];
        let input: &[f32] = if l == 0 { x } else { &acts[l - 1] };
        gram_a.iter_mut().for_each(|v| *v = 0.0);
        gram_d.iter_mut().for_each(|v| *v = 0.0);
        gemm::sgemm_nt(b, din, b, input, input, &mut gram_a[..b * b]);
        let delta = &deltas[l];
        gemm::sgemm_nt(b, dout, b, delta, delta, &mut gram_d[..b * b]);
        for i in 0..b {
            out[i * n + l] =
                (gram_a[i * b + i] as f64 + 1.0) * gram_d[i * b + i] as f64;
        }
    }
}

/// Scale every layer's delta row i by that layer's group clip factor
/// in place — the `reweight_direct` assembly: the tapped deltas from
/// the *first* backward are reused, so no second backward pass runs.
/// Under the global policy every layer sees the same nu slice; under
/// group-wise policies `nu.layer(l)` routes each layer to its group's
/// per-example factors.
pub fn scale_delta_rows(spec: &MlpSpec, nu: &NuBlock<'_>, s: &mut BatchScratch) {
    for l in 0..spec.n_layers() {
        let (_, dout) = spec.layers[l];
        let d = &mut s.deltas[l];
        for (r, &w) in nu.layer(l).iter().enumerate() {
            for v in d[r * dout..(r + 1) * dout].iter_mut() {
                *v *= w;
            }
        }
    }
}

/// Accumulate the batch-summed gradients from the current deltas into
/// the arena: grads[W_l] += tapsᵀ·Δ_l (`sgemm_tn`), grads[b_l] +=
/// column sums of Δ_l. With `scale` (the `reweight_pallas` path) the
/// per-example clip factor is fused into both reductions instead of
/// materializing a weighted delta matrix.
pub fn grads_from_deltas(
    spec: &MlpSpec,
    x: &[f32],
    s: &BatchScratch,
    scale: Option<&NuBlock<'_>>,
    grads: &mut GradVec,
) {
    let b = s.b;
    for l in 0..spec.n_layers() {
        let (din, dout) = spec.layers[l];
        let input: &[f32] = if l == 0 { x } else { &s.acts[l - 1] };
        let delta = &s.deltas[l];
        let scale = scale.map(|nb| nb.layer(l));
        match scale {
            Some(nu) => gemm::sgemm_tn_scaled(
                din,
                b,
                dout,
                input,
                nu,
                delta,
                grads.param_mut(2 * l),
            ),
            None => gemm::sgemm_tn(
                din,
                b,
                dout,
                input,
                delta,
                grads.param_mut(2 * l),
            ),
        }
        gemm::col_sums(b, dout, delta, scale, grads.param_mut(2 * l + 1));
    }
}

/// Materialize example i's full gradient into the arena (overwriting)
/// from the batch scratch rows, returning the squared norm computed
/// from the materialized values — the multiLoss structure,
/// deliberately heavier than the tap trick.
pub fn materialize_grad_row(
    spec: &MlpSpec,
    x: &[f32],
    s: &BatchScratch,
    i: usize,
    out: &mut GradVec,
) -> f64 {
    let mut sq = 0.0f64;
    for l in 0..spec.n_layers() {
        let (din, dout) = spec.layers[l];
        let input: &[f32] = if l == 0 {
            &x[i * din..(i + 1) * din]
        } else {
            &s.acts[l - 1][i * din..(i + 1) * din]
        };
        let delta = &s.deltas[l][i * dout..(i + 1) * dout];
        let gw = out.param_mut(2 * l);
        for (k, &xk) in input.iter().enumerate() {
            let row = &mut gw[k * dout..(k + 1) * dout];
            for (g, &d) in row.iter_mut().zip(delta.iter()) {
                *g = xk * d;
                sq += (*g as f64) * (*g as f64);
            }
        }
        let gb = out.param_mut(2 * l + 1);
        for (g, &d) in gb.iter_mut().zip(delta.iter()) {
            *g = d;
            sq += (*g as f64) * (*g as f64);
        }
    }
    sq
}

// ---------------------------------------------------------------------
// ModelFamily registration (taps::FamilyRegistry "mlp")
// ---------------------------------------------------------------------

impl ModelFamily for MlpSpec {
    fn family(&self) -> &'static str {
        "mlp"
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn d_in(&self) -> usize {
        self.d_in
    }

    fn n_classes(&self) -> usize {
        self.n_classes
    }

    fn grad_layout(&self) -> Vec<usize> {
        self.grad_lens()
    }

    /// One slab slot per linear layer, in layer order.
    fn norm_slots(&self) -> Vec<usize> {
        (0..self.n_layers()).collect()
    }

    fn validate_params(&self, config: &str, host: &[Vec<f32>]) -> Result<()> {
        self.check_params(config, host)
    }

    fn new_scratch(&self) -> Box<ScratchAny> {
        Box::new(BatchScratch::for_spec(self, self.batch))
    }

    fn forward_batch(
        &self,
        params: &[Vec<f32>],
        x: &[f32],
        labels: &[i32],
        s: &mut ScratchAny,
    ) -> (f64, usize) {
        let scr = downcast_scratch::<BatchScratch>(s, "mlp");
        forward_batch(self, params, x, labels, scr)
    }

    fn backward_batch(
        &self,
        params: &[Vec<f32>],
        labels: &[i32],
        nu: Option<&[f32]>,
        s: &mut ScratchAny,
    ) {
        let scr = downcast_scratch::<BatchScratch>(s, "mlp");
        backward_batch(self, params, labels, nu, scr)
    }

    fn sq_norms(&self, x: &[f32], s: &mut ScratchAny, out: &mut [f64]) {
        let scr = downcast_scratch::<BatchScratch>(s, "mlp");
        tap_sq_norms(self, x, scr, out)
    }

    fn gram_sq_norms(&self, x: &[f32], s: &mut ScratchAny, out: &mut [f64]) {
        let scr = downcast_scratch::<BatchScratch>(s, "mlp");
        gram_sq_norms(self, x, scr, out)
    }

    /// On a dense family the row-norm product *is* the exact norm —
    /// one tap row per example — so the bound coincides with
    /// `sq_norms`.
    fn tap_bound_sq_norms(&self, x: &[f32], s: &mut ScratchAny, out: &mut [f64]) {
        let scr = downcast_scratch::<BatchScratch>(s, "mlp");
        tap_sq_norms(self, x, scr, out)
    }

    fn scale_delta_rows(&self, nu: &NuBlock<'_>, s: &mut ScratchAny) {
        let scr = downcast_scratch::<BatchScratch>(s, "mlp");
        scale_delta_rows(self, nu, scr)
    }

    fn grads_from_deltas(
        &self,
        x: &[f32],
        s: &mut ScratchAny,
        scale: Option<&NuBlock<'_>>,
        grads: &mut GradVec,
    ) {
        let scr = downcast_scratch::<BatchScratch>(s, "mlp");
        grads_from_deltas(self, x, scr, scale, grads)
    }

    fn materialize_grad_row(
        &self,
        x: &[f32],
        s: &ScratchAny,
        i: usize,
        out: &mut GradVec,
        _work: &mut Vec<f64>,
    ) -> f64 {
        let scr = downcast_scratch_ref::<BatchScratch>(s, "mlp");
        materialize_grad_row(self, x, scr, i, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ParamSpec;

    fn tiny_cfg() -> ConfigSpec {
        ConfigSpec {
            name: "tiny_b2".into(),
            model: "mlp".into(),
            dataset: "mnist".into(),
            batch: 2,
            n_classes: 3,
            tags: vec![],
            input_shape: vec![2, 4],
            input_dtype: "f32".into(),
            act_elems_per_example: 5,
            conv: None,
            spec: None,
            params: vec![
                ParamSpec { name: "fc0.w".into(), shape: vec![4, 5] },
                ParamSpec { name: "fc0.b".into(), shape: vec![5] },
                ParamSpec { name: "fc1.w".into(), shape: vec![5, 3] },
                ParamSpec { name: "fc1.b".into(), shape: vec![3] },
            ],
            artifacts: Default::default(),
        }
    }

    fn tiny_params(spec: &MlpSpec, seed: u64) -> Vec<Vec<f32>> {
        use crate::rng::ChaCha20;
        let mut rng = ChaCha20::seeded(seed, 42);
        spec.layers
            .iter()
            .flat_map(|&(i, o)| {
                vec![
                    (0..i * o)
                        .map(|_| rng.next_f32() - 0.5)
                        .collect::<Vec<f32>>(),
                    (0..o).map(|_| rng.next_f32() - 0.5).collect(),
                ]
            })
            .collect()
    }

    #[test]
    fn spec_parses_and_validates() {
        let cfg = tiny_cfg();
        let spec = MlpSpec::from_config(&cfg).unwrap();
        assert_eq!(spec.d_in, 4);
        assert_eq!(spec.layers, vec![(4, 5), (5, 3)]);
        assert_eq!(spec.n_classes, 3);

        let mut bad = cfg.clone();
        bad.params[2].shape = vec![6, 3]; // chain mismatch
        assert!(MlpSpec::from_config(&bad).is_err());
        let mut wrong_model = cfg.clone();
        wrong_model.model = "cnn".into();
        assert!(MlpSpec::from_config(&wrong_model).is_err());
    }

    #[test]
    fn softmax_ce_loss_matches_uniform_at_zero_logits() {
        let cfg = tiny_cfg();
        let spec = MlpSpec::from_config(&cfg).unwrap();
        let params = spec.zero_grads(); // all-zero weights: logits are zero
        let mut s = Scratch::for_spec(&spec);
        let (loss, _) = forward(&spec, &params, &[0.3, -0.1, 0.5, 0.9], 1, &mut s);
        assert!((loss - (3.0f32).ln()).abs() < 1e-6, "loss {loss}");
        for &p in &s.probs {
            assert!((p - 1.0 / 3.0).abs() < 1e-6);
        }
    }

    /// Backward gradients match central finite differences of the loss
    /// — the ground-truth check the whole native backend rests on.
    #[test]
    fn gradient_matches_finite_differences() {
        let cfg = tiny_cfg();
        let spec = MlpSpec::from_config(&cfg).unwrap();
        let params = tiny_params(&spec, 9);
        let x = [0.8f32, -0.4, 0.1, 1.2];
        let y = 2i32;

        let mut s = Scratch::for_spec(&spec);
        forward(&spec, &params, &x, y, &mut s);
        let sq = backward(&spec, &params, &x, y, &mut s);
        let mut grads = spec.zero_grads();
        let sq_mat = materialize_grad(&spec, &x, &s, &mut grads);
        assert!(
            (sq - sq_mat).abs() / sq_mat.max(1e-9) < 1e-5,
            "tap norm {sq} vs materialized {sq_mat}"
        );

        let eps = 1e-3f32;
        let mut scratch = Scratch::for_spec(&spec);
        for t in 0..params.len() {
            for idx in [0usize, params[t].len() / 2, params[t].len() - 1] {
                let mut p_hi = params.clone();
                p_hi[t][idx] += eps;
                let (l_hi, _) = forward(&spec, &p_hi, &x, y, &mut scratch);
                let mut p_lo = params.clone();
                p_lo[t][idx] -= eps;
                let (l_lo, _) = forward(&spec, &p_lo, &x, y, &mut scratch);
                let fd = (l_hi - l_lo) / (2.0 * eps);
                let an = grads[t][idx];
                assert!(
                    (fd - an).abs() < 2e-3 * (1.0 + an.abs()),
                    "param {t}[{idx}]: finite-diff {fd} vs analytic {an}"
                );
            }
        }
    }

    /// The batched GEMM core agrees with the scalar reference path on
    /// every intermediate: pre-activations, probs, deltas, tap norms,
    /// and assembled gradients. This anchors everything `NativeStep`
    /// executes to the finite-difference-validated scalar math.
    #[test]
    fn batched_core_matches_scalar_reference() {
        let cfg = tiny_cfg();
        let spec = MlpSpec::from_config(&cfg).unwrap();
        let params = tiny_params(&spec, 21);
        let b = 6usize;
        use crate::rng::ChaCha20;
        let mut rng = ChaCha20::seeded(33, 1);
        let x: Vec<f32> =
            (0..b * spec.d_in).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
        let labels: Vec<i32> =
            (0..b).map(|_| (rng.next_u32() % 3) as i32).collect();

        let n = spec.n_layers();
        let mut bs = BatchScratch::for_spec(&spec, b);
        let (loss_sum, _) = forward_batch(&spec, &params, &x, &labels, &mut bs);
        backward_batch(&spec, &params, &labels, None, &mut bs);
        let mut tap_slab = vec![0.0f64; b * n];
        tap_sq_norms(&spec, &x, &bs, &mut tap_slab);
        let mut gram_slab = vec![0.0f64; b * n];
        gram_sq_norms(&spec, &x, &mut bs, &mut gram_slab);
        // whole-model norms = slab row sums (the global policy's reduce)
        let row_sum = |slab: &[f64], i: usize| -> f64 {
            slab[i * n..(i + 1) * n].iter().sum()
        };
        let tap: Vec<f64> = (0..b).map(|i| row_sum(&tap_slab, i)).collect();
        let gram: Vec<f64> = (0..b).map(|i| row_sum(&gram_slab, i)).collect();
        let mut bgrads = GradVec::with_layout(&spec.grad_lens());
        grads_from_deltas(&spec, &x, &bs, None, &mut bgrads);

        let mut s = Scratch::for_spec(&spec);
        let mut sgrads = spec.zero_grads();
        let mut sloss = 0.0f64;
        for i in 0..b {
            let xi = &x[i * spec.d_in..(i + 1) * spec.d_in];
            let (loss, _) = forward(&spec, &params, xi, labels[i], &mut s);
            sloss += loss as f64;
            let sq = backward(&spec, &params, xi, labels[i], &mut s);
            assert!(
                (sq - tap[i]).abs() / sq.max(1e-9) < 1e-6,
                "tap norm row {i}: batched {} vs scalar {sq}",
                tap[i]
            );
            assert!(
                (gram[i] - sq).abs() / sq.max(1e-9) < 1e-5,
                "gram norm row {i}: {} vs {sq}",
                gram[i]
            );
            // per-layer deltas match
            for l in 0..spec.n_layers() {
                let (_, dout) = spec.layers[l];
                for (j, &dv) in s.deltas[l].iter().enumerate() {
                    let bv = bs.deltas[l][i * dout + j];
                    assert!(
                        (dv - bv).abs() < 1e-6,
                        "delta[{l}][{i},{j}]: {bv} vs {dv}"
                    );
                }
            }
            accumulate_weighted(&spec, xi, &s, 1.0, &mut sgrads);
        }
        assert!((loss_sum - sloss).abs() / sloss.abs().max(1e-9) < 1e-6);
        for (t, sg) in sgrads.iter().enumerate() {
            for (j, (&bv, &sv)) in
                bgrads.param(t).iter().zip(sg.iter()).enumerate()
            {
                assert!(
                    (bv - sv).abs() < 1e-5,
                    "grad[{t}][{j}]: batched {bv} vs scalar {sv}"
                );
            }
        }
        // per-layer slab terms agree between the tap and Gram routes
        for (slot, (&tv, &gv)) in
            tap_slab.iter().zip(gram_slab.iter()).enumerate()
        {
            assert!(
                (tv - gv).abs() / tv.max(1e-9) < 1e-5,
                "slab slot {slot}: tap {tv} vs gram {gv}"
            );
        }
        // the fused scaled GEMM matches scaling the delta rows first —
        // here with a genuinely per-layer nu block (2 groups) so the
        // group routing is exercised, not just the global degenerate
        let nu: Vec<f32> =
            (0..2 * b).map(|i| 0.2 + 0.1 * i as f32).collect();
        let groups: Vec<usize> = (0..n).map(|l| (l >= 1) as usize).collect();
        let block = NuBlock { nu: &nu, groups: &groups, b };
        let mut fused = GradVec::with_layout(&spec.grad_lens());
        grads_from_deltas(&spec, &x, &bs, Some(&block), &mut fused);
        scale_delta_rows(&spec, &block, &mut bs);
        let mut scaled = GradVec::with_layout(&spec.grad_lens());
        grads_from_deltas(&spec, &x, &bs, None, &mut scaled);
        for (&fv, &sv) in fused.flat().iter().zip(scaled.flat()) {
            assert!((fv - sv).abs() < 1e-5, "fused {fv} vs scaled {sv}");
        }
    }

    #[test]
    fn materialize_row_matches_scalar_materialize() {
        let cfg = tiny_cfg();
        let spec = MlpSpec::from_config(&cfg).unwrap();
        let params = tiny_params(&spec, 8);
        let b = 3usize;
        use crate::rng::ChaCha20;
        let mut rng = ChaCha20::seeded(9, 2);
        let x: Vec<f32> =
            (0..b * spec.d_in).map(|_| rng.next_f32() - 0.5).collect();
        let labels: Vec<i32> = vec![0, 2, 1];
        let mut bs = BatchScratch::for_spec(&spec, b);
        forward_batch(&spec, &params, &x, &labels, &mut bs);
        backward_batch(&spec, &params, &labels, None, &mut bs);
        let mut s = Scratch::for_spec(&spec);
        for i in 0..b {
            let xi = &x[i * spec.d_in..(i + 1) * spec.d_in];
            forward(&spec, &params, xi, labels[i], &mut s);
            backward(&spec, &params, xi, labels[i], &mut s);
            let mut want = spec.zero_grads();
            let sq_s = materialize_grad(&spec, xi, &s, &mut want);
            let mut got = GradVec::with_layout(&spec.grad_lens());
            let sq_b = materialize_grad_row(&spec, &x, &bs, i, &mut got);
            assert!((sq_s - sq_b).abs() / sq_s.max(1e-9) < 1e-6);
            for (t, w) in want.iter().enumerate() {
                for (&wv, &gv) in w.iter().zip(got.param(t).iter()) {
                    assert!((wv - gv).abs() < 1e-6, "{wv} vs {gv}");
                }
            }
        }
    }

    #[test]
    fn weighted_accumulate_scales_materialized_grad() {
        let cfg = tiny_cfg();
        let spec = MlpSpec::from_config(&cfg).unwrap();
        let params = tiny_params(&spec, 4);
        let x = [0.2f32, 0.7, -0.3, 0.5];
        let mut s = Scratch::for_spec(&spec);
        forward(&spec, &params, &x, 0, &mut s);
        backward(&spec, &params, &x, 0, &mut s);

        let mut mat = spec.zero_grads();
        materialize_grad(&spec, &x, &s, &mut mat);
        let mut acc = spec.zero_grads();
        accumulate_weighted(&spec, &x, &s, 0.25, &mut acc);
        for (a, m) in acc.iter().zip(&mat) {
            for (&av, &mv) in a.iter().zip(m) {
                assert!((av - 0.25 * mv).abs() < 1e-6);
            }
        }
    }
}
