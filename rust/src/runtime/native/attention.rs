//! Single-block transformer encoder for the native backend — the
//! `"transformer"` entry of the `taps::FamilyRegistry`, closing the
//! paper's generality claim for attention + residual blocks.
//!
//! Architecture (token ids in, class logits out):
//!
//!   x0 = embed(tokens) + b_e                    (T x d per example)
//!   q/k/v = x0·W_{q,k,v} + b                    (per-head split of d)
//!   att_h = softmax(q_h·k_hᵀ / sqrt(d_h))       (T x T per head)
//!   ctx   = concat_h(att_h·v_h)
//!   x1    = x0 + ctx·W_o + b_o                  (residual 1)
//!   f1    = relu(x1·W_1 + b_1)
//!   x2    = x1 + f1·W_2 + b_2                   (residual 2)
//!   pool  = mean_T(x2)
//!   logits = pool·W_h + b_h, softmax-CE loss
//!
//! Simplifications vs a production block (documented, deliberate): no
//! LayerNorm and no positional embedding — neither carries per-example
//! clipped parameters that would change the tap structure, while the
//! residual paths (which *do* change where the taps sit) are retained.
//!
//! The tap structure: every parametric layer is a linear map applied
//! independently to the T sequence positions of each example, i.e. the
//! exact weight-sharing pattern of the conv family with positions in
//! place of patches. Parametric layer l of example i has tap matrix
//! A_{l,i} (T x d_in) and delta matrix Δ_{l,i} (T x d_out), and
//!
//!   g_{l,i} = A_{l,i}ᵀ · Δ_{l,i}
//!
//! so the three norm routes carry over from `conv.rs` unchanged:
//! direct per-example product (`sq_norms`), position-Gram Hadamard
//! reduction (`gram_sq_norms`, paper Sec 5.2 — the off-diagonal
//! cross-position terms are load-bearing because positions share the
//! weights), and the Cauchy–Schwarz row-norm-product bound
//! (`tap_bound_sq_norms`, diagnostics only). The embedding is the same
//! thing with a one-hot tap matrix: its gradient scatters delta rows
//! into token rows, so ‖g‖² reduces to a token-equality masked Gram
//! (`Σ_{t1,t2: tok_t1 = tok_t2} ⟨δ_t1, δ_t2⟩`).
//!
//! Parametric layers, in slab/arena order (one (W, b) pair each):
//!
//!   0 embed   tap: one-hot tokens    delta: dx0
//!   1 q-proj  tap: x0                delta: dq
//!   2 k-proj  tap: x0                delta: dk
//!   3 v-proj  tap: x0                delta: dv
//!   4 o-proj  tap: ctx               delta: dx1   (residual: dx1 also
//!                                                  feeds dx0)
//!   5 ff1     tap: x1                delta: dz1
//!   6 ff2     tap: f1                delta: dx2
//!   7 head    tap: pool (1 row/ex)   delta: dz
//!
//! Every delta buffer belongs to exactly one layer, so
//! `scale_delta_rows` (the `reweight_direct` assembly) can scale them
//! independently per ClipPolicy group. The whole backward chain is
//! linear in the softmax-CE output delta, so the nu-reweighted second
//! backward of `reweight`/`reweight_gram` is exact here too.
//!
//! Determinism follows the gemm module's contract: parallelism only
//! over disjoint per-example output chunks (`par_chunks_mut` zips),
//! f64 scalar reductions in fixed ascending order, f32 accumulation
//! only as axpy into slices.

use super::gemm;
use super::taps::{
    downcast_scratch, downcast_scratch_ref, ModelFamily, NuBlock, ScratchAny,
};
use crate::runtime::manifest::ConfigSpec;
use crate::runtime::spec::ModelSpec;
use crate::runtime::store::GradVec;
use anyhow::{bail, ensure, Result};
use rayon::prelude::*;

/// Transformer-block dimensions parsed and validated from a manifest
/// config. `heads` comes from the config's spec provenance (the
/// `transformer(...)` DSL arm) — it is not recoverable from the param
/// shapes alone.
#[derive(Debug, Clone)]
pub struct AttnSpec {
    pub batch: usize,
    /// sequence length T (= flat input elements per example)
    pub seq: usize,
    pub d_model: usize,
    pub heads: usize,
    /// feed-forward hidden width
    pub ff: usize,
    pub vocab: usize,
    pub n_classes: usize,
}

/// Number of parametric layers (embed, q, k, v, o, ff1, ff2, head).
const N_LAYERS: usize = 8;

impl AttnSpec {
    pub fn from_config(cfg: &ConfigSpec) -> Result<AttnSpec> {
        ensure!(
            cfg.model == "transformer",
            "native attention supports the `transformer` config family; \
             config {} has model {:?}",
            cfg.name,
            cfg.model
        );
        ensure!(
            cfg.input_dtype == "f32",
            "native transformer expects f32-staged token ids, config {} \
             has {:?}",
            cfg.name,
            cfg.input_dtype
        );
        ensure!(
            cfg.input_shape.len() == 2 && cfg.input_shape[0] == cfg.batch,
            "config {}: transformer input shape {:?} must be [batch, seq] \
             leading with batch {}",
            cfg.name,
            cfg.input_shape,
            cfg.batch
        );
        let seq = cfg.input_shape[1];
        let (heads, d_model, ff) = match &cfg.spec {
            Some(ModelSpec::Transformer { heads, d_model, seq: sseq, ff }) => {
                ensure!(
                    *sseq == seq,
                    "config {}: spec seq {} != input shape seq {seq}",
                    cfg.name,
                    sseq
                );
                (*heads, *d_model, *ff)
            }
            _ => bail!(
                "config {}: transformer family needs `transformer(...)` \
                 spec provenance for the head count",
                cfg.name
            ),
        };
        ensure!(
            heads >= 1 && d_model % heads == 0,
            "config {}: d_model {d_model} must be divisible by heads {heads}",
            cfg.name
        );
        ensure!(
            cfg.params.len() == 2 * N_LAYERS,
            "config {}: transformer params must be {} (weight, bias) \
             pairs, got {} tensors",
            cfg.name,
            N_LAYERS,
            cfg.params.len()
        );
        // embed pair pins the vocab; every later pair is chain-checked
        let ew = &cfg.params[0];
        let eb = &cfg.params[1];
        ensure!(
            ew.shape.len() == 2 && ew.shape[1] == d_model && eb.shape == [d_model],
            "config {}: embed expects [vocab, {d_model}] + [{d_model}], \
             got {:?} / {:?}",
            cfg.name,
            ew.shape,
            eb.shape
        );
        let vocab = ew.shape[0];
        let proj_dims: [(usize, usize, &str); 6] = [
            (d_model, d_model, "attn.q"),
            (d_model, d_model, "attn.k"),
            (d_model, d_model, "attn.v"),
            (d_model, d_model, "attn.o"),
            (d_model, ff, "ff1"),
            (ff, d_model, "ff2"),
        ];
        for (j, &(din, dout, name)) in proj_dims.iter().enumerate() {
            let w = &cfg.params[2 + 2 * j];
            let b = &cfg.params[3 + 2 * j];
            ensure!(
                w.shape == [din, dout] && b.shape == [dout],
                "config {}: {name} expects [{din}, {dout}] + [{dout}], \
                 got {:?} / {:?}",
                cfg.name,
                w.shape,
                b.shape
            );
        }
        let hw = &cfg.params[14];
        let hb = &cfg.params[15];
        ensure!(
            hw.shape == [d_model, cfg.n_classes] && hb.shape == [cfg.n_classes],
            "config {}: head expects [{d_model}, {}] + [{}], got {:?} / {:?}",
            cfg.name,
            cfg.n_classes,
            cfg.n_classes,
            hw.shape,
            hb.shape
        );
        Ok(AttnSpec {
            batch: cfg.batch,
            seq,
            d_model,
            heads,
            ff,
            vocab,
            n_classes: cfg.n_classes,
        })
    }

    /// Per-head width d_h.
    pub fn dh(&self) -> usize {
        self.d_model / self.heads
    }

    /// Per-parameter element counts in manifest order — the gradient
    /// arena layout.
    pub fn grad_lens(&self) -> Vec<usize> {
        let (d, f, nc) = (self.d_model, self.ff, self.n_classes);
        vec![
            self.vocab * d,
            d, // embed
            d * d,
            d, // q
            d * d,
            d, // k
            d * d,
            d, // v
            d * d,
            d, // o
            d * f,
            f, // ff1
            f * d,
            d, // ff2
            d * nc,
            nc, // head
        ]
    }

    /// Check a param store's tensor count and per-tensor lengths.
    pub fn check_params(&self, config: &str, host: &[Vec<f32>]) -> Result<()> {
        let lens = self.grad_lens();
        ensure!(
            host.len() == lens.len(),
            "{config}: param store has {} tensors, transformer spec needs {}",
            host.len(),
            lens.len()
        );
        for (t, (&want, tensor)) in lens.iter().zip(host.iter()).enumerate() {
            ensure!(
                tensor.len() == want,
                "{config}: tensor {t} has {} elements, spec needs {want}",
                tensor.len()
            );
        }
        Ok(())
    }

    /// Largest per-example (d_in x d_out) weight block — the grow-only
    /// workspace bound shared by the norm and gradient partials.
    fn wmax(&self) -> usize {
        self.d_model * self.d_model.max(self.ff)
    }

    fn bmax(&self) -> usize {
        self.d_model.max(self.ff)
    }
}

/// Whole-batch forward/backward scratch. Fixed-size buffers allocate at
/// construction; the per-example norm/gradient workspaces
/// (`ex_*`) grow on first use and are reused after — the warm step
/// allocates nothing (`tests/no_alloc.rs`).
pub struct AttnScratch {
    b: usize,
    // forward activations (taps)
    /// embedded input x0, b x T x d — tap for q/k/v
    x0: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// softmax attention rows, b x H x T x T
    att: Vec<f32>,
    /// concat-head context, b x T x d — tap for o
    ctx: Vec<f32>,
    /// residual 1, b x T x d — tap for ff1
    x1: Vec<f32>,
    /// ff pre-activation, b x T x F
    z1: Vec<f32>,
    /// relu(z1), b x T x F — tap for ff2
    f1: Vec<f32>,
    /// residual 2, b x T x d
    x2: Vec<f32>,
    /// mean-pooled features, b x d — tap for the head
    pool: Vec<f32>,
    logits: Vec<f32>,
    probs: Vec<f32>,
    // backward deltas (one buffer per parametric layer; see module docs)
    dz: Vec<f32>,
    dpool: Vec<f32>,
    dx2: Vec<f32>,
    dz1: Vec<f32>,
    dx1: Vec<f32>,
    dctx: Vec<f32>,
    dq: Vec<f32>,
    dk: Vec<f32>,
    dv: Vec<f32>,
    dx0: Vec<f32>,
    // per-example attention-backward workspaces, b x T x T
    ex_da: Vec<f32>,
    ex_ds: Vec<f32>,
    // lazily grown per-example norm/grad partials
    ex_w: Vec<f32>,
    ex_work: Vec<f64>,
    ex_b: Vec<f32>,
    ex_ga: Vec<f32>,
    ex_gd: Vec<f32>,
}

impl AttnScratch {
    pub fn for_spec(spec: &AttnSpec, b: usize) -> AttnScratch {
        let (t, d, f) = (spec.seq, spec.d_model, spec.ff);
        let (h, nc) = (spec.heads, spec.n_classes);
        AttnScratch {
            b,
            x0: vec![0.0; b * t * d],
            q: vec![0.0; b * t * d],
            k: vec![0.0; b * t * d],
            v: vec![0.0; b * t * d],
            att: vec![0.0; b * h * t * t],
            ctx: vec![0.0; b * t * d],
            x1: vec![0.0; b * t * d],
            z1: vec![0.0; b * t * f],
            f1: vec![0.0; b * t * f],
            x2: vec![0.0; b * t * d],
            pool: vec![0.0; b * d],
            logits: vec![0.0; b * nc],
            probs: vec![0.0; b * nc],
            dz: vec![0.0; b * nc],
            dpool: vec![0.0; b * d],
            dx2: vec![0.0; b * t * d],
            dz1: vec![0.0; b * t * f],
            dx1: vec![0.0; b * t * d],
            dctx: vec![0.0; b * t * d],
            dq: vec![0.0; b * t * d],
            dk: vec![0.0; b * t * d],
            dv: vec![0.0; b * t * d],
            dx0: vec![0.0; b * t * d],
            ex_da: vec![0.0; b * t * t],
            ex_ds: vec![0.0; b * t * t],
            ex_w: Vec::new(),
            ex_work: Vec::new(),
            ex_b: Vec::new(),
            ex_ga: Vec::new(),
            ex_gd: Vec::new(),
        }
    }
}

/// Bias rows + one GEMM: out[r] = bias + input[r]·W, for `rows`
/// independent rows (sequence positions or pooled examples).
fn linear_rows(
    rows: usize,
    din: usize,
    dout: usize,
    input: &[f32],
    w: &[f32],
    bias: &[f32],
    out: &mut [f32],
) {
    for r in 0..rows {
        out[r * dout..(r + 1) * dout].copy_from_slice(bias);
    }
    gemm::sgemm(rows, din, dout, input, w, out);
}

fn example_rows(v: &[f32], i: usize, per_example: usize) -> &[f32] {
    &v[i * per_example..(i + 1) * per_example]
}

/// The dense-tap term (||a_i||² + 1)·||δ_i||², f64-accumulated — exact
/// for the pooled head layer; the single definition all three norm
/// routes share so they cannot silently desynchronize.
fn fc_tap_sq(input: &[f32], deltas: &[f32], i: usize, din: usize, dout: usize) -> f64 {
    let a = example_rows(input, i, din);
    let d = example_rows(deltas, i, dout);
    let a2: f64 = a.iter().map(|&v| (v as f64) * (v as f64)).sum();
    let d2: f64 = d.iter().map(|&v| (v as f64) * (v as f64)).sum();
    (a2 + 1.0) * d2
}

/// The six position-shared projection layers as (parametric layer,
/// tap, delta, d_in, d_out) rows, given already-downgraded shared
/// views of the scratch buffers. Embed (layer 0) and head (layer 7)
/// have different tap structure and are handled by each caller.
#[allow(clippy::too_many_arguments)]
fn proj_table<'a>(
    spec: &AttnSpec,
    x0: &'a [f32],
    ctx: &'a [f32],
    x1: &'a [f32],
    f1: &'a [f32],
    dq: &'a [f32],
    dk: &'a [f32],
    dv: &'a [f32],
    dx1: &'a [f32],
    dz1: &'a [f32],
    dx2: &'a [f32],
) -> [(usize, &'a [f32], &'a [f32], usize, usize); 6] {
    let (d, f) = (spec.d_model, spec.ff);
    [
        (1, x0, dq, d, d),
        (2, x0, dk, d, d),
        (3, x0, dv, d, d),
        (4, ctx, dx1, d, d),
        (5, x1, dz1, d, f),
        (6, f1, dx2, f, d),
    ]
}

/// Batched forward over the staged token batch (`x` holds token ids
/// widened to f32, b x T). Fills every tap buffer; returns (f64 loss
/// sum, correct-prediction count). Labels must be pre-validated;
/// token ids are asserted against the vocab here.
pub fn forward_batch(
    spec: &AttnSpec,
    params: &[Vec<f32>],
    x: &[f32],
    labels: &[i32],
    s: &mut AttnScratch,
) -> (f64, usize) {
    let b = s.b;
    let (t, d, f) = (spec.seq, spec.d_model, spec.ff);
    let (h, dh) = (spec.heads, spec.dh());
    debug_assert_eq!(x.len(), b * t);

    // 1. embedding lookup + bias, parallel over examples
    {
        let ew = &params[0];
        let eb = &params[1];
        let vocab = spec.vocab;
        s.x0.par_chunks_mut(t * d).enumerate().for_each(|(i, xrow)| {
            for tt in 0..t {
                let tok = x[i * t + tt];
                assert!(
                    tok >= 0.0 && (tok as usize) < vocab,
                    "token id {tok} out of range for vocab {vocab}"
                );
                let tok = tok as usize;
                let dst = &mut xrow[tt * d..(tt + 1) * d];
                dst.copy_from_slice(&ew[tok * d..(tok + 1) * d]);
                for (o, &bv) in dst.iter_mut().zip(eb.iter()) {
                    *o += bv;
                }
            }
        });
    }

    // 2. q/k/v projections: one batched GEMM each over all b*T rows
    linear_rows(b * t, d, d, &s.x0, &params[2], &params[3], &mut s.q);
    linear_rows(b * t, d, d, &s.x0, &params[4], &params[5], &mut s.k);
    linear_rows(b * t, d, d, &s.x0, &params[6], &params[7], &mut s.v);

    // 3. per-head softmax attention, parallel over examples. 1/sqrt(dh)
    // folds into the q factor of each score product (the backward
    // mirrors this by folding it into dS).
    {
        let invs = 1.0f32 / (dh as f32).sqrt();
        let (q, k, v) = (&s.q, &s.k, &s.v);
        s.att
            .par_chunks_mut(h * t * t)
            .zip(s.ctx.par_chunks_mut(t * d))
            .enumerate()
            .for_each(|(i, (abuf, cbuf))| {
                let qi = example_rows(q, i, t * d);
                let ki = example_rows(k, i, t * d);
                let vi = example_rows(v, i, t * d);
                cbuf.iter_mut().for_each(|z| *z = 0.0);
                for hh in 0..h {
                    let off = hh * dh;
                    let ah = &mut abuf[hh * t * t..(hh + 1) * t * t];
                    // scores: S[tt,u] = Σ_j (q[tt,j]·invs)·k[u,j]
                    ah.iter_mut().for_each(|z| *z = 0.0);
                    for tt in 0..t {
                        let qrow = &qi[tt * d + off..tt * d + off + dh];
                        let srow = &mut ah[tt * t..(tt + 1) * t];
                        for (j, &qv0) in qrow.iter().enumerate() {
                            let qv = qv0 * invs;
                            if qv != 0.0 {
                                for (u, sv) in srow.iter_mut().enumerate() {
                                    *sv += qv * ki[u * d + off + j];
                                }
                            }
                        }
                    }
                    // row-wise numerically stable softmax (f64 exp sum,
                    // same op order as taps::softmax_xent_rows)
                    for tt in 0..t {
                        let srow = &mut ah[tt * t..(tt + 1) * t];
                        let mut m = f32::NEG_INFINITY;
                        for &sv in srow.iter() {
                            if sv > m {
                                m = sv;
                            }
                        }
                        let mut sum = 0.0f64;
                        for sv in srow.iter_mut() {
                            let e = ((*sv - m) as f64).exp();
                            *sv = e as f32;
                            sum += e;
                        }
                        let inv = (1.0 / sum) as f32;
                        for sv in srow.iter_mut() {
                            *sv *= inv;
                        }
                    }
                    // ctx head block: C[tt] += Σ_u att[tt,u]·v[u]
                    for tt in 0..t {
                        let arow = &ah[tt * t..(tt + 1) * t];
                        let crow = &mut cbuf[tt * d + off..tt * d + off + dh];
                        for (u, &av) in arow.iter().enumerate() {
                            if av != 0.0 {
                                let vrow = &vi[u * d + off..u * d + off + dh];
                                for (cv, &vv) in crow.iter_mut().zip(vrow) {
                                    *cv += av * vv;
                                }
                            }
                        }
                    }
                }
            });
    }

    // 4. output projection + residual 1
    linear_rows(b * t, d, d, &s.ctx, &params[8], &params[9], &mut s.x1);
    for (o, &r) in s.x1.iter_mut().zip(s.x0.iter()) {
        *o += r;
    }

    // 5. feed-forward + residual 2
    linear_rows(b * t, d, f, &s.x1, &params[10], &params[11], &mut s.z1);
    for (a, &z) in s.f1.iter_mut().zip(s.z1.iter()) {
        *a = z.max(0.0);
    }
    linear_rows(b * t, f, d, &s.f1, &params[12], &params[13], &mut s.x2);
    for (o, &r) in s.x2.iter_mut().zip(s.x1.iter()) {
        *o += r;
    }

    // 6. mean-pool over positions (exact f32 1/T for the grid's
    // power-of-two sequence lengths)
    {
        let invt = 1.0f32 / t as f32;
        for i in 0..b {
            let xrow = example_rows(&s.x2, i, t * d);
            let prow = &mut s.pool[i * d..(i + 1) * d];
            prow.iter_mut().for_each(|z| *z = 0.0);
            for tt in 0..t {
                for (pv, &xv) in
                    prow.iter_mut().zip(&xrow[tt * d..(tt + 1) * d])
                {
                    *pv += xv;
                }
            }
            for pv in prow.iter_mut() {
                *pv *= invt;
            }
        }
    }

    // 7. classification head + shared softmax-CE
    linear_rows(b, d, spec.n_classes, &s.pool, &params[14], &params[15], &mut s.logits);
    super::taps::softmax_xent_rows(
        b,
        spec.n_classes,
        &s.logits,
        &mut s.probs,
        labels,
    )
}

/// Batched backward (after `forward_batch`): fills every per-layer
/// delta buffer. `nu`, when given, scales example i's output delta by
/// nu_i — the reweighted second pass; the whole chain below is linear
/// in dz, so this reweights every layer's delta exactly.
pub fn backward_batch(
    spec: &AttnSpec,
    params: &[Vec<f32>],
    labels: &[i32],
    nu: Option<&[f32]>,
    s: &mut AttnScratch,
) {
    let b = s.b;
    let (t, d, f) = (spec.seq, spec.d_model, spec.ff);
    let (h, dh) = (spec.heads, spec.dh());
    let nc = spec.n_classes;

    // head delta: dCE_i/dz = softmax(z_i) - onehot(y_i), nu_i-scaled
    {
        let dz = &mut s.dz;
        dz.copy_from_slice(&s.probs);
        for r in 0..b {
            dz[r * nc + labels[r] as usize] -= 1.0;
        }
        if let Some(nu) = nu {
            for (r, &w) in nu.iter().enumerate() {
                for v in dz[r * nc..(r + 1) * nc].iter_mut() {
                    *v *= w;
                }
            }
        }
    }

    // through the head: dpool = dz · W_hᵀ
    s.dpool.iter_mut().for_each(|z| *z = 0.0);
    gemm::sgemm_nt(b, nc, d, &s.dz, &params[14], &mut s.dpool);

    // through the mean-pool: every position gets dpool/T
    {
        let invt = 1.0f32 / t as f32;
        for i in 0..b {
            let prow = &s.dpool[i * d..(i + 1) * d];
            let xrow = &mut s.dx2[i * t * d..(i + 1) * t * d];
            for tt in 0..t {
                for (o, &pv) in
                    xrow[tt * d..(tt + 1) * d].iter_mut().zip(prow)
                {
                    *o = pv * invt;
                }
            }
        }
    }

    // ff branch: dz1 = (dx2 · W_2ᵀ) ∘ relu'(z1)
    s.dz1.iter_mut().for_each(|z| *z = 0.0);
    gemm::sgemm_nt(b * t, d, f, &s.dx2, &params[12], &mut s.dz1);
    for (dv, &zv) in s.dz1.iter_mut().zip(s.z1.iter()) {
        if zv <= 0.0 {
            *dv = 0.0;
        }
    }

    // residual 2 joins: dx1 = dx2 + dz1 · W_1ᵀ
    s.dx1.copy_from_slice(&s.dx2);
    gemm::sgemm_nt(b * t, f, d, &s.dz1, &params[10], &mut s.dx1);

    // through the o-projection: dctx = dx1 · W_oᵀ
    s.dctx.iter_mut().for_each(|z| *z = 0.0);
    gemm::sgemm_nt(b * t, d, d, &s.dx1, &params[8], &mut s.dctx);

    // attention backward, parallel over examples
    {
        let invs = 1.0f32 / (dh as f32).sqrt();
        let AttnScratch {
            q, k, v, att, dctx, dq, dk, dv, ex_da, ex_ds, ..
        } = s;
        // downgrade the read-only fields to shared refs: the parallel
        // closure must be Sync, and a captured `&mut` is not
        let (q, k, v, att, dctx) = (&*q, &*k, &*v, &*att, &*dctx);
        dq.par_chunks_mut(t * d)
            .zip(dk.par_chunks_mut(t * d))
            .zip(dv.par_chunks_mut(t * d))
            .zip(ex_da.par_chunks_mut(t * t))
            .zip(ex_ds.par_chunks_mut(t * t))
            .enumerate()
            .for_each(|(i, ((((dqi, dki), dvi), dabuf), dsbuf))| {
                let qi = example_rows(q, i, t * d);
                let ki = example_rows(k, i, t * d);
                let vi = example_rows(v, i, t * d);
                let dhi = example_rows(dctx, i, t * d);
                dqi.iter_mut().for_each(|z| *z = 0.0);
                dki.iter_mut().for_each(|z| *z = 0.0);
                dvi.iter_mut().for_each(|z| *z = 0.0);
                for hh in 0..h {
                    let off = hh * dh;
                    let ah =
                        &att[(i * h + hh) * t * t..(i * h + hh + 1) * t * t];
                    // dV[u] += Σ_tt att[tt,u]·dctx[tt]
                    for tt in 0..t {
                        let arow = &ah[tt * t..(tt + 1) * t];
                        let drow = &dhi[tt * d + off..tt * d + off + dh];
                        for (u, &av) in arow.iter().enumerate() {
                            if av != 0.0 {
                                let dvrow =
                                    &mut dvi[u * d + off..u * d + off + dh];
                                for (o, &g) in dvrow.iter_mut().zip(drow) {
                                    *o += av * g;
                                }
                            }
                        }
                    }
                    // dA[tt,u] = Σ_j dctx[tt,j]·v[u,j]
                    dabuf.iter_mut().for_each(|z| *z = 0.0);
                    for tt in 0..t {
                        let drow = &dhi[tt * d + off..tt * d + off + dh];
                        let darow = &mut dabuf[tt * t..(tt + 1) * t];
                        for (j, &c) in drow.iter().enumerate() {
                            if c != 0.0 {
                                for (u, da) in darow.iter_mut().enumerate() {
                                    *da += c * vi[u * d + off + j];
                                }
                            }
                        }
                    }
                    // softmax Jacobian per row:
                    // dS = A ∘ (dA - Σ_u A[u]·dA[u]); the row dot is
                    // f64-accumulated in ascending order
                    for tt in 0..t {
                        let arow = &ah[tt * t..(tt + 1) * t];
                        let darow = &dabuf[tt * t..(tt + 1) * t];
                        let dsrow = &mut dsbuf[tt * t..(tt + 1) * t];
                        let mut rd = 0.0f64;
                        for (&av, &dav) in arow.iter().zip(darow.iter()) {
                            rd += (av as f64) * (dav as f64);
                        }
                        let rd = rd as f32;
                        for ((o, &av), &dav) in
                            dsrow.iter_mut().zip(arow).zip(darow)
                        {
                            *o = av * (dav - rd);
                        }
                    }
                    // dQ[tt] += Σ_u (dS[tt,u]·invs)·k[u];
                    // dK[u]  += Σ_tt (dS[tt,u]·invs)·q[tt]
                    for tt in 0..t {
                        let dsrow = &dsbuf[tt * t..(tt + 1) * t];
                        let qrow = &qi[tt * d + off..tt * d + off + dh];
                        let dqrow = &mut dqi[tt * d + off..tt * d + off + dh];
                        for (u, &g0) in dsrow.iter().enumerate() {
                            let g = g0 * invs;
                            if g != 0.0 {
                                let krow =
                                    &ki[u * d + off..u * d + off + dh];
                                for (o, &kv) in dqrow.iter_mut().zip(krow) {
                                    *o += g * kv;
                                }
                                let dkrow =
                                    &mut dki[u * d + off..u * d + off + dh];
                                for (o, &qv) in dkrow.iter_mut().zip(qrow) {
                                    *o += g * qv;
                                }
                            }
                        }
                    }
                }
            });
    }

    // residual 1 joins the three projection paths:
    // dx0 = dx1 + dq·W_qᵀ + dk·W_kᵀ + dv·W_vᵀ
    s.dx0.copy_from_slice(&s.dx1);
    gemm::sgemm_nt(b * t, d, d, &s.dq, &params[2], &mut s.dx0);
    gemm::sgemm_nt(b * t, d, d, &s.dk, &params[4], &mut s.dx0);
    gemm::sgemm_nt(b * t, d, d, &s.dv, &params[6], &mut s.dx0);
}

/// Slab slot base of parametric layer `pl` under
/// `norm_slots() = [0,0,1,1,...,6,6,7]`: two slots (weight, bias) per
/// position-shared layer, one for the pooled head.
fn slot_base(pl: usize) -> usize {
    2 * pl
}

/// Number of slab slots per example.
const N_SLOTS: usize = 15;

/// Exact per-example squared gradient norms — the direct route: per
/// position-shared layer, materialize the small d_in x d_out product
/// A_iᵀ·Δ_i per example (f64-reduced, the same kernel the gradient
/// assembly and multiloss materialization use) and take its Frobenius
/// norm, plus the bias column-sum term; the embedding reduces over
/// token-equality pairs; the head uses the dense tap trick. Parallel
/// over examples into disjoint slab rows and workspace chunks.
pub fn sq_norms(spec: &AttnSpec, x: &[f32], s: &mut AttnScratch, out: &mut [f64]) {
    let b = s.b;
    let t = spec.seq;
    let d = spec.d_model;
    debug_assert_eq!(out.len(), b * N_SLOTS);
    let (max_w, max_b) = (spec.wmax(), spec.bmax());
    let AttnScratch {
        x0, ctx, x1, f1, pool, dq, dk, dv, dx0, dx1, dx2, dz1, dz,
        ex_w, ex_work, ex_b, ..
    } = s;
    if ex_w.len() < b * max_w {
        ex_w.resize(b * max_w, 0.0);
        ex_work.resize(b * max_w, 0.0);
    }
    if ex_b.len() < b * max_b {
        ex_b.resize(b * max_b, 0.0);
    }
    // downgrade the read-only fields to shared refs for the Sync closure
    let (x0, ctx, x1, f1, pool) = (&*x0, &*ctx, &*x1, &*f1, &*pool);
    let (dq, dk, dv, dx0, dx1, dx2, dz1, dz) =
        (&*dq, &*dk, &*dv, &*dx0, &*dx1, &*dx2, &*dz1, &*dz);
    let projs = proj_table(spec, x0, ctx, x1, f1, dq, dk, dv, dx1, dz1, dx2);
    out.par_chunks_mut(N_SLOTS)
        .zip(ex_w.par_chunks_mut(max_w))
        .zip(ex_work.par_chunks_mut(max_w))
        .zip(ex_b.par_chunks_mut(max_b))
        .enumerate()
        .for_each(|(i, (((row, wbuf), workbuf), bbuf))| {
            // embed weight: ‖G‖² = Σ_{t1,t2: tok_t1 = tok_t2} ⟨δ_t1, δ_t2⟩
            let toks = &x[i * t..(i + 1) * t];
            let dxi = example_rows(dx0, i, t * d);
            let mut w_term = 0.0f64;
            for t1 in 0..t {
                for t2 in 0..t {
                    if toks[t1] == toks[t2] {
                        let r1 = &dxi[t1 * d..(t1 + 1) * d];
                        let r2 = &dxi[t2 * d..(t2 + 1) * d];
                        for (&a, &c) in r1.iter().zip(r2.iter()) {
                            w_term += (a as f64) * (c as f64);
                        }
                    }
                }
            }
            row[slot_base(0)] = w_term;
            // embed bias: column sums of dx0_i
            let bias = &mut bbuf[..d];
            bias.iter_mut().for_each(|z| *z = 0.0);
            gemm::col_sums(t, d, dxi, None, bias);
            row[slot_base(0) + 1] = bias
                .iter()
                .map(|&v| (v as f64) * (v as f64))
                .sum::<f64>();
            // position-shared projections
            for &(pl, tap, delta, din, dout) in projs.iter() {
                let tapi = example_rows(tap, i, t * din);
                let di = example_rows(delta, i, t * dout);
                let mbuf = &mut wbuf[..din * dout];
                mbuf.iter_mut().for_each(|z| *z = 0.0);
                gemm::sgemm_tn_f64acc(
                    din,
                    t,
                    dout,
                    tapi,
                    None,
                    di,
                    mbuf,
                    &mut workbuf[..din * dout],
                );
                row[slot_base(pl)] = mbuf
                    .iter()
                    .map(|&v| (v as f64) * (v as f64))
                    .sum::<f64>();
                let bias = &mut bbuf[..dout];
                bias.iter_mut().for_each(|z| *z = 0.0);
                gemm::col_sums(t, dout, di, None, bias);
                row[slot_base(pl) + 1] = bias
                    .iter()
                    .map(|&v| (v as f64) * (v as f64))
                    .sum::<f64>();
            }
            // pooled head: dense tap trick (exact)
            row[slot_base(7)] = fc_tap_sq(pool, dz, i, d, spec.n_classes);
        });
}

/// Exact per-example squared gradient norms — the position-Gram route
/// (paper Sec 5.2): per projection layer, form the T x T position
/// Grams A_i·A_iᵀ and Δ_i·Δ_iᵀ and sum their Hadamard product; the
/// all-ones bias tap contributes Σ_pq (Δ_i·Δ_iᵀ)_pq; the embedding's
/// one-hot tap Gram *is* the token-equality mask, so its weight term
/// is the masked sum over the delta Gram. The off-diagonal terms are
/// exactly what position weight-sharing adds over the MLP diagonal.
pub fn gram_sq_norms(
    spec: &AttnSpec,
    x: &[f32],
    s: &mut AttnScratch,
    out: &mut [f64],
) {
    let b = s.b;
    let t = spec.seq;
    let d = spec.d_model;
    debug_assert_eq!(out.len(), b * N_SLOTS);
    let AttnScratch {
        x0, ctx, x1, f1, pool, dq, dk, dv, dx0, dx1, dx2, dz1, dz,
        ex_ga, ex_gd, ..
    } = s;
    if ex_ga.len() < b * t * t {
        ex_ga.resize(b * t * t, 0.0);
        ex_gd.resize(b * t * t, 0.0);
    }
    let (x0, ctx, x1, f1, pool) = (&*x0, &*ctx, &*x1, &*f1, &*pool);
    let (dq, dk, dv, dx0, dx1, dx2, dz1, dz) =
        (&*dq, &*dk, &*dv, &*dx0, &*dx1, &*dx2, &*dz1, &*dz);
    let projs = proj_table(spec, x0, ctx, x1, f1, dq, dk, dv, dx1, dz1, dx2);
    out.par_chunks_mut(N_SLOTS)
        .zip(ex_ga.par_chunks_mut(t * t))
        .zip(ex_gd.par_chunks_mut(t * t))
        .enumerate()
        .for_each(|(i, ((row, gabuf), gdbuf))| {
            // embed: delta position-Gram masked by token equality
            // (the one-hot tap Gram), bias as the all-ones tap sum
            let toks = &x[i * t..(i + 1) * t];
            let dxi = example_rows(dx0, i, t * d);
            let gd = &mut gdbuf[..t * t];
            gd.iter_mut().for_each(|z| *z = 0.0);
            gemm::sgemm_nt(t, d, t, dxi, dxi, gd);
            let mut w_term = 0.0f64;
            let mut b_term = 0.0f64;
            for t1 in 0..t {
                for t2 in 0..t {
                    let gv = gd[t1 * t + t2] as f64;
                    if toks[t1] == toks[t2] {
                        w_term += gv;
                    }
                    b_term += gv;
                }
            }
            // joint addend in the first slot, +0.0 pad in the second
            // (the slab contract)
            row[slot_base(0)] = w_term + b_term;
            row[slot_base(0) + 1] = 0.0;
            // position-shared projections
            for &(pl, tap, delta, din, dout) in projs.iter() {
                let tapi = example_rows(tap, i, t * din);
                let di = example_rows(delta, i, t * dout);
                let ga = &mut gabuf[..t * t];
                ga.iter_mut().for_each(|z| *z = 0.0);
                let gd = &mut gdbuf[..t * t];
                gd.iter_mut().for_each(|z| *z = 0.0);
                gemm::sgemm_nt(t, din, t, tapi, tapi, ga);
                gemm::sgemm_nt(t, dout, t, di, di, gd);
                let mut w_term = 0.0f64;
                let mut b_term = 0.0f64;
                for (&gav, &gdv) in ga.iter().zip(gd.iter()) {
                    w_term += (gav as f64) * (gdv as f64);
                    b_term += gdv as f64;
                }
                row[slot_base(pl)] = w_term + b_term;
                row[slot_base(pl) + 1] = 0.0;
            }
            row[slot_base(7)] = fc_tap_sq(pool, dz, i, d, spec.n_classes);
        });
}

/// The row-norm-product upper bound: per projection layer,
/// (‖A_i‖²_F + T)·‖Δ_i‖²_F (the +T augments the bias's all-ones tap
/// column); the embedding's one-hot tap has ‖A‖²_F = T. Exact on the
/// pooled head, a strict overestimate wherever an example's position
/// taps are not mutually orthogonal — see the module docs. Never used
/// to clip.
pub fn tap_bound_sq_norms(
    spec: &AttnSpec,
    _x: &[f32],
    s: &AttnScratch,
    out: &mut [f64],
) {
    let b = s.b;
    let t = spec.seq;
    let d = spec.d_model;
    debug_assert_eq!(out.len(), b * N_SLOTS);
    let projs = proj_table(
        spec, &s.x0, &s.ctx, &s.x1, &s.f1, &s.dq, &s.dk, &s.dv, &s.dx1,
        &s.dz1, &s.dx2,
    );
    for i in 0..b {
        let row = &mut out[i * N_SLOTS..(i + 1) * N_SLOTS];
        // embed: one-hot tap rows have unit norm, so ‖A‖²_F = T; +T
        // for the bias's all-ones column
        let dxi = example_rows(&s.dx0, i, t * d);
        let d2: f64 = dxi.iter().map(|&v| (v as f64) * (v as f64)).sum();
        row[slot_base(0)] = (t as f64 + t as f64) * d2;
        row[slot_base(0) + 1] = 0.0;
        for &(pl, tap, delta, din, dout) in projs.iter() {
            let tapi = example_rows(tap, i, t * din);
            let di = example_rows(delta, i, t * dout);
            let a2: f64 = tapi.iter().map(|&v| (v as f64) * (v as f64)).sum();
            let d2: f64 = di.iter().map(|&v| (v as f64) * (v as f64)).sum();
            row[slot_base(pl)] = (a2 + t as f64) * d2;
            row[slot_base(pl) + 1] = 0.0;
        }
        row[slot_base(7)] = fc_tap_sq(&s.pool, &s.dz, i, d, spec.n_classes);
    }
}

/// Scale every layer's delta rows by that layer's group clip factor in
/// place — the `reweight_direct` assembly. Each parametric layer owns
/// its delta buffer (see the module docs' table), so group-wise
/// policies scale them independently.
pub fn scale_delta_rows(spec: &AttnSpec, nu: &NuBlock<'_>, s: &mut AttnScratch) {
    let (t, d, f) = (spec.seq, spec.d_model, spec.ff);
    let nc = spec.n_classes;
    let targets: [(usize, &mut Vec<f32>, usize); 8] = [
        (0, &mut s.dx0, t * d),
        (1, &mut s.dq, t * d),
        (2, &mut s.dk, t * d),
        (3, &mut s.dv, t * d),
        (4, &mut s.dx1, t * d),
        (5, &mut s.dz1, t * f),
        (6, &mut s.dx2, t * d),
        (7, &mut s.dz, nc),
    ];
    for (pl, buf, per_example) in targets {
        for (i, &wv) in nu.layer(pl).iter().enumerate() {
            for v in buf[i * per_example..(i + 1) * per_example].iter_mut() {
                *v *= wv;
            }
        }
    }
}

/// Accumulate the batch-summed gradients from the current deltas into
/// the arena. With `scale` (the `reweight_pallas` path) the clip
/// factor fuses into the reductions, applied uniformly over the T
/// position rows each example owns.
///
/// Projection layers keep the **per-example association**: example i's
/// contribution is the f64-reduced A_iᵀ·Δ_i (`sgemm_tn_f64acc`), so
/// the assembly matches the multiloss materialization and the nxBP
/// coordinator loop and the cross-method float divergence stays
/// batch-sized. A d_in x d_out output fills only one GEMM tile, so the
/// per-example partials are computed on all cores (disjoint
/// `ex_w`/`ex_b` chunks) and merged in ascending example order. The
/// embedding scatters delta rows into token rows serially (ascending
/// examples, ascending positions); the pooled head is a plain dense
/// reduction over the batch.
pub fn grads_from_deltas(
    spec: &AttnSpec,
    x: &[f32],
    s: &mut AttnScratch,
    scale: Option<&NuBlock<'_>>,
    grads: &mut GradVec,
) {
    let b = s.b;
    let (t, d) = (spec.seq, spec.d_model);
    let nc = spec.n_classes;
    let (max_w, max_b) = (spec.wmax(), spec.bmax());
    let AttnScratch {
        x0, ctx, x1, f1, pool, dq, dk, dv, dx0, dx1, dx2, dz1, dz,
        ex_w, ex_work, ex_b, ..
    } = s;
    if ex_w.len() < b * max_w {
        ex_w.resize(b * max_w, 0.0);
        ex_work.resize(b * max_w, 0.0);
    }
    if ex_b.len() < b * max_b {
        ex_b.resize(b * max_b, 0.0);
    }
    let (x0, ctx, x1, f1, pool) = (&*x0, &*ctx, &*x1, &*f1, &*pool);
    let (dq, dk, dv, dx0, dx1, dx2, dz1, dz) =
        (&*dq, &*dk, &*dv, &*dx0, &*dx1, &*dx2, &*dz1, &*dz);

    // embed: scatter delta rows into token rows, ascending examples
    // then positions (serial — deterministic and tiny: b·T axpys)
    {
        let scale_l = scale.map(|nb| nb.layer(0));
        let gw = grads.param_mut(0);
        for i in 0..b {
            let dxi = example_rows(dx0, i, t * d);
            // 1.0 * v is bitwise v, so the unscaled path shares this loop
            let nu_i = scale_l.map_or(1.0, |nu| nu[i]);
            for tt in 0..t {
                let tok = x[i * t + tt] as usize;
                let grow = &mut gw[tok * d..(tok + 1) * d];
                let drow = &dxi[tt * d..(tt + 1) * d];
                for (g, &dv0) in grow.iter_mut().zip(drow) {
                    *g += nu_i * dv0;
                }
            }
        }
        let gb = grads.param_mut(1);
        for i in 0..b {
            let dxi = example_rows(dx0, i, t * d);
            match scale_l {
                Some(nu) => gemm::col_sums_uniform(t, d, dxi, nu[i], gb),
                None => gemm::col_sums(t, d, dxi, None, gb),
            }
        }
    }

    // position-shared projections: per-example f64 partials on all
    // cores, then ascending-example merge
    let projs = proj_table(spec, x0, ctx, x1, f1, dq, dk, dv, dx1, dz1, dx2);
    for &(pl, tap, delta, din, dout) in projs.iter() {
        let scale_l = scale.map(|nb| nb.layer(pl));
        let wlen = din * dout;
        ex_w.par_chunks_mut(max_w)
            .zip(ex_work.par_chunks_mut(max_w))
            .zip(ex_b.par_chunks_mut(max_b))
            .enumerate()
            .for_each(|(i, ((wbuf, workbuf), bbuf))| {
                let tapi = example_rows(tap, i, t * din);
                let di = example_rows(delta, i, t * dout);
                let wpart = &mut wbuf[..wlen];
                wpart.iter_mut().for_each(|z| *z = 0.0);
                let bpart = &mut bbuf[..dout];
                bpart.iter_mut().for_each(|z| *z = 0.0);
                let work = &mut workbuf[..wlen];
                match scale_l {
                    Some(nu) => {
                        gemm::sgemm_tn_f64acc_uniform(
                            din, t, dout, tapi, nu[i], di, wpart, work,
                        );
                        gemm::col_sums_uniform(t, dout, di, nu[i], bpart);
                    }
                    None => {
                        gemm::sgemm_tn_f64acc(
                            din, t, dout, tapi, None, di, wpart, work,
                        );
                        gemm::col_sums(t, dout, di, None, bpart);
                    }
                }
            });
        let gw = grads.param_mut(2 * pl);
        for i in 0..b {
            let wpart = &ex_w[i * max_w..i * max_w + wlen];
            for (g, &v0) in gw.iter_mut().zip(wpart) {
                *g += v0;
            }
        }
        let gb = grads.param_mut(2 * pl + 1);
        for i in 0..b {
            let bpart = &ex_b[i * max_b..i * max_b + dout];
            for (g, &v0) in gb.iter_mut().zip(bpart) {
                *g += v0;
            }
        }
    }

    // pooled head: one dense reduction over the batch (MLP idiom)
    {
        let scale_l = scale.map(|nb| nb.layer(7));
        match scale_l {
            Some(nu) => gemm::sgemm_tn_scaled(
                d,
                b,
                nc,
                pool,
                nu,
                dz,
                grads.param_mut(14),
            ),
            None => gemm::sgemm_tn(d, b, nc, pool, dz, grads.param_mut(14)),
        }
        gemm::col_sums(b, nc, dz, scale_l, grads.param_mut(15));
    }
}

/// Materialize example i's full gradient into the arena (overwriting),
/// returning its squared norm from the materialized values — the
/// multiLoss structure. The projection blocks run the same per-example
/// A_iᵀ·Δ_i f64 reduction as `sq_norms`, so the reported norms agree
/// bitwise with the direct route on those layers. `work` is the
/// caller's grow-only f64 workspace (multiloss chunks own one each,
/// so this is safe to run concurrently over distinct examples).
pub fn materialize_grad_row(
    spec: &AttnSpec,
    x: &[f32],
    s: &AttnScratch,
    i: usize,
    out: &mut GradVec,
    work: &mut Vec<f64>,
) -> f64 {
    let (t, d) = (spec.seq, spec.d_model);
    let nc = spec.n_classes;
    let max_w = spec.wmax();
    if work.len() < max_w {
        work.resize(max_w, 0.0);
    }
    let mut sq = 0.0f64;

    // embed: zero the full block, scatter this example's delta rows
    {
        let dxi = example_rows(&s.dx0, i, t * d);
        let gw = out.param_mut(0);
        gw.iter_mut().for_each(|z| *z = 0.0);
        for tt in 0..t {
            let tok = x[i * t + tt] as usize;
            let grow = &mut gw[tok * d..(tok + 1) * d];
            let drow = &dxi[tt * d..(tt + 1) * d];
            for (g, &dv0) in grow.iter_mut().zip(drow) {
                *g += dv0;
            }
        }
        sq += gw.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>();
        let gb = out.param_mut(1);
        gb.iter_mut().for_each(|z| *z = 0.0);
        gemm::col_sums(t, d, dxi, None, gb);
        sq += gb.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>();
    }

    // position-shared projections
    let projs = proj_table(
        spec, &s.x0, &s.ctx, &s.x1, &s.f1, &s.dq, &s.dk, &s.dv, &s.dx1,
        &s.dz1, &s.dx2,
    );
    for &(pl, tap, delta, din, dout) in projs.iter() {
        let tapi = example_rows(tap, i, t * din);
        let di = example_rows(delta, i, t * dout);
        let gw = out.param_mut(2 * pl);
        gw.iter_mut().for_each(|z| *z = 0.0);
        gemm::sgemm_tn_f64acc(
            din,
            t,
            dout,
            tapi,
            None,
            di,
            gw,
            &mut work[..din * dout],
        );
        sq += gw.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>();
        let gb = out.param_mut(2 * pl + 1);
        gb.iter_mut().for_each(|z| *z = 0.0);
        gemm::col_sums(t, dout, di, None, gb);
        sq += gb.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>();
    }

    // pooled head (dense, MLP idiom)
    {
        let a = example_rows(&s.pool, i, d);
        let dzi = example_rows(&s.dz, i, nc);
        let gw = out.param_mut(14);
        for (kk, &xk) in a.iter().enumerate() {
            let row = &mut gw[kk * nc..(kk + 1) * nc];
            for (g, &dv0) in row.iter_mut().zip(dzi.iter()) {
                *g = xk * dv0;
                sq += (*g as f64) * (*g as f64);
            }
        }
        let gb = out.param_mut(15);
        for (g, &dv0) in gb.iter_mut().zip(dzi.iter()) {
            *g = dv0;
            sq += (*g as f64) * (*g as f64);
        }
    }
    sq
}

// ---------------------------------------------------------------------
// ModelFamily registration (taps::FamilyRegistry "transformer")
// ---------------------------------------------------------------------

impl ModelFamily for AttnSpec {
    fn family(&self) -> &'static str {
        "transformer"
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn d_in(&self) -> usize {
        self.seq
    }

    fn n_classes(&self) -> usize {
        self.n_classes
    }

    fn grad_layout(&self) -> Vec<usize> {
        self.grad_lens()
    }

    /// Two slots per position-shared layer (weight term, then bias
    /// term), one for the pooled head.
    fn norm_slots(&self) -> Vec<usize> {
        vec![0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7]
    }

    fn validate_params(&self, config: &str, host: &[Vec<f32>]) -> Result<()> {
        self.check_params(config, host)
    }

    fn new_scratch(&self) -> Box<ScratchAny> {
        Box::new(AttnScratch::for_spec(self, self.batch))
    }

    fn forward_batch(
        &self,
        params: &[Vec<f32>],
        x: &[f32],
        labels: &[i32],
        s: &mut ScratchAny,
    ) -> (f64, usize) {
        let scr = downcast_scratch::<AttnScratch>(s, "transformer");
        forward_batch(self, params, x, labels, scr)
    }

    fn backward_batch(
        &self,
        params: &[Vec<f32>],
        labels: &[i32],
        nu: Option<&[f32]>,
        s: &mut ScratchAny,
    ) {
        let scr = downcast_scratch::<AttnScratch>(s, "transformer");
        backward_batch(self, params, labels, nu, scr)
    }

    fn sq_norms(&self, x: &[f32], s: &mut ScratchAny, out: &mut [f64]) {
        let scr = downcast_scratch::<AttnScratch>(s, "transformer");
        sq_norms(self, x, scr, out)
    }

    fn gram_sq_norms(&self, x: &[f32], s: &mut ScratchAny, out: &mut [f64]) {
        let scr = downcast_scratch::<AttnScratch>(s, "transformer");
        gram_sq_norms(self, x, scr, out)
    }

    fn tap_bound_sq_norms(&self, x: &[f32], s: &mut ScratchAny, out: &mut [f64]) {
        let scr = downcast_scratch::<AttnScratch>(s, "transformer");
        tap_bound_sq_norms(self, x, scr, out)
    }

    fn scale_delta_rows(&self, nu: &NuBlock<'_>, s: &mut ScratchAny) {
        let scr = downcast_scratch::<AttnScratch>(s, "transformer");
        scale_delta_rows(self, nu, scr)
    }

    fn grads_from_deltas(
        &self,
        x: &[f32],
        s: &mut ScratchAny,
        scale: Option<&NuBlock<'_>>,
        grads: &mut GradVec,
    ) {
        let scr = downcast_scratch::<AttnScratch>(s, "transformer");
        grads_from_deltas(self, x, scr, scale, grads)
    }

    fn materialize_grad_row(
        &self,
        x: &[f32],
        s: &ScratchAny,
        i: usize,
        out: &mut GradVec,
        work: &mut Vec<f64>,
    ) -> f64 {
        let scr = downcast_scratch_ref::<AttnScratch>(s, "transformer");
        materialize_grad_row(self, x, scr, i, out, work)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ParamSpec;
    use crate::runtime::store::clip_factor;
    use crate::rng::ChaCha20;

    fn tiny_cfg() -> ConfigSpec {
        ConfigSpec {
            name: "tiny_tf_b2".into(),
            model: "transformer".into(),
            dataset: "imdb".into(),
            batch: 2,
            n_classes: 3,
            tags: vec![],
            input_shape: vec![2, 4],
            input_dtype: "f32".into(),
            act_elems_per_example: 0,
            conv: None,
            spec: Some(ModelSpec::Transformer {
                heads: 2,
                d_model: 4,
                seq: 4,
                ff: 6,
            }),
            params: vec![
                ParamSpec { name: "embed.w".into(), shape: vec![11, 4] },
                ParamSpec { name: "embed.b".into(), shape: vec![4] },
                ParamSpec { name: "attn.q.w".into(), shape: vec![4, 4] },
                ParamSpec { name: "attn.q.b".into(), shape: vec![4] },
                ParamSpec { name: "attn.k.w".into(), shape: vec![4, 4] },
                ParamSpec { name: "attn.k.b".into(), shape: vec![4] },
                ParamSpec { name: "attn.v.w".into(), shape: vec![4, 4] },
                ParamSpec { name: "attn.v.b".into(), shape: vec![4] },
                ParamSpec { name: "attn.o.w".into(), shape: vec![4, 4] },
                ParamSpec { name: "attn.o.b".into(), shape: vec![4] },
                ParamSpec { name: "ff1.w".into(), shape: vec![4, 6] },
                ParamSpec { name: "ff1.b".into(), shape: vec![6] },
                ParamSpec { name: "ff2.w".into(), shape: vec![6, 4] },
                ParamSpec { name: "ff2.b".into(), shape: vec![4] },
                ParamSpec { name: "head.w".into(), shape: vec![4, 3] },
                ParamSpec { name: "head.b".into(), shape: vec![3] },
            ],
            artifacts: Default::default(),
        }
    }

    fn rand_params(spec: &AttnSpec, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = ChaCha20::seeded(seed, 42);
        spec.grad_lens()
            .iter()
            .map(|&n| (0..n).map(|_| rng.next_f32() - 0.5).collect())
            .collect()
    }

    /// Token batch with duplicated ids inside each example, so the
    /// embedding's token-equality (one-hot Gram) path is exercised.
    fn tiny_tokens() -> Vec<f32> {
        vec![3.0, 5.0, 3.0, 9.0, 1.0, 1.0, 7.0, 2.0]
    }

    fn run_fwd_bwd(
        spec: &AttnSpec,
        params: &[Vec<f32>],
        x: &[f32],
        labels: &[i32],
    ) -> (f64, AttnScratch) {
        let mut s = AttnScratch::for_spec(spec, spec.batch);
        let (loss, _) = forward_batch(spec, params, x, labels, &mut s);
        backward_batch(spec, params, labels, None, &mut s);
        (loss, s)
    }

    #[test]
    fn spec_parses_and_validates() {
        let cfg = tiny_cfg();
        let spec = AttnSpec::from_config(&cfg).unwrap();
        assert_eq!(spec.seq, 4);
        assert_eq!(spec.d_model, 4);
        assert_eq!(spec.heads, 2);
        assert_eq!(spec.dh(), 2);
        assert_eq!(spec.ff, 6);
        assert_eq!(spec.vocab, 11);
        assert_eq!(spec.n_classes, 3);
        assert_eq!(spec.grad_lens().len(), 16);
        assert_eq!(spec.grad_lens()[0], 11 * 4);
        assert_eq!(
            ModelFamily::norm_slots(&spec),
            vec![0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7]
        );

        let mut wrong_model = cfg.clone();
        wrong_model.model = "mlp".into();
        assert!(AttnSpec::from_config(&wrong_model).is_err());

        let mut bad_heads = cfg.clone();
        bad_heads.spec = Some(ModelSpec::Transformer {
            heads: 3,
            d_model: 4,
            seq: 4,
            ff: 6,
        });
        assert!(AttnSpec::from_config(&bad_heads).is_err());

        let mut bad_chain = cfg.clone();
        bad_chain.params[2].shape = vec![5, 4]; // q in-dim != d_model
        assert!(AttnSpec::from_config(&bad_chain).is_err());

        let mut no_spec = cfg.clone();
        no_spec.spec = None;
        let err = AttnSpec::from_config(&no_spec).unwrap_err();
        assert!(format!("{err:#}").contains("spec provenance"));
    }

    /// Assembled batch gradients match central finite differences of
    /// the batch loss sum, for every tensor including the embedding —
    /// the ground-truth check the whole family rests on.
    #[test]
    fn gradient_matches_finite_differences() {
        let cfg = tiny_cfg();
        let spec = AttnSpec::from_config(&cfg).unwrap();
        let params = rand_params(&spec, 9);
        let x = tiny_tokens();
        let labels = vec![2i32, 0];

        let (_, mut s) = run_fwd_bwd(&spec, &params, &x, &labels);
        let mut grads = GradVec::with_layout(&spec.grad_lens());
        grads_from_deltas(&spec, &x, &mut s, None, &mut grads);

        let eps = 1e-3f32;
        let mut fs = AttnScratch::for_spec(&spec, spec.batch);
        for t in 0..params.len() {
            for idx in [0usize, params[t].len() / 2, params[t].len() - 1] {
                let mut p_hi = params.clone();
                p_hi[t][idx] += eps;
                let (l_hi, _) =
                    forward_batch(&spec, &p_hi, &x, &labels, &mut fs);
                let mut p_lo = params.clone();
                p_lo[t][idx] -= eps;
                let (l_lo, _) =
                    forward_batch(&spec, &p_lo, &x, &labels, &mut fs);
                let fd = ((l_hi - l_lo) / (2.0 * eps as f64)) as f32;
                let an = grads.param(t)[idx];
                assert!(
                    (fd - an).abs() < 3e-3 * (1.0 + an.abs()),
                    "param {t}[{idx}]: finite-diff {fd} vs analytic {an}"
                );
            }
        }
    }

    /// The three norm routes agree where they must (direct = gram =
    /// materialized, within float tolerance) and the Cauchy–Schwarz
    /// bound dominates the exact norm with genuine slack on the
    /// position-shared layers.
    #[test]
    fn norm_routes_agree_and_tap_bounds_them() {
        let cfg = tiny_cfg();
        let spec = AttnSpec::from_config(&cfg).unwrap();
        let params = rand_params(&spec, 21);
        let x = tiny_tokens();
        let labels = vec![1i32, 2];
        let b = spec.batch;

        let (_, mut s) = run_fwd_bwd(&spec, &params, &x, &labels);
        let mut direct = vec![0.0f64; b * N_SLOTS];
        sq_norms(&spec, &x, &mut s, &mut direct);
        let mut gram = vec![0.0f64; b * N_SLOTS];
        gram_sq_norms(&spec, &x, &mut s, &mut gram);
        let mut bound = vec![0.0f64; b * N_SLOTS];
        tap_bound_sq_norms(&spec, &x, &s, &mut bound);

        let layer_sum = |slab: &[f64], i: usize, pl: usize| -> f64 {
            if pl < 7 {
                slab[i * N_SLOTS + 2 * pl] + slab[i * N_SLOTS + 2 * pl + 1]
            } else {
                slab[i * N_SLOTS + 14]
            }
        };
        let mut work = Vec::new();
        let mut mat = GradVec::with_layout(&spec.grad_lens());
        for i in 0..b {
            let sq_mat = materialize_grad_row(&spec, &x, &s, i, &mut mat, &mut work);
            let mut d_tot = 0.0f64;
            let mut g_tot = 0.0f64;
            let mut t_tot = 0.0f64;
            let mut exact_proj = 0.0f64;
            let mut bound_proj = 0.0f64;
            for pl in 0..N_LAYERS {
                let dv = layer_sum(&direct, i, pl);
                let gv = layer_sum(&gram, i, pl);
                let tv = layer_sum(&bound, i, pl);
                assert!(
                    (dv - gv).abs() / dv.max(1e-12) < 1e-5,
                    "example {i} layer {pl}: direct {dv} vs gram {gv}"
                );
                assert!(
                    tv >= gv * (1.0 - 1e-9),
                    "example {i} layer {pl}: bound {tv} < exact {gv}"
                );
                if (1..=6).contains(&pl) {
                    exact_proj += gv;
                    bound_proj += tv;
                }
                d_tot += dv;
                g_tot += gv;
                t_tot += tv;
            }
            assert!(
                (d_tot - sq_mat).abs() / sq_mat.max(1e-12) < 1e-5,
                "example {i}: direct total {d_tot} vs materialized {sq_mat}"
            );
            assert!(
                (g_tot - sq_mat).abs() / sq_mat.max(1e-12) < 1e-5,
                "example {i}: gram total {g_tot} vs materialized {sq_mat}"
            );
            // the bound has real slack on the shared-weight layers
            assert!(
                bound_proj > 1.001 * exact_proj,
                "example {i}: projection bound {bound_proj} not above \
                 exact {exact_proj}"
            );
            assert!(t_tot >= g_tot, "example {i}: total bound below exact");
        }
    }

    /// The three weighted-assembly routes agree under a global nu:
    /// reweighted second backward, in-place delta scaling, and the
    /// fused scaled reduction.
    #[test]
    fn weighted_assembly_routes_agree() {
        let cfg = tiny_cfg();
        let spec = AttnSpec::from_config(&cfg).unwrap();
        let params = rand_params(&spec, 4);
        let x = tiny_tokens();
        let labels = vec![0i32, 1];
        let b = spec.batch;
        let nu: Vec<f32> = (0..b).map(|i| 0.3 + 0.2 * i as f32).collect();
        let groups = vec![0usize; N_LAYERS];
        let block = NuBlock { nu: &nu, groups: &groups, b };

        // route A: reweighted second backward
        let (_, mut s) = run_fwd_bwd(&spec, &params, &x, &labels);
        backward_batch(&spec, &params, &labels, Some(&nu), &mut s);
        let mut ga = GradVec::with_layout(&spec.grad_lens());
        grads_from_deltas(&spec, &x, &mut s, None, &mut ga);

        // route B: scale the tapped deltas in place
        let (_, mut s) = run_fwd_bwd(&spec, &params, &x, &labels);
        scale_delta_rows(&spec, &block, &mut s);
        let mut gb = GradVec::with_layout(&spec.grad_lens());
        grads_from_deltas(&spec, &x, &mut s, None, &mut gb);

        // route C: fuse the factors into the reductions
        let (_, mut s) = run_fwd_bwd(&spec, &params, &x, &labels);
        let mut gc = GradVec::with_layout(&spec.grad_lens());
        grads_from_deltas(&spec, &x, &mut s, Some(&block), &mut gc);

        for ((&av, &bv), &cv) in
            ga.flat().iter().zip(gb.flat()).zip(gc.flat())
        {
            assert!((av - bv).abs() < 1e-5, "reweighted {av} vs scaled {bv}");
            assert!((bv - cv).abs() < 1e-5, "scaled {bv} vs fused {cv}");
        }
    }

    /// Group-blocked nu: the fused assembly matches scaling each
    /// example's materialized gradient per group — the ClipPolicy
    /// ground truth.
    #[test]
    fn group_blocks_match_per_group_materialized_scaling() {
        let cfg = tiny_cfg();
        let spec = AttnSpec::from_config(&cfg).unwrap();
        let params = rand_params(&spec, 13);
        let x = tiny_tokens();
        let labels = vec![2i32, 1];
        let b = spec.batch;
        // two groups: attention side (embed..o) vs ff+head
        let groups: Vec<usize> =
            (0..N_LAYERS).map(|l| usize::from(l >= 5)).collect();
        let nu: Vec<f32> =
            (0..2 * b).map(|i| 0.15 + 0.12 * i as f32).collect();
        let block = NuBlock { nu: &nu, groups: &groups, b };

        let (_, mut s) = run_fwd_bwd(&spec, &params, &x, &labels);
        let mut fused = GradVec::with_layout(&spec.grad_lens());
        grads_from_deltas(&spec, &x, &mut s, Some(&block), &mut fused);

        let mut want = GradVec::with_layout(&spec.grad_lens());
        let mut mat = GradVec::with_layout(&spec.grad_lens());
        let mut work = Vec::new();
        for i in 0..b {
            materialize_grad_row(&spec, &x, &s, i, &mut mat, &mut work);
            // group 0 = params 0..10 (layers 0..=4), group 1 = 10..16
            want.add_scaled_params(&mat, 0, 10, nu[i]);
            want.add_scaled_params(&mat, 10, 16, nu[b + i]);
        }
        for (t, (&fv, &wv)) in
            fused.flat().iter().zip(want.flat()).enumerate()
        {
            assert!(
                (fv - wv).abs() < 1e-5,
                "flat[{t}]: fused {fv} vs materialized {wv}"
            );
        }
    }

    /// Clipped-sum equivalence: reweighting by clip factors equals the
    /// sum of per-example materialized clipped gradients, and the
    /// factors genuinely clip.
    #[test]
    fn materialized_clipped_sum_matches_reweighted_assembly() {
        let cfg = tiny_cfg();
        let spec = AttnSpec::from_config(&cfg).unwrap();
        let params = rand_params(&spec, 7);
        let x = tiny_tokens();
        let labels = vec![1i32, 0];
        let b = spec.batch;

        let (_, mut s) = run_fwd_bwd(&spec, &params, &x, &labels);
        let mut slab = vec![0.0f64; b * N_SLOTS];
        sq_norms(&spec, &x, &mut s, &mut slab);
        let norms: Vec<f64> = (0..b)
            .map(|i| {
                slab[i * N_SLOTS..(i + 1) * N_SLOTS]
                    .iter()
                    .sum::<f64>()
                    .sqrt()
            })
            .collect();
        // pick the clip below the largest norm so it provably binds
        let clip = (0.8 * norms.iter().cloned().fold(0.0, f64::max)) as f32;
        let nu: Vec<f32> =
            norms.iter().map(|&n| clip_factor(n as f32, clip)).collect();
        assert!(
            nu.iter().any(|&v| v < 1.0),
            "clip 0.5 should bind for at least one example: {nu:?}"
        );
        let groups = vec![0usize; N_LAYERS];
        let block = NuBlock { nu: &nu, groups: &groups, b };
        let mut fused = GradVec::with_layout(&spec.grad_lens());
        grads_from_deltas(&spec, &x, &mut s, Some(&block), &mut fused);

        let mut want = GradVec::with_layout(&spec.grad_lens());
        let mut mat = GradVec::with_layout(&spec.grad_lens());
        let mut work = Vec::new();
        for i in 0..b {
            let sq = materialize_grad_row(&spec, &x, &s, i, &mut mat, &mut work);
            let f = clip_factor((sq as f32).sqrt(), clip);
            assert!((f - nu[i]).abs() < 1e-6, "factor {f} vs nu {}", nu[i]);
            want.add_scaled(&mat, f);
        }
        for (&fv, &wv) in fused.flat().iter().zip(want.flat()) {
            assert!((fv - wv).abs() < 1e-5, "fused {fv} vs clipped sum {wv}");
        }
    }

    /// Scratch reuse across batches changes no bits: soiling the
    /// scratch with an unrelated batch and re-running the original
    /// reproduces loss, slab, and gradients exactly.
    #[test]
    fn scratch_reuse_is_bitwise_clean() {
        let cfg = tiny_cfg();
        let spec = AttnSpec::from_config(&cfg).unwrap();
        let params = rand_params(&spec, 31);
        let x = tiny_tokens();
        let labels = vec![0i32, 2];
        let b = spec.batch;

        let run = |s: &mut AttnScratch| -> (f64, Vec<f64>, Vec<f32>) {
            let (loss, _) = forward_batch(&spec, &params, &x, &labels, s);
            backward_batch(&spec, &params, &labels, None, s);
            let mut slab = vec![0.0f64; b * N_SLOTS];
            sq_norms(&spec, &x, s, &mut slab);
            let mut g = GradVec::with_layout(&spec.grad_lens());
            grads_from_deltas(&spec, &x, s, None, &mut g);
            (loss, slab, g.flat().to_vec())
        };

        let mut s = AttnScratch::for_spec(&spec, b);
        let (loss_a, slab_a, grads_a) = run(&mut s);
        // soil with a different batch
        let x2 = vec![10.0f32, 0.0, 4.0, 4.0, 6.0, 8.0, 8.0, 0.0];
        let labels2 = vec![1i32, 1];
        let (_, _) = forward_batch(&spec, &params, &x2, &labels2, &mut s);
        backward_batch(&spec, &params, &labels2, None, &mut s);
        let mut slab2 = vec![0.0f64; b * N_SLOTS];
        gram_sq_norms(&spec, &x2, &mut s, &mut slab2);
        // re-run the original: every bit must match the cold run
        let (loss_b, slab_b, grads_b) = run(&mut s);
        assert_eq!(loss_a.to_bits(), loss_b.to_bits(), "loss drifted");
        for (j, (a, c)) in slab_a.iter().zip(slab_b.iter()).enumerate() {
            assert_eq!(a.to_bits(), c.to_bits(), "slab slot {j} drifted");
        }
        for (j, (a, c)) in grads_a.iter().zip(grads_b.iter()).enumerate() {
            assert_eq!(a.to_bits(), c.to_bits(), "grad flat[{j}] drifted");
        }
    }
}
