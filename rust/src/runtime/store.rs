//! Backend-neutral runtime state: host-side batch staging buffers,
//! the parameter store, step outputs, and deterministic parameter
//! initialization. Every `Backend` (native or PJRT) consumes these;
//! nothing here depends on xla.

use super::manifest::ConfigSpec;
use anyhow::{bail, Result};

/// Structured results of one step execution.
#[derive(Debug, Clone)]
pub struct StepOut {
    /// per-parameter gradients (host f32), same order as the manifest
    pub grads: Vec<Vec<f32>>,
    pub loss: f32,
    /// per-example gradient norms (reweight/multiloss) or the single
    /// example's norm (naive1)
    pub norms: Option<Vec<f32>>,
    /// correct-prediction count (fwd artifact only)
    pub correct: Option<f32>,
}

/// The clip factor nu = min(1, clip / norm) of one per-example
/// gradient norm — the single definition every clipping path (batched
/// kernels, the multiloss materialization, the nxbp loop) must share:
/// the DP sensitivity bound is exactly `norm * nu <= clip`.
pub fn clip_factor(norm: f32, clip: f32) -> f32 {
    if norm > clip {
        clip / norm
    } else {
        1.0
    }
}

/// Host-side batch staging buffers, reused across steps to keep
/// allocation out of the hot loop.
pub struct BatchStage {
    pub feat_f32: Vec<f32>,
    pub feat_i32: Vec<i32>,
    pub labels: Vec<i32>,
    pub input_dims: Vec<i64>,
    pub is_f32: bool,
}

impl BatchStage {
    pub fn for_config(cfg: &ConfigSpec) -> BatchStage {
        let elems = cfg.input_elems();
        let is_f32 = cfg.input_dtype == "f32";
        BatchStage {
            feat_f32: if is_f32 { vec![0.0; elems] } else { Vec::new() },
            feat_i32: if is_f32 { Vec::new() } else { vec![0; elems] },
            labels: vec![0; cfg.batch],
            input_dims: cfg.input_shape.iter().map(|&d| d as i64).collect(),
            is_f32,
        }
    }

    /// Number of staged examples (the leading batch dimension).
    pub fn batch(&self) -> usize {
        self.labels.len()
    }
}

/// Parameter store: per-tensor host copies in manifest order. Backends
/// read `host` on each step; `mark_dirty` records optimizer updates so
/// device-resident backends know to re-upload. `(id, version)` is a
/// globally unique key for the current contents — the PJRT engine uses
/// it to cache device literals across the nxBP loop's per-example
/// calls (§Perf L3 iteration 1).
pub struct ParamStore {
    pub host: Vec<Vec<f32>>,
    pub dims: Vec<Vec<i64>>,
    id: u64,
    version: u64,
}

/// Process-unique ParamStore ids, so caches keyed on (id, version)
/// can never confuse two stores.
static NEXT_STORE_ID: std::sync::atomic::AtomicU64 =
    std::sync::atomic::AtomicU64::new(1);

impl ParamStore {
    /// Initialize from the flat f32 concatenation `init` (e.g. from a
    /// checkpoint or `init_params_glorot`).
    pub fn new(cfg: &ConfigSpec, init: Option<&[f32]>) -> Result<ParamStore> {
        let mut host = Vec::with_capacity(cfg.params.len());
        let mut dims = Vec::with_capacity(cfg.params.len());
        let mut off = 0usize;
        for p in &cfg.params {
            let n = p.elems();
            let v = match init {
                Some(flat) => {
                    if flat.len() < off + n {
                        bail!("init vector too short for {}", p.name);
                    }
                    flat[off..off + n].to_vec()
                }
                None => vec![0.0; n],
            };
            off += n;
            host.push(v);
            dims.push(p.shape.iter().map(|&d| d as i64).collect());
        }
        if let Some(flat) = init {
            if flat.len() != off {
                bail!("init vector length {} != param elems {}", flat.len(), off);
            }
        }
        Ok(ParamStore {
            host,
            dims,
            id: NEXT_STORE_ID
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed),
            version: 0,
        })
    }

    /// Record that `host` changed (after an optimizer step).
    pub fn mark_dirty(&mut self) {
        self.version += 1;
    }

    /// Process-unique identity of this store.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Monotone change counter — device backends key upload caches on
    /// `(id, version)`.
    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn total_elems(&self) -> usize {
        self.host.iter().map(|v| v.len()).sum()
    }
}

/// Deterministic parameter initialization on the Rust side (Glorot
/// uniform, mirroring layers.py) so training runs do not depend on
/// Python at runtime.
pub fn init_params_glorot(cfg: &ConfigSpec, seed: u64) -> Vec<f32> {
    use crate::rng::{streams, ChaCha20};
    let mut rng = ChaCha20::seeded(seed, streams::INIT);
    let mut flat = Vec::with_capacity(cfg.param_elems());
    for p in &cfg.params {
        let (fan_in, fan_out) = match p.shape.len() {
            2 => (p.shape[0], p.shape[1]),
            4 => {
                let rf = p.shape[2] * p.shape[3];
                (p.shape[1] * rf, p.shape[0] * rf)
            }
            _ => (p.elems().max(1), 1),
        };
        let is_bias = p.shape.len() == 1;
        let limit = (6.0 / (fan_in + fan_out) as f64).sqrt() as f32;
        for _ in 0..p.elems() {
            if is_bias {
                flat.push(0.0);
            } else {
                flat.push((rng.next_f32() * 2.0 - 1.0) * limit);
            }
        }
    }
    flat
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ParamSpec;

    fn dummy_cfg() -> ConfigSpec {
        ConfigSpec {
            name: "t".into(),
            model: "mlp".into(),
            dataset: "mnist".into(),
            batch: 4,
            n_classes: 10,
            tags: vec![],
            input_shape: vec![4, 3],
            input_dtype: "f32".into(),
            act_elems_per_example: 0,
            conv: None,
            params: vec![
                ParamSpec { name: "w".into(), shape: vec![3, 2] },
                ParamSpec { name: "b".into(), shape: vec![2] },
            ],
            artifacts: Default::default(),
        }
    }

    #[test]
    fn param_store_layout() {
        let cfg = dummy_cfg();
        let init: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let ps = ParamStore::new(&cfg, Some(&init)).unwrap();
        assert_eq!(ps.host.len(), 2);
        assert_eq!(ps.host[0], vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(ps.host[1], vec![6., 7.]);
        assert_eq!(ps.total_elems(), 8);
        // wrong length rejected
        assert!(ParamStore::new(&cfg, Some(&init[..7])).is_err());
    }

    #[test]
    fn dirty_marks_bump_version() {
        let cfg = dummy_cfg();
        let mut ps = ParamStore::new(&cfg, None).unwrap();
        let v0 = ps.version();
        ps.mark_dirty();
        assert_eq!(ps.version(), v0 + 1);
    }

    #[test]
    fn glorot_init_bounds_and_bias_zero() {
        let cfg = dummy_cfg();
        let flat = init_params_glorot(&cfg, 3);
        assert_eq!(flat.len(), 8);
        let limit = (6.0f64 / 5.0).sqrt() as f32;
        assert!(flat[..6].iter().all(|&v| v.abs() <= limit));
        assert!(flat[..6].iter().any(|&v| v != 0.0));
        assert_eq!(&flat[6..], &[0.0, 0.0]);
        // deterministic
        assert_eq!(flat, init_params_glorot(&cfg, 3));
        assert_ne!(flat, init_params_glorot(&cfg, 4));
    }

    #[test]
    fn clip_factor_formula() {
        assert_eq!(clip_factor(2.0, 1.0), 0.5);
        assert_eq!(clip_factor(0.5, 1.0), 1.0);
        assert_eq!(clip_factor(1.0, 1.0), 1.0); // boundary: untouched
    }

    #[test]
    fn stage_shapes() {
        let cfg = dummy_cfg();
        let stage = BatchStage::for_config(&cfg);
        assert!(stage.is_f32);
        assert_eq!(stage.feat_f32.len(), 12);
        assert_eq!(stage.labels.len(), 4);
        assert_eq!(stage.input_dims, vec![4, 3]);
        assert_eq!(stage.batch(), 4);
    }
}
