//! Backend-neutral runtime state: host-side batch staging buffers,
//! the parameter store, the caller-owned step output arena
//! (`StepOut`/`GradVec`), and deterministic parameter initialization.
//! Every `Backend` (native or PJRT) consumes these; nothing here
//! depends on xla.

use super::manifest::ConfigSpec;
use anyhow::{bail, Result};

/// A flat per-parameter gradient buffer: one contiguous `f32`
/// allocation plus per-parameter sub-ranges in manifest order. This is
/// the storage every step writes its gradients into — one buffer, not
/// one `Vec` per tensor — so a reused `StepOut` arena makes the warm
/// step path allocation-free, and whole-gradient operations (noise,
/// scaling, accumulation) are single flat passes.
#[derive(Debug, Clone, PartialEq)]
pub struct GradVec {
    flat: Vec<f32>,
    /// cumulative element offsets; `bounds[0] == 0`, param i spans
    /// `bounds[i]..bounds[i+1]`
    bounds: Vec<usize>,
}

impl Default for GradVec {
    /// Same as `new` — the `bounds[0] == 0` invariant must hold even
    /// for an empty buffer.
    fn default() -> Self {
        GradVec::new()
    }
}

impl GradVec {
    /// An empty buffer (no parameters); `ensure_layout` grows it.
    pub fn new() -> GradVec {
        GradVec { flat: Vec::new(), bounds: vec![0] }
    }

    /// Pre-sized buffer for per-parameter lengths `lens` (zeroed).
    pub fn with_layout(lens: &[usize]) -> GradVec {
        let mut g = GradVec::new();
        g.ensure_layout(lens);
        g
    }

    /// Pre-sized buffer matching a config's parameter tensors.
    pub fn for_config(cfg: &ConfigSpec) -> GradVec {
        let lens: Vec<usize> = cfg.params.iter().map(|p| p.elems()).collect();
        GradVec::with_layout(&lens)
    }

    /// Build from per-tensor vectors (tests, artifact decoding).
    pub fn from_vecs(vecs: &[Vec<f32>]) -> GradVec {
        let lens: Vec<usize> = vecs.iter().map(|v| v.len()).collect();
        let mut g = GradVec::with_layout(&lens);
        for (i, v) in vecs.iter().enumerate() {
            g.param_mut(i).copy_from_slice(v);
        }
        g
    }

    /// Whether the current layout is exactly `lens`.
    pub fn layout_matches(&self, lens: &[usize]) -> bool {
        self.bounds.len() == lens.len() + 1
            && lens
                .iter()
                .enumerate()
                .all(|(i, &l)| self.bounds[i + 1] - self.bounds[i] == l)
    }

    /// Adopt the layout `lens`, reallocating only on a change — the
    /// warm path (same step, same config) never allocates here.
    /// Contents are unspecified afterwards; call `zero` before
    /// accumulating.
    pub fn ensure_layout(&mut self, lens: &[usize]) {
        if self.layout_matches(lens) {
            return;
        }
        self.bounds.clear();
        self.bounds.push(0);
        let mut total = 0usize;
        for &l in lens {
            total += l;
            self.bounds.push(total);
        }
        self.flat.clear();
        self.flat.resize(total, 0.0);
    }

    /// Number of parameter tensors.
    pub fn n_params(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Total elements across all parameters.
    pub fn total_elems(&self) -> usize {
        *self.bounds.last().unwrap()
    }

    /// Parameter i's gradient slice.
    pub fn param(&self, i: usize) -> &[f32] {
        &self.flat[self.bounds[i]..self.bounds[i + 1]]
    }

    /// Parameter i's gradient slice, mutable.
    pub fn param_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.flat[self.bounds[i]..self.bounds[i + 1]]
    }

    /// All gradients as one flat slice (concatenated manifest order).
    pub fn flat(&self) -> &[f32] {
        &self.flat
    }

    /// All gradients as one flat mutable slice.
    pub fn flat_mut(&mut self) -> &mut [f32] {
        &mut self.flat
    }

    /// Iterate the per-parameter views in manifest order.
    pub fn params(&self) -> impl Iterator<Item = &[f32]> {
        (0..self.n_params()).map(move |i| self.param(i))
    }

    /// Zero every element (no reallocation).
    pub fn zero(&mut self) {
        self.flat.iter_mut().for_each(|v| *v = 0.0);
    }

    /// Multiply every element by `s`.
    pub fn scale(&mut self, s: f32) {
        self.flat.iter_mut().for_each(|v| *v *= s);
    }

    /// `self += other` elementwise; layouts must match. A hard assert,
    /// not a debug one: a silent `zip` truncation here would drop part
    /// of an accumulated gradient — in the nxBP loop that is a wrong
    /// DP update with no error, exactly the failure mode this repo
    /// hard-errors on elsewhere.
    pub fn add(&mut self, other: &GradVec) {
        assert_eq!(self.bounds, other.bounds, "GradVec layout mismatch");
        debug_assert_finite(&other.flat, "GradVec::add rhs");
        for (a, &b) in self.flat.iter_mut().zip(&other.flat) {
            *a += b;
        }
    }

    /// `self += s * other` elementwise; layouts must match (hard
    /// assert — see `add`).
    pub fn add_scaled(&mut self, other: &GradVec, s: f32) {
        assert_eq!(self.bounds, other.bounds, "GradVec layout mismatch");
        debug_assert!(s.is_finite(), "GradVec::add_scaled: non-finite scale {s}");
        debug_assert_finite(&other.flat, "GradVec::add_scaled rhs");
        for (a, &b) in self.flat.iter_mut().zip(&other.flat) {
            *a += s * b;
        }
    }

    /// Squared L2 norm over the parameter range `lo..hi` (f64
    /// accumulation, ascending element order). This is the per-group
    /// norm of a materialized gradient — the bounds already delimit
    /// the groups, so a group-wise clip policy is a sum over a bounds
    /// window.
    pub fn sq_norm_params(&self, lo: usize, hi: usize) -> f64 {
        let range = self.bounds[lo]..self.bounds[hi];
        self.flat[range]
            .iter()
            .map(|&v| (v as f64) * (v as f64))
            .sum()
    }

    /// `self[lo..hi] += s * other[lo..hi]` over a parameter range —
    /// the group-wise counterpart of `add_scaled` (hard layout assert,
    /// see `add`).
    pub fn add_scaled_params(&mut self, other: &GradVec, lo: usize, hi: usize, s: f32) {
        assert_eq!(self.bounds, other.bounds, "GradVec layout mismatch");
        debug_assert!(
            s.is_finite(),
            "GradVec::add_scaled_params: non-finite scale {s}"
        );
        let range = self.bounds[lo]..self.bounds[hi];
        debug_assert_finite(&other.flat[range.clone()], "GradVec::add_scaled_params rhs");
        for (a, &b) in self.flat[range.clone()]
            .iter_mut()
            .zip(&other.flat[range])
        {
            *a += s * b;
        }
    }
}

/// Caller-owned, reusable step output arena. A `StepFn::run_into`
/// call writes its results here instead of allocating return values;
/// reusing one arena across steps makes the warm execution path
/// allocation-free (pinned by `tests/no_alloc.rs`).
///
/// Layout (pre-sized by `for_config`, grown on demand otherwise):
///   - `grads`: flat gradient buffer with per-parameter views
///     (`GradVec`), zeroed by the step itself at the start of every
///     `run_into` — callers never need to clear it;
///   - `norms`: per-example gradient norms for the norm-reporting
///     methods (capacity = batch), absent otherwise;
///   - `loss` / `correct`: scalars (`correct` is the
///     correct-prediction *count* of the fwd artifact — a `u32`, not
///     a float).
#[derive(Debug, Clone, Default)]
pub struct StepOut {
    /// per-parameter gradients, flat (host f32), manifest order
    pub grads: GradVec,
    pub loss: f32,
    norms: Vec<f32>,
    has_norms: bool,
    /// per-group per-example norms under a grouped clip policy,
    /// group-major (group g, example i at `g*batch + i`); empty under
    /// a global policy
    group_norms: Vec<f32>,
    /// number of groups in `group_norms` (0 = not produced)
    n_groups: usize,
    /// correct-prediction count (fwd artifact only)
    pub correct: Option<u32>,
}

impl StepOut {
    /// An empty arena; the first `run_into` sizes it (one-shot
    /// callers via `StepFn::run` use this).
    pub fn new() -> StepOut {
        StepOut::default()
    }

    /// Arena pre-sized for `cfg`: gradient layout from the config's
    /// parameter tensors, norms capacity for one batch.
    pub fn for_config(cfg: &ConfigSpec) -> StepOut {
        StepOut {
            grads: GradVec::for_config(cfg),
            loss: 0.0,
            norms: Vec::with_capacity(cfg.batch),
            has_norms: false,
            group_norms: Vec::new(),
            n_groups: 0,
            correct: None,
        }
    }

    /// Begin a step: adopt the gradient layout `lens` (no-op when it
    /// already matches), zero the gradient buffer, clear norms and
    /// scalars. Steps call this first — the arena's previous contents
    /// never leak into a new step's outputs.
    pub fn reset(&mut self, lens: &[usize]) {
        self.grads.ensure_layout(lens);
        self.grads.zero();
        self.loss = 0.0;
        self.norms.clear();
        self.has_norms = false;
        self.group_norms.clear();
        self.n_groups = 0;
        self.correct = None;
    }

    /// The per-example norms, if this step produced them.
    pub fn norms(&self) -> Option<&[f32]> {
        if self.has_norms {
            Some(&self.norms)
        } else {
            None
        }
    }

    /// Mark norms present and return the n-slot buffer to fill
    /// (zero-initialized; reuses capacity on the warm path).
    pub fn norms_fill(&mut self, n: usize) -> &mut [f32] {
        self.norms.clear();
        self.norms.resize(n, 0.0);
        self.has_norms = true;
        &mut self.norms
    }

    /// Copy `src` in as this step's per-example norms.
    pub fn set_norms(&mut self, src: &[f32]) {
        self.norms.clear();
        self.norms.extend_from_slice(src);
        self.has_norms = true;
    }

    /// Per-group per-example norms and the group count, if this step
    /// ran under a grouped clip policy. Group-major: group g's norms
    /// are `view[g*b..(g+1)*b]` with `b = len/n_groups`.
    pub fn group_norms(&self) -> Option<(&[f32], usize)> {
        if self.n_groups > 0 {
            Some((&self.group_norms, self.n_groups))
        } else {
            None
        }
    }

    /// Copy `src` (group-major, `n_groups` blocks) in as this step's
    /// per-group norms. Reuses capacity on the warm path.
    pub fn set_group_norms(&mut self, src: &[f32], n_groups: usize) {
        debug_assert!(n_groups > 0 && src.len() % n_groups == 0);
        self.group_norms.clear();
        self.group_norms.extend_from_slice(src);
        self.n_groups = n_groups;
    }
}

/// The clip factor nu = min(1, clip / norm) of one per-example
/// gradient norm — the single definition every clipping path (batched
/// kernels, the multiloss materialization, the nxbp loop) must share:
/// the DP sensitivity bound is exactly `norm * nu <= clip`.
pub fn clip_factor(norm: f32, clip: f32) -> f32 {
    debug_assert!(
        norm.is_finite() && norm >= 0.0,
        "clip_factor: bad per-example norm {norm}"
    );
    debug_assert!(
        clip.is_finite() && clip > 0.0,
        "clip_factor: bad clip bound {clip}"
    );
    let nu = if norm > clip { clip / norm } else { 1.0 };
    // the DP invariant itself: norm * nu <= clip, i.e. nu in (0, 1]
    debug_assert!(nu > 0.0 && nu <= 1.0, "clip_factor: nu {nu} outside (0, 1]");
    nu
}

/// Debug-profile poisoning guard: assert every element is finite.
/// Compiled out of release builds; in the test profile a NaN/Inf
/// gradient fails *at the source* instead of surfacing steps later as
/// a silently drifted loss.
#[inline]
pub(crate) fn debug_assert_finite(xs: &[f32], what: &str) {
    if !cfg!(debug_assertions) {
        return;
    }
    if let Some((i, v)) = xs.iter().enumerate().find(|(_, v)| !v.is_finite()) {
        panic!("{what}: non-finite value {v} at flat index {i}");
    }
}

/// Host-side batch staging buffers, reused across steps to keep
/// allocation out of the hot loop.
pub struct BatchStage {
    pub feat_f32: Vec<f32>,
    pub feat_i32: Vec<i32>,
    pub labels: Vec<i32>,
    pub input_dims: Vec<i64>,
    pub is_f32: bool,
}

impl BatchStage {
    pub fn for_config(cfg: &ConfigSpec) -> BatchStage {
        let elems = cfg.input_elems();
        let is_f32 = cfg.input_dtype == "f32";
        BatchStage {
            feat_f32: if is_f32 { vec![0.0; elems] } else { Vec::new() },
            feat_i32: if is_f32 { Vec::new() } else { vec![0; elems] },
            labels: vec![0; cfg.batch],
            input_dims: cfg.input_shape.iter().map(|&d| d as i64).collect(),
            is_f32,
        }
    }

    /// Number of staged examples (the leading batch dimension).
    pub fn batch(&self) -> usize {
        self.labels.len()
    }
}

/// Parameter store: per-tensor host copies in manifest order. Backends
/// read `host` on each step; `mark_dirty` records optimizer updates so
/// device-resident backends know to re-upload. `(id, version)` is a
/// globally unique key for the current contents — the PJRT engine uses
/// it to cache device literals across the nxBP loop's per-example
/// calls (§Perf L3 iteration 1).
pub struct ParamStore {
    pub host: Vec<Vec<f32>>,
    pub dims: Vec<Vec<i64>>,
    id: u64,
    version: u64,
}

/// Process-unique ParamStore ids, so caches keyed on (id, version)
/// can never confuse two stores.
static NEXT_STORE_ID: std::sync::atomic::AtomicU64 =
    std::sync::atomic::AtomicU64::new(1);

impl ParamStore {
    /// Initialize from the flat f32 concatenation `init` (e.g. from a
    /// checkpoint or `init_params_glorot`).
    pub fn new(cfg: &ConfigSpec, init: Option<&[f32]>) -> Result<ParamStore> {
        let mut host = Vec::with_capacity(cfg.params.len());
        let mut dims = Vec::with_capacity(cfg.params.len());
        let mut off = 0usize;
        for p in &cfg.params {
            let n = p.elems();
            let v = match init {
                Some(flat) => {
                    if flat.len() < off + n {
                        bail!("init vector too short for {}", p.name);
                    }
                    flat[off..off + n].to_vec()
                }
                None => vec![0.0; n],
            };
            off += n;
            host.push(v);
            dims.push(p.shape.iter().map(|&d| d as i64).collect());
        }
        if let Some(flat) = init {
            if flat.len() != off {
                bail!("init vector length {} != param elems {}", flat.len(), off);
            }
        }
        Ok(ParamStore {
            host,
            dims,
            id: NEXT_STORE_ID
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed),
            version: 0,
        })
    }

    /// Record that `host` changed (after an optimizer step).
    pub fn mark_dirty(&mut self) {
        self.version += 1;
    }

    /// Process-unique identity of this store.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Monotone change counter — device backends key upload caches on
    /// `(id, version)`.
    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn total_elems(&self) -> usize {
        self.host.iter().map(|v| v.len()).sum()
    }
}

/// Deterministic parameter initialization on the Rust side (Glorot
/// uniform, mirroring layers.py) so training runs do not depend on
/// Python at runtime.
pub fn init_params_glorot(cfg: &ConfigSpec, seed: u64) -> Vec<f32> {
    use crate::rng::{streams, ChaCha20};
    let mut rng = ChaCha20::seeded(seed, streams::INIT);
    let mut flat = Vec::with_capacity(cfg.param_elems());
    for p in &cfg.params {
        let (fan_in, fan_out) = match p.shape.len() {
            2 => (p.shape[0], p.shape[1]),
            4 => {
                let rf = p.shape[2] * p.shape[3];
                (p.shape[1] * rf, p.shape[0] * rf)
            }
            _ => (p.elems().max(1), 1),
        };
        let is_bias = p.shape.len() == 1;
        let limit = (6.0 / (fan_in + fan_out) as f64).sqrt() as f32;
        for _ in 0..p.elems() {
            if is_bias {
                flat.push(0.0);
            } else {
                flat.push((rng.next_f32() * 2.0 - 1.0) * limit);
            }
        }
    }
    flat
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ParamSpec;

    fn dummy_cfg() -> ConfigSpec {
        ConfigSpec {
            name: "t".into(),
            model: "mlp".into(),
            dataset: "mnist".into(),
            batch: 4,
            n_classes: 10,
            tags: vec![],
            input_shape: vec![4, 3],
            input_dtype: "f32".into(),
            act_elems_per_example: 0,
            conv: None,
            spec: None,
            params: vec![
                ParamSpec { name: "w".into(), shape: vec![3, 2] },
                ParamSpec { name: "b".into(), shape: vec![2] },
            ],
            artifacts: Default::default(),
        }
    }

    #[test]
    fn param_store_layout() {
        let cfg = dummy_cfg();
        let init: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let ps = ParamStore::new(&cfg, Some(&init)).unwrap();
        assert_eq!(ps.host.len(), 2);
        assert_eq!(ps.host[0], vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(ps.host[1], vec![6., 7.]);
        assert_eq!(ps.total_elems(), 8);
        // wrong length rejected
        assert!(ParamStore::new(&cfg, Some(&init[..7])).is_err());
    }

    #[test]
    fn dirty_marks_bump_version() {
        let cfg = dummy_cfg();
        let mut ps = ParamStore::new(&cfg, None).unwrap();
        let v0 = ps.version();
        ps.mark_dirty();
        assert_eq!(ps.version(), v0 + 1);
    }

    #[test]
    fn glorot_init_bounds_and_bias_zero() {
        let cfg = dummy_cfg();
        let flat = init_params_glorot(&cfg, 3);
        assert_eq!(flat.len(), 8);
        let limit = (6.0f64 / 5.0).sqrt() as f32;
        assert!(flat[..6].iter().all(|&v| v.abs() <= limit));
        assert!(flat[..6].iter().any(|&v| v != 0.0));
        assert_eq!(&flat[6..], &[0.0, 0.0]);
        // deterministic
        assert_eq!(flat, init_params_glorot(&cfg, 3));
        assert_ne!(flat, init_params_glorot(&cfg, 4));
    }

    #[test]
    fn clip_factor_formula() {
        assert_eq!(clip_factor(2.0, 1.0), 0.5);
        assert_eq!(clip_factor(0.5, 1.0), 1.0);
        assert_eq!(clip_factor(1.0, 1.0), 1.0); // boundary: untouched
    }

    #[test]
    fn stage_shapes() {
        let cfg = dummy_cfg();
        let stage = BatchStage::for_config(&cfg);
        assert!(stage.is_f32);
        assert_eq!(stage.feat_f32.len(), 12);
        assert_eq!(stage.labels.len(), 4);
        assert_eq!(stage.input_dims, vec![4, 3]);
        assert_eq!(stage.batch(), 4);
    }

    #[test]
    fn grad_vec_layout_views_and_ops() {
        let mut g = GradVec::with_layout(&[6, 2]);
        assert_eq!(g.n_params(), 2);
        assert_eq!(g.total_elems(), 8);
        assert_eq!(g.param(0).len(), 6);
        assert_eq!(g.param(1).len(), 2);
        g.param_mut(1).copy_from_slice(&[1.0, 2.0]);
        assert_eq!(&g.flat()[6..], &[1.0, 2.0]);
        // ensure_layout is a no-op on a matching layout (same storage)
        let ptr = g.flat().as_ptr();
        g.ensure_layout(&[6, 2]);
        assert_eq!(g.flat().as_ptr(), ptr);
        assert_eq!(&g.flat()[6..], &[1.0, 2.0]);
        // ...and rebuilds (zeroed) on a different one
        g.ensure_layout(&[3]);
        assert_eq!(g.n_params(), 1);
        assert!(g.flat().iter().all(|&v| v == 0.0));
        // arithmetic
        let a = GradVec::from_vecs(&[vec![1.0, 2.0], vec![3.0]]);
        let mut b = GradVec::from_vecs(&[vec![10.0, 10.0], vec![10.0]]);
        b.add_scaled(&a, 2.0);
        assert_eq!(b.flat(), &[12.0, 14.0, 16.0]);
        b.add(&a);
        assert_eq!(b.flat(), &[13.0, 16.0, 19.0]);
        b.scale(0.5);
        assert_eq!(b.flat(), &[6.5, 8.0, 9.5]);
        assert_eq!(b.params().count(), 2);
    }

    #[test]
    fn grad_vec_param_range_ops() {
        let a = GradVec::from_vecs(&[vec![3.0, 4.0], vec![2.0], vec![6.0]]);
        // per-range squared norms partition the whole
        assert_eq!(a.sq_norm_params(0, 1), 25.0);
        assert_eq!(a.sq_norm_params(1, 3), 40.0);
        assert_eq!(
            a.sq_norm_params(0, 3),
            a.flat().iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
        );
        // range add_scaled touches only the window
        let mut b = GradVec::with_layout(&[2, 1, 1]);
        b.add_scaled_params(&a, 1, 3, 0.5);
        assert_eq!(b.flat(), &[0.0, 0.0, 1.0, 3.0]);
        b.add_scaled_params(&a, 0, 1, 2.0);
        assert_eq!(b.flat(), &[6.0, 8.0, 1.0, 3.0]);
    }

    #[test]
    fn step_out_reset_and_norms() {
        let cfg = dummy_cfg();
        let mut out = StepOut::for_config(&cfg);
        assert_eq!(out.grads.total_elems(), 8);
        assert!(out.norms().is_none());
        out.loss = 3.0;
        out.correct = Some(2);
        out.grads.param_mut(0)[0] = 9.0;
        {
            let n = out.norms_fill(4);
            n[0] = 1.5;
        }
        assert_eq!(out.norms().unwrap().len(), 4);
        assert_eq!(out.norms().unwrap()[0], 1.5);
        out.set_norms(&[0.5, 0.25]);
        assert_eq!(out.norms().unwrap(), &[0.5, 0.25]);
        assert!(out.group_norms().is_none());
        out.set_group_norms(&[1.0, 2.0, 3.0, 4.0], 2);
        let (gn, g) = out.group_norms().unwrap();
        assert_eq!((gn, g), (&[1.0f32, 2.0, 3.0, 4.0][..], 2));
        // reset clears everything a step could have written
        out.reset(&[6, 2]);
        assert_eq!(out.loss, 0.0);
        assert!(out.norms().is_none());
        assert!(out.group_norms().is_none());
        assert!(out.correct.is_none());
        assert!(out.grads.flat().iter().all(|&v| v == 0.0));
        // an empty arena grows on first reset (one-shot callers)
        let mut fresh = StepOut::new();
        fresh.reset(&[6, 2]);
        assert_eq!(fresh.grads.total_elems(), 8);
    }
}
