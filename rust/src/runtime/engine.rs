//! PJRT execution engine: compile HLO-text artifacts on the CPU
//! client, cache executables, and marshal batches/params in and
//! gradients out.
//!
//! Adapted from the /opt/xla-example/load_hlo reference: HLO *text* is
//! the interchange format (the 0.5.1 xla_extension rejects jax>=0.5
//! serialized protos), and every artifact returns one tuple
//! (lowered with return_tuple=True).

use super::manifest::{ArtifactSpec, ConfigSpec, Manifest};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// A compiled step executable plus its output layout.
pub struct StepExe {
    pub exe: xla::PjRtLoadedExecutable,
    pub n_params: usize,
    pub outputs: Vec<String>,
    pub method: String,
    pub compile_ms: f64,
}

/// Structured results of one step execution.
#[derive(Debug, Clone)]
pub struct StepOut {
    /// per-parameter gradients (host f32), same order as the manifest
    pub grads: Vec<Vec<f32>>,
    pub loss: f32,
    /// per-example gradient norms (reweight/multiloss) or the single
    /// example's norm (naive1)
    pub norms: Option<Vec<f32>>,
    /// correct-prediction count (fwd artifact only)
    pub correct: Option<f32>,
}

/// Engine: one PJRT CPU client + an executable cache keyed by artifact
/// file name.
pub struct Engine {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<StepExe>>>,
}

// SAFETY: the xla crate wraps raw PJRT pointers without Send/Sync
// markers, but the PJRT C API contract makes clients and loaded
// executables thread-safe (execution is internally synchronized;
// executables are immutable after compilation). The only shared
// mutable state on our side is the compile cache, which is
// mutex-guarded.
unsafe impl Send for StepExe {}
unsafe impl Sync for StepExe {}
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    pub fn new(manifest: Manifest) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        crate::log_debug!(
            "PJRT client: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Engine { client, manifest, cache: Mutex::new(HashMap::new()) })
    }

    pub fn from_dir(dir: &std::path::Path) -> Result<Engine> {
        Engine::new(Manifest::load(dir)?)
    }

    /// Compile (or fetch from cache) the executable for a config's
    /// method.
    pub fn load(&self, cfg: &ConfigSpec, method: &str) -> Result<Arc<StepExe>> {
        let art = cfg.artifact(method)?;
        {
            let cache = self.cache.lock().unwrap();
            if let Some(exe) = cache.get(&art.file) {
                return Ok(exe.clone());
            }
        }
        let exe = Arc::new(self.compile_artifact(cfg, art)?);
        self.cache
            .lock()
            .unwrap()
            .insert(art.file.clone(), exe.clone());
        Ok(exe)
    }

    fn compile_artifact(
        &self,
        cfg: &ConfigSpec,
        art: &ArtifactSpec,
    ) -> Result<StepExe> {
        let path = self.manifest.artifact_path(art);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
        crate::log_debug!("compiled {} in {:.0} ms", art.file, compile_ms);
        Ok(StepExe {
            exe,
            n_params: cfg.params.len(),
            outputs: art.outputs.clone(),
            method: art.method.clone(),
            compile_ms,
        })
    }

    /// Number of executables compiled so far (cache size).
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

/// Host-side batch staging buffers, reused across steps to keep
/// allocation out of the hot loop.
pub struct BatchStage {
    pub feat_f32: Vec<f32>,
    pub feat_i32: Vec<i32>,
    pub labels: Vec<i32>,
    pub input_dims: Vec<i64>,
    pub is_f32: bool,
}

impl BatchStage {
    pub fn for_config(cfg: &ConfigSpec) -> BatchStage {
        let elems = cfg.input_elems();
        let is_f32 = cfg.input_dtype == "f32";
        BatchStage {
            feat_f32: if is_f32 { vec![0.0; elems] } else { Vec::new() },
            feat_i32: if is_f32 { Vec::new() } else { vec![0; elems] },
            labels: vec![0; cfg.batch],
            input_dims: cfg.input_shape.iter().map(|&d| d as i64).collect(),
            is_f32,
        }
    }

    fn input_literal(&self) -> Result<xla::Literal> {
        let lit = if self.is_f32 {
            xla::Literal::vec1(&self.feat_f32)
        } else {
            xla::Literal::vec1(&self.feat_i32)
        };
        Ok(lit.reshape(&self.input_dims)?)
    }

    fn label_literal(&self) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(&self.labels)
            .reshape(&[self.labels.len() as i64])?)
    }
}

/// Parameter store: host copies + prebuilt literals (rebuilt after
/// each optimizer update).
pub struct ParamStore {
    pub host: Vec<Vec<f32>>,
    pub dims: Vec<Vec<i64>>,
    literals: Vec<xla::Literal>,
    dirty: bool,
}

impl ParamStore {
    /// Initialize from the flat f32 concatenation `init` (e.g. from a
    /// checkpoint or the `init` artifact of the Python side).
    pub fn new(cfg: &ConfigSpec, init: Option<&[f32]>) -> Result<ParamStore> {
        let mut host = Vec::with_capacity(cfg.params.len());
        let mut dims = Vec::with_capacity(cfg.params.len());
        let mut off = 0usize;
        for p in &cfg.params {
            let n = p.elems();
            let v = match init {
                Some(flat) => {
                    if flat.len() < off + n {
                        bail!("init vector too short for {}", p.name);
                    }
                    flat[off..off + n].to_vec()
                }
                None => vec![0.0; n],
            };
            off += n;
            host.push(v);
            dims.push(p.shape.iter().map(|&d| d as i64).collect());
        }
        if let Some(flat) = init {
            if flat.len() != off {
                bail!("init vector length {} != param elems {}", flat.len(), off);
            }
        }
        let mut ps = ParamStore { host, dims, literals: Vec::new(), dirty: true };
        ps.rebuild_literals()?;
        Ok(ps)
    }

    pub fn rebuild_literals(&mut self) -> Result<()> {
        self.literals.clear();
        for (v, d) in self.host.iter().zip(&self.dims) {
            self.literals.push(xla::Literal::vec1(v).reshape(d)?);
        }
        self.dirty = false;
        Ok(())
    }

    pub fn mark_dirty(&mut self) {
        self.dirty = true;
    }

    pub fn literals(&mut self) -> Result<&[xla::Literal]> {
        if self.dirty {
            self.rebuild_literals()?;
        }
        Ok(&self.literals)
    }

    pub fn total_elems(&self) -> usize {
        self.host.iter().map(|v| v.len()).sum()
    }
}

/// Execute one step: params + staged batch (+ optional clip scalar).
///
/// Parameters are passed by reference into PJRT (`Borrow<Literal>`)
/// rather than cloned — `Literal::clone` is a deep copy through the C
/// API, and the nxBP loop would otherwise deep-copy every parameter
/// tensor once per *example* (§Perf L3 iteration 1).
pub fn run_step(
    exe: &StepExe,
    params: &mut ParamStore,
    stage: &BatchStage,
    clip: Option<f32>,
) -> Result<StepOut> {
    let mut owned: Vec<xla::Literal> = Vec::with_capacity(3);
    owned.push(stage.input_literal()?);
    owned.push(stage.label_literal()?);
    if let Some(c) = clip {
        owned.push(xla::Literal::scalar(c));
    }
    let param_lits = params.literals()?;
    let mut args: Vec<&xla::Literal> =
        Vec::with_capacity(param_lits.len() + owned.len());
    args.extend(param_lits.iter());
    args.extend(owned.iter());
    let result = exe.exe.execute::<&xla::Literal>(&args)?;
    let tuple = result[0][0].to_literal_sync()?;
    let parts = tuple.to_tuple()?;
    decode_outputs(exe, parts)
}

fn decode_outputs(exe: &StepExe, parts: Vec<xla::Literal>) -> Result<StepOut> {
    let has_grads = exe.outputs.iter().any(|o| o == "grads");
    let n_grads = if has_grads { exe.n_params } else { 0 };
    let expected = n_grads + exe.outputs.len() - usize::from(has_grads);
    if parts.len() != expected {
        bail!(
            "{}: expected {} outputs ({:?} over {} params), got {}",
            exe.method,
            expected,
            exe.outputs,
            exe.n_params,
            parts.len()
        );
    }
    let mut it = parts.into_iter();
    let mut grads = Vec::with_capacity(n_grads);
    for _ in 0..n_grads {
        grads.push(it.next().unwrap().to_vec::<f32>()?);
    }
    let mut out = StepOut { grads, loss: 0.0, norms: None, correct: None };
    for name in exe.outputs.iter().filter(|o| o.as_str() != "grads") {
        let lit = it.next().unwrap();
        match name.as_str() {
            "loss" => out.loss = lit.to_vec::<f32>()?[0],
            "norms" | "norm" => out.norms = Some(lit.to_vec::<f32>()?),
            "correct" => out.correct = Some(lit.to_vec::<f32>()?[0]),
            other => bail!("unknown output group {other:?}"),
        }
    }
    Ok(out)
}

/// Deterministic parameter initialization on the Rust side (Glorot
/// uniform, mirroring layers.py) so training runs do not depend on
/// Python at runtime.
pub fn init_params_glorot(cfg: &ConfigSpec, seed: u64) -> Vec<f32> {
    use crate::rng::{streams, ChaCha20};
    let mut rng = ChaCha20::seeded(seed, streams::INIT);
    let mut flat = Vec::with_capacity(cfg.param_elems());
    for p in &cfg.params {
        let (fan_in, fan_out) = match p.shape.len() {
            2 => (p.shape[0], p.shape[1]),
            4 => {
                let rf = p.shape[2] * p.shape[3];
                (p.shape[1] * rf, p.shape[0] * rf)
            }
            _ => (p.elems().max(1), 1),
        };
        let is_bias = p.shape.len() == 1;
        let limit = (6.0 / (fan_in + fan_out) as f64).sqrt() as f32;
        for _ in 0..p.elems() {
            if is_bias {
                flat.push(0.0);
            } else {
                flat.push((rng.next_f32() * 2.0 - 1.0) * limit);
            }
        }
    }
    flat
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ParamSpec;

    fn dummy_cfg() -> ConfigSpec {
        ConfigSpec {
            name: "t".into(),
            model: "mlp".into(),
            dataset: "mnist".into(),
            batch: 4,
            n_classes: 10,
            tags: vec![],
            input_shape: vec![4, 3],
            input_dtype: "f32".into(),
            act_elems_per_example: 0,
            params: vec![
                ParamSpec { name: "w".into(), shape: vec![3, 2] },
                ParamSpec { name: "b".into(), shape: vec![2] },
            ],
            artifacts: Default::default(),
        }
    }

    #[test]
    fn param_store_layout() {
        let cfg = dummy_cfg();
        let init: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let ps = ParamStore::new(&cfg, Some(&init)).unwrap();
        assert_eq!(ps.host.len(), 2);
        assert_eq!(ps.host[0], vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(ps.host[1], vec![6., 7.]);
        assert_eq!(ps.total_elems(), 8);
        // wrong length rejected
        assert!(ParamStore::new(&cfg, Some(&init[..7])).is_err());
    }

    #[test]
    fn glorot_init_bounds_and_bias_zero() {
        let cfg = dummy_cfg();
        let flat = init_params_glorot(&cfg, 3);
        assert_eq!(flat.len(), 8);
        let limit = (6.0f64 / 5.0).sqrt() as f32;
        assert!(flat[..6].iter().all(|&v| v.abs() <= limit));
        assert!(flat[..6].iter().any(|&v| v != 0.0));
        assert_eq!(&flat[6..], &[0.0, 0.0]);
        // deterministic
        assert_eq!(flat, init_params_glorot(&cfg, 3));
        assert_ne!(flat, init_params_glorot(&cfg, 4));
    }

    #[test]
    fn stage_shapes() {
        let cfg = dummy_cfg();
        let stage = BatchStage::for_config(&cfg);
        assert!(stage.is_f32);
        assert_eq!(stage.feat_f32.len(), 12);
        assert_eq!(stage.labels.len(), 4);
        assert_eq!(stage.input_dims, vec![4, 3]);
    }
}
