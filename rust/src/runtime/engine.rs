//! PJRT execution engine (feature `pjrt`): compile HLO-text artifacts
//! on the CPU client, cache executables, and marshal batches/params in
//! and gradients out. This is the artifact-backed `Backend`
//! implementation; the hermetic reference implementation lives in
//! `runtime::native`.
//!
//! Adapted from the /opt/xla-example/load_hlo reference: HLO *text* is
//! the interchange format (the 0.5.1 xla_extension rejects jax>=0.5
//! serialized protos), and every artifact returns one tuple
//! (lowered with return_tuple=True).

use super::backend::{Backend, StepFn};
use super::manifest::{ArtifactSpec, ConfigSpec, Manifest};
use super::policy::ClipPolicy;
use super::store::{BatchStage, ParamStore, StepOut};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
// lint: allow-file(no-wallclock-entropy) -- Instant measures compile
// latency only (`compile_ms` telemetry in StepExe); wall time never
// feeds step math, artifact selection, or anything replayed.
use std::time::Instant;

/// A compiled step executable plus its output layout.
pub struct StepExe {
    pub exe: xla::PjRtLoadedExecutable,
    pub n_params: usize,
    pub outputs: Vec<String>,
    pub method: String,
    pub compile_ms: f64,
    /// parameter literals cached by (ParamStore id, version): the nxBP
    /// loop calls run() once per example on unchanged params, and
    /// rebuilding literals each call would deep-copy every parameter
    /// tensor through the C API per example (§Perf L3 iteration 1).
    /// Arc so the lock is released before execution (PJRT executes
    /// concurrently; the literals are immutable once built).
    lit_cache: Mutex<Option<(u64, u64, Arc<Vec<xla::Literal>>)>>,
}

/// Engine: one PJRT CPU client + an executable cache keyed by artifact
/// file name.
pub struct Engine {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    /// BTreeMap, not HashMap: anything that iterates or logs the cache
    /// must see one fixed order — hash order varies per process.
    cache: Mutex<BTreeMap<String, Arc<StepExe>>>,
}

// The xla crate wraps raw PJRT pointers without Send/Sync markers, but
// the PJRT C API contract makes clients and loaded executables
// thread-safe: execution is internally synchronized and executables
// are immutable after compilation.

// SAFETY: PJRT loaded executables are immutable after compilation and
// internally synchronized; `lit_cache` is mutex-guarded.
unsafe impl Send for StepExe {}
// SAFETY: concurrent `execute` calls on one executable are legal per
// the PJRT C API; shared mutable state (`lit_cache`) is mutex-guarded.
unsafe impl Sync for StepExe {}
// SAFETY: the PJRT CPU client is thread-safe per the C API contract;
// `manifest` is immutable and `cache` is mutex-guarded.
unsafe impl Send for Engine {}
// SAFETY: same as Send — every &Engine operation either reads
// immutable state or goes through the `cache` mutex.
unsafe impl Sync for Engine {}

impl Engine {
    pub fn new(manifest: Manifest) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        crate::log_debug!(
            "PJRT client: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Engine { client, manifest, cache: Mutex::new(BTreeMap::new()) })
    }

    pub fn from_dir(dir: &std::path::Path) -> Result<Engine> {
        Engine::new(Manifest::load(dir)?)
    }

    fn compile_artifact(
        &self,
        cfg: &ConfigSpec,
        art: &ArtifactSpec,
    ) -> Result<StepExe> {
        let path = self.manifest.artifact_path(art);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
        crate::log_debug!("compiled {} in {:.0} ms", art.file, compile_ms);
        Ok(StepExe {
            exe,
            n_params: cfg.params.len(),
            outputs: art.outputs.clone(),
            method: art.method.clone(),
            compile_ms,
            lit_cache: Mutex::new(None),
        })
    }

    /// Number of executables compiled so far (cache size).
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

impl Backend for Engine {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) the executable for a config's
    /// method.
    fn load(&self, cfg: &ConfigSpec, method: &str) -> Result<Arc<dyn StepFn>> {
        let art = cfg.artifact(method)?;
        {
            let cache = self.cache.lock().unwrap();
            if let Some(exe) = cache.get(&art.file) {
                return Ok(exe.clone());
            }
        }
        let exe = Arc::new(self.compile_artifact(cfg, art)?);
        self.cache
            .lock()
            .unwrap()
            .insert(art.file.clone(), exe.clone());
        Ok(exe)
    }
}

fn input_literal(stage: &BatchStage) -> Result<xla::Literal> {
    let lit = if stage.is_f32 {
        xla::Literal::vec1(&stage.feat_f32)
    } else {
        xla::Literal::vec1(&stage.feat_i32)
    };
    Ok(lit.reshape(&stage.input_dims)?)
}

fn label_literal(stage: &BatchStage) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(&stage.labels)
        .reshape(&[stage.labels.len() as i64])?)
}

impl StepFn for StepExe {
    fn method(&self) -> &str {
        &self.method
    }

    fn compile_ms(&self) -> f64 {
        self.compile_ms
    }

    /// Execute one step into the caller's arena: params + staged batch
    /// (+ optional clip policy). The AOT artifacts bake in the
    /// classical scalar clip, so only the global-hard policy is
    /// executable here — anything else needs `--backend native`.
    ///
    /// Parameters are passed by reference into PJRT (`Borrow<Literal>`)
    /// and their literals are cached across calls keyed on the store's
    /// `(id, version)` — `Literal` construction is a deep copy through
    /// the C API, and the nxBP loop would otherwise pay it once per
    /// *example* (§Perf L3 iteration 1).
    ///
    /// Marshalling out of PJRT literals inherently copies, so this
    /// backend does not meet the native backend's zero-allocation
    /// warm-path guarantee — the arena still saves the per-step
    /// `Vec<Vec<f32>>` churn on the Rust side.
    fn run_into(
        &self,
        params: &ParamStore,
        stage: &BatchStage,
        policy: Option<&ClipPolicy>,
        out: &mut StepOut,
    ) -> Result<()> {
        let mut owned: Vec<xla::Literal> = Vec::with_capacity(3);
        owned.push(input_literal(stage)?);
        owned.push(label_literal(stage)?);
        if let Some(p) = policy {
            if !p.is_global_hard() {
                bail!(
                    "{}: clip policy {p} needs per-layer norm structure, but \
                     the AOT artifact bakes in the classical global hard \
                     clip — run grouped/automatic policies with `--backend \
                     native`",
                    self.method
                );
            }
            owned.push(xla::Literal::scalar(p.clip()));
        }
        let key = (params.id(), params.version());
        // scope the lock to the cache lookup/refresh — PJRT execution
        // is internally synchronized and must not be serialized here
        let param_lits: Arc<Vec<xla::Literal>> = {
            let mut cache = self.lit_cache.lock().unwrap();
            match &*cache {
                Some((id, ver, lits)) if (*id, *ver) == key => lits.clone(),
                _ => {
                    let fresh: Arc<Vec<xla::Literal>> = Arc::new(
                        params
                            .host
                            .iter()
                            .zip(&params.dims)
                            .map(|(v, d)| {
                                Ok(xla::Literal::vec1(v).reshape(d)?)
                            })
                            .collect::<Result<_>>()?,
                    );
                    *cache = Some((key.0, key.1, fresh.clone()));
                    fresh
                }
            }
        };
        let mut args: Vec<&xla::Literal> =
            Vec::with_capacity(param_lits.len() + owned.len());
        args.extend(param_lits.iter());
        args.extend(owned.iter());
        let result = self.exe.execute::<&xla::Literal>(&args)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        decode_outputs_into(self, parts, out)
    }
}

fn decode_outputs_into(
    exe: &StepExe,
    parts: Vec<xla::Literal>,
    out: &mut StepOut,
) -> Result<()> {
    let has_grads = exe.outputs.iter().any(|o| o == "grads");
    let n_grads = if has_grads { exe.n_params } else { 0 };
    let expected = n_grads + exe.outputs.len() - usize::from(has_grads);
    if parts.len() != expected {
        bail!(
            "{}: expected {} outputs ({:?} over {} params), got {}",
            exe.method,
            expected,
            exe.outputs,
            exe.n_params,
            parts.len()
        );
    }
    let mut it = parts.into_iter();
    let mut grad_vecs: Vec<Vec<f32>> = Vec::with_capacity(n_grads);
    for _ in 0..n_grads {
        grad_vecs.push(it.next().unwrap().to_vec::<f32>()?);
    }
    let lens: Vec<usize> = grad_vecs.iter().map(|v| v.len()).collect();
    // reset adopts the decoded layout and clears norms/scalars; for a
    // grad-less artifact (fwd) the arena's gradient buffer collapses
    // to the empty layout
    out.reset(&lens);
    for (i, v) in grad_vecs.iter().enumerate() {
        out.grads.param_mut(i).copy_from_slice(v);
    }
    for name in exe.outputs.iter().filter(|o| o.as_str() != "grads") {
        let lit = it.next().unwrap();
        match name.as_str() {
            "loss" => out.loss = lit.to_vec::<f32>()?[0],
            "norms" | "norm" => out.set_norms(&lit.to_vec::<f32>()?),
            // the artifact returns the correct-prediction count as an
            // f32 scalar; it is an integer count in [0, batch]
            "correct" => {
                out.correct = Some(lit.to_vec::<f32>()?[0].round() as u32)
            }
            other => bail!("unknown output group {other:?}"),
        }
    }
    Ok(())
}
