//! artifacts/manifest.json loader — the contract between the Python
//! compile path (aot.py) and the Rust request path.

use super::spec::ModelSpec;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub method: String,
    pub file: String,
    /// extra scalar args after (params, X, y): currently just "clip"
    pub extra_args: Vec<String>,
    /// named output groups: "grads" then e.g. "loss", "norms"
    pub outputs: Vec<String>,
}

/// Convolution hyperparameters shared by every conv layer of a `cnn`
/// config. The manifest's param shapes carry (cout, cin, kh, kw) but
/// not stride/padding, so those ride here; absent, the native conv
/// family's defaults (3x3, stride 2, pad 1) apply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvMeta {
    pub kernel: usize,
    pub stride: usize,
    pub pad: usize,
    /// average-pool window (and stride) after every conv layer;
    /// 0 or 1 means no pooling
    pub pool: usize,
}

impl Default for ConvMeta {
    fn default() -> Self {
        ConvMeta { kernel: 3, stride: 2, pad: 1, pool: 0 }
    }
}

#[derive(Debug, Clone)]
pub struct ConfigSpec {
    pub name: String,
    pub model: String,
    pub dataset: String,
    pub batch: usize,
    pub n_classes: usize,
    pub tags: Vec<String>,
    pub input_shape: Vec<usize>,
    pub input_dtype: String, // "f32" | "i32"
    /// pre-activation (tap) elements per example — memory model input
    pub act_elems_per_example: usize,
    /// conv hyperparameters (model == "cnn" only)
    pub conv: Option<ConvMeta>,
    /// The `ModelSpec` this config was synthesized from, when it came
    /// through `spec::ConfigBuilder` (every builtin preset and every
    /// spec-resolved config). Structural derivations — e.g. the
    /// batch-1 nxBP sibling via `ConfigSpec::with_batch` — need it;
    /// manifest-loaded (AOT artifact) configs carry `None` and fall
    /// back to the manifest's `_b` naming convention instead.
    pub spec: Option<ModelSpec>,
    pub params: Vec<ParamSpec>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl ConfigSpec {
    pub fn param_elems(&self) -> usize {
        self.params.iter().map(|p| p.elems()).sum()
    }

    pub fn input_elems(&self) -> usize {
        self.input_shape.iter().product()
    }

    pub fn artifact(&self, method: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(method).with_context(|| {
            format!(
                "config {} has no `{}` artifact (has: {:?})",
                self.name,
                method,
                self.artifacts.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn has_tag(&self, tag: &str) -> bool {
        self.tags.iter().any(|t| t == tag)
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub configs: BTreeMap<String, ConfigSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = crate::util::read_file(&path)?;
        let root = Json::parse(&text)
            .with_context(|| format!("parsing {}", path.display()))?;
        Self::from_json(dir, &root)
    }

    pub fn from_json(dir: &Path, root: &Json) -> Result<Manifest> {
        let mut configs = BTreeMap::new();
        let cfgs = root
            .get("configs")
            .as_obj()
            .context("manifest missing `configs`")?;
        for (name, c) in cfgs {
            let mut params = Vec::new();
            for p in c.get("params").as_arr().unwrap_or(&[]) {
                params.push(ParamSpec {
                    name: p.get("name").as_str().unwrap_or("?").to_string(),
                    shape: usizes(p.get("shape"))?,
                });
            }
            let mut artifacts = BTreeMap::new();
            if let Some(arts) = c.get("artifacts").as_obj() {
                for (method, a) in arts {
                    artifacts.insert(
                        method.clone(),
                        ArtifactSpec {
                            method: method.clone(),
                            file: a
                                .get("file")
                                .as_str()
                                .context("artifact missing file")?
                                .to_string(),
                            extra_args: strings(a.get("extra_args")),
                            outputs: strings(a.get("outputs")),
                        },
                    );
                }
            }
            let spec = ConfigSpec {
                name: name.clone(),
                model: c.get("model").as_str().unwrap_or("?").to_string(),
                dataset: c.get("dataset").as_str().unwrap_or("?").to_string(),
                batch: c.get("batch").as_usize().context("missing batch")?,
                n_classes: c.get("n_classes").as_usize().unwrap_or(0),
                tags: strings(c.get("tags")),
                input_shape: usizes(c.get("input").get("shape"))?,
                input_dtype: c
                    .get("input")
                    .get("dtype")
                    .as_str()
                    .unwrap_or("f32")
                    .to_string(),
                act_elems_per_example: c
                    .get("act_elems_per_example")
                    .as_usize()
                    .unwrap_or(0),
                conv: conv_meta(c.get("conv")),
                spec: None,
                params,
                artifacts,
            };
            configs.insert(name.clone(), spec);
        }
        if configs.is_empty() {
            bail!("manifest has no configs — run `make artifacts`");
        }
        Ok(Manifest { dir: dir.to_path_buf(), configs })
    }

    pub fn config(&self, name: &str) -> Result<&ConfigSpec> {
        self.configs.get(name).with_context(|| {
            format!(
                "unknown config {:?}; available: {:?}",
                name,
                self.configs.keys().take(8).collect::<Vec<_>>()
            )
        })
    }

    /// Configs carrying an experiment tag (e.g. "fig5"), sorted by name.
    pub fn by_tag(&self, tag: &str) -> Vec<&ConfigSpec> {
        self.configs.values().filter(|c| c.has_tag(tag)).collect()
    }

    /// The batch-1 naive (nxBP body) config for a batched config, by
    /// the manifest's `_b<batch>` naming convention. This is the
    /// fallback for manifest-loaded configs only — spec-derived
    /// configs rebuild the sibling structurally via
    /// `ConfigSpec::with_batch` (see `Backend::naive_sibling`).
    pub fn naive_config(&self, name: &str) -> Result<&ConfigSpec> {
        let base = name.rsplit_once("_b").map(|(b, _)| b).unwrap_or(name);
        self.config(&format!("{base}_b1"))
    }

    pub fn artifact_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

/// Parse an optional `"conv": {"kernel": 3, "stride": 2, "pad": 1}`
/// block; missing fields take the `ConvMeta` defaults.
fn conv_meta(j: &Json) -> Option<ConvMeta> {
    j.as_obj()?;
    let d = ConvMeta::default();
    Some(ConvMeta {
        kernel: j.get("kernel").as_usize().unwrap_or(d.kernel),
        stride: j.get("stride").as_usize().unwrap_or(d.stride),
        pad: j.get("pad").as_usize().unwrap_or(d.pad),
        pool: j.get("pool").as_usize().unwrap_or(d.pool),
    })
}

fn usizes(j: &Json) -> Result<Vec<usize>> {
    let arr = j.as_arr().context("expected array")?;
    arr.iter()
        .map(|v| v.as_usize().context("expected number"))
        .collect()
}

fn strings(j: &Json) -> Vec<String> {
    j.as_arr()
        .map(|a| {
            a.iter()
                .filter_map(|v| v.as_str().map(String::from))
                .collect()
        })
        .unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Json {
        Json::parse(
            r#"{
              "version": 1,
              "configs": {
                "mlp2_mnist_b32": {
                  "model": "mlp", "dataset": "mnist", "batch": 32,
                  "n_classes": 10, "tags": ["fig5"],
                  "input": {"shape": [32,1,28,28], "dtype": "f32"},
                  "label": {"shape": [32], "dtype": "i32"},
                  "params": [
                    {"name": "fc0.w", "shape": [784,128]},
                    {"name": "fc0.b", "shape": [128]}
                  ],
                  "artifacts": {
                    "reweight": {"file": "m.reweight.hlo.txt",
                                  "extra_args": ["clip"],
                                  "outputs": ["grads","loss","norms"]}
                  }
                },
                "mlp2_mnist_b1": {
                  "model": "mlp", "dataset": "mnist", "batch": 1,
                  "n_classes": 10, "tags": ["naive"],
                  "input": {"shape": [1,1,28,28], "dtype": "f32"},
                  "label": {"shape": [1], "dtype": "i32"},
                  "params": [], "artifacts": {}
                }
              }
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_manifest() {
        let m = Manifest::from_json(Path::new("/tmp"), &sample()).unwrap();
        let c = m.config("mlp2_mnist_b32").unwrap();
        assert_eq!(c.batch, 32);
        assert_eq!(c.params.len(), 2);
        assert_eq!(c.param_elems(), 784 * 128 + 128);
        assert_eq!(c.input_elems(), 32 * 784);
        let a = c.artifact("reweight").unwrap();
        assert_eq!(a.extra_args, vec!["clip"]);
        assert!(c.artifact("nope").is_err());
        assert!(c.has_tag("fig5"));
        assert_eq!(m.by_tag("fig5").len(), 1);
    }

    #[test]
    fn conv_meta_parses_with_defaults() {
        let j = Json::parse(
            r#"{"configs": {"cnn2_mnist_b16": {
                "model": "cnn", "dataset": "mnist", "batch": 16,
                "n_classes": 10,
                "input": {"shape": [16,1,28,28], "dtype": "f32"},
                "conv": {"kernel": 3, "stride": 2},
                "params": [], "artifacts": {}
            }}}"#,
        )
        .unwrap();
        let m = Manifest::from_json(Path::new("/tmp"), &j).unwrap();
        let c = m.config("cnn2_mnist_b16").unwrap();
        // pad/pool missing => defaults (pad 1, no pool)
        assert_eq!(
            c.conv,
            Some(ConvMeta { kernel: 3, stride: 2, pad: 1, pool: 0 })
        );
        // mlp configs carry no conv block
        let m2 = Manifest::from_json(Path::new("/tmp"), &sample()).unwrap();
        assert_eq!(m2.config("mlp2_mnist_b32").unwrap().conv, None);
    }

    #[test]
    fn naive_lookup() {
        let m = Manifest::from_json(Path::new("/tmp"), &sample()).unwrap();
        let n = m.naive_config("mlp2_mnist_b32").unwrap();
        assert_eq!(n.batch, 1);
    }

    #[test]
    fn missing_configs_rejected() {
        let j = Json::parse(r#"{"configs": {}}"#).unwrap();
        assert!(Manifest::from_json(Path::new("/tmp"), &j).is_err());
    }

    /// Regression for the no-hash-container rule's motivation: config
    /// (and artifact) iteration order must be a pure function of the
    /// key set — independent of the order the manifest text lists them
    /// in, stable across loads.
    #[test]
    fn config_iteration_order_is_stable() {
        fn cfg(name: &str) -> String {
            format!(
                r#""{name}": {{
                    "model": "mlp", "dataset": "mnist", "batch": 1,
                    "n_classes": 10,
                    "input": {{"shape": [1,1,28,28], "dtype": "f32"}},
                    "params": [], "artifacts": {{}}
                }}"#
            )
        }
        let (a, b, c) = (cfg("zz_last"), cfg("aa_first"), cfg("mm_mid"));
        let fwd = format!(r#"{{"configs": {{{a}, {b}, {c}}}}}"#);
        let rev = format!(r#"{{"configs": {{{c}, {b}, {a}}}}}"#);
        let order = |text: &str| -> Vec<String> {
            let m =
                Manifest::from_json(Path::new("/tmp"), &Json::parse(text).unwrap()).unwrap();
            m.configs.keys().cloned().collect()
        };
        let o1 = order(&fwd);
        assert_eq!(o1, vec!["aa_first", "mm_mid", "zz_last"], "sorted by key");
        assert_eq!(o1, order(&rev), "insertion order must not leak through");
        assert_eq!(o1, order(&fwd), "repeat load, identical order");
    }
}
