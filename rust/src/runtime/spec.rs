//! The ModelSpec DSL + `ConfigBuilder`: config *synthesis* for any
//! architecture x dataset x batch, replacing "look a name up in a
//! closed grid".
//!
//! The paper's headline claims are scaling curves — step time as a
//! function of batch size and architecture — so the interesting
//! configs are exactly the ones a fixed grid does not contain. This
//! module turns a small parseable spec into a full `ConfigSpec`
//! (param shapes, activation elements, conv meta, the standard
//! artifact set) on demand:
//!
//! ```text
//!   model spec   mlp(depth=4,width=512)
//!                cnn(depth=2,k=3,s=1,pad=1,ch=8-16)
//!                transformer(heads=2,d_model=32,seq=64,ff=64)
//!   spec key     <model-spec>@<dataset>:b<batch>
//!                e.g. mlp(depth=4,width=512)@cifar10:b256
//!                     transformer(heads=4,d_model=64)@imdb:b32
//! ```
//!
//! Grammar notes:
//!   - keys may be abbreviated (`d`/`depth`, `w`/`width`, `k`/`kernel`,
//!     `s`/`stride`, `p`/`pad`, `ch`/`channels`, `h`/`heads`,
//!     `dm`/`d_model`), appear in any order, and fall back to the
//!     builtin grid's defaults when omitted;
//!   - `ch` is a dash-separated out-channel progression whose length is
//!     the conv depth (`depth` may be given redundantly, but must then
//!     agree);
//!   - the *canonical* form (what `Display` prints) spells every field
//!     out in a fixed order, so `SpecKey::to_string()` is a stable key
//!     for bench records and checkpoints, and `parse(print(x)) == x`.
//!
//! Resolution order (see `Backend::resolve`): a config reference that
//! parses as a spec key is synthesized here (native backend only);
//! otherwise it must name a builtin preset / manifest entry. The
//! builtin grid itself is a thin preset layer over this builder
//! (`runtime::native::builtin_manifest`), which is what lets
//! `ConfigSpec::with_batch` derive e.g. the batch-1 nxBP sibling
//! *structurally* instead of by `_b`-suffix string surgery.

use super::manifest::{ArtifactSpec, ConfigSpec, ConvMeta, ParamSpec};
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::fmt;

/// Default hidden width of `mlp(...)` specs (the builtin grid's width).
pub const DEFAULT_MLP_WIDTH: usize = 128;

/// Default out-channel progression of `cnn(...)` specs; depths past the
/// table repeat the last entry.
pub const DEFAULT_CNN_CHANNELS: [usize; 4] = [8, 16, 32, 32];

/// A parsed model architecture spec — the open half of a config
/// (the closed half being dataset + batch, carried by `SpecKey`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ModelSpec {
    /// Dense net: `depth` fc layers, hidden width `width`, final layer
    /// onto the dataset's classes.
    Mlp { depth: usize, width: usize },
    /// Conv net: one kxk/stride-s/pad-p conv per entry of `ch` (the
    /// out-channel progression), then one fc head onto the classes.
    /// `pool >= 2` inserts a parameterless `pool`x`pool` average pool
    /// (window == stride) after every conv layer; 0 means none (1 is
    /// normalized to 0 at parse time — a 1x1 mean is the identity).
    Cnn { k: usize, s: usize, pad: usize, pool: usize, ch: Vec<usize> },
    /// Single-block transformer encoder over a token-sequence dataset:
    /// token embedding into `d_model`, `heads`-head self-attention
    /// (q/k/v/o projections), a residual `ff`-wide MLP, mean-pool, and
    /// a classifier head. `seq` must match the dataset's sequence
    /// length (it is part of the spec so the canonical printed form
    /// fully determines the activation geometry).
    Transformer { heads: usize, d_model: usize, seq: usize, ff: usize },
}

/// The default channel progression truncated/extended to `depth`.
fn default_channels(depth: usize) -> Vec<usize> {
    (0..depth)
        .map(|i| DEFAULT_CNN_CHANNELS[i.min(DEFAULT_CNN_CHANNELS.len() - 1)])
        .collect()
}

impl ModelSpec {
    /// Parse `family(key=value,...)`. See the module docs for the
    /// grammar; the canonical printed form always round-trips.
    pub fn parse(src: &str) -> Result<ModelSpec> {
        let s = src.trim();
        let open = s.find('(').with_context(|| {
            format!(
                "model spec {src:?}: expected `family(key=value,...)`, \
                 e.g. mlp(depth=4,width=512) or cnn(depth=2,k=3,s=1,pad=1,ch=8-16)"
            )
        })?;
        ensure!(
            s.ends_with(')'),
            "model spec {src:?}: missing closing `)`"
        );
        let family = &s[..open];
        // family first: an unknown family must say so, not blame the
        // first key canon_key fails to recognize for it
        ensure!(
            family == "mlp" || family == "cnn" || family == "transformer",
            "model spec {src:?}: unknown model family {family:?} \
             (mlp|cnn|transformer)"
        );
        let body = &s[open + 1..s.len() - 1];
        let mut fields: BTreeMap<&'static str, &str> = BTreeMap::new();
        for part in body.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (k, v) = part.split_once('=').with_context(|| {
                format!("model spec {src:?}: expected key=value, got {part:?}")
            })?;
            let key = canon_key(family, k.trim())
                .with_context(|| format!("model spec {src:?}"))?;
            ensure!(
                fields.insert(key, v.trim()).is_none(),
                "model spec {src:?}: duplicate key {key:?}"
            );
        }
        match family {
            "mlp" => {
                let depth = field_usize(&fields, "depth", src)?.unwrap_or(2);
                let width =
                    field_usize(&fields, "width", src)?.unwrap_or(DEFAULT_MLP_WIDTH);
                ensure!(depth >= 1, "model spec {src:?}: depth must be >= 1");
                ensure!(width >= 1, "model spec {src:?}: width must be >= 1");
                Ok(ModelSpec::Mlp { depth, width })
            }
            "cnn" => {
                let k = field_usize(&fields, "k", src)?.unwrap_or(3);
                let s_ = field_usize(&fields, "s", src)?.unwrap_or(2);
                let pad = field_usize(&fields, "pad", src)?.unwrap_or(1);
                // a 1x1 mean pool is the identity — normalize to "none"
                // so the canonical form (which omits pool=0) round-trips
                let pool = match field_usize(&fields, "pool", src)?.unwrap_or(0)
                {
                    0 | 1 => 0,
                    p => p,
                };
                ensure!(k >= 1, "model spec {src:?}: kernel must be >= 1");
                ensure!(s_ >= 1, "model spec {src:?}: stride must be >= 1");
                let depth = field_usize(&fields, "depth", src)?;
                let ch = match fields.get("ch") {
                    Some(v) => {
                        let ch: Vec<usize> = v
                            .split('-')
                            .map(|c| {
                                c.trim().parse::<usize>().with_context(|| {
                                    format!(
                                        "model spec {src:?}: ch expects \
                                         dash-separated channel counts, got {v:?}"
                                    )
                                })
                            })
                            .collect::<Result<_>>()?;
                        if let Some(d) = depth {
                            ensure!(
                                ch.len() == d,
                                "model spec {src:?}: depth={d} but ch lists \
                                 {} channels",
                                ch.len()
                            );
                        }
                        ch
                    }
                    None => default_channels(depth.unwrap_or(2)),
                };
                ensure!(
                    !ch.is_empty() && ch.iter().all(|&c| c >= 1),
                    "model spec {src:?}: channel counts must be >= 1"
                );
                Ok(ModelSpec::Cnn { k, s: s_, pad, pool, ch })
            }
            "transformer" => {
                let heads = field_usize(&fields, "heads", src)?.unwrap_or(2);
                let d_model =
                    field_usize(&fields, "d_model", src)?.unwrap_or(32);
                let seq = field_usize(&fields, "seq", src)?.unwrap_or(64);
                let ff = field_usize(&fields, "ff", src)?.unwrap_or(64);
                ensure!(
                    heads >= 1 && d_model >= 1 && seq >= 1 && ff >= 1,
                    "model spec {src:?}: heads, d_model, seq and ff must \
                     all be >= 1"
                );
                ensure!(
                    d_model % heads == 0,
                    "model spec {src:?}: d_model={d_model} must be \
                     divisible by heads={heads}"
                );
                Ok(ModelSpec::Transformer { heads, d_model, seq, ff })
            }
            _ => unreachable!("family validated above"),
        }
    }

    /// Registry name of the model family this spec synthesizes
    /// (matches `ConfigSpec::model`).
    pub fn family(&self) -> &'static str {
        match self {
            ModelSpec::Mlp { .. } => "mlp",
            ModelSpec::Cnn { .. } => "cnn",
            ModelSpec::Transformer { .. } => "transformer",
        }
    }

    /// Number of parameterized layers before the classifier head
    /// counts itself: fc layers for mlp, conv layers for cnn,
    /// encoder blocks for transformer (one, today).
    pub fn depth(&self) -> usize {
        match self {
            ModelSpec::Mlp { depth, .. } => *depth,
            ModelSpec::Cnn { ch, .. } => ch.len(),
            ModelSpec::Transformer { .. } => 1,
        }
    }
}

impl fmt::Display for ModelSpec {
    /// The canonical form: every field explicit, fixed order.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ModelSpec::Mlp { depth, width } => {
                write!(f, "mlp(depth={depth},width={width})")
            }
            ModelSpec::Cnn { k, s, pad, pool, ch } => {
                let chs: Vec<String> =
                    ch.iter().map(|c| c.to_string()).collect();
                // pool is printed only when active so pre-pool spec
                // strings (and their bench/checkpoint keys) are stable
                let pool_part = if *pool >= 2 {
                    format!(",pool={pool}")
                } else {
                    String::new()
                };
                write!(
                    f,
                    "cnn(depth={},k={k},s={s},pad={pad}{pool_part},ch={})",
                    ch.len(),
                    chs.join("-")
                )
            }
            ModelSpec::Transformer { heads, d_model, seq, ff } => {
                write!(
                    f,
                    "transformer(heads={heads},d_model={d_model},\
                     seq={seq},ff={ff})"
                )
            }
        }
    }
}

/// Map a (possibly abbreviated) spec key to its canonical field name.
fn canon_key(family: &str, k: &str) -> Result<&'static str> {
    Ok(match (family, k) {
        ("mlp", "depth") | ("mlp", "d") => "depth",
        ("mlp", "width") | ("mlp", "w") => "width",
        ("cnn", "depth") | ("cnn", "d") => "depth",
        ("cnn", "k") | ("cnn", "kernel") => "k",
        ("cnn", "s") | ("cnn", "stride") => "s",
        ("cnn", "pad") | ("cnn", "p") => "pad",
        ("cnn", "pool") => "pool",
        ("cnn", "ch") | ("cnn", "channels") => "ch",
        ("transformer", "heads") | ("transformer", "h") => "heads",
        ("transformer", "d_model") | ("transformer", "dm") => "d_model",
        ("transformer", "seq") => "seq",
        ("transformer", "ff") => "ff",
        _ => bail!("unknown key {k:?} for a {family} spec"),
    })
}

fn field_usize(
    fields: &BTreeMap<&'static str, &str>,
    key: &'static str,
    src: &str,
) -> Result<Option<usize>> {
    match fields.get(key) {
        None => Ok(None),
        Some(v) => Ok(Some(v.parse::<usize>().with_context(|| {
            format!("model spec {src:?}: {key} expects an integer, got {v:?}")
        })?)),
    }
}

/// A full config reference in spec form: model x dataset x batch —
/// everything the builder needs, and (printed canonically) the stable
/// name synthesized configs carry through bench records, checkpoints,
/// and the CLI.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpecKey {
    pub model: ModelSpec,
    pub dataset: String,
    pub batch: usize,
}

impl SpecKey {
    pub fn new(model: ModelSpec, dataset: &str, batch: usize) -> SpecKey {
        SpecKey { model, dataset: dataset.to_string(), batch }
    }

    /// Parse `model(...)@dataset:bN`.
    pub fn parse(src: &str) -> Result<SpecKey> {
        let s = src.trim();
        let (model, rest) = s.rsplit_once('@').with_context(|| {
            format!(
                "config spec {src:?}: expected `model(...)@dataset:bN`, \
                 e.g. mlp(depth=4,width=512)@cifar10:b256"
            )
        })?;
        let (dataset, b) = rest.rsplit_once(":b").with_context(|| {
            format!("config spec {src:?}: expected `dataset:bN` after `@`")
        })?;
        let batch: usize = b.parse().with_context(|| {
            format!("config spec {src:?}: batch expects an integer, got {b:?}")
        })?;
        ensure!(batch >= 1, "config spec {src:?}: batch must be >= 1");
        ensure!(
            !dataset.is_empty()
                && dataset
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_'),
            "config spec {src:?}: bad dataset name {dataset:?}"
        );
        Ok(SpecKey {
            model: ModelSpec::parse(model)?,
            dataset: dataset.to_string(),
            batch,
        })
    }
}

impl fmt::Display for SpecKey {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}@{}:b{}", self.model, self.dataset, self.batch)
    }
}

/// Image-shaped f32 datasets the builder can synthesize configs for:
/// ([c, h, w], n_classes). Kept in sync with `data::synth::by_name`.
pub fn dataset_shape(name: &str) -> Result<(Vec<usize>, usize)> {
    Ok(match name {
        "mnist" | "fmnist" => (vec![1, 28, 28], 10),
        "cifar10" => (vec![3, 32, 32], 10),
        "lsun16" => (vec![3, 16, 16], 10),
        "lsun32" => (vec![3, 32, 32], 10),
        "lsun48" => (vec![3, 48, 48], 10),
        "lsun64" => (vec![3, 64, 64], 10),
        "imdb" => bail!(
            "dataset \"imdb\" stages i32 token features; the native model \
             families consume f32 images, so it cannot be synthesized from \
             a model spec"
        ),
        other => bail!(
            "unknown dataset {other:?} \
             (mnist|fmnist|cifar10|lsun16|lsun32|lsun48|lsun64)"
        ),
    })
}

/// Token-sequence i32 datasets the builder can synthesize
/// `transformer(...)` configs for: (seq, vocab, n_classes). Kept in
/// sync with `data::synth::by_name` (pinned by
/// `dataset_table_matches_the_synth_generators`).
pub fn token_dataset_shape(name: &str) -> Result<(usize, usize, usize)> {
    Ok(match name {
        "imdb" => (64, 5000, 2),
        other => bail!("unknown token dataset {other:?} (imdb)"),
    })
}

fn artifact(method: &str, config: &str) -> (String, ArtifactSpec) {
    let (extra, outputs): (&[&str], &[&str]) = match method {
        "nonprivate" => (&[], &["grads", "loss"]),
        "reweight" | "reweight_gram" | "reweight_direct" | "reweight_pallas"
        | "multiloss" => (&["clip"], &["grads", "loss", "norms"]),
        "naive1" => (&[], &["grads", "loss", "norm"]),
        "fwd" => (&[], &["loss", "correct"]),
        _ => (&[], &[]),
    };
    (
        method.to_string(),
        ArtifactSpec {
            method: method.to_string(),
            file: format!("native:{config}.{method}"),
            extra_args: extra.iter().map(|s| s.to_string()).collect(),
            outputs: outputs.iter().map(|s| s.to_string()).collect(),
        },
    )
}

/// The full batched method family every synthesized config carries
/// (plus `naive1` on batch-1 configs — the nxBP loop body).
pub fn standard_artifacts(
    name: &str,
    batch: usize,
) -> BTreeMap<String, ArtifactSpec> {
    let mut artifacts = BTreeMap::new();
    for m in [
        "nonprivate",
        "reweight",
        "reweight_gram",
        "reweight_direct",
        "reweight_pallas",
        "multiloss",
        "fwd",
    ] {
        let (k, v) = artifact(m, name);
        artifacts.insert(k, v);
    }
    if batch == 1 {
        let (k, v) = artifact("naive1", name);
        artifacts.insert(k, v);
    }
    artifacts
}

/// Synthesize a full `ConfigSpec` — param shapes, activation elements,
/// conv meta, the standard artifact set, and the canonical name — from
/// a `ModelSpec` x dataset x batch. This is the open replacement for
/// the closed builtin grid; the grid itself is now a preset layer that
/// calls this builder under its stable short names (`named`).
pub struct ConfigBuilder {
    model: ModelSpec,
    dataset: String,
    batch: usize,
    name: Option<String>,
}

impl ConfigBuilder {
    pub fn new(model: ModelSpec, dataset: &str, batch: usize) -> ConfigBuilder {
        ConfigBuilder {
            model,
            dataset: dataset.to_string(),
            batch,
            name: None,
        }
    }

    pub fn from_key(key: SpecKey) -> ConfigBuilder {
        ConfigBuilder {
            model: key.model,
            dataset: key.dataset,
            batch: key.batch,
            name: None,
        }
    }

    /// Override the canonical printed name (the builtin grid's preset
    /// layer names its configs `mlp2_mnist_b32`-style).
    pub fn named(mut self, name: &str) -> ConfigBuilder {
        self.name = Some(name.to_string());
        self
    }

    fn key(&self) -> SpecKey {
        SpecKey {
            model: self.model.clone(),
            dataset: self.dataset.clone(),
            batch: self.batch,
        }
    }

    pub fn build(&self) -> Result<ConfigSpec> {
        let key = self.key();
        ensure!(self.batch >= 1, "config spec {key}: batch must be >= 1");
        let name = self.name.clone().unwrap_or_else(|| key.to_string());
        // Mirror the parse-time invariants: `ModelSpec`'s fields and
        // `ConfigBuilder::new` are pub, so a programmatically built
        // spec can bypass `ModelSpec::parse` — without these, s=0
        // would reach `conv_out`'s division and depth=0 would
        // underflow the act_elems arithmetic instead of erroring.
        // Each arm resolves its own dataset table (image families read
        // `dataset_shape`, the transformer reads `token_dataset_shape`)
        // and yields (params, act_elems, conv, per-example feature
        // shape, n_classes).
        let (params, act_elems, conv, feat_shape, n_classes) = match &self.model
        {
            ModelSpec::Mlp { depth, width } => {
                let (img_shape, n_classes) = dataset_shape(&self.dataset)
                    .with_context(|| {
                        format!("building config for spec {key}")
                    })?;
                ensure!(
                    *depth >= 1 && *width >= 1,
                    "config spec {key}: depth and width must be >= 1"
                );
                let d_in: usize = img_shape.iter().product();
                let mut params = Vec::with_capacity(depth * 2);
                let mut prev = d_in;
                for l in 0..*depth {
                    let out = if l == depth - 1 { n_classes } else { *width };
                    params.push(ParamSpec {
                        name: format!("fc{l}.w"),
                        shape: vec![prev, out],
                    });
                    params.push(ParamSpec {
                        name: format!("fc{l}.b"),
                        shape: vec![out],
                    });
                    prev = out;
                }
                (
                    params,
                    (depth - 1) * width + n_classes,
                    None,
                    img_shape,
                    n_classes,
                )
            }
            ModelSpec::Cnn { k, s, pad, pool, ch } => {
                let (img_shape, n_classes) = dataset_shape(&self.dataset)
                    .with_context(|| {
                        format!("building config for spec {key}")
                    })?;
                ensure!(
                    *k >= 1 && *s >= 1,
                    "config spec {key}: kernel and stride must be >= 1"
                );
                ensure!(
                    !ch.is_empty() && ch.iter().all(|&c| c >= 1),
                    "config spec {key}: channel counts must be >= 1"
                );
                ensure!(
                    *pool != 1,
                    "config spec {key}: pool=1 is the identity — use 0 \
                     (ModelSpec::parse normalizes this)"
                );
                let meta = ConvMeta {
                    kernel: *k,
                    stride: *s,
                    pad: *pad,
                    pool: *pool,
                };
                let (mut cin, mut h, mut w) =
                    (img_shape[0], img_shape[1], img_shape[2]);
                let mut params = Vec::with_capacity(ch.len() * 2 + 2);
                let mut act_elems = 0usize;
                for (l, &cout) in ch.iter().enumerate() {
                    let (k0, p0) = (meta.kernel, meta.pad);
                    ensure!(
                        h + 2 * p0 >= k0 && w + 2 * p0 >= k0,
                        "config spec {key}: conv layer {l}'s {k0}x{k0} kernel \
                         does not fit the {h}x{w} map at pad {p0} — reduce \
                         depth/kernel or increase pad"
                    );
                    params.push(ParamSpec {
                        name: format!("conv{l}.w"),
                        shape: vec![cout, cin, meta.kernel, meta.kernel],
                    });
                    params.push(ParamSpec {
                        name: format!("conv{l}.b"),
                        shape: vec![cout],
                    });
                    h = super::native::gemm::conv_out(
                        h,
                        meta.kernel,
                        meta.stride,
                        meta.pad,
                    );
                    w = super::native::gemm::conv_out(
                        w,
                        meta.kernel,
                        meta.stride,
                        meta.pad,
                    );
                    ensure!(
                        h >= 1 && w >= 1,
                        "config spec {key}: the spatial map collapsed to \
                         {h}x{w} after conv layer {l}"
                    );
                    act_elems += h * w * cout;
                    // a pool stage stores its own (smaller) map — it is
                    // a chain layer with activations but no params
                    if meta.pool >= 2 {
                        ensure!(
                            h >= meta.pool && w >= meta.pool,
                            "config spec {key}: the {}x{} pool window does \
                             not fit the {h}x{w} map after conv layer {l}",
                            meta.pool,
                            meta.pool
                        );
                        h /= meta.pool;
                        w /= meta.pool;
                        act_elems += h * w * cout;
                    }
                    cin = cout;
                }
                let flat = cin * h * w;
                params.push(ParamSpec {
                    name: "fc.w".into(),
                    shape: vec![flat, n_classes],
                });
                params.push(ParamSpec {
                    name: "fc.b".into(),
                    shape: vec![n_classes],
                });
                act_elems += n_classes;
                (params, act_elems, Some(meta), img_shape, n_classes)
            }
            ModelSpec::Transformer { heads, d_model, seq, ff } => {
                let (dseq, vocab, n_classes) =
                    token_dataset_shape(&self.dataset).with_context(|| {
                        format!("building config for spec {key}")
                    })?;
                ensure!(
                    *heads >= 1 && *d_model >= 1 && *ff >= 1,
                    "config spec {key}: heads, d_model and ff must be >= 1"
                );
                ensure!(
                    *d_model % *heads == 0,
                    "config spec {key}: d_model={d_model} must be \
                     divisible by heads={heads}"
                );
                ensure!(
                    *seq == dseq,
                    "config spec {key}: spec seq={seq} but dataset {} \
                     stages sequences of length {dseq}",
                    self.dataset
                );
                let d = *d_model;
                let mut params = Vec::with_capacity(16);
                params.push(ParamSpec {
                    name: "embed.w".into(),
                    shape: vec![vocab, d],
                });
                params.push(ParamSpec {
                    name: "embed.b".into(),
                    shape: vec![d],
                });
                for proj in ["q", "k", "v", "o"] {
                    params.push(ParamSpec {
                        name: format!("attn.{proj}.w"),
                        shape: vec![d, d],
                    });
                    params.push(ParamSpec {
                        name: format!("attn.{proj}.b"),
                        shape: vec![d],
                    });
                }
                params.push(ParamSpec {
                    name: "ff1.w".into(),
                    shape: vec![d, *ff],
                });
                params.push(ParamSpec { name: "ff1.b".into(), shape: vec![*ff] });
                params.push(ParamSpec {
                    name: "ff2.w".into(),
                    shape: vec![*ff, d],
                });
                params.push(ParamSpec { name: "ff2.b".into(), shape: vec![d] });
                params.push(ParamSpec {
                    name: "head.w".into(),
                    shape: vec![d, n_classes],
                });
                params.push(ParamSpec {
                    name: "head.b".into(),
                    shape: vec![n_classes],
                });
                // T x d maps: x0, q, k, v, ctx, x1, dx-side reuse of the
                // same chain; T x ff: z1, f1; per-head T x T attention;
                // pooled vector + logits
                let act = 8 * dseq * d
                    + 2 * dseq * *ff
                    + heads * dseq * dseq
                    + d
                    + n_classes;
                (params, act, None, vec![dseq], n_classes)
            }
        };
        let mut tags: Vec<String> = Vec::new();
        if self.batch == 1 {
            tags.push("naive".into());
        }
        let mut input_shape = vec![self.batch];
        input_shape.extend_from_slice(&feat_shape);
        Ok(ConfigSpec {
            name: name.clone(),
            model: self.model.family().to_string(),
            dataset: self.dataset.clone(),
            batch: self.batch,
            n_classes,
            tags,
            input_shape,
            input_dtype: "f32".into(),
            act_elems_per_example: act_elems,
            conv,
            spec: Some(self.model.clone()),
            params,
            artifacts: standard_artifacts(&name, self.batch),
        })
    }
}

impl ConfigSpec {
    /// Rebuild this config at a different batch size — *structurally*,
    /// through the spec provenance and `ConfigBuilder`, never by name
    /// surgery. The sibling carries the canonical spec name (a preset
    /// short name is not propagated) and, at batch 1, the `naive1`
    /// artifact the nxBP loop needs. Manifest-loaded configs without
    /// provenance cannot be rebuilt; `Backend::naive_sibling` falls
    /// back to the manifest's `_b` naming convention for those.
    pub fn with_batch(&self, batch: usize) -> Result<ConfigSpec> {
        let model = self.spec.clone().with_context(|| {
            format!(
                "config {} carries no model spec provenance \
                 (manifest-loaded) — cannot derive a batch-{batch} sibling \
                 structurally",
                self.name
            )
        })?;
        ConfigBuilder::new(model, &self.dataset, batch).build()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_print_roundtrip_canonical() {
        for src in [
            "mlp(depth=4,width=512)",
            "mlp(depth=1,width=7)",
            "cnn(depth=2,k=3,s=1,pad=1,ch=8-16)",
            "cnn(depth=3,k=5,s=2,pad=2,ch=4-4-12)",
            "cnn(depth=2,k=3,s=1,pad=1,pool=2,ch=8-16)",
            "transformer(heads=2,d_model=32,seq=64,ff=64)",
            "transformer(heads=4,d_model=16,seq=32,ff=48)",
        ] {
            let spec = ModelSpec::parse(src).unwrap();
            assert_eq!(spec.to_string(), src);
            assert_eq!(ModelSpec::parse(&spec.to_string()).unwrap(), spec);
        }
    }

    #[test]
    fn parse_accepts_aliases_order_whitespace_and_defaults() {
        let a = ModelSpec::parse("mlp(w=64, d=3)").unwrap();
        assert_eq!(a, ModelSpec::Mlp { depth: 3, width: 64 });
        let b = ModelSpec::parse("mlp()").unwrap();
        assert_eq!(b, ModelSpec::Mlp { depth: 2, width: DEFAULT_MLP_WIDTH });
        let c = ModelSpec::parse(" cnn( stride=1 , kernel=3 ) ").unwrap();
        assert_eq!(
            c,
            ModelSpec::Cnn { k: 3, s: 1, pad: 1, pool: 0, ch: vec![8, 16] }
        );
        // depth alone pulls the default channel progression (and
        // extends it past the table by repeating the last entry)
        let d = ModelSpec::parse("cnn(depth=5,p=0)").unwrap();
        assert_eq!(
            d,
            ModelSpec::Cnn {
                k: 3,
                s: 2,
                pad: 0,
                pool: 0,
                ch: vec![8, 16, 32, 32, 32]
            }
        );
        // pool=1 is the identity and normalizes to "no pool", so the
        // canonical form (which omits it) still round-trips
        let p = ModelSpec::parse("cnn(pool=1)").unwrap();
        assert!(matches!(p, ModelSpec::Cnn { pool: 0, .. }));
        assert!(!p.to_string().contains("pool"));
        // redundant-but-consistent depth+ch is fine
        let e = ModelSpec::parse("cnn(depth=2,ch=8-16)").unwrap();
        assert_eq!(e.depth(), 2);
        // transformer aliases + grid defaults (heads=2, d_model=32,
        // seq=64, ff=64)
        let t = ModelSpec::parse("transformer(dm=16, h=4)").unwrap();
        assert_eq!(
            t,
            ModelSpec::Transformer { heads: 4, d_model: 16, seq: 64, ff: 64 }
        );
        let t = ModelSpec::parse("transformer()").unwrap();
        assert_eq!(
            t,
            ModelSpec::Transformer { heads: 2, d_model: 32, seq: 64, ff: 64 }
        );
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "mlp",                       // no parens
            "mlp(depth=4",               // unclosed
            "mlp(depth)",                // no value
            "mlp(depth=4,depth=6)",      // duplicate key
            "mlp(depth=x)",              // bad int
            "mlp(depth=0)",              // zero depth
            "mlp(k=3)",                  // cnn key on mlp
            "rnn(depth=2)",              // unknown family
            "cnn(depth=3,ch=8-16)",      // depth/ch disagree
            "cnn(ch=8-0)",               // zero channels
            "cnn(s=0)",                  // zero stride
            "transformer(heads=3,d_model=32)", // heads do not divide d_model
            "transformer(heads=0)",      // zero heads
            "transformer(k=3)",          // cnn key on transformer
        ] {
            assert!(ModelSpec::parse(bad).is_err(), "{bad:?} parsed");
        }
        // an unknown family names the family — it does not blame the
        // first key (`canon_key` would otherwise see it first)
        let err = ModelSpec::parse("resnet(depth=18)").unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("unknown model family") && msg.contains("resnet"),
            "{msg}"
        );
    }

    /// Property: a randomly generated spec survives print -> parse
    /// exactly (the canonical form is a faithful key).
    #[test]
    fn prop_spec_roundtrip() {
        use crate::testkit::prop;
        prop::check(200, |g| {
            let spec = match g.usize_incl(0..=2) {
                0 => ModelSpec::Mlp {
                    depth: g.usize_incl(1..=12),
                    width: g.usize_incl(1..=2048),
                },
                1 => {
                    let depth = g.usize_incl(1..=5);
                    ModelSpec::Cnn {
                        k: g.usize_incl(1..=7),
                        s: g.usize_incl(1..=3),
                        pad: g.usize_incl(0..=3),
                        pool: if g.bool() { 0 } else { g.usize_incl(2..=4) },
                        ch: (0..depth).map(|_| g.usize_incl(1..=64)).collect(),
                    }
                }
                _ => {
                    let heads = g.usize_incl(1..=4);
                    ModelSpec::Transformer {
                        heads,
                        d_model: heads * g.usize_incl(1..=16),
                        seq: g.usize_incl(1..=128),
                        ff: g.usize_incl(1..=128),
                    }
                }
            };
            let printed = spec.to_string();
            let back = ModelSpec::parse(&printed)
                .map_err(|e| format!("{printed}: {e:#}"))?;
            if back != spec {
                return Err(format!("{printed} reparsed as {back:?}"));
            }
            // ...and the full key round-trips too
            let key = SpecKey::new(spec, "cifar10", g.usize_incl(1..=512));
            let back = SpecKey::parse(&key.to_string())
                .map_err(|e| format!("{key}: {e:#}"))?;
            if back != key {
                return Err(format!("{key} reparsed as {back:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn spec_key_parse_and_errors() {
        let k = SpecKey::parse("mlp(depth=4,width=512)@cifar10:b256").unwrap();
        assert_eq!(k.dataset, "cifar10");
        assert_eq!(k.batch, 256);
        assert_eq!(k.to_string(), "mlp(depth=4,width=512)@cifar10:b256");
        for bad in [
            "mlp(depth=4,width=512)",            // no @dataset
            "mlp(depth=4)@cifar10",              // no :bN
            "mlp(depth=4)@cifar10:b0",           // zero batch
            "mlp(depth=4)@cifar10:bxyz",         // bad batch
            "mlp(depth=4)@ci far:b8",            // bad dataset
            "mlp2_mnist_b32",                    // grid preset names are not specs
        ] {
            assert!(SpecKey::parse(bad).is_err(), "{bad:?} parsed");
        }
    }

    #[test]
    fn builder_synthesizes_off_grid_mlp() {
        let cfg = ConfigBuilder::from_key(
            SpecKey::parse("mlp(depth=4,width=512)@cifar10:b256").unwrap(),
        )
        .build()
        .unwrap();
        assert_eq!(cfg.name, "mlp(depth=4,width=512)@cifar10:b256");
        assert_eq!(cfg.model, "mlp");
        assert_eq!(cfg.batch, 256);
        assert_eq!(cfg.input_shape, vec![256, 3, 32, 32]);
        // 3072 -> 512 -> 512 -> 512 -> 10
        assert_eq!(cfg.params.len(), 8);
        assert_eq!(cfg.params[0].shape, vec![3072, 512]);
        assert_eq!(cfg.params[2].shape, vec![512, 512]);
        assert_eq!(cfg.params[6].shape, vec![512, 10]);
        assert_eq!(cfg.params[7].shape, vec![10]);
        assert_eq!(cfg.act_elems_per_example, 3 * 512 + 10);
        assert_eq!(cfg.conv, None);
        assert_eq!(
            cfg.spec,
            Some(ModelSpec::Mlp { depth: 4, width: 512 })
        );
        // the standard batched artifact set, no naive1 above batch 1
        for m in ["reweight", "reweight_direct", "multiloss", "fwd"] {
            assert!(cfg.artifacts.contains_key(m), "{m}");
        }
        assert!(!cfg.artifacts.contains_key("naive1"));
    }

    #[test]
    fn builder_synthesizes_stride1_cnn() {
        let cfg = ConfigBuilder::from_key(
            SpecKey::parse("cnn(depth=2,k=3,s=1,pad=1,ch=8-16)@mnist:b48")
                .unwrap(),
        )
        .build()
        .unwrap();
        // stride-1 pad-1 3x3 preserves the 28x28 map
        assert_eq!(cfg.params[0].shape, vec![8, 1, 3, 3]);
        assert_eq!(cfg.params[2].shape, vec![16, 8, 3, 3]);
        assert_eq!(cfg.params[4].shape, vec![28 * 28 * 16, 10]);
        assert_eq!(
            cfg.act_elems_per_example,
            28 * 28 * 8 + 28 * 28 * 16 + 10
        );
        assert_eq!(
            cfg.conv,
            Some(ConvMeta { kernel: 3, stride: 1, pad: 1, pool: 0 })
        );
        assert_eq!(cfg.batch, 48);
    }

    /// A pooled spec synthesizes pool stages into the spatial chain and
    /// the activation budget: each pool is a parameterless chain layer
    /// whose (smaller) output map is stored alongside the conv maps.
    #[test]
    fn builder_synthesizes_pooled_cnn() {
        let key = "cnn(depth=1,k=3,s=1,pad=1,pool=2,ch=4)@mnist:b8";
        let cfg = ConfigBuilder::from_key(SpecKey::parse(key).unwrap())
            .build()
            .unwrap();
        assert_eq!(cfg.name, key);
        // conv keeps 28x28, pool halves it to 14x14, fc sees the
        // pooled map
        assert_eq!(cfg.params[0].shape, vec![4, 1, 3, 3]);
        assert_eq!(cfg.params[2].shape, vec![14 * 14 * 4, 10]);
        assert_eq!(
            cfg.act_elems_per_example,
            28 * 28 * 4 + 14 * 14 * 4 + 10
        );
        assert_eq!(
            cfg.conv,
            Some(ConvMeta { kernel: 3, stride: 1, pad: 1, pool: 2 })
        );
        // a pool window larger than the map is rejected
        let err = ConfigBuilder::from_key(
            SpecKey::parse("cnn(depth=1,k=3,s=2,pad=0,pool=16,ch=4)@mnist:b4")
                .unwrap(),
        )
        .build()
        .unwrap_err();
        assert!(format!("{err:#}").contains("pool window"), "{err:#}");
    }

    /// The transformer arm resolves the token dataset table and
    /// synthesizes the full 16-tensor (embed, q/k/v/o, ff1/ff2, head)
    /// param chain with input shape [batch, seq].
    #[test]
    fn builder_synthesizes_transformer() {
        let key = "transformer(heads=2,d_model=32,seq=64,ff=64)@imdb:b16";
        let cfg = ConfigBuilder::from_key(SpecKey::parse(key).unwrap())
            .build()
            .unwrap();
        assert_eq!(cfg.name, key);
        assert_eq!(cfg.model, "transformer");
        assert_eq!(cfg.batch, 16);
        assert_eq!(cfg.n_classes, 2);
        assert_eq!(cfg.input_shape, vec![16, 64]);
        // token ids are staged widened to f32 (the native staging seam)
        assert_eq!(cfg.input_dtype, "f32");
        assert_eq!(cfg.conv, None);
        assert_eq!(cfg.params.len(), 16);
        assert_eq!(cfg.params[0].shape, vec![5000, 32]); // embed.w
        assert_eq!(cfg.params[0].name, "embed.w");
        assert_eq!(cfg.params[2].shape, vec![32, 32]); // attn.q.w
        assert_eq!(cfg.params[8].name, "attn.o.w");
        assert_eq!(cfg.params[10].shape, vec![32, 64]); // ff1.w
        assert_eq!(cfg.params[12].shape, vec![64, 32]); // ff2.w
        assert_eq!(cfg.params[14].shape, vec![32, 2]); // head.w
        assert_eq!(cfg.params[15].shape, vec![2]);
        assert_eq!(
            cfg.act_elems_per_example,
            8 * 64 * 32 + 2 * 64 * 64 + 2 * 64 * 64 + 32 + 2
        );
        assert_eq!(
            cfg.spec,
            Some(ModelSpec::Transformer {
                heads: 2,
                d_model: 32,
                seq: 64,
                ff: 64
            })
        );
        for m in ["reweight", "reweight_gram", "multiloss", "fwd"] {
            assert!(cfg.artifacts.contains_key(m), "{m}");
        }
        // structural batch-1 sibling carries naive1 for the nxBP oracle
        let sib = cfg.with_batch(1).unwrap();
        assert_eq!(sib.input_shape, vec![1, 64]);
        assert!(sib.artifacts.contains_key("naive1"));
        // transformer on an image dataset is a token-table error
        let err = ConfigBuilder::from_key(
            SpecKey::parse("transformer(heads=2,d_model=16)@mnist:b4")
                .unwrap(),
        )
        .build()
        .unwrap_err();
        assert!(format!("{err:#}").contains("token dataset"), "{err:#}");
        // spec/dataset sequence length mismatch is rejected
        let err = ConfigBuilder::from_key(
            SpecKey::parse("transformer(heads=2,d_model=16,seq=32)@imdb:b4")
                .unwrap(),
        )
        .build()
        .unwrap_err();
        assert!(format!("{err:#}").contains("seq"), "{err:#}");
    }

    /// The batch-1 sibling is derived structurally: same shapes, batch
    /// 1, and the `naive1` artifact the nxBP loop needs.
    #[test]
    fn with_batch_derives_naive_sibling() {
        let cfg = ConfigBuilder::from_key(
            SpecKey::parse("mlp(depth=3,width=96)@mnist:b24").unwrap(),
        )
        .build()
        .unwrap();
        let sib = cfg.with_batch(1).unwrap();
        assert_eq!(sib.batch, 1);
        assert_eq!(sib.input_shape[0], 1);
        assert_eq!(sib.params.len(), cfg.params.len());
        for (a, b) in sib.params.iter().zip(&cfg.params) {
            assert_eq!(a.shape, b.shape);
        }
        assert_eq!(sib.act_elems_per_example, cfg.act_elems_per_example);
        assert!(sib.artifacts.contains_key("naive1"));
        assert!(sib.has_tag("naive"));
        // no provenance -> no structural sibling
        let mut bare = cfg.clone();
        bare.spec = None;
        let err = bare.with_batch(1).unwrap_err();
        assert!(format!("{err:#}").contains("provenance"));
    }

    #[test]
    fn builder_rejects_unsynthesizable_keys() {
        // unknown dataset
        let err = ConfigBuilder::new(
            ModelSpec::Mlp { depth: 2, width: 8 },
            "nope",
            4,
        )
        .build()
        .unwrap_err();
        assert!(format!("{err:#}").contains("unknown dataset"));
        // token dataset
        let err = ConfigBuilder::new(
            ModelSpec::Mlp { depth: 2, width: 8 },
            "imdb",
            4,
        )
        .build()
        .unwrap_err();
        assert!(format!("{err:#}").contains("imdb"));
        // kernel outgrows the shrinking map
        let err = ConfigBuilder::new(
            ModelSpec::Cnn { k: 5, s: 2, pad: 0, pool: 0, ch: vec![4, 4, 4] },
            "mnist",
            4,
        )
        .build()
        .unwrap_err();
        assert!(format!("{err:#}").contains("does not fit"));
        // programmatically built specs bypass parse: build() must
        // still reject degenerate geometry (a release-mode s=0 would
        // otherwise divide by zero inside conv_out)
        let err = ConfigBuilder::new(
            ModelSpec::Cnn { k: 3, s: 0, pad: 1, pool: 0, ch: vec![8] },
            "mnist",
            4,
        )
        .build()
        .unwrap_err();
        assert!(format!("{err:#}").contains("stride"), "{err:#}");
        let err = ConfigBuilder::new(
            ModelSpec::Mlp { depth: 0, width: 8 },
            "mnist",
            4,
        )
        .build()
        .unwrap_err();
        assert!(format!("{err:#}").contains("depth"), "{err:#}");
    }

    /// `dataset_shape` must stay in lock-step with the synthetic
    /// generators in `data::synth::by_name` — this pins the two tables
    /// together so a shape/class change in one cannot silently drift
    /// from the other (the builder would synthesize params for a stale
    /// shape while the staged data had the new one).
    #[test]
    fn dataset_table_matches_the_synth_generators() {
        for name in
            ["mnist", "fmnist", "cifar10", "lsun16", "lsun32", "lsun48", "lsun64"]
        {
            let (shape, n_classes) = dataset_shape(name).unwrap();
            let ds = crate::data::synth::by_name(name, 4, 0).unwrap();
            assert_eq!(ds.shape, shape, "{name}");
            assert_eq!(ds.n_classes, n_classes, "{name}");
        }
        // imdb is not an *image* dataset (and unknown names stay errors)
        assert!(dataset_shape("imdb").is_err());
        assert!(dataset_shape("nope").is_err());
        // ...but it is the token table's one entry, pinned to the synth
        // generator the same way: seq/class drift in either table would
        // desync the builder's param shapes from the staged data
        let (seq, vocab, n_classes) = token_dataset_shape("imdb").unwrap();
        let ds = crate::data::synth::by_name("imdb", 16, 0).unwrap();
        assert_eq!(ds.shape, vec![seq], "imdb seq");
        assert_eq!(ds.n_classes, n_classes, "imdb classes");
        // the generator's token ids stay inside the embed table the
        // builder sizes from `vocab`
        match &ds.features {
            crate::data::Features::I32(v) => {
                assert!(v.iter().all(|&t| t >= 0 && (t as usize) < vocab));
            }
            _ => panic!("imdb must stage i32 token ids"),
        }
        assert!(token_dataset_shape("mnist").is_err());
    }

    /// Synthesized configs pass the same structural validation the
    /// model families apply at load time — the builder and the family
    /// parsers can never disagree about what a spec means.
    #[test]
    fn synthesized_configs_satisfy_family_parsers() {
        use crate::runtime::native::taps::{FamilyRegistry, ModelFamily as _};
        let reg = FamilyRegistry::builtin();
        for key in [
            "mlp(depth=4,width=512)@cifar10:b256",
            "mlp(depth=1,width=32)@mnist:b4",
            "cnn(depth=2,k=3,s=1,pad=1,ch=8-16)@mnist:b48",
            "cnn(depth=3,k=5,s=2,pad=2,ch=4-8-8)@lsun32:b16",
            "cnn(depth=2,k=3,s=1,pad=1,pool=2,ch=4-8)@mnist:b8",
            "transformer(heads=2,d_model=32,seq=64,ff=64)@imdb:b16",
            "transformer(heads=4,d_model=16,seq=64,ff=24)@imdb:b4",
        ] {
            let cfg = ConfigBuilder::from_key(SpecKey::parse(key).unwrap())
                .build()
                .unwrap_or_else(|e| panic!("{key}: {e:#}"));
            let fam = reg
                .build(&cfg)
                .unwrap_or_else(|e| panic!("{key}: {e:#}"));
            assert_eq!(fam.batch(), cfg.batch, "{key}");
            let lens = fam.grad_layout();
            assert_eq!(lens.len(), cfg.params.len(), "{key}");
            for (l, p) in lens.iter().zip(&cfg.params) {
                assert_eq!(*l, p.elems(), "{key}.{}", p.name);
            }
        }
    }
}
