//! ChaCha20 stream cipher used as a CSPRNG (RFC 8439).
//!
//! Differential privacy's guarantees are only as good as the noise
//! source: a predictable PRNG voids the Gaussian mechanism, so the
//! coordinator draws all privacy noise from ChaCha20 keystream rather
//! than a statistical generator. (The offline crate set has no `rand`;
//! this is a from-scratch implementation validated against the RFC
//! test vectors.)

/// ChaCha20 block function state.
pub struct ChaCha20 {
    key: [u32; 8],
    nonce: [u32; 3],
    counter: u32,
    buf: [u8; 64],
    buf_used: usize,
}

const CONSTANTS: [u32; 4] = [0x61707865, 0x3320646e, 0x79622d32, 0x6b206574];

#[inline(always)]
fn quarter_round(s: &mut [u32; 16], a: usize, b: usize, c: usize, d: usize) {
    s[a] = s[a].wrapping_add(s[b]);
    s[d] = (s[d] ^ s[a]).rotate_left(16);
    s[c] = s[c].wrapping_add(s[d]);
    s[b] = (s[b] ^ s[c]).rotate_left(12);
    s[a] = s[a].wrapping_add(s[b]);
    s[d] = (s[d] ^ s[a]).rotate_left(8);
    s[c] = s[c].wrapping_add(s[d]);
    s[b] = (s[b] ^ s[c]).rotate_left(7);
}

impl ChaCha20 {
    /// Construct from a 256-bit key and 96-bit nonce.
    pub fn new(key: &[u8; 32], nonce: &[u8; 12]) -> Self {
        let mut k = [0u32; 8];
        for i in 0..8 {
            k[i] = u32::from_le_bytes(key[4 * i..4 * i + 4].try_into().unwrap());
        }
        let mut n = [0u32; 3];
        for i in 0..3 {
            n[i] = u32::from_le_bytes(nonce[4 * i..4 * i + 4].try_into().unwrap());
        }
        ChaCha20 { key: k, nonce: n, counter: 0, buf: [0; 64], buf_used: 64 }
    }

    /// Convenience seeding for reproducible experiment streams: the
    /// seed fills the key; the stream id fills the nonce. Distinct
    /// (seed, stream) pairs yield independent keystreams.
    pub fn seeded(seed: u64, stream: u64) -> Self {
        let mut key = [0u8; 32];
        key[..8].copy_from_slice(&seed.to_le_bytes());
        key[8..16].copy_from_slice(&seed.wrapping_mul(0x9E3779B97F4A7C15).to_le_bytes());
        key[16..24].copy_from_slice(&(!seed).to_le_bytes());
        key[24..32].copy_from_slice(&seed.rotate_left(32).to_le_bytes());
        let mut nonce = [0u8; 12];
        nonce[..8].copy_from_slice(&stream.to_le_bytes());
        ChaCha20::new(&key, &nonce)
    }

    /// Raw 20-round block function at the given counter.
    pub fn block(&self, counter: u32) -> [u8; 64] {
        let mut s = [0u32; 16];
        s[..4].copy_from_slice(&CONSTANTS);
        s[4..12].copy_from_slice(&self.key);
        s[12] = counter;
        s[13..16].copy_from_slice(&self.nonce);
        let init = s;
        for _ in 0..10 {
            // column rounds
            quarter_round(&mut s, 0, 4, 8, 12);
            quarter_round(&mut s, 1, 5, 9, 13);
            quarter_round(&mut s, 2, 6, 10, 14);
            quarter_round(&mut s, 3, 7, 11, 15);
            // diagonal rounds
            quarter_round(&mut s, 0, 5, 10, 15);
            quarter_round(&mut s, 1, 6, 11, 12);
            quarter_round(&mut s, 2, 7, 8, 13);
            quarter_round(&mut s, 3, 4, 9, 14);
        }
        let mut out = [0u8; 64];
        for i in 0..16 {
            let w = s[i].wrapping_add(init[i]);
            out[4 * i..4 * i + 4].copy_from_slice(&w.to_le_bytes());
        }
        out
    }

    fn refill(&mut self) {
        self.buf = self.block(self.counter);
        self.counter = self.counter.wrapping_add(1);
        self.buf_used = 0;
    }

    /// Fill `dst` with keystream bytes.
    pub fn fill_bytes(&mut self, dst: &mut [u8]) {
        let mut i = 0;
        while i < dst.len() {
            if self.buf_used == 64 {
                self.refill();
            }
            let n = (dst.len() - i).min(64 - self.buf_used);
            dst[i..i + n].copy_from_slice(&self.buf[self.buf_used..self.buf_used + n]);
            self.buf_used += n;
            i += n;
        }
    }

    pub fn next_u32(&mut self) -> u32 {
        let mut b = [0u8; 4];
        self.fill_bytes(&mut b);
        u32::from_le_bytes(b)
    }

    /// Fast path: serve u64s directly from the keystream buffer.
    /// Calls are always 8-byte aligned in practice (buf starts empty
    /// and refills at 64), so this produces the same stream as
    /// fill_bytes would — just without the per-call memcpy (§Perf L3:
    /// this sits under every Gaussian draw in the DP noise step).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        if self.buf_used + 8 > 64 {
            self.refill();
        }
        let v = u64::from_le_bytes(
            self.buf[self.buf_used..self.buf_used + 8].try_into().unwrap(),
        );
        self.buf_used += 8;
        v
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, bound) via Lemire-style rejection
    /// sampling (no modulo bias).
    pub fn next_bounded(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        let zone = u64::MAX - (u64::MAX % bound);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % bound;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// RFC 8439 §2.3.2 test vector: key 00..1f, nonce
    /// 00:00:00:09:00:00:00:4a:00:00:00:00, counter 1.
    #[test]
    fn rfc8439_block_vector() {
        let mut key = [0u8; 32];
        for (i, k) in key.iter_mut().enumerate() {
            *k = i as u8;
        }
        let nonce = [0, 0, 0, 9, 0, 0, 0, 0x4a, 0, 0, 0, 0];
        let c = ChaCha20::new(&key, &nonce);
        let block = c.block(1);
        let expected: [u8; 64] = [
            0x10, 0xf1, 0xe7, 0xe4, 0xd1, 0x3b, 0x59, 0x15, 0x50, 0x0f, 0xdd,
            0x1f, 0xa3, 0x20, 0x71, 0xc4, 0xc7, 0xd1, 0xf4, 0xc7, 0x33, 0xc0,
            0x68, 0x03, 0x04, 0x22, 0xaa, 0x9a, 0xc3, 0xd4, 0x6c, 0x4e, 0xd2,
            0x82, 0x64, 0x46, 0x07, 0x9f, 0xaa, 0x09, 0x14, 0xc2, 0xd7, 0x05,
            0xd9, 0x8b, 0x02, 0xa2, 0xb5, 0x12, 0x9c, 0xd1, 0xde, 0x16, 0x4e,
            0xb9, 0xcb, 0xd0, 0x83, 0xe8, 0xa2, 0x50, 0x3c, 0x4e,
        ];
        assert_eq!(block, expected);
    }

    /// RFC 8439 §2.4.2 keystream (first 16 bytes of block counter 1
    /// with the encryption test vector key/nonce).
    #[test]
    fn rfc8439_encrypt_vector_prefix() {
        let mut key = [0u8; 32];
        for (i, k) in key.iter_mut().enumerate() {
            *k = i as u8;
        }
        let nonce = [0, 0, 0, 0, 0, 0, 0, 0x4a, 0, 0, 0, 0];
        let c = ChaCha20::new(&key, &nonce);
        let ks = c.block(1);
        // ciphertext[0..16] = plaintext[0..16] XOR keystream
        let plaintext = b"Ladies and Gentl";
        let expected_ct: [u8; 16] = [
            0x6e, 0x2e, 0x35, 0x9a, 0x25, 0x68, 0xf9, 0x80, 0x41, 0xba, 0x07,
            0x28, 0xdd, 0x0d, 0x69, 0x81,
        ];
        for i in 0..16 {
            assert_eq!(plaintext[i] ^ ks[i], expected_ct[i]);
        }
    }

    #[test]
    fn deterministic_and_stream_separated() {
        let mut a = ChaCha20::seeded(7, 0);
        let mut b = ChaCha20::seeded(7, 0);
        let mut c = ChaCha20::seeded(7, 1);
        let (x, y, z) = (a.next_u64(), b.next_u64(), c.next_u64());
        assert_eq!(x, y);
        assert_ne!(x, z);
    }

    #[test]
    fn fill_bytes_matches_block_stream() {
        let mut r = ChaCha20::seeded(1, 2);
        let mut a = [0u8; 100];
        r.fill_bytes(&mut a);
        let r2 = ChaCha20::seeded(1, 2);
        let b0 = r2.block(0);
        let b1 = r2.block(1);
        assert_eq!(&a[..64], &b0[..]);
        assert_eq!(&a[64..], &b1[..36]);
    }

    #[test]
    fn uniform_unit_interval() {
        let mut r = ChaCha20::seeded(3, 0);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {}", mean);
    }

    #[test]
    fn bounded_is_unbiased_ish() {
        let mut r = ChaCha20::seeded(11, 0);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.next_bounded(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "counts {:?}", counts);
        }
    }
}
