//! Gaussian sampling for the Gaussian mechanism (paper Sec 2.2,
//! Lemma 2) on top of the ChaCha20 CSPRNG.
//!
//! Box-Muller rather than Ziggurat: constant-time-ish per sample and
//! no precomputed tables whose boundary handling could bias the tails
//! (tail accuracy is what the DP guarantee leans on).

use super::chacha::ChaCha20;

/// Stateful standard-normal sampler (caches the second Box-Muller
/// variate).
pub struct Gaussian {
    rng: ChaCha20,
    spare: Option<f64>,
}

impl Gaussian {
    pub fn new(rng: ChaCha20) -> Self {
        Gaussian { rng, spare: None }
    }

    pub fn seeded(seed: u64, stream: u64) -> Self {
        Gaussian::new(ChaCha20::seeded(seed, stream))
    }

    /// One standard normal draw.
    pub fn sample(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        // Box-Muller; u1 in (0,1] so ln(u1) is finite.
        let u1 = 1.0 - self.rng.next_f64();
        let u2 = self.rng.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Fill `dst` with N(0, sigma^2) noise added in place:
    /// `dst[i] += sigma * z_i`. This is the hot call in the DP update;
    /// it draws f64 and narrows to f32 at the end to avoid f32
    /// rounding inside Box-Muller.
    pub fn add_noise_f32(&mut self, dst: &mut [f32], sigma: f64) {
        if sigma == 0.0 {
            return;
        }
        for v in dst.iter_mut() {
            *v += (sigma * self.sample()) as f32;
        }
    }

    /// Draw a vector of N(0, sigma^2) samples.
    pub fn sample_vec(&mut self, n: usize, sigma: f64) -> Vec<f64> {
        (0..n).map(|_| sigma * self.sample()).collect()
    }
}

/// §Perf L3: noise generation dominated the DP step (68% of step time
/// for the MLP). This is the optimized path: polar method over
/// fixed-size chunks of the **flat** gradient buffer (the `GradVec`
/// arena is one contiguous allocation, so no per-tensor work list is
/// needed), each chunk on its own ChaCha stream derived from
/// (seed, step, chunk index) — bitwise deterministic for a given
/// (seed, step) regardless of thread scheduling, because chunk
/// boundaries are fixed and rayon only hands out disjoint chunks.
pub fn add_noise_parallel(grads: &mut [f32], sigma: f64, seed: u64, step: u64) {
    use rayon::prelude::*;
    // a NaN/Inf sigma would poison every gradient element in one call;
    // negative sigma means the caller's noise-multiplier math is wrong
    debug_assert!(
        sigma.is_finite() && sigma >= 0.0,
        "add_noise_parallel: bad sigma {sigma}"
    );
    if sigma == 0.0 {
        return;
    }
    const CHUNK: usize = 16 * 1024;
    grads
        .par_chunks_mut(CHUNK)
        .enumerate()
        .for_each(|(widx, chunk)| {
            // stream id: disjoint from the sequential streams and
            // unique per (step, chunk): [1][step:39][chunk:24]
            let stream = (1u64 << 63) | (step << 24) | widx as u64;
            let mut rng = ChaCha20::seeded(seed ^ 0xD09E, stream);
            fill_chunk(chunk, sigma, &mut rng);
        });
}

/// f32 polar transform for the f32-gradient hot path: the output is
/// f32 anyway, so a f64 transform buys nothing — ln is the remaining
/// per-pair cost and f32 ln is ~2x cheaper (§Perf L3 iteration 5).
#[inline]
fn polar_pair_f32(rng: &mut ChaCha20) -> (f32, f32) {
    loop {
        let bits = rng.next_u64();
        let u = ((bits as u32) as f32) * (2.0 / 4294967296.0) - 1.0;
        let v = (((bits >> 32) as u32) as f32) * (2.0 / 4294967296.0) - 1.0;
        let s = u * u + v * v;
        if s > 0.0 && s < 1.0 {
            let f = (-2.0 * s.ln() / s).sqrt();
            return (u * f, v * f);
        }
    }
}

#[inline]
fn fill_chunk(chunk: &mut [f32], sigma: f64, rng: &mut ChaCha20) {
    let sig = sigma as f32;
    let mut i = 0;
    while i + 1 < chunk.len() {
        let (a, b) = polar_pair_f32(rng);
        chunk[i] += sig * a;
        chunk[i + 1] += sig * b;
        i += 2;
    }
    if i < chunk.len() {
        let (a, _) = polar_pair_f32(rng);
        chunk[i] += sig * a;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn moments(xs: &[f64]) -> (f64, f64, f64, f64) {
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
        let skew = xs.iter().map(|x| (x - mean).powi(3)).sum::<f64>()
            / n
            / var.powf(1.5);
        let kurt =
            xs.iter().map(|x| (x - mean).powi(4)).sum::<f64>() / n / var.powi(2);
        (mean, var, skew, kurt)
    }

    #[test]
    fn standard_normal_moments() {
        let mut g = Gaussian::seeded(42, 0);
        let xs = g.sample_vec(200_000, 1.0);
        let (mean, var, skew, kurt) = moments(&xs);
        assert!(mean.abs() < 0.01, "mean {}", mean);
        assert!((var - 1.0).abs() < 0.02, "var {}", var);
        assert!(skew.abs() < 0.03, "skew {}", skew);
        assert!((kurt - 3.0).abs() < 0.1, "kurtosis {}", kurt);
    }

    #[test]
    fn scaled_noise_variance() {
        let mut g = Gaussian::seeded(7, 3);
        let sigma = 2.5;
        let xs = g.sample_vec(100_000, sigma);
        let (_, var, _, _) = moments(&xs);
        assert!((var - sigma * sigma).abs() < 0.15, "var {}", var);
    }

    #[test]
    fn tail_mass_is_gaussian() {
        // P(|Z| > 2) ~= 0.0455, P(|Z| > 3) ~= 0.0027
        let mut g = Gaussian::seeded(9, 0);
        let n = 400_000;
        let (mut t2, mut t3) = (0usize, 0usize);
        for _ in 0..n {
            let z = g.sample().abs();
            if z > 2.0 {
                t2 += 1;
            }
            if z > 3.0 {
                t3 += 1;
            }
        }
        let p2 = t2 as f64 / n as f64;
        let p3 = t3 as f64 / n as f64;
        assert!((p2 - 0.0455).abs() < 0.003, "p2 {}", p2);
        assert!((p3 - 0.0027).abs() < 0.0008, "p3 {}", p3);
    }

    #[test]
    fn add_noise_deterministic_per_seed() {
        let mut a = vec![1.0f32; 8];
        let mut b = vec![1.0f32; 8];
        Gaussian::seeded(5, 1).add_noise_f32(&mut a, 0.5);
        Gaussian::seeded(5, 1).add_noise_f32(&mut b, 0.5);
        assert_eq!(a, b);
        let mut c = vec![1.0f32; 8];
        Gaussian::seeded(5, 2).add_noise_f32(&mut c, 0.5);
        assert_ne!(a, c);
    }

    #[test]
    fn zero_sigma_is_identity() {
        let mut a = vec![1.0f32, -2.0, 3.5];
        Gaussian::seeded(1, 0).add_noise_f32(&mut a, 0.0);
        assert_eq!(a, vec![1.0, -2.0, 3.5]);
    }

    #[test]
    fn parallel_noise_deterministic_and_gaussian() {
        let mk = || vec![0.0f32; 40_123];
        let mut a = mk();
        let mut b = mk();
        add_noise_parallel(&mut a, 1.5, 7, 3);
        add_noise_parallel(&mut b, 1.5, 7, 3);
        assert_eq!(a, b, "same (seed, step) must be bitwise identical");
        let mut c = mk();
        add_noise_parallel(&mut c, 1.5, 7, 4);
        assert_ne!(a, c, "different step must differ");
        // moments of the flat buffer
        let xs: Vec<f64> = a.iter().map(|&x| x as f64).collect();
        let (mean, var, skew, kurt) = moments(&xs);
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 2.25).abs() < 0.1, "var {var}");
        assert!(skew.abs() < 0.06, "skew {skew}");
        assert!((kurt - 3.0).abs() < 0.2, "kurt {kurt}");
        // chunks are independent: correlation across the chunk
        // boundary at 16384 is negligible
        let n = 10_000;
        let mut dot = 0.0;
        for i in 0..n {
            dot += xs[i] * xs[16_384 + i];
        }
        assert!((dot / n as f64).abs() < 0.1);
    }

    #[test]
    fn parallel_noise_zero_sigma_and_odd_sizes() {
        let mut a = vec![1.0f32; 7];
        add_noise_parallel(&mut a, 0.0, 1, 1);
        assert_eq!(a, vec![1.0; 7]);
        let mut b = vec![0.0f32; 3];
        add_noise_parallel(&mut b, 1.0, 1, 1);
        assert!(b.iter().all(|&x| x != 0.0 && x.is_finite()));
    }
}
