//! Randomness substrate: ChaCha20 CSPRNG + Gaussian sampling.
//!
//! All randomness in the coordinator (minibatch sampling, synthetic
//! data, DP noise) flows through seeded ChaCha20 streams so that runs
//! are exactly reproducible given (seed, stream-id), while the noise
//! itself remains cryptographically unpredictable across seeds.

pub mod chacha;
pub mod gaussian;

pub use chacha::ChaCha20;
pub use gaussian::{add_noise_parallel, Gaussian};

/// Stream-id conventions, so subsystems never share a keystream.
pub mod streams {
    pub const DATA: u64 = 1;
    pub const SHUFFLE: u64 = 2;
    pub const NOISE: u64 = 3;
    pub const SAMPLER: u64 = 4;
    pub const INIT: u64 = 5;
}

/// Fisher-Yates shuffle driven by the CSPRNG.
pub fn shuffle<T>(rng: &mut ChaCha20, xs: &mut [T]) {
    for i in (1..xs.len()).rev() {
        let j = rng.next_bounded(i as u64 + 1) as usize;
        xs.swap(i, j);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = ChaCha20::seeded(13, streams::SHUFFLE);
        let mut xs: Vec<usize> = (0..100).collect();
        shuffle(&mut rng, &mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn shuffle_uniformity_spot_check() {
        // position of element 0 should be ~uniform over 10 slots
        let mut counts = [0usize; 10];
        for seed in 0..20_000u64 {
            let mut rng = ChaCha20::seeded(seed, streams::SHUFFLE);
            let mut xs: Vec<usize> = (0..10).collect();
            shuffle(&mut rng, &mut xs);
            let pos = xs.iter().position(|&v| v == 0).unwrap();
            counts[pos] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 2000.0).abs() < 300.0, "{:?}", counts);
        }
    }
}
