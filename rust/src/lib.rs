//! fastclip — differentially private deep learning with fast
//! per-example gradient clipping (Lee & Kifer, 2020).
//!
//! Architecture: a coordinator (data pipeline, gradient-method
//! dispatch, RDP accounting, DP noise, optimizers, benchmarking)
//! driving pluggable execution backends through `runtime::Backend`:
//!
//!   - `runtime::native::NativeBackend` (default, always on): pure-Rust
//!     *batched* execution through an open `ModelFamily` registry
//!     (dense MLPs + im2col conv built in) and an open *config* space —
//!     `Backend::resolve` synthesizes any `model@dataset:bN` spec key
//!     through `runtime::spec::ConfigBuilder` (the builtin grid is a
//!     preset layer over the same builder) — activations and deltas as
//!     batched matrices over the cache-blocked rayon GEMM kernels in
//!     `runtime::native::gemm`, bitwise deterministic, all seven clip
//!     methods (reweight, gram, direct, pallas-fused, multiloss, nxbp,
//!     nonprivate), writing into a caller-owned `StepOut` arena so the
//!     warm step path allocates nothing. Tier-1 (`cargo build
//!     --release && cargo test -q`) runs entirely on this backend — no
//!     Python, no artifacts, no xla.
//!
//!   - `runtime::engine::Engine` (cargo feature `pjrt`): executes AOT
//!     HLO-text artifacts via the PJRT C API. The artifacts come from
//!     the Python build path (python/compile: Pallas kernels + JAX step
//!     functions, AOT-lowered; `make artifacts`) and cover the full
//!     model zoo (CNN/RNN/LSTM/transformer and the reweight_pallas /
//!     reweight_gram / reweight_direct kernel variants).
//!
//! Both backends implement the same step contract, so the paper's
//! central equivalence claim (reweight == multiloss == nxbp clipped
//! gradients) is tested hermetically on native and, when artifacts are
//! present, cross-checked against the compiled path.

pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod optim;
pub mod privacy;
pub mod rng;
pub mod runtime;
pub mod testkit;
pub mod util;
