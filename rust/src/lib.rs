//! fastclip — differentially private deep learning with fast
//! per-example gradient clipping (Lee & Kifer, 2020).
//!
//! Three-layer architecture (DESIGN.md):
//!   L1/L2 (build time, Python): Pallas kernels + JAX step functions,
//!     AOT-lowered to HLO text artifacts.
//!   L3 (this crate): the coordinator — data pipeline, gradient-method
//!     dispatch, RDP accounting, DP noise, optimizers, benchmarking —
//!     executing the artifacts via the PJRT C API. No Python at runtime.

pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod optim;
pub mod privacy;
pub mod rng;
pub mod runtime;
pub mod testkit;
pub mod util;
