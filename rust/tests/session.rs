//! Session-core equivalence suite: the `TrainSession` state machine
//! must reproduce the pre-refactor monolithic trainer **bitwise** —
//! a manual `step()` loop equals `train()`, a streaming IDX source
//! equals the in-memory dataset, a graceful-stop checkpoint is a valid
//! resume point, and a preset stop flag checkpoints at step 0.

use fastclip::coordinator::{
    checkpoint, train, ClipMethod, TrainOptions, TrainSession,
};
use fastclip::data::idx::{load_idx_dataset, write_idx, IdxArray};
use fastclip::data::StreamingIdxSource;
use fastclip::runtime::{Backend, NativeBackend};
use std::path::{Path, PathBuf};
use std::sync::atomic::AtomicBool;
use std::sync::{Arc, OnceLock};

fn native() -> &'static NativeBackend {
    static B: OnceLock<NativeBackend> = OnceLock::new();
    B.get_or_init(NativeBackend::new)
}

/// Fresh temp dir (removed first — a stale previous run must not leak
/// into checkpoint comparisons).
fn tmp(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("fastclip_session_{name}"));
    std::fs::remove_dir_all(&d).ok();
    d
}

/// Write an mnist-shaped (28x28, 10-class) IDX image/label pair with
/// deterministic contents; returns the two file paths.
fn write_mnist_pair(dir: &Path, n: usize) -> (PathBuf, PathBuf) {
    let images = IdxArray {
        dims: vec![n, 28, 28],
        data: (0..n * 28 * 28).map(|i| (i * 31 % 251) as u8).collect(),
    };
    let labels = IdxArray {
        dims: vec![n],
        data: (0..n).map(|i| (i % 10) as u8).collect(),
    };
    let pi = dir.join("images-idx3-ubyte");
    let pl = dir.join("labels-idx1-ubyte");
    write_idx(&pi, &images).unwrap();
    write_idx(&pl, &labels).unwrap();
    (pi, pl)
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// The tentpole contract: driving a `TrainSession` by hand is the
/// monolithic `train()` — same per-step losses, bitwise-identical
/// final parameters, identical privacy spend, same checkpoint bytes.
#[test]
fn train_equals_manual_session_loop_bitwise() {
    let dir_train = tmp("loop_train");
    let dir_manual = tmp("loop_manual");
    let base = |ckpt: &Path| TrainOptions {
        config: "mlp2_mnist_b32".into(),
        method: ClipMethod::Reweight,
        steps: 6,
        dataset_n: 96,
        optimizer: "sgd".into(),
        lr: 0.05,
        log_every: 0,
        seed: 11,
        checkpoint_dir: Some(ckpt.to_path_buf()),
        ..Default::default()
    };

    let rep = train(native(), &base(&dir_train)).unwrap();

    let mut session =
        TrainSession::new(native(), &base(&dir_manual)).unwrap();
    let mut losses = Vec::new();
    while !session.finished() {
        losses.push(session.step().unwrap());
    }
    assert!(session.maybe_checkpoint().unwrap());
    let eps_manual = session.epsilon().unwrap();
    let (rep_manual, _arena) = session.finish();

    assert_eq!(rep.steps, 6);
    assert_eq!(rep_manual.steps, 6);
    assert_eq!(bits(&rep.losses), bits(&losses));
    assert_eq!(bits(&rep.losses), bits(&rep_manual.losses));
    let (e_t, o_t) = rep.epsilon.unwrap();
    let (e_m, o_m) = eps_manual;
    assert!((e_t - e_m).abs() < 1e-12, "{e_t} vs {e_m}");
    assert_eq!(o_t, o_m);

    // checkpoints byte-for-byte identical (params AND meta)
    for f in ["params.bin", "meta.json"] {
        let a = std::fs::read(dir_train.join(f)).unwrap();
        let b = std::fs::read(dir_manual.join(f)).unwrap();
        assert_eq!(a, b, "{f} differs between train() and the manual loop");
    }
    let cfg = native().manifest().config("mlp2_mnist_b32").unwrap();
    let (meta, _) = checkpoint::load(&dir_train, cfg).unwrap();
    assert_eq!(meta.step, 6);
    for d in [&dir_train, &dir_manual] {
        std::fs::remove_dir_all(d).ok();
    }
}

/// Streaming satellite: a chunked IDX-backed source trains the mnist
/// MLP bitwise-identically to the same rows fully resident in memory
/// — under Poisson sampling, the regime the paper's accounting
/// assumes. (Residency bounds are pinned by the `data::stream` unit
/// tests; this pins end-to-end equality.)
#[test]
fn streaming_source_trains_identically_to_in_memory() {
    let dir = tmp("stream_idx");
    std::fs::create_dir_all(&dir).unwrap();
    let (pi, pl) = write_mnist_pair(&dir, 256);

    let opts = TrainOptions {
        config: "mlp2_mnist_b32".into(),
        method: ClipMethod::Reweight,
        steps: 5,
        dataset_n: 96,
        optimizer: "sgd".into(),
        lr: 0.05,
        sigma: 1.0,
        log_every: 0,
        seed: 11,
        poisson: true,
        ..Default::default()
    };

    let mem = load_idx_dataset("mnist", &pi, &pl, 10).unwrap();
    // chunk 16 rows: far smaller than the 96-row sampled range, so
    // scattered Poisson batches cross many chunk boundaries
    let streaming =
        StreamingIdxSource::open("mnist", &pi, &pl, 10, 16).unwrap();
    let mut s_mem = TrainSession::with_parts(
        native(),
        &opts,
        Some(Box::new(mem)),
        None,
    )
    .unwrap();
    let mut s_str = TrainSession::with_parts(
        native(),
        &opts,
        Some(Box::new(streaming)),
        None,
    )
    .unwrap();

    while !s_mem.finished() {
        let a = s_mem.step().unwrap();
        let b = s_str.step().unwrap();
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "per-step loss diverged at step {}",
            s_mem.step_index()
        );
    }
    assert!(s_str.finished());
    let pa = s_mem.params_snapshot();
    let pb = s_str.params_snapshot();
    assert_eq!(pa.len(), pb.len());
    for (ta, tb) in pa.iter().zip(&pb) {
        assert_eq!(bits(ta), bits(tb), "final params diverged");
    }
    let (ra, _) = s_mem.finish();
    let (rb, _) = s_str.finish();
    let (ea, oa) = ra.epsilon.unwrap();
    let (eb, ob) = rb.epsilon.unwrap();
    assert_eq!(ea.to_bits(), eb.to_bits());
    assert_eq!(oa, ob);
    std::fs::remove_dir_all(&dir).ok();
}

/// Graceful-shutdown satellite, degenerate case: a stop flag already
/// set when `train()` starts runs zero steps and still writes a
/// truthful (step-0) checkpoint.
#[test]
fn preset_stop_flag_checkpoints_immediately() {
    let dir = tmp("preset_stop");
    let opts = TrainOptions {
        config: "mlp2_mnist_b32".into(),
        method: ClipMethod::Reweight,
        steps: 50,
        dataset_n: 96,
        optimizer: "sgd".into(),
        log_every: 0,
        seed: 2,
        checkpoint_dir: Some(dir.clone()),
        stop: Some(Arc::new(AtomicBool::new(true))),
        ..Default::default()
    };
    let rep = train(native(), &opts).unwrap();
    assert_eq!(rep.steps, 0);
    assert!(rep.losses.is_empty());
    let cfg = native().manifest().config("mlp2_mnist_b32").unwrap();
    let (meta, flat) = checkpoint::load(&dir, cfg).unwrap();
    assert_eq!(meta.step, 0);
    assert_eq!(flat.len(), cfg.param_elems());
    std::fs::remove_dir_all(&dir).ok();
}

/// Graceful-shutdown satellite, the real contract: a checkpoint
/// written at a mid-run stop resumes into exactly the uninterrupted
/// trajectory — params bitwise, epsilon to 1e-9.
#[test]
fn mid_run_stop_checkpoint_is_a_valid_resume_point() {
    let half = tmp("stop_half");
    let full = tmp("stop_full");
    let cont = tmp("stop_cont");
    let base = |steps: u64, ckpt: &Path| TrainOptions {
        config: "mlp2_mnist_b32".into(),
        method: ClipMethod::Reweight,
        steps,
        dataset_n: 96,
        optimizer: "sgd".into(),
        log_every: 0,
        seed: 4,
        checkpoint_dir: Some(ckpt.to_path_buf()),
        ..Default::default()
    };

    // simulate a stop after 3 of 8 steps: the driver's break path is
    // exactly "stop stepping, maybe_checkpoint" — drive it by hand so
    // the test is deterministic without signal plumbing
    let mut session = TrainSession::new(native(), &base(8, &half)).unwrap();
    for _ in 0..3 {
        session.step().unwrap();
    }
    assert!(!session.finished());
    assert!(session.maybe_checkpoint().unwrap());
    let (rep_half, _) = session.finish();
    assert_eq!(rep_half.steps, 3);

    let mut resumed = base(8, &full);
    resumed.resume = Some(half.clone());
    let r = train(native(), &resumed).unwrap();
    assert_eq!(r.steps, 8);
    assert_eq!(r.losses.len(), 5);

    let c = train(native(), &base(8, &cont)).unwrap();
    let cfg = native().manifest().config("mlp2_mnist_b32").unwrap();
    let (mf, pf) = checkpoint::load(&full, cfg).unwrap();
    let (mc, pc) = checkpoint::load(&cont, cfg).unwrap();
    assert_eq!(mf.step, 8);
    assert_eq!(mc.step, 8);
    assert_eq!(bits(&pf), bits(&pc), "resumed-after-stop params diverged");
    let (er, oa) = r.epsilon.unwrap();
    let (ec, ob) = c.epsilon.unwrap();
    assert!((er - ec).abs() < 1e-9, "{er} vs {ec}");
    assert_eq!(oa, ob);
    for d in [&half, &full, &cont] {
        std::fs::remove_dir_all(d).ok();
    }
}
