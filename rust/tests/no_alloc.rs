//! The zero-allocation contract of the step execution arena
//! (`StepFn::run_into`, DESIGN.md §"Step execution contract"): after
//! one cold execution has sized every buffer — the caller's `StepOut`
//! arena, the step's scratch, the lazily grown per-example working
//! buffers, the rayon pool — a warm step performs **zero** heap
//! allocations, for every batched method on all three model families and
//! for every clip-policy shape (global hard, per-layer, automatic):
//! the policy seam's group bookkeeping (layer→group map, per-group
//! norm slots) must be sized on the cold pass like everything else.
//!
//! The measurement uses the crate's counting global allocator
//! (`util::alloc`), whose counter is process-wide. This file
//! therefore holds exactly ONE `#[test]` (integration test binaries
//! are separate processes, but tests *within* a binary run on
//! concurrent threads and would pollute the delta).

use fastclip::data;
#[allow(unused_imports)] // trait methods on Arc<dyn StepFn>
use fastclip::runtime::StepFn;
use fastclip::runtime::{
    init_params_glorot, Backend, BatchStage, ClipPolicy, NativeBackend,
    ParamStore, StepOut,
};
use fastclip::util::alloc::allocation_count;

#[test]
fn warm_step_path_performs_zero_heap_allocations() {
    if !fastclip::util::alloc::counting_enabled() {
        eprintln!(
            "SKIP warm_step_path_performs_zero_heap_allocations: built \
             without the `alloc-count` feature, so the counting \
             allocator is not installed and a zero delta would be \
             vacuous"
        );
        return;
    }
    let backend = NativeBackend::new();
    // one config per native family (the satellite contract), at batch
    // sizes big enough that every parallel stage actually fans out
    for config in ["mlp2_mnist_b32", "cnn2_mnist_b16", "transformer_imdb_b16"] {
        let cfg = backend.manifest().config(config).unwrap().clone();
        let ds = data::load_dataset(&cfg.dataset, 64, 7).unwrap();
        let mut stage = BatchStage::for_config(&cfg);
        let batch: Vec<usize> = (0..cfg.batch).collect();
        match ds.features {
            data::Features::F32(_) => data::gather_batch_f32(
                &ds,
                &batch,
                &mut stage.feat_f32,
                &mut stage.labels,
            ),
            // imdb token ids widen into the transformer's f32 stage
            data::Features::I32(_) => data::gather_batch_i32_as_f32(
                &ds,
                &batch,
                &mut stage.feat_f32,
                &mut stage.labels,
            ),
        }
        let params =
            ParamStore::new(&cfg, Some(&init_params_glorot(&cfg, 3))).unwrap();
        // one arena reused across every method of the config — exactly
        // how the trainer holds it
        let mut out = StepOut::for_config(&cfg);
        let policies = [
            ClipPolicy::parse("global:0.5").unwrap(),
            ClipPolicy::parse("per_layer:0.5").unwrap(),
            ClipPolicy::parse("auto:0.5,g=0.01").unwrap(),
        ];
        for method in [
            "nonprivate",
            "reweight",
            "reweight_gram",
            "reweight_direct",
            "reweight_pallas",
            "multiloss",
            "fwd",
        ] {
            let step = backend.load(&cfg, method).unwrap();
            for policy in &policies {
                // nonprivate/fwd ignore the policy; probing them once
                // (under the first one) keeps the matrix cheap
                if matches!(method, "nonprivate" | "fwd")
                    && !policy.is_global_hard()
                {
                    continue;
                }
                let pol = Some(policy);
                // Execute inside the rayon pool: launching a parallel
                // region from an *external* thread goes through the
                // pool's injector queue, which may allocate queue
                // blocks — pool plumbing, not step state. One scope
                // hoists the whole warm+measure sequence into a
                // worker, where nested parallel regions use the
                // allocation-free fast path.
                let mut delta = u64::MAX;
                rayon::scope(|_| {
                    // warm up: cold passes size the scratch, the lazy
                    // per-example buffers, the group bookkeeping, and
                    // the arena
                    for _ in 0..3 {
                        step.run_into(&params, &stage, pol, &mut out)
                            .unwrap();
                    }
                    let before = allocation_count();
                    for _ in 0..5 {
                        step.run_into(&params, &stage, pol, &mut out)
                            .unwrap();
                    }
                    delta = allocation_count() - before;
                });
                assert_eq!(
                    delta, 0,
                    "{config}/{method} under {policy}: {delta} heap \
                     allocations across 5 warm steps — the StepOut arena \
                     contract is broken"
                );
            }
        }
    }

    // --- TrainSession::step(): the whole warm training step ----------
    // (sample → gather → compute clipped grads → noise → account →
    // optimizer update) must also be allocation-free: the batch buffer,
    // the Poisson scratch, the staging buffers, the arena, and the
    // metrics vectors are all pre-sized at session construction. Both
    // sampling regimes are probed — Poisson exercises the pad/truncate
    // scratch, shuffle the epoch re-shuffle.
    use fastclip::coordinator::{ClipMethod, TrainOptions, TrainSession};
    for poisson in [false, true] {
        let opts = TrainOptions {
            config: "mlp2_mnist_b32".into(),
            method: ClipMethod::Reweight,
            steps: 64,
            dataset_n: 64,
            optimizer: "adam".into(),
            log_every: 0,
            poisson,
            seed: 5,
            ..TrainOptions::default()
        };
        let mut session = TrainSession::new(&backend, &opts).unwrap();
        let mut delta = u64::MAX;
        rayon::scope(|_| {
            // warm up: adam's first step sizes its moment buffers; the
            // first computes size scratch and arena
            for _ in 0..3 {
                session.step().unwrap();
            }
            let before = allocation_count();
            for _ in 0..5 {
                session.step().unwrap();
            }
            delta = allocation_count() - before;
        });
        assert_eq!(
            delta, 0,
            "TrainSession::step (poisson={poisson}): {delta} heap \
             allocations across 5 warm steps — the session \
             zero-allocation contract is broken"
        );
    }
}
