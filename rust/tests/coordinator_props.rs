//! Property tests (testkit::prop, the offline proptest substitute) on
//! coordinator invariants: routing/batching/state management must hold
//! for arbitrary shapes and seeds, not just the benchmark configs.

use fastclip::data::{PoissonSampler, ShuffleBatcher};
use fastclip::optim::{Adam, Optimizer, Sgd};
use fastclip::privacy::{calibrate_sigma, epsilon_for, RdpAccountant};
use fastclip::rng::{ChaCha20, Gaussian};
use fastclip::testkit::prop;
use std::collections::HashSet;

/// Every epoch of the shuffle batcher is an exact partition of the
/// dataset (each index exactly once across full batches).
#[test]
fn prop_shuffle_batcher_partitions_epoch() {
    prop::check(60, |g| {
        let n = g.usize_in(8..400);
        let tau = g.usize_incl(1..=n);
        let mut b = ShuffleBatcher::new(n, tau, g.u64());
        let mut seen = HashSet::new();
        for _ in 0..b.batches_per_epoch() {
            for i in b.next_batch() {
                if i >= n {
                    return Err(format!("index {i} out of range {n}"));
                }
                if !seen.insert(i) {
                    return Err(format!("index {i} repeated within epoch"));
                }
            }
        }
        let expect = (n / tau) * tau;
        if seen.len() != expect {
            return Err(format!("covered {} of {expect}", seen.len()));
        }
        Ok(())
    });
}

/// Poisson batches always match the executable's fixed batch shape and
/// stay in range.
#[test]
fn prop_poisson_batches_fixed_shape() {
    prop::check(60, |g| {
        let n = g.usize_in(8..500);
        let tau = g.usize_incl(1..=n);
        let mut p = PoissonSampler::new(n, tau, g.u64());
        for _ in 0..5 {
            let b = p.next_batch();
            if b.len() != tau {
                return Err(format!("batch len {} != {tau}", b.len()));
            }
            if b.iter().any(|&i| i >= n) {
                return Err("index out of range".into());
            }
        }
        Ok(())
    });
}

/// Clip factor nu = min(1, c/norm): the reweighted norm never exceeds
/// c and direction is preserved (sign of every coordinate unchanged).
#[test]
fn prop_clip_factor_bounds() {
    prop::check(200, |g| {
        let n = g.usize_in(1..64);
        let v = g.f32_vec(n, -5.0, 5.0);
        let c = g.f64_in(0.01, 3.0) as f32;
        let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        let nu = if norm > c { c / norm } else { 1.0 };
        let clipped: Vec<f32> = v.iter().map(|x| nu * x).collect();
        let cnorm = clipped.iter().map(|x| x * x).sum::<f32>().sqrt();
        if cnorm > c * 1.0001 && norm > c {
            return Err(format!("clipped norm {cnorm} > c {c}"));
        }
        if norm <= c && (cnorm - norm).abs() > 1e-6 {
            return Err("clip modified an in-bounds vector".into());
        }
        for (a, b) in v.iter().zip(&clipped) {
            if a.signum() != b.signum() && *a != 0.0 && *b != 0.0 {
                return Err("clip flipped a sign".into());
            }
        }
        Ok(())
    });
}

/// Accountant monotonicity in all three knobs, for arbitrary settings.
#[test]
fn prop_accountant_monotone() {
    prop::check(80, |g| {
        let q = g.f64_in(0.001, 0.5);
        let sigma = g.f64_in(0.5, 5.0);
        let steps = g.usize_in(1..2000) as u64;
        let delta = 1e-5;
        let base = epsilon_for(q, sigma, steps, delta);
        if !(base.is_finite() && base >= 0.0) {
            return Err(format!("eps not finite: {base}"));
        }
        if epsilon_for(q, sigma, steps + 100, delta) < base {
            return Err("eps decreased with more steps".into());
        }
        if epsilon_for(q, sigma * 1.5, steps, delta) > base {
            return Err("eps increased with more noise".into());
        }
        if epsilon_for((q * 1.5).min(1.0), sigma, steps, delta) < base {
            return Err("eps decreased with more sampling".into());
        }
        Ok(())
    });
}

/// Calibration post-condition: returned sigma meets the budget.
#[test]
fn prop_calibration_meets_budget() {
    prop::check(25, |g| {
        let q = g.f64_in(0.001, 0.2);
        let steps = g.usize_in(10..3000) as u64;
        let eps = g.f64_in(0.3, 8.0);
        let delta = 1e-5;
        match calibrate_sigma(q, steps, eps, delta) {
            None => Ok(()), // infeasible is a legal answer
            Some(sigma) => {
                let spent = epsilon_for(q, sigma, steps, delta);
                if spent > eps + 1e-6 {
                    Err(format!("sigma {sigma} spends {spent} > {eps}"))
                } else {
                    Ok(())
                }
            }
        }
    });
}

/// Composition order never matters (Lemma 3 is a sum).
#[test]
fn prop_composition_commutes() {
    prop::check(40, |g| {
        let steps: Vec<(f64, f64)> = (0..g.usize_in(2..6))
            .map(|_| (g.f64_in(0.001, 0.3), g.f64_in(0.6, 3.0)))
            .collect();
        let mut fwd = RdpAccountant::new();
        for &(q, s) in &steps {
            fwd.step(q, s);
        }
        let mut rev = RdpAccountant::new();
        for &(q, s) in steps.iter().rev() {
            rev.step(q, s);
        }
        let (a, _) = fwd.epsilon(1e-5);
        let (b, _) = rev.epsilon(1e-5);
        if (a - b).abs() > 1e-9 {
            return Err(format!("composition not commutative: {a} vs {b}"));
        }
        Ok(())
    });
}

/// Optimizer state invariants: finite params under arbitrary bounded
/// gradients, zero gradient is a fixed point for SGD.
#[test]
fn prop_optimizers_stay_finite() {
    use fastclip::runtime::GradVec;
    prop::check(40, |g| {
        let n_tensors = g.usize_in(1..4);
        let sizes: Vec<usize> = (0..n_tensors).map(|_| g.usize_in(1..64)).collect();
        let mut params: Vec<Vec<f32>> =
            sizes.iter().map(|&n| g.f32_vec(n, -1.0, 1.0)).collect();
        let mut adam = Adam::new(g.f64_in(1e-4, 1e-1));
        let mut sgd = Sgd::new(g.f64_in(1e-4, 1e-1));
        let mut noise = Gaussian::new(ChaCha20::seeded(g.u64(), 0));
        for _ in 0..20 {
            let mut grads = GradVec::with_layout(&sizes);
            noise.add_noise_f32(grads.flat_mut(), 2.0);
            adam.step(&mut params, &grads);
        }
        if params.iter().flatten().any(|x| !x.is_finite()) {
            return Err("adam produced non-finite params".into());
        }
        let snapshot = params.clone();
        let zero = GradVec::with_layout(&sizes);
        sgd.step(&mut params, &zero);
        if params != snapshot {
            return Err("sgd moved on zero gradient".into());
        }
        Ok(())
    });
}

/// Gaussian noise scale: empirical stddev tracks sigma across
/// magnitudes (the mechanism calibration depends on this).
#[test]
fn prop_noise_scale_tracks_sigma() {
    prop::check(15, |g| {
        let sigma = g.f64_in(0.05, 10.0);
        let mut gauss = Gaussian::new(ChaCha20::seeded(g.u64(), 1));
        let mut buf = vec![0f32; 4000];
        gauss.add_noise_f32(&mut buf, sigma);
        let mean = buf.iter().sum::<f32>() as f64 / buf.len() as f64;
        let var = buf.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>()
            / buf.len() as f64;
        let rel = (var.sqrt() - sigma).abs() / sigma;
        if rel > 0.12 {
            return Err(format!("stddev {} vs sigma {sigma}", var.sqrt()));
        }
        Ok(())
    });
}
