//! Property tests (testkit::prop, the offline proptest substitute) on
//! coordinator invariants: routing/batching/state management must hold
//! for arbitrary shapes and seeds, not just the benchmark configs.

use fastclip::data::{PoissonSampler, ShuffleBatcher};
use fastclip::optim::{Adam, Optimizer, Sgd};
use fastclip::privacy::{calibrate_sigma, epsilon_for, RdpAccountant};
use fastclip::rng::{ChaCha20, Gaussian};
use fastclip::runtime::ClipPolicy;
use fastclip::testkit::prop;
use std::collections::HashSet;

/// Every epoch of the shuffle batcher is an exact partition of the
/// dataset (each index exactly once across full batches).
#[test]
fn prop_shuffle_batcher_partitions_epoch() {
    prop::check(60, |g| {
        let n = g.usize_in(8..400);
        let tau = g.usize_incl(1..=n);
        let mut b = ShuffleBatcher::new(n, tau, g.u64());
        let mut seen = HashSet::new();
        for _ in 0..b.batches_per_epoch() {
            for i in b.next_batch() {
                if i >= n {
                    return Err(format!("index {i} out of range {n}"));
                }
                if !seen.insert(i) {
                    return Err(format!("index {i} repeated within epoch"));
                }
            }
        }
        let expect = (n / tau) * tau;
        if seen.len() != expect {
            return Err(format!("covered {} of {expect}", seen.len()));
        }
        Ok(())
    });
}

/// Poisson batches always match the executable's fixed batch shape and
/// stay in range.
#[test]
fn prop_poisson_batches_fixed_shape() {
    prop::check(60, |g| {
        let n = g.usize_in(8..500);
        let tau = g.usize_incl(1..=n);
        let mut p = PoissonSampler::new(n, tau, g.u64());
        for _ in 0..5 {
            let b = p.next_batch();
            if b.len() != tau {
                return Err(format!("batch len {} != {tau}", b.len()));
            }
            if b.iter().any(|&i| i >= n) {
                return Err("index out of range".into());
            }
        }
        Ok(())
    });
}

/// Hard clip factor nu = min(1, c/norm), generalized per *group* (the
/// policy seam's granularity axis): partitioning a vector into
/// arbitrary contiguous groups and reweighting each by its own nu
/// keeps every group's norm within c, leaves in-bounds groups
/// untouched, preserves every sign, and bounds the whole reweighted
/// vector by c·sqrt(G) — the grouped mechanism's L2 sensitivity, the
/// value the trainer calibrates noise to. G = 1 is the classic
/// whole-vector bound.
#[test]
fn prop_grouped_hard_clip_bounds() {
    prop::check(200, |g| {
        let c = g.f64_in(0.01, 3.0) as f32;
        let pol = ClipPolicy::hard_global(c);
        let ngroups = g.usize_in(1..5);
        let sizes: Vec<usize> =
            (0..ngroups).map(|_| g.usize_in(1..48)).collect();
        let groups: Vec<Vec<f32>> =
            sizes.iter().map(|&n| g.f32_vec(n, -5.0, 5.0)).collect();
        let mut total_sq = 0f64;
        for v in &groups {
            let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt();
            let nu = pol.nu_for(norm);
            let clipped: Vec<f32> = v.iter().map(|x| nu * x).collect();
            let cnorm = clipped.iter().map(|x| x * x).sum::<f32>().sqrt();
            if cnorm > c * 1.0001 {
                return Err(format!("group clipped norm {cnorm} > c {c}"));
            }
            if norm <= c && (cnorm - norm).abs() > 1e-6 {
                return Err("clip modified an in-bounds group".into());
            }
            for (a, b) in v.iter().zip(&clipped) {
                if a.signum() != b.signum() && *a != 0.0 && *b != 0.0 {
                    return Err("clip flipped a sign".into());
                }
            }
            total_sq += (cnorm as f64).powi(2);
        }
        let bound = c as f64 * (ngroups as f64).sqrt();
        if total_sq.sqrt() > bound * 1.0001 {
            return Err(format!(
                "whole-vector norm {} > grouped sensitivity {bound}",
                total_sq.sqrt()
            ));
        }
        Ok(())
    });
}

/// Automatic clipping (Bu et al. 2022) nu = C/(norm+gamma): the
/// reweighted norm stays *strictly* below C for every norm >= 0
/// (including 0 — no division hazard), nu is monotone nonincreasing
/// in the norm, and as gamma -> 0 the rule approaches the
/// normalized-gradient limit nu·norm -> C.
#[test]
fn prop_automatic_nu_properties() {
    prop::check(200, |g| {
        let c = g.f64_in(0.01, 3.0) as f32;
        let gamma = g.f64_in(1e-4, 0.5) as f32;
        let pol = ClipPolicy::parse(&format!("auto:{c},g={gamma}"))
            .map_err(|e| e.to_string())?;
        let mut norms: Vec<f32> =
            (0..32).map(|_| g.f64_in(0.0, 50.0) as f32).collect();
        norms.push(0.0);
        norms.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut prev_nu = f32::INFINITY;
        for &n in &norms {
            let nu = pol.nu_for(n);
            if !(nu > 0.0 && nu.is_finite()) {
                return Err(format!("bad nu {nu} at norm {n}"));
            }
            if nu * n >= c {
                return Err(format!(
                    "auto-clipped norm {} not strictly below C {c} \
                     (norm {n}, gamma {gamma})",
                    nu * n
                ));
            }
            if nu > prev_nu * 1.000001 {
                return Err(format!("nu increased at norm {n}"));
            }
            prev_nu = nu;
        }
        // gamma -> 0: every example's contribution normalizes to C
        let tiny = ClipPolicy::parse(&format!("auto:{c},g=0.0000001"))
            .map_err(|e| e.to_string())?;
        for &n in &norms {
            if n < 0.01 {
                continue;
            }
            let scaled = tiny.nu_for(n) * n;
            if (scaled - c).abs() / c > 1e-4 {
                return Err(format!(
                    "gamma->0 limit broken: nu*norm {scaled} vs C {c} \
                     at norm {n}"
                ));
            }
        }
        Ok(())
    });
}

/// The policy grammar's parse <-> print contract: the canonical
/// `Display` form of any parsed policy re-parses to an equal policy,
/// and the help grammar names every registered kind (what `--help`
/// and parse errors render).
#[test]
fn prop_policy_parse_print_roundtrip() {
    prop::check(100, |g| {
        let c = g.f64_in(0.01, 9.0) as f32;
        let gamma = g.f64_in(1e-4, 1.0) as f32;
        let b1 = g.usize_in(1..4);
        let b2 = b1 + g.usize_in(1..4);
        let spellings = [
            format!("global:{c}"),
            format!("per_layer:{c}"),
            format!("auto:{c},g={gamma}"),
            format!("per_layer:{c},g={gamma}"),
            format!("groups({b1}):{c}"),
            format!("groups({b1},{b2}):{c},g={gamma}"),
        ];
        for s in &spellings {
            let p = ClipPolicy::parse(s).map_err(|e| e.to_string())?;
            let printed = p.to_string();
            let p2 = ClipPolicy::parse(&printed)
                .map_err(|e| format!("canonical {printed:?}: {e}"))?;
            if p != p2 {
                return Err(format!(
                    "{s:?} -> {printed:?} did not round-trip"
                ));
            }
        }
        let help = ClipPolicy::help_grammar();
        for k in ClipPolicy::kinds() {
            let head = k.syntax.split(':').next().unwrap();
            if !help.contains(head) {
                return Err(format!("help grammar omits {head:?}"));
            }
        }
        Ok(())
    });
}

/// Accountant monotonicity in all three knobs, for arbitrary settings.
#[test]
fn prop_accountant_monotone() {
    prop::check(80, |g| {
        let q = g.f64_in(0.001, 0.5);
        let sigma = g.f64_in(0.5, 5.0);
        let steps = g.usize_in(1..2000) as u64;
        let delta = 1e-5;
        let base = epsilon_for(q, sigma, steps, delta);
        if !(base.is_finite() && base >= 0.0) {
            return Err(format!("eps not finite: {base}"));
        }
        if epsilon_for(q, sigma, steps + 100, delta) < base {
            return Err("eps decreased with more steps".into());
        }
        if epsilon_for(q, sigma * 1.5, steps, delta) > base {
            return Err("eps increased with more noise".into());
        }
        if epsilon_for((q * 1.5).min(1.0), sigma, steps, delta) < base {
            return Err("eps decreased with more sampling".into());
        }
        Ok(())
    });
}

/// Calibration post-condition: returned sigma meets the budget.
#[test]
fn prop_calibration_meets_budget() {
    prop::check(25, |g| {
        let q = g.f64_in(0.001, 0.2);
        let steps = g.usize_in(10..3000) as u64;
        let eps = g.f64_in(0.3, 8.0);
        let delta = 1e-5;
        match calibrate_sigma(q, steps, eps, delta) {
            None => Ok(()), // infeasible is a legal answer
            Some(sigma) => {
                let spent = epsilon_for(q, sigma, steps, delta);
                if spent > eps + 1e-6 {
                    Err(format!("sigma {sigma} spends {spent} > {eps}"))
                } else {
                    Ok(())
                }
            }
        }
    });
}

/// Composition order never matters (Lemma 3 is a sum).
#[test]
fn prop_composition_commutes() {
    prop::check(40, |g| {
        let steps: Vec<(f64, f64)> = (0..g.usize_in(2..6))
            .map(|_| (g.f64_in(0.001, 0.3), g.f64_in(0.6, 3.0)))
            .collect();
        let mut fwd = RdpAccountant::new();
        for &(q, s) in &steps {
            fwd.step(q, s);
        }
        let mut rev = RdpAccountant::new();
        for &(q, s) in steps.iter().rev() {
            rev.step(q, s);
        }
        let (a, _) = fwd.epsilon(1e-5);
        let (b, _) = rev.epsilon(1e-5);
        if (a - b).abs() > 1e-9 {
            return Err(format!("composition not commutative: {a} vs {b}"));
        }
        Ok(())
    });
}

/// Optimizer state invariants: finite params under arbitrary bounded
/// gradients, zero gradient is a fixed point for SGD.
#[test]
fn prop_optimizers_stay_finite() {
    use fastclip::runtime::GradVec;
    prop::check(40, |g| {
        let n_tensors = g.usize_in(1..4);
        let sizes: Vec<usize> = (0..n_tensors).map(|_| g.usize_in(1..64)).collect();
        let mut params: Vec<Vec<f32>> =
            sizes.iter().map(|&n| g.f32_vec(n, -1.0, 1.0)).collect();
        let mut adam = Adam::new(g.f64_in(1e-4, 1e-1));
        let mut sgd = Sgd::new(g.f64_in(1e-4, 1e-1));
        let mut noise = Gaussian::new(ChaCha20::seeded(g.u64(), 0));
        for _ in 0..20 {
            let mut grads = GradVec::with_layout(&sizes);
            noise.add_noise_f32(grads.flat_mut(), 2.0);
            adam.step(&mut params, &grads);
        }
        if params.iter().flatten().any(|x| !x.is_finite()) {
            return Err("adam produced non-finite params".into());
        }
        let snapshot = params.clone();
        let zero = GradVec::with_layout(&sizes);
        sgd.step(&mut params, &zero);
        if params != snapshot {
            return Err("sgd moved on zero gradient".into());
        }
        Ok(())
    });
}

/// Gaussian noise scale: empirical stddev tracks sigma across
/// magnitudes (the mechanism calibration depends on this).
#[test]
fn prop_noise_scale_tracks_sigma() {
    prop::check(15, |g| {
        let sigma = g.f64_in(0.05, 10.0);
        let mut gauss = Gaussian::new(ChaCha20::seeded(g.u64(), 1));
        let mut buf = vec![0f32; 4000];
        gauss.add_noise_f32(&mut buf, sigma);
        let mean = buf.iter().sum::<f32>() as f64 / buf.len() as f64;
        let var = buf.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>()
            / buf.len() as f64;
        let rel = (var.sqrt() - sigma).abs() / sigma;
        if rel > 0.12 {
            return Err(format!("stddev {} vs sigma {sigma}", var.sqrt()));
        }
        Ok(())
    });
}
