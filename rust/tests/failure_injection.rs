//! Failure-injection tests: the coordinator must fail loudly and
//! legibly on corrupt inputs — silent misconfiguration in a DP system
//! is a privacy bug, not just a reliability bug.

use fastclip::coordinator::{train, ClipMethod, TrainOptions};
use fastclip::runtime::{artifacts_dir, Engine, Manifest, ParamStore};
use fastclip::util::json::Json;
use std::path::Path;

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("fastclip_fail_{name}"));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn missing_manifest_is_a_clear_error() {
    let d = tmp_dir("nomanifest");
    let err = match Engine::from_dir(&d) {
        Ok(_) => panic!("engine built without a manifest"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("manifest.json"), "unhelpful error: {msg}");
}

#[test]
fn empty_manifest_rejected() {
    let d = tmp_dir("empty");
    std::fs::write(d.join("manifest.json"), r#"{"configs": {}}"#).unwrap();
    let err = match Engine::from_dir(&d) {
        Ok(_) => panic!("engine built from empty manifest"),
        Err(e) => e,
    };
    assert!(format!("{err:#}").contains("make artifacts"));
}

#[test]
fn corrupt_manifest_json_rejected() {
    let d = tmp_dir("corrupt");
    std::fs::write(d.join("manifest.json"), "{not json").unwrap();
    assert!(Engine::from_dir(&d).is_err());
}

#[test]
fn missing_artifact_file_fails_at_load() {
    // manifest points at an hlo file that does not exist
    let d = tmp_dir("missingfile");
    let manifest = r#"{
      "configs": {
        "ghost_b2": {
          "model": "mlp", "dataset": "mnist", "batch": 2, "n_classes": 10,
          "tags": [], "input": {"shape": [2, 784], "dtype": "f32"},
          "label": {"shape": [2], "dtype": "i32"},
          "params": [{"name": "w", "shape": [784, 10]}],
          "artifacts": {"nonprivate": {"file": "ghost.hlo.txt",
                          "extra_args": [], "outputs": ["grads", "loss"]}}
        }
      }
    }"#;
    std::fs::write(d.join("manifest.json"), manifest).unwrap();
    let engine = Engine::from_dir(&d).unwrap();
    let cfg = engine.manifest.config("ghost_b2").unwrap();
    let err = match engine.load(cfg, "nonprivate") {
        Ok(_) => panic!("load of missing artifact succeeded"),
        Err(e) => e,
    };
    assert!(format!("{err:#}").contains("ghost.hlo.txt"));
}

#[test]
fn garbage_hlo_text_fails_at_compile() {
    let d = tmp_dir("badhlo");
    let manifest = r#"{
      "configs": {
        "bad_b2": {
          "model": "mlp", "dataset": "mnist", "batch": 2, "n_classes": 10,
          "tags": [], "input": {"shape": [2, 784], "dtype": "f32"},
          "label": {"shape": [2], "dtype": "i32"},
          "params": [],
          "artifacts": {"nonprivate": {"file": "bad.hlo.txt",
                          "extra_args": [], "outputs": ["grads", "loss"]}}
        }
      }
    }"#;
    std::fs::write(d.join("manifest.json"), manifest).unwrap();
    std::fs::write(d.join("bad.hlo.txt"), "ENTRY garbage { this is not hlo }")
        .unwrap();
    let engine = Engine::from_dir(&d).unwrap();
    let cfg = engine.manifest.config("bad_b2").unwrap();
    assert!(engine.load(cfg, "nonprivate").is_err());
}

#[test]
fn unknown_config_and_method_errors_name_the_problem() {
    let engine = Engine::from_dir(&artifacts_dir()).unwrap();
    let err = engine.manifest.config("no_such_config").unwrap_err();
    assert!(format!("{err:#}").contains("no_such_config"));
    let cfg = engine.manifest.config("mlp2_mnist_b32").unwrap();
    let err = cfg.artifact("no_such_method").unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("no_such_method") && msg.contains("mlp2_mnist_b32"));
}

#[test]
fn train_rejects_dataset_smaller_than_batch() {
    let engine = Engine::from_dir(&artifacts_dir()).unwrap();
    let opts = TrainOptions {
        config: "mlp2_mnist_b32".into(),
        method: ClipMethod::NonPrivate,
        steps: 1,
        dataset_n: 8, // < batch 32
        log_every: 0,
        ..Default::default()
    };
    assert!(train(&engine, &opts).is_err());
}

#[test]
fn param_store_rejects_wrong_init_length() {
    let engine = Engine::from_dir(&artifacts_dir()).unwrap();
    let cfg = engine.manifest.config("mlp2_mnist_b32").unwrap();
    let too_short = vec![0.0f32; cfg.param_elems() - 1];
    assert!(ParamStore::new(cfg, Some(&too_short)).is_err());
}

#[test]
fn manifest_reload_roundtrip() {
    // the shipped manifest parses, and re-serializing the parsed view
    // of one config keeps the fields we depend on
    let m = Manifest::load(Path::new(&artifacts_dir())).unwrap();
    let cfg = m.config("cnn_mnist_b32").unwrap();
    assert_eq!(cfg.batch, 32);
    assert!(cfg.act_elems_per_example > 10_000); // conv feature maps
    let mut j = Json::obj();
    j.set("batch", cfg.batch.into());
    assert_eq!(Json::parse(&j.to_string()).unwrap().get("batch").as_usize(), Some(32));
}

#[test]
fn infeasible_privacy_target_is_an_error_not_a_silent_fallback() {
    let engine = Engine::from_dir(&artifacts_dir()).unwrap();
    let opts = TrainOptions {
        config: "mlp2_mnist_b32".into(),
        method: ClipMethod::Reweight,
        steps: 100_000,
        dataset_n: 64, // q = 0.5: brutal
        target_eps: Some(0.01),
        log_every: 0,
        ..Default::default()
    };
    let err = train(&engine, &opts).unwrap_err();
    assert!(format!("{err:#}").contains("infeasible"));
}
