//! Failure-injection tests: the coordinator must fail loudly and
//! legibly on corrupt inputs — silent misconfiguration in a DP system
//! is a privacy bug, not just a reliability bug.
//!
//! Manifest/coordinator failures are tested hermetically (no backend
//! needed, or the native backend). Compile-path failures need the PJRT
//! engine and skip with a message when it is unavailable.

use fastclip::coordinator::{train, ClipMethod, GradComputer, TrainOptions};
use fastclip::runtime::{
    Backend, BatchStage, ClipPolicy, ConfigSpec, Manifest, NativeBackend,
    ParamStore, StepFn, StepOut,
};
use fastclip::util::json::Json;
use std::path::Path;
use std::sync::Arc;

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("fastclip_fail_{name}"));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

// referenced only from the cfg(not(feature = "pjrt")) test bodies
#[allow(dead_code)]
fn skip_no_pjrt(test: &str) {
    eprintln!(
        "SKIP {test}: needs the PJRT backend (build with --features pjrt \
         and set FASTCLIP_ARTIFACTS to a `make artifacts` output dir)"
    );
}

#[test]
fn missing_manifest_is_a_clear_error() {
    let d = tmp_dir("nomanifest");
    let err = match Manifest::load(&d) {
        Ok(_) => panic!("manifest loaded from an empty dir"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("manifest.json"), "unhelpful error: {msg}");
}

#[test]
fn empty_manifest_rejected() {
    let d = tmp_dir("empty");
    std::fs::write(d.join("manifest.json"), r#"{"configs": {}}"#).unwrap();
    let err = match Manifest::load(&d) {
        Ok(_) => panic!("empty manifest accepted"),
        Err(e) => e,
    };
    assert!(format!("{err:#}").contains("make artifacts"));
}

#[test]
fn corrupt_manifest_json_rejected() {
    let d = tmp_dir("corrupt");
    std::fs::write(d.join("manifest.json"), "{not json").unwrap();
    assert!(Manifest::load(&d).is_err());
}

#[test]
fn missing_artifact_file_fails_at_load() {
    // manifest points at an hlo file that does not exist
    #[cfg(feature = "pjrt")]
    {
        use fastclip::runtime::Engine;
        let d = tmp_dir("missingfile");
        let manifest = r#"{
          "configs": {
            "ghost_b2": {
              "model": "mlp", "dataset": "mnist", "batch": 2, "n_classes": 10,
              "tags": [], "input": {"shape": [2, 784], "dtype": "f32"},
              "label": {"shape": [2], "dtype": "i32"},
              "params": [{"name": "w", "shape": [784, 10]}],
              "artifacts": {"nonprivate": {"file": "ghost.hlo.txt",
                              "extra_args": [], "outputs": ["grads", "loss"]}}
            }
          }
        }"#;
        std::fs::write(d.join("manifest.json"), manifest).unwrap();
        let engine = Engine::from_dir(&d).unwrap();
        let cfg = engine.manifest().config("ghost_b2").unwrap();
        let err = match engine.load(cfg, "nonprivate") {
            Ok(_) => panic!("load of missing artifact succeeded"),
            Err(e) => e,
        };
        assert!(format!("{err:#}").contains("ghost.hlo.txt"));
        return;
    }
    #[cfg(not(feature = "pjrt"))]
    skip_no_pjrt("missing_artifact_file_fails_at_load");
}

#[test]
fn garbage_hlo_text_fails_at_compile() {
    #[cfg(feature = "pjrt")]
    {
        use fastclip::runtime::Engine;
        let d = tmp_dir("badhlo");
        let manifest = r#"{
          "configs": {
            "bad_b2": {
              "model": "mlp", "dataset": "mnist", "batch": 2, "n_classes": 10,
              "tags": [], "input": {"shape": [2, 784], "dtype": "f32"},
              "label": {"shape": [2], "dtype": "i32"},
              "params": [],
              "artifacts": {"nonprivate": {"file": "bad.hlo.txt",
                              "extra_args": [], "outputs": ["grads", "loss"]}}
            }
          }
        }"#;
        std::fs::write(d.join("manifest.json"), manifest).unwrap();
        std::fs::write(d.join("bad.hlo.txt"), "ENTRY garbage { this is not hlo }")
            .unwrap();
        let engine = Engine::from_dir(&d).unwrap();
        let cfg = engine.manifest().config("bad_b2").unwrap();
        assert!(engine.load(cfg, "nonprivate").is_err());
        return;
    }
    #[cfg(not(feature = "pjrt"))]
    skip_no_pjrt("garbage_hlo_text_fails_at_compile");
}

#[test]
fn unknown_config_and_method_errors_name_the_problem() {
    let backend = NativeBackend::new();
    let err = backend.manifest().config("no_such_config").unwrap_err();
    assert!(format!("{err:#}").contains("no_such_config"));
    let cfg = backend.manifest().config("mlp2_mnist_b32").unwrap();
    let err = cfg.artifact("no_such_method").unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("no_such_method") && msg.contains("mlp2_mnist_b32"));
    // backend.load routes through the same manifest error (naive1 is
    // only registered on the batch-1 siblings)
    let err = backend.load(cfg, "naive1").unwrap_err();
    assert!(format!("{err:#}").contains("naive1"));
}

/// A backend whose steps return gradients but *no* per-example norms —
/// the failure mode of a miscompiled/miswired naive1 artifact.
struct NoNormBackend {
    manifest: Manifest,
}

impl NoNormBackend {
    fn new() -> NoNormBackend {
        // same config family as the native backend, broken execution
        let native = NativeBackend::new();
        NoNormBackend {
            manifest: Manifest {
                dir: std::path::PathBuf::from("mock:no-norms"),
                configs: native.manifest().configs.clone(),
            },
        }
    }
}

impl Backend for NoNormBackend {
    fn name(&self) -> &'static str {
        "mock-no-norms"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn load(&self, cfg: &ConfigSpec, _method: &str) -> anyhow::Result<Arc<dyn StepFn>> {
        Ok(Arc::new(NoNormStep {
            elems: cfg.params.iter().map(|p| p.elems()).collect(),
        }))
    }
}

struct NoNormStep {
    elems: Vec<usize>,
}

impl StepFn for NoNormStep {
    fn method(&self) -> &str {
        "naive1"
    }

    fn run_into(
        &self,
        _params: &ParamStore,
        _stage: &BatchStage,
        _policy: Option<&ClipPolicy>,
        out: &mut StepOut,
    ) -> anyhow::Result<()> {
        // gradients present, loss present... but no per-example norms
        // (the injected fault): reset clears any norms a previous step
        // left in the arena
        out.reset(&self.elems);
        out.loss = 0.1;
        Ok(())
    }
}

/// A manifest-bound backend (the default `Backend::resolve` — what the
/// PJRT engine uses) must reject a spec key with *guidance* (it parses
/// as a spec, the backend just cannot synthesize it), while plain
/// unknown names keep the manifest's error.
#[test]
fn manifest_bound_backend_rejects_spec_keys_with_guidance() {
    let backend = NoNormBackend::new(); // uses the default resolve
    let err = backend.resolve("mlp(depth=2,width=8)@mnist:b4").unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("manifest-bound") && msg.contains("--backend native"),
        "unhelpful spec-key error: {msg}"
    );
    let err = backend.resolve("nope_b2").unwrap_err();
    assert!(format!("{err:#}").contains("nope_b2"));
    // a malformed spec-shaped name gets the grammar error, not the
    // bare unknown-config message
    let err = backend.resolve("mlp(depth=4,widht=8)@mnist:b4").unwrap_err();
    assert!(format!("{err:#}").contains("does not parse"), "{err:#}");
    // the native backend, by contrast, synthesizes the same key
    assert!(NativeBackend::new()
        .resolve("mlp(depth=2,width=8)@mnist:b4")
        .is_ok());
}

/// A naive1 step that omits the per-example norm must abort the nxbp
/// loop: treating the missing norm as 0 would set nu = 1 and add an
/// *unclipped* gradient under noise calibrated for sensitivity `clip`
/// — a silent privacy violation, not a recoverable default.
#[test]
fn nxbp_missing_norm_is_an_error_not_unclipped() {
    let backend = NoNormBackend::new();
    let cfg = backend.manifest().config("mlp2_mnist_b32").unwrap().clone();
    let mut computer =
        GradComputer::new(&backend, "mlp2_mnist_b32", ClipMethod::NxBp)
            .unwrap();
    let mut params = ParamStore::new(&cfg, None).unwrap();
    let stage = BatchStage::for_config(&cfg);
    let mut out = computer.new_out();
    let pol = ClipPolicy::hard_global(1.0);
    let err = computer
        .compute(&mut params, &stage, &pol, &mut out)
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("norm") && msg.contains("unclipped"),
        "error must explain the privacy hazard: {msg}"
    );
}

#[test]
fn train_rejects_dataset_smaller_than_batch() {
    let backend = NativeBackend::new();
    let opts = TrainOptions {
        config: "mlp2_mnist_b32".into(),
        method: ClipMethod::NonPrivate,
        steps: 1,
        dataset_n: 8, // < batch 32
        log_every: 0,
        ..Default::default()
    };
    assert!(train(&backend, &opts).is_err());
}

#[test]
fn param_store_rejects_wrong_init_length() {
    let backend = NativeBackend::new();
    let cfg = backend.manifest().config("mlp2_mnist_b32").unwrap();
    let too_short = vec![0.0f32; cfg.param_elems() - 1];
    assert!(ParamStore::new(cfg, Some(&too_short)).is_err());
}

#[test]
fn manifest_roundtrip_preserves_fields() {
    // the native manifest's view of a config survives a JSON round
    // trip of the fields the coordinator depends on
    let backend = NativeBackend::new();
    let cfg = backend.manifest().config("mlp4_cifar10_b32").unwrap();
    assert_eq!(cfg.batch, 32);
    assert!(cfg.act_elems_per_example > 0);
    let mut j = Json::obj();
    j.set("batch", cfg.batch.into());
    assert_eq!(
        Json::parse(&j.to_string()).unwrap().get("batch").as_usize(),
        Some(32)
    );
    // and the on-disk artifacts manifest, when present, still parses
    let dir = fastclip::runtime::artifacts_dir();
    if dir.join("manifest.json").is_file() {
        let m = Manifest::load(Path::new(&dir)).unwrap();
        assert!(!m.configs.is_empty());
    } else {
        eprintln!(
            "note: no artifacts manifest at {} — checked the native \
             manifest only",
            dir.display()
        );
    }
}

#[test]
fn infeasible_privacy_target_is_an_error_not_a_silent_fallback() {
    let backend = NativeBackend::new();
    let opts = TrainOptions {
        config: "mlp2_mnist_b32".into(),
        method: ClipMethod::Reweight,
        steps: 100_000,
        dataset_n: 64, // q = 0.5: brutal
        target_eps: Some(0.01),
        log_every: 0,
        ..Default::default()
    };
    let err = train(&backend, &opts).unwrap_err();
    assert!(format!("{err:#}").contains("infeasible"));
}

/// An eval set smaller than one batch must be a hard error — the old
/// hand-rolled eval path divided by zero batches and reported NaN
/// loss/accuracy without complaint.
#[test]
fn eval_set_smaller_than_batch_is_an_error_not_nan() {
    let backend = NativeBackend::new();
    let cfg = backend.manifest().config("mlp2_mnist_b32").unwrap().clone();
    let fwd = backend.load(&cfg, "fwd").unwrap();
    let mut params = ParamStore::new(&cfg, None).unwrap();
    let tiny = fastclip::data::load_dataset("mnist", 16, 0).unwrap(); // < 32
    let err =
        fastclip::coordinator::evaluate(fwd.as_ref(), &mut params, &tiny, &cfg)
            .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("eval set") && msg.contains("16"),
        "unhelpful error: {msg}"
    );
}
