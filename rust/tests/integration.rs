//! Integration tests for the paper's central correctness claim (all
//! clipping strategies produce identical clipped gradients),
//! end-to-end training behaviour, and checkpointing.
//!
//! Everything in this file runs hermetically on the pure-Rust
//! `NativeBackend` — no Python, no artifacts, no xla — covering all
//! three native model families (dense MLPs, the im2col conv family,
//! and the transformer encoder with its attention taps).
//! Tests that need the compiled model zoo (the RNN/transformer
//! configs, plus CNN cross-checks against compiled HLO) run only when
//! the crate is built with `--features pjrt` *and* $FASTCLIP_ARTIFACTS
//! points at a manifest; otherwise they skip with an explanatory
//! message instead of failing.

use fastclip::coordinator::{
    stage_batch, train, ClipMethod, GradComputer, TrainOptions,
};
use fastclip::data;
#[allow(unused_imports)] // trait methods on Box<dyn ModelFamily>
use fastclip::runtime::ModelFamily;
use fastclip::runtime::{
    init_params_glorot, Backend, BatchStage, ClipPolicy, GradVec,
    NativeBackend, ParamStore,
};
use std::sync::OnceLock;

/// The hermetic backend every test can rely on.
fn native() -> &'static NativeBackend {
    static B: OnceLock<NativeBackend> = OnceLock::new();
    B.get_or_init(NativeBackend::new)
}

/// The artifact-backed backend, when this build can provide one.
#[cfg(feature = "pjrt")]
fn pjrt() -> Option<&'static dyn Backend> {
    use fastclip::runtime::{artifacts_dir, Engine};
    static E: OnceLock<Option<Engine>> = OnceLock::new();
    E.get_or_init(|| {
        if !fastclip::runtime::artifacts_available() {
            return None; // absent artifacts => legitimate skip
        }
        // artifacts are *present*: failing to load them is a real
        // failure, not a skip — surface it instead of masking the
        // cross-check coverage
        Some(Engine::from_dir(&artifacts_dir()).expect(
            "FASTCLIP_ARTIFACTS manifest exists but the PJRT engine \
             failed to load it",
        ))
    })
    .as_ref()
    .map(|e| e as &dyn Backend)
}

#[cfg(not(feature = "pjrt"))]
fn pjrt() -> Option<&'static dyn Backend> {
    None
}

/// Skip notice for artifact-dependent tests (satellite: skip, don't
/// panic, when the pjrt backend is unavailable).
fn skip_no_pjrt(test: &str) {
    eprintln!(
        "SKIP {test}: needs the PJRT backend (build with --features pjrt \
         and set FASTCLIP_ARTIFACTS to a `make artifacts` output dir)"
    );
}

/// Max relative difference between two gradient arenas.
fn max_rel_diff(a: &GradVec, b: &GradVec) -> f32 {
    assert_eq!(a.total_elems(), b.total_elems());
    let mut worst = 0f32;
    for (&u, &v) in a.flat().iter().zip(b.flat()) {
        let denom = u.abs().max(v.abs()).max(1e-3);
        worst = worst.max((u - v).abs() / denom);
    }
    worst
}

fn run_method(
    backend: &dyn Backend,
    config: &str,
    method: ClipMethod,
    clip: f32,
) -> fastclip::runtime::StepOut {
    run_method_seeded(backend, config, method, clip, 7, 11)
}

fn run_method_seeded(
    backend: &dyn Backend,
    config: &str,
    method: ClipMethod,
    clip: f32,
    data_seed: u64,
    param_seed: u64,
) -> fastclip::runtime::StepOut {
    run_policy_seeded(
        backend,
        config,
        method,
        &ClipPolicy::hard_global(clip),
        data_seed,
        param_seed,
    )
}

fn run_policy_seeded(
    backend: &dyn Backend,
    config: &str,
    method: ClipMethod,
    policy: &ClipPolicy,
    data_seed: u64,
    param_seed: u64,
) -> fastclip::runtime::StepOut {
    // resolve, not manifest lookup: config may be a spec key
    let cfg = backend.resolve(config).unwrap();
    let ds = data::load_dataset(&cfg.dataset, 256, data_seed).unwrap();
    let mut stage = BatchStage::for_config(&cfg);
    let batch: Vec<usize> = (0..cfg.batch).collect();
    stage_batch(&ds, &batch, &mut stage);
    let mut params =
        ParamStore::new(&cfg, Some(&init_params_glorot(&cfg, param_seed)))
            .unwrap();
    let mut computer = GradComputer::new(backend, config, method).unwrap();
    let mut out = computer.new_out();
    computer.compute(&mut params, &stage, policy, &mut out).unwrap();
    out
}

/// The paper's equivalence claim (Sec 5) on one backend: Reweight ==
/// multiLoss == nxBP gradients, up to float reassociation. `tol` is
/// backend-specific: the deterministic native backend holds 1e-4;
/// compiled HLO keeps the seed's 2e-3 (XLA fusion reassociates more).
fn assert_equivalence(backend: &dyn Backend, config: &str, tol: f32) {
    let clip = 0.5; // low threshold so clipping is active
    let rw = run_method(backend, config, ClipMethod::Reweight, clip);
    let ml = run_method(backend, config, ClipMethod::MultiLoss, clip);
    let nx = run_method(backend, config, ClipMethod::NxBp, clip);
    assert!(
        max_rel_diff(&rw.grads, &ml.grads) < tol,
        "reweight vs multiloss"
    );
    assert!(max_rel_diff(&rw.grads, &nx.grads) < tol, "reweight vs nxbp");
    // per-example norms agree too
    let (nr, nm) = (rw.norms().unwrap(), ml.norms().unwrap());
    for (a, b) in nr.iter().zip(nm) {
        assert!((a - b).abs() / b.max(1e-3) < 1e-3, "{a} vs {b}");
    }
}

#[test]
fn all_private_methods_agree_mlp() {
    assert_equivalence(native(), "mlp2_mnist_b32", 1e-4);
}

#[test]
fn all_private_methods_agree_deep_mlp() {
    assert_equivalence(native(), "mlp4_mnist_b16", 1e-4);
}

/// The full native method matrix: every private strategy — the
/// paper's reweight, the Gram-norm variant, the one-backward direct
/// assembly, the fused-GEMM pallas variant, the materialized
/// multiloss, and the naive nxbp loop — produces the same clipped
/// gradient and the same per-example norms on the same staged batch,
/// within 1e-5. Covers all three model families: dense MLPs, the conv
/// family (im2col taps) where the norms flow through the exact
/// per-example position reduction rather than the row-norm product,
/// and the transformer encoder whose embedding/attention/FFN taps all
/// share weights across sequence positions.
#[test]
fn native_method_matrix_agrees() {
    let clip = 0.5;
    let others = [
        ClipMethod::ReweightGram,
        ClipMethod::ReweightDirect,
        ClipMethod::ReweightPallas,
        ClipMethod::MultiLoss,
        ClipMethod::NxBp,
    ];
    for config in [
        "mlp2_mnist_b32",
        "mlp4_mnist_b16",
        "cnn2_mnist_b16",
        "cnn4_mnist_b16",
        "transformer_imdb_b32",
    ] {
        let rw = run_method(native(), config, ClipMethod::Reweight, clip);
        let rw_norms = rw.norms().unwrap();
        for m in others {
            let o = run_method(native(), config, m, clip);
            let diff = max_rel_diff(&rw.grads, &o.grads);
            assert!(
                diff < 1e-5,
                "reweight vs {} on {config}: rel diff {diff}",
                m.name()
            );
            let on = o.norms().unwrap();
            assert_eq!(rw_norms.len(), on.len(), "{}", m.name());
            for (a, b) in rw_norms.iter().zip(on) {
                assert!(
                    (a - b).abs() / b.max(1e-3) < 1e-5,
                    "{} norm {a} vs {b} on {config}",
                    m.name()
                );
            }
            assert!(
                (rw.loss - o.loss).abs() / rw.loss.max(1e-3) < 1e-5,
                "{} loss {} vs {} on {config}",
                m.name(),
                o.loss,
                rw.loss
            );
        }
    }
}

/// The acceptance matrix for the spec resolver (PR 5): the full
/// seven-method agreement holds *off the grid* — on configs the old
/// closed manifest could not express, reached through `model@dataset:bN`
/// spec keys. One off-grid MLP (non-grid width/depth/batch) and one
/// stride-1 conv geometry at batch 48 (the ROADMAP's "other
/// geometries" ask: stride 1 maximizes patch overlap, so the exact
/// per-example norm reduction and the Gram route's off-diagonal terms
/// are working hardest here).
#[test]
fn off_grid_method_matrix_agrees() {
    let clip = 0.5;
    let others = [
        ClipMethod::ReweightGram,
        ClipMethod::ReweightDirect,
        ClipMethod::ReweightPallas,
        ClipMethod::MultiLoss,
        ClipMethod::NxBp,
    ];
    for config in [
        "mlp(depth=3,width=192)@mnist:b24",
        "cnn(depth=2,k=3,s=1,pad=1,ch=4-8)@mnist:b48",
    ] {
        // genuinely off the grid: the manifest cannot name it
        assert!(native().manifest().config(config).is_err(), "{config}");
        let rw = run_method(native(), config, ClipMethod::Reweight, clip);
        let rw_norms = rw.norms().unwrap();
        for m in others {
            let o = run_method(native(), config, m, clip);
            let diff = max_rel_diff(&rw.grads, &o.grads);
            assert!(
                diff < 1e-5,
                "reweight vs {} on {config}: rel diff {diff}",
                m.name()
            );
            let on = o.norms().unwrap();
            assert_eq!(rw_norms.len(), on.len(), "{}", m.name());
            for (a, b) in rw_norms.iter().zip(on) {
                assert!(
                    (a - b).abs() / b.max(1e-3) < 1e-5,
                    "{} norm {a} vs {b} on {config}",
                    m.name()
                );
            }
        }
    }
}

/// The tentpole acceptance matrix: under grouped and automatic clip
/// policies, every batched method agrees with the materialized nxBP
/// per-group reference at 1e-5 — on all three native families. The nxBP
/// loop clips each param-group view of the materialized per-example
/// gradient independently, so it is the oracle for *any* policy the
/// seam can express; the batched methods must reproduce it through
/// the B×L slab reduction and group-block nu scaling.
#[test]
fn grouped_and_automatic_policies_match_nxbp_oracle() {
    let batched = [
        ClipMethod::Reweight,
        ClipMethod::ReweightGram,
        ClipMethod::ReweightDirect,
        ClipMethod::ReweightPallas,
        ClipMethod::MultiLoss,
    ];
    for policy in ["per_layer:0.3", "auto:0.5,g=0.05", "groups(1):0.4"] {
        let pol = ClipPolicy::parse(policy).unwrap();
        for config in ["mlp4_mnist_b16", "cnn2_mnist_b16", "transformer_imdb_b16"] {
            let nx = run_policy_seeded(
                native(),
                config,
                ClipMethod::NxBp,
                &pol,
                7,
                11,
            );
            for m in batched {
                let o = run_policy_seeded(native(), config, m, &pol, 7, 11);
                let diff = max_rel_diff(&nx.grads, &o.grads);
                assert!(
                    diff < 1e-5,
                    "nxbp vs {} under {policy} on {config}: rel diff {diff}",
                    m.name()
                );
                // grouped policies publish per-group norms on both
                // routes (group-major G·b); they must agree too
                match (nx.group_norms(), o.group_norms()) {
                    (Some((a, ga)), Some((b, gb))) => {
                        assert_eq!(
                            ga,
                            gb,
                            "{} group count under {policy} on {config}",
                            m.name()
                        );
                        for (x, y) in a.iter().zip(b) {
                            assert!(
                                (x - y).abs() / y.max(1e-3) < 1e-5,
                                "{} group norm {x} vs {y} under {policy} \
                                 on {config}",
                                m.name()
                            );
                        }
                    }
                    (None, None) => {} // single-group policy
                    (a, b) => panic!(
                        "{} group-norm presence mismatch under {policy} on \
                         {config}: oracle {:?} vs {:?}",
                        m.name(),
                        a.map(|(_, g)| g),
                        b.map(|(_, g)| g)
                    ),
                }
            }
        }
    }
}

/// Warm-vs-cold bitwise equivalence through the arena API, for all
/// seven clip methods on all three families: a computer whose step state
/// and output arena are already warm (and dirty from a previous step)
/// must produce results bitwise identical to a freshly constructed
/// computer writing into a fresh arena. This is the reuse contract of
/// `StepFn::run_into` (DESIGN.md §"Step execution contract").
#[test]
fn warm_arena_matches_cold_for_all_seven_methods() {
    for config in ["mlp2_mnist_b16", "cnn2_mnist_b16", "transformer_imdb_b16"] {
        let cfg = native().manifest().config(config).unwrap().clone();
        let ds = data::load_dataset(&cfg.dataset, 256, 11).unwrap();
        let mut stage = BatchStage::for_config(&cfg);
        let batch: Vec<usize> = (0..cfg.batch).collect();
        stage_batch(&ds, &batch, &mut stage);
        let mut params =
            ParamStore::new(&cfg, Some(&init_params_glorot(&cfg, 13)))
                .unwrap();
        let pol = ClipPolicy::hard_global(0.5);
        for method in ClipMethod::all() {
            let mut warm =
                GradComputer::new(native(), config, method).unwrap();
            let mut out = warm.new_out();
            // first pass dirties the arena and every scratch buffer...
            warm.compute(&mut params, &stage, &pol, &mut out).unwrap();
            // ...second (warm) pass reuses all of it
            warm.compute(&mut params, &stage, &pol, &mut out).unwrap();
            let mut fresh =
                GradComputer::new(native(), config, method).unwrap();
            let mut cold = fresh.new_out();
            fresh.compute(&mut params, &stage, &pol, &mut cold).unwrap();
            assert_eq!(
                out.grads,
                cold.grads,
                "{config}/{}: warm grads != cold grads",
                method.name()
            );
            assert_eq!(
                out.norms(),
                cold.norms(),
                "{config}/{}: warm norms != cold norms",
                method.name()
            );
            assert_eq!(
                out.loss.to_bits(),
                cold.loss.to_bits(),
                "{config}/{}: warm loss != cold loss",
                method.name()
            );
            assert_eq!(out.correct, cold.correct, "{config}/{}", method.name());
        }
    }
}

/// Property (satellite): every reported per-example norm, scaled by
/// its clip factor nu = min(1, c/norm), stays within the sensitivity
/// bound c — for arbitrary clip thresholds, seeds, configs, and every
/// norm-reporting batched method.
#[test]
fn prop_reported_norm_times_nu_within_clip() {
    use fastclip::testkit::prop;
    let methods = [
        ClipMethod::Reweight,
        ClipMethod::ReweightGram,
        ClipMethod::ReweightDirect,
        ClipMethod::ReweightPallas,
        ClipMethod::MultiLoss,
    ];
    let configs = [
        "mlp2_mnist_b16",
        "mlp4_mnist_b16",
        "mlp2_cifar10_b16",
        "cnn2_mnist_b16",
        "cnn2_cifar10_b16",
    ];
    prop::check(12, |g| {
        let clip = g.f64_in(0.02, 2.0) as f32;
        let config = *g.choice(&configs);
        let method = *g.choice(&methods);
        let out = run_method_seeded(
            native(),
            config,
            method,
            clip,
            g.u64() % 1000,
            g.u64() % 1000,
        );
        let norms = out
            .norms()
            .ok_or_else(|| format!("{} reported no norms", method.name()))?;
        if norms.len() != 16 {
            return Err(format!("{} norms, want 16", norms.len()));
        }
        for &n in norms {
            if !n.is_finite() || n <= 0.0 {
                return Err(format!("bad norm {n} ({}, {config})", method.name()));
            }
            let nu = if n > clip { clip / n } else { 1.0 };
            if n * nu > clip * 1.0001 {
                return Err(format!(
                    "norm {n} * nu {nu} = {} exceeds clip {clip} \
                     ({}, {config})",
                    n * nu,
                    method.name()
                ));
            }
        }
        Ok(())
    });
}

/// The paper's Sec 5 equivalence on the *native* conv family: the
/// same claim `all_private_methods_agree_cnn` makes against compiled
/// artifacts, but hermetic — reweight == multiloss == nxbp on a CNN.
#[test]
fn all_private_methods_agree_cnn_native() {
    assert_equivalence(native(), "cnn2_mnist_b16", 1e-4);
}

/// Norm-route ordering across the tap seam: the exact norms and the
/// Gram-route norms agree on both families, and the row-norm-product
/// tap bound is equal on MLPs (each example owns one tap row) but a
/// strict overestimate on conv (an example's patches overlap) — the
/// im2col subtlety the paper calls out, documented in DESIGN.md.
#[test]
fn tap_bound_equals_exact_on_mlp_dominates_on_conv() {
    for (config, is_conv) in [("mlp2_mnist_b16", false), ("cnn2_mnist_b16", true)]
    {
        let cfg = native().manifest().config(config).unwrap().clone();
        let ds = data::load_dataset(&cfg.dataset, 256, 3).unwrap();
        let mut stage = BatchStage::for_config(&cfg);
        let batch: Vec<usize> = (0..cfg.batch).collect();
        stage_batch(&ds, &batch, &mut stage);
        let params =
            ParamStore::new(&cfg, Some(&init_params_glorot(&cfg, 5))).unwrap();
        // the family resolves through the backend's open registry —
        // the same path `load` uses
        let model = native().families().build(&cfg).unwrap();
        let mut s = model.new_scratch();
        model.forward_batch(
            &params.host,
            &stage.feat_f32,
            &stage.labels,
            s.as_mut(),
        );
        model.backward_batch(&params.host, &stage.labels, None, s.as_mut());
        let mut exact = vec![0.0f64; cfg.batch];
        model.sq_norms(&stage.feat_f32, s.as_mut(), &mut exact);
        let mut gram = vec![0.0f64; cfg.batch];
        model.gram_sq_norms(&stage.feat_f32, s.as_mut(), &mut gram);
        let mut tap = vec![0.0f64; cfg.batch];
        model.tap_bound_sq_norms(&stage.feat_f32, s.as_mut(), &mut tap);
        for i in 0..cfg.batch {
            assert!(
                (exact[i] - gram[i]).abs() / gram[i].max(1e-9) < 1e-5,
                "{config} example {i}: exact {} vs gram {}",
                exact[i],
                gram[i]
            );
            if is_conv {
                assert!(
                    tap[i] >= gram[i] * (1.0 - 1e-9),
                    "{config} example {i}: tap bound {} below exact {}",
                    tap[i],
                    gram[i]
                );
            } else {
                assert!(
                    (tap[i] - gram[i]).abs() / gram[i].max(1e-9) < 1e-5,
                    "{config} example {i}: tap {} != gram {} on an MLP",
                    tap[i],
                    gram[i]
                );
            }
        }
        if is_conv {
            assert!(
                (0..cfg.batch).any(|i| tap[i] > gram[i] * 1.0001),
                "{config}: tap bound never strictly loose — patches \
                 stopped overlapping?"
            );
        }
    }
}

#[test]
fn all_private_methods_agree_mlp_pjrt() {
    match pjrt() {
        Some(b) => assert_equivalence(b, "mlp2_mnist_b32", 2e-3),
        None => skip_no_pjrt("all_private_methods_agree_mlp_pjrt"),
    }
}

#[test]
fn all_private_methods_agree_cnn() {
    let Some(b) = pjrt() else {
        skip_no_pjrt("all_private_methods_agree_cnn");
        return;
    };
    let clip = 0.5;
    let rw = run_method(b, "cnn_mnist_b32", ClipMethod::Reweight, clip);
    let ml = run_method(b, "cnn_mnist_b32", ClipMethod::MultiLoss, clip);
    let nx = run_method(b, "cnn_mnist_b32", ClipMethod::NxBp, clip);
    assert!(max_rel_diff(&rw.grads, &ml.grads) < 2e-3);
    assert!(max_rel_diff(&rw.grads, &nx.grads) < 2e-3);
}

#[test]
fn pallas_backend_matches_jnp() {
    let Some(b) = pjrt() else {
        skip_no_pjrt("pallas_backend_matches_jnp");
        return;
    };
    let rw = run_method(b, "mlp2_mnist_b32", ClipMethod::Reweight, 0.5);
    let pl = run_method(b, "mlp2_mnist_b32", ClipMethod::ReweightPallas, 0.5);
    assert!(max_rel_diff(&rw.grads, &pl.grads) < 1e-3);
}

#[test]
fn direct_extension_matches_two_backward() {
    let Some(b) = pjrt() else {
        skip_no_pjrt("direct_extension_matches_two_backward");
        return;
    };
    let rw = run_method(b, "mlp2_mnist_b32", ClipMethod::Reweight, 0.5);
    let dr = run_method(b, "mlp2_mnist_b32", ClipMethod::ReweightDirect, 0.5);
    assert!(max_rel_diff(&rw.grads, &dr.grads) < 1e-3);
    let cw = run_method(b, "cnn_mnist_b32", ClipMethod::Reweight, 0.5);
    let cd = run_method(b, "cnn_mnist_b32", ClipMethod::ReweightDirect, 0.5);
    assert!(max_rel_diff(&cw.grads, &cd.grads) < 1e-3);
}

#[test]
fn gram_extension_matches_materialized_rnn() {
    let Some(b) = pjrt() else {
        skip_no_pjrt("gram_extension_matches_materialized_rnn");
        return;
    };
    let rw = run_method(b, "rnn_mnist_b32", ClipMethod::Reweight, 0.5);
    let gr = run_method(b, "rnn_mnist_b32", ClipMethod::ReweightGram, 0.5);
    assert!(max_rel_diff(&rw.grads, &gr.grads) < 1e-3);
}

#[test]
fn transformer_methods_agree() {
    let Some(b) = pjrt() else {
        skip_no_pjrt("transformer_methods_agree");
        return;
    };
    let rw = run_method(b, "transformer_imdb_b32", ClipMethod::Reweight, 0.5);
    let ml = run_method(b, "transformer_imdb_b32", ClipMethod::MultiLoss, 0.5);
    assert!(max_rel_diff(&rw.grads, &ml.grads) < 2e-3);
}

/// Clipped gradient norm never exceeds c (the mechanism's sensitivity
/// bound, Definition 4 — this is what the privacy proof rests on).
#[test]
fn clipped_gradient_norm_bounded() {
    let clip = 0.25f32;
    let out = run_method(native(), "mlp2_mnist_b32", ClipMethod::Reweight, clip);
    // ||1/tau sum_i clip(g_i)|| <= 1/tau * tau * c = c
    let total_sq: f32 = out.grads.flat().iter().map(|&x| x * x).sum();
    assert!(
        total_sq.sqrt() <= clip * 1.01,
        "averaged clipped grad norm {} > clip {}",
        total_sq.sqrt(),
        clip
    );
    let norms = out.norms().unwrap();
    assert!(norms.iter().all(|&n| n > 0.0));
}

/// Unclipped (nonprivate) differs from clipped when clipping is active.
#[test]
fn clipping_changes_gradient() {
    let non = run_method(native(), "mlp2_mnist_b32", ClipMethod::NonPrivate, 1.0);
    let rw = run_method(native(), "mlp2_mnist_b32", ClipMethod::Reweight, 0.05);
    assert!(max_rel_diff(&non.grads, &rw.grads) > 0.05);
}

/// Loss decreases over a short nonprivate run (training actually
/// optimizes) and stays finite under DP noise.
#[test]
fn training_loss_decreases() {
    let opts = TrainOptions {
        config: "mlp2_mnist_b32".into(),
        method: ClipMethod::NonPrivate,
        steps: 60,
        dataset_n: 512,
        lr: 2e-3,
        log_every: 0,
        seed: 1,
        ..Default::default()
    };
    let report = train(native(), &opts).unwrap();
    let first: f32 = report.losses[..10].iter().sum::<f32>() / 10.0;
    let last: f32 = report.losses[50..].iter().sum::<f32>() / 10.0;
    assert!(
        last < first - 0.1,
        "loss did not decrease: {first} -> {last}"
    );
}

#[test]
fn dp_training_stays_finite_and_accounts() {
    let opts = TrainOptions {
        config: "mlp2_mnist_b32".into(),
        method: ClipMethod::Reweight,
        steps: 30,
        dataset_n: 512,
        sigma: 1.1,
        log_every: 0,
        seed: 2,
        ..Default::default()
    };
    let report = train(native(), &opts).unwrap();
    assert!(report.losses.iter().all(|l| l.is_finite()));
    let (eps, order) = report.epsilon.unwrap();
    assert!(eps > 0.0 && eps < 50.0, "eps {eps}");
    assert!(order >= 2);
}

/// Same seed => identical run; different seed => different noise.
#[test]
fn training_is_deterministic_per_seed() {
    let mk = |seed| TrainOptions {
        config: "mlp2_mnist_b32".into(),
        method: ClipMethod::Reweight,
        steps: 10,
        dataset_n: 256,
        log_every: 0,
        seed,
        ..Default::default()
    };
    let a = train(native(), &mk(5)).unwrap();
    let b = train(native(), &mk(5)).unwrap();
    let c = train(native(), &mk(6)).unwrap();
    assert_eq!(a.losses, b.losses);
    assert_ne!(a.losses, c.losses);
}

/// Target-epsilon calibration path: requested budget is respected.
#[test]
fn target_epsilon_calibration() {
    let opts = TrainOptions {
        config: "mlp2_mnist_b32".into(),
        method: ClipMethod::Reweight,
        steps: 25,
        dataset_n: 512,
        target_eps: Some(1.5),
        delta: 1e-5,
        log_every: 0,
        ..Default::default()
    };
    let report = train(native(), &opts).unwrap();
    let (eps, _) = report.epsilon.unwrap();
    assert!(eps <= 1.5 + 1e-6, "spent {eps} > budget 1.5");
    assert!(report.sigma > 0.3);
}

/// Checkpoint round-trip through the trainer.
#[test]
fn checkpoint_roundtrip() {
    let dir = std::env::temp_dir().join("fastclip_it_ckpt");
    let opts = TrainOptions {
        config: "mlp2_mnist_b32".into(),
        method: ClipMethod::Reweight,
        steps: 5,
        dataset_n: 256,
        log_every: 0,
        checkpoint_dir: Some(dir.clone()),
        ..Default::default()
    };
    train(native(), &opts).unwrap();
    let cfg = native().manifest().config("mlp2_mnist_b32").unwrap();
    let (meta, flat) =
        fastclip::coordinator::checkpoint::load(&dir, cfg).unwrap();
    assert_eq!(meta.step, 5);
    assert_eq!(flat.len(), cfg.param_elems());
    assert!(flat.iter().all(|x| x.is_finite()));
    std::fs::remove_dir_all(&dir).ok();
}

/// The acceptance command (PR 5): `fastclip train --model
/// "mlp(depth=4,width=512)" --dataset cifar10 --batch 256 --backend
/// native` — a config outside the old grid — trains end to end
/// through the spec resolver.
#[test]
fn off_grid_spec_trains_end_to_end() {
    let opts = TrainOptions {
        config: "mlp(depth=4,width=512)@cifar10:b256".into(),
        method: ClipMethod::Reweight,
        steps: 2,
        dataset_n: 512,
        log_every: 0,
        ..Default::default()
    };
    let report = train(native(), &opts).unwrap();
    assert_eq!(report.config, "mlp(depth=4,width=512)@cifar10:b256");
    assert_eq!(report.steps, 2);
    assert!(report.losses.iter().all(|l| l.is_finite()));
    // b256 at n=512 is q=0.5 — the accountant charged it
    assert!((report.sampling_rate - 0.5).abs() < 1e-12);
    assert!(report.epsilon.is_some());
}

/// Save → resume → continue round-trip. With the stateless SGD
/// optimizer the resumed run *is* the continuous run bitwise: the
/// sampler is replayed to the resume point, the noise stream is
/// step-keyed, and the accountant re-charges the checkpointed steps —
/// so final parameters match exactly and the spent epsilon agrees.
#[test]
fn resume_roundtrip_matches_continuous_run() {
    let half = std::env::temp_dir().join("fastclip_resume_half");
    let full = std::env::temp_dir().join("fastclip_resume_full");
    let cont = std::env::temp_dir().join("fastclip_resume_cont");
    for d in [&half, &full, &cont] {
        std::fs::remove_dir_all(d).ok();
    }
    let base = |steps: u64, ckpt: &std::path::Path| TrainOptions {
        config: "mlp2_mnist_b32".into(),
        method: ClipMethod::Reweight,
        steps,
        dataset_n: 256,
        optimizer: "sgd".into(),
        log_every: 0,
        seed: 3,
        checkpoint_dir: Some(ckpt.to_path_buf()),
        ..Default::default()
    };
    train(native(), &base(4, &half)).unwrap();
    let mut resumed = base(8, &full);
    resumed.resume = Some(half.clone());
    let r = train(native(), &resumed).unwrap();
    assert_eq!(r.steps, 8);
    let c = train(native(), &base(8, &cont)).unwrap();
    let cfg = native().manifest().config("mlp2_mnist_b32").unwrap();
    let (mf, pf) =
        fastclip::coordinator::checkpoint::load(&full, cfg).unwrap();
    let (mc, pc) =
        fastclip::coordinator::checkpoint::load(&cont, cfg).unwrap();
    assert_eq!(mf.step, 8);
    assert_eq!(mc.step, 8);
    // bitwise-identical final parameters
    assert_eq!(pf, pc);
    // identical privacy spend (bulk re-charge vs per-step loop may
    // differ by float reassociation only)
    let (er, oa) = r.epsilon.unwrap();
    let (ec, ob) = c.epsilon.unwrap();
    assert!((er - ec).abs() < 1e-9, "{er} vs {ec}");
    assert_eq!(oa, ob);
    // the resumed run's recorded losses are the continuous run's tail
    assert_eq!(r.losses.len(), 4);
    assert_eq!(r.losses, c.losses[4..].to_vec());
    for d in [&half, &full, &cont] {
        std::fs::remove_dir_all(d).ok();
    }
}

/// Resume guard rails: `--steps` is a total (a checkpoint already at
/// or past it is an error), and a checkpoint for a different config is
/// rejected rather than silently reshaped.
#[test]
fn resume_validates_steps_and_config() {
    let dir = std::env::temp_dir().join("fastclip_resume_guard");
    std::fs::remove_dir_all(&dir).ok();
    let mk = |config: &str, steps: u64| TrainOptions {
        config: config.into(),
        method: ClipMethod::Reweight,
        steps,
        dataset_n: 256,
        log_every: 0,
        checkpoint_dir: Some(dir.clone()),
        ..Default::default()
    };
    train(native(), &mk("mlp2_mnist_b32", 3)).unwrap();
    let mut stale = mk("mlp2_mnist_b32", 3);
    stale.checkpoint_dir = None;
    stale.resume = Some(dir.clone());
    let err = train(native(), &stale).unwrap_err();
    assert!(format!("{err:#}").contains("total"), "{err:#}");
    let mut wrong = mk("mlp4_mnist_b32", 8);
    wrong.checkpoint_dir = None;
    wrong.resume = Some(dir.clone());
    let err = train(native(), &wrong).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("mlp2_mnist_b32"), "{msg}");
    // a different seed would silently diverge from the continued run
    let mut reseeded = mk("mlp2_mnist_b32", 8);
    reseeded.checkpoint_dir = None;
    reseeded.resume = Some(dir.clone());
    reseeded.seed = 99;
    let err = train(native(), &reseeded).unwrap_err();
    assert!(format!("{err:#}").contains("--seed"), "{err:#}");
    // the checkpoint records ONE (sigma, q) for its whole history, so
    // a heterogeneous continuation must be refused, not mis-recorded
    let mut hot = mk("mlp2_mnist_b32", 8);
    hot.checkpoint_dir = None;
    hot.resume = Some(dir.clone());
    hot.sigma = 2.5;
    let err = train(native(), &hot).unwrap_err();
    assert!(format!("{err:#}").contains("sigma"), "{err:#}");
    let mut rerated = mk("mlp2_mnist_b32", 8);
    rerated.checkpoint_dir = None;
    rerated.resume = Some(dir.clone());
    rerated.dataset_n = 512;
    let err = train(native(), &rerated).unwrap_err();
    assert!(format!("{err:#}").contains("sampling rate"), "{err:#}");
    // the sampling regime is recorded; a silent Poisson<->shuffle flip
    // would change both the batch stream and the RDP assumption
    let mut resampled = mk("mlp2_mnist_b32", 8);
    resampled.checkpoint_dir = None;
    resampled.resume = Some(dir.clone());
    resampled.poisson = true;
    let err = train(native(), &resampled).unwrap_err();
    assert!(format!("{err:#}").contains("--poisson"), "{err:#}");
    // methods agree to ~1e-5, not bitwise: switching is not a continuation
    let mut remethod = mk("mlp2_mnist_b32", 8);
    remethod.checkpoint_dir = None;
    remethod.resume = Some(dir.clone());
    remethod.method = ClipMethod::MultiLoss;
    let err = train(native(), &remethod).unwrap_err();
    assert!(format!("{err:#}").contains("--method"), "{err:#}");
    // clip drives both the threshold and the noise scale
    let mut reclipped = mk("mlp2_mnist_b32", 8);
    reclipped.checkpoint_dir = None;
    reclipped.resume = Some(dir.clone());
    reclipped.clip = 0.25;
    let err = train(native(), &reclipped).unwrap_err();
    assert!(format!("{err:#}").contains("clip"), "{err:#}");
    // the optimizer name is recorded; switching it is not a continuation
    let mut swapped = mk("mlp2_mnist_b32", 8);
    swapped.checkpoint_dir = None;
    swapped.resume = Some(dir.clone());
    swapped.optimizer = "sgd".into(); // checkpoint recorded adam
    let err = train(native(), &swapped).unwrap_err();
    assert!(format!("{err:#}").contains("--optimizer"), "{err:#}");
    // the learning rate is recorded; the tail must train at it
    let mut relearned = mk("mlp2_mnist_b32", 8);
    relearned.checkpoint_dir = None;
    relearned.resume = Some(dir.clone());
    relearned.lr = 0.05;
    let err = train(native(), &relearned).unwrap_err();
    assert!(format!("{err:#}").contains("--lr"), "{err:#}");
    // --target-eps on resume would double-count the recorded spend
    let mut budgeted = mk("mlp2_mnist_b32", 8);
    budgeted.checkpoint_dir = None;
    budgeted.resume = Some(dir.clone());
    budgeted.target_eps = Some(2.0);
    let err = train(native(), &budgeted).unwrap_err();
    assert!(format!("{err:#}").contains("target-eps"), "{err:#}");
    std::fs::remove_dir_all(&dir).ok();
}

/// The refactor's continuity claim at the trainer level: an explicit
/// `global:C` policy is the same process as the classical `--clip C`
/// path — identical losses (clipping AND the noise stream; the
/// pre-policy path keeps the exact f64 clip as its sensitivity, and
/// 0.5 round-trips through the policy's f32 threshold exactly).
#[test]
fn explicit_global_policy_trains_bitwise_like_default() {
    let mk = |policy: Option<ClipPolicy>| TrainOptions {
        config: "mlp2_mnist_b32".into(),
        method: ClipMethod::Reweight,
        steps: 6,
        dataset_n: 256,
        clip: 0.5,
        policy,
        log_every: 0,
        seed: 9,
        ..Default::default()
    };
    let a = train(native(), &mk(None)).unwrap();
    let b = train(
        native(),
        &mk(Some(ClipPolicy::parse("global:0.5").unwrap())),
    )
    .unwrap();
    assert_eq!(a.losses, b.losses);
    assert_eq!(a.policy, b.policy);
    assert_eq!(a.sensitivity, b.sensitivity);
}

/// Grouped noise calibration: per-layer clipping on an L-layer model
/// has L2 sensitivity C·sqrt(L) — neighboring datasets move each
/// group's contribution by up to C on *disjoint* coordinates — and
/// the trainer reports (and calibrates the Gaussian to) exactly that,
/// plus per-group mean unclipped norms in the metrics.
#[test]
fn trainer_calibrates_grouped_sensitivity_and_reports_group_norms() {
    let opts = TrainOptions {
        config: "mlp2_mnist_b32".into(),
        method: ClipMethod::Reweight,
        steps: 4,
        dataset_n: 256,
        policy: Some(ClipPolicy::parse("per_layer:0.5").unwrap()),
        log_every: 0,
        seed: 4,
        ..Default::default()
    };
    let report = train(native(), &opts).unwrap();
    assert_eq!(report.policy, "per_layer:0.5");
    // mlp2 has 2 parametric (W, b) layers => G = 2
    assert!((report.sensitivity - 0.5 * 2f64.sqrt()).abs() < 1e-12);
    assert!(report.losses.iter().all(|l| l.is_finite()));
    let means = report.metrics_json.get("group_norm_mean");
    let arr = means.as_arr().unwrap();
    assert_eq!(arr.len(), 2);
    assert!(arr.iter().all(|m| m.as_f64().unwrap() > 0.0));
}

/// Resume guard for clip policies: a policy-recording checkpoint only
/// continues under the identical canonical policy; a pre-policy
/// checkpoint (no recorded policy) continues under the classical
/// global hard clip — bare `--clip` or an explicit `global:C` — and
/// refuses any other policy.
#[test]
fn resume_validates_clip_policy() {
    let dir = std::env::temp_dir().join("fastclip_resume_policy");
    std::fs::remove_dir_all(&dir).ok();
    let mk = |steps: u64, policy: Option<ClipPolicy>| TrainOptions {
        config: "mlp2_mnist_b32".into(),
        method: ClipMethod::Reweight,
        steps,
        dataset_n: 256,
        policy,
        log_every: 0,
        checkpoint_dir: Some(dir.clone()),
        ..Default::default()
    };
    let per_layer = || ClipPolicy::parse("per_layer:0.5").unwrap();
    train(native(), &mk(3, Some(per_layer()))).unwrap();
    // a different policy is refused, naming the recorded one
    let mut wrong = mk(6, Some(ClipPolicy::parse("per_layer:0.25").unwrap()));
    wrong.checkpoint_dir = None;
    wrong.resume = Some(dir.clone());
    let err = train(native(), &wrong).unwrap_err();
    assert!(format!("{err:#}").contains("per_layer:0.5"), "{err:#}");
    // dropping down to the classical --clip path is also refused —
    // the threshold structure and the noise scale would change
    let mut dropped = mk(6, None);
    dropped.checkpoint_dir = None;
    dropped.resume = Some(dir.clone());
    let err = train(native(), &dropped).unwrap_err();
    assert!(format!("{err:#}").contains("per_layer:0.5"), "{err:#}");
    // the identical policy continues (and re-records it)
    let mut ok = mk(6, Some(per_layer()));
    ok.resume = Some(dir.clone());
    let report = train(native(), &ok).unwrap();
    assert_eq!(report.steps, 6);

    // pre-policy checkpoint compatibility: strip the recorded policy
    // from the meta — what a checkpoint written before this refactor
    // looks like — and check the compat arms against it
    let cfg = native().manifest().config("mlp2_mnist_b32").unwrap();
    let (mut meta, flat) =
        fastclip::coordinator::checkpoint::load(&dir, cfg).unwrap();
    meta.clip_policy = None;
    meta.clip = 1.0; // the classical threshold those steps "ran" at
    let ps = ParamStore::new(cfg, Some(&flat)).unwrap();
    fastclip::coordinator::checkpoint::save(&dir, &meta, &ps).unwrap();
    // a grouped policy cannot continue a pre-policy checkpoint
    let mut grouped = mk(9, Some(per_layer()));
    grouped.checkpoint_dir = None;
    grouped.resume = Some(dir.clone());
    let err = train(native(), &grouped).unwrap_err();
    assert!(format!("{err:#}").contains("predates"), "{err:#}");
    // ...but the bare --clip path does (the original continuity check)
    let mut classical = mk(9, None);
    classical.checkpoint_dir = None;
    classical.resume = Some(dir.clone());
    assert_eq!(train(native(), &classical).unwrap().steps, 9);
    // ...and so does the explicit spelling of the same policy
    let mut explicit =
        mk(12, Some(ClipPolicy::parse("global:1.0").unwrap()));
    explicit.checkpoint_dir = None;
    explicit.resume = Some(dir.clone());
    assert_eq!(train(native(), &explicit).unwrap().steps, 12);
    std::fs::remove_dir_all(&dir).ok();
}

/// `--eval-n` replaces the silent hardcoded 4-batch eval set: it is
/// validated against the config batch (eval runs in full batches) and
/// actually sizes the eval set when valid.
#[test]
fn eval_n_is_validated_against_the_batch() {
    let mk = |eval_n: Option<usize>| TrainOptions {
        config: "mlp2_mnist_b32".into(),
        method: ClipMethod::NonPrivate,
        steps: 2,
        dataset_n: 64,
        eval_every: 2,
        eval_n,
        log_every: 0,
        ..Default::default()
    };
    let err = train(native(), &mk(Some(16))).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("--eval-n 16") && msg.contains("32"),
        "unhelpful error: {msg}"
    );
    // a non-multiple would silently drop the remainder examples
    let err = train(native(), &mk(Some(100))).unwrap_err();
    assert!(format!("{err:#}").contains("multiple"), "{err:#}");
    // --eval-n without --eval-every would be silently ignored
    let mut idle = mk(Some(64));
    idle.eval_every = 0;
    let err = train(native(), &idle).unwrap_err();
    assert!(format!("{err:#}").contains("--eval-every"), "{err:#}");
    let report = train(native(), &mk(Some(64))).unwrap();
    assert_eq!(report.eval_points.len(), 1);
    let (_, l, a) = report.eval_points[0];
    assert!(l.is_finite() && (0.0..=1.0).contains(&a));
}

/// Poisson-sampling mode runs and matches the fixed batch ABI.
#[test]
fn poisson_sampling_mode() {
    let opts = TrainOptions {
        config: "mlp2_mnist_b32".into(),
        method: ClipMethod::Reweight,
        steps: 8,
        dataset_n: 512,
        poisson: true,
        log_every: 0,
        ..Default::default()
    };
    let report = train(native(), &opts).unwrap();
    assert_eq!(report.losses.len(), 8);
}

/// Eval path: the fwd step runs during training and reports accuracy.
#[test]
fn eval_during_training_reports_accuracy() {
    let opts = TrainOptions {
        config: "mlp2_mnist_b32".into(),
        method: ClipMethod::NonPrivate,
        steps: 10,
        dataset_n: 256,
        eval_every: 5,
        log_every: 0,
        ..Default::default()
    };
    let report = train(native(), &opts).unwrap();
    assert_eq!(report.eval_points.len(), 2);
    for &(_, l, a) in &report.eval_points {
        assert!(l.is_finite());
        assert!((0.0..=1.0).contains(&a));
    }
}

/// Every fig5 config's reweight step loads and executes.
fn assert_fig5_sweep(backend: &dyn Backend) {
    for cfg in backend.manifest().by_tag("fig5") {
        let out = run_method(backend, &cfg.name, ClipMethod::Reweight, 1.0);
        assert!(out.loss.is_finite(), "{} loss", cfg.name);
        assert_eq!(out.grads.n_params(), cfg.params.len(), "{}", cfg.name);
        for (g, p) in out.grads.params().zip(&cfg.params) {
            assert_eq!(g.len(), p.elems(), "{}.{}", cfg.name, p.name);
        }
    }
}

#[test]
fn all_fig5_configs_execute() {
    assert_fig5_sweep(native());
}

#[test]
fn all_fig5_configs_execute_pjrt() {
    match pjrt() {
        Some(b) => assert_fig5_sweep(b),
        None => skip_no_pjrt("all_fig5_configs_execute_pjrt"),
    }
}
