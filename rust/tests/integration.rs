//! Integration tests over real AOT artifacts: the paper's central
//! correctness claim (all clipping strategies produce identical
//! gradients), end-to-end training behaviour, and checkpointing.
//!
//! Requires `make artifacts` to have run (CI: these are repo-relative).

use fastclip::coordinator::{
    stage_batch, train, ClipMethod, GradComputer, TrainOptions,
};
use fastclip::data;
use fastclip::runtime::{
    artifacts_dir, init_params_glorot, BatchStage, Engine, ParamStore,
};
use std::sync::OnceLock;

fn engine() -> &'static Engine {
    static ENGINE: OnceLock<Engine> = OnceLock::new();
    ENGINE.get_or_init(|| {
        Engine::from_dir(&artifacts_dir()).expect(
            "artifacts not found — run `make artifacts` before `cargo test`",
        )
    })
}

/// Max relative difference between two gradient sets.
fn max_rel_diff(a: &[Vec<f32>], b: &[Vec<f32>]) -> f32 {
    let mut worst = 0f32;
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.len(), y.len());
        for (&u, &v) in x.iter().zip(y) {
            let denom = u.abs().max(v.abs()).max(1e-3);
            worst = worst.max((u - v).abs() / denom);
        }
    }
    worst
}

fn run_method(config: &str, method: ClipMethod, clip: f32) -> fastclip::runtime::StepOut {
    let eng = engine();
    let cfg = eng.manifest.config(config).unwrap().clone();
    let ds = data::load_dataset(&cfg.dataset, 256, 7).unwrap();
    let mut stage = BatchStage::for_config(&cfg);
    let batch: Vec<usize> = (0..cfg.batch).collect();
    stage_batch(&ds, &batch, &mut stage);
    let mut params =
        ParamStore::new(&cfg, Some(&init_params_glorot(&cfg, 11))).unwrap();
    let mut computer = GradComputer::new(eng, config, method).unwrap();
    computer.compute(&mut params, &stage, clip).unwrap()
}

/// The paper's equivalence claim (Sec 5): ReweightGP == multiLoss ==
/// nxBP gradients, bitwise up to float reassociation.
#[test]
fn all_private_methods_agree_mlp() {
    let clip = 0.5; // low threshold so clipping is active
    let rw = run_method("mlp2_mnist_b32", ClipMethod::Reweight, clip);
    let ml = run_method("mlp2_mnist_b32", ClipMethod::MultiLoss, clip);
    let nx = run_method("mlp2_mnist_b32", ClipMethod::NxBp, clip);
    assert!(max_rel_diff(&rw.grads, &ml.grads) < 2e-3, "reweight vs multiloss");
    assert!(max_rel_diff(&rw.grads, &nx.grads) < 2e-3, "reweight vs nxbp");
    // per-example norms agree too
    let (nr, nm) = (rw.norms.unwrap(), ml.norms.unwrap());
    for (a, b) in nr.iter().zip(&nm) {
        assert!((a - b).abs() / b.max(1e-3) < 1e-3, "{a} vs {b}");
    }
}

#[test]
fn all_private_methods_agree_cnn() {
    let clip = 0.5;
    let rw = run_method("cnn_mnist_b32", ClipMethod::Reweight, clip);
    let ml = run_method("cnn_mnist_b32", ClipMethod::MultiLoss, clip);
    let nx = run_method("cnn_mnist_b32", ClipMethod::NxBp, clip);
    assert!(max_rel_diff(&rw.grads, &ml.grads) < 2e-3);
    assert!(max_rel_diff(&rw.grads, &nx.grads) < 2e-3);
}

#[test]
fn pallas_backend_matches_jnp() {
    let rw = run_method("mlp2_mnist_b32", ClipMethod::Reweight, 0.5);
    let pl = run_method("mlp2_mnist_b32", ClipMethod::ReweightPallas, 0.5);
    assert!(max_rel_diff(&rw.grads, &pl.grads) < 1e-3);
}

#[test]
fn direct_extension_matches_two_backward() {
    let rw = run_method("mlp2_mnist_b32", ClipMethod::Reweight, 0.5);
    let dr = run_method("mlp2_mnist_b32", ClipMethod::ReweightDirect, 0.5);
    assert!(max_rel_diff(&rw.grads, &dr.grads) < 1e-3);
    let cw = run_method("cnn_mnist_b32", ClipMethod::Reweight, 0.5);
    let cd = run_method("cnn_mnist_b32", ClipMethod::ReweightDirect, 0.5);
    assert!(max_rel_diff(&cw.grads, &cd.grads) < 1e-3);
}

#[test]
fn gram_extension_matches_materialized_rnn() {
    let rw = run_method("rnn_mnist_b32", ClipMethod::Reweight, 0.5);
    let gr = run_method("rnn_mnist_b32", ClipMethod::ReweightGram, 0.5);
    assert!(max_rel_diff(&rw.grads, &gr.grads) < 1e-3);
}

#[test]
fn transformer_methods_agree() {
    let rw = run_method("transformer_imdb_b32", ClipMethod::Reweight, 0.5);
    let ml = run_method("transformer_imdb_b32", ClipMethod::MultiLoss, 0.5);
    assert!(max_rel_diff(&rw.grads, &ml.grads) < 2e-3);
}

/// Clipped gradient norm never exceeds c (the mechanism's sensitivity
/// bound, Definition 4 — this is what the privacy proof rests on).
#[test]
fn clipped_gradient_norm_bounded() {
    let clip = 0.25f32;
    let out = run_method("mlp2_mnist_b32", ClipMethod::Reweight, clip);
    let tau = 32.0f32;
    // ||1/tau sum_i clip(g_i)|| <= 1/tau * tau * c = c
    let total_sq: f32 = out
        .grads
        .iter()
        .flat_map(|g| g.iter())
        .map(|&x| x * x)
        .sum();
    assert!(
        total_sq.sqrt() <= clip * 1.01,
        "averaged clipped grad norm {} > clip {}",
        total_sq.sqrt(),
        clip
    );
    // and with per-example norms >= clip, each contribution is exactly c
    let norms = out.norms.unwrap();
    assert!(norms.iter().all(|&n| n > 0.0));
    let _ = tau;
}

/// Unclipped (nonprivate) differs from clipped when clipping is active.
#[test]
fn clipping_changes_gradient() {
    let non = run_method("mlp2_mnist_b32", ClipMethod::NonPrivate, 1.0);
    let rw = run_method("mlp2_mnist_b32", ClipMethod::Reweight, 0.05);
    assert!(max_rel_diff(&non.grads, &rw.grads) > 0.05);
}

/// Loss decreases over a short nonprivate run (training actually
/// optimizes) and stays finite under DP noise.
#[test]
fn training_loss_decreases() {
    let eng = engine();
    let opts = TrainOptions {
        config: "mlp2_mnist_b32".into(),
        method: ClipMethod::NonPrivate,
        steps: 60,
        dataset_n: 512,
        lr: 2e-3,
        log_every: 0,
        seed: 1,
        ..Default::default()
    };
    let report = train(eng, &opts).unwrap();
    let first: f32 = report.losses[..10].iter().sum::<f32>() / 10.0;
    let last: f32 = report.losses[50..].iter().sum::<f32>() / 10.0;
    assert!(
        last < first - 0.1,
        "loss did not decrease: {first} -> {last}"
    );
}

#[test]
fn dp_training_stays_finite_and_accounts() {
    let eng = engine();
    let opts = TrainOptions {
        config: "mlp2_mnist_b32".into(),
        method: ClipMethod::Reweight,
        steps: 30,
        dataset_n: 512,
        sigma: 1.1,
        log_every: 0,
        seed: 2,
        ..Default::default()
    };
    let report = train(eng, &opts).unwrap();
    assert!(report.losses.iter().all(|l| l.is_finite()));
    let (eps, order) = report.epsilon.unwrap();
    assert!(eps > 0.0 && eps < 50.0, "eps {eps}");
    assert!(order >= 2);
}

/// Same seed => identical run; different seed => different noise.
#[test]
fn training_is_deterministic_per_seed() {
    let eng = engine();
    let mk = |seed| TrainOptions {
        config: "mlp2_mnist_b32".into(),
        method: ClipMethod::Reweight,
        steps: 10,
        dataset_n: 256,
        log_every: 0,
        seed,
        ..Default::default()
    };
    let a = train(eng, &mk(5)).unwrap();
    let b = train(eng, &mk(5)).unwrap();
    let c = train(eng, &mk(6)).unwrap();
    assert_eq!(a.losses, b.losses);
    assert_ne!(a.losses, c.losses);
}

/// Target-epsilon calibration path: requested budget is respected.
#[test]
fn target_epsilon_calibration() {
    let eng = engine();
    let opts = TrainOptions {
        config: "mlp2_mnist_b32".into(),
        method: ClipMethod::Reweight,
        steps: 25,
        dataset_n: 512,
        target_eps: Some(1.5),
        delta: 1e-5,
        log_every: 0,
        ..Default::default()
    };
    let report = train(eng, &opts).unwrap();
    let (eps, _) = report.epsilon.unwrap();
    assert!(eps <= 1.5 + 1e-6, "spent {eps} > budget 1.5");
    assert!(report.sigma > 0.3);
}

/// Checkpoint round-trip through the trainer.
#[test]
fn checkpoint_roundtrip() {
    let eng = engine();
    let dir = std::env::temp_dir().join("fastclip_it_ckpt");
    let opts = TrainOptions {
        config: "mlp2_mnist_b32".into(),
        method: ClipMethod::Reweight,
        steps: 5,
        dataset_n: 256,
        log_every: 0,
        checkpoint_dir: Some(dir.clone()),
        ..Default::default()
    };
    train(eng, &opts).unwrap();
    let cfg = eng.manifest.config("mlp2_mnist_b32").unwrap();
    let (meta, flat) =
        fastclip::coordinator::checkpoint::load(&dir, cfg).unwrap();
    assert_eq!(meta.step, 5);
    assert_eq!(flat.len(), cfg.param_elems());
    assert!(flat.iter().all(|x| x.is_finite()));
    std::fs::remove_dir_all(&dir).ok();
}

/// Poisson-sampling mode runs and matches the fixed batch ABI.
#[test]
fn poisson_sampling_mode() {
    let eng = engine();
    let opts = TrainOptions {
        config: "mlp2_mnist_b32".into(),
        method: ClipMethod::Reweight,
        steps: 8,
        dataset_n: 512,
        poisson: true,
        log_every: 0,
        ..Default::default()
    };
    let report = train(eng, &opts).unwrap();
    assert_eq!(report.losses.len(), 8);
}

/// Every fig5 config's fwd + reweight artifacts load and execute.
#[test]
fn all_fig5_configs_execute() {
    let eng = engine();
    for cfg in eng.manifest.by_tag("fig5") {
        let out = run_method(&cfg.name, ClipMethod::Reweight, 1.0);
        assert!(out.loss.is_finite(), "{} loss", cfg.name);
        assert_eq!(out.grads.len(), cfg.params.len(), "{}", cfg.name);
        for (g, p) in out.grads.iter().zip(&cfg.params) {
            assert_eq!(g.len(), p.elems(), "{}.{}", cfg.name, p.name);
        }
    }
}
